#!/usr/bin/env python3
"""Convert the binary PPM grids the Rust side writes into PNGs (stdlib
only — zlib + struct). Usage: python tools/ppm2png.py grid.ppm [out.png]"""

import struct
import sys
import zlib


def read_ppm(path):
    data = open(path, "rb").read()
    # header: P6\n<w> <h>\n255\n
    parts = data.split(b"\n", 3)
    assert parts[0] == b"P6", "not a binary PPM"
    w, h = map(int, parts[1].split())
    assert parts[2] == b"255"
    raw = parts[3]
    assert len(raw) >= w * h * 3
    return w, h, raw[: w * h * 3]


def write_png(path, w, h, rgb):
    def chunk(tag, payload):
        out = struct.pack(">I", len(payload)) + tag + payload
        return out + struct.pack(">I", zlib.crc32(tag + payload) & 0xFFFFFFFF)

    ihdr = struct.pack(">IIBBBBB", w, h, 8, 2, 0, 0, 0)
    scanlines = b"".join(
        b"\x00" + rgb[y * w * 3 : (y + 1) * w * 3] for y in range(h)
    )
    png = (
        b"\x89PNG\r\n\x1a\n"
        + chunk(b"IHDR", ihdr)
        + chunk(b"IDAT", zlib.compress(scanlines, 9))
        + chunk(b"IEND", b"")
    )
    open(path, "wb").write(png)


if __name__ == "__main__":
    src = sys.argv[1]
    dst = sys.argv[2] if len(sys.argv) > 2 else src.rsplit(".", 1)[0] + ".png"
    w, h, rgb = read_ppm(src)
    write_png(dst, w, h, rgb)
    print(f"wrote {dst} ({w}x{h})")
