#!/usr/bin/env python3
"""Observability regression gates for benches/serving.rs part 5.

The serving bench's trace part (`cargo bench --bench serving -- --trace-only`)
writes bench_out/serving_trace.json; this script turns it into a CI gate
(mirroring tools/check_async.py):

  * span chains: every completed generate span must carry the full
    lifecycle (submit -> admit -> first/last dispatch -> end) with
    monotonic timestamps, >= 1 dispatch, and queued_s + exec_s <= e2e_s
    (the derived stage times must not exceed end-to-end). The ring must
    also hold at least one eval-kind span and one canceled span — the
    mixed workload the bench drives.
  * timeline: the dispatch-timeline ring must be non-empty, with each
    record carrying non-negative phase durations and k >= 1 fused steps.
  * metrics: the Prometheus text must parse line by line (every sample
    a `name{labels} value` with a float value, every name declared by
    exactly one preceding `# TYPE` line) and contain the required
    series (pool step-time quantiles + count/sum, adaptive
    accept/reject, request latency, job counters).
  * overhead: steps/s with the span ring enabled must be >= 0.95x the
    ring-off throughput — tracing must stay off the hot step path.

Usage: python3 tools/check_trace.py bench_out/serving_trace.json
Exits non-zero with a per-violation report on failure.
"""

import json
import re
import sys

EPS = 1e-6

REQUIRED_SERIES = [
    "gofast_requests_done_total",
    "gofast_samples_done_total",
    "gofast_request_latency_seconds",
    "gofast_pool_step_seconds",
    "gofast_pool_step_seconds_count",
    "gofast_pool_step_seconds_sum",
    "gofast_pool_adaptive_accepted_total",
    "gofast_pool_adaptive_rejected_total",
    "gofast_pool_adaptive_reject_rate",
    "gofast_pool_bucket_steps_total",
    "gofast_health_status",
    "gofast_health_events_total",
    "gofast_jobs_submitted_total",
    "gofast_jobs_delivered_total",
    "gofast_canceled_total",
]

SAMPLE_RE = re.compile(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})? (\S+)$")
TYPE_RE = re.compile(r"^# TYPE ([a-zA-Z_:][a-zA-Z0-9_:]*) (counter|gauge)$")


def check_spans(spans, errors):
    complete_gen = 0
    evals = 0
    canceled = 0
    for s in spans:
        sid = s.get("id")
        if sid is None or "submit_s" not in s or "kind" not in s:
            errors.append(f"span {s}: missing id/kind/submit_s")
            continue
        if s.get("kind") == "eval":
            evals += 1
        if s.get("outcome") == "canceled":
            canceled += 1
        if s.get("outcome") != "complete":
            continue
        stages = ["submit_s", "admit_s", "first_dispatch_s", "last_dispatch_s", "end_s"]
        missing = [k for k in stages if k not in s]
        if missing:
            errors.append(f"span {sid}: complete but missing {missing}")
            continue
        ts = [s[k] for k in stages]
        if any(b < a - EPS for a, b in zip(ts, ts[1:])):
            errors.append(f"span {sid}: non-monotonic stage timestamps {ts}")
        if s.get("dispatches", 0) < 1:
            errors.append(f"span {sid}: complete with no dispatches")
        q, x, e = s.get("queued_s", 0.0), s.get("exec_s", 0.0), s.get("e2e_s", 0.0)
        if q + x > e + EPS:
            errors.append(f"span {sid}: queued {q} + exec {x} > e2e {e}")
        if s.get("kind") == "generate":
            complete_gen += 1
    if complete_gen < 1:
        errors.append(f"spans: no complete generate chains ({len(spans)} spans)")
    if evals < 1:
        errors.append("spans: no eval-kind spans (the bench ran an evaluate)")
    if canceled < 1:
        errors.append("spans: no canceled span (the bench canceled a queued job)")
    return complete_gen, evals, canceled


def check_timeline(timeline, errors):
    if not timeline:
        errors.append("timeline: dispatch-timeline ring is empty")
        return
    for i, d in enumerate(timeline):
        if d.get("k", 0) < 1:
            errors.append(f"timeline[{i}]: k < 1 ({d.get('k')})")
        for k in ("upload_s", "exec_s", "download_s"):
            if d.get(k, 0.0) < 0.0:
                errors.append(f"timeline[{i}]: negative {k} ({d.get(k)})")


def check_metrics(text, errors):
    if not text:
        errors.append("metrics: empty Prometheus text")
        return
    typed = {}
    seen = set()
    for ln, line in enumerate(text.splitlines(), 1):
        if not line:
            continue
        m = TYPE_RE.match(line)
        if m:
            if m.group(1) in typed:
                errors.append(f"metrics line {ln}: duplicate TYPE for {m.group(1)}")
            typed[m.group(1)] = m.group(2)
            continue
        m = SAMPLE_RE.match(line)
        if not m:
            errors.append(f"metrics line {ln}: unparseable: {line!r}")
            continue
        name = m.group(1)
        seen.add(name)
        if name not in typed:
            errors.append(f"metrics line {ln}: sample {name} before its # TYPE")
        try:
            float(m.group(3))
        except ValueError:
            errors.append(f"metrics line {ln}: non-float value {m.group(3)!r}")
    for name in REQUIRED_SERIES:
        if name not in seen:
            errors.append(f"metrics: required series {name} absent")
    return len(seen)


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "bench_out/serving_trace.json"
    with open(path) as f:
        doc = json.load(f)
    errors = []

    spans = doc.get("spans", [])
    gen, evals, canceled = check_spans(spans, errors)
    check_timeline(doc.get("timeline", []), errors)
    n_series = check_metrics(doc.get("metrics_text", ""), errors)

    ring = doc.get("ring", {})
    off, on = ring.get("off_steps_per_s", 0.0), ring.get("on_steps_per_s", 0.0)
    ratio = ring.get("ratio", 0.0)
    if off <= 0 or on <= 0:
        errors.append(f"overhead: missing throughput numbers (off={off}, on={on})")
    elif ratio < 0.95:
        errors.append(
            f"overhead: ring-on throughput {on:.0f} steps/s is {ratio:.3f}x "
            f"ring-off {off:.0f} (must be >= 0.95x)"
        )

    print(
        f"[check_trace] {path}: spans={len(spans)} complete_generate={gen} "
        f"eval={evals} canceled={canceled} series={n_series} ring_ratio={ratio:.3f}"
    )
    if errors:
        for e in errors:
            print(f"[check_trace] FAIL: {e}", file=sys.stderr)
        return 1
    print("[check_trace] ok: span chains, timeline, metrics and overhead hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
