#!/usr/bin/env python3
"""FID*-vs-NFE regression thresholds for benches/eval.rs output.

The eval bench (benches/eval.rs) runs every served solver (adaptive /
em / ddim / pc) through the engine's lane-program pools AND through the
offline per-lane bypass, and records the served-vs-offline deltas in
bench_out/eval.json. This script turns that upload-only artifact into a
CI gate:

  * parity: for every served row — the predictor–corrector rows exactly
    like em/ddim — |d_nfe| must be 0 (the per-lane RNG contract makes
    NFE exactly equal) and |d_fid| / |d_is| within 1e-6 relative — the
    engine-vs-offline agreement criterion;
  * NFE accounting: every served pc row's mean NFE must equal
    2 x predictor steps + 1 (two score evals per PC step, one denoise)
    — a drifted StepKernel cost table fails here;
  * sanity: every FID*/IS* finite, FID* >= 0, IS* >= 1 - 1e-9;
  * regression ceiling: served FID* must stay below EVAL_FID_MAX
    (env, default 5000 — generous enough for the miniature CI models,
    tight enough to catch a diverged solver or a broken feature net);
  * coverage: EVAL_REQUIRE_SOLVERS (env, comma list, default empty)
    names solvers that MUST contribute parity rows — CI sets
    adaptive,em,ddim,pc so a silently skipped pool cannot pass.

Usage: python3 tools/check_eval.py bench_out/eval.json
Exits non-zero with a per-violation report on failure.
"""

import json
import math
import os
import sys


def rel(delta: float, base: float) -> float:
    return abs(delta) / max(abs(base), 1.0)


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "bench_out/eval.json"
    fid_max = float(os.environ.get("EVAL_FID_MAX", "5000"))
    require = [
        s.strip()
        for s in os.environ.get("EVAL_REQUIRE_SOLVERS", "").split(",")
        if s.strip()
    ]
    with open(path) as f:
        doc = json.load(f)
    rows = doc.get("rows", [])
    parity = doc.get("parity", [])
    errors = []
    if not rows:
        errors.append("no rows in eval output")
    if not parity:
        errors.append("no parity entries in eval output (served rows missing?)")
    for want in require:
        if not any(p.get("solver") == want for p in parity):
            errors.append(
                f"required solver '{want}' has no parity rows "
                "(pool skipped or artifacts missing?)"
            )

    for r in rows:
        tag = f"{r.get('path')}/{r.get('solver')}/{r.get('knob')}"
        for key, lo in [("fid", 0.0), ("is", 1.0 - 1e-9)]:
            v = r.get(key)
            if v is None or not math.isfinite(v):
                errors.append(f"{tag}: {key} not finite ({v})")
            elif v < lo:
                errors.append(f"{tag}: {key}={v} below {lo}")
        if r.get("path") == "served" and math.isfinite(r.get("fid", math.nan)):
            if r["fid"] > fid_max:
                errors.append(
                    f"{tag}: FID* {r['fid']:.3f} exceeds EVAL_FID_MAX={fid_max}"
                )
        if r.get("path") == "served" and r.get("solver") == "pc":
            # pc knobs are "steps=<n>"; NFE must be 2*steps + 1 exactly
            # (predictor + corrector score evals, then the denoise call)
            knob = str(r.get("knob", ""))
            steps = int(knob.split("=", 1)[1]) if knob.startswith("steps=") else None
            nfe = r.get("mean_nfe", math.nan)
            if steps is None:
                errors.append(f"{tag}: pc row has no steps=<n> knob ({knob!r})")
            elif not (math.isfinite(nfe) and abs(nfe - (2 * steps + 1)) < 1e-9):
                errors.append(
                    f"{tag}: pc NFE {nfe} != 2 x {steps} steps + 1 denoise"
                )

    for p in parity:
        tag = f"parity/{p.get('solver')}/{p.get('knob')}"
        d_nfe = p.get("d_nfe", math.nan)
        if not (math.isfinite(d_nfe) and d_nfe == 0.0):
            errors.append(f"{tag}: served/offline NFE differ (d_nfe={d_nfe})")
        for key, base_key in [("d_fid", "fid"), ("d_is", "is")]:
            d = p.get(key, math.nan)
            base = p.get(base_key, math.nan)
            if not math.isfinite(d) or rel(d, base) > 1e-6:
                errors.append(
                    f"{tag}: served/offline {base_key} drift {key}={d} "
                    f"(rel {rel(d, base) if math.isfinite(d) else math.nan:.3e} > 1e-6)"
                )

    solvers = sorted({p.get("solver") for p in parity})
    print(
        f"[check_eval] {path}: {len(rows)} rows, parity over solvers {solvers}, "
        f"EVAL_FID_MAX={fid_max}, required={require or '-'}"
    )
    if errors:
        for e in errors:
            print(f"[check_eval] FAIL: {e}", file=sys.stderr)
        return 1
    print("[check_eval] ok: parity and FID* thresholds hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
