#!/usr/bin/env python3
"""QoS fairness/latency regression gates for benches/serving.rs part 3.

The serving bench's QoS part (`cargo bench --bench serving -- --qos-only`)
writes bench_out/serving_qos.json with two experiments; this script turns
it into a CI gate (mirroring tools/check_eval.py):

  * fairness: two saturated pools under 3:1 deficit-round-robin weights
    must receive fused steps proportional to their weights —
    |share - weight_share| / weight_share <= QOS_SHARE_TOL (env,
    default 0.10, the ±10% acceptance criterion) — and zero pools may
    starve (steps == 0). Pools must still be saturated at the snapshot
    (queue_depth > 0), else the share math covered a drained pool and
    the bench needs a deeper backlog (--qos-sat-requests).
  * latency: with priority classes on, interactive p95 under a batch
    flood must not exceed the single-class FIFO baseline
    (qos_p95 <= fifo_p95 * QOS_P95_FACTOR, default 1.0) and total
    throughput must hold (>= fifo * QOS_TPUT_FACTOR, default 0.85 to
    absorb wall-clock noise — priority reorders work, it does not add
    any).

Usage: python3 tools/check_qos.py bench_out/serving_qos.json
Exits non-zero with a per-violation report on failure.
"""

import json
import math
import os
import sys


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "bench_out/serving_qos.json"
    share_tol = float(os.environ.get("QOS_SHARE_TOL", "0.10"))
    p95_factor = float(os.environ.get("QOS_P95_FACTOR", "1.0"))
    tput_factor = float(os.environ.get("QOS_TPUT_FACTOR", "0.85"))
    with open(path) as f:
        doc = json.load(f)
    errors = []

    pools = doc.get("fairness", {}).get("pools", [])
    if len(pools) < 2:
        errors.append(f"fairness: expected >= 2 pools, got {len(pools)}")
    total_w = sum(p.get("weight", 0.0) for p in pools)
    total_steps = sum(p.get("steps", 0) for p in pools)
    for p in pools:
        tag = f"fairness/{p.get('pool')}"
        if not p.get("saturated", False):
            errors.append(
                f"{tag}: pool drained before the snapshot (queue_depth="
                f"{p.get('queue_depth')}); rerun with a deeper backlog "
                f"(--qos-sat-requests)"
            )
        if p.get("steps", 0) <= 0:
            errors.append(f"{tag}: starved (0 steps under weight {p.get('weight')})")
            continue
        if total_steps > 0 and total_w > 0:
            share = p["steps"] / total_steps
            expect = p["weight"] / total_w
            err = abs(share - expect) / expect
            if err > share_tol:
                errors.append(
                    f"{tag}: step share {share:.3f} vs weight share {expect:.3f} "
                    f"(rel err {err:.3f} > {share_tol})"
                )

    lat = doc.get("latency", {})
    fifo, qos = lat.get("fifo"), lat.get("qos")
    if not fifo or not qos:
        errors.append("latency: missing fifo/qos modes")
    else:
        lat_sane = True
        for mode, m in [("fifo", fifo), ("qos", qos)]:
            if m.get("probes", 0) <= 0:
                errors.append(f"latency/{mode}: no probes completed")
                lat_sane = False
            for key in ["p95_s", "throughput_sps"]:
                v = m.get(key)
                if v is None or not math.isfinite(v):
                    errors.append(f"latency/{mode}: {key} not finite ({v})")
                    lat_sane = False
        if lat_sane:
            if qos["p95_s"] > fifo["p95_s"] * p95_factor:
                errors.append(
                    f"latency: interactive p95 regressed with QoS on "
                    f"({qos['p95_s']:.3f}s > {fifo['p95_s']:.3f}s * {p95_factor})"
                )
            if qos["throughput_sps"] < fifo["throughput_sps"] * tput_factor:
                errors.append(
                    f"latency: QoS reduced throughput "
                    f"({qos['throughput_sps']:.2f} < {fifo['throughput_sps']:.2f} "
                    f"* {tput_factor} samples/s)"
                )

    print(
        f"[check_qos] {path}: {len(pools)} pools, share_tol={share_tol}, "
        f"p95_factor={p95_factor}, tput_factor={tput_factor}"
    )
    if fifo and qos and "p95_s" in fifo and "p95_s" in qos:
        speedup = fifo["p95_s"] / max(qos["p95_s"], 1e-9)
        print(
            f"[check_qos] interactive p95: fifo {fifo['p95_s']:.3f}s -> "
            f"qos {qos['p95_s']:.3f}s ({speedup:.1f}x)"
        )
    if errors:
        for e in errors:
            print(f"[check_qos] FAIL: {e}", file=sys.stderr)
        return 1
    print("[check_qos] ok: weighted shares and priority latency hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
