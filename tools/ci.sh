#!/usr/bin/env bash
# Tier-1 verification: build + tests + formatting. Artifact-dependent
# integration tests skip themselves when `make artifacts` has not run,
# so this works on a fresh checkout.
set -euo pipefail
cd "$(dirname "$0")/.."

if [ ! -f artifacts/manifest.json ]; then
  echo "NOTE: artifacts/ absent — artifact-gated integration tests (incl. the" >&2
  echo "bucket-migration determinism tests) self-skip; run 'make artifacts'" >&2
  echo "before trusting a green run for serving-path coverage." >&2
fi

cargo build --release
cargo test --release -q
cargo fmt --check
