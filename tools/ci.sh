#!/usr/bin/env bash
# Tier-1 verification: build + tests + lints + formatting.
# Artifact-dependent integration tests skip themselves when
# `make artifacts` has not run, so this works on a fresh checkout; the CI
# `artifacts` job builds a miniature set so they actually execute there.
set -euo pipefail
cd "$(dirname "$0")/.."

if [ ! -f artifacts/manifest.json ]; then
  echo "NOTE: artifacts/ absent — artifact-gated integration tests (incl. the" >&2
  echo "bucket-migration determinism and engine-evaluate tests) self-skip; run" >&2
  echo "'make artifacts' (or see the ci.yml artifacts job for the miniature" >&2
  echo "recipe) before trusting a green run for serving-path coverage." >&2
fi

cargo build --release
cargo test --release -q
cargo clippy --all-targets -- -D warnings
cargo fmt --check

# FID*-vs-NFE regression thresholds: when the eval bench has produced
# its JSON (the CI artifacts job runs `cargo bench --bench eval` first),
# enforce served-vs-offline parity (adaptive/em/ddim and the pc rows,
# whose NFE must also equal 2 x predictor steps + 1) and the FID*
# ceiling instead of merely uploading the curve. The CI artifacts job
# additionally sets EVAL_REQUIRE_SOLVERS so no pool silently skips.
if [ -f bench_out/eval.json ]; then
  python3 tools/check_eval.py bench_out/eval.json
fi

# QoS fairness/latency gates: when the serving bench's QoS part has run
# (`cargo bench --bench serving -- --qos-only` in the CI artifacts job),
# enforce weighted-share proportionality (±10%, zero starved pools) and
# the interactive-p95 / throughput criteria on its JSON.
if [ -f bench_out/serving_qos.json ]; then
  python3 tools/check_qos.py bench_out/serving_qos.json
fi

# Async-job gates: when the serving bench's async part has run
# (`cargo bench --bench serving -- --async-only` in the CI artifacts
# job), enforce exactly-once submit->poll delivery and the
# binary-frame-vs-base64 payload reduction on its JSON.
if [ -f bench_out/serving_async.json ]; then
  python3 tools/check_async.py bench_out/serving_async.json
fi

# Observability gates: when the serving bench's trace part has run
# (`cargo bench --bench serving -- --trace-only` in the CI artifacts
# job), enforce complete span chains, dispatch-timeline sanity,
# well-formed Prometheus text with the required series, and the
# <= 5% tracing-overhead ceiling on its JSON.
if [ -f bench_out/serving_trace.json ]; then
  python3 tools/check_trace.py bench_out/serving_trace.json
fi

# Diagnostics/watchdog gates: when the serving bench's diag part has
# run (`cargo bench --bench serving -- --diag-only` in the CI artifacts
# job), enforce a contiguous monotone bin grid, exact profile-vs-stats
# accept/reject reconciliation, the injected stall firing through both
# the health op and the Prometheus text, and the <= 5% sampling
# overhead ceiling on its JSON.
if [ -f bench_out/serving_diag.json ]; then
  python3 tools/check_diag.py bench_out/serving_diag.json
fi

# Dispatch-amortisation gates: when the perf bench's k-sweep has run
# (`cargo bench --bench perf` in the CI artifacts job), enforce
# bit-identical samples, unchanged NFE/score_evals and — for the
# adaptive accept/reject fold — unchanged rejections across
# steps-per-dispatch k in {1,4,8}, roughly k-fold fewer dispatches,
# and reduced host<->device bytes on its JSON (one sweep each for the
# em and adaptive pools).
if [ -f bench_out/perf_dispatch.json ]; then
  python3 tools/check_perf.py bench_out/perf_dispatch.json
fi
