#!/usr/bin/env python3
"""Async-job delivery/payload regression gates for benches/serving.rs part 4.

The serving bench's async part (`cargo bench --bench serving -- --async-only`)
writes bench_out/serving_async.json; this script turns it into a CI gate
(mirroring tools/check_qos.py):

  * delivery: every submitted job must be drained through poll exactly
    once — delivered == submitted, zero duplicates, zero failed jobs.
    The async layer adds scheduling, it must not lose or re-deliver work.
  * payload: the negotiated binary frame must be strictly smaller than
    the base64 payload it replaces, both as the payload field alone and
    as the total wire footprint (header line + frame vs the b64 line) —
    otherwise the framing negotiation is pure overhead.

Usage: python3 tools/check_async.py bench_out/serving_async.json
Exits non-zero with a per-violation report on failure.
"""

import json
import sys


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "bench_out/serving_async.json"
    with open(path) as f:
        doc = json.load(f)
    errors = []

    submitted = doc.get("submitted", 0)
    delivered = doc.get("delivered", 0)
    duplicates = doc.get("duplicates", 0)
    failures = doc.get("failures", 0)
    if submitted <= 0:
        errors.append(f"delivery: no jobs submitted ({submitted})")
    if delivered != submitted:
        errors.append(
            f"delivery: {delivered} of {submitted} submitted jobs drained "
            f"(every job must be delivered exactly once)"
        )
    if duplicates != 0:
        errors.append(f"delivery: {duplicates} duplicate/unexpected deliveries")
    if failures != 0:
        errors.append(f"delivery: {failures} jobs completed with an error")

    payload = doc.get("payload", {})
    b64 = payload.get("b64_bytes", 0)
    b64_total = payload.get("b64_total_bytes", 0)
    bin_ = payload.get("bin_bytes", 0)
    bin_total = payload.get("bin_total_bytes", 0)
    if bin_ <= 0 or b64 <= 0:
        errors.append(f"payload: missing byte counts (b64={b64}, bin={bin_})")
    else:
        if bin_ >= b64:
            errors.append(
                f"payload: binary frame not smaller than base64 "
                f"({bin_} >= {b64} bytes)"
            )
        if bin_total >= b64_total:
            errors.append(
                f"payload: binary wire footprint not smaller than base64 "
                f"({bin_total} >= {b64_total} bytes)"
            )

    print(
        f"[check_async] {path}: submitted={submitted} delivered={delivered} "
        f"duplicates={duplicates} failures={failures}"
    )
    if b64 and bin_:
        print(
            f"[check_async] payload: base64 {b64} -> binary {bin_} bytes "
            f"({b64 / max(bin_, 1):.2f}x), wire {b64_total} -> {bin_total}"
        )
    if errors:
        for e in errors:
            print(f"[check_async] FAIL: {e}", file=sys.stderr)
        return 1
    print("[check_async] ok: exactly-once delivery and binary framing hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
