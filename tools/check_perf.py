#!/usr/bin/env python3
"""Dispatch-amortisation regression gates for benches/perf.rs part 4.

The perf bench's dispatch part (`cargo bench --bench perf`) runs the
same em and adaptive requests through engines at steps-per-dispatch
k in {1, 4, 8} and writes bench_out/perf_dispatch.json; this script
turns it into a CI gate (mirroring tools/check_qos.py):

  * equivalence: every k must produce bit-identical samples to k = 1
    (outputs_match), the identical per-sample NFE / total score-eval
    budget, and — for the adaptive fold, whose rejected attempts still
    run the score net — the identical rejection count. Fusing amortises
    launches, it must never change the math or the billing.
  * amortisation: at k > 1 dispatches must fall roughly k-fold —
    dispatches(k) <= dispatches(1) / k * (1 + PERF_DISPATCH_TOL, env,
    default 0.10) + PERF_DISPATCH_SLACK (env, default 16: denoise calls
    and no-op tail dispatches of lanes whose schedule is not a multiple
    of k) — and must never increase.
  * transfers: device-resident lane state must shrink both transfer
    directions — bytes_h2d(k) < bytes_h2d(1) and
    bytes_d2h(k) < bytes_d2h(1) (for fixed-step pools the per-step x
    round-trip is the bulk of k = 1 traffic; for the adaptive fold the
    per-attempt state download is replaced by the 4k-scalar-per-lane
    attempt log).

The JSON carries one entry per solver under "sweeps"; the pre-fold
single-sweep shape ("sweep" at top level) is still accepted.

Usage: python3 tools/check_perf.py bench_out/perf_dispatch.json
Exits non-zero with a per-violation report on failure.
"""

import json
import os
import sys


def check_sweep(doc: dict, tol: float, slack: float) -> list[str]:
    errors = []
    solver = doc.get("solver", "?")
    sweep = {int(e.get("k", 0)): e for e in doc.get("sweep", [])}
    base = sweep.get(1)
    if base is None:
        errors.append(f"{solver}: missing the k=1 baseline entry")
    fused = sorted(k for k in sweep if k > 1)
    if not fused:
        errors.append(f"{solver}: no fused entries (got k={sorted(sweep)})")

    if base is not None:
        for k in fused:
            e = sweep[k]
            tag = f"{solver} k={k}"
            if not e.get("outputs_match", False):
                errors.append(f"{tag}: samples not bit-identical to k=1")
            for key in ["nfe_total", "score_evals", "rejections"]:
                if key not in base and key not in e:
                    continue
                if e.get(key) != base.get(key):
                    errors.append(
                        f"{tag}: {key} changed ({base.get(key)} -> {e.get(key)}); "
                        f"fusing must not change the NFE/attempt accounting"
                    )
            d1, dk = base.get("dispatches", 0), e.get("dispatches", 0)
            bound = d1 / k * (1 + tol) + slack
            if dk > bound:
                errors.append(
                    f"{tag}: dispatches {dk} > {bound:.1f} "
                    f"(= {d1}/{k} * (1+{tol}) + {slack}); launches not amortised"
                )
            if dk > d1:
                errors.append(f"{tag}: dispatches increased ({d1} -> {dk})")
            for key in ["bytes_h2d", "bytes_d2h"]:
                if e.get(key, 0) >= base.get(key, 0):
                    errors.append(
                        f"{tag}: {key} not reduced "
                        f"({base.get(key)} -> {e.get(key)}); lane state is "
                        f"round-tripping instead of staying device-resident"
                    )

    if base is not None:
        for k in fused:
            e = sweep[k]
            d1 = max(base.get("dispatches", 0), 1)
            print(
                f"[check_perf] {solver} k={k}: dispatches {base.get('dispatches')} "
                f"-> {e.get('dispatches')} "
                f"({d1 / max(e.get('dispatches', 0), 1):.1f}x), "
                f"bytes/sample {base.get('bytes_per_sample', 0):.0f} -> "
                f"{e.get('bytes_per_sample', 0):.0f}"
            )
    return errors


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "bench_out/perf_dispatch.json"
    tol = float(os.environ.get("PERF_DISPATCH_TOL", "0.10"))
    slack = float(os.environ.get("PERF_DISPATCH_SLACK", "16"))
    with open(path) as f:
        doc = json.load(f)

    # one sweep per solver; the pre-fold shape held a single em sweep
    # at the top level
    sweeps = doc.get("sweeps")
    if sweeps is None:
        sweeps = [doc]

    print(
        f"[check_perf] {path}: solvers "
        f"{[d.get('solver') for d in sweeps]}, tol={tol}, slack={slack}"
    )
    errors = []
    solvers = set()
    for d in sweeps:
        solvers.add(str(d.get("solver", "?")).split(":")[0])
        errors.extend(check_sweep(d, tol, slack))
    # the tentpole gate: a multi-sweep file must cover the adaptive fold
    if len(sweeps) > 1 and "adaptive" not in solvers:
        errors.append(f"sweeps missing the adaptive fold (got {sorted(solvers)})")

    if errors:
        for e in errors:
            print(f"[check_perf] FAIL: {e}", file=sys.stderr)
        return 1
    print("[check_perf] ok: bit-identical samples at k-fold fewer dispatches")
    return 0


if __name__ == "__main__":
    sys.exit(main())
