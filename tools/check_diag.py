#!/usr/bin/env python3
"""Diagnostics + watchdog regression gates for benches/serving.rs part 6.

The serving bench's diag part (`cargo bench --bench serving -- --diag-only`)
writes bench_out/serving_diag.json; this script turns it into a CI gate
(mirroring tools/check_trace.py):

  * profile bin grid: every pool's 32 bins must tile [t_lo, t_hi]
    contiguously and monotonically in diffusion time (bin i's t_hi ==
    bin i+1's t_lo, strictly increasing), with per-bin h_min <= h_max
    and non-negative counts.
  * reconciliation: for every adaptive pool, sum(accepted + rejected)
    across bins must equal the pool's stats accept/reject counters
    exactly — the profile and the QoS counters are fed from the same
    step fold, so any drift is double- or under-counting.
  * sampling: with --diag-sample 1 every admitted lane is traced, so
    the adaptive pool must retain at least one trace whose steps carry
    (t, h, err, accepted) with t in [0, 1] and h > 0.
  * watchdog: the stall-injection run (zero budget, per-iteration
    checks, two active pools) must have fired at least one stall
    event, observable in both the health op's counters and the
    Prometheus text (gofast_health_status gauge +
    gofast_health_events_total{kind="stall"} counter).
  * overhead: steps/s with --diag-sample 1 must be >= 0.95x the
    diag-off throughput — diagnostics must stay off the hot step path.

Usage: python3 tools/check_diag.py bench_out/serving_diag.json
Exits non-zero with a per-violation report on failure.
"""

import json
import re
import sys

EPS = 1e-9

HEALTH_SERIES_RE = re.compile(
    r'^gofast_health_events_total\{kind="stall"\} (\d+(?:\.\d+)?)$', re.M
)


def check_bins(pool, errors):
    name = f"{pool.get('model')}/{pool.get('solver')}"
    bins = pool.get("bins", [])
    if not bins:
        errors.append(f"{name}: empty bin grid")
        return
    t_lo, t_hi = pool.get("t_lo", 0.0), pool.get("t_hi", 1.0)
    if not t_lo < t_hi:
        errors.append(f"{name}: degenerate grid [{t_lo}, {t_hi}]")
    if abs(bins[0].get("t_lo", -1) - t_lo) > 1e-6:
        errors.append(f"{name}: first bin starts at {bins[0].get('t_lo')}, not {t_lo}")
    if abs(bins[-1].get("t_hi", -1) - t_hi) > 1e-6:
        errors.append(f"{name}: last bin ends at {bins[-1].get('t_hi')}, not {t_hi}")
    for i, b in enumerate(bins):
        if not b.get("t_lo", 0.0) < b.get("t_hi", 0.0):
            errors.append(f"{name} bin {i}: t_lo {b.get('t_lo')} !< t_hi {b.get('t_hi')}")
        if i and abs(b.get("t_lo", -1) - bins[i - 1].get("t_hi", -2)) > 1e-6:
            errors.append(
                f"{name} bin {i}: grid not contiguous "
                f"({bins[i - 1].get('t_hi')} -> {b.get('t_lo')})"
            )
        for k in ("steps", "accepted", "rejected"):
            if b.get(k, 0) < 0:
                errors.append(f"{name} bin {i}: negative {k}")
        if b.get("accepted", 0) + b.get("rejected", 0) > 0:
            if b.get("h_min", 0.0) > b.get("h_max", 0.0) + EPS:
                errors.append(
                    f"{name} bin {i}: h_min {b.get('h_min')} > h_max {b.get('h_max')}"
                )


def check_reconciliation(profile, errors):
    stats = {p.get("pool"): p for p in profile.get("stats_pools", [])}
    adaptive_pools = 0
    for pool in profile.get("pools", []):
        check_bins(pool, errors)
        name = f"{pool.get('model')}/{pool.get('solver')}"
        if not pool.get("adaptive"):
            continue
        adaptive_pools += 1
        acc = sum(b.get("accepted", 0) for b in pool.get("bins", []))
        rej = sum(b.get("rejected", 0) for b in pool.get("bins", []))
        s = stats.get(name)
        if s is None:
            errors.append(f"{name}: adaptive pool missing from stats_pools")
            continue
        if acc != s.get("accepted") or rej != s.get("rejected"):
            errors.append(
                f"{name}: profile bins sum to {acc} accepted / {rej} rejected, "
                f"stats counters say {s.get('accepted')} / {s.get('rejected')}"
            )
        if acc + rej < 1:
            errors.append(f"{name}: adaptive pool saw no proposals")
    if adaptive_pools < 1:
        errors.append("profile: no adaptive pools (the bench drives adaptive traffic)")


def check_traces(profile, errors):
    traced_steps = 0
    for pool in profile.get("pools", []):
        if not pool.get("adaptive"):
            continue
        for t in pool.get("traces", []):
            for s in t.get("steps", []):
                traced_steps += 1
                if not -EPS <= s.get("t", -1.0) <= 1.0 + EPS:
                    errors.append(f"trace lane {t.get('lane')}: t out of range {s.get('t')}")
                if s.get("h", 0.0) <= 0.0:
                    errors.append(f"trace lane {t.get('lane')}: non-positive h {s.get('h')}")
    if traced_steps < 1:
        errors.append("traces: --diag-sample 1 run retained no adaptive trace steps")
    return traced_steps


def check_stall(stall, metrics_text, errors):
    count = stall.get("counts", {}).get("stall", 0)
    if not stall.get("fired") or count < 1:
        errors.append(f"stall: injection run fired no stall event (count {count})")
    if not any(e.get("kind") == "stall" for e in stall.get("events", [])):
        errors.append("stall: no stall event in the health ring")
    if "gofast_health_status" not in metrics_text:
        errors.append("metrics: gofast_health_status gauge absent")
    m = HEALTH_SERIES_RE.search(metrics_text)
    if m is None:
        errors.append('metrics: gofast_health_events_total{kind="stall"} absent')
    elif float(m.group(1)) < 1:
        errors.append(f"metrics: stall counter {m.group(1)} < 1 despite injection")
    return count


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "bench_out/serving_diag.json"
    with open(path) as f:
        doc = json.load(f)
    errors = []

    profile = doc.get("profile", {})
    check_reconciliation(profile, errors)
    traced = check_traces(profile, errors)
    stalls = check_stall(doc.get("stall", {}), doc.get("metrics_text", ""), errors)

    overhead = doc.get("overhead", {})
    off = overhead.get("off_steps_per_s", 0.0)
    on = overhead.get("on_steps_per_s", 0.0)
    ratio = overhead.get("ratio", 0.0)
    if off <= 0 or on <= 0:
        errors.append(f"overhead: missing throughput numbers (off={off}, on={on})")
    elif ratio < 0.95:
        errors.append(
            f"overhead: diag-on throughput {on:.0f} steps/s is {ratio:.3f}x "
            f"diag-off {off:.0f} (must be >= 0.95x)"
        )

    print(
        f"[check_diag] {path}: pools={len(profile.get('pools', []))} "
        f"traced_steps={traced} stall_events={stalls} diag_ratio={ratio:.3f}"
    )
    if errors:
        for e in errors:
            print(f"[check_diag] FAIL: {e}", file=sys.stderr)
        return 1
    print("[check_diag] ok: bin grid, reconciliation, watchdog and overhead hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
