//! Quickstart: load a trained model, sample a batch with the paper's
//! adaptive solver (Algorithm 1), report NFE, and write an image grid.
//!
//!   cargo run --release --offline --example quickstart -- [model] [eps_rel]

use gofast::rng::Rng;
use gofast::runtime::Runtime;
use gofast::solvers::{adaptive, Ctx, SolveOpts};
use gofast::tensor::save_image_grid;
use gofast::Result;
use std::path::Path;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model_name = args.get(1).map(String::as_str).unwrap_or("vp");
    let eps_rel: f64 = args.get(2).map(|s| s.parse().unwrap()).unwrap_or(0.05);

    // 1. runtime over the AOT artifacts (python never runs here)
    let rt = Runtime::new(Path::new("artifacts"))?;
    let model = rt.model(model_name)?;
    println!(
        "loaded {}: {} process, {}x{} images, {} params",
        model.meta.name, model.meta.sde_kind, model.meta.h, model.meta.w, model.meta.n_params
    );

    // 2. solve 16 reverse diffusions with per-sample adaptive steps
    let ctx = Ctx::new(&model, 16, SolveOpts::default());
    let mut rng = Rng::new(42);
    let opts = adaptive::AdaptiveOpts::with_eps_rel(eps_rel);
    let t0 = std::time::Instant::now();
    let res = adaptive::run_fused(&ctx, &mut rng, &opts)?;
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "eps_rel={eps_rel}: mean NFE {:.1} (min {} / max {}), {} rejections, {:.2}s",
        res.mean_nfe(),
        res.nfe_per_sample.iter().min().unwrap(),
        res.max_nfe(),
        res.rejections,
        wall,
    );

    // 3. write the grid
    let mut images = res.x;
    model.meta.process().to_unit_range(&mut images);
    save_image_grid(Path::new("quickstart.ppm"), &images, model.meta.h, model.meta.w, 4)?;
    println!("wrote quickstart.ppm");
    Ok(())
}
