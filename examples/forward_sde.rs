//! Algorithm 2 (paper App. C): the general forward-time adaptive solver
//! on three classic SDEs, checked against analytic moments — no score
//! network involved, pure host math.
//!
//!   cargo run --release --offline --example forward_sde

use gofast::rng::Rng;
use gofast::solvers::general::{solve, GeneralOpts, NoiseKind};
use gofast::Result;

fn main() -> Result<()> {
    let mut master = Rng::new(2024);

    // --- Ornstein-Uhlenbeck: dx = -a x dt + s dw ---------------------------
    let (a, s) = (1.5, 0.8);
    let mut finals = Vec::new();
    let mut total_steps = 0u64;
    for k in 0..400 {
        let mut rng = master.fork(k);
        let traj = solve(
            |x, _t, out| out.iter_mut().zip(x).for_each(|(o, &xi)| *o = -a * xi),
            |_x, _t, out| out.iter_mut().for_each(|o| *o = s),
            &[3.0],
            0.0,
            6.0,
            &mut rng,
            &GeneralOpts { eps_rel: 0.05, eps_abs: 1e-3, ..Default::default() },
        )?;
        total_steps += traj.steps;
        finals.push(traj.final_state()[0]);
    }
    let n = finals.len() as f64;
    let mean = finals.iter().sum::<f64>() / n;
    let var = finals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
    println!("Ornstein-Uhlenbeck  (400 paths, {:.0} avg steps/path)", total_steps as f64 / n);
    println!("  stationary mean: {mean:+.4}   (analytic 0)");
    println!("  stationary var:  {var:.4}   (analytic s^2/2a = {:.4})", s * s / (2.0 * a));

    // --- Geometric Brownian motion (Itō, state-dependent g) -----------------
    let (mu, sigma) = (0.25, 0.5);
    let mut sum = 0.0;
    let paths = 3000;
    for k in 0..paths {
        let mut rng = master.fork(10_000 + k);
        let traj = solve(
            |x, _t, out| out[0] = mu * x[0],
            |x, _t, out| out[0] = sigma * x[0],
            &[1.0],
            0.0,
            1.0,
            &mut rng,
            &GeneralOpts {
                eps_rel: 0.02,
                eps_abs: 1e-4,
                noise: NoiseKind::ItoStateDependent,
                ..Default::default()
            },
        )?;
        sum += traj.final_state()[0];
    }
    let mean = sum / paths as f64;
    println!("Geometric Brownian motion ({paths} paths)");
    println!("  E[x(1)]: {mean:.4}   (analytic e^mu = {:.4})", (mu as f64).exp());

    // --- Double-well: dx = (x - x^3) dt + s dw (nonlinear, bimodal) ----------
    let s = 0.5;
    let mut left = 0;
    let paths = 500;
    for k in 0..paths {
        let mut rng = master.fork(50_000 + k);
        let traj = solve(
            |x, _t, out| out[0] = x[0] - x[0] * x[0] * x[0],
            |_x, _t, out| out[0] = s,
            &[0.0],
            0.0,
            10.0,
            &mut rng,
            &GeneralOpts { eps_rel: 0.05, eps_abs: 1e-3, ..Default::default() },
        )?;
        if traj.final_state()[0] < 0.0 {
            left += 1;
        }
    }
    println!("Double-well ({paths} paths from x=0)");
    println!(
        "  P(left basin): {:.3}   (symmetry => 0.5)",
        left as f64 / paths as f64
    );
    Ok(())
}
