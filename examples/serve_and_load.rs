//! End-to-end serving driver (docs/ARCHITECTURE.md §Server validation):
//! starts the continuous-batching engine + TCP server in-process, replays
//! a Poisson request trace with mixed sizes and tolerances through real
//! TCP client connections, and reports latency / throughput / NFE /
//! batch-occupancy / per-bucket scheduling.
//!
//!   cargo run --release --offline --example serve_and_load -- \
//!       [--model vp] [--rate 2.0] [--duration 15] [--bucket 16]

use gofast::bench::{fmt_duration, summarize};
use gofast::cli::Args;
use gofast::coordinator::{Engine, EngineConfig};
use gofast::rng::Rng;
use gofast::server::{serve, Client, GenerateRequest, ServerConfig};
use gofast::tensor::save_image_grid;
use gofast::workload::{poisson_trace, TraceConfig};
use gofast::{Context, Result};
use std::net::TcpListener;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

fn main() -> Result<()> {
    let args = Args::parse_env()?;
    let model = args.str_or("model", "vp");
    let rate = args.f64_or("rate", 2.0)?;
    let duration = args.f64_or("duration", 15.0)?;
    let bucket = args.usize_or("bucket", 16)?;

    // --- server side ---------------------------------------------------------
    let mut ecfg = EngineConfig::new("artifacts", &model);
    ecfg.bucket = bucket;
    let engine = Engine::start(ecfg).context("starting engine (run `make artifacts`)")?;
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    {
        let client = engine.client();
        std::thread::spawn(move || {
            let _ = serve(
                listener,
                client,
                ServerConfig { port: addr.port(), default_eps_rel: 0.05 },
            );
        });
    }
    println!("engine + server up on {addr} (model={model}, bucket={bucket})");

    // --- workload -------------------------------------------------------------
    let mut rng = Rng::new(7);
    let trace = poisson_trace(
        &mut rng,
        &TraceConfig {
            duration_s: duration,
            rate_rps: rate,
            n_choices: vec![1, 2, 4, 8],
            eps_choices: vec![0.02, 0.05, 0.1],
        },
    );
    println!(
        "replaying {} requests over {duration}s (Poisson, {rate} req/s, mixed eps_rel)",
        trace.len()
    );

    let lat = Arc::new(Mutex::new(Vec::<f64>::new()));
    let nfes = Arc::new(Mutex::new(Vec::<u64>::new()));
    let samples = Arc::new(Mutex::new(0usize));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for item in trace {
        // open-loop replay: wait until the arrival time, then fire
        let wait = item.at_s - t0.elapsed().as_secs_f64();
        if wait > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(wait));
        }
        let (lat, nfes, samples) = (lat.clone(), nfes.clone(), samples.clone());
        let addr_s = addr.to_string();
        handles.push(std::thread::spawn(move || {
            let t_req = Instant::now();
            let mut c = match Client::connect(&addr_s) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("connect failed: {e:#}");
                    return;
                }
            };
            let req = GenerateRequest::new(item.n)
                .eps_rel(item.eps_rel)
                .seed(item.seed)
                .images(false);
            match c.run(&req) {
                Ok(r) => {
                    lat.lock().unwrap().push(t_req.elapsed().as_secs_f64());
                    nfes.lock().unwrap().extend(r.nfe);
                    *samples.lock().unwrap() += item.n;
                }
                Err(e) => eprintln!("request failed: {e:#}"),
            }
        }));
    }
    for h in handles {
        let _ = h.join();
    }
    let elapsed = t0.elapsed().as_secs_f64();

    // --- report ----------------------------------------------------------------
    let lat = lat.lock().unwrap().clone();
    let nfes = nfes.lock().unwrap().clone();
    let n_samples = *samples.lock().unwrap();
    let stats = summarize(lat);
    let mean_nfe = nfes.iter().sum::<u64>() as f64 / nfes.len().max(1) as f64;
    let srv = engine.client().stats()?;
    println!("\n=== serve_and_load results ===");
    println!("requests completed : {}", stats.n);
    println!("samples generated  : {n_samples} ({:.2} samples/s)", n_samples as f64 / elapsed);
    println!(
        "request latency    : p50 {} p95 {} max {}",
        fmt_duration(stats.p50),
        fmt_duration(stats.p95),
        fmt_duration(stats.max)
    );
    println!("mean NFE/sample    : {mean_nfe:.1}");
    println!("engine steps       : {} ({} rejections)", srv.steps, srv.rejections);
    println!("mean occupancy     : {:.2}/{bucket} slots", srv.mean_occupancy);
    println!("score evals        : {}", srv.score_evals);
    let per_bucket = srv
        .steps_per_bucket
        .iter()
        .filter(|(_, n)| *n > 0)
        .map(|(b, n)| format!("{b}:{n}"))
        .collect::<Vec<_>>()
        .join(" ");
    println!(
        "bucket scheduling  : steps [{per_bucket}] migrations {}v/{}^ wasted lane-steps {}",
        srv.migrations_down, srv.migrations_up, srv.wasted_lane_steps
    );

    // grab one last batch of images for the record
    let mut c = Client::connect(&addr.to_string())?;
    let r = c.run(&GenerateRequest::new(16).eps_rel(0.05).seed(12345))?;
    save_image_grid(Path::new("serve_and_load.ppm"), &r.images, 16, 16, 4)?;
    println!("wrote serve_and_load.ppm");
    Ok(())
}
