//! Tolerance sweep (the paper's one free knob, §5 Limitations): show the
//! speed/quality trade-off by sweeping eps_rel and reporting NFE and, if
//! the FID nets are built, FID*/IS* per setting — a miniature Figure 1.
//!
//!   cargo run --release --offline --example tolerance_sweep -- \
//!       [--model vp] [--samples 128] [--eps 0.01,0.02,0.05,0.1,0.5]

use gofast::bench::Table;
use gofast::cli::Args;
use gofast::metrics;
use gofast::rng::Rng;
use gofast::runtime::Runtime;
use gofast::solvers::{adaptive, Ctx, SolveOpts};
use gofast::tensor::Tensor;
use gofast::Result;
use std::path::Path;

fn main() -> Result<()> {
    let args = Args::parse_env()?;
    let model_name = args.str_or("model", "vp");
    let samples = args.usize_or("samples", 128)?;
    let eps_list = args.f64_list_or("eps", &[0.01, 0.02, 0.05, 0.1, 0.5])?;

    let rt = Runtime::new(Path::new("artifacts"))?;
    let model = rt.model(&model_name)?;
    let bucket = *model.buckets("adaptive_step").last().unwrap();
    let ctx = Ctx::new(&model, bucket, SolveOpts::default());

    // FID reference (optional — NFE-only sweep if nets are not built yet)
    let fid_setup = metrics::reference_for(&rt, &model.meta).ok();

    let mut table = Table::new(&["eps_rel", "mean NFE", "reject%", "FID*", "IS*", "wall_s"]);
    for &eps in &eps_list {
        let mut rng = Rng::new(99);
        let mut images = Tensor::zeros(&[samples, model.meta.dim]);
        let mut nfe_sum = 0u64;
        let mut rej = 0u64;
        let mut attempts = 0u64;
        let t0 = std::time::Instant::now();
        let mut done = 0;
        while done < samples {
            let take = (samples - done).min(bucket);
            let res =
                adaptive::run_fused(&ctx, &mut rng, &adaptive::AdaptiveOpts::with_eps_rel(eps))?;
            for i in 0..take {
                images.row_mut(done + i).copy_from_slice(res.x.row(i));
            }
            nfe_sum += res.nfe_per_sample[..take].iter().sum::<u64>();
            rej += res.rejections;
            attempts += res.steps * bucket as u64;
            done += take;
        }
        let wall = t0.elapsed().as_secs_f64();
        model.meta.process().to_unit_range(&mut images);
        let (fid_s, is_s) = match &fid_setup {
            Some((net, refstats)) => {
                let (fid, is) = metrics::evaluate(net, &images, refstats)?;
                (format!("{fid:.2}"), format!("{is:.2}"))
            }
            None => ("-".into(), "-".into()),
        };
        table.row(vec![
            format!("{eps}"),
            format!("{:.1}", nfe_sum as f64 / samples as f64),
            format!("{:.1}", 100.0 * rej as f64 / attempts.max(1) as f64),
            fid_s,
            is_s,
            format!("{wall:.1}"),
        ]);
    }
    println!("\nmodel={model_name} samples={samples}\n");
    print!("{}", table.render());
    Ok(())
}
