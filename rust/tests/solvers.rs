//! Integration: solver semantics over real artifacts — fused/composed
//! equivalence, NFE accounting, determinism, tolerance monotonicity.

mod common;

use gofast::rng::Rng;
use gofast::runtime::Runtime;
use gofast::solvers::{adaptive, em, Ctx, SolveOpts};

fn ctx_opts() -> SolveOpts {
    SolveOpts { fused_buffers: true, denoise: true }
}

#[test]
fn em_fused_matches_composed() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let m = rt.model("vp").unwrap();
    let b = m.buckets("em_step")[0];
    let ctx = Ctx::new(&m, b, ctx_opts());
    let res_f = em::run(&ctx, &mut Rng::new(3), 16).unwrap();
    let res_c = em::run_composed(&ctx, &mut Rng::new(3), 16).unwrap();
    let diff = res_f.x.max_abs_diff(&res_c.x);
    assert!(diff < 2e-3, "fused vs composed EM diverged: {diff}");
    assert_eq!(res_f.nfe_per_sample, res_c.nfe_per_sample);
}

#[test]
fn adaptive_fused_matches_composed_trajectory() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let m = rt.model("vp").unwrap();
    let b = m.buckets("adaptive_step")[0];
    let ctx = Ctx::new(&m, b, ctx_opts());
    let opts = adaptive::AdaptiveOpts::with_eps_rel(0.05);
    let res_f = adaptive::run_fused(&ctx, &mut Rng::new(11), &opts).unwrap();
    let res_c = adaptive::run_composed(&ctx, &mut Rng::new(11), &opts).unwrap();
    // identical accept/reject sequence => identical NFE; small numeric drift
    assert_eq!(res_f.nfe_per_sample, res_c.nfe_per_sample, "accept/reject paths diverged");
    let diff = res_f.x.max_abs_diff(&res_c.x);
    assert!(diff < 5e-2, "endpoints diverged: {diff}");
}

#[test]
fn adaptive_is_deterministic_for_seed() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let m = rt.model("vp").unwrap();
    let b = m.buckets("adaptive_step")[0];
    let ctx = Ctx::new(&m, b, ctx_opts());
    let opts = adaptive::AdaptiveOpts::with_eps_rel(0.05);
    let a = adaptive::run_fused(&ctx, &mut Rng::new(7), &opts).unwrap();
    let c = adaptive::run_fused(&ctx, &mut Rng::new(7), &opts).unwrap();
    assert_eq!(a.x, c.x);
    assert_eq!(a.nfe_per_sample, c.nfe_per_sample);
}

#[test]
fn tighter_tolerance_needs_more_nfe() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let m = rt.model("vp").unwrap();
    let b = m.buckets("adaptive_step")[0];
    let ctx = Ctx::new(&m, b, ctx_opts());
    let loose = adaptive::run_fused(
        &ctx,
        &mut Rng::new(5),
        &adaptive::AdaptiveOpts::with_eps_rel(0.5),
    )
    .unwrap();
    let tight = adaptive::run_fused(
        &ctx,
        &mut Rng::new(5),
        &adaptive::AdaptiveOpts::with_eps_rel(0.01),
    )
    .unwrap();
    assert!(
        tight.mean_nfe() > loose.mean_nfe(),
        "tight {} <= loose {}",
        tight.mean_nfe(),
        loose.mean_nfe()
    );
}

#[test]
fn adaptive_nfe_is_two_per_attempt_plus_denoise() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let m = rt.model("vp").unwrap();
    let b = m.buckets("adaptive_step")[0];
    let ctx = Ctx::new(&m, b, ctx_opts());
    let res = adaptive::run_fused(
        &ctx,
        &mut Rng::new(2),
        &adaptive::AdaptiveOpts::with_eps_rel(0.05),
    )
    .unwrap();
    for &n in &res.nfe_per_sample {
        assert!(n >= 3, "at least one step + denoise");
        assert_eq!((n - 1) % 2, 0, "NFE {n}: 2 per attempt + 1 denoise");
    }
}

#[test]
fn samples_end_in_data_range_neighborhood() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let m = rt.model("vp").unwrap();
    let b = m.buckets("adaptive_step")[0];
    let ctx = Ctx::new(&m, b, ctx_opts());
    // Aggregate over several seeds: individual trajectories of a
    // relative-tolerance solver on an imperfect score net can run away
    // (delta ~ eps_rel|x| self-accepts large states), but the bulk of
    // samples must land near the VP data range [-1, 1].
    let mut total = 0usize;
    let mut out_of_range = 0usize;
    for seed in [1, 2, 3, 4] {
        let res = adaptive::run_fused(
            &ctx,
            &mut Rng::new(seed),
            &adaptive::AdaptiveOpts::with_eps_rel(0.05),
        )
        .unwrap();
        total += res.x.len();
        out_of_range += res.x.data.iter().filter(|v| v.abs() > 3.0).count();
    }
    let frac = out_of_range as f64 / total as f64;
    assert!(frac < 0.3, "{:.1}% of components unconverged", frac * 100.0);
}

#[test]
fn no_denoise_option_skips_final_eval() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let m = rt.model("vp").unwrap();
    let b = m.buckets("adaptive_step")[0];
    let ctx = Ctx::new(&m, b, SolveOpts { fused_buffers: true, denoise: false });
    let res = adaptive::run_fused(
        &ctx,
        &mut Rng::new(2),
        &adaptive::AdaptiveOpts::with_eps_rel(0.1),
    )
    .unwrap();
    for &n in &res.nfe_per_sample {
        assert_eq!(n % 2, 0, "without denoise NFE must be even, got {n}");
    }
}

#[test]
fn ve_model_solves_too() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let Ok(m) = rt.model("ve") else {
        eprintln!("skipping: ve variant not built yet");
        return;
    };
    let b = m.buckets("adaptive_step")[0];
    let ctx = Ctx::new(&m, b, ctx_opts());
    let res = adaptive::run_fused(
        &ctx,
        &mut Rng::new(4),
        &adaptive::AdaptiveOpts::with_eps_rel(0.05),
    )
    .unwrap();
    assert!(res.x.data.iter().all(|v| v.is_finite()));
    // VE needs more steps than VP at equal tolerance (paper §4.1)
    assert!(res.mean_nfe() > 10.0);
}
