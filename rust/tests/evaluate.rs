//! Integration: engine-served FID*/IS* evaluation — agreement with the
//! offline per-lane bypass (for the adaptive solver *and* the served
//! fixed-step programs), eval-lane counters, and isolation from
//! concurrent client traffic. Skips (with a note) when artifacts or the
//! fid net/eval split are missing.

mod common;

use gofast::coordinator::{Engine, EngineConfig, EvalRequest};
use gofast::metrics;
use gofast::runtime::Runtime;
use gofast::solvers::{adaptive, spec, ServingSolver};
use std::path::{Path, PathBuf};

/// The eval path additionally needs the feature net + exported split.
fn eval_artifacts() -> Option<PathBuf> {
    let dir = common::artifacts()?;
    for need in ["params/fid16.bin", "data/synth-cifar.bin"] {
        if !dir.join(need).exists() {
            eprintln!("skipping: {need} not built (run `make artifacts`)");
            return None;
        }
    }
    Some(dir)
}

fn start_engine(dir: &Path) -> Engine {
    let mut cfg = EngineConfig::new(dir.to_path_buf(), "vp");
    cfg.bucket = common::engine_bucket(dir);
    Engine::start(cfg).expect("engine start")
}

fn eval_req(solver: ServingSolver, samples: usize, eps_rel: f64, seed: u64) -> EvalRequest {
    EvalRequest { model: String::new(), solver, samples, eps_rel, seed, priority: None }
}

/// Offline twin of the engine's eval lanes for any served solver —
/// `spec::evaluate_offline_lanes`, the same implementation
/// `gofast evaluate --offline` runs for served solver specs.
fn offline_eval(
    dir: &Path,
    solver: ServingSolver,
    samples: usize,
    eps_rel: f64,
    seed: u64,
) -> (f64, f64, f64) {
    let rt = Runtime::new(dir).unwrap();
    let model = rt.model("vp").unwrap();
    let (net, refstats) = metrics::reference_for(&rt, &model.meta).unwrap();
    let opts = adaptive::AdaptiveOpts { eps_rel, ..Default::default() };
    let r = spec::evaluate_offline_lanes(&model, &net, &refstats, solver, samples, seed, &opts, 16)
        .unwrap();
    (r.fid, r.is, r.mean_nfe)
}

fn rel(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1.0)
}

/// The acceptance criterion: `evaluate` served through the engine must
/// match the offline bypass on the same model/solver/seed. 70 samples
/// spans two fid-bucket chunks, so chunked admission (`sample_base`) and
/// the ordered Chan merge are both on the line.
#[test]
fn engine_evaluate_matches_offline_bypass() {
    let Some(dir) = eval_artifacts() else { return };
    let (samples, eps, seed) = (70usize, 0.5f64, 11u64);
    let engine = start_engine(&dir);
    let served =
        engine.client().evaluate(eval_req(ServingSolver::Adaptive, samples, eps, seed)).unwrap();
    assert_eq!(served.samples, samples);
    assert_eq!(served.model, "vp");
    assert_eq!(served.solver, "adaptive");
    let consumed: u64 = served.steps_per_bucket.iter().map(|(_, n)| *n).sum();
    assert!(consumed > 0, "evaluate consumed no steps: {:?}", served.steps_per_bucket);

    let stats = engine.client().stats().unwrap();
    assert_eq!(stats.evals_done, 1);
    assert_eq!(stats.eval_samples_done, samples as u64);
    assert_eq!(stats.eval_active, 0);
    assert!(stats.eval_lane_steps > 0);
    // eval samples are engine work too
    assert_eq!(stats.samples_done, samples as u64);
    // ...but not client requests
    assert_eq!(stats.requests_done, 0);
    drop(engine);

    let (fid, is, mean_nfe) = offline_eval(&dir, ServingSolver::Adaptive, samples, eps, seed);
    assert!(
        rel(served.fid, fid) <= 1e-6,
        "FID* disagrees: served {} vs offline {}",
        served.fid,
        fid
    );
    assert!(rel(served.is, is) <= 1e-6, "IS* disagrees: served {} vs offline {}", served.is, is);
    assert_eq!(served.mean_nfe, mean_nfe, "NFE disagrees");
    assert!(served.is >= 1.0 - 1e-9);
    assert!(served.fid.is_finite() && served.fid >= 0.0);
}

/// Served fixed-step programs must agree with their offline per-lane
/// twins exactly like the adaptive solver does — the acceptance
/// criterion of the solver-program pool subsystem. 70 samples again
/// spans two fid-bucket chunks.
#[test]
fn engine_evaluate_em_matches_offline_bypass() {
    let Some(dir) = eval_artifacts() else { return };
    let solver = ServingSolver::Em { steps: 12 };
    let (samples, seed) = (70usize, 5u64);
    let engine = start_engine(&dir);
    let served = engine.client().evaluate(eval_req(solver, samples, 0.5, seed)).unwrap();
    assert_eq!(served.solver, "em:12");
    // fixed schedule: every sample costs exactly steps + denoise
    assert_eq!(served.mean_nfe, 13.0);
    let stats = engine.client().stats().unwrap();
    let em = stats
        .programs
        .iter()
        .find(|p| p.solver == "em")
        .expect("em program stats present");
    assert!(em.steps > 0, "em pool ran no steps");
    assert!(em.occupied_lane_steps > 0);
    let ad = stats.programs.iter().find(|p| p.solver == "adaptive").unwrap();
    assert_eq!(ad.steps, 0, "adaptive pool should be untouched by an em eval");
    drop(engine);

    let (fid, is, mean_nfe) = offline_eval(&dir, solver, samples, 0.5, seed);
    assert!(
        rel(served.fid, fid) <= 1e-6,
        "EM FID* disagrees: served {} vs offline {}",
        served.fid,
        fid
    );
    assert!(
        rel(served.is, is) <= 1e-6,
        "EM IS* disagrees: served {} vs offline {}",
        served.is,
        is
    );
    assert_eq!(served.mean_nfe, mean_nfe);
}

/// Same agreement contract for the deterministic DDIM program (VP only).
#[test]
fn engine_evaluate_ddim_matches_offline_bypass() {
    let Some(dir) = eval_artifacts() else { return };
    if common::program_rungs(&dir, "ddim_step").is_empty() {
        eprintln!("skipping: no ddim_step artifacts at or below the engine bucket");
        return;
    }
    let solver = ServingSolver::Ddim { steps: 9 };
    let (samples, seed) = (6usize, 21u64);
    let engine = start_engine(&dir);
    let served = engine.client().evaluate(eval_req(solver, samples, 0.5, seed)).unwrap();
    assert_eq!(served.solver, "ddim:9");
    assert_eq!(served.mean_nfe, 10.0);
    drop(engine);

    let (fid, is, mean_nfe) = offline_eval(&dir, solver, samples, 0.5, seed);
    assert!(
        rel(served.fid, fid) <= 1e-6,
        "DDIM FID* disagrees: served {} vs offline {}",
        served.fid,
        fid
    );
    assert!(rel(served.is, is) <= 1e-6, "DDIM IS* disagrees");
    assert_eq!(served.mean_nfe, mean_nfe);
}

/// Same agreement contract for the Reverse-Diffusion + Langevin
/// predictor–corrector pool: served `pc:<n>` must match
/// `rdl::run_lanes` (via the shared offline dispatcher) to <= 1e-6 and
/// report NFE = 2 x predictor steps + denoise — the acceptance
/// criterion of the pc_step lane program.
#[test]
fn engine_evaluate_pc_matches_offline_bypass() {
    let Some(dir) = eval_artifacts() else { return };
    if common::program_rungs(&dir, "pc_step").is_empty() {
        eprintln!("skipping: no pc_step artifacts at or below the engine bucket");
        return;
    }
    let solver = ServingSolver::Pc { steps: 7, snr: Some(0.17) };
    let (samples, seed) = (6usize, 13u64);
    let engine = start_engine(&dir);
    let served = engine.client().evaluate(eval_req(solver, samples, 0.5, seed)).unwrap();
    assert_eq!(served.solver, "pc:7@0.17");
    // two score evals per predictor step, plus the denoise call
    assert_eq!(served.mean_nfe, 15.0);
    let stats = engine.client().stats().unwrap();
    let pc = stats.programs.iter().find(|p| p.solver == "pc").expect("pc program stats");
    assert!(pc.steps > 0, "pc pool ran no steps");
    assert_eq!(
        pc.score_evals,
        2 * pc.occupied_lane_steps,
        "pc score-eval accounting must be 2x per lane-step"
    );
    drop(engine);

    let (fid, is, mean_nfe) = offline_eval(&dir, solver, samples, 0.5, seed);
    assert!(
        rel(served.fid, fid) <= 1e-6,
        "PC FID* disagrees: served {} vs offline {}",
        served.fid,
        fid
    );
    assert!(rel(served.is, is) <= 1e-6, "PC IS* disagrees: served {} vs offline {}", served.is, is);
    assert_eq!(served.mean_nfe, mean_nfe);
}

/// Per-lane RNG streams make an eval run independent of co-batched
/// traffic: the same request must produce the same numbers with and
/// without concurrent client generates sharing the engine — including
/// cross-program traffic on a *different* pool of the same model.
#[test]
fn evaluate_is_deterministic_under_concurrent_traffic() {
    let Some(dir) = eval_artifacts() else { return };
    let (samples, eps, seed) = (6usize, 0.5f64, 3u64);
    let quiet = {
        let engine = start_engine(&dir);
        engine
            .client()
            .evaluate(eval_req(ServingSolver::Adaptive, samples, eps, seed))
            .unwrap()
    };
    let busy = {
        let engine = start_engine(&dir);
        let bg = {
            let c = engine.client();
            std::thread::spawn(move || c.generate(8, 0.1, 999).unwrap())
        };
        let bg_em = {
            let c = engine.client();
            std::thread::spawn(move || {
                c.generate_with("", ServingSolver::Em { steps: 7 }, 3, 0.1, 77)
            })
        };
        let r = engine
            .client()
            .evaluate(eval_req(ServingSolver::Adaptive, samples, eps, seed))
            .unwrap();
        bg.join().unwrap();
        let em = bg_em.join().unwrap().unwrap();
        assert!(em.nfe.iter().all(|&n| n == 8), "em nfe {:?}", em.nfe);
        r
    };
    assert!(rel(quiet.fid, busy.fid) <= 1e-9, "fid {} vs {}", quiet.fid, busy.fid);
    assert!(rel(quiet.is, busy.is) <= 1e-9, "is {} vs {}", quiet.is, busy.is);
    assert_eq!(quiet.mean_nfe, busy.mean_nfe);
}

#[test]
fn evaluate_validates_request() {
    let Some(dir) = common::artifacts() else { return };
    let engine = start_engine(&dir);
    let err = engine
        .client()
        .evaluate(eval_req(ServingSolver::Adaptive, 0, 0.5, 0))
        .unwrap_err()
        .to_string();
    assert!(err.contains("samples"), "{err}");
    // a zero-step fixed lane has no grid and would never converge; the
    // wire parser rejects "em:0", and direct API construction must be
    // rejected at admission too (not hang the pool)
    let err = engine
        .client()
        .evaluate(eval_req(ServingSolver::Em { steps: 0 }, 2, 0.5, 0))
        .unwrap_err()
        .to_string();
    assert!(err.contains("at least 1 step"), "{err}");
    let err = engine
        .client()
        .generate_with("", ServingSolver::Ddim { steps: 0 }, 1, 0.5, 0)
        .unwrap_err()
        .to_string();
    assert!(err.contains("at least 1 step"), "{err}");
    // a degenerate Langevin snr is the same class of admission error,
    // carried with the structured bad_solver code
    let err = engine
        .client()
        .evaluate(eval_req(ServingSolver::Pc { steps: 4, snr: Some(-1.0) }, 2, 0.5, 0))
        .unwrap_err()
        .to_string();
    assert!(err.contains("bad_solver") && err.contains("snr"), "{err}");
    let err = engine
        .client()
        .evaluate(EvalRequest {
            model: "nope".to_string(),
            solver: ServingSolver::Adaptive,
            samples: 2,
            eps_rel: 0.5,
            seed: 0,
            priority: None,
        })
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown model"), "{err}");
}
