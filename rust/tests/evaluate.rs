//! Integration: engine-served FID*/IS* evaluation — agreement with the
//! offline per-lane bypass, eval-lane counters, and isolation from
//! concurrent client traffic. Skips (with a note) when artifacts or the
//! fid net/eval split are missing.

mod common;

use gofast::coordinator::{Engine, EngineConfig, EvalRequest};
use gofast::metrics;
use gofast::runtime::Runtime;
use gofast::solvers::{adaptive, Ctx, SolveOpts};
use gofast::tensor::Tensor;
use std::path::{Path, PathBuf};

/// The eval path additionally needs the feature net + exported split.
fn eval_artifacts() -> Option<PathBuf> {
    let dir = common::artifacts()?;
    for need in ["params/fid16.bin", "data/synth-cifar.bin"] {
        if !dir.join(need).exists() {
            eprintln!("skipping: {need} not built (run `make artifacts`)");
            return None;
        }
    }
    Some(dir)
}

fn start_engine(dir: &Path) -> Engine {
    let mut cfg = EngineConfig::new(dir.to_path_buf(), "vp");
    cfg.bucket = common::engine_bucket(dir);
    Engine::start(cfg).expect("engine start")
}

fn eval_req(samples: usize, eps_rel: f64, seed: u64) -> EvalRequest {
    EvalRequest { model: String::new(), solver: "adaptive".to_string(), samples, eps_rel, seed }
}

/// Offline twin of the engine's eval lanes: per-sample forked RNG
/// streams, chunked generation, and the same streaming accumulator
/// arithmetic (this is what `gofast evaluate --offline` runs for the
/// adaptive solver).
fn offline_eval(dir: &Path, samples: usize, eps_rel: f64, seed: u64) -> (f64, f64, f64) {
    let rt = Runtime::new(dir).unwrap();
    let model = rt.model("vp").unwrap();
    let (net, refstats) = metrics::reference_for(&rt, &model.meta).unwrap();
    let bucket = common::engine_bucket(dir);
    let ctx = Ctx::new(&model, bucket, SolveOpts::default());
    let opts = adaptive::AdaptiveOpts { eps_rel, ..Default::default() };
    let mut images = Tensor::zeros(&[samples, model.meta.dim]);
    let mut nfe_sum = 0u64;
    let mut done = 0;
    while done < samples {
        let take = (samples - done).min(bucket);
        let res = adaptive::run_lanes(&ctx, seed, done as u64, take, &opts).unwrap();
        for i in 0..take {
            images.row_mut(done + i).copy_from_slice(res.x.row(i));
        }
        nfe_sum += res.nfe_per_sample.iter().sum::<u64>();
        done += take;
    }
    model.meta.process().to_unit_range(&mut images);
    let (fid, is) = metrics::evaluate_streaming(&net, &images, &refstats).unwrap();
    (fid, is, nfe_sum as f64 / samples as f64)
}

fn rel(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1.0)
}

/// The acceptance criterion: `evaluate` served through the engine must
/// match the offline bypass on the same model/solver/seed. 70 samples
/// spans two fid-bucket chunks, so chunked admission (`sample_base`) and
/// the ordered Chan merge are both on the line.
#[test]
fn engine_evaluate_matches_offline_bypass() {
    let Some(dir) = eval_artifacts() else { return };
    let (samples, eps, seed) = (70usize, 0.5f64, 11u64);
    let engine = start_engine(&dir);
    let served = engine.client().evaluate(eval_req(samples, eps, seed)).unwrap();
    assert_eq!(served.samples, samples);
    assert_eq!(served.model, "vp");
    let consumed: u64 = served.steps_per_bucket.iter().map(|(_, n)| *n).sum();
    assert!(consumed > 0, "evaluate consumed no steps: {:?}", served.steps_per_bucket);

    let stats = engine.client().stats().unwrap();
    assert_eq!(stats.evals_done, 1);
    assert_eq!(stats.eval_samples_done, samples as u64);
    assert_eq!(stats.eval_active, 0);
    assert!(stats.eval_lane_steps > 0);
    // eval samples are engine work too
    assert_eq!(stats.samples_done, samples as u64);
    // ...but not client requests
    assert_eq!(stats.requests_done, 0);
    drop(engine);

    let (fid, is, mean_nfe) = offline_eval(&dir, samples, eps, seed);
    assert!(
        rel(served.fid, fid) <= 1e-6,
        "FID* disagrees: served {} vs offline {}",
        served.fid,
        fid
    );
    assert!(rel(served.is, is) <= 1e-6, "IS* disagrees: served {} vs offline {}", served.is, is);
    assert_eq!(served.mean_nfe, mean_nfe, "NFE disagrees");
    assert!(served.is >= 1.0 - 1e-9);
    assert!(served.fid.is_finite() && served.fid >= 0.0);
}

/// Per-lane RNG streams make an eval run independent of co-batched
/// traffic: the same request must produce the same numbers with and
/// without concurrent client generates sharing the pool.
#[test]
fn evaluate_is_deterministic_under_concurrent_traffic() {
    let Some(dir) = eval_artifacts() else { return };
    let (samples, eps, seed) = (6usize, 0.5f64, 3u64);
    let quiet = {
        let engine = start_engine(&dir);
        engine.client().evaluate(eval_req(samples, eps, seed)).unwrap()
    };
    let busy = {
        let engine = start_engine(&dir);
        let bg = {
            let c = engine.client();
            std::thread::spawn(move || c.generate(8, 0.1, 999).unwrap())
        };
        let r = engine.client().evaluate(eval_req(samples, eps, seed)).unwrap();
        bg.join().unwrap();
        r
    };
    assert!(rel(quiet.fid, busy.fid) <= 1e-9, "fid {} vs {}", quiet.fid, busy.fid);
    assert!(rel(quiet.is, busy.is) <= 1e-9, "is {} vs {}", quiet.is, busy.is);
    assert_eq!(quiet.mean_nfe, busy.mean_nfe);
}

#[test]
fn evaluate_validates_request() {
    let Some(dir) = common::artifacts() else { return };
    let engine = start_engine(&dir);
    let err = engine
        .client()
        .evaluate(EvalRequest {
            model: String::new(),
            solver: "ode".to_string(),
            samples: 2,
            eps_rel: 0.5,
            seed: 0,
        })
        .unwrap_err()
        .to_string();
    assert!(err.contains("adaptive"), "{err}");
    let err = engine.client().evaluate(eval_req(0, 0.5, 0)).unwrap_err().to_string();
    assert!(err.contains("samples"), "{err}");
    let err = engine
        .client()
        .evaluate(EvalRequest {
            model: "nope".to_string(),
            solver: String::new(),
            samples: 2,
            eps_rel: 0.5,
            seed: 0,
        })
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown model"), "{err}");
}
