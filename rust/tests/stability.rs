//! Paper Appendix F: stability and bias of the numerical scheme on the
//! linear test SDE dx = lambda x dt + sigma dw. The extrapolated
//! stochastic-improved-Euler scheme must remain asymptotically unbiased
//! in mean (-> 0) and mean square (-> sigma^2 / 2|lambda|).

use gofast::rng::Rng;
use gofast::solvers::general::{solve, GeneralOpts};

fn run_linear(lambda: f64, sigma: f64, t_end: f64, paths: u64, eps_rel: f64) -> (f64, f64) {
    let mut master = Rng::new(2718);
    let mut finals = Vec::new();
    for k in 0..paths {
        let mut rng = master.fork(k);
        let traj = solve(
            |x, _t, out| out[0] = lambda * x[0],
            |_x, _t, out| out[0] = sigma,
            &[1.0],
            0.0,
            t_end,
            &mut rng,
            &GeneralOpts { eps_rel, eps_abs: 1e-4, ..Default::default() },
        )
        .unwrap();
        finals.push(traj.final_state()[0]);
    }
    let n = finals.len() as f64;
    let mean = finals.iter().sum::<f64>() / n;
    let msq = finals.iter().map(|v| v * v).sum::<f64>() / n;
    (mean, msq)
}

#[test]
fn mean_is_asymptotically_unbiased() {
    // |1 + lambda h| < 1 regime; long horizon kills the initial condition
    let (mean, _) = run_linear(-2.0, 0.7, 6.0, 600, 0.05);
    assert!(mean.abs() < 0.05, "E[y_n] should -> 0, got {mean}");
}

#[test]
fn mean_square_is_stationary_to_leading_order() {
    // App. F proves asymptotic (h -> 0) unbiasedness in mean square; at
    // *practical* tolerances the adaptive scheme carries an O(|lambda| h)
    // variance bias (the retained-noise rejections correlate h with z).
    // We assert the right order of magnitude here and exact unbiasedness
    // in mean below; DESIGN.md §11 documents the bias.
    let (lambda, sigma) = (-2.0f64, 0.7f64);
    let want = sigma * sigma / (2.0 * lambda.abs()); // 0.1225
    let (_, msq) = run_linear(lambda, sigma, 6.0, 1200, 0.02);
    let rel = (msq - want).abs() / want;
    assert!(rel < 0.5, "E[y^2] {msq} vs sigma^2/2|lambda| {want} (rel {rel:.3})");
    assert!(msq.is_finite() && msq > 0.0);
}

#[test]
fn stiffer_lambda_still_stable_with_adaptive_h() {
    // fixed-step EM with h > 2/|lambda| would explode; the controller
    // must keep h inside the stability region automatically.
    let (mean, msq) = run_linear(-50.0, 1.0, 2.0, 200, 0.05);
    assert!(mean.is_finite() && msq.is_finite());
    assert!(mean.abs() < 0.1, "{mean}");
    let want = 1.0 / 100.0;
    assert!((msq - want).abs() / want < 0.3, "msq {msq} want {want}");
}

#[test]
fn deterministic_decay_matches_exponential() {
    // sigma = 0: the extrapolated pair is the deterministic improved
    // Euler (order 2); x(2) = e^(-2 lambda)
    let mut rng = Rng::new(4);
    let traj = solve(
        |x, _t, out| out[0] = -1.5 * x[0],
        |_x, _t, out| out[0] = 0.0,
        &[1.0],
        0.0,
        2.0,
        &mut rng,
        &GeneralOpts { eps_rel: 1e-3, eps_abs: 1e-6, ..Default::default() },
    )
    .unwrap();
    let want = (-3.0f64).exp();
    let got = traj.final_state()[0];
    assert!((got - want).abs() < 5e-4, "{got} vs {want}");
}
