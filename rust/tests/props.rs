//! Property tests (testkit substrate) over the artifact-independent
//! invariants: step-size controller, process math, JSON/base64/config
//! round-trips, histogram quantile bounds, workload traces.

use gofast::prop_assert;
use gofast::sde::Process;
use gofast::solvers::time_grid;
use gofast::testkit::check;

#[test]
fn prop_controller_shrinks_on_large_error() {
    // h' = theta * h * E^-r must be < h whenever E > theta^(1/r) >= accept
    check("controller", 500, |g| {
        let h = g.f64(1e-6, 1.0);
        let r = g.f64(0.5, 1.0);
        let theta = 0.9;
        let e = g.f64(1.0, 100.0); // rejected proposals have E > 1
        let h2 = theta * h * e.powf(-r);
        prop_assert!(h2 < h, "h grew on rejection: {h} -> {h2} (E={e}, r={r})");
        Ok(())
    });
}

#[test]
fn prop_controller_grows_on_small_error() {
    check("controller-grow", 500, |g| {
        let h = g.f64(1e-6, 1.0);
        let r = g.f64(0.5, 1.0);
        let e = g.f64(1e-4, 0.8); // well-accepted proposals
        let h2 = 0.9 * h * e.powf(-r);
        prop_assert!(h2 > h, "h shrank on good step: {h} -> {h2} (E={e})");
        Ok(())
    });
}

#[test]
fn prop_time_grid_covers_interval() {
    check("time-grid", 200, |g| {
        let p = if g.bool() { Process::vp() } else { Process::ve(g.f64(5.0, 100.0)) };
        let n = g.size(1, 2000);
        let grid = time_grid(&p, n);
        prop_assert!(grid.len() == n + 1, "len {}", grid.len());
        prop_assert!(grid[0] == 1.0, "start {}", grid[0]);
        prop_assert!((grid[n] - p.t_eps()).abs() < 1e-12, "end {}", grid[n]);
        let uniform = (1.0 - p.t_eps()) / n as f64;
        for w in grid.windows(2) {
            prop_assert!((w[0] - w[1] - uniform).abs() < 1e-9, "non-uniform step");
        }
        Ok(())
    });
}

#[test]
fn prop_process_std_monotone_and_positive() {
    check("process-std", 300, |g| {
        let p = if g.bool() { Process::vp() } else { Process::ve(g.f64(2.0, 500.0)) };
        let t1 = g.f64(1e-5, 0.999);
        let t2 = t1 + g.f64(1e-6, 1.0 - t1);
        let (s1, s2) = (p.marginal_std(t1), p.marginal_std(t2));
        prop_assert!(s1 > 0.0 && s2 > 0.0, "non-positive std");
        prop_assert!(s2 >= s1 - 1e-12, "std not monotone: {s1} > {s2}");
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    use gofast::json::Value;
    check("json-roundtrip", 300, |g| {
        // build a random value tree
        fn build(g: &mut gofast::testkit::Gen, depth: usize) -> Value {
            match if depth == 0 { g.usize(0, 3) } else { g.usize(0, 5) } {
                0 => Value::Num((g.f64(-1e6, 1e6) * 1000.0).round() / 1000.0),
                1 => Value::Bool(g.bool()),
                2 => Value::Null,
                3 => Value::Str(
                    (0..g.usize(0, 12))
                        .map(|_| *g.pick(&['a', 'Z', '"', '\\', '\n', 'x', '0']))
                        .collect(),
                ),
                4 => Value::Arr((0..g.usize(0, 4)).map(|_| build(g, depth - 1)).collect()),
                _ => Value::Obj(
                    (0..g.usize(0, 4))
                        .map(|i| (format!("k{i}"), build(g, depth - 1)))
                        .collect(),
                ),
            }
        }
        let v = build(g, 3);
        let text = v.to_string();
        let back = gofast::json::parse(&text).map_err(|e| format!("parse failed: {e} on {text}"))?;
        prop_assert!(back == v, "roundtrip mismatch: {text}");
        Ok(())
    });
}

#[test]
fn prop_b64_roundtrip() {
    use gofast::server::b64;
    check("b64-roundtrip", 300, |g| {
        let n = g.usize(0, 200);
        let data: Vec<u8> = (0..n).map(|_| (g.rng.next_u64() & 0xFF) as u8).collect();
        let enc = b64::encode(&data);
        prop_assert!(enc.len() == data.len().div_ceil(3) * 4, "bad length");
        let dec = b64::decode(&enc).map_err(|e| e.to_string())?;
        prop_assert!(dec == data, "roundtrip mismatch");
        Ok(())
    });
}

#[test]
fn prop_histogram_quantiles_bounded() {
    use gofast::metrics::hist::Histogram;
    check("hist-quantile", 100, |g| {
        let mut h = Histogram::new();
        let n = g.size(1, 500);
        let mut max = 0f64;
        for _ in 0..n {
            let v = g.f64(1e-5, 100.0);
            max = max.max(v);
            h.record(v);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        prop_assert!(p50 <= p99 + 1e-12, "quantiles not monotone");
        prop_assert!(p99 <= max * 1.06, "p99 {p99} above max {max}");
        Ok(())
    });
}

#[test]
fn prop_config_roundtrip_numbers() {
    use gofast::config::Config;
    check("config", 200, |g| {
        let port = g.usize(1, 65535);
        let eps = (g.f64(0.001, 0.999) * 1000.0).round() / 1000.0;
        let text = format!("[s]\nport = {port}\neps = {eps}\nname = \"m{port}\"\n");
        let c = Config::parse(&text).map_err(|e| e.to_string())?;
        prop_assert!(c.usize_or("s.port", 0).unwrap() == port, "port");
        prop_assert!((c.f64_or("s.eps", 0.0).unwrap() - eps).abs() < 1e-12, "eps");
        prop_assert!(c.str_or("s.name", "").unwrap() == format!("m{port}"), "name");
        Ok(())
    });
}

#[test]
fn prop_poisson_trace_sorted_within_duration() {
    use gofast::rng::Rng;
    use gofast::workload::{poisson_trace, TraceConfig};
    check("trace", 100, |g| {
        let cfg = TraceConfig {
            duration_s: g.f64(1.0, 50.0),
            rate_rps: g.f64(0.5, 20.0),
            ..Default::default()
        };
        let trace = poisson_trace(&mut Rng::new(g.seed), &cfg);
        for w in trace.windows(2) {
            prop_assert!(w[1].at_s >= w[0].at_s, "unsorted arrivals");
        }
        prop_assert!(
            trace.iter().all(|i| i.at_s < cfg.duration_s),
            "arrival beyond duration"
        );
        Ok(())
    });
}

#[test]
fn prop_linalg_sqrtm_squares_back() {
    use gofast::linalg::{matmul, sqrtm_psd, transpose};
    check("sqrtm", 50, |g| {
        let n = g.size(2, 24);
        let b: Vec<f64> = (0..n * n).map(|_| g.rng.normal()).collect();
        let mut a = matmul(&b, &transpose(&b, n, n), n, n, n);
        for i in 0..n {
            a[i * n + i] += 0.05;
        }
        let s = sqrtm_psd(&a, n);
        let ss = matmul(&s, &s, n, n, n);
        for (x, y) in ss.iter().zip(&a) {
            prop_assert!((x - y).abs() < 1e-7, "sqrtm^2 != A ({x} vs {y}, n={n})");
        }
        Ok(())
    });
}
