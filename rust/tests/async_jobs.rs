//! Integration: the async job layer on the wire — submit/poll parity
//! with sync generate, exactly-once delivery, cancel accounting through
//! the QoS path, unknown-job error shapes, periodic re-generation, and
//! binary-frame payload equivalence.

mod common;

use gofast::coordinator::{Engine, EngineConfig};
use gofast::server::{serve, Client, GenerateRequest, ServerConfig};

fn spawn_server_cfg(
    tweak: impl FnOnce(&mut EngineConfig),
) -> Option<(Engine, std::net::SocketAddr)> {
    let dir = common::artifacts()?;
    let mut cfg = EngineConfig::new(dir.clone(), "vp");
    cfg.bucket = common::engine_bucket(&dir);
    tweak(&mut cfg);
    let engine = Engine::start(cfg).expect("engine");
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let client = engine.client();
    std::thread::spawn(move || {
        let _ = serve(
            listener,
            client,
            ServerConfig { port: addr.port(), default_eps_rel: 0.05 },
        );
    });
    Some((engine, addr))
}

fn spawn_server() -> Option<(Engine, std::net::SocketAddr)> {
    spawn_server_cfg(|_| {})
}

/// Poll until `job` delivers its (single) update, failing the test if
/// it takes longer than ~60 s.
fn poll_one(c: &mut Client, job: u64, binary: bool) -> gofast::server::JobUpdate {
    for _ in 0..600 {
        let mut got = c.poll_job(job, 100, binary).unwrap();
        if let Some(u) = got.pop() {
            assert!(got.is_empty(), "more than one update for job {job}");
            return u;
        }
    }
    panic!("job {job} never delivered");
}

/// The tentpole parity gate: a submitted generate, drained through
/// poll, is bit-identical to the same request run synchronously — same
/// images, same per-sample NFE. The async layer adds scheduling, never
/// arithmetic.
#[test]
fn submit_poll_matches_sync_generate() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let req = GenerateRequest::new(3).solver("em:6").eps_rel(0.5).seed(42);
    let sync = c.run(&req).unwrap();
    let job = c.submit(&req).unwrap();
    assert!(job > 0);
    let u = poll_one(&mut c, job, false);
    assert!(u.is_ok(), "submitted job failed: {:?}", u.error);
    assert_eq!(u.job, job);
    assert_eq!(u.op, "generate");
    let r = u.gen.expect("generate payload");
    assert_eq!(r.images, sync.images, "async result must be bit-identical to sync");
    assert_eq!(r.nfe, sync.nfe);
    // the jobs counters saw exactly this lifecycle
    let stats = c.stats().unwrap();
    let jobs = stats.get("jobs").expect("stats.jobs");
    assert_eq!(jobs.get("submitted").unwrap().as_f64().unwrap(), 1.0);
    assert_eq!(jobs.get("delivered").unwrap().as_f64().unwrap(), 1.0);
    assert_eq!(jobs.get("active").unwrap().as_f64().unwrap(), 0.0);
}

/// Exactly-once delivery: a drained job is gone — the next poll returns
/// nothing, and polling it by id is a structured `unknown_job` error.
#[test]
fn poll_drains_each_job_once() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let req = GenerateRequest::new(1).solver("em:4").eps_rel(0.5).seed(7).images(false);
    let a = c.submit(&req).unwrap();
    let b = c.submit(&req).unwrap();
    assert_ne!(a, b, "job ids must be unique");
    // blocking poll with no filter drains both, in submit order
    let mut seen = Vec::new();
    for _ in 0..600 {
        let got = c.poll(100, false).unwrap();
        seen.extend(got.into_iter().map(|u| u.job));
        if seen.len() >= 2 {
            break;
        }
    }
    assert_eq!(seen, vec![a, b]);
    // drained means gone: empty drain, and the ids no longer resolve
    assert!(c.poll(0, false).unwrap().is_empty());
    let err = c.poll_job(a, 0, false).unwrap_err().to_string();
    assert!(err.contains("[unknown_job]"), "{err}");
}

/// Cancel of a still-queued job frees the queue and quota accounting —
/// the same bookkeeping path as deadline shedding — and the job id
/// stops resolving. The lane-holding job is untouched.
#[test]
fn cancel_queued_job_frees_queue_and_quota() {
    let Some((_engine, addr)) = spawn_server_cfg(|cfg| {
        // one lane for the whole model, so the second job must queue
        cfg.qos.set_max_active_lanes("vp", 1);
    }) else {
        return;
    };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let blocker = c
        .submit(&GenerateRequest::new(1).solver("em:2000").eps_rel(0.5).seed(7).images(false))
        .unwrap();
    while c.stats().unwrap().get("active_slots").unwrap().as_f64().unwrap() == 0.0 {
        std::thread::yield_now();
    }
    let victim = c
        .submit(&GenerateRequest::new(1).solver("em:4").eps_rel(0.5).seed(9).images(false))
        .unwrap();
    while c.stats().unwrap().get("queue_depth").unwrap().as_f64().unwrap() == 0.0 {
        std::thread::yield_now();
    }
    assert!(c.cancel(victim).unwrap(), "queued job must cancel");
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("queue_depth").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(stats.get("qos").unwrap().get("canceled").unwrap().as_f64().unwrap(), 1.0);
    let jobs = stats.get("jobs").expect("stats.jobs");
    assert_eq!(jobs.get("canceled").unwrap().as_f64().unwrap(), 1.0);
    // the canceled id is gone from the table
    let err = c.poll_job(victim, 0, false).unwrap_err().to_string();
    assert!(err.contains("[unknown_job]"), "{err}");
    // the blocker ran to completion and still delivers
    let u = poll_one(&mut c, blocker, false);
    assert!(u.is_ok(), "{:?}", u.error);
    assert_eq!(u.gen.unwrap().nfe, vec![2001]);
}

/// Cancel of a never-issued id and of an already-completed job both
/// answer `unknown_job` — and a completed job's result stays pollable
/// after the refused cancel.
#[test]
fn cancel_unknown_or_completed_is_unknown_job() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let err = c.cancel(9999).unwrap_err().to_string();
    assert!(err.contains("[unknown_job]"), "{err}");
    let job = c
        .submit(&GenerateRequest::new(1).solver("em:4").eps_rel(0.5).seed(3).images(false))
        .unwrap();
    // wait for the engine to finish the sample before canceling
    while c.stats().unwrap().get("requests_done").unwrap().as_f64().unwrap() == 0.0 {
        std::thread::yield_now();
    }
    let err = c.cancel(job).unwrap_err().to_string();
    assert!(err.contains("[unknown_job]"), "{err}");
    assert!(err.contains("already completed"), "{err}");
    let u = poll_one(&mut c, job, false);
    assert!(u.is_ok(), "completed job must stay pollable after refused cancel");
    assert_eq!(u.gen.unwrap().nfe, vec![5]);
}

/// Periodic jobs re-run their spec on an interval with distinct sample
/// bases per round: round indices arrive in order, round 0 matches the
/// plain sync run of the same spec, and cancel stops the worker and
/// removes the job.
#[test]
fn periodic_fires_rounds_and_cancels() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let req = GenerateRequest::new(1).solver("em:5").eps_rel(0.5).seed(11);
    let sync = c.run(&req).unwrap();
    let job = c.periodic(&req, 10).unwrap();
    let mut rounds = Vec::new();
    for _ in 0..600 {
        for u in c.poll_job(job, 100, false).unwrap() {
            assert!(u.is_ok(), "periodic round failed: {:?}", u.error);
            let round = u.round.expect("periodic updates carry a round index");
            if round == 0 {
                let r = u.gen.as_ref().expect("round payload");
                assert_eq!(r.images, sync.images, "round 0 must match the sync run");
            }
            rounds.push(round);
        }
        if rounds.len() >= 2 {
            break;
        }
    }
    assert!(rounds.len() >= 2, "periodic job fired {} round(s)", rounds.len());
    assert_eq!(rounds[0], 0);
    assert!(rounds.windows(2).all(|w| w[1] == w[0] + 1), "rounds out of order: {rounds:?}");
    assert!(c.cancel(job).unwrap(), "periodic cancel");
    let err = c.poll_job(job, 0, false).unwrap_err().to_string();
    assert!(err.contains("[unknown_job]"), "{err}");
    let stats = c.stats().unwrap();
    let jobs = stats.get("jobs").expect("stats.jobs");
    assert_eq!(jobs.get("periodic").unwrap().as_f64().unwrap(), 0.0, "worker must stop");
}

/// The negotiated binary frame carries exactly the same pixels as the
/// base64 payload — for sync generate and for the async poll path.
#[test]
fn binary_frames_match_base64() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let req = GenerateRequest::new(2).solver("em:6").eps_rel(0.5).seed(5);
    let b64 = c.run(&req).unwrap();
    let bin = c.run(&req.clone().binary(true)).unwrap();
    assert_eq!(bin.images, b64.images, "binary frame must decode to the base64 pixels");
    assert_eq!(bin.nfe, b64.nfe);
    let job = c.submit(&req).unwrap();
    let u = poll_one(&mut c, job, true);
    assert!(u.is_ok(), "{:?}", u.error);
    assert_eq!(u.gen.unwrap().images, b64.images, "binary poll must match too");
}

/// `hello` reports the protocol version and capabilities so clients
/// stop probing stats: every op, the served models and solver
/// programs, and binary-frame availability.
#[test]
fn hello_reports_version_ops_and_capabilities() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let h = c.hello().unwrap();
    assert_eq!(h.get("v").unwrap().as_f64().unwrap(), 1.0);
    let ops: Vec<&str> =
        h.get("ops").unwrap().as_arr().unwrap().iter().map(|o| o.as_str().unwrap()).collect();
    for op in
        ["hello", "ping", "stats", "generate", "evaluate", "submit", "poll", "cancel", "periodic"]
    {
        assert!(ops.contains(&op), "hello must advertise {op}: {ops:?}");
    }
    let models: Vec<&str> = h
        .get("models")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|m| m.as_str().unwrap())
        .collect();
    assert!(models.contains(&"vp"), "{models:?}");
    assert!(!h.get("solvers").unwrap().as_arr().unwrap().is_empty());
    assert!(h.get("binary").unwrap().as_bool().unwrap());
    // fused-adaptive capability is always advertised (true only when an
    // adaptive pool dispatches the device-side fold at k > 1)
    assert!(h.get("fused_adaptive").is_some(), "hello must advertise fused_adaptive");
}
