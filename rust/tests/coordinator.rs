//! Integration: the continuous-batching engine — request lifecycle,
//! mixed tolerances in one batch, admission control, determinism,
//! bucket migration, multi-model routing.

mod common;

use gofast::coordinator::{Engine, EngineConfig};

fn engine() -> Option<Engine> {
    let dir = common::artifacts()?;
    let mut cfg = EngineConfig::new(dir.clone(), "vp");
    cfg.bucket = common::engine_bucket(&dir);
    Some(Engine::start(cfg).expect("engine start"))
}

#[test]
fn single_request_roundtrip() {
    let Some(engine) = engine() else { return };
    let c = engine.client();
    let r = c.generate(4, 0.05, 42).unwrap();
    assert_eq!(r.images.shape, vec![4, 768]);
    assert!(r.images.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    assert_eq!(r.nfe.len(), 4);
    assert!(r.nfe.iter().all(|&n| n >= 3));
    let stats = c.stats().unwrap();
    assert_eq!(stats.requests_done, 1);
    assert_eq!(stats.samples_done, 4);
}

#[test]
fn oversized_request_streams_through_slots() {
    let Some(engine) = engine() else { return };
    let c = engine.client();
    // 40 samples > 16 slots: lanes must recycle
    let r = c.generate(40, 0.1, 1).unwrap();
    assert_eq!(r.images.shape[0], 40);
    let stats = c.stats().unwrap();
    assert_eq!(stats.samples_done, 40);
    assert_eq!(stats.active_slots, 0);
}

#[test]
fn concurrent_mixed_tolerance_requests() {
    let Some(engine) = engine() else { return };
    let mut handles = Vec::new();
    for (i, eps) in [(0u64, 0.02), (1, 0.05), (2, 0.1), (3, 0.5)] {
        let c = engine.client();
        handles.push(std::thread::spawn(move || {
            let r = c.generate(4, eps, 100 + i).expect("generate");
            (eps, r.nfe.iter().sum::<u64>() as f64 / 4.0)
        }));
    }
    let mut results: Vec<(f64, f64)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    results.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    // requests with tighter tolerance must spend more NFE even when
    // co-batched with looser ones (per-lane eps_rel)
    assert!(
        results.first().unwrap().1 > results.last().unwrap().1,
        "NFE not ordered by tolerance: {results:?}"
    );
    let stats = engine.client().stats().unwrap();
    assert_eq!(stats.requests_done, 4);
}

#[test]
fn same_seed_same_images_regardless_of_batching() {
    let Some(engine) = engine() else { return };
    let c = engine.client();
    let a = c.generate(3, 0.05, 777).unwrap();
    // second run shares the engine with another concurrent request
    let c2 = engine.client();
    let bg = std::thread::spawn(move || c2.generate(8, 0.1, 555).unwrap());
    let b = c.generate(3, 0.05, 777).unwrap();
    bg.join().unwrap();
    assert_eq!(a.images, b.images, "per-sample RNG must make results batching-independent");
    assert_eq!(a.nfe, b.nfe);
}

#[test]
fn zero_sample_request_is_rejected() {
    let Some(engine) = engine() else { return };
    let err = engine.client().generate(0, 0.05, 0).unwrap_err().to_string();
    assert!(err.contains("n must be > 0"), "{err}");
}

#[test]
fn admission_control_rejects_overflow() {
    let Some(dir) = common::artifacts() else { return };
    let mut cfg = EngineConfig::new(dir.clone(), "vp");
    cfg.bucket = common::engine_bucket(&dir);
    cfg.max_queue_samples = 8;
    let engine = Engine::start(cfg).unwrap();
    let err = engine.client().generate(100, 0.5, 0).unwrap_err().to_string();
    assert!(err.contains("queue full"), "{err}");
}

#[test]
fn occupancy_reported_under_load() {
    let Some(engine) = engine() else { return };
    let c = engine.client();
    c.generate(32, 0.1, 9).unwrap();
    let stats = c.stats().unwrap();
    assert!(stats.mean_occupancy > 1.0, "occupancy {}", stats.mean_occupancy);
    assert!(stats.steps > 0);
}

#[test]
fn unknown_model_is_rejected() {
    let Some(engine) = engine() else { return };
    let err = engine.client().generate_on("nope", 1, 0.1, 0).unwrap_err().to_string();
    assert!(err.contains("unknown model"), "{err}");
}

/// The acceptance criterion of the bucket scheduler: a migrating pool
/// must produce the same images as a fixed-width pool for the same
/// seeds — migration moves lane state between widths without altering
/// any sample's trajectory.
#[test]
fn migrating_engine_matches_fixed_engine() {
    let Some(dir) = common::artifacts() else { return };
    let bucket = common::engine_bucket(&dir);
    if common::step_buckets(&dir).iter().filter(|&&b| b <= bucket).count() < 2 {
        eprintln!("skipping: needs a multi-rung bucket ladder");
        return;
    }
    let mut fixed_cfg = EngineConfig::new(dir.clone(), "vp");
    fixed_cfg.bucket = bucket;
    fixed_cfg.migrate = false;
    let mut mig_cfg = EngineConfig::new(dir, "vp");
    mig_cfg.bucket = bucket;
    mig_cfg.migrate = true;
    let fixed = Engine::start(fixed_cfg).unwrap();
    let migr = Engine::start(mig_cfg).unwrap();
    for (n, eps, seed) in [(1usize, 0.1, 41u64), (3, 0.05, 777)] {
        let a = fixed.client().generate(n, eps, seed).unwrap();
        let b = migr.client().generate(n, eps, seed).unwrap();
        assert_eq!(a.images, b.images, "bucket migration altered the trajectory (n={n})");
        assert_eq!(a.nfe, b.nfe, "bucket migration altered NFE (n={n})");
    }
    // active lanes <= half the width the whole run: the scheduler must
    // actually have dropped below the max bucket, and wasted fewer
    // lane-steps than the fixed pool on the identical workload
    let ms = migr.client().stats().unwrap();
    let narrow: u64 =
        ms.steps_per_bucket.iter().filter(|(b, _)| *b < bucket).map(|(_, s)| *s).sum();
    assert!(narrow > 0, "no steps below max bucket: {:?}", ms.steps_per_bucket);
    assert!(ms.migrations_down > 0, "no downshift recorded");
    let fs = fixed.client().stats().unwrap();
    assert!(
        ms.wasted_lane_steps < fs.wasted_lane_steps,
        "migrating wasted {} lane-steps vs fixed {}",
        ms.wasted_lane_steps,
        fs.wasted_lane_steps
    );
}

#[test]
fn per_bucket_stats_cover_all_steps() {
    let Some(engine) = engine() else { return };
    let c = engine.client();
    c.generate(1, 0.1, 3).unwrap();
    let stats = c.stats().unwrap();
    let total: u64 = stats.steps_per_bucket.iter().map(|(_, s)| *s).sum();
    assert_eq!(total, stats.steps, "per-bucket step counts must sum to total steps");
    assert_eq!(
        stats.wasted_lane_steps + stats.occupied_lane_steps,
        stats.steps_per_bucket.iter().map(|(b, s)| *b as u64 * *s).sum::<u64>(),
        "lane-step accounting must balance"
    );
    assert_eq!(stats.models, vec!["vp".to_string()]);
}

#[test]
fn multi_model_round_robin_serves_both() {
    let Some(dir) = common::artifacts() else { return };
    let rt = gofast::runtime::Runtime::new(&dir).unwrap();
    let mut names = rt.variant_names();
    drop(rt);
    names.sort();
    if names.len() < 2 {
        eprintln!("skipping: needs >= 2 variants, have {names:?}");
        return;
    }
    let mut cfg = EngineConfig::new(dir.clone(), &names[0]);
    cfg.models = vec![names[0].clone(), names[1].clone()];
    cfg.bucket = common::engine_bucket(&dir);
    let engine = Engine::start(cfg).unwrap();
    let mut handles = Vec::new();
    for name in [names[0].clone(), names[1].clone()] {
        let c = engine.client();
        handles.push(std::thread::spawn(move || {
            c.generate_on(&name, 2, 0.1, 7).unwrap().nfe.len()
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 4);
    let stats = engine.client().stats().unwrap();
    assert_eq!(stats.samples_done, 4);
    assert_eq!(stats.requests_done, 2);
    assert_eq!(stats.models, names[..2].to_vec());
}
