//! Integration: the continuous-batching engine — request lifecycle,
//! mixed tolerances in one batch, admission control, determinism,
//! bucket migration, multi-model routing, fixed-step solver-program
//! pools (em/ddim lanes behind the same scheduler), and the QoS
//! subsystem (weights, quotas, priorities, deadline shedding).

mod common;

use gofast::coordinator::{qos, CancelOutcome, Engine, EngineConfig, SampleRequest};
use gofast::solvers::ServingSolver;

fn engine() -> Option<Engine> {
    let dir = common::artifacts()?;
    let mut cfg = EngineConfig::new(dir.clone(), "vp");
    cfg.bucket = common::engine_bucket(&dir);
    Some(Engine::start(cfg).expect("engine start"))
}

#[test]
fn single_request_roundtrip() {
    let Some(engine) = engine() else { return };
    let c = engine.client();
    let r = c.generate(4, 0.05, 42).unwrap();
    assert_eq!(r.images.shape, vec![4, 768]);
    assert!(r.images.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    assert_eq!(r.nfe.len(), 4);
    assert!(r.nfe.iter().all(|&n| n >= 3));
    let stats = c.stats().unwrap();
    assert_eq!(stats.requests_done, 1);
    assert_eq!(stats.samples_done, 4);
}

#[test]
fn oversized_request_streams_through_slots() {
    let Some(engine) = engine() else { return };
    let c = engine.client();
    // 40 samples > 16 slots: lanes must recycle
    let r = c.generate(40, 0.1, 1).unwrap();
    assert_eq!(r.images.shape[0], 40);
    let stats = c.stats().unwrap();
    assert_eq!(stats.samples_done, 40);
    assert_eq!(stats.active_slots, 0);
}

#[test]
fn concurrent_mixed_tolerance_requests() {
    let Some(engine) = engine() else { return };
    let mut handles = Vec::new();
    for (i, eps) in [(0u64, 0.02), (1, 0.05), (2, 0.1), (3, 0.5)] {
        let c = engine.client();
        handles.push(std::thread::spawn(move || {
            let r = c.generate(4, eps, 100 + i).expect("generate");
            (eps, r.nfe.iter().sum::<u64>() as f64 / 4.0)
        }));
    }
    let mut results: Vec<(f64, f64)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    results.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    // requests with tighter tolerance must spend more NFE even when
    // co-batched with looser ones (per-lane eps_rel)
    assert!(
        results.first().unwrap().1 > results.last().unwrap().1,
        "NFE not ordered by tolerance: {results:?}"
    );
    let stats = engine.client().stats().unwrap();
    assert_eq!(stats.requests_done, 4);
}

#[test]
fn same_seed_same_images_regardless_of_batching() {
    let Some(engine) = engine() else { return };
    let c = engine.client();
    let a = c.generate(3, 0.05, 777).unwrap();
    // second run shares the engine with another concurrent request
    let c2 = engine.client();
    let bg = std::thread::spawn(move || c2.generate(8, 0.1, 555).unwrap());
    let b = c.generate(3, 0.05, 777).unwrap();
    bg.join().unwrap();
    assert_eq!(a.images, b.images, "per-sample RNG must make results batching-independent");
    assert_eq!(a.nfe, b.nfe);
}

#[test]
fn zero_sample_request_is_rejected() {
    let Some(engine) = engine() else { return };
    let err = engine.client().generate(0, 0.05, 0).unwrap_err().to_string();
    assert!(err.contains("n must be > 0"), "{err}");
}

#[test]
fn admission_control_rejects_overflow() {
    let Some(dir) = common::artifacts() else { return };
    let mut cfg = EngineConfig::new(dir.clone(), "vp");
    cfg.bucket = common::engine_bucket(&dir);
    cfg.max_queue_samples = 8;
    let engine = Engine::start(cfg).unwrap();
    let err = engine.client().generate(100, 0.5, 0).unwrap_err().to_string();
    assert!(err.contains("queue full"), "{err}");
    // the global cap is a structured rejection too
    assert!(err.starts_with(qos::CODE_QUEUE_FULL), "{err}");
}

/// Per-model admission quota: an over-quota generate is rejected with a
/// structured `quota_exceeded` error instead of queuing unboundedly,
/// and the engine keeps serving within-quota traffic.
#[test]
fn per_model_quota_rejects_with_coded_error() {
    let Some(dir) = common::artifacts() else { return };
    let mut cfg = EngineConfig::new(dir.clone(), "vp");
    cfg.bucket = common::engine_bucket(&dir);
    cfg.qos.set_max_queued("vp", 8);
    let engine = Engine::start(cfg).unwrap();
    let c = engine.client();
    let err = c.generate(100, 0.5, 0).unwrap_err().to_string();
    assert!(err.starts_with(qos::CODE_QUOTA), "{err}");
    assert!(err.contains("'vp'") && err.contains("quota 8"), "{err}");
    // within-quota traffic still flows, and the rejection was counted
    c.generate(2, 0.5, 1).unwrap();
    let stats = c.stats().unwrap();
    assert_eq!(stats.rejected_quota, 1);
    assert_eq!(stats.requests_done, 1);
}

/// A queued request whose deadline expires before any of its samples
/// reaches a lane is shed with a `deadline_exceeded` error; requests
/// already holding lanes run to completion.
#[test]
fn deadline_sheds_still_queued_requests() {
    let Some(dir) = common::artifacts() else { return };
    let mut cfg = EngineConfig::new(dir.clone(), "vp");
    cfg.bucket = common::engine_bucket(&dir);
    // one lane for the whole model, so the second request must queue
    cfg.qos.set_max_active_lanes("vp", 1);
    let engine = Engine::start(cfg).unwrap();
    let c_long = engine.client();
    let long = std::thread::spawn(move || {
        c_long.generate_with("", ServingSolver::Em { steps: 2000 }, 1, 0.5, 7).unwrap()
    });
    let c = engine.client();
    while c.stats().unwrap().active_slots == 0 {
        std::thread::yield_now();
    }
    let err = c
        .generate_request(SampleRequest {
            model: String::new(),
            solver: ServingSolver::Em { steps: 4 },
            n: 1,
            eps_rel: 0.5,
            seed: 9,
            sample_base: 0,
            priority: None,
            deadline_ms: Some(1),
            cancel_token: None,
        })
        .unwrap_err()
        .to_string();
    assert!(err.starts_with(qos::CODE_DEADLINE), "{err}");
    let r = long.join().unwrap();
    assert_eq!(r.nfe, vec![2001], "the running request must complete untouched");
    let stats = c.stats().unwrap();
    assert_eq!(stats.shed_deadline, 1);
    assert_eq!(stats.requests_done, 1);
    // a deadline generous enough to be admitted is not shed
    let ok = c
        .generate_request(SampleRequest {
            model: String::new(),
            solver: ServingSolver::Em { steps: 4 },
            n: 1,
            eps_rel: 0.5,
            seed: 9,
            sample_base: 0,
            priority: Some(qos::Priority::Interactive),
            deadline_ms: Some(60_000),
            cancel_token: None,
        })
        .unwrap();
    assert_eq!(ok.nfe, vec![5]);
}

/// Client-side cancellation mirrors deadline shedding: a fully-queued
/// request is dequeued (queue freed, quota released, its waiter
/// unblocked with an error), a request already holding lanes reports
/// `Running` and completes untouched, and an unknown or already-spent
/// token is `NotFound`.
#[test]
fn cancel_dequeues_queued_request_and_frees_accounting() {
    let Some(dir) = common::artifacts() else { return };
    let mut cfg = EngineConfig::new(dir.clone(), "vp");
    cfg.bucket = common::engine_bucket(&dir);
    // one lane for the whole model, so the victim request must queue
    cfg.qos.set_max_active_lanes("vp", 1);
    let engine = Engine::start(cfg).unwrap();
    let req = |steps: usize, seed: u64, token: u64| SampleRequest {
        model: String::new(),
        solver: ServingSolver::Em { steps },
        n: 1,
        eps_rel: 0.5,
        seed,
        sample_base: 0,
        priority: None,
        deadline_ms: None,
        cancel_token: Some(token),
    };
    let c_long = engine.client();
    let long = std::thread::spawn(move || c_long.generate_request(req(2000, 7, 1)).unwrap());
    let c = engine.client();
    while c.stats().unwrap().active_slots == 0 {
        std::thread::yield_now();
    }
    // the lane-holding request cannot be canceled, only observed
    assert_eq!(c.cancel(1).unwrap(), CancelOutcome::Running);
    let c_victim = engine.client();
    let victim = std::thread::spawn(move || {
        c_victim.generate_request(req(4, 9, 42)).unwrap_err().to_string()
    });
    while c.stats().unwrap().queued_samples == 0 {
        std::thread::yield_now();
    }
    assert_eq!(c.cancel(42).unwrap(), CancelOutcome::Canceled);
    let err = victim.join().unwrap();
    assert!(err.contains("canceled"), "{err}");
    // the same token a second time, and a never-issued token: NotFound
    assert_eq!(c.cancel(42).unwrap(), CancelOutcome::NotFound);
    assert_eq!(c.cancel(999).unwrap(), CancelOutcome::NotFound);
    let stats = c.stats().unwrap();
    assert_eq!(stats.canceled, 1);
    assert_eq!(stats.queued_samples, 0, "cancel must free the queue");
    let r = long.join().unwrap();
    assert_eq!(r.nfe, vec![2001], "the running request must complete untouched");
    // the freed lane quota admits new traffic
    let ok = c.generate_request(req(4, 3, 0)).unwrap();
    assert_eq!(ok.nfe, vec![5]);
}

/// The `max_active_lanes` quota is a throttle: a request larger than
/// the cap still completes, but the model never occupies more lanes
/// than granted.
#[test]
fn lane_quota_throttles_model_occupancy() {
    let Some(dir) = common::artifacts() else { return };
    let mut cfg = EngineConfig::new(dir.clone(), "vp");
    cfg.bucket = common::engine_bucket(&dir);
    cfg.qos.set_max_active_lanes("vp", 2);
    let engine = Engine::start(cfg).unwrap();
    let c_bg = engine.client();
    let run = std::thread::spawn(move || {
        c_bg.generate_with("", ServingSolver::Em { steps: 30 }, 6, 0.5, 3).unwrap()
    });
    let c = engine.client();
    let mut peak = 0;
    loop {
        let s = c.stats().unwrap();
        peak = peak.max(s.active_slots);
        if s.requests_done >= 1 {
            break;
        }
        std::thread::yield_now();
    }
    let r = run.join().unwrap();
    assert_eq!(r.images.shape[0], 6, "throttled request still completes");
    assert!(peak <= 2, "lane quota exceeded: observed {peak} active lanes");
}

/// The QoS determinism guard: weights, quotas and priority classes
/// change who waits, never what is computed — single-tenant results are
/// bit-identical between a default engine and a QoS-configured one.
#[test]
fn qos_config_is_bit_identical_for_single_tenant_traffic() {
    let Some(dir) = common::artifacts() else { return };
    let bucket = common::engine_bucket(&dir);
    let mut plain_cfg = EngineConfig::new(dir.clone(), "vp");
    plain_cfg.bucket = bucket;
    let mut qos_cfg = EngineConfig::new(dir, "vp");
    qos_cfg.bucket = bucket;
    qos_cfg.qos.weights = qos::parse_weights("vp=3,vp/em=0.5").unwrap();
    qos_cfg.qos.set_max_queued("vp", 4096);
    qos_cfg.qos.default_priority = qos::Priority::Batch;
    let plain = Engine::start(plain_cfg).unwrap();
    let wqos = Engine::start(qos_cfg).unwrap();
    for (solver, n, eps, seed) in [
        (ServingSolver::Adaptive, 3usize, 0.1, 41u64),
        (ServingSolver::Em { steps: 9 }, 2, 0.5, 7),
        (ServingSolver::Adaptive, 1, 0.05, 77),
    ] {
        let a = plain.client().generate_with("", solver, n, eps, seed).unwrap();
        let b = wqos.client().generate_with("", solver, n, eps, seed).unwrap();
        assert_eq!(a.images, b.images, "QoS config altered sample content ({solver:?})");
        assert_eq!(a.nfe, b.nfe, "QoS config altered NFE ({solver:?})");
    }
    // the weighted engine exports its policy through stats
    let stats = wqos.client().stats().unwrap();
    let adaptive =
        stats.pool_qos.iter().find(|p| p.solver == "adaptive").expect("adaptive pool qos");
    assert_eq!(adaptive.weight, 3.0);
    let em = stats.pool_qos.iter().find(|p| p.solver == "em").expect("em pool qos");
    assert_eq!(em.weight, 0.5, "model/program weight must win over the model weight");
    assert!(adaptive.turns > 0 && em.turns > 0);
    assert_eq!(stats.queued_samples, 0, "all traffic drained");
    let interactive = stats.classes.iter().find(|c| c.class == "interactive").unwrap();
    assert_eq!(interactive.requests_done, 0, "default class was overridden to batch");
    let batch = stats.classes.iter().find(|c| c.class == "batch").unwrap();
    assert_eq!(batch.requests_done, 3);
    assert!(batch.e2e_p95_s > 0.0 && batch.queue_wait_p50_s >= 0.0);
}

#[test]
fn occupancy_reported_under_load() {
    let Some(engine) = engine() else { return };
    let c = engine.client();
    c.generate(32, 0.1, 9).unwrap();
    let stats = c.stats().unwrap();
    assert!(stats.mean_occupancy > 1.0, "occupancy {}", stats.mean_occupancy);
    assert!(stats.steps > 0);
}

#[test]
fn unknown_model_is_rejected() {
    let Some(engine) = engine() else { return };
    let err = engine.client().generate_on("nope", 1, 0.1, 0).unwrap_err().to_string();
    assert!(err.contains("unknown model"), "{err}");
}

/// The acceptance criterion of the bucket scheduler: a migrating pool
/// must produce the same images as a fixed-width pool for the same
/// seeds — migration moves lane state between widths without altering
/// any sample's trajectory.
#[test]
fn migrating_engine_matches_fixed_engine() {
    let Some(dir) = common::artifacts() else { return };
    let bucket = common::engine_bucket(&dir);
    if common::step_buckets(&dir).iter().filter(|&&b| b <= bucket).count() < 2 {
        eprintln!("skipping: needs a multi-rung bucket ladder");
        return;
    }
    let mut fixed_cfg = EngineConfig::new(dir.clone(), "vp");
    fixed_cfg.bucket = bucket;
    fixed_cfg.migrate = false;
    let mut mig_cfg = EngineConfig::new(dir, "vp");
    mig_cfg.bucket = bucket;
    mig_cfg.migrate = true;
    let fixed = Engine::start(fixed_cfg).unwrap();
    let migr = Engine::start(mig_cfg).unwrap();
    for (n, eps, seed) in [(1usize, 0.1, 41u64), (3, 0.05, 777)] {
        let a = fixed.client().generate(n, eps, seed).unwrap();
        let b = migr.client().generate(n, eps, seed).unwrap();
        assert_eq!(a.images, b.images, "bucket migration altered the trajectory (n={n})");
        assert_eq!(a.nfe, b.nfe, "bucket migration altered NFE (n={n})");
    }
    // active lanes <= half the width the whole run: the scheduler must
    // actually have dropped below the max bucket, and wasted fewer
    // lane-steps than the fixed pool on the identical workload
    let ms = migr.client().stats().unwrap();
    let narrow: u64 =
        ms.steps_per_bucket.iter().filter(|(b, _)| *b < bucket).map(|(_, s)| *s).sum();
    assert!(narrow > 0, "no steps below max bucket: {:?}", ms.steps_per_bucket);
    assert!(ms.migrations_down > 0, "no downshift recorded");
    let fs = fixed.client().stats().unwrap();
    assert!(
        ms.wasted_lane_steps < fs.wasted_lane_steps,
        "migrating wasted {} lane-steps vs fixed {}",
        ms.wasted_lane_steps,
        fs.wasted_lane_steps
    );
}

/// EM lanes are first-class serving workloads: correct image range,
/// exact per-sample NFE (steps + denoise), per-program stats, and
/// per-lane step budgets co-batching in one pool.
#[test]
fn fixed_step_generate_roundtrip() {
    let Some(engine) = engine() else { return };
    let c = engine.client();
    let a = c.generate_with("", ServingSolver::Em { steps: 6 }, 3, 0.5, 42).unwrap();
    assert_eq!(a.images.shape, vec![3, 768]);
    assert!(a.images.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    assert!(a.nfe.iter().all(|&n| n == 7), "em nfe {:?}", a.nfe);
    // different step budgets in the same pool: each lane keeps its own
    let b = c.generate_with("", ServingSolver::Em { steps: 11 }, 2, 0.5, 42).unwrap();
    assert!(b.nfe.iter().all(|&n| n == 12), "em nfe {:?}", b.nfe);
    let stats = c.stats().unwrap();
    let em = stats.programs.iter().find(|p| p.solver == "em").expect("em stats");
    assert!(em.steps >= 11, "em steps {}", em.steps);
    assert_eq!(stats.samples_done, 5);
    // aggregate counters cover the per-program ones
    let prog_steps: u64 = stats.programs.iter().map(|p| p.steps).sum();
    assert_eq!(prog_steps, stats.steps);
}

/// Fixed-step samples are batching-independent exactly like adaptive
/// ones: per-lane RNG streams + per-lane grid positions.
#[test]
fn fixed_step_same_seed_same_images_under_load() {
    let Some(engine) = engine() else { return };
    let c = engine.client();
    let solver = ServingSolver::Em { steps: 8 };
    let a = c.generate_with("", solver, 3, 0.5, 123).unwrap();
    let c2 = engine.client();
    let bg = std::thread::spawn(move || c2.generate(6, 0.1, 555).unwrap());
    let b = c.generate_with("", solver, 3, 0.5, 123).unwrap();
    bg.join().unwrap();
    assert_eq!(a.images, b.images, "em lanes must be batching-independent");
    assert_eq!(a.nfe, b.nfe);
}

/// The migration-determinism contract extends to fixed-step lanes: a
/// migrating pool must produce the same images as a pinned one while
/// lanes move buckets mid-trajectory. A long-running lane is admitted
/// alone (the pool shrinks around it), then a second request grows the
/// pool back — so a live lane crosses bucket widths both ways. Run for
/// the em pool and (artifacts permitting) the pc pool: a live pc lane
/// must carry `(t, h, rng, x, xprev, snr)` across widths bit-identically
/// — the short request uses an explicit non-default snr so the per-lane
/// snr is actually on the line.
fn fixed_step_migration_case(long_solver: ServingSolver, short_solver: ServingSolver) {
    fixed_step_migration_case_k(long_solver, short_solver, 1)
}

/// Like [`fixed_step_migration_case`], at `k` steps per dispatch: the
/// migrating engine runs device-resident fused dispatches while the
/// pinned baseline stays at k = 1, so for k > 1 a live lane's full
/// tuple `(t, h, nfe, rng, x, xprev, snr)` must survive the slab
/// download -> host row remap -> lazy re-upload around every width
/// switch (and the admission syncs the short request forces) to come
/// out bit-identical.
fn fixed_step_migration_case_k(
    long_solver: ServingSolver,
    short_solver: ServingSolver,
    k: usize,
) {
    let Some(dir) = common::artifacts() else { return };
    let bucket = common::engine_bucket(&dir);
    if common::step_buckets(&dir).iter().filter(|&&b| b <= bucket).count() < 2 {
        eprintln!("skipping: needs a multi-rung bucket ladder");
        return;
    }
    let program = long_solver.name();
    if common::program_rungs(&dir, long_solver.step_artifact()).len() < 2 {
        eprintln!("skipping: needs >= 2 {program} rungs at or below the engine bucket");
        return;
    }
    if k > 1 {
        let fused = format!("{}k{k}", long_solver.step_artifact());
        if common::program_rungs(&dir, &fused).len() < 2 {
            eprintln!("skipping: needs >= 2 {fused} rungs (rebuild artifacts)");
            return;
        }
    }
    let run = |migrate: bool, k: usize| {
        let mut cfg = EngineConfig::new(dir.clone(), "vp");
        cfg.bucket = bucket;
        cfg.migrate = migrate;
        cfg.steps_per_dispatch = k;
        let engine = Engine::start(cfg).unwrap();
        let c_bg = engine.client();
        let long = std::thread::spawn(move || {
            c_bg.generate_with("", long_solver, 1, 0.5, 41).unwrap()
        });
        // wait until the long lane is live so the short request
        // co-batches with (and then outlives-into) a width change
        let c = engine.client();
        while c.stats().unwrap().active_slots == 0 {
            std::thread::yield_now();
        }
        let short = c.generate_with("", short_solver, 2, 0.5, 77).unwrap();
        let long = long.join().unwrap();
        let stats = c.stats().unwrap();
        (long, short, stats)
    };
    let (long_m, short_m, stats_m) = run(true, k);
    let (long_f, short_f, _) = run(false, 1);
    assert_eq!(
        long_m.images, long_f.images,
        "{program} migration altered the long lane's trajectory"
    );
    assert_eq!(long_m.nfe, long_f.nfe);
    assert_eq!(short_m.images, short_f.images, "{program} migration altered the short lanes");
    assert_eq!(short_m.nfe, short_f.nfe);
    // the migrating pool must actually have moved: steps below the
    // max rung and at least one width switch
    let ps = stats_m.programs.iter().find(|p| p.solver == program).expect("program stats");
    let narrow: u64 =
        ps.steps_per_bucket.iter().filter(|(b, _)| *b < bucket).map(|(_, s)| *s).sum();
    assert!(narrow > 0, "no {program} steps below max bucket: {:?}", ps.steps_per_bucket);
    assert!(
        ps.migrations_up + ps.migrations_down > 0,
        "{program} pool never switched width"
    );
}

#[test]
fn fixed_step_migration_matches_pinned_pool() {
    fixed_step_migration_case(
        ServingSolver::Em { steps: 400 },
        ServingSolver::Em { steps: 4 },
    );
}

#[test]
fn pc_migration_matches_pinned_pool() {
    fixed_step_migration_case(
        ServingSolver::Pc { steps: 200, snr: None },
        ServingSolver::Pc { steps: 4, snr: Some(0.17) },
    );
}

/// Device-resident migration: a fused k=8 migrating pool must match the
/// host-side k=1 pinned pool bit-for-bit — live-lane state round-trips
/// through the device slab across every width change.
#[test]
fn fused_em_migration_matches_pinned_pool() {
    fixed_step_migration_case_k(
        ServingSolver::Em { steps: 400 },
        ServingSolver::Em { steps: 4 },
        8,
    );
}

#[test]
fn fused_pc_migration_matches_pinned_pool() {
    fixed_step_migration_case_k(
        ServingSolver::Pc { steps: 200, snr: None },
        ServingSolver::Pc { steps: 4, snr: Some(0.17) },
        8,
    );
}

/// The fused-dispatch acceptance criterion: k steps per dispatch is a
/// pure amortisation — images and NFE are bit-identical to k = 1, while
/// dispatches and device->host traffic drop. Step budgets deliberately
/// not divisible by 8 so the last dispatch rides no-op tail nodes.
fn fused_dispatch_case(solver: ServingSolver, n: usize, seed: u64) {
    let Some(dir) = common::artifacts() else { return };
    let fused = format!("{}k8", solver.step_artifact());
    if common::program_rungs(&dir, &fused).is_empty() {
        eprintln!("skipping: no {fused} artifacts at or below the engine bucket");
        return;
    }
    let run = |k: usize| {
        let mut cfg = EngineConfig::new(dir.clone(), "vp");
        cfg.bucket = common::engine_bucket(&dir);
        cfg.steps_per_dispatch = k;
        let engine = Engine::start(cfg).unwrap();
        let c = engine.client();
        let r = c.generate_with("", solver, n, 0.5, seed).unwrap();
        (r, c.stats().unwrap())
    };
    let (r1, s1) = run(1);
    let (r8, s8) = run(8);
    assert_eq!(r8.images, r1.images, "{solver:?}: fused dispatch altered samples");
    assert_eq!(r8.nfe, r1.nfe, "{solver:?}: fused dispatch altered NFE");
    assert_eq!(s8.score_evals, s1.score_evals, "{solver:?}: NFE accounting drifted");
    assert!(
        s8.dispatches < s1.dispatches,
        "{solver:?}: k=8 did not amortise dispatches ({} vs {})",
        s8.dispatches,
        s1.dispatches
    );
    assert!(
        s8.bytes_d2h < s1.bytes_d2h,
        "{solver:?}: k=8 did not keep state device-resident ({} vs {} bytes d2h)",
        s8.bytes_d2h,
        s1.bytes_d2h
    );
}

#[test]
fn fused_em_dispatch_is_bit_identical() {
    fused_dispatch_case(ServingSolver::Em { steps: 37 }, 3, 42);
}

#[test]
fn fused_ddim_dispatch_is_bit_identical() {
    fused_dispatch_case(ServingSolver::Ddim { steps: 21 }, 2, 7);
}

#[test]
fn fused_pc_dispatch_is_bit_identical() {
    fused_dispatch_case(ServingSolver::Pc { steps: 19, snr: Some(0.17) }, 2, 11);
}

/// The adaptive tentpole acceptance criterion: the device-side
/// accept/reject fold is a pure amortisation of Algorithm 1. Images,
/// NFE, score_evals and rejections are bit-identical to k = 1 —
/// rejected attempts still run the score net and are still billed,
/// folded from the device attempt log — while dispatches and
/// device->host traffic drop (the fold downloads 4k log scalars per
/// lane instead of 2 full state rows per attempt).
#[test]
fn fused_adaptive_dispatch_is_bit_identical() {
    let Some(dir) = common::artifacts() else { return };
    if common::program_rungs(&dir, "adaptive_stepk8").is_empty() {
        eprintln!("skipping: no adaptive_stepk8 artifacts at or below the engine bucket");
        return;
    }
    let run = |k: usize| {
        let mut cfg = EngineConfig::new(dir.clone(), "vp");
        cfg.bucket = common::engine_bucket(&dir);
        cfg.steps_per_dispatch = k;
        let engine = Engine::start(cfg).unwrap();
        let c = engine.client();
        let r = c.generate_with("", ServingSolver::Adaptive, 3, 0.1, 42).unwrap();
        (r, c.stats().unwrap())
    };
    let (r1, s1) = run(1);
    let (r8, s8) = run(8);
    assert_eq!(r8.images, r1.images, "adaptive fold altered samples");
    assert_eq!(r8.nfe, r1.nfe, "adaptive fold altered NFE");
    assert_eq!(s8.score_evals, s1.score_evals, "attempt billing drifted from k=1");
    assert_eq!(s8.rejections, s1.rejections, "accept/reject outcomes drifted from k=1");
    assert!(s1.rejections > 0, "case must exercise rejected attempts");
    assert!(
        s8.dispatches < s1.dispatches,
        "adaptive k=8 did not amortise dispatches ({} vs {})",
        s8.dispatches,
        s1.dispatches
    );
    assert!(
        s8.bytes_d2h < s1.bytes_d2h,
        "adaptive k=8 did not shrink device->host traffic ({} vs {} bytes)",
        s8.bytes_d2h,
        s1.bytes_d2h
    );
}

/// Bucket migration under the fused adaptive fold: a live lane crossing
/// widths carries its full tuple `(t, h, eps_rel, nfe, rng, x, xprev)`
/// through the slab download -> host row remap -> lazy re-upload
/// bit-exactly. A tight-tolerance lane runs alone (the pool shrinks
/// around it), a loose request grows it back, and the migrating k=8
/// engine must match the pinned k=1 engine on samples, NFE and
/// rejection counts.
#[test]
fn fused_adaptive_migration_matches_pinned_pool() {
    let Some(dir) = common::artifacts() else { return };
    let bucket = common::engine_bucket(&dir);
    if common::program_rungs(&dir, "adaptive_step").len() < 2 {
        eprintln!("skipping: needs >= 2 adaptive_step rungs at or below the engine bucket");
        return;
    }
    if common::program_rungs(&dir, "adaptive_stepk8").len() < 2 {
        eprintln!("skipping: needs >= 2 adaptive_stepk8 rungs (rebuild artifacts)");
        return;
    }
    let run = |migrate: bool, k: usize| {
        let mut cfg = EngineConfig::new(dir.clone(), "vp");
        cfg.bucket = bucket;
        cfg.migrate = migrate;
        cfg.steps_per_dispatch = k;
        cfg.diag_sample = 1; // trace every lane: markers must survive remap
        let engine = Engine::start(cfg).unwrap();
        let c_bg = engine.client();
        let long = std::thread::spawn(move || {
            c_bg.generate_with("", ServingSolver::Adaptive, 1, 0.01, 41).unwrap()
        });
        // wait until the long lane is live so the short request
        // co-batches with (and then outlives-into) a width change
        let c = engine.client();
        while c.stats().unwrap().active_slots == 0 {
            std::thread::yield_now();
        }
        let short = c.generate_with("", ServingSolver::Adaptive, 2, 0.5, 77).unwrap();
        let long = long.join().unwrap();
        let stats = c.stats().unwrap();
        let diag = c.diag(gofast::coordinator::DiagQuery::default()).unwrap();
        (long, short, stats, diag)
    };
    let (long_m, short_m, stats_m, diag_m) = run(true, 8);
    let (long_f, short_f, stats_f, _) = run(false, 1);
    assert_eq!(long_m.images, long_f.images, "migration altered the tight lane's trajectory");
    assert_eq!(long_m.nfe, long_f.nfe);
    assert_eq!(short_m.images, short_f.images, "migration altered the loose lanes");
    assert_eq!(short_m.nfe, short_f.nfe);
    assert_eq!(stats_m.rejections, stats_f.rejections, "migration altered accept/reject");
    let ps = stats_m.programs.iter().find(|p| p.solver == "adaptive").expect("program stats");
    let narrow: u64 =
        ps.steps_per_bucket.iter().filter(|(b, _)| *b < bucket).map(|(_, s)| *s).sum();
    assert!(narrow > 0, "no adaptive steps below max bucket: {:?}", ps.steps_per_bucket);
    assert!(ps.migrations_up + ps.migrations_down > 0, "adaptive pool never switched width");
    // sampled-trace markers must follow lanes through `PoolDiag::remap`:
    // every trace closes cleanly, and the tight lane's trace covers its
    // whole trajectory — one record per Algorithm-1 attempt (nfe counts
    // 2 evals per attempt plus the final denoise)
    let pool = diag_m
        .pools
        .iter()
        .find(|p| p.solver == "adaptive" && p.model == "vp")
        .expect("adaptive diag pool");
    assert_eq!(pool.traces.len(), 3, "every lane must carry a trace");
    assert!(pool.traces.iter().all(|t| t.done), "a trace lost its lane across migration");
    let longest = pool.traces.iter().map(|t| t.steps.len() as u64).max().unwrap();
    assert_eq!(longest, (long_m.nfe[0] - 1) / 2, "tight lane's trace is truncated");
}

/// Per-pool `--steps-per-dispatch` overrides: keyed entries resolve to
/// their pools (model/solver key wins over the global default), pools
/// without an override keep the global, and a key matching no served
/// pool fails startup like a typo'd `--weights` key.
#[test]
fn steps_per_dispatch_overrides_resolve_per_pool() {
    let Some(dir) = common::artifacts() else { return };
    if common::program_rungs(&dir, "adaptive_stepk8").is_empty()
        || common::program_rungs(&dir, "em_stepk4").is_empty()
    {
        eprintln!("skipping: needs fused adaptive_stepk8 and em_stepk4 artifacts");
        return;
    }
    let bucket = common::engine_bucket(&dir);
    let mut cfg = EngineConfig::new(dir.clone(), "vp");
    cfg.bucket = bucket;
    cfg.steps_per_dispatch = 1;
    // ':' is the CLI-friendly alias for '/', normalized by the parser
    cfg.steps_overrides = qos::parse_steps_spec("vp:adaptive=8,vp/em=4").unwrap().1;
    let engine = Engine::start(cfg).unwrap();
    let stats = engine.client().stats().unwrap();
    let k_of = |solver: &str| {
        stats.pool_qos.iter().find(|p| p.solver == solver).map(|p| p.steps_per_dispatch)
    };
    assert_eq!(k_of("adaptive"), Some(8), "adaptive override must win over the global");
    assert_eq!(k_of("em"), Some(4), "em override must win over the global");
    for solver in ["ddim", "pc"] {
        if let Some(k) = k_of(solver) {
            assert_eq!(k, 1, "{solver} pool must keep the global default");
        }
    }
    let mut bad = EngineConfig::new(dir, "vp");
    bad.bucket = bucket;
    bad.steps_overrides = qos::parse_steps_spec("nope=4").unwrap().1;
    let err = match Engine::start(bad) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("typo'd steps-per-dispatch key must fail startup"),
    };
    assert!(err.contains("matches no served pool"), "{err}");
}

/// A requested steps-per-dispatch with no lowered fused variant (k = 5;
/// aot.py lowers FUSED_STEPS = 4, 8) resolves down to the largest
/// available k instead of silently emptying the ladder and un-serving
/// the pool: the request is admitted, outputs and score_evals stay
/// bit-identical to k = 1, and dispatches still amortise (k = 4 under
/// the hood).
#[test]
fn unsupported_steps_per_dispatch_falls_back_to_available_variant() {
    let Some(dir) = common::artifacts() else { return };
    if common::program_rungs(&dir, "em_stepk4").is_empty() {
        eprintln!("skipping: no em_stepk4 artifacts at or below the engine bucket");
        return;
    }
    let run = |k: usize| {
        let mut cfg = EngineConfig::new(dir.clone(), "vp");
        cfg.bucket = common::engine_bucket(&dir);
        cfg.steps_per_dispatch = k;
        let engine = Engine::start(cfg).unwrap();
        let c = engine.client();
        let r = c.generate_with("", ServingSolver::Em { steps: 37 }, 2, 0.5, 5).unwrap();
        (r, c.stats().unwrap())
    };
    let (r1, s1) = run(1);
    let (r5, s5) = run(5);
    assert_eq!(r5.images, r1.images, "k=5 fallback altered samples");
    assert_eq!(r5.nfe, r1.nfe, "k=5 fallback altered NFE");
    assert_eq!(s5.score_evals, s1.score_evals, "k=5 fallback drifted NFE accounting");
    assert!(
        s5.dispatches < s1.dispatches,
        "k=5 must resolve to the k=4 fused variant and amortise dispatches ({} vs {})",
        s5.dispatches,
        s1.dispatches
    );
}

/// PC lanes are first-class serving workloads: correct image range,
/// exact per-sample NFE (2 x predictor steps + denoise), per-program
/// stats with the 2x score-eval cost, and per-lane snr co-batching in
/// one pool.
#[test]
fn pc_generate_roundtrip_counts_two_evals_per_step() {
    let Some(dir) = common::artifacts() else { return };
    if common::program_rungs(&dir, "pc_step").is_empty() {
        eprintln!("skipping: no pc_step artifacts at or below the engine bucket");
        return;
    }
    let Some(engine) = engine() else { return };
    let c = engine.client();
    let a = c.generate_with("", ServingSolver::Pc { steps: 6, snr: None }, 3, 0.5, 42).unwrap();
    assert_eq!(a.images.shape, vec![3, 768]);
    assert!(a.images.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    assert!(a.nfe.iter().all(|&n| n == 13), "pc nfe {:?}", a.nfe);
    // a different snr (and step budget) co-batches in the same pool
    let b = c
        .generate_with("", ServingSolver::Pc { steps: 4, snr: Some(0.17) }, 2, 0.5, 42)
        .unwrap();
    assert!(b.nfe.iter().all(|&n| n == 9), "pc nfe {:?}", b.nfe);
    let stats = c.stats().unwrap();
    let pc = stats.programs.iter().find(|p| p.solver == "pc").expect("pc stats");
    assert!(pc.steps >= 6, "pc steps {}", pc.steps);
    assert_eq!(
        pc.score_evals,
        2 * pc.occupied_lane_steps,
        "stats.programs.pc must report score_evals = 2 x occupied lane-steps"
    );
    // an invalid snr built via the Rust API is a coded admission error
    let err = c
        .generate_with("", ServingSolver::Pc { steps: 4, snr: Some(0.0) }, 1, 0.5, 0)
        .unwrap_err()
        .to_string();
    assert!(err.starts_with(qos::CODE_BAD_SOLVER), "{err}");
    assert!(err.contains("snr"), "{err}");
}

/// Requesting a solver the model has no pool for is a clean protocol
/// error at admission, not an engine-thread fault.
#[test]
fn solver_without_pool_is_rejected_cleanly() {
    let Some(engine) = engine() else { return };
    // vp serves ddim only if its artifacts exist; either way the error
    // paths below must be admission-time rejections
    let err = engine
        .client()
        .generate_with("nope", ServingSolver::Em { steps: 4 }, 1, 0.5, 0)
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown model"), "{err}");
    // the engine must still be healthy after a rejection
    engine.client().generate(1, 0.5, 0).unwrap();
}

#[test]
fn per_bucket_stats_cover_all_steps() {
    let Some(engine) = engine() else { return };
    let c = engine.client();
    c.generate(1, 0.1, 3).unwrap();
    let stats = c.stats().unwrap();
    let total: u64 = stats.steps_per_bucket.iter().map(|(_, s)| *s).sum();
    assert_eq!(total, stats.steps, "per-bucket step counts must sum to total steps");
    assert_eq!(
        stats.wasted_lane_steps + stats.occupied_lane_steps,
        stats.steps_per_bucket.iter().map(|(b, s)| *b as u64 * *s).sum::<u64>(),
        "lane-step accounting must balance"
    );
    assert_eq!(stats.models, vec!["vp".to_string()]);
}

#[test]
fn multi_model_round_robin_serves_both() {
    let Some(dir) = common::artifacts() else { return };
    let rt = gofast::runtime::Runtime::new(&dir).unwrap();
    let mut names = rt.variant_names();
    drop(rt);
    names.sort();
    if names.len() < 2 {
        eprintln!("skipping: needs >= 2 variants, have {names:?}");
        return;
    }
    let mut cfg = EngineConfig::new(dir.clone(), &names[0]);
    cfg.models = vec![names[0].clone(), names[1].clone()];
    cfg.bucket = common::engine_bucket(&dir);
    let engine = Engine::start(cfg).unwrap();
    let mut handles = Vec::new();
    for name in [names[0].clone(), names[1].clone()] {
        let c = engine.client();
        handles.push(std::thread::spawn(move || {
            c.generate_on(&name, 2, 0.1, 7).unwrap().nfe.len()
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 4);
    let stats = engine.client().stats().unwrap();
    assert_eq!(stats.samples_done, 4);
    assert_eq!(stats.requests_done, 2);
    assert_eq!(stats.models, names[..2].to_vec());
}
