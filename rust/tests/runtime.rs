//! Integration: PJRT runtime over real artifacts — loading, ABI, literal
//! vs buffer execution paths, NFE accounting.

mod common;

use gofast::runtime::{score_evals_per_call, Runtime};
use gofast::tensor::Tensor;

#[test]
fn manifest_loads_and_lists_variants() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let names = rt.variant_names();
    assert!(names.iter().any(|n| n == "vp"), "variants: {names:?}");
}

#[test]
fn model_meta_is_consistent() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let m = rt.model("vp").unwrap();
    assert_eq!(m.meta.dim, m.meta.h * m.meta.w * m.meta.c);
    assert_eq!(m.meta.sde_kind, "vp");
    assert!(!m.buckets("score").is_empty());
    assert!(!m.buckets("adaptive_step").is_empty());
}

#[test]
fn unknown_variant_is_a_clean_error() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let err = match rt.model("nope") {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected error for unknown variant"),
    };
    assert!(err.contains("nope"), "{err}");
}

#[test]
fn score_executes_and_is_finite() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let m = rt.model("vp").unwrap();
    let b = m.buckets("score")[0];
    let x = Tensor::zeros(&[b, m.meta.dim]);
    let t = Tensor { shape: vec![b], data: vec![0.5; b] };
    let out = m.exec_literals("score", b, &[&x, &t]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape, vec![b, m.meta.dim]);
    assert!(out[0].data.iter().all(|v| v.is_finite()));
}

#[test]
fn literal_and_buffer_paths_agree() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let m = rt.model("vp").unwrap();
    let b = m.buckets("score")[0];
    let mut x = Tensor::zeros(&[b, m.meta.dim]);
    for (i, v) in x.data.iter_mut().enumerate() {
        *v = ((i % 17) as f32 - 8.0) * 0.1;
    }
    let t = Tensor { shape: vec![b], data: vec![0.7; b] };
    let a = m.exec_literals("score", b, &[&x, &t]).unwrap();
    let c = m.exec_buffers("score", b, &[&x, &t]).unwrap();
    assert_eq!(a[0].shape, c[0].shape);
    let diff = a[0].max_abs_diff(&c[0]);
    assert!(diff == 0.0, "paths diverge by {diff}");
}

#[test]
fn adaptive_step_returns_three_outputs() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let m = rt.model("vp").unwrap();
    let b = m.buckets("adaptive_step")[0];
    let d = m.meta.dim;
    let x = Tensor::zeros(&[b, d]);
    let t = Tensor { shape: vec![b], data: vec![0.5; b] };
    let h = Tensor { shape: vec![b], data: vec![0.01; b] };
    let z = Tensor::zeros(&[b, d]);
    let ea = Tensor::scalar(0.0078);
    let er = Tensor { shape: vec![b], data: vec![0.05; b] };
    let out = m.exec_literals("adaptive_step", b, &[&x, &x, &t, &h, &z, &ea, &er]).unwrap();
    assert_eq!(out.len(), 3, "x'', x', E2");
    assert_eq!(out[0].shape, vec![b, d]);
    assert_eq!(out[1].shape, vec![b, d]);
    assert_eq!(out[2].shape, vec![b]);
}

#[test]
fn adaptive_step_zero_h_is_identity_with_zero_error() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let m = rt.model("vp").unwrap();
    let b = m.buckets("adaptive_step")[0];
    let d = m.meta.dim;
    let mut x = Tensor::zeros(&[b, d]);
    for (i, v) in x.data.iter_mut().enumerate() {
        *v = (i % 7) as f32 * 0.2 - 0.6;
    }
    let t = Tensor { shape: vec![b], data: vec![0.5; b] };
    let h = Tensor { shape: vec![b], data: vec![0.0; b] };
    let mut z = Tensor::zeros(&[b, d]);
    z.fill(1.3);
    let ea = Tensor::scalar(0.0078);
    let er = Tensor { shape: vec![b], data: vec![0.05; b] };
    let out = m.exec_literals("adaptive_step", b, &[&x, &x, &t, &h, &z, &ea, &er]).unwrap();
    assert!(out[0].max_abs_diff(&x) < 1e-6, "x'' must equal x at h=0");
    assert!(out[2].data.iter().all(|&e| e.abs() < 1e-6), "E2 must be 0 at h=0");
}

#[test]
fn nfe_accounting_matches_program_semantics() {
    assert_eq!(score_evals_per_call("score"), 1);
    assert_eq!(score_evals_per_call("adaptive_step"), 2);
    assert_eq!(score_evals_per_call("pc_step"), 2);
    assert_eq!(score_evals_per_call("em_step"), 1);
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let m = rt.model("vp").unwrap();
    rt.reset_stats();
    let b = m.buckets("score")[0];
    let x = Tensor::zeros(&[b, m.meta.dim]);
    let t = Tensor { shape: vec![b], data: vec![0.5; b] };
    m.exec_literals("score", b, &[&x, &t]).unwrap();
    m.exec_literals("score", b, &[&x, &t]).unwrap();
    let stats = rt.stats();
    assert_eq!(stats.score_evals, 2);
    assert_eq!(stats.calls, vec![("score".to_string(), 2)]);
}

/// The hoisted executable cache: steady-state dispatch of the same
/// (program, bucket) resolves through the model-level map, not the
/// string-keyed runtime lookup — repeated calls must not add misses.
#[test]
fn executable_cache_reused_across_dispatches() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let m = rt.model("vp").unwrap();
    let b = m.buckets("score")[0];
    let x = Tensor::zeros(&[b, m.meta.dim]);
    let t = Tensor { shape: vec![b], data: vec![0.5; b] };
    assert_eq!(m.exe_cache_misses(), 0);
    m.exec_buffers("score", b, &[&x, &t]).unwrap();
    assert_eq!(m.exe_cache_misses(), 1, "first dispatch populates the cache");
    for _ in 0..3 {
        m.exec_buffers("score", b, &[&x, &t]).unwrap();
    }
    assert_eq!(m.exe_cache_misses(), 1, "steady-state dispatch must hit the cache");
    // a different bucket is a different executable: exactly one new miss
    if let Some(&b2) = m.buckets("score").iter().find(|&&bb| bb != b) {
        let x2 = Tensor::zeros(&[b2, m.meta.dim]);
        let t2 = Tensor { shape: vec![b2], data: vec![0.5; b2] };
        m.exec_buffers("score", b2, &[&x2, &t2]).unwrap();
        m.exec_buffers("score", b2, &[&x2, &t2]).unwrap();
        assert_eq!(m.exe_cache_misses(), 2);
    }
}

#[test]
fn bucket_for_picks_smallest_fitting() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let m = rt.model("vp").unwrap();
    let buckets = m.buckets("score").to_vec();
    assert_eq!(m.bucket_for("score", 1).unwrap(), buckets[0]);
    let largest = *buckets.last().unwrap();
    assert_eq!(m.bucket_for("score", largest).unwrap(), largest);
    // oversubscribed requests clamp to the largest bucket
    assert_eq!(m.bucket_for("score", largest + 1).unwrap(), largest);
}

#[test]
fn bucket_for_edge_cases() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let m = rt.model("vp").unwrap();
    // n = 0 picks the smallest compiled bucket
    assert_eq!(m.bucket_for("score", 0).unwrap(), m.buckets("score")[0]);
    // unknown program is a clean error naming the program
    let err = m.bucket_for("warp_drive", 4).unwrap_err().to_string();
    assert!(err.contains("warp_drive"), "{err}");
    // an unknown program also has an empty bucket view
    assert!(m.buckets("warp_drive").is_empty());
}
