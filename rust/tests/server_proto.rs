//! Integration: TCP JSON-lines protocol end to end — ping/stats/generate,
//! solver specs on the wire, image payload integrity, malformed-request
//! handling.

mod common;

use gofast::coordinator::{Engine, EngineConfig};
use gofast::server::{serve, Client, EvalRequest, GenerateRequest, ServerConfig};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

fn spawn_server_cfg(
    models: &[&str],
    tweak: impl FnOnce(&mut EngineConfig),
) -> Option<(Engine, std::net::SocketAddr)> {
    let dir = common::artifacts()?;
    let mut cfg = EngineConfig::new(dir.clone(), models[0]);
    cfg.models = models.iter().map(|m| m.to_string()).collect();
    cfg.bucket = common::engine_bucket(&dir);
    tweak(&mut cfg);
    let engine = Engine::start(cfg).expect("engine");
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let client = engine.client();
    std::thread::spawn(move || {
        let _ = serve(
            listener,
            client,
            ServerConfig { port: addr.port(), default_eps_rel: 0.05 },
        );
    });
    Some((engine, addr))
}

fn spawn_server_for(models: &[&str]) -> Option<(Engine, std::net::SocketAddr)> {
    spawn_server_cfg(models, |_| {})
}

fn spawn_server() -> Option<(Engine, std::net::SocketAddr)> {
    spawn_server_for(&["vp"])
}

#[test]
fn ping_stats_generate_roundtrip() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.ping().unwrap();
    let r = c.run(&GenerateRequest::new(2).eps_rel(0.1).seed(3)).unwrap();
    assert_eq!(r.images.shape, vec![2, 768]);
    assert!(r.images.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    assert_eq!(r.nfe.len(), 2);
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("samples_done").unwrap().as_f64().unwrap(), 2.0);
}

#[test]
fn images_can_be_omitted() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let r = c.run(&GenerateRequest::new(1).eps_rel(0.5).images(false)).unwrap();
    assert_eq!(r.images.len(), 0);
    assert_eq!(r.nfe.len(), 1);
}

#[test]
fn malformed_json_gets_error_response_and_connection_survives() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writeln!(writer, "this is not json").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"), "{line}");
    // connection still usable
    writeln!(writer, "{{\"op\":\"ping\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "{line}");
}

#[test]
fn unknown_op_is_rejected() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writeln!(writer, "{{\"op\":\"destroy\"}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("unknown op"), "{line}");
    // the rejection is structured (bad_op) and lists the supported ops
    assert!(line.contains("\"code\":\"bad_op\""), "{line}");
    for op in ["hello", "submit", "poll", "cancel", "periodic", "generate"] {
        assert!(line.contains(op), "supported-op list must name {op}: {line}");
    }
    // every response carries the protocol version
    assert!(line.contains("\"v\":1"), "{line}");
}

/// The evaluate op goes through the engine's eval lanes and reports the
/// run in both the response and the stats counters.
#[test]
fn evaluate_roundtrip_reports_metrics_and_counters() {
    let Some((_engine, addr)) = spawn_server() else { return };
    // eval additionally needs the fid net + exported eval split
    for need in ["artifacts/params/fid16.bin", "artifacts/data/synth-cifar.bin"] {
        if !std::path::Path::new(need).exists() {
            eprintln!("skipping: {need} not built");
            return;
        }
    }
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let r = c
        .run_eval(&EvalRequest::new(3).solver("adaptive").eps_rel(0.5).seed(7))
        .unwrap();
    assert_eq!(r.samples, 3);
    assert_eq!(r.solver, "adaptive");
    assert!(r.fid.is_finite() && r.fid >= 0.0, "fid {}", r.fid);
    assert!(r.is >= 1.0 - 1e-9, "is {}", r.is);
    assert!(r.mean_nfe >= 3.0, "nfe {}", r.mean_nfe);
    let consumed: u64 = r.steps_per_bucket.iter().map(|(_, n)| *n).sum();
    assert!(consumed > 0, "no steps consumed: {:?}", r.steps_per_bucket);
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("evals_done").unwrap().as_f64().unwrap(), 1.0);
    assert_eq!(stats.get("eval_samples_done").unwrap().as_f64().unwrap(), 3.0);
    assert!(stats.get("eval_lane_steps").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(stats.get("eval_active").unwrap().as_f64().unwrap(), 0.0);
}

/// Fixed-step solver specs ride the wire end to end: the request names
/// `em:<n>`, the engine serves it from the em lane pool, and both the
/// response and the per-program stats counters report it.
#[test]
fn evaluate_em_roundtrip_over_the_wire() {
    let Some((_engine, addr)) = spawn_server() else { return };
    for need in ["artifacts/params/fid16.bin", "artifacts/data/synth-cifar.bin"] {
        if !std::path::Path::new(need).exists() {
            eprintln!("skipping: {need} not built");
            return;
        }
    }
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let r = c.run_eval(&EvalRequest::new(3).solver("em:8").eps_rel(0.5).seed(7)).unwrap();
    assert_eq!(r.solver, "em:8");
    assert_eq!(r.samples, 3);
    assert_eq!(r.mean_nfe, 9.0, "em NFE must be steps + denoise exactly");
    assert!(r.fid.is_finite() && r.fid >= 0.0, "fid {}", r.fid);
    let stats = c.stats().unwrap();
    let programs = stats.get("programs").expect("stats.programs");
    let em = programs.get("em").expect("programs.em");
    assert!(em.get("steps").unwrap().as_f64().unwrap() >= 8.0);
    assert!(em.get("occupied_lane_steps").unwrap().as_f64().unwrap() > 0.0);
    let adaptive = programs.get("adaptive").expect("programs.adaptive");
    assert_eq!(adaptive.get("steps").unwrap().as_f64().unwrap(), 0.0);
}

/// Generate accepts a solver spec too and echoes the canonical string.
#[test]
fn generate_with_solver_spec() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let r = c
        .run(&GenerateRequest::new(2).solver("em:5").eps_rel(0.5).seed(3).images(false))
        .unwrap();
    assert_eq!(r.nfe, vec![6, 6], "em nfe is steps + denoise");
}

/// Satellite guard: requesting DDIM on a non-VP model must be a clean
/// `ok:false` protocol error at admission (naming the constraint), not
/// an engine-thread fault — and the connection must stay usable.
#[test]
fn ddim_on_non_vp_model_is_clean_protocol_error() {
    let Some(dir) = common::artifacts() else { return };
    if !dir.join("params/ve.bin").exists() {
        eprintln!("skipping: ve variant not built");
        return;
    }
    let Some((_engine, addr)) = spawn_server_for(&["vp", "ve"]) else { return };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let err = c
        .run_eval(&EvalRequest::new(2).model("ve").solver("ddim:4").eps_rel(0.5))
        .unwrap_err()
        .to_string();
    assert!(err.contains("VP"), "error must name the VP constraint: {err}");
    let err = c
        .run(&GenerateRequest::new(1).model("ve").solver("ddim:4").eps_rel(0.5).images(false))
        .unwrap_err()
        .to_string();
    assert!(err.contains("VP"), "{err}");
    // the engine survived both rejections: vp traffic still flows, and
    // ve still serves its own solvers
    c.run(&GenerateRequest::new(1).model("ve").solver("em:3").eps_rel(0.5).images(false))
        .unwrap();
    c.run(&GenerateRequest::new(1).eps_rel(0.5).images(false)).unwrap();
}

/// Unknown or malformed solver specs die in the wire parser with the
/// accepted-spec list and the structured `bad_solver` code.
#[test]
fn evaluate_rejects_unknown_solver() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let err = c
        .run_eval(&EvalRequest::new(2).solver("ode").eps_rel(0.5))
        .unwrap_err()
        .to_string();
    assert!(err.contains("adaptive, em[:<steps>], ddim[:<steps>]"), "{err}");
    assert!(err.contains("pc[:<steps>[@<snr>]]"), "{err}");
    assert!(err.contains("[bad_solver]"), "{err}");
    let err = c
        .run_eval(&EvalRequest::new(2).solver("em:nope").eps_rel(0.5))
        .unwrap_err()
        .to_string();
    assert!(err.contains("bad step count"), "{err}");
}

/// Satellite guard: a degenerate pc spec (`snr <= 0`, zero steps) is a
/// structured wire error — `ok:false` plus `code:"bad_solver"` —
/// mirroring the zero-step fixed-spec guard, and the connection stays
/// usable.
#[test]
fn bad_pc_spec_error_shape_on_the_wire() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    for (spec, needle) in
        [("pc:64@0", "snr > 0"), ("pc:0", "at least 1 step"), ("pc:64@nope", "bad snr")]
    {
        writeln!(writer, "{{\"op\":\"generate\",\"n\":1,\"solver\":\"{spec}\"}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":false"), "{spec}: {line}");
        assert!(line.contains("\"code\":\"bad_solver\""), "{spec}: {line}");
        assert!(line.contains(needle), "{spec}: {line}");
    }
    // the connection survived the rejections
    writeln!(writer, "{{\"op\":\"ping\"}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "{line}");
}

/// PC specs ride the wire end to end: `pc:<n>[@<snr>]` routes to the pc
/// lane pool, the canonical spec string echoes back, and NFE reports
/// the 2x predictor-corrector cost plus the denoise call.
#[test]
fn pc_specs_ride_the_wire() {
    let Some(dir) = common::artifacts() else { return };
    if common::program_rungs(&dir, "pc_step").is_empty() {
        eprintln!("skipping: no pc_step artifacts at or below the engine bucket");
        return;
    }
    let Some((_engine, addr)) = spawn_server() else { return };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let r = c
        .run(&GenerateRequest::new(2).solver("pc:4").eps_rel(0.5).seed(3).images(false))
        .unwrap();
    assert_eq!(r.nfe, vec![9, 9], "pc nfe is 2 x steps + denoise");
    let r = c
        .run(&GenerateRequest::new(1).solver("pc:4@0.17").eps_rel(0.5).seed(3).images(false))
        .unwrap();
    assert_eq!(r.nfe, vec![9]);
    let stats = c.stats().unwrap();
    let pc = stats.get("programs").unwrap().get("pc").expect("programs.pc");
    assert!(pc.get("steps").unwrap().as_f64().unwrap() >= 4.0);
    let evals = pc.get("score_evals").unwrap().as_f64().unwrap();
    let occupied = pc.get("occupied_lane_steps").unwrap().as_f64().unwrap();
    assert_eq!(evals, 2.0 * occupied, "stats.programs.pc score-eval accounting");
    // evaluate over the wire too (needs the fid net + reference split)
    for need in ["artifacts/params/fid16.bin", "artifacts/data/synth-cifar.bin"] {
        if !std::path::Path::new(need).exists() {
            eprintln!("skipping evaluate half: {need} not built");
            return;
        }
    }
    let r = c
        .run_eval(&EvalRequest::new(3).solver("pc:4@0.17").eps_rel(0.5).seed(7))
        .unwrap();
    assert_eq!(r.solver, "pc:4@0.17");
    assert_eq!(r.mean_nfe, 9.0);
    assert!(r.fid.is_finite() && r.fid >= 0.0, "fid {}", r.fid);
}

/// The QoS wire fields ride generate end to end: `priority` and
/// `deadline_ms` are accepted, a generous deadline does not shed, and a
/// malformed priority dies in the parser with the accepted names.
#[test]
fn generate_priority_and_deadline_roundtrip() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let r = c
        .run(&GenerateRequest::new(1)
            .eps_rel(0.5)
            .seed(3)
            .priority("interactive")
            .deadline_ms(60_000)
            .images(false))
        .unwrap();
    assert_eq!(r.nfe.len(), 1);
    let r = c
        .run(&GenerateRequest::new(2)
            .solver("em:4")
            .eps_rel(0.5)
            .seed(3)
            .priority("batch")
            .images(false))
        .unwrap();
    assert_eq!(r.nfe, vec![5, 5]);
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writeln!(writer, "{{\"op\":\"generate\",\"n\":1,\"priority\":\"urgent\"}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("unknown priority"), "{line}");
    assert!(line.contains("interactive, batch"), "{line}");
    // per-class counters saw both classes
    let stats = c.stats().unwrap();
    let classes = stats.get("qos").unwrap().get("classes").unwrap();
    let inter = classes.get("interactive").unwrap();
    assert_eq!(inter.get("requests_done").unwrap().as_f64().unwrap(), 1.0);
    let batch = classes.get("batch").unwrap();
    assert_eq!(batch.get("requests_done").unwrap().as_f64().unwrap(), 1.0);
    assert!(batch.get("e2e_p95_s").unwrap().as_f64().unwrap() > 0.0);
}

/// Satellite guard: a quota-exceeded generate is a structured wire
/// error — `ok:false` plus a machine-readable `code` field — not prose
/// only, and not an unbounded queue.
#[test]
fn quota_rejection_error_shape_on_the_wire() {
    let Some((_engine, addr)) =
        spawn_server_cfg(&["vp"], |cfg| cfg.qos.set_max_queued("vp", 4))
    else {
        return;
    };
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writeln!(writer, "{{\"op\":\"generate\",\"n\":50,\"eps_rel\":0.5}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"), "{line}");
    assert!(line.contains("\"code\":\"quota_exceeded\""), "{line}");
    assert!(line.contains("quota 4"), "{line}");
    // the typed client surfaces the code, and within-quota traffic flows
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let err = c
        .run(&GenerateRequest::new(50).eps_rel(0.5).images(false))
        .unwrap_err()
        .to_string();
    assert!(err.contains("[quota_exceeded]"), "{err}");
    c.run(&GenerateRequest::new(2).eps_rel(0.5).seed(1).images(false)).unwrap();
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("qos").unwrap().get("rejected_quota").unwrap().as_f64().unwrap(), 2.0);
}

/// `evaluate` takes a priority class but refuses `deadline_ms` (eval
/// jobs run to completion); the refusal happens at the protocol layer,
/// before any engine work.
#[test]
fn evaluate_priority_accepted_deadline_rejected() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writeln!(writer, "{{\"op\":\"evaluate\",\"samples\":4,\"deadline_ms\":10}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("not supported on evaluate"), "{line}");
    // a priority'd evaluate runs through the eval lanes (needs the fid
    // net + reference split)
    for need in ["artifacts/params/fid16.bin", "artifacts/data/synth-cifar.bin"] {
        if !std::path::Path::new(need).exists() {
            eprintln!("skipping evaluate half: {need} not built");
            return;
        }
    }
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let r = c
        .run_eval(&EvalRequest::new(3).solver("em:6").eps_rel(0.5).seed(7).priority("batch"))
        .unwrap();
    assert_eq!(r.samples, 3);
    assert_eq!(r.mean_nfe, 7.0);
}

/// `stats` exports the QoS view: global + per-pool queue depth next to
/// each pool's weight and service turns.
#[test]
fn stats_export_queue_depth_and_pool_qos() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.run(&GenerateRequest::new(2).eps_rel(0.5).seed(1).images(false)).unwrap();
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("queue_depth").unwrap().as_f64().unwrap(), 0.0, "drained engine");
    let qos = stats.get("qos").unwrap();
    assert_eq!(qos.get("shed_deadline").unwrap().as_f64().unwrap(), 0.0);
    let pools = qos.get("pools").unwrap();
    let adaptive = pools.get("vp/adaptive").expect("vp/adaptive pool in qos stats");
    assert_eq!(adaptive.get("weight").unwrap().as_f64().unwrap(), 1.0);
    assert!(adaptive.get("turns").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(adaptive.get("queue_depth").unwrap().as_f64().unwrap(), 0.0);
    // the per-program breakdown carries queue_depth too
    let prog = stats.get("programs").unwrap().get("adaptive").unwrap();
    assert_eq!(prog.get("queue_depth").unwrap().as_f64().unwrap(), 0.0);
}

/// Satellite guard: the span ring really is a ring. Submitting more
/// requests than `--trace-ring` capacity evicts the oldest spans, and
/// `trace` queries on evicted ids/jobs return empty rather than stale
/// records.
#[test]
fn span_ring_wraparound_evicts_oldest_spans() {
    let Some((_engine, addr)) = spawn_server_cfg(&["vp"], |cfg| cfg.trace_ring = 3) else {
        return;
    };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    // first request rides the async job path so both query shapes
    // (span id and job id) can be exercised after its eviction
    let job = c.submit(&GenerateRequest::new(1).eps_rel(0.5).seed(1).images(false)).unwrap();
    while c.poll_job(job, 2000, false).unwrap().is_empty() {}
    let v = c.trace(None, 0, false).unwrap();
    let spans = v.req("spans").unwrap().as_arr().unwrap();
    assert_eq!(spans.len(), 1);
    let first_id = spans[0].req("id").unwrap().as_f64().unwrap() as u64;
    // overflow the ring: 6 more single-span requests into capacity 3
    for seed in 2..8u64 {
        c.run(&GenerateRequest::new(1).eps_rel(0.5).seed(seed).images(false)).unwrap();
    }
    let v = c.trace(None, 0, false).unwrap();
    let spans = v.req("spans").unwrap().as_arr().unwrap();
    assert_eq!(spans.len(), 3, "ring must retain exactly its capacity");
    for s in spans {
        let id = s.req("id").unwrap().as_f64().unwrap() as u64;
        assert!(id > first_id, "oldest span must have been evicted, saw id {id}");
    }
    // job query on the evicted job: empty, not a stale record
    let v = c.trace(Some(job), 0, false).unwrap();
    assert!(v.req("spans").unwrap().as_arr().unwrap().is_empty());
    // raw id query on the evicted span id: same
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writeln!(writer, "{{\"op\":\"trace\",\"id\":{first_id}}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "{line}");
    assert!(line.contains("\"spans\":[]"), "evicted id must query empty: {line}");
}

#[test]
fn parallel_connections_share_the_engine() {
    let Some((engine, addr)) = spawn_server() else { return };
    let mut handles = Vec::new();
    for i in 0..4u64 {
        let addr_s = addr.to_string();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr_s).unwrap();
            c.run(&GenerateRequest::new(2).eps_rel(0.1).seed(i).images(false))
                .unwrap()
                .nfe
                .len()
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 8);
    let stats = engine.client().stats().unwrap();
    assert_eq!(stats.samples_done, 8);
    assert_eq!(stats.requests_done, 4);
}
