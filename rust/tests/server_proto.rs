//! Integration: TCP JSON-lines protocol end to end — ping/stats/generate,
//! image payload integrity, malformed-request handling.

mod common;

use gofast::coordinator::{Engine, EngineConfig};
use gofast::server::{serve, Client, ServerConfig};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

fn spawn_server() -> Option<(Engine, std::net::SocketAddr)> {
    let dir = common::artifacts()?;
    let mut cfg = EngineConfig::new(dir, "vp");
    cfg.bucket = 16;
    let engine = Engine::start(cfg).expect("engine");
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let client = engine.client();
    std::thread::spawn(move || {
        let _ = serve(
            listener,
            client,
            ServerConfig { port: addr.port(), default_eps_rel: 0.05 },
        );
    });
    Some((engine, addr))
}

#[test]
fn ping_stats_generate_roundtrip() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.ping().unwrap();
    let r = c.generate(2, 0.1, 3, true).unwrap();
    assert_eq!(r.images.shape, vec![2, 768]);
    assert!(r.images.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    assert_eq!(r.nfe.len(), 2);
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("samples_done").unwrap().as_f64().unwrap(), 2.0);
}

#[test]
fn images_can_be_omitted() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let r = c.generate(1, 0.5, 0, false).unwrap();
    assert_eq!(r.images.len(), 0);
    assert_eq!(r.nfe.len(), 1);
}

#[test]
fn malformed_json_gets_error_response_and_connection_survives() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writeln!(writer, "this is not json").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"), "{line}");
    // connection still usable
    writeln!(writer, "{{\"op\":\"ping\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "{line}");
}

#[test]
fn unknown_op_is_rejected() {
    let Some((_engine, addr)) = spawn_server() else { return };
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writeln!(writer, "{{\"op\":\"destroy\"}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("unknown op"), "{line}");
}

#[test]
fn parallel_connections_share_the_engine() {
    let Some((engine, addr)) = spawn_server() else { return };
    let mut handles = Vec::new();
    for i in 0..4u64 {
        let addr_s = addr.to_string();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr_s).unwrap();
            c.generate(2, 0.1, i, false).unwrap().nfe.len()
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 8);
    let stats = engine.client().stats().unwrap();
    assert_eq!(stats.samples_done, 8);
    assert_eq!(stats.requests_done, 4);
}
