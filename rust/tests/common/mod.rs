//! Shared helpers for artifact-dependent integration tests: tests skip
//! (pass vacuously with a note) when `make artifacts` has not run yet,
//! so `cargo test` works at any build stage.

#![allow(dead_code)] // each integration test binary uses a subset

use std::path::{Path, PathBuf};

pub fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from("artifacts");
    if p.join("manifest.json").exists() && p.join("params").join("vp.bin").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

/// Widest compiled `adaptive_step` bucket for `vp`, capped at 16 — the
/// engine width the coordinator/server tests run at. Read from the
/// manifest (no PJRT needed) so the tests also pass against miniature
/// artifact sets (CI builds one with STEP_BUCKETS=(1,2)).
pub fn engine_bucket(dir: &Path) -> usize {
    gofast::runtime::manifest_engine_bucket(dir, "vp", 16).unwrap_or(16)
}

/// All compiled `adaptive_step` buckets for `vp`, ascending.
pub fn step_buckets(dir: &Path) -> Vec<usize> {
    gofast::runtime::manifest_buckets(dir, "vp", "adaptive_step").unwrap_or_default()
}

/// Compiled rungs of any step `program` ("pc_step", "ddim_step", ...)
/// for `vp` at or below the engine bucket — the shared gate for
/// artifact-dependent fixed-step solver tests (a pool exists only when
/// this is non-empty; migration tests need two rungs).
pub fn program_rungs(dir: &Path, program: &str) -> Vec<usize> {
    let cap = engine_bucket(dir);
    gofast::runtime::manifest_buckets(dir, "vp", program)
        .unwrap_or_default()
        .into_iter()
        .filter(|&b| b <= cap)
        .collect()
}

#[macro_export]
macro_rules! require_artifacts {
    () => {
        match common::artifacts() {
            Some(p) => p,
            None => return,
        }
    };
}
