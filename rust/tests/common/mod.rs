//! Shared helpers for artifact-dependent integration tests: tests skip
//! (pass vacuously with a note) when `make artifacts` has not run yet,
//! so `cargo test` works at any build stage.

use std::path::PathBuf;

pub fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from("artifacts");
    if p.join("manifest.json").exists() && p.join("params").join("vp.bin").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[macro_export]
macro_rules! require_artifacts {
    () => {
        match common::artifacts() {
            Some(p) => p,
            None => return,
        }
    };
}
