//! # gofast
//!
//! A serving engine for score-based (diffusion) generative models built
//! around the adaptive SDE solver of *"Gotta Go Fast When Generating Data
//! with Score-Based Models"* (Jolicoeur-Martineau et al., 2021).
//!
//! Three-layer architecture (docs/ARCHITECTURE.md):
//! * **L1** — Pallas kernels (authored in `python/compile/kernels/`),
//! * **L2** — JAX score network + solver-step graphs, AOT-lowered to HLO
//!   text artifacts (`python/compile/aot.py`),
//! * **L3** — this crate: the PJRT runtime that loads those artifacts and
//!   the coordinator that serves sampling requests with per-sample
//!   adaptive step sizes (continuous batching across models and
//!   occupancy-matched batch buckets).
//!
//! Python never runs on the request path; after `make artifacts` the
//! `gofast` binary is self-contained.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod json;
pub mod linalg;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod sde;
pub mod server;
pub mod solvers;
pub mod tensor;
pub mod testkit;
pub mod workload;

pub use anyhow::{anyhow, bail, Context, Result};
