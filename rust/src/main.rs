//! `gofast` CLI — leader entrypoint.
//!
//! Subcommands:
//!   generate  sample a batch offline with any solver, write a PPM grid
//!   serve     start the continuous-batching TCP server
//!   client    issue generate/stats requests against a running server
//!   inspect   list artifact variants, programs and buckets
//!   evaluate  FID*/IS* against the reference split, served through the
//!             engine's scheduler/registry path (--offline bypasses it)
//!   trace     dump request-lifecycle spans and dispatch timelines from
//!             a running server (--chrome writes a chrome://tracing file)
//!   diag      dump per-pool solver profiles and sampled lane traces
//!             (--csv for plot-ready output)
//!   health    print the engine watchdog's status, counters and events
//!
//! Paper-table regeneration lives in `benches/` (cargo bench).

use gofast::cli::Args;
use gofast::config::Config;
use gofast::coordinator::{qos, Engine, EngineConfig};
use gofast::metrics;
use gofast::rng::Rng;
use gofast::runtime::Runtime;
use gofast::solvers::{self, adaptive, ddim, em, lamba, prob_flow, rdl, spec, Ctx, SolveOpts};
use gofast::tensor::{save_image_grid, Tensor};
use gofast::{bail, json, Context, Result};
use std::path::{Path, PathBuf};

fn main() {
    let args = match Args::parse_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let r = match cmd {
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "inspect" => cmd_inspect(&args),
        "evaluate" => cmd_evaluate(&args),
        "trace" => cmd_trace(&args),
        "diag" => cmd_diag(&args),
        "health" => cmd_health(&args),
        "help" | "--help" => {
            print!("{}", USAGE);
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "\
gofast — adaptive-SDE diffusion sampling engine

USAGE: gofast <command> [flags]

  generate  --model vp [--solver adaptive|em|rdl|ddim|ode|lamba]
            [--n 16] [--eps-rel 0.05] [--steps 256] [--seed 0]
            [--bucket 16] [--composed] [--no-denoise] [--out grid.ppm]
            [--artifacts artifacts]
  serve     [--config configs/server.toml] [--models vp,ve]
            [--solvers adaptive,em,ddim,pc] [--max-bucket 16] [--no-migrate]
            [--steps-per-dispatch 1] [--weights vp=3,ve=1|vp/em=0.5]
            [--quota vp=256] [--quota-lanes vp=8]
            [--default-priority interactive|batch] [--trace-ring 1024]
            [--diag-sample 0] [--health-interval 1.0] [--stall-budget 10.0]
            [--set k=v ...]
            (--steps-per-dispatch k>1 keeps fixed-step lane state
             device-resident and advances k grid nodes per kernel
             launch via the fused k-step artifacts — bit-identical
             samples, ~k-fold fewer dispatches; pools whose artifacts
             lack the fused variants are left unserved)
            (QoS: --weights sets deficit-round-robin pool weights keyed
             model or model/program; --quota caps queued samples and
             --quota-lanes active lanes per model; requests may carry
             priority/deadline_ms — see rust/src/server/mod.rs)
            (--trace-ring N keeps the newest N request-lifecycle spans
             for the trace op; 0 disables tracing entirely)
            (--diag-sample N records every Nth admitted lane's full
             (t, h, err, accepted) step sequence for the diag op; 0 —
             the default — keeps the hot step path allocation-free.
             --health-interval / --stall-budget tune the watchdog's
             check cadence and per-lane no-progress budget, seconds)
  client    [generate|submit|poll|cancel|watch|hello|metrics]
            [--addr 127.0.0.1:7878] [--model vp]
            [--solver adaptive|em:<n>|ddim:<n>|pc:<n>[@<snr>]]
            [--n 4] [--eps-rel 0.05] [--seed 0] [--priority interactive|batch]
            [--deadline-ms 0] [--binary] [--stats] [--out grid.ppm]
            (async job ops — wire spec in docs/PROTOCOL.md:
             submit fires a generate and prints the job id;
             poll [--job id] [--timeout-ms 0] drains completed jobs;
             cancel --job id frees a still-queued job;
             watch [--rate-ms 1000] [--rounds 0] runs a periodic job and
             streams its rounds, each with a span-derived queue/exec
             latency line; hello prints server capabilities; metrics
             prints the Prometheus text exposition;
             --binary asks for raw f32 payload frames instead of base64)
  evaluate  --model vp [--solver adaptive|em:<n>|ddim:<n>|pc:<n>[@<snr>]|...]
            [--samples 256]
            [--eps-rel 0.05] [--seed 0] [--addr host:port] [--offline]
            [--check] [...generate flags]
            (default: served through the engine's solver-program lane
             pools; --offline bypasses the coordinator; --check runs both
             and asserts agreement. pc:<n> is the served predictor-
             corrector — 2 score evals per step, @<snr> overrides the
             process-default Langevin SNR. Non-served solvers — ode,
             lamba, ... — are --offline only.)
  inspect   [--artifacts artifacts]
  trace     [--addr 127.0.0.1:7878] [--last 0] [--chrome trace.json]
            (dump request-lifecycle spans + the dispatch timeline from a
             running server's trace ring; --chrome writes a
             chrome://tracing / Perfetto timeline JSON with per-dispatch
             upload/exec/download phases and watchdog health events as
             instant markers; --last 0 = all retained spans)
  diag      [--addr 127.0.0.1:7878] [--pool model:solver] [--lane id]
            [--csv]
            (dump per-pool diffusion-time profiles — step sizes,
             accept/reject counts, error norms per bin — and, when the
             server runs with --diag-sample, retained lane traces.
             --csv emits plot-ready rows: bins by default, one row per
             recorded step with --lane)
  health    [--addr 127.0.0.1:7878]
            (print the watchdog's status gauge, per-kind event
             counters, and the retained health-event ring)
";

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

fn run_solver(
    ctx: &Ctx,
    rng: &mut Rng,
    solver: &str,
    args: &Args,
) -> Result<solvers::SolveResult> {
    let steps = args.usize_or("steps", 256)?;
    let eps_rel = args.f64_or("eps-rel", 0.05)?;
    match solver {
        "adaptive" => {
            let opts = adaptive::AdaptiveOpts {
                eps_rel,
                r: args.f64_or("r", 0.9)?,
                safety: args.f64_or("safety", 0.9)?,
                ..Default::default()
            };
            if args.has("composed") {
                adaptive::run_composed(ctx, rng, &opts)
            } else {
                adaptive::run_fused(ctx, rng, &opts)
            }
        }
        "em" => {
            if args.has("composed") {
                em::run_composed(ctx, rng, steps)
            } else {
                em::run(ctx, rng, steps)
            }
        }
        "rdl" => rdl::run(ctx, rng, steps, None),
        "ddim" => ddim::run(ctx, rng, steps),
        "ode" => prob_flow::run(
            ctx,
            rng,
            &prob_flow::OdeOpts {
                rtol: args.f64_or("rtol", 1e-4)?,
                atol: args.f64_or("atol", 1e-4)?,
                ..Default::default()
            },
        ),
        "lamba" => lamba::run(
            ctx,
            rng,
            &lamba::LambaOpts { eps_rel, ..Default::default() },
        ),
        other => bail!("unknown solver '{other}'"),
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let rt = Runtime::new(&artifacts_dir(args))?;
    let model_name = args.str_or("model", "vp");
    let model = rt.model(&model_name)?;
    let bucket = args.usize_or("bucket", 16)?;
    let opts = SolveOpts {
        fused_buffers: !args.has("literals"),
        denoise: !args.has("no-denoise"),
    };
    let ctx = Ctx::new(&model, bucket, opts);
    let solver = args.str_or("solver", "adaptive");
    let n = args.usize_or("n", bucket)?;
    let mut rng = Rng::new(args.u64_or("seed", 0)?);
    let mut images = Tensor::zeros(&[n, model.meta.dim]);
    let mut nfe_all = Vec::new();
    let t0 = std::time::Instant::now();
    let mut done = 0;
    while done < n {
        let take = (n - done).min(bucket);
        let res = run_solver(&ctx, &mut rng, &solver, args)?;
        for i in 0..take {
            images.row_mut(done + i).copy_from_slice(res.x.row(i));
        }
        nfe_all.extend_from_slice(&res.nfe_per_sample[..take]);
        done += take;
    }
    let wall = t0.elapsed().as_secs_f64();
    let process = model.meta.process();
    process.to_unit_range(&mut images);
    let mean_nfe = nfe_all.iter().sum::<u64>() as f64 / nfe_all.len() as f64;
    println!(
        "model={model_name} solver={solver} n={n} mean_nfe={mean_nfe:.1} wall={wall:.2}s ({:.2} samples/s)",
        n as f64 / wall
    );
    let out = args.str_or("out", "grid.ppm");
    let cols = (n as f64).sqrt().ceil() as usize;
    save_image_grid(Path::new(&out), &images, model.meta.h, model.meta.w, cols.max(1))?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::load(Path::new(path))?,
        None => {
            let default = Path::new("configs/server.toml");
            if default.exists() {
                Config::load(default)?
            } else {
                Config::parse("")?
            }
        }
    };
    cfg.apply_overrides(args)?;
    let artifacts = PathBuf::from(cfg.str_or("artifacts", "artifacts")?);
    // models: --models a,b > [server] models = ["a","b"] > server.model
    let models: Vec<String> = if args.has("models") {
        args.str_list_or("models", &[])
    } else if let Some(gofast::config::Item::List(items)) = cfg.get("server.models") {
        items
            .iter()
            .map(|i| Ok(i.as_str()?.to_string()))
            .collect::<gofast::Result<Vec<String>>>()?
    } else {
        vec![cfg.str_or("server.model", "vp")?]
    };
    if models.is_empty() {
        bail!("--models needs at least one model name");
    }
    let port = cfg.usize_or("server.port", 7878)? as u16;
    let default_bucket = cfg.usize_or("server.bucket", 16)?;
    let bucket =
        args.usize_or("max-bucket", cfg.usize_or("server.max_bucket", default_bucket)?)?;
    let migrate = if args.has("no-migrate") {
        false
    } else {
        args.bool_or("migrate", cfg.bool_or("server.migrate", true)?)?
    };
    // --solvers: which lane-program pools each model gets; names are
    // validated by the same spec parser the wire layer uses, so serve
    // and the protocol cannot drift in accepted solvers
    let mut programs = Vec::new();
    for name in args.str_list_or("solvers", &["adaptive", "em", "ddim", "pc"]) {
        if name.contains(':') || name.contains('@') {
            // a silently-dropped step count (or snr) would misconfigure
            // every bare-name request, so refuse it outright
            bail!(
                "--solvers takes bare program names (got '{name}'); step counts \
                 and snr travel per request, e.g. solver=em:128 or pc:64@0.17"
            );
        }
        let prog = spec::parse(&name)?.name().to_string();
        if !programs.contains(&prog) {
            programs.push(prog);
        }
    }
    // QoS: pool weights, per-model quotas, default priority class
    // (validated against the served models at engine startup)
    let mut qcfg = qos::QosConfig {
        weights: qos::parse_weights(&args.str_or("weights", ""))?,
        quotas: Vec::new(),
        default_priority: qos::Priority::parse(
            &args.str_or("default-priority", "interactive"),
        )?,
    };
    for (model, n) in qos::parse_quota_list(&args.str_or("quota", ""))? {
        qcfg.set_max_queued(&model, n);
    }
    for (model, n) in qos::parse_quota_list(&args.str_or("quota-lanes", ""))? {
        qcfg.set_max_active_lanes(&model, n);
    }

    let mut ecfg = EngineConfig::new(&artifacts, &models[0]);
    ecfg.models = models.clone();
    ecfg.programs = programs.clone();
    ecfg.bucket = bucket;
    ecfg.migrate = migrate;
    ecfg.fused_buffers = cfg.bool_or("server.fused_buffers", true)?;
    // global k plus optional per-pool overrides: "8", "vp=4", or
    // "8,vp/adaptive=4" (':' also accepted as the key separator);
    // override keys are validated against served pools at startup
    let (steps_global, steps_overrides) =
        qos::parse_steps_spec(&args.str_or("steps-per-dispatch", ""))?;
    ecfg.steps_per_dispatch =
        steps_global.unwrap_or(cfg.usize_or("server.steps_per_dispatch", 1)?);
    if ecfg.steps_per_dispatch == 0 {
        bail!("server.steps_per_dispatch must be >= 1");
    }
    ecfg.steps_overrides = steps_overrides;
    ecfg.max_queue_samples = cfg.usize_or("server.max_queue_samples", 4096)?;
    ecfg.trace_ring =
        args.usize_or("trace-ring", cfg.usize_or("server.trace_ring", 1024)?)?;
    ecfg.diag_sample =
        args.usize_or("diag-sample", cfg.usize_or("server.diag_sample", 0)?)?;
    ecfg.health_interval_s = args
        .f64_or("health-interval", cfg.f64_or("server.health_interval_s", 1.0)?)?;
    ecfg.stall_budget_s =
        args.f64_or("stall-budget", cfg.f64_or("server.stall_budget_s", 10.0)?)?;
    ecfg.qos = qcfg;

    let engine = Engine::start(ecfg)?;
    let listener = std::net::TcpListener::bind(("127.0.0.1", port))
        .with_context(|| format!("binding port {port}"))?;
    println!(
        "gofast serving models={models:?} solvers={programs:?} on 127.0.0.1:{port} \
         (max-bucket={bucket}, migrate={migrate})"
    );
    gofast::server::serve(
        listener,
        engine.client(),
        gofast::server::ServerConfig {
            port,
            default_eps_rel: cfg.f64_or("solver.eps_rel", 0.05)?,
        },
    )
}

/// The one request surface every client subcommand serializes from
/// (sync `generate`, async `submit`/`watch`): flags -> builder.
fn gen_request(args: &Args) -> Result<gofast::server::GenerateRequest> {
    let priority = args.str_or("priority", "");
    if !priority.is_empty() {
        qos::Priority::parse(&priority)?; // fail locally, not on the wire
    }
    Ok(gofast::server::GenerateRequest::new(args.usize_or("n", 4)?)
        .model(&args.str_or("model", ""))
        .solver(&args.str_or("solver", ""))
        .eps_rel(args.f64_or("eps-rel", 0.05)?)
        .seed(args.u64_or("seed", 0)?)
        .priority(&priority)
        .deadline_ms(args.u64_or("deadline-ms", 0)?)
        .binary(args.has("binary")))
}

fn print_gen(args: &Args, n: usize, r: &gofast::server::ClientGenResult) -> Result<()> {
    let model = args.str_or("model", "");
    let solver = args.str_or("solver", "");
    let mean_nfe = r.nfe.iter().sum::<u64>() as f64 / r.nfe.len().max(1) as f64;
    println!(
        "model={} solver={} n={n} wall={:.2}s queued={:.3}s mean_nfe={mean_nfe:.1}",
        if model.is_empty() { "<default>" } else { &model },
        if solver.is_empty() { "adaptive" } else { &solver },
        r.wall_s,
        r.queued_s
    );
    if let Some(out) = args.get("out") {
        let d = r.images.shape[1] / 3;
        let side = (d as f64).sqrt() as usize;
        let cols = (n as f64).sqrt().ceil() as usize;
        save_image_grid(Path::new(out), &r.images, side, side, cols.max(1))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn print_update(u: &gofast::server::JobUpdate) {
    let round = u.round.map(|r| format!(" round={r}")).unwrap_or_default();
    if let Some(err) = &u.error {
        let code = u.code.as_deref().unwrap_or("internal");
        println!("job {} {}{round} failed [{code}]: {err}", u.job, u.op);
    } else if let Some(g) = &u.gen {
        let mean_nfe = g.nfe.iter().sum::<u64>() as f64 / g.nfe.len().max(1) as f64;
        println!(
            "job {} {}{round} done: n={} wall={:.2}s queued={:.3}s mean_nfe={mean_nfe:.1}",
            u.job,
            u.op,
            g.nfe.len(),
            g.wall_s,
            g.queued_s
        );
    } else if let Some(e) = &u.eval {
        println!(
            "job {} {}{round} done: samples={} FID*={:.3} IS*={:.3} NFE={:.1}",
            u.job, u.op, e.samples, e.fid, e.is, e.mean_nfe
        );
    }
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:7878");
    let mut client = gofast::server::Client::connect(&addr)?;
    if args.has("stats") {
        println!("{}", client.stats()?);
        return Ok(());
    }
    let binary = args.has("binary");
    match args.positional.get(1).map(|s| s.as_str()).unwrap_or("generate") {
        "generate" => {
            let req = gen_request(args)?;
            let n = args.usize_or("n", 4)?;
            let r = client.run(&req)?;
            print_gen(args, n, &r)
        }
        "submit" => {
            let id = client.submit(&gen_request(args)?)?;
            println!("job {id}");
            Ok(())
        }
        "poll" => {
            let timeout_ms = args.u64_or("timeout-ms", 0)?;
            let updates = match args.get("job") {
                Some(_) => client.poll_job(args.u64_or("job", 0)?, timeout_ms, binary)?,
                None => client.poll(timeout_ms, binary)?,
            };
            if updates.is_empty() {
                println!("no completed jobs");
            }
            for u in &updates {
                print_update(u);
            }
            Ok(())
        }
        "cancel" => {
            let id = args.u64_or("job", 0)?;
            if id == 0 {
                bail!("cancel needs --job <id>");
            }
            if client.cancel(id)? {
                println!("job {id} canceled (freed while queued)");
            } else {
                println!("job {id} still running (will complete; poll for the result)");
            }
            Ok(())
        }
        "watch" => {
            let rate_ms = args.u64_or("rate-ms", 1000)?;
            let rounds = args.u64_or("rounds", 0)?; // 0 = until killed
            let id = client.periodic(&gen_request(args)?, rate_ms)?;
            println!("periodic job {id} every {rate_ms}ms (ctrl-c to stop)");
            let mut seen = 0u64;
            loop {
                for u in client.poll_job(id, 1000, binary)? {
                    print_update(&u);
                    print_watch_trace(&mut client, id);
                    print_watch_health(&mut client);
                    seen += 1;
                }
                if rounds > 0 && seen >= rounds {
                    let _ = client.cancel(id);
                    return Ok(());
                }
            }
        }
        "hello" => {
            println!("{}", client.hello()?);
            Ok(())
        }
        "metrics" => {
            print!("{}", client.metrics()?);
            Ok(())
        }
        other => bail!(
            "unknown client subcommand '{other}' (generate, submit, poll, cancel, watch, \
             hello, metrics)"
        ),
    }
}

/// Compact span-derived telemetry line under each watch round: where
/// the round's wall time went (queue wait vs lane execution) and how
/// many dispatch batches advanced it. Silent when the server runs with
/// --trace-ring 0 or the span has already been evicted.
fn print_watch_trace(client: &mut gofast::server::Client, job: u64) {
    let Ok(v) = client.trace(Some(job), 0, false) else { return };
    let Ok(spans) = v.req("spans").and_then(|s| s.as_arr()) else { return };
    let Some(s) = spans.iter().rev().find(|s| s.get("e2e_s").is_some()) else { return };
    let f = |k: &str| s.get(k).and_then(|x| x.as_f64().ok()).unwrap_or(0.0);
    println!(
        "  span {}: queued={:.1}ms exec={:.1}ms e2e={:.1}ms dispatches={}",
        f("id") as u64,
        f("queued_s") * 1e3,
        f("exec_s") * 1e3,
        f("e2e_s") * 1e3,
        f("dispatches") as u64,
    );
}

/// Watchdog line under each watch round: overall status plus any
/// event kinds that have fired so far. Silent against servers that
/// predate the health op.
fn print_watch_health(client: &mut gofast::server::Client) {
    let Ok(v) = client.health() else { return };
    let Ok(status) = v.req("status").and_then(|s| s.as_f64()) else { return };
    let mut fired = Vec::new();
    if let Ok(counts) = v.req("counts") {
        for (kind, n) in counts.members() {
            if n.as_f64().unwrap_or(0.0) > 0.0 {
                fired.push(format!("{kind}={}", n.as_f64().unwrap_or(0.0) as u64));
            }
        }
    }
    println!(
        "  health {}{}{}",
        if status >= 1.0 { "ok" } else { "DEGRADED" },
        if fired.is_empty() { "" } else { " " },
        fired.join(" "),
    );
}

/// `gofast trace`: dump the server's span ring (and dispatch timeline)
/// as text, or as a chrome://tracing / Perfetto JSON with --chrome.
fn cmd_trace(args: &Args) -> Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:7878");
    let mut client = gofast::server::Client::connect(&addr)?;
    let last = args.usize_or("last", 0)?;
    let v = client.trace(None, last, true)?;
    let spans = v.req("spans")?.as_arr()?;
    let timeline = v.req("timeline")?.as_arr()?;
    if let Some(out) = args.get("chrome") {
        // watchdog events share the telemetry epoch with the rings, so
        // firings line up with the dispatch timeline; tolerate servers
        // that predate the health op
        let health = client.health().ok();
        let events = health
            .as_ref()
            .and_then(|h| h.req("events").and_then(|e| e.as_arr()).ok())
            .unwrap_or(&[]);
        let text = chrome_trace(spans, timeline, events)?;
        std::fs::write(out, &text).with_context(|| format!("writing {out}"))?;
        println!(
            "wrote {out}: {} request spans, {} dispatches, {} health events \
             (open in chrome://tracing or Perfetto)",
            spans.len(),
            timeline.len(),
            events.len()
        );
        return Ok(());
    }
    for s in spans {
        let g = |k: &str| s.get(k).and_then(|x| x.as_str().ok()).unwrap_or("-");
        let f = |k: &str| s.get(k).and_then(|x| x.as_f64().ok());
        let mut line = format!(
            "span {} {} {}/{} n={} priority={}",
            f("id").unwrap_or(0.0) as u64,
            g("kind"),
            g("model"),
            g("solver"),
            f("n").unwrap_or(0.0) as u64,
            g("priority"),
        );
        if let Some(q) = f("queued_s") {
            line.push_str(&format!(" queued={:.1}ms", q * 1e3));
        }
        if let Some(x) = f("exec_s") {
            line.push_str(&format!(" exec={:.1}ms", x * 1e3));
        }
        line.push_str(&format!(" dispatches={}", f("dispatches").unwrap_or(0.0) as u64));
        match s.get("outcome") {
            Some(o) => line.push_str(&format!(" outcome={}", o.as_str()?)),
            None => line.push_str(" outcome=in-flight"),
        }
        if let Some(c) = s.get("code") {
            line.push_str(&format!(" code={}", c.as_str()?));
        }
        println!("{line}");
    }
    println!("{} spans, {} dispatch records (--chrome <out.json> for a timeline)",
        spans.len(), timeline.len());
    Ok(())
}

/// Chrome-trace ("trace event format") export: one complete ("X")
/// event per finished request span (its own tid, so concurrent
/// requests stack instead of clobbering), plus upload/exec/download
/// phase events per dispatch on tid 0, plus one global-scope instant
/// ("i") marker per watchdog health event. Timestamps are microseconds
/// on the telemetry epoch shared by all three sources.
fn chrome_trace(
    spans: &[json::Value],
    timeline: &[json::Value],
    health: &[json::Value],
) -> Result<String> {
    use json::Value;
    let mut events: Vec<Value> = Vec::new();
    for d in timeline {
        let f = |k: &str| d.get(k).and_then(|x| x.as_f64().ok()).unwrap_or(0.0);
        let program = d.get("program").and_then(|x| x.as_str().ok()).unwrap_or("dispatch");
        let mut t = f("start_s");
        for (phase, dur) in
            [("upload", f("upload_s")), ("exec", f("exec_s")), ("download", f("download_s"))]
        {
            // zero-length upload/download phases (device-resident lane
            // state) would only clutter the timeline
            if dur > 0.0 || phase == "exec" {
                events.push(Value::obj(vec![
                    ("name", Value::str(format!("{program}:{phase}"))),
                    ("cat", Value::str("dispatch")),
                    ("ph", Value::str("X")),
                    ("ts", Value::num(t * 1e6)),
                    ("dur", Value::num(dur * 1e6)),
                    ("pid", Value::num(0.0)),
                    ("tid", Value::num(0.0)),
                    ("args", d.clone()),
                ]));
            }
            t += dur;
        }
    }
    for s in spans {
        let f = |k: &str| s.get(k).and_then(|x| x.as_f64().ok());
        let (Some(id), Some(submit)) = (f("id"), f("submit_s")) else { continue };
        // in-flight spans have no duration yet; skip them rather than
        // invent an end time
        let Some(e2e) = f("e2e_s") else { continue };
        let name = format!(
            "{} {}/{}",
            s.get("kind").and_then(|x| x.as_str().ok()).unwrap_or("request"),
            s.get("model").and_then(|x| x.as_str().ok()).unwrap_or("?"),
            s.get("solver").and_then(|x| x.as_str().ok()).unwrap_or("?"),
        );
        events.push(Value::obj(vec![
            ("name", Value::str(name)),
            ("cat", Value::str("request")),
            ("ph", Value::str("X")),
            ("ts", Value::num(submit * 1e6)),
            ("dur", Value::num(e2e * 1e6)),
            ("pid", Value::num(1.0)),
            ("tid", Value::num(id)),
            ("args", s.clone()),
        ]));
    }
    for h in health {
        // global-scope instant events draw a full-height line across
        // every track, so firings line up with the dispatch timeline
        let Some(at) = h.get("at_s").and_then(|x| x.as_f64().ok()) else { continue };
        let kind = h.get("kind").and_then(|x| x.as_str().ok()).unwrap_or("health");
        events.push(Value::obj(vec![
            ("name", Value::str(kind)),
            ("cat", Value::str("health")),
            ("ph", Value::str("i")),
            ("s", Value::str("g")),
            ("ts", Value::num(at * 1e6)),
            ("pid", Value::num(0.0)),
            ("tid", Value::num(0.0)),
            ("args", h.clone()),
        ]));
    }
    Ok(Value::obj(vec![("traceEvents", Value::Arr(events))]).to_string())
}

/// `gofast diag`: per-pool solver profiles (step sizes, accept/reject
/// counts, error norms over the diffusion-time grid) plus any sampled
/// lane traces, as text or plot-ready CSV. `--lane` narrows traces to
/// one request id; with `--csv` it switches the output to one row per
/// recorded step instead of one row per profile bin.
fn cmd_diag(args: &Args) -> Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:7878");
    let mut client = gofast::server::Client::connect(&addr)?;
    let lane = match args.get("lane") {
        Some(_) => Some(args.u64_or("lane", 0)?),
        None => None,
    };
    let v = client.diag(args.get("pool"), lane)?;
    let pools = v.req("pools")?.as_arr()?;
    if args.has("csv") {
        return print_diag_csv(pools, lane.is_some());
    }
    for p in pools {
        let g = |k: &str| p.get(k).and_then(|x| x.as_str().ok()).unwrap_or("?");
        let adaptive = p.get("adaptive").and_then(|x| x.as_bool().ok()).unwrap_or(false);
        let bins = p.req("bins")?.as_arr()?;
        let traces = p.req("traces")?.as_arr()?;
        let (mut steps, mut acc, mut rej) = (0u64, 0u64, 0u64);
        for b in bins {
            let f = |k: &str| b.get(k).and_then(|x| x.as_f64().ok()).unwrap_or(0.0);
            steps += f("steps") as u64;
            acc += f("accepted") as u64;
            rej += f("rejected") as u64;
        }
        if adaptive {
            let n = (acc + rej).max(1);
            println!(
                "pool {}/{} adaptive: {} proposals ({} accepted, {} rejected, \
                 reject rate {:.3}), {} sampled traces",
                g("model"),
                g("solver"),
                acc + rej,
                acc,
                rej,
                rej as f64 / n as f64,
                traces.len(),
            );
        } else {
            println!(
                "pool {}/{} fixed: {} grid nodes, {} sampled traces",
                g("model"),
                g("solver"),
                steps,
                traces.len(),
            );
        }
        for b in bins {
            let f = |k: &str| b.get(k).and_then(|x| x.as_f64().ok()).unwrap_or(0.0);
            if f("steps") + f("accepted") + f("rejected") == 0.0 {
                continue; // untouched bin
            }
            if adaptive {
                println!(
                    "  t [{:.3}, {:.3}): acc={} rej={} h_mean={:.4} h=[{:.4}, {:.4}] \
                     err_mean={:.3} err_max={:.3}",
                    f("t_lo"),
                    f("t_hi"),
                    f("accepted") as u64,
                    f("rejected") as u64,
                    f("h_mean"),
                    f("h_min"),
                    f("h_max"),
                    f("err_mean"),
                    f("err_max"),
                );
            } else {
                println!(
                    "  t [{:.3}, {:.3}): steps={}",
                    f("t_lo"),
                    f("t_hi"),
                    f("steps") as u64
                );
            }
        }
        for t in traces {
            let f = |k: &str| t.get(k).and_then(|x| x.as_f64().ok()).unwrap_or(0.0);
            let done = t.get("done").and_then(|x| x.as_bool().ok()).unwrap_or(false);
            let n = t.req("steps")?.as_arr()?.len();
            println!(
                "  trace lane={} sample={} steps={} {}",
                f("lane") as u64,
                f("sample") as u64,
                n,
                if done { "done" } else { "running" },
            );
        }
    }
    if pools.is_empty() {
        println!("no pools matched (diag --pool takes model:solver or model/solver)");
    }
    Ok(())
}

/// Plot-ready CSV for `gofast diag --csv`: one row per profile bin,
/// or — with `--lane` — one row per recorded step of that lane's
/// sampled traces.
fn print_diag_csv(pools: &[json::Value], per_step: bool) -> Result<()> {
    if per_step {
        println!("model,solver,lane,sample,step,t,h,err,accepted");
    } else {
        println!(
            "model,solver,bin,t_lo,t_hi,steps,accepted,rejected,\
             h_mean,h_min,h_max,err_mean,err_max"
        );
    }
    for p in pools {
        let g = |k: &str| p.get(k).and_then(|x| x.as_str().ok()).unwrap_or("?");
        let (model, solver) = (g("model"), g("solver"));
        if per_step {
            for t in p.req("traces")?.as_arr()? {
                let tf = |k: &str| t.get(k).and_then(|x| x.as_f64().ok()).unwrap_or(0.0);
                for (i, s) in t.req("steps")?.as_arr()?.iter().enumerate() {
                    let sf = |k: &str| s.get(k).and_then(|x| x.as_f64().ok()).unwrap_or(0.0);
                    let acc = s.get("accepted").and_then(|x| x.as_bool().ok()).unwrap_or(false);
                    println!(
                        "{model},{solver},{},{},{i},{},{},{},{}",
                        tf("lane") as u64,
                        tf("sample") as u64,
                        sf("t"),
                        sf("h"),
                        sf("err"),
                        acc as u8,
                    );
                }
            }
        } else {
            for (i, b) in p.req("bins")?.as_arr()?.iter().enumerate() {
                let f = |k: &str| b.get(k).and_then(|x| x.as_f64().ok()).unwrap_or(0.0);
                println!(
                    "{model},{solver},{i},{},{},{},{},{},{},{},{},{},{}",
                    f("t_lo"),
                    f("t_hi"),
                    f("steps") as u64,
                    f("accepted") as u64,
                    f("rejected") as u64,
                    f("h_mean"),
                    f("h_min"),
                    f("h_max"),
                    f("err_mean"),
                    f("err_max"),
                );
            }
        }
    }
    Ok(())
}

/// `gofast health`: the watchdog's status gauge, per-kind cumulative
/// counters, and the retained health-event ring (oldest first).
fn cmd_health(args: &Args) -> Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:7878");
    let mut client = gofast::server::Client::connect(&addr)?;
    let v = client.health()?;
    let status = v.req("status")?.as_f64()?;
    println!("status {}", if status >= 1.0 { "ok" } else { "DEGRADED" });
    for (kind, n) in v.req("counts")?.members() {
        println!("  {kind}: {}", n.as_f64()? as u64);
    }
    let events = v.req("events")?.as_arr()?;
    for e in events {
        let g = |k: &str| e.get(k).and_then(|x| x.as_str().ok()).unwrap_or("");
        let at = e.get("at_s").and_then(|x| x.as_f64().ok()).unwrap_or(0.0);
        let pool = match (g("model"), g("solver")) {
            ("", _) => String::new(),
            (m, s) => format!(" {m}/{s}"),
        };
        println!("event +{at:.3}s {}{pool}: {}", g("kind"), g("detail"));
    }
    if events.is_empty() {
        println!("no health events recorded");
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let man = json::parse_file(&dir.join("manifest.json"))?;
    for (name, v) in man.req("variants")?.members() {
        let meta = v.req("meta")?;
        println!(
            "variant {name}: {} {}x{}x{} params={} dataset={}",
            meta.req("sde_kind")?.as_str()?,
            meta.req("h")?.as_usize()?,
            meta.req("w")?.as_usize()?,
            meta.req("c")?.as_usize()?,
            meta.req("n_params")?.as_usize()?,
            meta.req("dataset")?.as_str()?,
        );
        for p in v.req("programs")?.as_arr()? {
            println!(
                "  {}_b{} -> {}",
                p.req("program")?.as_str()?,
                p.req("bucket")?.as_usize()?,
                p.req("file")?.as_str()?
            );
        }
    }
    for (name, v) in man.req("fidnets")?.members() {
        let meta = v.req("meta")?;
        println!(
            "fidnet {name}: dim={} classes={} feat={}",
            meta.req("dim")?.as_usize()?,
            meta.req("n_classes")?.as_usize()?,
            meta.req("feat_dim")?.as_usize()?,
        );
    }
    Ok(())
}

struct EvalSummary {
    fid: f64,
    is: f64,
    mean_nfe: f64,
    steps_per_bucket: Vec<(usize, u64)>,
}

/// Solver spec for the serving path, consolidated through
/// `solvers::spec::parse` (the same parser the server wire layer and
/// `serve --solvers` use). A `--steps` flag supplies the default step
/// count for bare fixed-step names (`--solver em --steps 100` ==
/// `--solver em:100`).
fn parse_served_solver(args: &Args) -> Result<solvers::ServingSolver> {
    let steps = match args.get("steps") {
        None => None,
        Some(_) => Some(args.usize_or("steps", 256)?),
    };
    spec::parse_with_steps(&args.str_or("solver", "adaptive"), steps)
}

/// Evaluation through the serving path: a running server (`--addr`) or
/// an in-process engine spun up on the artifacts dir.
fn evaluate_served(args: &Args, solver: solvers::ServingSolver) -> Result<EvalSummary> {
    let model = args.str_or("model", "vp");
    let samples = args.usize_or("samples", 256)?;
    let eps_rel = args.f64_or("eps-rel", 0.05)?;
    let seed = args.u64_or("seed", 0)?;
    if let Some(addr) = args.get("addr") {
        // the wire request carries no controller/bucket config — those
        // are the remote server's; a silent mismatch would make --check
        // fail spuriously, so refuse the combination instead
        for flag in ["r", "safety", "bucket", "no-migrate"] {
            if args.has(flag) {
                if args.has("check") {
                    bail!(
                        "--{flag} does not travel with --addr (the server keeps its own \
                         solver config), so --check would compare different controllers; \
                         drop --{flag} or evaluate against a local engine"
                    );
                }
                eprintln!("note: --{flag} is ignored with --addr (server config wins)");
            }
        }
        let mut client = gofast::server::Client::connect(addr)?;
        let r = client.run_eval(
            &gofast::server::EvalRequest::new(samples)
                .model(&model)
                .solver(&solver.spec_string())
                .eps_rel(eps_rel)
                .seed(seed),
        )?;
        return Ok(EvalSummary {
            fid: r.fid,
            is: r.is,
            mean_nfe: r.mean_nfe,
            steps_per_bucket: r.steps_per_bucket,
        });
    }
    let dir = artifacts_dir(args);
    let bucket =
        gofast::runtime::manifest_engine_bucket(&dir, &model, args.usize_or("bucket", 16)?)?;
    let mut ecfg = EngineConfig::new(&dir, &model);
    ecfg.bucket = bucket;
    ecfg.migrate = !args.has("no-migrate");
    ecfg.r = args.f64_or("r", ecfg.r)?;
    ecfg.safety = args.f64_or("safety", ecfg.safety)?;
    let engine = Engine::start(ecfg)?;
    let r = engine.client().evaluate(gofast::coordinator::EvalRequest {
        model: String::new(),
        solver,
        samples,
        eps_rel,
        seed,
        priority: None,
    })?;
    Ok(EvalSummary {
        fid: r.fid,
        is: r.is,
        mean_nfe: r.mean_nfe,
        steps_per_bucket: r.steps_per_bucket,
    })
}

/// The engine bypass: generate and score locally, no coordinator.
/// Served solvers (adaptive, em:<n>, ddim:<n>, pc:<n>[@<snr>]) run
/// engine-equivalent per-sample lanes (`spec::run_lanes`), so their
/// FID*/IS* match the served path on the same seed; other solvers
/// (ode, lamba, legacy batch rdl, ...) use their batch RNG scheme and
/// are only available here.
fn evaluate_offline(args: &Args) -> Result<EvalSummary> {
    let dir = artifacts_dir(args);
    let rt = Runtime::new(&dir)?;
    let model_name = args.str_or("model", "vp");
    let model = rt.model(&model_name)?;
    let (net, ref_stats) = metrics::reference_for(&rt, &model.meta)?;
    let samples = args.usize_or("samples", 256)?;
    let seed = args.u64_or("seed", 0)?;
    if let Ok(solver) = parse_served_solver(args) {
        let opts = adaptive::AdaptiveOpts {
            eps_rel: args.f64_or("eps-rel", 0.05)?,
            r: args.f64_or("r", 0.9)?,
            safety: args.f64_or("safety", 0.9)?,
            ..Default::default()
        };
        let r = spec::evaluate_offline_lanes(
            &model,
            &net,
            &ref_stats,
            solver,
            samples,
            seed,
            &opts,
            args.usize_or("bucket", 16)?,
        )?;
        return Ok(EvalSummary {
            fid: r.fid,
            is: r.is,
            mean_nfe: r.mean_nfe,
            steps_per_bucket: Vec::new(),
        });
    }
    // non-served solvers: the legacy batch bypass
    let solver = args.str_or("solver", "adaptive");
    let bucket = args.usize_or("bucket", 64)?;
    let ctx = Ctx::new(&model, bucket, SolveOpts::default());
    let mut images = Tensor::zeros(&[samples, model.meta.dim]);
    let mut nfe_sum = 0u64;
    let mut rng = Rng::new(seed);
    let mut done = 0;
    while done < samples {
        let take = (samples - done).min(bucket);
        let res = run_solver(&ctx, &mut rng, &solver, args)?;
        for i in 0..take {
            images.row_mut(done + i).copy_from_slice(res.x.row(i));
        }
        nfe_sum += res.nfe_per_sample[..take].iter().sum::<u64>();
        done += take;
    }
    model.meta.process().to_unit_range(&mut images);
    let (fid, is) = metrics::evaluate(&net, &images, &ref_stats)?;
    Ok(EvalSummary {
        fid,
        is,
        mean_nfe: nfe_sum as f64 / samples as f64,
        steps_per_bucket: Vec::new(),
    })
}

fn print_eval(path: &str, args: &Args, solver_label: &str, s: &EvalSummary) -> Result<()> {
    let model = args.str_or("model", "vp");
    let samples = args.usize_or("samples", 256)?;
    print!(
        "[{path}] model={model} solver={solver_label} samples={samples} NFE={:.1} FID*={:.3} IS*={:.3}",
        s.mean_nfe, s.fid, s.is
    );
    let consumed: Vec<String> = s
        .steps_per_bucket
        .iter()
        .filter(|(_, n)| *n > 0)
        .map(|(b, n)| format!("{b}:{n}"))
        .collect();
    if consumed.is_empty() {
        println!();
    } else {
        println!(" steps_per_bucket={}", consumed.join(","));
    }
    Ok(())
}

/// FID*/IS* of a model+solver against the reference split. Default route
/// is the serving path (in-process engine, or a live server with
/// `--addr`); `--offline` bypasses the coordinator; `--check` runs both
/// and asserts they agree (<= 1e-6 relative — the offline per-lane
/// bypass mirrors the engine's RNG streams exactly, for fixed-step
/// solvers just like adaptive).
fn cmd_evaluate(args: &Args) -> Result<()> {
    let check = args.has("check");
    if args.has("offline") && !check {
        let label = match parse_served_solver(args) {
            Ok(s) => s.spec_string(),
            Err(_) => args.str_or("solver", "adaptive"),
        };
        let s = evaluate_offline(args)?;
        return print_eval("offline", args, &label, &s);
    }
    let solver = parse_served_solver(args)?;
    let label = solver.spec_string();
    let served = evaluate_served(args, solver)?;
    print_eval("served", args, &label, &served)?;
    if check {
        let off = evaluate_offline(args)?;
        print_eval("offline", args, &label, &off)?;
        let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1.0);
        if rel(served.fid, off.fid) > 1e-6
            || rel(served.is, off.is) > 1e-6
            || served.mean_nfe != off.mean_nfe
        {
            bail!(
                "served/offline evaluation disagree: FID* {:.9} vs {:.9}, IS* {:.9} vs {:.9}, NFE {:.3} vs {:.3}",
                served.fid, off.fid, served.is, off.is, served.mean_nfe, off.mean_nfe
            );
        }
        println!("check ok: served == offline (<= 1e-6 relative)");
    }
    Ok(())
}
