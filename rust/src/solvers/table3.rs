//! Off-the-shelf SDE solver suite (paper Appendix A / Table 3): the
//! schemes the authors tried from DifferentialEquations.jl before
//! designing Algorithm 1, reimplemented over our score artifact.
//!
//! * `euler_heun`  — fixed-step Stratonovich Heun (2 NFE/step).
//! * `sra1`        — Rößler (2010)-style order-1.5 SRK for additive
//!   noise with embedded error control (3+ NFE/step equivalents; the
//!   DiffEq.jl SOSRA/SRA3 family). Reimplementation; tableau follows the
//!   SRA1 structure (2 drift stages + iterated-integral chi2 term).
//! * `milstein`    — adaptive Milstein; with state-independent g the
//!   correction term vanishes, so it reduces to adaptive EM (we report
//!   this honestly; the paper saw outright divergence in julia).
//! * `issem`       — drift-implicit split-step EM: the linear VP drift is
//!   solved implicitly in closed form (VE drift is 0 => identical to EM).
//!
//! All integrate the *reverse* diffusion like the other solvers: time
//! runs 1 -> t_eps with step h > 0 and drift F = f - g^2 s.

use super::{fill_noise, t_vec, time_grid, Ctx, SolveResult};
use crate::rng::Rng;
use crate::tensor::Tensor;
use crate::Result;

/// Fixed-step Stratonovich Heun: average drift and diffusion over the
/// EM predictor, 2 NFE/step.
pub fn euler_heun(ctx: &Ctx, rng: &mut Rng, n_steps: usize) -> Result<SolveResult> {
    let b = ctx.bucket;
    let d = ctx.dim();
    let grid = time_grid(&ctx.process, n_steps);
    let mut x = ctx.sample_prior(rng);
    let mut z = Tensor::zeros(&[b, d]);
    let mut xp = Tensor::zeros(&[b, d]);
    for w in grid.windows(2) {
        let (t, tn) = (w[0], w[1]);
        let h = t - tn;
        fill_noise(rng, &mut z);
        let t_in = t_vec(b, t);
        let k1 = ctx.rdp_drift(&x, &t_in)?;
        let (g1, g2) = (ctx.process.diffusion(t) as f32, ctx.process.diffusion(tn) as f32);
        let (a, c1) = ((-h) as f32, (h.sqrt()) as f32 * g1);
        for i in 0..b {
            let (xr, kr, zr, or) = (x.row(i), k1.row(i), z.row(i), xp.row_mut(i));
            for j in 0..d {
                or[j] = xr[j] + a * kr[j] + c1 * zr[j];
            }
        }
        let k2 = ctx.rdp_drift(&xp, &t_vec(b, tn))?;
        let cavg = (h.sqrt() as f32) * 0.5 * (g1 + g2);
        for i in 0..b {
            let (xr, k1r, k2r, zr) = (x.row_mut(i), k1.row(i), k2.row(i), z.row(i));
            for j in 0..d {
                xr[j] += a * 0.5 * (k1r[j] + k2r[j]) + cavg * zr[j];
            }
        }
    }
    let mut nfe = vec![2 * n_steps as u64; b];
    if ctx.opts.denoise {
        x = ctx.denoise(&x, &t_vec(b, ctx.process.t_eps()))?;
        nfe.iter_mut().for_each(|n| *n += 1);
    }
    Ok(SolveResult { x, nfe_per_sample: nfe, steps: n_steps as u64, rejections: 0 })
}

#[derive(Clone, Copy, Debug)]
pub struct Sra1Opts {
    pub eps_rel: f64,
    pub eps_abs: Option<f64>,
    pub h_init: f64,
    pub safety: f64,
    pub max_iters: u64,
}

impl Default for Sra1Opts {
    fn default() -> Self {
        Sra1Opts { eps_rel: 0.05, eps_abs: None, h_init: 0.01, safety: 0.9, max_iters: 200_000 }
    }
}

/// Order-1.5 additive-noise SRK with embedded error (SRA1 structure).
/// Batch-lockstep step size (as DiffEq.jl treats the flattened system).
pub fn sra1(ctx: &Ctx, rng: &mut Rng, opts: &Sra1Opts) -> Result<SolveResult> {
    let b = ctx.bucket;
    let d = ctx.dim();
    let t_eps = ctx.process.t_eps();
    let eps_abs = opts.eps_abs.unwrap_or_else(|| ctx.process.eps_abs());
    let mut x = ctx.sample_prior(rng);
    let mut t = 1.0f64;
    let mut h = opts.h_init;
    let (mut steps, mut rejections, mut nfe_count) = (0u64, 0u64, 0u64);
    let mut dw = Tensor::zeros(&[b, d]);
    let mut dz = Tensor::zeros(&[b, d]);

    while t > t_eps + 1e-12 {
        if steps >= opts.max_iters {
            crate::bail!("sra1 exceeded {} iterations (instability)", opts.max_iters);
        }
        steps += 1;
        h = h.min(t - t_eps);
        let tn = t - h;
        fill_noise(rng, &mut dw);
        fill_noise(rng, &mut dz);
        let sq = h.sqrt() as f32;
        let (g1, g2) = (ctx.process.diffusion(t) as f32, ctx.process.diffusion(tn) as f32);
        let k1 = ctx.rdp_drift(&x, &t_vec(b, t))?;
        nfe_count += 1;
        // stage 2 state: x - 3/4 h k1 + 3/2 chi2 g2      (reverse time)
        // chi2 = (dW + dZ/sqrt(3))/2 per component, scaled by sqrt(h)
        let mut h2st = Tensor::zeros(&[b, d]);
        for i in 0..b {
            let (xr, kr, wr, zr, or) =
                (x.row(i), k1.row(i), dw.row(i), dz.row(i), h2st.row_mut(i));
            for j in 0..d {
                let chi2 = 0.5 * sq * (wr[j] + zr[j] / 3f32.sqrt());
                or[j] = xr[j] - 0.75 * (h as f32) * kr[j] + 1.5 * chi2 * g2;
            }
        }
        let k2 = ctx.rdp_drift(&h2st, &t_vec(b, t - 0.75 * h))?;
        nfe_count += 1;
        // proposal + embedded error
        let mut y = x.clone();
        let mut err_sq = 0f64;
        for i in 0..b {
            let (yr, k1r, k2r, wr, zr, xr) =
                (y.row_mut(i), k1.row(i), k2.row(i), dw.row(i), dz.row(i), x.row(i));
            for j in 0..d {
                let chi2 = 0.5 * sq * (wr[j] + zr[j] / 3f32.sqrt());
                yr[j] = xr[j] - (h as f32) * (k1r[j] / 3.0 + 2.0 * k2r[j] / 3.0)
                    + sq * wr[j] * g1
                    + chi2 * (g2 - g1);
                let e = (h as f32) * (k1r[j] - k2r[j]) / 3.0;
                let sc = (eps_abs as f32).max(opts.eps_rel as f32 * xr[j].abs().max(yr[j].abs()));
                let r = (e / sc) as f64;
                err_sq += r * r;
            }
        }
        let err = (err_sq / (b * d) as f64).sqrt();
        if err <= 1.0 {
            x = y;
            t = tn;
        } else {
            rejections += 1;
        }
        h *= (opts.safety * err.max(1e-12).powf(-0.5)).clamp(0.1, 5.0);
    }
    let mut nfe = vec![2 * nfe_count / 2; b];
    if ctx.opts.denoise {
        x = ctx.denoise(&x, &t_vec(b, t_eps))?;
        nfe.iter_mut().for_each(|n| *n += 1);
    }
    Ok(SolveResult { x, nfe_per_sample: nfe, steps, rejections })
}

/// Adaptive Milstein. g is state-independent for VE/VP, so the Milstein
/// correction 1/2 g g' (dW^2 - h) vanishes: identical update to adaptive
/// EM with the Lamba-style drift-pair error estimate.
pub fn milstein(ctx: &Ctx, rng: &mut Rng, eps_rel: f64) -> Result<SolveResult> {
    let opts = super::lamba::LambaOpts {
        eps_rel,
        norm: super::adaptive::ErrNorm::L2,
        ..Default::default()
    };
    super::lamba::run(ctx, rng, &opts)
}

/// Drift-implicit split-step EM, fixed step. For VP the linear implicit
/// equation solves in closed form; for VE it reduces to EM (f = 0).
///   x* : x* = x - h f(x*, tn) + h g(t)^2 s(x, t)  =>
///   x* = (x + h g^2 s) / (1 - h c)  with f(x,t) = c x, c = -beta/2
pub fn issem(ctx: &Ctx, rng: &mut Rng, n_steps: usize) -> Result<SolveResult> {
    let b = ctx.bucket;
    let d = ctx.dim();
    let grid = time_grid(&ctx.process, n_steps);
    let mut x = ctx.sample_prior(rng);
    let mut z = Tensor::zeros(&[b, d]);
    for w in grid.windows(2) {
        let (t, tn) = (w[0], w[1]);
        let h = t - tn;
        fill_noise(rng, &mut z);
        let s = ctx.score(&x, &t_vec(b, t))?;
        let g = ctx.process.diffusion(t);
        let g2h = (h * g * g) as f32;
        let c = ctx.process.drift_coef(tn); // implicit at the *target* time
        let denom = (1.0 - h * c) as f32; // reverse step: x* (1 - h c) = rhs
        let noise = (h.sqrt() * g) as f32;
        for i in 0..b {
            let (xr, sr, zr) = (x.row_mut(i), s.row(i), z.row(i));
            for j in 0..d {
                xr[j] = (xr[j] + g2h * sr[j] + noise * zr[j]) / denom;
            }
        }
    }
    let mut nfe = vec![n_steps as u64; b];
    if ctx.opts.denoise {
        x = ctx.denoise(&x, &t_vec(b, ctx.process.t_eps()))?;
        nfe.iter_mut().for_each(|n| *n += 1);
    }
    Ok(SolveResult { x, nfe_per_sample: nfe, steps: n_steps as u64, rejections: 0 })
}
