//! Reverse-Diffusion + Langevin corrector (Song et al. 2020a's
//! Predictor–Corrector sampler, the paper's "baseline" for VE models).
//! 2 NFE per step: one predictor score eval + one corrector score eval,
//! with the corrector step size set from the target signal-to-noise
//! ratio (0.16 for VE, 0.01 for VP, following Song et al.). The fused
//! `pc_step` kernel takes `snr` as a per-lane vector (§3.1.5 style), so
//! requests with different SNR targets co-batch in one serving pool and
//! free lanes ride through with `h = 0`, zero noise, `snr = 0` — an
//! exact no-op.

use super::{fill_noise, t_vec, time_grid, Ctx, SolveResult};
use crate::rng::Rng;
use crate::tensor::Tensor;
use crate::Result;

pub fn default_snr(process: &crate::sde::Process) -> f64 {
    match process {
        crate::sde::Process::Ve { .. } => 0.16,
        crate::sde::Process::Vp { .. } => 0.01,
    }
}

/// `n_steps` predictor+corrector iterations => NFE = 2*n_steps (+1 denoise).
pub fn run(ctx: &Ctx, rng: &mut Rng, n_steps: usize, snr: Option<f64>) -> Result<SolveResult> {
    let b = ctx.bucket;
    let snr = snr.unwrap_or_else(|| default_snr(&ctx.process));
    let grid = time_grid(&ctx.process, n_steps);
    let mut x = ctx.sample_prior(rng);
    let mut z1 = Tensor::zeros(&[b, ctx.dim()]);
    let mut z2 = Tensor::zeros(&[b, ctx.dim()]);
    let snr_t = t_vec(b, snr);
    for w in grid.windows(2) {
        let (t, t_next) = (w[0], w[1]);
        let h = t - t_next;
        fill_noise(rng, &mut z1);
        fill_noise(rng, &mut z2);
        let t_in = t_vec(b, t);
        let h_in = t_vec(b, h);
        let mut out = ctx.model.exec(
            "pc_step",
            ctx.bucket,
            &[&x, &t_in, &h_in, &z1, &z2, &snr_t],
            ctx.opts.fused_buffers,
        )?;
        x = out.pop().unwrap();
    }
    let mut nfe = vec![2 * n_steps as u64; b];
    if ctx.opts.denoise {
        x = ctx.denoise(&x, &t_vec(b, ctx.process.t_eps()))?;
        nfe.iter_mut().for_each(|n| *n += 1);
    }
    Ok(SolveResult { x, nfe_per_sample: nfe, steps: n_steps as u64, rejections: 0 })
}

/// PC with *per-lane* RNG streams matching the serving engine's lane
/// semantics exactly: lane `i` owns `Rng::new(seed).fork(base + i)`,
/// draws its prior and — each grid step — first the predictor noise
/// `z1` then the corrector noise `z2` from that stream, and walks the
/// uniform grid `uniform_t(t_eps, n_steps, k)` — the same draws and
/// nodes the engine's `pc_step` lane pool feeds the kernel. Padding
/// lanes ride along engine-style (`h = 0`, zero noise, `snr = 0`: an
/// exact no-op). The `--offline` twin the engine-vs-offline agreement
/// check for served PC evaluation is defined against; see
/// `em::run_lanes` for the contract.
pub fn run_lanes(
    ctx: &Ctx,
    seed: u64,
    base: u64,
    count: usize,
    n_steps: usize,
    snr: f64,
) -> Result<SolveResult> {
    let mut z1 = Tensor::zeros(&[ctx.bucket, ctx.dim()]);
    let mut z2 = Tensor::zeros(&[ctx.bucket, ctx.dim()]);
    let evals = super::spec::kernel("pc").unwrap().score_evals_per_step;
    super::run_fixed_lanes(ctx, seed, base, count, n_steps, evals, |x, t, tn, rngs| {
        let b = x.shape[0];
        let mut t_in = vec![1.0f32; b];
        let mut h_in = vec![0.0f32; b];
        let mut snr_in = vec![0.0f32; b];
        for (i, rng) in rngs.iter_mut().enumerate() {
            t_in[i] = t as f32;
            h_in[i] = (t - tn) as f32;
            snr_in[i] = snr as f32;
            rng.fill_normal(z1.row_mut(i));
            rng.fill_normal(z2.row_mut(i));
        }
        let t_t = Tensor { shape: vec![b], data: t_in };
        let h_t = Tensor { shape: vec![b], data: h_in };
        let snr_t = Tensor { shape: vec![b], data: snr_in };
        let mut out = ctx.model.exec(
            "pc_step",
            b,
            &[x, &t_t, &h_t, &z1, &z2, &snr_t],
            ctx.opts.fused_buffers,
        )?;
        Ok(out.pop().unwrap())
    })
}
