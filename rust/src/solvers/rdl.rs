//! Reverse-Diffusion + Langevin corrector (Song et al. 2020a's
//! Predictor–Corrector sampler, the paper's "baseline" for VE models).
//! 2 NFE per step: one predictor score eval + one corrector score eval,
//! with the corrector step size set from the target signal-to-noise
//! ratio (0.16 for VE, 0.01 for VP, following Song et al.).

use super::{fill_noise, t_vec, time_grid, Ctx, SolveResult};
use crate::rng::Rng;
use crate::tensor::Tensor;
use crate::Result;

pub fn default_snr(process: &crate::sde::Process) -> f64 {
    match process {
        crate::sde::Process::Ve { .. } => 0.16,
        crate::sde::Process::Vp { .. } => 0.01,
    }
}

/// `n_steps` predictor+corrector iterations => NFE = 2*n_steps (+1 denoise).
pub fn run(ctx: &Ctx, rng: &mut Rng, n_steps: usize, snr: Option<f64>) -> Result<SolveResult> {
    let b = ctx.bucket;
    let snr = snr.unwrap_or_else(|| default_snr(&ctx.process));
    let grid = time_grid(&ctx.process, n_steps);
    let mut x = ctx.sample_prior(rng);
    let mut z1 = Tensor::zeros(&[b, ctx.dim()]);
    let mut z2 = Tensor::zeros(&[b, ctx.dim()]);
    let snr_t = Tensor::scalar(snr as f32);
    for w in grid.windows(2) {
        let (t, t_next) = (w[0], w[1]);
        let h = t - t_next;
        fill_noise(rng, &mut z1);
        fill_noise(rng, &mut z2);
        let t_in = t_vec(b, t);
        let h_in = t_vec(b, h);
        let mut out = ctx.model.exec(
            "pc_step",
            ctx.bucket,
            &[&x, &t_in, &h_in, &z1, &z2, &snr_t],
            ctx.opts.fused_buffers,
        )?;
        x = out.pop().unwrap();
    }
    let mut nfe = vec![2 * n_steps as u64; b];
    if ctx.opts.denoise {
        x = ctx.denoise(&x, &t_vec(b, ctx.process.t_eps()))?;
        nfe.iter_mut().for_each(|n| *n += 1);
    }
    Ok(SolveResult { x, nfe_per_sample: nfe, steps: n_steps as u64, rejections: 0 })
}
