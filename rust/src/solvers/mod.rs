//! Reverse-diffusion solvers (paper §2.4, §3, Appendix A).
//!
//! Two execution styles:
//! * **fused** — one AOT step-artifact call per iteration (both score
//!   evaluations + integrators + error norm in-graph); the serving path.
//! * **composed** — `score` artifact calls + host math; powers the
//!   ablation knobs (Tables 4–5), the off-the-shelf suite (Table 3) and
//!   the probability-flow ODE, where the paper's variations live outside
//!   what the fused graphs bake in.
//!
//! Every solver reports per-sample NFE (the paper's cost metric) plus
//! batch-level call counts.

pub mod adaptive;
pub mod ddim;
pub mod em;
pub mod general;
pub mod lamba;
pub mod prob_flow;
pub mod rdl;
pub mod spec;
pub mod table3;

pub use spec::{ServingSolver, Spec};

use crate::rng::Rng;
use crate::runtime::Model;
use crate::sde::Process;
use crate::tensor::Tensor;
use crate::Result;

/// Options shared by all solvers.
#[derive(Clone, Copy, Debug)]
pub struct SolveOpts {
    /// Use the device-resident-buffer execution path.
    pub fused_buffers: bool,
    /// Apply final Tweedie denoising at t_eps (paper App. D, approach 2).
    pub denoise: bool,
}

impl Default for SolveOpts {
    fn default() -> Self {
        SolveOpts { fused_buffers: true, denoise: true }
    }
}

/// Outcome of solving one batch of reverse diffusions.
#[derive(Clone, Debug)]
pub struct SolveResult {
    /// Final samples in the process data range, [B, D].
    pub x: Tensor,
    /// Score-network evaluations per sample (incl. the denoise call).
    pub nfe_per_sample: Vec<u64>,
    /// Iterations of the solver loop (batch-level).
    pub steps: u64,
    /// Rejected proposals across the batch (adaptive solvers only).
    pub rejections: u64,
}

impl SolveResult {
    pub fn mean_nfe(&self) -> f64 {
        if self.nfe_per_sample.is_empty() {
            return 0.0;
        }
        self.nfe_per_sample.iter().sum::<u64>() as f64 / self.nfe_per_sample.len() as f64
    }

    pub fn max_nfe(&self) -> u64 {
        self.nfe_per_sample.iter().copied().max().unwrap_or(0)
    }
}

/// Batched access to the score network and its surrounding step programs.
/// Thin convenience over `runtime::Model` fixing (bucket, exec-mode).
pub struct Ctx<'m, 'rt> {
    pub model: &'m Model<'rt>,
    pub process: Process,
    pub bucket: usize,
    pub opts: SolveOpts,
}

impl<'m, 'rt> Ctx<'m, 'rt> {
    pub fn new(model: &'m Model<'rt>, bucket: usize, opts: SolveOpts) -> Ctx<'m, 'rt> {
        Ctx { model, process: model.meta.process(), bucket, opts }
    }

    pub fn dim(&self) -> usize {
        self.model.meta.dim
    }

    /// s_theta(x, t): one score evaluation per sample.
    pub fn score(&self, x: &Tensor, t: &Tensor) -> Result<Tensor> {
        let mut out =
            self.model.exec("score", self.bucket, &[x, t], self.opts.fused_buffers)?;
        Ok(out.pop().unwrap())
    }

    /// Reverse-SDE deterministic term  f(x,t) - g(t)^2 s(x,t), host-composed.
    pub fn rdp_drift(&self, x: &Tensor, t: &Tensor) -> Result<Tensor> {
        let mut s = self.score(x, t)?;
        for i in 0..self.bucket {
            let ti = t.data[i] as f64;
            let g2 = self.process.diffusion(ti).powi(2) as f32;
            let fc = self.process.drift_coef(ti) as f32;
            let (xr, sr) = (x.row(i), s.row_mut(i));
            for j in 0..xr.len() {
                sr[j] = fc * xr[j] - g2 * sr[j];
            }
        }
        Ok(s)
    }

    /// Tweedie denoising at per-sample times `t` (1 NFE per sample).
    pub fn denoise(&self, x: &Tensor, t: &Tensor) -> Result<Tensor> {
        let mut out =
            self.model.exec("denoise", self.bucket, &[x, t], self.opts.fused_buffers)?;
        Ok(out.pop().unwrap())
    }

    /// Draw the prior x(1).
    pub fn sample_prior(&self, rng: &mut Rng) -> Tensor {
        let mut x = Tensor::zeros(&[self.bucket, self.dim()]);
        self.process.sample_prior(rng, &mut x);
        x
    }
}

/// `i`-th node of the uniform reverse-time grid from 1 down to `t_eps`
/// in `n` steps (paper App. D time sequence). The single definition both
/// the offline grids and the serving fixed-step lane pools index, so the
/// two paths cannot drift.
pub fn uniform_t(t_eps: f64, n: usize, i: usize) -> f64 {
    1.0 - (1.0 - t_eps) * i as f64 / n as f64
}

/// Uniform reverse-time grid from 1 down to t_eps with n steps.
pub fn time_grid(process: &Process, n: usize) -> Vec<f64> {
    let t_eps = process.t_eps();
    (0..=n).map(|i| uniform_t(t_eps, n, i)).collect()
}

/// Tensor of one repeated time value.
pub fn t_vec(bucket: usize, t: f64) -> Tensor {
    Tensor { shape: vec![bucket], data: vec![t as f32; bucket] }
}

/// Fill `z` with standard normals.
pub fn fill_noise(rng: &mut Rng, z: &mut Tensor) {
    rng.fill_normal(&mut z.data);
}

/// Shared scaffold for the fixed-step per-lane offline runs (EM, DDIM,
/// PC): guards, per-lane RNG/prior setup mirroring the engine's
/// admission, the uniform-grid walk, denoising, and trimming to `count`
/// rows. `evals_per_step` is the kernel's per-step NFE cost (its
/// `StepKernel` row — 1 for EM/DDIM, 2 for PC's predictor+corrector).
/// `step` advances the whole pool one grid node — it receives the pool
/// state `x`, the grid pair `(t, t_next)` and the live lanes' RNG
/// streams (`rngs.len() == count`; padding lanes must be filled
/// engine-style: exact no-op inputs, zero noise) and returns the
/// kernel's `x_next`.
pub(crate) fn run_fixed_lanes(
    ctx: &Ctx,
    seed: u64,
    base: u64,
    count: usize,
    n_steps: usize,
    evals_per_step: u64,
    mut step: impl FnMut(&Tensor, f64, f64, &mut [Rng]) -> Result<Tensor>,
) -> Result<SolveResult> {
    let b = ctx.bucket;
    if count > b {
        crate::bail!("count {count} exceeds bucket {b}");
    }
    if n_steps == 0 {
        crate::bail!("fixed-step solver needs at least 1 step");
    }
    let d = ctx.dim();
    let t_eps = ctx.process.t_eps();
    let prior_std = ctx.process.prior_std() as f32;
    let mut rngs: Vec<Rng> = (0..count).map(|i| Rng::new(seed).fork(base + i as u64)).collect();
    let mut x = Tensor::zeros(&[b, d]);
    for (i, rng) in rngs.iter_mut().enumerate() {
        for v in x.row_mut(i).iter_mut() {
            *v = rng.normal() as f32 * prior_std;
        }
    }
    for k in 0..n_steps {
        let t = uniform_t(t_eps, n_steps, k);
        let tn = uniform_t(t_eps, n_steps, k + 1);
        let xn = step(&x, t, tn, &mut rngs)?;
        for i in 0..count {
            x.row_mut(i).copy_from_slice(xn.row(i));
        }
    }
    let mut nfe = vec![n_steps as u64 * evals_per_step; count];
    if ctx.opts.denoise {
        x = ctx.denoise(&x, &t_vec(b, t_eps))?;
        nfe.iter_mut().for_each(|n| *n += 1);
    }
    let x = Tensor::from_vec(&[count, d], x.data[..count * d].to_vec())?;
    Ok(SolveResult { x, nfe_per_sample: nfe, steps: n_steps as u64, rejections: 0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_grid_endpoints_and_monotone() {
        let p = Process::vp();
        let g = time_grid(&p, 100);
        assert_eq!(g.len(), 101);
        assert_eq!(g[0], 1.0);
        assert!((g[100] - p.t_eps()).abs() < 1e-12);
        for w in g.windows(2) {
            assert!(w[1] < w[0]);
        }
    }

    #[test]
    fn t_vec_shape() {
        let t = t_vec(4, 0.5);
        assert_eq!(t.shape, vec![4]);
        assert!(t.data.iter().all(|&v| v == 0.5));
    }

    #[test]
    fn mean_nfe_of_empty_result_is_zero_not_nan() {
        let r = SolveResult {
            x: Tensor::zeros(&[0]),
            nfe_per_sample: vec![],
            steps: 0,
            rejections: 0,
        };
        assert_eq!(r.mean_nfe(), 0.0);
        assert_eq!(r.max_nfe(), 0);
    }

    #[test]
    fn mean_nfe_averages_per_sample_counts() {
        let r = SolveResult {
            x: Tensor::zeros(&[2, 1]),
            nfe_per_sample: vec![10, 20],
            steps: 10,
            rejections: 0,
        };
        assert_eq!(r.mean_nfe(), 15.0);
    }
}
