//! Unified solver specification: a single enum naming every solver the
//! benches/tables exercise, with one dispatch point. Keeps paper-table
//! code declarative ("run this list of rows").

use super::{adaptive, ddim, em, lamba, prob_flow, rdl, table3, Ctx, SolveResult};
use crate::rng::Rng;
use crate::Result;

#[derive(Clone, Debug)]
pub enum Spec {
    /// Algorithm 1, fused artifact path.
    Adaptive(adaptive::AdaptiveOpts),
    /// Algorithm 1, composed (host-math) path with ablation knobs.
    AdaptiveComposed(adaptive::AdaptiveOpts),
    /// Euler–Maruyama with n uniform steps.
    Em(usize),
    EmComposed(usize),
    /// Reverse-Diffusion + Langevin (PC), n predictor steps.
    Rdl(usize),
    /// DDIM with n steps (VP only).
    Ddim(usize),
    /// Probability-flow ODE, RK45.
    Ode(prob_flow::OdeOpts),
    /// Lamba (2003) adaptive EM.
    Lamba(lamba::LambaOpts),
    /// Fixed-step Stratonovich Heun.
    EulerHeun(usize),
    /// Order-1.5 additive-noise SRK (SRA1 structure), adaptive.
    Sra1(table3::Sra1Opts),
    /// Adaptive Milstein (== adaptive EM for additive noise).
    Milstein(f64),
    /// Drift-implicit split-step EM, n steps.
    Issem(usize),
}

impl Spec {
    pub fn name(&self) -> &'static str {
        match self {
            Spec::Adaptive(_) => "ours",
            Spec::AdaptiveComposed(_) => "ours-composed",
            Spec::Em(_) => "euler-maruyama",
            Spec::EmComposed(_) => "euler-maruyama-composed",
            Spec::Rdl(_) => "reverse-diffusion+langevin",
            Spec::Ddim(_) => "ddim",
            Spec::Ode(_) => "probability-flow",
            Spec::Lamba(_) => "lamba-em",
            Spec::EulerHeun(_) => "euler-heun",
            Spec::Sra1(_) => "sra1",
            Spec::Milstein(_) => "milstein",
            Spec::Issem(_) => "issem",
        }
    }

    pub fn run(&self, ctx: &Ctx, rng: &mut Rng) -> Result<SolveResult> {
        match self {
            Spec::Adaptive(o) => adaptive::run_fused(ctx, rng, o),
            Spec::AdaptiveComposed(o) => adaptive::run_composed(ctx, rng, o),
            Spec::Em(n) => em::run(ctx, rng, *n),
            Spec::EmComposed(n) => em::run_composed(ctx, rng, *n),
            Spec::Rdl(n) => rdl::run(ctx, rng, *n, None),
            Spec::Ddim(n) => ddim::run(ctx, rng, *n),
            Spec::Ode(o) => prob_flow::run(ctx, rng, o),
            Spec::Lamba(o) => lamba::run(ctx, rng, o),
            Spec::EulerHeun(n) => table3::euler_heun(ctx, rng, *n),
            Spec::Sra1(o) => table3::sra1(ctx, rng, o),
            Spec::Milstein(e) => table3::milstein(ctx, rng, *e),
            Spec::Issem(n) => table3::issem(ctx, rng, *n),
        }
    }
}
