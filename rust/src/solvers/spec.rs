//! Unified solver specification.
//!
//! Three layers:
//! * [`StepKernel`] — the **single table** of per-solver serving facts
//!   (compiled artifact, score evals per step, fixed-vs-adaptive
//!   stepping, auxiliary kernel inputs such as the second noise tensor
//!   `z2` and the Langevin `snr` vector, VP-only restrictions). The
//!   coordinator's descriptor-driven lane programs, the runtime's NFE
//!   accounting and [`ServingSolver`] all read this table, so a new
//!   fixed-step solver is one table row plus an offline twin;
//! * [`ServingSolver`] — the solvers the engine's lane-program pools
//!   serve (`coordinator::programs`), with the **single** spec parser
//!   ([`parse`]) shared by `gofast evaluate` (served and `--offline`),
//!   `gofast serve --solvers`, and the server wire layer, so the paths
//!   cannot drift in accepted names or defaults;
//! * [`Spec`] — the wider bench/table enum naming every solver the
//!   paper tables exercise, with one dispatch point.

use super::{adaptive, ddim, em, lamba, prob_flow, rdl, table3, Ctx, SolveResult};
use crate::rng::Rng;
use crate::{anyhow, bail, Result};

/// Step count a fixed-step spec defaults to when neither the spec string
/// (`em:<n>`) nor the caller supplies one.
pub const DEFAULT_FIXED_STEPS: usize = 256;

/// The second per-lane time input a fixed-step kernel takes alongside
/// `t` (the two shapes the compiled step artifacts use).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimeArg {
    /// Step size `h = t - t_next` (em_step, pc_step); a free lane rides
    /// through with `h = 0` as an exact no-op.
    StepSize,
    /// The next grid node `t_next` itself (ddim_step); a free lane rides
    /// through with `t_next == t`.
    NextTime,
}

/// Everything the serving stack needs to know about one solver's
/// compiled step kernel — the per-solver facts that used to be
/// duplicated across the lane-program impls, `ServingSolver` and the
/// runtime's `score_evals_per_call`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepKernel {
    /// Routing / spec name ("adaptive" | "em" | "ddim" | "pc").
    pub solver: &'static str,
    /// Compiled artifact advancing a pool of this solver's lanes.
    pub artifact: &'static str,
    /// Score-network evaluations one kernel call costs each live lane —
    /// the paper's NFE metric (2 for the predictor+corrector pair).
    pub score_evals_per_step: u64,
    /// Adaptive stepping (per-lane controller state, host accept/reject)
    /// vs a fixed uniform schedule driven purely by this descriptor.
    pub adaptive: bool,
    /// Shape of the second time input (fixed-step kernels).
    pub time: TimeArg,
    /// Fresh per-lane noise tensors drawn each step, in kernel input
    /// order (`z1`, `z2`): 1 for EM, 2 for PC's predictor + corrector
    /// draws, 0 for deterministic DDIM.
    pub noise_inputs: usize,
    /// Trailing per-lane Langevin signal-to-noise input (`snr[B]`).
    pub snr_input: bool,
    /// Kernel is only defined for VP processes (paper §4).
    pub vp_only: bool,
    /// Largest `k` for which aot.py lowers a fused `k`-per-dispatch
    /// variant of this artifact ([`fused_artifact`]); 1 means only the
    /// single-step kernel exists. Fixed-step kernels fuse `k` grid
    /// nodes; the adaptive kernel fuses `k` *attempts* of Algorithm 1
    /// (the accept/reject fold and step-size controller run on device,
    /// and the host replays the decisions from the returned attempt
    /// log).
    pub max_steps_per_dispatch: usize,
}

/// The solver table: one row per served step kernel. Adding a served
/// fixed-step solver means adding a row here (+ its aot.py graph and
/// offline `run_lanes` twin) — not a new `LaneProgram` impl.
pub const STEP_KERNELS: &[StepKernel] = &[
    StepKernel {
        solver: "adaptive",
        artifact: "adaptive_step",
        score_evals_per_step: 2,
        adaptive: true,
        time: TimeArg::StepSize,
        noise_inputs: 1,
        snr_input: false,
        vp_only: false,
        max_steps_per_dispatch: 8,
    },
    StepKernel {
        solver: "em",
        artifact: "em_step",
        score_evals_per_step: 1,
        adaptive: false,
        time: TimeArg::StepSize,
        noise_inputs: 1,
        snr_input: false,
        vp_only: false,
        max_steps_per_dispatch: 8,
    },
    StepKernel {
        solver: "ddim",
        artifact: "ddim_step",
        score_evals_per_step: 1,
        adaptive: false,
        time: TimeArg::NextTime,
        noise_inputs: 0,
        snr_input: false,
        vp_only: true,
        max_steps_per_dispatch: 8,
    },
    StepKernel {
        solver: "pc",
        artifact: "pc_step",
        score_evals_per_step: 2,
        adaptive: false,
        time: TimeArg::StepSize,
        noise_inputs: 2,
        snr_input: true,
        vp_only: false,
        max_steps_per_dispatch: 8,
    },
];

/// Kernel descriptor for a solver name, if the table has one.
pub fn kernel(solver: &str) -> Option<&'static StepKernel> {
    STEP_KERNELS.iter().find(|k| k.solver == solver)
}

/// Kernel descriptor for a compiled step-artifact name — how the
/// runtime's per-call NFE accounting reads the table.
pub fn kernel_for_artifact(artifact: &str) -> Option<&'static StepKernel> {
    STEP_KERNELS.iter().find(|k| k.artifact == artifact)
}

/// Name of the fused `k`-grid-nodes-per-dispatch variant of a step
/// artifact (`em_step` at k=8 → `em_stepk8`). The naming contract is
/// shared with aot.py's fused lowering and parsed back by
/// [`kernel_for_fused_artifact`].
pub fn fused_artifact(artifact: &str, k: usize) -> String {
    format!("{artifact}k{k}")
}

/// Inverse of [`fused_artifact`]: descriptor + `k` for a fused artifact
/// name, or `None` if it is not a `<step_artifact>k<k≥2>` name from the
/// table (single-step names and non-step programs fall through).
pub fn kernel_for_fused_artifact(artifact: &str) -> Option<(&'static StepKernel, usize)> {
    let (base, k) = artifact.rsplit_once('k')?;
    let k = k.parse::<usize>().ok().filter(|&k| k >= 2)?;
    kernel_for_artifact(base).map(|kernel| (kernel, k))
}

/// A solver the serving engine can run as a lane-program pool.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ServingSolver {
    /// Algorithm 1 (the paper's adaptive solver); per-lane step sizes.
    Adaptive,
    /// Euler–Maruyama, `steps` uniform steps per lane.
    Em { steps: usize },
    /// DDIM (deterministic, VP only), `steps` uniform steps per lane.
    Ddim { steps: usize },
    /// Reverse-Diffusion + Langevin predictor–corrector (Song et al.
    /// 2021), `steps` predictor steps per lane (2 score evals each).
    /// `snr` is the Langevin corrector's target signal-to-noise ratio;
    /// `None` defers to the serving process default
    /// (`rdl::default_snr`: 0.16 VE, 0.01 VP).
    Pc { steps: usize, snr: Option<f64> },
}

impl ServingSolver {
    /// This solver's row of the [`STEP_KERNELS`] table.
    pub fn kernel(&self) -> &'static StepKernel {
        let name = match self {
            ServingSolver::Adaptive => "adaptive",
            ServingSolver::Em { .. } => "em",
            ServingSolver::Ddim { .. } => "ddim",
            ServingSolver::Pc { .. } => "pc",
        };
        kernel(name).expect("every ServingSolver has a STEP_KERNELS row")
    }

    /// Routing name ("adaptive" | "em" | "ddim" | "pc").
    pub fn name(&self) -> &'static str {
        self.kernel().solver
    }

    /// Compiled step artifact that advances a pool of this solver's lanes.
    pub fn step_artifact(&self) -> &'static str {
        self.kernel().artifact
    }

    /// Fixed step count (None for the adaptive solver).
    pub fn steps(&self) -> Option<usize> {
        match self {
            ServingSolver::Adaptive => None,
            ServingSolver::Em { steps }
            | ServingSolver::Ddim { steps }
            | ServingSolver::Pc { steps, .. } => Some(*steps),
        }
    }

    /// Explicit Langevin SNR (PC only; `None` = the process default).
    pub fn snr(&self) -> Option<f64> {
        match self {
            ServingSolver::Pc { snr, .. } => *snr,
            _ => None,
        }
    }

    /// Canonical spec string (`adaptive`, `em:<n>`, `ddim:<n>`,
    /// `pc:<n>[@<snr>]`) — round-trips through [`parse`].
    pub fn spec_string(&self) -> String {
        match (self.steps(), self.snr()) {
            (None, _) => self.name().to_string(),
            (Some(n), None) => format!("{}:{n}", self.name()),
            (Some(n), Some(snr)) => format!("{}:{n}@{snr}", self.name()),
        }
    }

    /// Admission-time validation. [`parse`] already rejects `em:0` and
    /// `pc:64@0` on the wire/CLI, but a spec constructed directly
    /// through the Rust API must not reach a lane pool: a zero-step
    /// fixed lane has no grid and would never converge, and a
    /// non-positive or non-finite SNR makes the Langevin corrector
    /// degenerate (or NaN).
    pub fn validate(&self) -> Result<()> {
        if self.steps() == Some(0) {
            bail!("solver '{}' needs at least 1 step", self.name());
        }
        if let Some(snr) = self.snr() {
            if !(snr.is_finite() && snr > 0.0) {
                bail!("solver '{}' needs a finite snr > 0 (got {snr})", self.name());
            }
        }
        Ok(())
    }
}

/// Parse a serving solver spec: `""`/`"adaptive"`, `"em[:<steps>]"`,
/// `"ddim[:<steps>]"`, `"pc[:<steps>[@<snr>]]"` (bare fixed-step names
/// default to [`DEFAULT_FIXED_STEPS`]; a `pc` spec without `@<snr>`
/// uses the serving process's default SNR).
pub fn parse(s: &str) -> Result<ServingSolver> {
    parse_with_steps(s, None)
}

/// [`parse`] with a caller-supplied default step count (e.g. the CLI's
/// `--steps` flag); an explicit `name:<steps>` in the spec wins.
pub fn parse_with_steps(s: &str, default_steps: Option<usize>) -> Result<ServingSolver> {
    let s = s.trim();
    let (name, arg) = match s.split_once(':') {
        Some((n, a)) => (n.trim(), Some(a.trim())),
        None => (s, None),
    };
    // `pc:<steps>@<snr>`: split the optional snr suffix off the count
    let (count_arg, snr_arg) = match arg.and_then(|a| a.split_once('@')) {
        Some((c, v)) => (Some(c.trim()), Some(v.trim())),
        None => (arg, None),
    };
    let fixed_steps = || -> Result<usize> {
        let steps = match count_arg {
            Some(a) => a
                .parse::<usize>()
                .map_err(|_| anyhow!("bad step count '{a}' in solver spec '{s}'"))?,
            None => default_steps.unwrap_or(DEFAULT_FIXED_STEPS),
        };
        if steps == 0 {
            bail!("solver spec '{s}' needs at least 1 step");
        }
        Ok(steps)
    };
    if snr_arg.is_some() && name != "pc" {
        bail!("only pc specs take an @<snr> suffix (got '{s}')");
    }
    match name {
        "" | "adaptive" => {
            if arg.is_some() {
                bail!("'adaptive' takes no step count (got '{s}')");
            }
            Ok(ServingSolver::Adaptive)
        }
        "em" | "euler-maruyama" => Ok(ServingSolver::Em { steps: fixed_steps()? }),
        "ddim" => Ok(ServingSolver::Ddim { steps: fixed_steps()? }),
        "pc" => {
            let steps = fixed_steps()?;
            let snr = snr_arg
                .map(|v| -> Result<f64> {
                    let snr = v
                        .parse::<f64>()
                        .map_err(|_| anyhow!("bad snr '{v}' in solver spec '{s}'"))?;
                    if !(snr.is_finite() && snr > 0.0) {
                        bail!("solver spec '{s}' needs a finite snr > 0");
                    }
                    Ok(snr)
                })
                .transpose()?;
            Ok(ServingSolver::Pc { steps, snr })
        }
        other => bail!(
            "unknown solver '{other}' (serving specs: adaptive, em[:<steps>], \
             ddim[:<steps>], pc[:<steps>[@<snr>]])"
        ),
    }
}

/// Engine-equivalent per-lane offline run — the `--offline` twin of the
/// serving lane pools. Lane `i` forks `Rng::new(seed).fork(base + i)`
/// and follows exactly the arithmetic the engine's pool for this solver
/// runs, so results are bit-identical to the served path for the same
/// `(seed, base, eps_rel)`. `aopts` configures the adaptive controller
/// (fixed-step solvers ignore it).
pub fn run_lanes(
    solver: ServingSolver,
    ctx: &Ctx,
    seed: u64,
    base: u64,
    count: usize,
    aopts: &adaptive::AdaptiveOpts,
) -> Result<SolveResult> {
    match solver {
        ServingSolver::Adaptive => adaptive::run_lanes(ctx, seed, base, count, aopts),
        ServingSolver::Em { steps } => em::run_lanes(ctx, seed, base, count, steps),
        ServingSolver::Ddim { steps } => ddim::run_lanes(ctx, seed, base, count, steps),
        ServingSolver::Pc { steps, snr } => {
            let snr = snr.unwrap_or_else(|| rdl::default_snr(&ctx.process));
            rdl::run_lanes(ctx, seed, base, count, steps, snr)
        }
    }
}

/// Outcome of [`evaluate_offline_lanes`].
#[derive(Clone, Copy, Debug)]
pub struct OfflineEval {
    pub fid: f64,
    pub is: f64,
    /// Mean score-net evaluations per sample (incl. the denoise call).
    pub mean_nfe: f64,
    pub wall_s: f64,
}

/// Chunked per-lane offline FID*/IS* evaluation of a served solver spec
/// — the single implementation behind `gofast evaluate --offline`, the
/// eval bench's parity twin, and the engine-vs-offline agreement
/// tests (so the offline side of the <= 1e-6 contract cannot fork).
/// Generates `samples` images through [`run_lanes`] in pool-width
/// chunks (the width is the solver's widest compiled rung under
/// `max_bucket`; the result does not depend on it — per-lane streams
/// only see the global sample index), converts to unit range, and
/// scores with the same streaming accumulator arithmetic as the
/// engine's eval lanes.
pub fn evaluate_offline_lanes(
    model: &crate::runtime::Model,
    net: &crate::runtime::FidNet,
    reference: &crate::metrics::FeatureStats,
    solver: ServingSolver,
    samples: usize,
    seed: u64,
    aopts: &adaptive::AdaptiveOpts,
    max_bucket: usize,
) -> Result<OfflineEval> {
    let bucket = crate::runtime::manifest_program_bucket(
        model.runtime().root(),
        &model.meta.name,
        solver.step_artifact(),
        max_bucket,
    )?;
    let ctx = Ctx::new(model, bucket, super::SolveOpts::default());
    let mut images = crate::tensor::Tensor::zeros(&[samples, model.meta.dim]);
    let mut nfe_sum = 0u64;
    let t0 = std::time::Instant::now();
    let mut done = 0;
    while done < samples {
        let take = (samples - done).min(bucket);
        let res = run_lanes(solver, &ctx, seed, done as u64, take, aopts)?;
        for i in 0..take {
            images.row_mut(done + i).copy_from_slice(res.x.row(i));
        }
        nfe_sum += res.nfe_per_sample.iter().sum::<u64>();
        done += take;
    }
    model.meta.process().to_unit_range(&mut images);
    let (fid, is) = crate::metrics::evaluate_streaming(net, &images, reference)?;
    Ok(OfflineEval {
        fid,
        is,
        mean_nfe: nfe_sum as f64 / samples as f64,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

#[derive(Clone, Debug)]
pub enum Spec {
    /// Algorithm 1, fused artifact path.
    Adaptive(adaptive::AdaptiveOpts),
    /// Algorithm 1, composed (host-math) path with ablation knobs.
    AdaptiveComposed(adaptive::AdaptiveOpts),
    /// Euler–Maruyama with n uniform steps.
    Em(usize),
    EmComposed(usize),
    /// Reverse-Diffusion + Langevin (PC), n predictor steps.
    Rdl(usize),
    /// DDIM with n steps (VP only).
    Ddim(usize),
    /// Probability-flow ODE, RK45.
    Ode(prob_flow::OdeOpts),
    /// Lamba (2003) adaptive EM.
    Lamba(lamba::LambaOpts),
    /// Fixed-step Stratonovich Heun.
    EulerHeun(usize),
    /// Order-1.5 additive-noise SRK (SRA1 structure), adaptive.
    Sra1(table3::Sra1Opts),
    /// Adaptive Milstein (== adaptive EM for additive noise).
    Milstein(f64),
    /// Drift-implicit split-step EM, n steps.
    Issem(usize),
}

impl Spec {
    pub fn name(&self) -> &'static str {
        match self {
            Spec::Adaptive(_) => "ours",
            Spec::AdaptiveComposed(_) => "ours-composed",
            Spec::Em(_) => "euler-maruyama",
            Spec::EmComposed(_) => "euler-maruyama-composed",
            Spec::Rdl(_) => "reverse-diffusion+langevin",
            Spec::Ddim(_) => "ddim",
            Spec::Ode(_) => "probability-flow",
            Spec::Lamba(_) => "lamba-em",
            Spec::EulerHeun(_) => "euler-heun",
            Spec::Sra1(_) => "sra1",
            Spec::Milstein(_) => "milstein",
            Spec::Issem(_) => "issem",
        }
    }

    pub fn run(&self, ctx: &Ctx, rng: &mut Rng) -> Result<SolveResult> {
        match self {
            Spec::Adaptive(o) => adaptive::run_fused(ctx, rng, o),
            Spec::AdaptiveComposed(o) => adaptive::run_composed(ctx, rng, o),
            Spec::Em(n) => em::run(ctx, rng, *n),
            Spec::EmComposed(n) => em::run_composed(ctx, rng, *n),
            Spec::Rdl(n) => rdl::run(ctx, rng, *n, None),
            Spec::Ddim(n) => ddim::run(ctx, rng, *n),
            Spec::Ode(o) => prob_flow::run(ctx, rng, o),
            Spec::Lamba(o) => lamba::run(ctx, rng, o),
            Spec::EulerHeun(n) => table3::euler_heun(ctx, rng, *n),
            Spec::Sra1(o) => table3::sra1(ctx, rng, o),
            Spec::Milstein(e) => table3::milstein(ctx, rng, *e),
            Spec::Issem(n) => table3::issem(ctx, rng, *n),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_served_specs() {
        assert_eq!(parse("").unwrap(), ServingSolver::Adaptive);
        assert_eq!(parse("adaptive").unwrap(), ServingSolver::Adaptive);
        assert_eq!(parse("em:128").unwrap(), ServingSolver::Em { steps: 128 });
        assert_eq!(parse(" ddim : 32 ").unwrap(), ServingSolver::Ddim { steps: 32 });
        assert_eq!(parse("em").unwrap(), ServingSolver::Em { steps: DEFAULT_FIXED_STEPS });
        assert_eq!(parse("euler-maruyama:8").unwrap(), ServingSolver::Em { steps: 8 });
        assert_eq!(parse("pc").unwrap(), ServingSolver::Pc {
            steps: DEFAULT_FIXED_STEPS,
            snr: None
        });
        assert_eq!(parse("pc:64").unwrap(), ServingSolver::Pc { steps: 64, snr: None });
        assert_eq!(
            parse("pc:64@0.17").unwrap(),
            ServingSolver::Pc { steps: 64, snr: Some(0.17) }
        );
        assert_eq!(
            parse(" pc : 8 @ 0.01 ").unwrap(),
            ServingSolver::Pc { steps: 8, snr: Some(0.01) }
        );
    }

    #[test]
    fn parse_rejects_bad_pc_snr() {
        // zero steps, zero / negative / non-finite / malformed snr, and
        // @<snr> on a non-pc solver are all wire-parser rejections
        for bad in ["pc:0", "pc:64@0", "pc:64@-1", "pc:64@nope", "pc:64@inf", "em:8@0.1"] {
            assert!(parse(bad).is_err(), "'{bad}' should not parse");
        }
        let err = parse("pc:64@0").unwrap_err().to_string();
        assert!(err.contains("snr > 0"), "{err}");
        // the Rust-API path is guarded too
        assert!(ServingSolver::Pc { steps: 4, snr: Some(0.0) }.validate().is_err());
        assert!(ServingSolver::Pc { steps: 4, snr: Some(f64::NAN) }.validate().is_err());
        assert!(ServingSolver::Pc { steps: 0, snr: None }.validate().is_err());
        assert!(ServingSolver::Pc { steps: 4, snr: Some(0.17) }.validate().is_ok());
    }

    #[test]
    fn kernel_table_is_the_single_source_of_solver_facts() {
        for (solver, artifact, evals, adaptive) in [
            (ServingSolver::Adaptive, "adaptive_step", 2, true),
            (ServingSolver::Em { steps: 4 }, "em_step", 1, false),
            (ServingSolver::Ddim { steps: 4 }, "ddim_step", 1, false),
            (ServingSolver::Pc { steps: 4, snr: None }, "pc_step", 2, false),
        ] {
            let k = solver.kernel();
            assert_eq!(solver.step_artifact(), artifact);
            assert_eq!(k.score_evals_per_step, evals);
            assert_eq!(k.adaptive, adaptive);
            assert_eq!(kernel_for_artifact(artifact), Some(k));
            assert_eq!(kernel(solver.name()), Some(k));
        }
        // the PC row carries the aux-input facts the lane program builds
        // its device args from
        let pc = kernel("pc").unwrap();
        assert_eq!((pc.noise_inputs, pc.snr_input, pc.vp_only), (2, true, false));
        assert!(kernel("ode").is_none());
        assert!(kernel_for_artifact("score").is_none());
        // fused-dispatch facts: every served kernel fuses (adaptive via
        // the device-side accept/reject fold), and the name round-trips
        // through the helpers
        for name in ["adaptive", "em", "ddim", "pc"] {
            let k = kernel(name).unwrap();
            assert!(k.max_steps_per_dispatch >= 8, "{name}");
            let fused = fused_artifact(k.artifact, 8);
            assert_eq!(kernel_for_fused_artifact(&fused), Some((k, 8)));
        }
        assert_eq!(fused_artifact("em_step", 8), "em_stepk8");
        // non-fused names fall through: the base single-step artifact,
        // k<2 and non-table bases are all None
        assert!(kernel_for_fused_artifact("em_step").is_none());
        assert!(kernel_for_fused_artifact("em_stepk1").is_none());
        assert!(kernel_for_fused_artifact("scorek8").is_none());
    }

    #[test]
    fn parse_with_steps_prefers_the_explicit_suffix() {
        assert_eq!(
            parse_with_steps("em", Some(64)).unwrap(),
            ServingSolver::Em { steps: 64 }
        );
        assert_eq!(
            parse_with_steps("em:100", Some(64)).unwrap(),
            ServingSolver::Em { steps: 100 }
        );
        assert_eq!(parse_with_steps("adaptive", Some(64)).unwrap(), ServingSolver::Adaptive);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in ["ode", "em:zero", "em:0", "adaptive:5", "rdl:10"] {
            assert!(parse(bad).is_err(), "'{bad}' should not parse");
        }
        let err = parse("ode").unwrap_err().to_string();
        assert!(err.contains("adaptive, em[:<steps>], ddim[:<steps>]"), "{err}");
    }

    #[test]
    fn spec_string_round_trips() {
        for s in [
            ServingSolver::Adaptive,
            ServingSolver::Em { steps: 12 },
            ServingSolver::Ddim { steps: 7 },
            ServingSolver::Pc { steps: 20, snr: None },
            ServingSolver::Pc { steps: 20, snr: Some(0.17) },
        ] {
            assert_eq!(parse(&s.spec_string()).unwrap(), s);
        }
        assert_eq!(ServingSolver::Pc { steps: 20, snr: Some(0.17) }.spec_string(), "pc:20@0.17");
    }
}
