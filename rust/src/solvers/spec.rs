//! Unified solver specification.
//!
//! Two layers:
//! * [`ServingSolver`] — the solvers the engine's lane-program pools
//!   serve (`coordinator::programs`), with the **single** spec parser
//!   ([`parse`]) shared by `gofast evaluate` (served and `--offline`),
//!   `gofast serve --solvers`, and the server wire layer, so the paths
//!   cannot drift in accepted names or defaults;
//! * [`Spec`] — the wider bench/table enum naming every solver the
//!   paper tables exercise, with one dispatch point.

use super::{adaptive, ddim, em, lamba, prob_flow, rdl, table3, Ctx, SolveResult};
use crate::rng::Rng;
use crate::{anyhow, bail, Result};

/// Step count a fixed-step spec defaults to when neither the spec string
/// (`em:<n>`) nor the caller supplies one.
pub const DEFAULT_FIXED_STEPS: usize = 256;

/// A solver the serving engine can run as a lane-program pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServingSolver {
    /// Algorithm 1 (the paper's adaptive solver); per-lane step sizes.
    Adaptive,
    /// Euler–Maruyama, `steps` uniform steps per lane.
    Em { steps: usize },
    /// DDIM (deterministic, VP only), `steps` uniform steps per lane.
    Ddim { steps: usize },
}

impl ServingSolver {
    /// Routing name ("adaptive" | "em" | "ddim").
    pub fn name(&self) -> &'static str {
        match self {
            ServingSolver::Adaptive => "adaptive",
            ServingSolver::Em { .. } => "em",
            ServingSolver::Ddim { .. } => "ddim",
        }
    }

    /// Compiled step artifact that advances a pool of this solver's lanes.
    pub fn step_artifact(&self) -> &'static str {
        match self {
            ServingSolver::Adaptive => "adaptive_step",
            ServingSolver::Em { .. } => "em_step",
            ServingSolver::Ddim { .. } => "ddim_step",
        }
    }

    /// Fixed step count (None for the adaptive solver).
    pub fn steps(&self) -> Option<usize> {
        match self {
            ServingSolver::Adaptive => None,
            ServingSolver::Em { steps } | ServingSolver::Ddim { steps } => Some(*steps),
        }
    }

    /// Canonical spec string (`adaptive`, `em:<n>`, `ddim:<n>`) —
    /// round-trips through [`parse`].
    pub fn spec_string(&self) -> String {
        match self.steps() {
            None => self.name().to_string(),
            Some(n) => format!("{}:{n}", self.name()),
        }
    }

    /// Admission-time validation. [`parse`] already rejects `em:0` on
    /// the wire/CLI, but a spec constructed directly through the Rust
    /// API must not reach a lane pool: a zero-step fixed lane has no
    /// grid and would never converge.
    pub fn validate(&self) -> Result<()> {
        if self.steps() == Some(0) {
            bail!("solver '{}' needs at least 1 step", self.name());
        }
        Ok(())
    }
}

/// Parse a serving solver spec: `""`/`"adaptive"`, `"em[:<steps>]"`,
/// `"ddim[:<steps>]"` (bare fixed-step names default to
/// [`DEFAULT_FIXED_STEPS`]).
pub fn parse(s: &str) -> Result<ServingSolver> {
    parse_with_steps(s, None)
}

/// [`parse`] with a caller-supplied default step count (e.g. the CLI's
/// `--steps` flag); an explicit `name:<steps>` in the spec wins.
pub fn parse_with_steps(s: &str, default_steps: Option<usize>) -> Result<ServingSolver> {
    let s = s.trim();
    let (name, arg) = match s.split_once(':') {
        Some((n, a)) => (n.trim(), Some(a.trim())),
        None => (s, None),
    };
    let fixed_steps = || -> Result<usize> {
        let steps = match arg {
            Some(a) => a
                .parse::<usize>()
                .map_err(|_| anyhow!("bad step count '{a}' in solver spec '{s}'"))?,
            None => default_steps.unwrap_or(DEFAULT_FIXED_STEPS),
        };
        if steps == 0 {
            bail!("solver spec '{s}' needs at least 1 step");
        }
        Ok(steps)
    };
    match name {
        "" | "adaptive" => {
            if arg.is_some() {
                bail!("'adaptive' takes no step count (got '{s}')");
            }
            Ok(ServingSolver::Adaptive)
        }
        "em" | "euler-maruyama" => Ok(ServingSolver::Em { steps: fixed_steps()? }),
        "ddim" => Ok(ServingSolver::Ddim { steps: fixed_steps()? }),
        other => bail!(
            "unknown solver '{other}' (serving specs: adaptive, em[:<steps>], ddim[:<steps>])"
        ),
    }
}

/// Engine-equivalent per-lane offline run — the `--offline` twin of the
/// serving lane pools. Lane `i` forks `Rng::new(seed).fork(base + i)`
/// and follows exactly the arithmetic the engine's pool for this solver
/// runs, so results are bit-identical to the served path for the same
/// `(seed, base, eps_rel)`. `aopts` configures the adaptive controller
/// (fixed-step solvers ignore it).
pub fn run_lanes(
    solver: ServingSolver,
    ctx: &Ctx,
    seed: u64,
    base: u64,
    count: usize,
    aopts: &adaptive::AdaptiveOpts,
) -> Result<SolveResult> {
    match solver {
        ServingSolver::Adaptive => adaptive::run_lanes(ctx, seed, base, count, aopts),
        ServingSolver::Em { steps } => em::run_lanes(ctx, seed, base, count, steps),
        ServingSolver::Ddim { steps } => ddim::run_lanes(ctx, seed, base, count, steps),
    }
}

/// Outcome of [`evaluate_offline_lanes`].
#[derive(Clone, Copy, Debug)]
pub struct OfflineEval {
    pub fid: f64,
    pub is: f64,
    /// Mean score-net evaluations per sample (incl. the denoise call).
    pub mean_nfe: f64,
    pub wall_s: f64,
}

/// Chunked per-lane offline FID*/IS* evaluation of a served solver spec
/// — the single implementation behind `gofast evaluate --offline`, the
/// eval bench's parity twin, and the engine-vs-offline agreement
/// tests (so the offline side of the <= 1e-6 contract cannot fork).
/// Generates `samples` images through [`run_lanes`] in pool-width
/// chunks (the width is the solver's widest compiled rung under
/// `max_bucket`; the result does not depend on it — per-lane streams
/// only see the global sample index), converts to unit range, and
/// scores with the same streaming accumulator arithmetic as the
/// engine's eval lanes.
pub fn evaluate_offline_lanes(
    model: &crate::runtime::Model,
    net: &crate::runtime::FidNet,
    reference: &crate::metrics::FeatureStats,
    solver: ServingSolver,
    samples: usize,
    seed: u64,
    aopts: &adaptive::AdaptiveOpts,
    max_bucket: usize,
) -> Result<OfflineEval> {
    let bucket = crate::runtime::manifest_program_bucket(
        model.runtime().root(),
        &model.meta.name,
        solver.step_artifact(),
        max_bucket,
    )?;
    let ctx = Ctx::new(model, bucket, super::SolveOpts::default());
    let mut images = crate::tensor::Tensor::zeros(&[samples, model.meta.dim]);
    let mut nfe_sum = 0u64;
    let t0 = std::time::Instant::now();
    let mut done = 0;
    while done < samples {
        let take = (samples - done).min(bucket);
        let res = run_lanes(solver, &ctx, seed, done as u64, take, aopts)?;
        for i in 0..take {
            images.row_mut(done + i).copy_from_slice(res.x.row(i));
        }
        nfe_sum += res.nfe_per_sample.iter().sum::<u64>();
        done += take;
    }
    model.meta.process().to_unit_range(&mut images);
    let (fid, is) = crate::metrics::evaluate_streaming(net, &images, reference)?;
    Ok(OfflineEval {
        fid,
        is,
        mean_nfe: nfe_sum as f64 / samples as f64,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

#[derive(Clone, Debug)]
pub enum Spec {
    /// Algorithm 1, fused artifact path.
    Adaptive(adaptive::AdaptiveOpts),
    /// Algorithm 1, composed (host-math) path with ablation knobs.
    AdaptiveComposed(adaptive::AdaptiveOpts),
    /// Euler–Maruyama with n uniform steps.
    Em(usize),
    EmComposed(usize),
    /// Reverse-Diffusion + Langevin (PC), n predictor steps.
    Rdl(usize),
    /// DDIM with n steps (VP only).
    Ddim(usize),
    /// Probability-flow ODE, RK45.
    Ode(prob_flow::OdeOpts),
    /// Lamba (2003) adaptive EM.
    Lamba(lamba::LambaOpts),
    /// Fixed-step Stratonovich Heun.
    EulerHeun(usize),
    /// Order-1.5 additive-noise SRK (SRA1 structure), adaptive.
    Sra1(table3::Sra1Opts),
    /// Adaptive Milstein (== adaptive EM for additive noise).
    Milstein(f64),
    /// Drift-implicit split-step EM, n steps.
    Issem(usize),
}

impl Spec {
    pub fn name(&self) -> &'static str {
        match self {
            Spec::Adaptive(_) => "ours",
            Spec::AdaptiveComposed(_) => "ours-composed",
            Spec::Em(_) => "euler-maruyama",
            Spec::EmComposed(_) => "euler-maruyama-composed",
            Spec::Rdl(_) => "reverse-diffusion+langevin",
            Spec::Ddim(_) => "ddim",
            Spec::Ode(_) => "probability-flow",
            Spec::Lamba(_) => "lamba-em",
            Spec::EulerHeun(_) => "euler-heun",
            Spec::Sra1(_) => "sra1",
            Spec::Milstein(_) => "milstein",
            Spec::Issem(_) => "issem",
        }
    }

    pub fn run(&self, ctx: &Ctx, rng: &mut Rng) -> Result<SolveResult> {
        match self {
            Spec::Adaptive(o) => adaptive::run_fused(ctx, rng, o),
            Spec::AdaptiveComposed(o) => adaptive::run_composed(ctx, rng, o),
            Spec::Em(n) => em::run(ctx, rng, *n),
            Spec::EmComposed(n) => em::run_composed(ctx, rng, *n),
            Spec::Rdl(n) => rdl::run(ctx, rng, *n, None),
            Spec::Ddim(n) => ddim::run(ctx, rng, *n),
            Spec::Ode(o) => prob_flow::run(ctx, rng, o),
            Spec::Lamba(o) => lamba::run(ctx, rng, o),
            Spec::EulerHeun(n) => table3::euler_heun(ctx, rng, *n),
            Spec::Sra1(o) => table3::sra1(ctx, rng, o),
            Spec::Milstein(e) => table3::milstein(ctx, rng, *e),
            Spec::Issem(n) => table3::issem(ctx, rng, *n),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_served_specs() {
        assert_eq!(parse("").unwrap(), ServingSolver::Adaptive);
        assert_eq!(parse("adaptive").unwrap(), ServingSolver::Adaptive);
        assert_eq!(parse("em:128").unwrap(), ServingSolver::Em { steps: 128 });
        assert_eq!(parse(" ddim : 32 ").unwrap(), ServingSolver::Ddim { steps: 32 });
        assert_eq!(parse("em").unwrap(), ServingSolver::Em { steps: DEFAULT_FIXED_STEPS });
        assert_eq!(parse("euler-maruyama:8").unwrap(), ServingSolver::Em { steps: 8 });
    }

    #[test]
    fn parse_with_steps_prefers_the_explicit_suffix() {
        assert_eq!(
            parse_with_steps("em", Some(64)).unwrap(),
            ServingSolver::Em { steps: 64 }
        );
        assert_eq!(
            parse_with_steps("em:100", Some(64)).unwrap(),
            ServingSolver::Em { steps: 100 }
        );
        assert_eq!(parse_with_steps("adaptive", Some(64)).unwrap(), ServingSolver::Adaptive);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in ["ode", "em:zero", "em:0", "adaptive:5", "rdl:10"] {
            assert!(parse(bad).is_err(), "'{bad}' should not parse");
        }
        let err = parse("ode").unwrap_err().to_string();
        assert!(err.contains("adaptive, em[:<steps>], ddim[:<steps>]"), "{err}");
    }

    #[test]
    fn spec_string_round_trips() {
        for s in [
            ServingSolver::Adaptive,
            ServingSolver::Em { steps: 12 },
            ServingSolver::Ddim { steps: 7 },
        ] {
            assert_eq!(parse(&s.spec_string()).unwrap(), s);
        }
    }
}
