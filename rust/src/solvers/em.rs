//! Euler–Maruyama baseline (paper §2.4): fixed uniform step size, one
//! score evaluation per step, fresh noise each step.

use super::{fill_noise, t_vec, time_grid, Ctx, SolveResult};
use crate::rng::Rng;
use crate::tensor::Tensor;
use crate::Result;

/// Solve the RDP with `n_steps` uniform EM steps via the fused em_step
/// artifact. NFE per sample = n_steps (+1 if denoising).
pub fn run(ctx: &Ctx, rng: &mut Rng, n_steps: usize) -> Result<SolveResult> {
    let b = ctx.bucket;
    let grid = time_grid(&ctx.process, n_steps);
    let mut x = ctx.sample_prior(rng);
    let mut z = Tensor::zeros(&[b, ctx.dim()]);
    for w in grid.windows(2) {
        let (t, t_next) = (w[0], w[1]);
        let h = t - t_next;
        fill_noise(rng, &mut z);
        let t_in = t_vec(b, t);
        let h_in = t_vec(b, h);
        let mut out = ctx.model.exec(
            "em_step",
            ctx.bucket,
            &[&x, &t_in, &h_in, &z],
            ctx.opts.fused_buffers,
        )?;
        x = out.pop().unwrap();
    }
    let mut nfe = vec![n_steps as u64; b];
    if ctx.opts.denoise {
        x = ctx.denoise(&x, &t_vec(b, ctx.process.t_eps()))?;
        nfe.iter_mut().for_each(|n| *n += 1);
    }
    Ok(SolveResult { x, nfe_per_sample: nfe, steps: n_steps as u64, rejections: 0 })
}

/// EM with *per-lane* RNG streams matching the serving engine's lane
/// semantics exactly: lane `i` owns `Rng::new(seed).fork(base + i)`,
/// draws its prior and every step's noise from that stream, and walks
/// the uniform grid `uniform_t(t_eps, n_steps, k)` — the same nodes the
/// engine's `em_step` lane pool feeds the kernel. Because no lane's
/// update reads another lane's state, a sample's trajectory here is
/// bit-identical to the served one for the same `(seed, base + i)`,
/// regardless of pool width, migration, or co-batched traffic. This is
/// the `--offline` twin the engine-vs-offline agreement check for
/// served EM evaluation is defined against.
///
/// `count` lanes (<= `ctx.bucket`) run batched at `ctx.bucket`; returns
/// `count` rows.
pub fn run_lanes(
    ctx: &Ctx,
    seed: u64,
    base: u64,
    count: usize,
    n_steps: usize,
) -> Result<SolveResult> {
    let mut z = Tensor::zeros(&[ctx.bucket, ctx.dim()]);
    let evals = super::spec::kernel("em").unwrap().score_evals_per_step;
    super::run_fixed_lanes(ctx, seed, base, count, n_steps, evals, |x, t, tn, rngs| {
        let b = x.shape[0];
        // padding lanes ride along exactly like the engine's free lanes:
        // t = 1, h = 0 (an exact no-op in the kernel), zero noise
        let mut t_in = vec![1.0f32; b];
        let mut h_in = vec![0.0f32; b];
        for (i, rng) in rngs.iter_mut().enumerate() {
            t_in[i] = t as f32;
            h_in[i] = (t - tn) as f32;
            rng.fill_normal(z.row_mut(i));
        }
        let t_t = Tensor { shape: vec![b], data: t_in };
        let h_t = Tensor { shape: vec![b], data: h_in };
        let mut out =
            ctx.model.exec("em_step", b, &[x, &t_t, &h_t, &z], ctx.opts.fused_buffers)?;
        Ok(out.pop().unwrap())
    })
}

/// Composed EM (host update over raw score calls) — baseline for the
/// fused-vs-composed perf comparison and cross-check tests.
pub fn run_composed(ctx: &Ctx, rng: &mut Rng, n_steps: usize) -> Result<SolveResult> {
    let b = ctx.bucket;
    let d = ctx.dim();
    let grid = time_grid(&ctx.process, n_steps);
    let mut x = ctx.sample_prior(rng);
    let mut z = Tensor::zeros(&[b, d]);
    for w in grid.windows(2) {
        let (t, t_next) = (w[0], w[1]);
        let h = t - t_next;
        fill_noise(rng, &mut z);
        let t_in = t_vec(b, t);
        let drift = ctx.rdp_drift(&x, &t_in)?;
        let g = ctx.process.diffusion(t) as f32;
        let (a, c) = (-(h as f32), (h.sqrt() as f32) * g);
        for i in 0..b {
            let (xr, dr, zr) = (x.row_mut(i), drift.row(i), z.row(i));
            for j in 0..d {
                xr[j] += a * dr[j] + c * zr[j];
            }
        }
    }
    let mut nfe = vec![n_steps as u64; b];
    if ctx.opts.denoise {
        x = ctx.denoise(&x, &t_vec(b, ctx.process.t_eps()))?;
        nfe.iter_mut().for_each(|n| *n += 1);
    }
    Ok(SolveResult { x, nfe_per_sample: nfe, steps: n_steps as u64, rejections: 0 })
}
