//! DDIM (Song et al. 2020b), deterministic eta = 0 variant — defined for
//! VP processes only (paper §4). One score evaluation per step.

use super::{t_vec, time_grid, Ctx, SolveResult};
use crate::rng::Rng;
use crate::{bail, Result};

pub fn run(ctx: &Ctx, rng: &mut Rng, n_steps: usize) -> Result<SolveResult> {
    if ctx.process.kind() != "vp" {
        bail!("DDIM is only defined for VP models (paper §4)");
    }
    let b = ctx.bucket;
    let grid = time_grid(&ctx.process, n_steps);
    let mut x = ctx.sample_prior(rng);
    for w in grid.windows(2) {
        let t_in = t_vec(b, w[0]);
        let tn_in = t_vec(b, w[1]);
        let mut out = ctx.model.exec(
            "ddim_step",
            ctx.bucket,
            &[&x, &t_in, &tn_in],
            ctx.opts.fused_buffers,
        )?;
        x = out.pop().unwrap();
    }
    let mut nfe = vec![n_steps as u64; b];
    if ctx.opts.denoise {
        x = ctx.denoise(&x, &t_vec(b, ctx.process.t_eps()))?;
        nfe.iter_mut().for_each(|n| *n += 1);
    }
    Ok(SolveResult { x, nfe_per_sample: nfe, steps: n_steps as u64, rejections: 0 })
}
