//! DDIM (Song et al. 2020b), deterministic eta = 0 variant — defined for
//! VP processes only (paper §4). One score evaluation per step.

use super::{t_vec, time_grid, Ctx, SolveResult};
use crate::rng::Rng;
use crate::tensor::Tensor;
use crate::{bail, Result};

pub fn run(ctx: &Ctx, rng: &mut Rng, n_steps: usize) -> Result<SolveResult> {
    if ctx.process.kind() != "vp" {
        bail!("DDIM is only defined for VP models (paper §4)");
    }
    let b = ctx.bucket;
    let grid = time_grid(&ctx.process, n_steps);
    let mut x = ctx.sample_prior(rng);
    for w in grid.windows(2) {
        let t_in = t_vec(b, w[0]);
        let tn_in = t_vec(b, w[1]);
        let mut out = ctx.model.exec(
            "ddim_step",
            ctx.bucket,
            &[&x, &t_in, &tn_in],
            ctx.opts.fused_buffers,
        )?;
        x = out.pop().unwrap();
    }
    let mut nfe = vec![n_steps as u64; b];
    if ctx.opts.denoise {
        x = ctx.denoise(&x, &t_vec(b, ctx.process.t_eps()))?;
        nfe.iter_mut().for_each(|n| *n += 1);
    }
    Ok(SolveResult { x, nfe_per_sample: nfe, steps: n_steps as u64, rejections: 0 })
}

/// DDIM with *per-lane* RNG streams matching the serving engine's lane
/// semantics: lane `i` draws its prior from `Rng::new(seed).fork(base +
/// i)` (DDIM is deterministic after the prior, so that is the stream's
/// only use) and walks the uniform grid `uniform_t(t_eps, n_steps, k)`
/// — the nodes the engine's `ddim_step` lane pool feeds the kernel.
/// The `--offline` twin for served DDIM evaluation; see
/// `em::run_lanes` for the agreement contract.
pub fn run_lanes(
    ctx: &Ctx,
    seed: u64,
    base: u64,
    count: usize,
    n_steps: usize,
) -> Result<SolveResult> {
    if ctx.process.kind() != "vp" {
        bail!("DDIM is only defined for VP models (paper §4)");
    }
    let evals = super::spec::kernel("ddim").unwrap().score_evals_per_step;
    super::run_fixed_lanes(ctx, seed, base, count, n_steps, evals, |x, t, tn, rngs| {
        let b = x.shape[0];
        // padding lanes ride along like the engine's free lanes:
        // t == tn makes the update an exact no-op
        let mut t_in = vec![1.0f32; b];
        let mut tn_in = vec![1.0f32; b];
        for i in 0..rngs.len() {
            t_in[i] = t as f32;
            tn_in[i] = tn as f32;
        }
        let t_t = Tensor { shape: vec![b], data: t_in };
        let tn_t = Tensor { shape: vec![b], data: tn_in };
        let mut out = ctx.model.exec("ddim_step", b, &[x, &t_t, &tn_t], ctx.opts.fused_buffers)?;
        Ok(out.pop().unwrap())
    })
}
