//! Algorithm 2 (paper Appendix C): dynamic-step-size extrapolation for
//! *arbitrary forward-time* diffusion processes dx = f(x,t)dt + g(x,t)dw,
//! with closure-provided drift/diffusion (no score network involved).
//!
//! Differences from Algorithm 1 (per the paper):
//! * forward time over a given [t_begin, t_end];
//! * state-dependent diffusion handled via the Itō correction draw
//!   s = ±1 (Roberts 2012); s = 0 for Stratonovich or g(x,t) = g(t);
//! * the full trajectory is retained;
//! * **noise is retained after a rejection** so rejections are unbiased.
//!
//! This module is pure host math — it is the reference implementation
//! used by the App. F stability tests and the `forward_sde` example.

use crate::rng::Rng;
use crate::Result;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NoiseKind {
    /// g depends on x under the Itō convention: draw s = ±1.
    ItoStateDependent,
    /// g(x,t) = g(t) or Stratonovich convention: s = 0.
    Additive,
}

#[derive(Clone, Copy, Debug)]
pub struct GeneralOpts {
    pub eps_rel: f64,
    pub eps_abs: f64,
    pub r: f64,
    pub safety: f64,
    pub h_init: f64,
    pub noise: NoiseKind,
    pub max_iters: u64,
}

impl Default for GeneralOpts {
    fn default() -> Self {
        GeneralOpts {
            eps_rel: 0.01,
            eps_abs: 1e-3,
            r: 0.9,
            safety: 0.9,
            h_init: 0.01,
            noise: NoiseKind::Additive,
            max_iters: 1_000_000,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Trajectory {
    /// (t, state) at every accepted step, including the initial state.
    pub points: Vec<(f64, Vec<f64>)>,
    pub steps: u64,
    pub rejections: u64,
}

impl Trajectory {
    pub fn final_state(&self) -> &[f64] {
        &self.points.last().unwrap().1
    }
}

/// Solve dx = f(x,t)dt + g(x,t)dw from (t_begin, x0) to t_end.
pub fn solve<F, G>(
    f: F,
    g: G,
    x0: &[f64],
    t_begin: f64,
    t_end: f64,
    rng: &mut Rng,
    opts: &GeneralOpts,
) -> Result<Trajectory>
where
    F: Fn(&[f64], f64, &mut [f64]),
    G: Fn(&[f64], f64, &mut [f64]),
{
    let d = x0.len();
    let mut x = x0.to_vec();
    let mut xprev = x0.to_vec();
    let mut t = t_begin;
    let mut h = opts.h_init.min(t_end - t_begin);
    let mut traj = Trajectory { points: vec![(t, x.clone())], steps: 0, rejections: 0 };
    // scratch
    let (mut fx, mut gx) = (vec![0.0; d], vec![0.0; d]);
    let (mut f2, mut g2) = (vec![0.0; d], vec![0.0; d]);
    let (mut xp, mut xt) = (vec![0.0; d], vec![0.0; d]);
    let mut z: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let mut s_draw = draw_s(rng, opts.noise);

    while t < t_end - 1e-14 {
        if traj.steps >= opts.max_iters {
            crate::bail!("general solver exceeded {} iterations", opts.max_iters);
        }
        traj.steps += 1;
        h = h.min(t_end - t);
        let sq = h.sqrt();
        // x' = x + h f(x,t) + sqrt(h) g(x,t) (z - s)
        f(&x, t, &mut fx);
        g(&x, t, &mut gx);
        for j in 0..d {
            xp[j] = x[j] + h * fx[j] + sq * gx[j] * (z[j] - s_draw);
        }
        // x~ = x + h f(x', t+h) + sqrt(h) g(x', t+h) (z + s)
        f(&xp, t + h, &mut f2);
        g(&xp, t + h, &mut g2);
        for j in 0..d {
            xt[j] = x[j] + h * f2[j] + sq * g2[j] * (z[j] + s_draw);
        }
        // E2 over x'' = (x' + x~)/2
        let mut acc = 0.0;
        for j in 0..d {
            let xpp = 0.5 * (xp[j] + xt[j]);
            let delta = opts.eps_abs.max(opts.eps_rel * xp[j].abs().max(xprev[j].abs()));
            let r = (xp[j] - xpp) / delta;
            acc += r * r;
        }
        let e2 = (acc / d as f64).sqrt();
        if e2 <= 1.0 {
            t += h;
            for j in 0..d {
                let xpp = 0.5 * (xp[j] + xt[j]);
                xprev[j] = xp[j];
                x[j] = xpp;
            }
            traj.points.push((t, x.clone()));
            // fresh noise only after acceptance (App. C: retain on rejection)
            for zj in z.iter_mut() {
                *zj = rng.normal();
            }
            s_draw = draw_s(rng, opts.noise);
        } else {
            traj.rejections += 1;
        }
        h = (h * opts.safety * e2.max(1e-12).powf(-opts.r)).min(t_end - t);
        if h <= 0.0 {
            h = 1e-12;
        }
    }
    Ok(traj)
}

fn draw_s(rng: &mut Rng, kind: NoiseKind) -> f64 {
    match kind {
        NoiseKind::Additive => 0.0,
        NoiseKind::ItoStateDependent => rng.sign(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Ornstein–Uhlenbeck: dx = -a x dt + s dw has stationary var s^2/(2a)
    /// — the paper's App. F linear test SDE, checking the scheme is
    /// asymptotically unbiased in mean and mean-square.
    #[test]
    fn ou_process_stationary_moments() {
        let (a, s) = (1.0, 0.5);
        let mut rng = Rng::new(123);
        let mut finals = Vec::new();
        for k in 0..200 {
            let mut r = rng.fork(k);
            let traj = solve(
                |x, _t, out| out.iter_mut().zip(x).for_each(|(o, &xi)| *o = -a * xi),
                |_x, _t, out| out.iter_mut().for_each(|o| *o = s),
                &[2.0, -2.0],
                0.0,
                8.0,
                &mut r,
                &GeneralOpts { eps_rel: 0.05, eps_abs: 1e-3, ..Default::default() },
            )
            .unwrap();
            finals.extend_from_slice(traj.final_state());
        }
        let n = finals.len() as f64;
        let mean = finals.iter().sum::<f64>() / n;
        let var = finals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
        let want_var = s * s / (2.0 * a); // 0.125
        assert!(mean.abs() < 0.06, "mean {mean}");
        assert!((var - want_var).abs() < 0.04, "var {var} want {want_var}");
    }

    /// Geometric Brownian motion (state-dependent g, Itō): E[x(T)] = x0 e^{mu T}.
    #[test]
    fn gbm_mean_matches_analytic() {
        let (mu, sigma, x0, t_end) = (0.3, 0.4, 1.0, 1.0);
        let mut rng = Rng::new(7);
        let mut sum = 0.0;
        let n = 2000;
        for k in 0..n {
            let mut r = rng.fork(k);
            let traj = solve(
                |x, _t, out| out[0] = mu * x[0],
                |x, _t, out| out[0] = sigma * x[0],
                &[x0],
                0.0,
                t_end,
                &mut r,
                &GeneralOpts {
                    eps_rel: 0.02,
                    eps_abs: 1e-4,
                    noise: NoiseKind::ItoStateDependent,
                    ..Default::default()
                },
            )
            .unwrap();
            sum += traj.final_state()[0];
        }
        let mean = sum / n as f64;
        let want = x0 * (mu * t_end).exp(); // 1.3499
        assert!((mean - want).abs() < 0.05, "mean {mean} want {want}");
    }

    #[test]
    fn deterministic_ode_high_accuracy() {
        // g = 0: dx = x dt => x(1) = e
        let mut rng = Rng::new(1);
        let traj = solve(
            |x, _t, out| out[0] = x[0],
            |_x, _t, out| out[0] = 0.0,
            &[1.0],
            0.0,
            1.0,
            &mut rng,
            &GeneralOpts { eps_rel: 1e-4, eps_abs: 1e-7, ..Default::default() },
        )
        .unwrap();
        let err = (traj.final_state()[0] - std::f64::consts::E).abs();
        assert!(err < 1e-3, "err {err}");
    }

    #[test]
    fn trajectory_is_monotone_in_time() {
        let mut rng = Rng::new(3);
        let traj = solve(
            |_x, _t, out| out[0] = 1.0,
            |_x, _t, out| out[0] = 0.1,
            &[0.0],
            0.5,
            2.0,
            &mut rng,
            &GeneralOpts::default(),
        )
        .unwrap();
        assert_eq!(traj.points.first().unwrap().0, 0.5);
        assert!((traj.points.last().unwrap().0 - 2.0).abs() < 1e-12);
        for w in traj.points.windows(2) {
            assert!(w[1].0 > w[0].0);
        }
    }

    /// Rejections keep the noise draw (App. C) — with a tolerance so tight
    /// everything rejects initially, the solver must still converge and
    /// remain unbiased (mean of OU at short horizon).
    #[test]
    fn tight_tolerance_still_converges() {
        let mut rng = Rng::new(9);
        let traj = solve(
            |x, _t, out| out[0] = -x[0],
            |_x, _t, out| out[0] = 1.0,
            &[1.0],
            0.0,
            0.5,
            &mut rng,
            &GeneralOpts { eps_rel: 1e-3, eps_abs: 1e-5, h_init: 0.5, ..Default::default() },
        )
        .unwrap();
        assert!(traj.rejections > 0, "expected at least one rejection");
        assert!(traj.final_state()[0].is_finite());
    }
}
