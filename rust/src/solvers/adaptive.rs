//! Algorithm 1 — the paper's contribution: dynamic-step-size
//! extrapolating solver for reverse diffusion processes.
//!
//! Integrator pair: Euler–Maruyama proposal `x'` + stochastic improved
//! Euler `x''` (Roberts 2012) sharing the first score evaluation; the
//! *extrapolated* `x''` is what's accepted (§3.1.1). Mixed tolerance
//! `delta = max(eps_abs, eps_rel * max(|x'|, |x'_prev|))` (Eq. 5), scaled
//! l2 error (§3.1.3), controller `h <- min(h_max, theta h E2^-r)` with
//! per-sample step sizes (§3.1.5).
//!
//! `run_fused` drives the `adaptive_step` artifact (2 NFE/call, all math
//! in-graph); `run_composed` reproduces the same trajectory from `score`
//! calls + host math and exposes every ablation knob of Tables 4–5.

use super::{fill_noise, t_vec, Ctx, SolveResult};
use crate::rng::Rng;
use crate::tensor::Tensor;
use crate::Result;

/// Error-norm choice (§3.1.3 ablation).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ErrNorm {
    /// Paper default: scaled l2, sqrt(mean(r^2)).
    L2,
    /// Ablation: l-infinity, max |r| (Table 4/5 `q = inf` rows).
    LInf,
}

#[derive(Clone, Copy, Debug)]
pub struct AdaptiveOpts {
    pub eps_rel: f64,
    /// None => paper default (y_max - y_min)/256 from the process range.
    pub eps_abs: Option<f64>,
    /// Controller exponent r (paper default 0.9).
    pub r: f64,
    /// Safety factor theta (paper default 0.9).
    pub safety: f64,
    pub h_init: f64,
    /// Accept x'' (extrapolation, paper default) or x' (plain EM pair).
    pub extrapolate: bool,
    /// delta uses max(|x'|, |x'_prev|) (Eq. 5, default) vs only |x'| (Eq. 4).
    pub prev_in_delta: bool,
    pub norm: ErrNorm,
    /// Hard cap on solver iterations (divergence guard).
    pub max_iters: u64,
}

impl Default for AdaptiveOpts {
    fn default() -> Self {
        AdaptiveOpts {
            eps_rel: 0.05,
            eps_abs: None,
            r: 0.9,
            safety: 0.9,
            h_init: 0.01,
            extrapolate: true,
            prev_in_delta: true,
            norm: ErrNorm::L2,
            max_iters: 100_000,
        }
    }
}

impl AdaptiveOpts {
    pub fn with_eps_rel(eps_rel: f64) -> Self {
        AdaptiveOpts { eps_rel, ..Default::default() }
    }

    fn resolve_eps_abs(&self, process: &crate::sde::Process) -> f64 {
        self.eps_abs.unwrap_or_else(|| process.eps_abs())
    }
}

/// Per-batch adaptive state (also used by the serving coordinator, which
/// backfills converged slots instead of waiting).
pub struct AdaptiveState {
    pub x: Tensor,
    pub xprev: Tensor,
    pub t: Vec<f64>,
    pub h: Vec<f64>,
    pub active: Vec<bool>,
    pub nfe: Vec<u64>,
    pub rejections: u64,
    pub steps: u64,
}

impl AdaptiveState {
    pub fn new(x: Tensor, h_init: f64, t_start: f64) -> AdaptiveState {
        let b = x.shape[0];
        AdaptiveState {
            xprev: x.clone(),
            x,
            t: vec![t_start; b],
            h: vec![h_init; b],
            active: vec![true; b],
            nfe: vec![0; b],
            rejections: 0,
            steps: 0,
        }
    }

    pub fn all_done(&self) -> bool {
        self.active.iter().all(|a| !a)
    }
}

/// One fused Algorithm-1 iteration over the whole batch. Inactive slots
/// ride along with h = 0 (the kernels make h=0 an exact no-op).
pub fn fused_iteration(
    ctx: &Ctx,
    st: &mut AdaptiveState,
    rng: &mut Rng,
    opts: &AdaptiveOpts,
) -> Result<()> {
    let b = ctx.bucket;
    let t_eps = ctx.process.t_eps();
    let eps_abs = opts.resolve_eps_abs(&ctx.process);
    // clamp h to remaining time; zero for inactive slots
    let mut h_eff = vec![0f32; b];
    for i in 0..b {
        if st.active[i] {
            st.h[i] = st.h[i].min(st.t[i] - t_eps).max(0.0);
            h_eff[i] = st.h[i] as f32;
        }
    }
    let mut z = Tensor::zeros(&[b, ctx.dim()]);
    fill_noise(rng, &mut z);
    let t_in = Tensor { shape: vec![b], data: st.t.iter().map(|&v| v as f32).collect() };
    let h_in = Tensor { shape: vec![b], data: h_eff };
    let ea = Tensor::scalar(eps_abs as f32);
    let er = Tensor { shape: vec![b], data: vec![opts.eps_rel as f32; b] };
    let out = ctx.model.exec(
        "adaptive_step",
        ctx.bucket,
        &[&st.x, &st.xprev, &t_in, &h_in, &z, &ea, &er],
        ctx.opts.fused_buffers,
    )?;
    let (xpp, xp, e2) = (&out[0], &out[1], &out[2]);
    st.steps += 1;
    for i in 0..b {
        if !st.active[i] {
            continue;
        }
        st.nfe[i] += 2;
        let e = e2.data[i] as f64;
        if e <= 1.0 {
            // accept: extrapolated proposal, advance time, roll x'_prev
            st.x.row_mut(i).copy_from_slice(xpp.row(i));
            st.xprev.row_mut(i).copy_from_slice(xp.row(i));
            st.t[i] -= st.h[i];
            if st.t[i] <= t_eps + 1e-12 {
                st.active[i] = false;
                continue;
            }
        } else {
            st.rejections += 1;
        }
        // controller update either way (paper §3.1.4)
        let grow = opts.safety * e.max(1e-12).powf(-opts.r);
        st.h[i] = (st.h[i] * grow).min(st.t[i] - t_eps);
    }
    Ok(())
}

/// Full Algorithm 1 via the fused step artifact.
pub fn run_fused(ctx: &Ctx, rng: &mut Rng, opts: &AdaptiveOpts) -> Result<SolveResult> {
    let x0 = ctx.sample_prior(rng);
    let mut st = AdaptiveState::new(x0, opts.h_init, 1.0);
    while !st.all_done() {
        if st.steps >= opts.max_iters {
            crate::bail!("adaptive solver exceeded {} iterations", opts.max_iters);
        }
        fused_iteration(ctx, &mut st, rng, opts)?;
    }
    finishup(ctx, st)
}

/// Algorithm 1 with host-side integrators over raw `score` calls.
/// Exposes the Table 4/5 ablation knobs the fused graph bakes in.
pub fn run_composed(ctx: &Ctx, rng: &mut Rng, opts: &AdaptiveOpts) -> Result<SolveResult> {
    let b = ctx.bucket;
    let d = ctx.dim();
    let t_eps = ctx.process.t_eps();
    let eps_abs = opts.resolve_eps_abs(&ctx.process) as f32;
    let x0 = ctx.sample_prior(rng);
    let mut st = AdaptiveState::new(x0, opts.h_init, 1.0);
    let mut z = Tensor::zeros(&[b, d]);
    let mut xp = Tensor::zeros(&[b, d]);
    let mut xt = Tensor::zeros(&[b, d]);

    while !st.all_done() {
        if st.steps >= opts.max_iters {
            crate::bail!("adaptive solver exceeded {} iterations", opts.max_iters);
        }
        st.steps += 1;
        for i in 0..b {
            if st.active[i] {
                st.h[i] = st.h[i].min(st.t[i] - t_eps).max(0.0);
            }
        }
        fill_noise(rng, &mut z);
        let t_in = Tensor { shape: vec![b], data: st.t.iter().map(|&v| v as f32).collect() };
        // stage 1: EM proposal x' = x - h*drift(x,t) + sqrt(h) g(t) z
        let d1 = ctx.rdp_drift(&st.x, &t_in)?;
        for i in 0..b {
            let h = if st.active[i] { st.h[i] } else { 0.0 };
            let g = ctx.process.diffusion(st.t[i]) as f32;
            let (sh, sg) = ((-h) as f32, (h.sqrt()) as f32 * g);
            let (xr, dr, zr, or) = (st.x.row(i), d1.row(i), z.row(i), xp.row_mut(i));
            for j in 0..d {
                or[j] = xr[j] + sh * dr[j] + sg * zr[j];
            }
        }
        // stage 2: improved-Euler companion at t - h with the same z
        let t2 = Tensor {
            shape: vec![b],
            data: (0..b)
                .map(|i| (st.t[i] - if st.active[i] { st.h[i] } else { 0.0 }) as f32)
                .collect(),
        };
        let d2 = ctx.rdp_drift(&xp, &t2)?;
        for i in 0..b {
            let h = if st.active[i] { st.h[i] } else { 0.0 };
            let g2 = ctx.process.diffusion(t2.data[i] as f64) as f32;
            let (sh, sg) = ((-h) as f32, (h.sqrt()) as f32 * g2);
            let (xr, dr, zr, or) = (st.x.row(i), d2.row(i), z.row(i), xt.row_mut(i));
            for j in 0..d {
                or[j] = xr[j] + sh * dr[j] + sg * zr[j];
            }
        }
        // accept/reject per sample
        for i in 0..b {
            if !st.active[i] {
                continue;
            }
            st.nfe[i] += 2;
            let (xpr, xtr, xr0, xprevr) =
                (xp.row(i), xt.row(i), st.x.row(i), st.xprev.row(i));
            // error between x' and x'' where x'' = (x' + x~)/2 => x' - x'' = (x' - x~)/2
            let mut acc = 0f64;
            let mut maxv = 0f64;
            for j in 0..d {
                let xpp_j = 0.5 * (xpr[j] + xtr[j]);
                let base = if opts.prev_in_delta {
                    xpr[j].abs().max(xprevr[j].abs())
                } else {
                    xpr[j].abs()
                };
                let delta = eps_abs.max(opts.eps_rel as f32 * base);
                let rj = ((xpr[j] - xpp_j) / delta) as f64;
                acc += rj * rj;
                maxv = maxv.max(rj.abs());
            }
            let e = match opts.norm {
                ErrNorm::L2 => (acc / d as f64).sqrt(),
                ErrNorm::LInf => maxv,
            };
            let _ = xr0;
            if e <= 1.0 {
                let chosen_is_xpp = opts.extrapolate;
                let (xrow, xprow) = (st.x.row_mut(i), st.xprev.row_mut(i));
                for j in 0..d {
                    let xpp_j = 0.5 * (xp.row(i)[j] + xt.row(i)[j]);
                    xrow[j] = if chosen_is_xpp { xpp_j } else { xp.row(i)[j] };
                    xprow[j] = xp.row(i)[j];
                }
                st.t[i] -= st.h[i];
                if st.t[i] <= t_eps + 1e-12 {
                    st.active[i] = false;
                    continue;
                }
            } else {
                st.rejections += 1;
            }
            let grow = opts.safety * e.max(1e-12).powf(-opts.r);
            st.h[i] = (st.h[i] * grow).min(st.t[i] - t_eps);
        }
    }
    finishup(ctx, st)
}

/// Algorithm 1 with *per-lane* RNG streams matching the serving engine's
/// lane semantics exactly: lane `i` owns `Rng::new(seed).fork(base + i)`,
/// draws its prior and every step's noise from that stream, and carries
/// `(t, h)` through the same clamp/controller arithmetic as
/// `coordinator::engine`'s step loop. Because no lane's update reads
/// another lane's state (§3.1.5), a sample's trajectory here is
/// bit-identical to the one the engine produces for the same
/// `(seed, base + i, eps_rel)` — regardless of pool width, migration, or
/// co-batched traffic. This is the `--offline` evaluation bypass the
/// engine-vs-offline agreement check is defined against.
///
/// `count` lanes (<= `ctx.bucket`) run batched at `ctx.bucket`; returns
/// `count` rows. Controller parameters come from `opts` (engine defaults:
/// `h_init` 0.01, `r` 0.9, `safety` 0.9).
pub fn run_lanes(
    ctx: &Ctx,
    seed: u64,
    base: u64,
    count: usize,
    opts: &AdaptiveOpts,
) -> Result<SolveResult> {
    let b = ctx.bucket;
    if count > b {
        crate::bail!("count {count} exceeds bucket {b}");
    }
    let d = ctx.dim();
    let t_eps = ctx.process.t_eps();
    let eps_abs = opts.resolve_eps_abs(&ctx.process);
    let prior_std = ctx.process.prior_std() as f32;

    let mut rngs: Vec<Rng> = (0..count).map(|i| Rng::new(seed).fork(base + i as u64)).collect();
    let mut x = Tensor::zeros(&[b, d]);
    for (i, rng) in rngs.iter_mut().enumerate() {
        for v in x.row_mut(i).iter_mut() {
            *v = rng.normal() as f32 * prior_std;
        }
    }
    let mut st = AdaptiveState::new(x, opts.h_init, 1.0);
    for i in count..b {
        st.active[i] = false;
    }
    let mut z = Tensor::zeros(&[b, d]);
    while !st.all_done() {
        if st.steps >= opts.max_iters {
            crate::bail!("adaptive solver exceeded {} iterations", opts.max_iters);
        }
        let mut t_in = vec![1.0f32; b];
        let mut h_in = vec![0.0f32; b];
        for i in 0..count {
            if st.active[i] {
                st.h[i] = st.h[i].min(st.t[i] - t_eps).max(0.0);
                t_in[i] = st.t[i] as f32;
                h_in[i] = st.h[i] as f32;
                rngs[i].fill_normal(z.row_mut(i));
            }
        }
        let t_t = Tensor { shape: vec![b], data: t_in };
        let h_t = Tensor { shape: vec![b], data: h_in };
        let ea = Tensor::scalar(eps_abs as f32);
        let er = Tensor { shape: vec![b], data: vec![opts.eps_rel as f32; b] };
        let out = ctx.model.exec(
            "adaptive_step",
            b,
            &[&st.x, &st.xprev, &t_t, &h_t, &z, &ea, &er],
            ctx.opts.fused_buffers,
        )?;
        let (xpp, xp, e2) = (&out[0], &out[1], &out[2]);
        st.steps += 1;
        for i in 0..count {
            if !st.active[i] {
                continue;
            }
            st.nfe[i] += 2;
            let e = e2.data[i] as f64;
            if e <= 1.0 {
                st.x.row_mut(i).copy_from_slice(xpp.row(i));
                st.xprev.row_mut(i).copy_from_slice(xp.row(i));
                st.t[i] -= st.h[i];
                if st.t[i] <= t_eps + 1e-12 {
                    st.active[i] = false;
                }
            } else {
                st.rejections += 1;
            }
            // engine controller form: h clamp floors at 0 so converged
            // lanes park rather than going negative
            let grow = opts.safety * e.max(1e-12).powf(-opts.r);
            st.h[i] = (st.h[i] * grow).min((st.t[i] - t_eps).max(0.0));
        }
    }
    let mut res = finishup(ctx, st)?;
    // trim the padding lanes off the result
    res.x = Tensor::from_vec(&[count, d], res.x.data[..count * d].to_vec())?;
    res.nfe_per_sample.truncate(count);
    Ok(res)
}

fn finishup(ctx: &Ctx, mut st: AdaptiveState) -> Result<SolveResult> {
    if ctx.opts.denoise {
        let t_end = t_vec(ctx.bucket, ctx.process.t_eps());
        st.x = ctx.denoise(&st.x, &t_end)?;
        for n in st.nfe.iter_mut() {
            *n += 1;
        }
    }
    Ok(SolveResult {
        x: st.x,
        nfe_per_sample: st.nfe,
        steps: st.steps,
        rejections: st.rejections,
    })
}
