//! Probability-flow ODE solved with adaptive Dormand–Prince RK45
//! (paper §4.2's "Probability Flow" comparator; Song et al. used
//! scipy.integrate.RK45 on the flattened batch — we match that lockstep
//! batch-wide step size).
//!
//! dx/dt = f(x,t) - 1/2 g(t)^2 s(x,t), integrated from t=1 to t_eps.
//! 6 fresh drift evaluations per attempted step (FSAL reuses the 7th).

use super::{t_vec, Ctx, SolveResult};
use crate::rng::Rng;
use crate::tensor::Tensor;
use crate::Result;

// Dormand–Prince 5(4) tableau.
const A: [[f64; 6]; 6] = [
    [1.0 / 5.0, 0.0, 0.0, 0.0, 0.0, 0.0],
    [3.0 / 40.0, 9.0 / 40.0, 0.0, 0.0, 0.0, 0.0],
    [44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0, 0.0, 0.0, 0.0],
    [19372.0 / 6561.0, -25360.0 / 2187.0, 64448.0 / 6561.0, -212.0 / 729.0, 0.0, 0.0],
    [9017.0 / 3168.0, -355.0 / 33.0, 46732.0 / 5247.0, 49.0 / 176.0, -5103.0 / 18656.0, 0.0],
    [35.0 / 384.0, 0.0, 500.0 / 1113.0, 125.0 / 192.0, -2187.0 / 6784.0, 11.0 / 84.0],
];
const C: [f64; 6] = [1.0 / 5.0, 3.0 / 10.0, 4.0 / 5.0, 8.0 / 9.0, 1.0, 1.0];
// 5th-order weights == A[5]; 4th-order embedded weights:
const B4: [f64; 7] = [
    5179.0 / 57600.0,
    0.0,
    7571.0 / 16695.0,
    393.0 / 640.0,
    -92097.0 / 339200.0,
    187.0 / 2100.0,
    1.0 / 40.0,
];

#[derive(Clone, Copy, Debug)]
pub struct OdeOpts {
    pub rtol: f64,
    pub atol: f64,
    pub max_iters: u64,
}

impl Default for OdeOpts {
    fn default() -> Self {
        OdeOpts { rtol: 1e-4, atol: 1e-4, max_iters: 20_000 }
    }
}

fn ode_drift(ctx: &Ctx, x: &Tensor, t: f64) -> Result<Tensor> {
    let t_in = t_vec(ctx.bucket, t);
    let mut out =
        ctx.model.exec("ode_drift", ctx.bucket, &[x, &t_in], ctx.opts.fused_buffers)?;
    Ok(out.pop().unwrap())
}

pub fn run(ctx: &Ctx, rng: &mut Rng, opts: &OdeOpts) -> Result<SolveResult> {
    let b = ctx.bucket;
    let d = ctx.dim();
    let n = (b * d) as f64;
    let t_eps = ctx.process.t_eps();
    let mut x = ctx.sample_prior(rng);
    let mut t = 1.0f64;
    // integrate backwards: dt < 0
    let mut h = -0.01f64;
    let mut nfe_count = 0u64;
    let mut steps = 0u64;
    let mut rejections = 0u64;
    let mut k: Vec<Tensor> = Vec::with_capacity(7);
    k.push(ode_drift(ctx, &x, t)?); // FSAL slot k0
    nfe_count += 1;

    while t > t_eps + 1e-12 {
        if steps >= opts.max_iters {
            crate::bail!("RK45 exceeded {} iterations", opts.max_iters);
        }
        steps += 1;
        if t + h < t_eps {
            h = t_eps - t;
        }
        // stages 1..6
        k.truncate(1);
        for s in 0..6 {
            let mut xs = x.clone();
            for (j, kj) in k.iter().enumerate() {
                let a = A[s][j];
                if a != 0.0 {
                    xs.axpy((a * h) as f32, kj);
                }
            }
            k.push(ode_drift(ctx, &xs, t + C[s] * h)?);
            nfe_count += 1;
        }
        // 5th-order solution y5 = x + h * sum(A[5][j] k_j) ... A[5] has 6 weights + k6 weight 0
        let mut y5 = x.clone();
        for (j, kj) in k.iter().take(6).enumerate() {
            let w = A[5][j];
            if w != 0.0 {
                y5.axpy((w * h) as f32, kj);
            }
        }
        // error = y5 - y4 = h * sum((b5 - b4)_j k_j)
        let mut err_sq = 0f64;
        {
            let b5: [f64; 7] = [A[5][0], A[5][1], A[5][2], A[5][3], A[5][4], A[5][5], 0.0];
            // scaled rms error
            let mut err_vec = vec![0f64; 1]; // accumulate on the fly instead
            let _ = &mut err_vec;
            for idx in 0..(b * d) {
                let mut e = 0f64;
                for (j, kj) in k.iter().enumerate() {
                    e += (b5[j] - B4[j]) * kj.data[idx] as f64;
                }
                e *= h;
                let sc = opts.atol
                    + opts.rtol * (x.data[idx].abs().max(y5.data[idx].abs()) as f64);
                let r = e / sc;
                err_sq += r * r;
            }
        }
        let err = (err_sq / n).sqrt();
        if err <= 1.0 {
            t += h;
            x = y5;
            let k_last = k.pop().unwrap();
            k.clear();
            k.push(k_last); // FSAL
        } else {
            rejections += 1;
            k.truncate(1);
        }
        // standard PI-free controller
        let factor = (0.9 * err.max(1e-12).powf(-0.2)).clamp(0.2, 5.0);
        h *= factor;
        if h.abs() < 1e-9 {
            h = -1e-9_f64.max(t_eps - t);
        }
    }
    let mut nfe = vec![nfe_count; b];
    if ctx.opts.denoise {
        x = ctx.denoise(&x, &t_vec(b, t_eps))?;
        nfe.iter_mut().for_each(|v| *v += 1);
    }
    Ok(SolveResult { x, nfe_per_sample: nfe, steps, rejections })
}
