//! Lamba (2003) adaptive timestepping — the only off-the-shelf adaptive
//! scheme the paper found competitive (App. A), and the basis of the
//! "Lamba integration" ablation rows in Tables 4–5.
//!
//! Error control uses the *deterministic* improved-Euler pair on the
//! drift only: k1 = F(x,t), k2 = F(x', t-h), err = h/2 |k1 - k2|; the
//! proposal is the plain EM step. Because the companion integrator is an
//! ODE method, extrapolating (accepting the improved-Euler mean update)
//! is unsound — the paper shows it diverges (Table 5: FID 169.78) — but
//! we expose it as a knob to reproduce exactly that row.

use super::{fill_noise, t_vec, Ctx, SolveResult};
use crate::rng::Rng;
use crate::solvers::adaptive::ErrNorm;
use crate::tensor::Tensor;
use crate::Result;

#[derive(Clone, Copy, Debug)]
pub struct LambaOpts {
    pub eps_rel: f64,
    pub eps_abs: Option<f64>,
    /// Controller exponent (Lamba's default 0.5).
    pub r: f64,
    pub safety: f64,
    pub h_init: f64,
    /// Norm for the scaled error (Lamba default is inf; paper ablates 2).
    pub norm: ErrNorm,
    /// Accept the improved-Euler mean update instead of EM (unsound).
    pub extrapolate: bool,
    pub max_iters: u64,
}

impl Default for LambaOpts {
    fn default() -> Self {
        LambaOpts {
            eps_rel: 0.05,
            eps_abs: None,
            r: 0.5,
            safety: 0.9,
            h_init: 0.01,
            norm: ErrNorm::LInf,
            extrapolate: false,
            max_iters: 100_000,
        }
    }
}

pub fn run(ctx: &Ctx, rng: &mut Rng, opts: &LambaOpts) -> Result<SolveResult> {
    let b = ctx.bucket;
    let d = ctx.dim();
    let t_eps = ctx.process.t_eps();
    let eps_abs = opts.eps_abs.unwrap_or_else(|| ctx.process.eps_abs()) as f32;
    let mut x = ctx.sample_prior(rng);
    let mut t = vec![1.0f64; b];
    let mut h = vec![opts.h_init; b];
    let mut active = vec![true; b];
    let mut nfe = vec![0u64; b];
    let (mut steps, mut rejections) = (0u64, 0u64);
    let mut z = Tensor::zeros(&[b, d]);
    let mut xp = Tensor::zeros(&[b, d]);

    while active.iter().any(|&a| a) {
        if steps >= opts.max_iters {
            crate::bail!("lamba solver exceeded {} iterations", opts.max_iters);
        }
        steps += 1;
        for i in 0..b {
            if active[i] {
                h[i] = h[i].min(t[i] - t_eps).max(0.0);
            }
        }
        fill_noise(rng, &mut z);
        let t_in = Tensor { shape: vec![b], data: t.iter().map(|&v| v as f32).collect() };
        let k1 = ctx.rdp_drift(&x, &t_in)?;
        // EM proposal
        for i in 0..b {
            let hi = if active[i] { h[i] } else { 0.0 };
            let g = ctx.process.diffusion(t[i]) as f32;
            let (a, c) = ((-hi) as f32, (hi.sqrt()) as f32 * g);
            let (xr, kr, zr, or) = (x.row(i), k1.row(i), z.row(i), xp.row_mut(i));
            for j in 0..d {
                or[j] = xr[j] + a * kr[j] + c * zr[j];
            }
        }
        let t2 = Tensor {
            shape: vec![b],
            data: (0..b)
                .map(|i| (t[i] - if active[i] { h[i] } else { 0.0 }) as f32)
                .collect(),
        };
        let k2 = ctx.rdp_drift(&xp, &t2)?;
        for i in 0..b {
            if !active[i] {
                continue;
            }
            nfe[i] += 2;
            let hi = h[i] as f32;
            let (k1r, k2r, xpr, xr) = (k1.row(i), k2.row(i), xp.row(i), x.row(i));
            let mut acc = 0f64;
            let mut maxv = 0f64;
            for j in 0..d {
                let err = 0.5 * hi * (k1r[j] - k2r[j]);
                let delta = eps_abs.max(opts.eps_rel as f32 * xr[j].abs());
                let r = (err / delta) as f64;
                acc += r * r;
                maxv = maxv.max(r.abs());
            }
            let e = match opts.norm {
                ErrNorm::L2 => (acc / d as f64).sqrt(),
                ErrNorm::LInf => maxv,
            };
            if e <= 1.0 {
                let hi64 = h[i];
                let g = ctx.process.diffusion(t[i]) as f32;
                let xrow = x.row_mut(i);
                if opts.extrapolate {
                    // deterministic improved-Euler mean + EM noise (unsound)
                    let c = (hi64.sqrt()) as f32 * g;
                    for j in 0..d {
                        xrow[j] += -hi * 0.5 * (k1r[j] + k2r[j]) + c * z.row(i)[j];
                    }
                } else {
                    xrow.copy_from_slice(xpr);
                }
                t[i] -= hi64;
                if t[i] <= t_eps + 1e-12 {
                    active[i] = false;
                    continue;
                }
            } else {
                rejections += 1;
            }
            let grow = opts.safety * e.max(1e-12).powf(-opts.r);
            h[i] = (h[i] * grow).min(t[i] - t_eps);
        }
    }
    if ctx.opts.denoise {
        x = ctx.denoise(&x, &t_vec(b, t_eps))?;
        nfe.iter_mut().for_each(|n| *n += 1);
    }
    Ok(SolveResult { x, nfe_per_sample: nfe, steps, rejections })
}
