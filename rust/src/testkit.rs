//! Seeded property-testing substrate (no proptest reachable offline).
//!
//! `check(name, cases, |g| ...)` runs a closure over `cases` deterministic
//! generators; a failing case reports its seed so
//! `GOFAST_PROP_SEED=<seed> cargo test <name>` reproduces it exactly.
//! No shrinking — generators are written to produce small cases often
//! (sizes are sampled log-uniformly starting at the minimum).

use crate::rng::Rng;

pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    /// Size sampled log-uniformly in [lo, hi] — biases toward small cases.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo >= 1 && hi >= lo);
        let lol = (lo as f64).ln();
        let hil = (hi as f64 + 1.0).ln();
        (self.rng.uniform_range(lol, hil).exp() as usize).clamp(lo, hi)
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_range(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 0
    }

    pub fn pick<'a, T>(&mut self, opts: &'a [T]) -> &'a T {
        &opts[self.rng.below(opts.len())]
    }

    pub fn vec_normal(&mut self, n: usize, scale: f64) -> Vec<f32> {
        (0..n).map(|_| (self.rng.normal() * scale) as f32).collect()
    }
}

/// Run `f` for `cases` generated cases; panics with the failing seed.
pub fn check<F: FnMut(&mut Gen) -> Result<(), String>>(name: &str, cases: u64, mut f: F) {
    // explicit reproduction path
    if let Ok(seed_s) = std::env::var("GOFAST_PROP_SEED") {
        let seed: u64 = seed_s.parse().expect("GOFAST_PROP_SEED must be u64");
        let mut g = Gen { rng: Rng::new(seed), seed };
        if let Err(msg) = f(&mut g) {
            panic!("[{name}] seed {seed}: {msg}");
        }
        return;
    }
    for case in 0..cases {
        let seed = 0x5EED_0000 + case;
        let mut g = Gen { rng: Rng::new(seed), seed };
        if let Err(msg) = f(&mut g) {
            panic!(
                "[{name}] case {case} failed (reproduce with GOFAST_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_respect_bounds() {
        check("sizes", 200, |g| {
            let s = g.size(1, 64);
            prop_assert!((1..=64).contains(&s), "size {s} out of bounds");
            Ok(())
        });
    }

    #[test]
    fn sizes_bias_small() {
        let mut g = Gen { rng: Rng::new(1), seed: 1 };
        let small = (0..1000).filter(|_| g.size(1, 1000) <= 100).count();
        assert!(small > 500, "log-uniform should favour small sizes, got {small}");
    }

    #[test]
    #[should_panic(expected = "GOFAST_PROP_SEED=")]
    fn failure_reports_seed() {
        check("always_fails", 5, |_| Err("nope".to_string()));
    }
}
