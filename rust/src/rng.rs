//! Deterministic RNG substrate (no `rand` crate reachable offline).
//!
//! xoshiro256++ with splitmix64 seeding; Gaussian variates via Box–Muller
//! with a cached spare. Streams are cheap to fork per sample/request so
//! every slot in a continuous batch owns an independent, reproducible
//! noise sequence — a requirement for the per-sample step-size solver
//! (rejected steps must be able to *retain* their noise, paper App. C).

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: core::array::from_fn(|_| splitmix64(&mut sm)), spare: None }
    }

    /// Independent child stream (used to give each batch slot its own RNG).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n.max(1) as u64) as usize
    }

    /// Standard normal (Box–Muller, cached spare).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Rademacher +-1 (Algorithm 2's Itō correction draw).
    pub fn sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// Exponential with rate `lambda` (Poisson arrival gaps in workload gen).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.uniform_range(f64::EPSILON, 1.0).ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_diverge() {
        let mut a = Rng::new(42);
        let mut c1 = a.fork(1);
        let mut c2 = a.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn sign_is_balanced() {
        let mut r = Rng::new(9);
        let pos = (0..10_000).filter(|_| r.sign() > 0.0).count();
        assert!((4500..5500).contains(&pos), "{pos}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let lambda = 4.0;
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "{mean}");
    }
}
