//! Minimal JSON substrate (no serde reachable offline): parser + writer +
//! ergonomic accessors. Used for the artifact manifest, model metadata,
//! the TCP wire protocol, and bench-result dumps. Objects preserve
//! insertion order (Vec of pairs); numbers are f64.

use crate::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    // --- accessors ----------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn members(&self) -> &[(String, Value)] {
        match self {
            Value::Obj(pairs) => pairs,
            _ => &[],
        }
    }

    // --- builders ------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }

    pub fn set(&mut self, key: &str, v: Value) {
        if let Value::Obj(pairs) = self {
            if let Some(slot) = pairs.iter_mut().find(|(k, _)| k == key) {
                slot.1 = v;
            } else {
                pairs.push((key.to_string(), v));
            }
        }
    }
}

// --- writer ---------------------------------------------------------------------

impl std::fmt::Display for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Obj(pairs) => {
                write!(f, "{{")?;
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut std::fmt::Formatter<'_>, s: &str) -> std::fmt::Result {
    write!(f, "\"")?;
    for ch in s.chars() {
        match ch {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

// --- parser ---------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing characters at offset {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.ws();
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at offset {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse().map_err(|_| anyhow!("bad number '{s}'"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| anyhow!("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| anyhow!("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i..self.i + 4).ok_or_else(|| anyhow!("bad \\u"))?,
                            )?;
                            self.i += 4;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // push raw byte, re-validating utf8 at the end of the run
                    let start = self.i - 1;
                    while self.i < self.b.len() && self.b[self.i] != b'"' && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                c => bail!("expected ',' or ']' got '{}'", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.expect(b':')?;
            out.push((key, self.value()?));
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                c => bail!("expected ',' or '}}' got '{}'", c as char),
            }
        }
    }
}

pub fn parse_file(path: &std::path::Path) -> Result<Value> {
    parse(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2.5,-3],"b":{"c":"hi\nthere","d":true,"e":null}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "hi\nthere");
    }

    #[test]
    fn parses_python_json_dump() {
        let src = "{\n \"name\": \"vp\",\n \"n_params\": 1215744,\n \"final_loss\": 0.123\n}";
        let v = parse(src).unwrap();
        assert_eq!(v.req("n_params").unwrap().as_usize().unwrap(), 1215744);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1} x").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Abc""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Abc");
    }

    #[test]
    fn set_overwrites_and_appends() {
        let mut v = Value::obj(vec![("a", Value::num(1.0))]);
        v.set("a", Value::num(2.0));
        v.set("b", Value::str("x"));
        assert_eq!(v.get("a").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn integer_formatting_is_stable() {
        assert_eq!(Value::num(42.0).to_string(), "42");
        assert_eq!(Value::num(0.5).to_string(), "0.5");
    }
}
