//! Host tensor substrate: a dense f32 array with shape, plus the image
//! utilities the examples/benches need (PPM grids). Device tensors live
//! in `runtime`; this type is what solvers and metrics manipulate on the
//! host side of the hot loop, so the mutating ops are allocation-free.

use crate::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        if shape.iter().product::<usize>() != data.len() {
            bail!("shape {:?} does not match len {}", shape, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![1], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Rows of a [B, D] tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let d = *self.shape.last().unwrap();
        &self.data[i * d..(i + 1) * d]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let d = *self.shape.last().unwrap();
        &mut self.data[i * d..(i + 1) * d]
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    // --- elementwise (allocation-free, used on solver host paths) ----------

    pub fn axpy(&mut self, a: f32, x: &Tensor) {
        debug_assert_eq!(self.shape, x.shape);
        for (s, xv) in self.data.iter_mut().zip(&x.data) {
            *s += a * xv;
        }
    }

    pub fn scale(&mut self, a: f32) {
        self.data.iter_mut().for_each(|x| *x *= a);
    }

    pub fn copy_from(&mut self, other: &Tensor) {
        debug_assert_eq!(self.shape, other.shape);
        self.data.copy_from_slice(&other.data);
    }

    pub fn clamp(&mut self, lo: f32, hi: f32) {
        self.data.iter_mut().for_each(|x| *x = x.clamp(lo, hi));
    }

    pub fn l2_norm(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Save a batch of flattened HWC images ([n, h*w*3], values in [0,1]) as a
/// binary PPM grid — viewable anywhere, zero dependencies.
pub fn save_image_grid(
    path: &std::path::Path,
    images: &Tensor,
    h: usize,
    w: usize,
    cols: usize,
) -> Result<()> {
    let n = images.shape[0];
    let rows = n.div_ceil(cols);
    let (gh, gw) = (rows * h + (rows - 1), cols * w + (cols - 1));
    let mut canvas = vec![32u8; gh * gw * 3]; // dark separator lines
    for i in 0..n {
        let (r, c) = (i / cols, i % cols);
        let (oy, ox) = (r * (h + 1), c * (w + 1));
        let img = images.row(i);
        for y in 0..h {
            for x in 0..w {
                for ch in 0..3 {
                    let v = (img[(y * w + x) * 3 + ch].clamp(0.0, 1.0) * 255.0) as u8;
                    canvas[((oy + y) * gw + ox + x) * 3 + ch] = v;
                }
            }
        }
    }
    let mut out = format!("P6\n{gw} {gh}\n255\n").into_bytes();
    out.extend_from_slice(&canvas);
    std::fs::write(path, out)?;
    Ok(())
}

/// Read a raw little-endian f32 file into a tensor of the given shape.
pub fn read_f32_file(path: &std::path::Path, shape: &[usize]) -> Result<Tensor> {
    let bytes = std::fs::read(path)?;
    let want: usize = shape.iter().product();
    if bytes.len() != want * 4 {
        bail!("{path:?}: expected {} f32s, file has {} bytes", want, bytes.len());
    }
    let data = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Tensor { shape: shape.to_vec(), data })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_validates_shape() {
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn rows_are_views() {
        let mut t = Tensor::zeros(&[3, 4]);
        t.row_mut(1).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.row(1), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.row(0), &[0.0; 4]);
    }

    #[test]
    fn axpy_matches_manual() {
        let mut a = Tensor::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::from_vec(&[4], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        a.axpy(0.5, &b);
        assert_eq!(a.data, vec![1.5, 2.5, 3.5, 4.5]);
    }

    #[test]
    fn image_grid_roundtrip() {
        let dir = std::env::temp_dir().join("gofast_test_grid.ppm");
        let imgs = Tensor::from_vec(&[2, 2 * 2 * 3], vec![0.5; 24]).unwrap();
        save_image_grid(&dir, &imgs, 2, 2, 2).unwrap();
        let bytes = std::fs::read(&dir).unwrap();
        assert!(bytes.starts_with(b"P6\n5 2\n255\n"));
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn f32_file_roundtrip() {
        let path = std::env::temp_dir().join("gofast_test_f32.bin");
        let vals: Vec<f32> = (0..12).map(|i| i as f32 * 0.25).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let t = read_f32_file(&path, &[3, 4]).unwrap();
        assert_eq!(t.data, vals);
        assert!(read_f32_file(&path, &[5, 4]).is_err());
        std::fs::remove_file(path).ok();
    }
}
