//! Small dense linear-algebra substrate for the FID* metric: feature
//! mean/covariance, a cyclic-Jacobi symmetric eigensolver, and the PSD
//! matrix square root. Matrices here are tiny (FEAT_DIM = 64), so clarity
//! beats blocking; everything is row-major `Vec<f64>`.

/// C = A (m x k) * B (k x n), row-major.
pub fn matmul(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

pub fn transpose(a: &[f64], m: usize, n: usize) -> Vec<f64> {
    let mut t = vec![0.0; m * n];
    for i in 0..m {
        for j in 0..n {
            t[j * m + i] = a[i * n + j];
        }
    }
    t
}

pub fn trace(a: &[f64], n: usize) -> f64 {
    (0..n).map(|i| a[i * n + i]).sum()
}

/// Mean vector and (biased) covariance of rows of `x` ([rows x d], f32).
pub fn mean_cov(x: &[f32], rows: usize, d: usize) -> (Vec<f64>, Vec<f64>) {
    assert!(rows > 1, "need >= 2 rows for covariance");
    let mut mu = vec![0.0f64; d];
    for r in 0..rows {
        for j in 0..d {
            mu[j] += x[r * d + j] as f64;
        }
    }
    mu.iter_mut().for_each(|v| *v /= rows as f64);
    let mut cov = vec![0.0f64; d * d];
    for r in 0..rows {
        let row = &x[r * d..(r + 1) * d];
        for i in 0..d {
            let di = row[i] as f64 - mu[i];
            for j in i..d {
                cov[i * d + j] += di * (row[j] as f64 - mu[j]);
            }
        }
    }
    let norm = 1.0 / (rows as f64 - 1.0);
    for i in 0..d {
        for j in i..d {
            let v = cov[i * d + j] * norm;
            cov[i * d + j] = v;
            cov[j * d + i] = v;
        }
    }
    (mu, cov)
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
/// Returns (eigenvalues, eigenvectors as columns of V: A = V diag(l) V^T).
pub fn sym_eigh(a_in: &[f64], n: usize) -> (Vec<f64>, Vec<f64>) {
    let mut a = a_in.to_vec();
    let mut v = vec![0.0; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a[i * n + j] * a[i * n + j];
            }
        }
        if off.sqrt() < 1e-12 * (trace(&a, n).abs().max(1.0)) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of A
                for k in 0..n {
                    let akp = a[k * n + p];
                    let akq = a[k * n + q];
                    a[k * n + p] = c * akp - s * akq;
                    a[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[p * n + k];
                    let aqk = a[q * n + k];
                    a[p * n + k] = c * apk - s * aqk;
                    a[q * n + k] = s * apk + c * aqk;
                }
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let eig = (0..n).map(|i| a[i * n + i]).collect();
    (eig, v)
}

/// PSD matrix square root via eigendecomposition (negative eigenvalues from
/// numerical noise are clamped to 0).
pub fn sqrtm_psd(a: &[f64], n: usize) -> Vec<f64> {
    let (eig, v) = sym_eigh(a, n);
    // V diag(sqrt(max(l,0))) V^T
    let mut vs = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            vs[i * n + j] = v[i * n + j] * eig[j].max(0.0).sqrt();
        }
    }
    matmul(&vs, &transpose(&v, n, n), n, n, n)
}

/// tr(sqrtm(C1 C2)) computed via the symmetric form
/// tr sqrtm(S C2 S) with S = sqrtm(C1) — both factors PSD.
pub fn trace_sqrt_product(c1: &[f64], c2: &[f64], n: usize) -> f64 {
    let s = sqrtm_psd(c1, n);
    let m = matmul(&matmul(&s, c2, n, n, n), &s, n, n, n);
    let (eig, _) = sym_eigh(&m, n);
    eig.iter().map(|&l| l.max(0.0).sqrt()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_psd(n: usize, seed: u64) -> Vec<f64> {
        let mut r = Rng::new(seed);
        let b: Vec<f64> = (0..n * n).map(|_| r.normal()).collect();
        // B B^T + eps I
        let mut a = matmul(&b, &transpose(&b, n, n), n, n, n);
        for i in 0..n {
            a[i * n + i] += 0.1;
        }
        a
    }

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let id = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &id, 2, 2, 2), a);
    }

    #[test]
    fn matmul_known() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let c = matmul(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2, 2, 2);
        assert_eq!(c, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn eigh_reconstructs() {
        let n = 16;
        let a = random_psd(n, 1);
        let (eig, v) = sym_eigh(&a, n);
        // V diag(l) V^T == A
        let mut vd = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                vd[i * n + j] = v[i * n + j] * eig[j];
            }
        }
        let rec = matmul(&vd, &transpose(&v, n, n), n, n, n);
        for (x, y) in rec.iter().zip(&a) {
            assert!((x - y).abs() < 1e-8, "{x} vs {y}");
        }
    }

    #[test]
    fn eigh_orthonormal_vectors() {
        let n = 12;
        let a = random_psd(n, 2);
        let (_, v) = sym_eigh(&a, n);
        let vtv = matmul(&transpose(&v, n, n), &v, n, n, n);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vtv[i * n + j] - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn sqrtm_squares_back() {
        let n = 8;
        let a = random_psd(n, 3);
        let s = sqrtm_psd(&a, n);
        let ss = matmul(&s, &s, n, n, n);
        for (x, y) in ss.iter().zip(&a) {
            assert!((x - y).abs() < 1e-8, "{x} vs {y}");
        }
    }

    #[test]
    fn trace_sqrt_product_of_identical_is_trace() {
        // tr sqrtm(C C) = tr C for PSD C
        let n = 6;
        let c = random_psd(n, 4);
        let t = trace_sqrt_product(&c, &c, n);
        assert!((t - trace(&c, n)).abs() < 1e-8, "{t}");
    }

    #[test]
    fn mean_cov_known_values() {
        // two points (0,0) and (2,2): mean (1,1), cov = [[2,2],[2,2]] (n-1 norm)
        let x = [0.0f32, 0.0, 2.0, 2.0];
        let (mu, cov) = mean_cov(&x, 2, 2);
        assert_eq!(mu, vec![1.0, 1.0]);
        assert_eq!(cov, vec![2.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn mean_cov_diagonal_for_independent() {
        let mut r = Rng::new(5);
        let rows = 20_000;
        let x: Vec<f32> = (0..rows * 2).map(|_| r.normal() as f32).collect();
        let (mu, cov) = mean_cov(&x, rows, 2);
        assert!(mu[0].abs() < 0.05 && mu[1].abs() < 0.05);
        assert!((cov[0] - 1.0).abs() < 0.05);
        assert!(cov[1].abs() < 0.05);
    }
}
