//! Config system: a TOML-subset parser (sections, strings, numbers,
//! bools, flat arrays, comments) feeding typed config structs, with CLI
//! override support (`--set section.key=value`). This is the launcher's
//! configuration layer; see `configs/server.toml` for the shipped default.

use crate::{anyhow, bail, cli::Args, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Item {
    Str(String),
    Num(f64),
    Bool(bool),
    List(Vec<Item>),
}

impl Item {
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Item::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Item::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }
}

/// `section.key -> Item`; keys in the root section have no prefix.
#[derive(Clone, Debug, Default)]
pub struct Config {
    items: BTreeMap<String, Item>,
}

impl Config {
    pub fn parse(src: &str) -> Result<Config> {
        let mut items = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let full = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            items.insert(full, parse_item(val.trim(), lineno + 1)?);
        }
        Ok(Config { items })
    }

    pub fn load(path: &std::path::Path) -> Result<Config> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Apply `--set section.key=value` CLI overrides (repeatable).
    pub fn apply_overrides(&mut self, args: &Args) -> Result<()> {
        for ov in args.get_all("set") {
            let (key, val) = ov
                .split_once('=')
                .ok_or_else(|| anyhow!("--set expects section.key=value, got '{ov}'"))?;
            self.items.insert(key.trim().to_string(), parse_item(val.trim(), 0)?);
        }
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&Item> {
        self.items.get(key)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        self.items.get(key).map(|i| i.as_f64()).transpose().map(|v| v.unwrap_or(default))
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.f64_or(key, default as f64)? as usize)
    }

    pub fn str_or(&self, key: &str, default: &str) -> Result<String> {
        match self.items.get(key) {
            Some(i) => Ok(i.as_str()?.to_string()),
            None => Ok(default.to_string()),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.items.get(key) {
            Some(Item::Bool(b)) => Ok(*b),
            Some(other) => bail!("{key}: expected bool, got {other:?}"),
            None => Ok(default),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.items.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_item(s: &str, lineno: usize) -> Result<Item> {
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Item::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Item::Bool(true));
    }
    if s == "false" {
        return Ok(Item::Bool(false));
    }
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        let parts: Result<Vec<Item>> = inner
            .split(',')
            .filter(|p| !p.trim().is_empty())
            .map(|p| parse_item(p.trim(), lineno))
            .collect();
        return Ok(Item::List(parts?));
    }
    s.parse::<f64>()
        .map(Item::Num)
        .map_err(|_| anyhow!("line {lineno}: cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# server defaults
artifacts = "artifacts"

[server]
port = 7878            # TCP port
max_batch = 64
buckets = [16, 64]
fused = true

[solver]
eps_rel = 0.05
kind = "adaptive"
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("artifacts", "").unwrap(), "artifacts");
        assert_eq!(c.usize_or("server.port", 0).unwrap(), 7878);
        assert!(c.bool_or("server.fused", false).unwrap());
        assert_eq!(c.f64_or("solver.eps_rel", 0.0).unwrap(), 0.05);
        match c.get("server.buckets").unwrap() {
            Item::List(v) => assert_eq!(v.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("server.port", 1234).unwrap(), 1234);
        assert_eq!(c.str_or("solver.kind", "adaptive").unwrap(), "adaptive");
    }

    #[test]
    fn cli_overrides() {
        let mut c = Config::parse(SAMPLE).unwrap();
        let args =
            Args::parse(["--set".to_string(), "server.port=9999".to_string()]).unwrap();
        c.apply_overrides(&args).unwrap();
        assert_eq!(c.usize_or("server.port", 0).unwrap(), 9999);
    }

    #[test]
    fn comment_inside_string_kept() {
        let c = Config::parse("name = \"a#b\"").unwrap();
        assert_eq!(c.str_or("name", "").unwrap(), "a#b");
    }

    #[test]
    fn bad_lines_error() {
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("x = what").is_err());
    }
}
