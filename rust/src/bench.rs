//! Bench harness substrate (no criterion reachable offline): wall-clock
//! timing with warmup, robust summary stats, aligned table printing (the
//! paper-table renderers in `benches/` build on this), and CSV dumps
//! under `bench_out/` (see docs/ARCHITECTURE.md §Benches).

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

pub fn summarize(mut xs: Vec<f64>) -> Stats {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| xs[((xs.len() as f64 - 1.0) * p).round() as usize];
    Stats {
        n: xs.len(),
        mean: xs.iter().sum::<f64>() / xs.len() as f64,
        p50: q(0.50),
        p95: q(0.95),
        p99: q(0.99),
        min: xs[0],
        max: *xs.last().unwrap(),
    }
}

/// Time `f` `iters` times (after `warmup` unrecorded runs); seconds each.
pub fn time_iters<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.1}us", secs * 1e6)
    }
}

/// Fixed-width table printer used by every paper-table bench.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// ASCII scatter/line plot for Figure-1 style outputs.
pub fn ascii_plot(series: &[(&str, Vec<(f64, f64)>)], width: usize, height: usize) -> String {
    let pts: Vec<(f64, f64)> = series.iter().flat_map(|(_, v)| v.iter().copied()).collect();
    if pts.is_empty() {
        return String::new();
    }
    let (xmin, xmax) = pts.iter().fold((f64::MAX, f64::MIN), |(a, b), p| (a.min(p.0), b.max(p.0)));
    let (ymin, ymax) = pts.iter().fold((f64::MAX, f64::MIN), |(a, b), p| (a.min(p.1), b.max(p.1)));
    let (xr, yr) = ((xmax - xmin).max(1e-12), (ymax - ymin).max(1e-12));
    let mut canvas = vec![vec![b' '; width]; height];
    let marks = [b'o', b'x', b'+', b'*', b'#'];
    for (si, (_, v)) in series.iter().enumerate() {
        for &(x, y) in v {
            let cx = (((x - xmin) / xr) * (width - 1) as f64).round() as usize;
            let cy = height - 1 - (((y - ymin) / yr) * (height - 1) as f64).round() as usize;
            canvas[cy][cx] = marks[si % marks.len()];
        }
    }
    let mut out = String::new();
    for (i, row) in canvas.iter().enumerate() {
        let label = if i == 0 {
            format!("{ymax:>9.2} |")
        } else if i == height - 1 {
            format!("{ymin:>9.2} |")
        } else {
            format!("{:>9} |", "")
        };
        out.push_str(&label);
        out.push_str(std::str::from_utf8(row).unwrap());
        out.push('\n');
    }
    out.push_str(&format!(
        "{:>10} {:<10.1}{:>w$.1}\n",
        "",
        xmin,
        xmax,
        w = width.saturating_sub(10)
    ));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} = {}\n", marks[si % marks.len()] as char, name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_on_known_data() {
        let s = summarize((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.n, 100);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert_eq!(s.p50, 51.0); // index (99*0.5).round() = 50 -> value 51
        assert_eq!(s.p95, 95.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "NFE", "FID"]);
        t.row(vec!["euler-maruyama".into(), "1000".into(), "2.55".into()]);
        t.row(vec!["ours".into(), "179".into(), "2.59".into()]);
        let r = t.render();
        assert!(r.contains("euler-maruyama  1000  2.55"));
        let csv = t.to_csv();
        assert!(csv.starts_with("method,NFE,FID\n"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn plot_contains_markers() {
        let p = ascii_plot(
            &[("a", vec![(0.0, 0.0), (1.0, 1.0)]), ("b", vec![(0.5, 0.5)])],
            20,
            5,
        );
        assert!(p.contains('o') && p.contains('x'));
    }

    #[test]
    fn fmt_durations() {
        assert_eq!(fmt_duration(2.5), "2.50s");
        assert_eq!(fmt_duration(0.0025), "2.50ms");
        assert_eq!(fmt_duration(2.5e-6), "2.5us");
    }
}
