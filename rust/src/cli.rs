//! CLI argument substrate (no clap reachable offline). Subcommand +
//! `--flag value` / `--flag=value` / boolean `--flag` parsing with typed
//! getters and a usage-error path the binary surfaces to the user.

use crate::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    pub fn parse_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(item) = it.next() {
            if let Some(stripped) = item.strip_prefix("--") {
                if stripped.is_empty() {
                    bail!("bare '--' is not supported");
                }
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        // value iff next token exists and is not itself a flag
                        match it.peek() {
                            Some(nxt) if !nxt.starts_with("--") => it.next().unwrap(),
                            _ => String::new(), // boolean flag
                        }
                    }
                };
                out.flags.entry(key).or_default().push(val);
            } else {
                out.positional.push(item);
            }
        }
        Ok(out)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All occurrences (for repeatable flags like --variant).
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags.get(key).map(|v| v.iter().map(|s| s.as_str()).collect()).unwrap_or_default()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn req(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required flag --{key}"))
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None | Some("") => Ok(default),
            Some(s) => s.parse().map_err(|_| anyhow!("--{key}: expected integer, got '{s}'")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None | Some("") => Ok(default),
            Some(s) => s.parse().map_err(|_| anyhow!("--{key}: expected number, got '{s}'")),
        }
    }

    /// Boolean flag: missing -> default, bare `--key` -> true, otherwise
    /// an explicit `--key=true/false` (or 1/0).
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("") | Some("true") | Some("1") => Ok(true),
            Some("false") | Some("0") => Ok(false),
            Some(s) => Err(anyhow!("--{key}: expected true/false, got '{s}'")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None | Some("") => Ok(default),
            Some(s) => s.parse().map_err(|_| anyhow!("--{key}: expected integer, got '{s}'")),
        }
    }

    /// Comma-separated list flag: `--eps 0.01,0.05` -> vec![0.01, 0.05].
    pub fn f64_list_or(&self, key: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(key) {
            None | Some("") => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|p| p.trim().parse().map_err(|_| anyhow!("--{key}: bad number '{p}'")))
                .collect(),
        }
    }

    pub fn str_list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            None | Some("") => default.iter().map(|s| s.to_string()).collect(),
            Some(s) => s.split(',').map(|p| p.trim().to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(parts: &[&str]) -> Args {
        Args::parse(parts.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["generate", "--model", "vp", "--n=64", "--fused"]);
        assert_eq!(a.positional, vec!["generate"]);
        assert_eq!(a.get("model"), Some("vp"));
        assert_eq!(a.usize_or("n", 1).unwrap(), 64);
        assert!(a.has("fused"));
        assert_eq!(a.get("fused"), Some(""));
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse(&["--fused", "--model", "ve"]);
        assert!(a.has("fused"));
        assert_eq!(a.get("model"), Some("ve"));
    }

    #[test]
    fn negative_number_values() {
        let a = parse(&["--offset=-1.5"]);
        assert_eq!(a.f64_or("offset", 0.0).unwrap(), -1.5);
    }

    #[test]
    fn lists() {
        let a = parse(&["--eps", "0.01, 0.05,0.1", "--names", "a,b"]);
        assert_eq!(a.f64_list_or("eps", &[]).unwrap(), vec![0.01, 0.05, 0.1]);
        assert_eq!(a.str_list_or("names", &[]), vec!["a", "b"]);
        assert_eq!(a.f64_list_or("missing", &[1.0]).unwrap(), vec![1.0]);
    }

    #[test]
    fn repeated_flags_last_wins_for_get() {
        let a = parse(&["--model", "vp", "--model", "ve"]);
        assert_eq!(a.get("model"), Some("ve"));
        assert_eq!(a.get_all("model"), vec!["vp", "ve"]);
    }

    #[test]
    fn bool_flags() {
        let a = parse(&["--migrate", "--fused=false", "--strict=1"]);
        assert!(a.bool_or("migrate", false).unwrap());
        assert!(!a.bool_or("fused", true).unwrap());
        assert!(a.bool_or("strict", false).unwrap());
        assert!(a.bool_or("missing", true).unwrap());
        assert!(!a.bool_or("missing", false).unwrap());
        let bad = parse(&["--migrate=maybe"]);
        assert!(bad.bool_or("migrate", false).is_err());
    }

    #[test]
    fn errors_are_reported() {
        let a = parse(&["--n", "abc"]);
        assert!(a.usize_or("n", 1).is_err());
        assert!(a.req("missing").is_err());
    }
}
