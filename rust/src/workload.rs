//! Serving workload generation: request traces with Poisson or bursty
//! arrivals over mixed request sizes/tolerances, used by the serving
//! bench and the end-to-end example.

use crate::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct TraceItem {
    /// Arrival offset from trace start, seconds.
    pub at_s: f64,
    pub n: usize,
    pub eps_rel: f64,
    pub seed: u64,
}

#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub duration_s: f64,
    /// Mean request arrival rate (requests/second).
    pub rate_rps: f64,
    /// Request sizes drawn uniformly from this set.
    pub n_choices: Vec<usize>,
    /// Tolerances drawn uniformly from this set (mixed-tolerance batching).
    pub eps_choices: Vec<f64>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            duration_s: 10.0,
            rate_rps: 2.0,
            n_choices: vec![1, 2, 4, 8],
            eps_choices: vec![0.02, 0.05, 0.1],
        }
    }
}

/// Poisson arrivals (exponential gaps).
pub fn poisson_trace(rng: &mut Rng, cfg: &TraceConfig) -> Vec<TraceItem> {
    let mut out = Vec::new();
    let mut t = 0.0;
    let mut k = 0u64;
    loop {
        t += rng.exponential(cfg.rate_rps);
        if t >= cfg.duration_s {
            return out;
        }
        out.push(TraceItem {
            at_s: t,
            n: cfg.n_choices[rng.below(cfg.n_choices.len())],
            eps_rel: cfg.eps_choices[rng.below(cfg.eps_choices.len())],
            seed: 1000 + k,
        });
        k += 1;
    }
}

/// Bursty arrivals: `bursts` clumps of `burst_size` back-to-back requests.
pub fn burst_trace(rng: &mut Rng, cfg: &TraceConfig, bursts: usize, burst_size: usize) -> Vec<TraceItem> {
    let mut out = Vec::new();
    let mut k = 0u64;
    for b in 0..bursts {
        let at = cfg.duration_s * b as f64 / bursts as f64;
        for _ in 0..burst_size {
            out.push(TraceItem {
                at_s: at,
                n: cfg.n_choices[rng.below(cfg.n_choices.len())],
                eps_rel: cfg.eps_choices[rng.below(cfg.eps_choices.len())],
                seed: 5000 + k,
            });
            k += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_respected() {
        let mut rng = Rng::new(1);
        let cfg = TraceConfig { duration_s: 200.0, rate_rps: 3.0, ..Default::default() };
        let trace = poisson_trace(&mut rng, &cfg);
        let rate = trace.len() as f64 / cfg.duration_s;
        assert!((rate - 3.0).abs() < 0.4, "rate {rate}");
        // arrivals sorted, inside the window
        for w in trace.windows(2) {
            assert!(w[1].at_s >= w[0].at_s);
        }
        assert!(trace.iter().all(|i| i.at_s < cfg.duration_s));
    }

    #[test]
    fn trace_draws_from_choice_sets() {
        let mut rng = Rng::new(2);
        let cfg = TraceConfig::default();
        for item in poisson_trace(&mut rng, &cfg) {
            assert!(cfg.n_choices.contains(&item.n));
            assert!(cfg.eps_choices.contains(&item.eps_rel));
        }
    }

    #[test]
    fn burst_trace_shape() {
        let mut rng = Rng::new(3);
        let cfg = TraceConfig::default();
        let t = burst_trace(&mut rng, &cfg, 4, 8);
        assert_eq!(t.len(), 32);
        let unique_seeds: std::collections::HashSet<u64> = t.iter().map(|i| i.seed).collect();
        assert_eq!(unique_seeds.len(), 32);
    }
}
