//! VE/VP process math mirrored from `python/compile/sde.py` (paper
//! §2.2–2.3). The fused step artifacts embed this math in their graphs;
//! the host-side mirror powers the composed solver path (Table 3 suite,
//! ablations), the step-size controller, and the prior sampler.
//!
//! The fixture tests at the bottom pin the exact values also asserted in
//! `python/tests/test_sde.py::test_rust_fixture_values_*` — the two
//! implementations cannot drift silently.

use crate::rng::Rng;
use crate::tensor::Tensor;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Process {
    /// Variance exploding: data range [0,1], sigma(t) geometric.
    Ve { sigma_min: f64, sigma_max: f64 },
    /// Variance preserving: data range [-1,1], beta(t) linear.
    Vp { beta_min: f64, beta_max: f64 },
}

impl Process {
    pub fn ve(sigma_max: f64) -> Process {
        Process::Ve { sigma_min: 0.01, sigma_max }
    }

    pub fn vp() -> Process {
        Process::Vp { beta_min: 0.1, beta_max: 20.0 }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Process::Ve { .. } => "ve",
            Process::Vp { .. } => "vp",
        }
    }

    /// Integration lower limit (paper App. D).
    pub fn t_eps(&self) -> f64 {
        match self {
            Process::Ve { .. } => 1e-5,
            Process::Vp { .. } => 1e-3,
        }
    }

    pub fn data_range(&self) -> (f64, f64) {
        match self {
            Process::Ve { .. } => (0.0, 1.0),
            Process::Vp { .. } => (-1.0, 1.0),
        }
    }

    /// Paper §3.1.2: one 8-bit colour increment.
    pub fn eps_abs(&self) -> f64 {
        let (lo, hi) = self.data_range();
        (hi - lo) / 256.0
    }

    pub fn sigma(&self, t: f64) -> f64 {
        match *self {
            Process::Ve { sigma_min, sigma_max } => {
                sigma_min * (sigma_max / sigma_min).powf(t)
            }
            Process::Vp { .. } => unreachable!("sigma(t) is a VE quantity"),
        }
    }

    pub fn beta(&self, t: f64) -> f64 {
        match *self {
            Process::Vp { beta_min, beta_max } => beta_min + t * (beta_max - beta_min),
            Process::Ve { .. } => unreachable!("beta(t) is a VP quantity"),
        }
    }

    fn int_beta(&self, t: f64) -> f64 {
        match *self {
            Process::Vp { beta_min, beta_max } => {
                beta_min * t + 0.5 * t * t * (beta_max - beta_min)
            }
            Process::Ve { .. } => unreachable!(),
        }
    }

    /// Diffusion coefficient g(t).
    pub fn diffusion(&self, t: f64) -> f64 {
        match *self {
            Process::Ve { sigma_min, sigma_max } => {
                self.sigma(t) * (2.0 * (sigma_max / sigma_min).ln()).sqrt()
            }
            Process::Vp { .. } => self.beta(t).sqrt(),
        }
    }

    /// Scalar drift coefficient: f(x,t) = drift_coef(t) * x.
    pub fn drift_coef(&self, t: f64) -> f64 {
        match self {
            Process::Ve { .. } => 0.0,
            Process::Vp { .. } => -0.5 * self.beta(t),
        }
    }

    /// Transition-kernel mean coefficient: E[x(t)|x0] = mean_coef(t) x0.
    pub fn mean_coef(&self, t: f64) -> f64 {
        match self {
            Process::Ve { .. } => 1.0,
            Process::Vp { .. } => (-0.5 * self.int_beta(t)).exp(),
        }
    }

    /// Transition-kernel std.
    pub fn marginal_std(&self, t: f64) -> f64 {
        match self {
            Process::Ve { .. } => self.sigma(t),
            Process::Vp { .. } => (1.0 - (-self.int_beta(t)).exp()).max(1e-12).sqrt(),
        }
    }

    pub fn prior_std(&self) -> f64 {
        match *self {
            Process::Ve { sigma_max, .. } => sigma_max,
            Process::Vp { .. } => 1.0,
        }
    }

    /// Var[x(t)|x0] for Tweedie denoising.
    pub fn tweedie_var(&self, t: f64) -> f64 {
        match self {
            Process::Ve { .. } => self.sigma(t) * self.sigma(t),
            Process::Vp { .. } => 1.0 - (-self.int_beta(t)).exp(),
        }
    }

    /// Draw x(1) ~ prior into `out` ([B, D]).
    pub fn sample_prior(&self, rng: &mut Rng, out: &mut Tensor) {
        let std = self.prior_std() as f32;
        for v in out.data.iter_mut() {
            *v = rng.normal() as f32 * std;
        }
    }

    /// Map model output range to [0,1] for image export / FID features.
    pub fn to_unit_range(&self, x: &mut Tensor) {
        let (lo, hi) = self.data_range();
        let (lo, hi) = (lo as f32, hi as f32);
        for v in x.data.iter_mut() {
            *v = ((*v - lo) / (hi - lo)).clamp(0.0, 1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Fixtures shared with python/tests/test_sde.py — keep in sync!
    const VE_FIX: [(f64, f64, f64); 5] = [
        (0.0, 0.01, 0.04127273),
        (0.25, 0.08408964, 0.347061),
        (0.5, 0.7071068, 2.918423),
        (0.75, 5.946036, 24.54091),
        (1.0, 50.0, 206.3637),
    ];

    const VP_FIX: [(f64, f64, f64, f64); 4] = [
        (0.25, 5.075, 0.7236571, 0.6901596),
        (0.5, 10.05, 0.2811829, 0.9596542),
        (0.75, 15.025, 0.0586635, 0.9982778),
        (1.0, 20.0, 0.006571586, 0.9999784),
    ];

    #[test]
    fn ve_matches_python_fixtures() {
        let p = Process::ve(50.0);
        for (t, sigma, g) in VE_FIX {
            assert!((p.sigma(t) - sigma).abs() / sigma < 1e-5, "sigma({t})");
            assert!((p.diffusion(t) - g).abs() / g < 1e-5, "g({t})");
        }
    }

    #[test]
    fn vp_matches_python_fixtures() {
        let p = Process::vp();
        for (t, beta, alpha, std) in VP_FIX {
            assert!((p.beta(t) - beta).abs() < 1e-9, "beta({t})");
            assert!((p.mean_coef(t) - alpha).abs() / alpha < 1e-5, "alpha({t})");
            assert!((p.marginal_std(t) - std).abs() < 1e-6, "std({t})");
        }
    }

    #[test]
    fn vp_variance_preserving_identity() {
        let p = Process::vp();
        for t in [0.1, 0.4, 0.8, 1.0] {
            let a = p.mean_coef(t);
            let s = p.marginal_std(t);
            assert!((a * a + s * s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn eps_abs_paper_values() {
        assert!((Process::vp().eps_abs() - 0.0078125).abs() < 1e-9);
        assert!((Process::ve(50.0).eps_abs() - 0.00390625).abs() < 1e-9);
    }

    #[test]
    fn prior_sample_moments() {
        let p = Process::ve(30.0);
        let mut rng = Rng::new(0);
        let mut x = Tensor::zeros(&[64, 256]);
        p.sample_prior(&mut rng, &mut x);
        let n = x.len() as f64;
        let mean: f64 = x.data.iter().map(|&v| v as f64).sum::<f64>() / n;
        let var: f64 = x.data.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.5, "{mean}");
        assert!((var.sqrt() - 30.0).abs() < 0.5, "{}", var.sqrt());
    }

    #[test]
    fn unit_range_mapping() {
        let p = Process::vp();
        let mut x = Tensor::from_vec(&[1, 3], vec![-1.0, 0.0, 2.0]).unwrap();
        p.to_unit_range(&mut x);
        assert_eq!(x.data, vec![0.0, 0.5, 1.0]);
    }

    #[test]
    fn drift_coef_signs() {
        assert_eq!(Process::ve(50.0).drift_coef(0.5), 0.0);
        assert!(Process::vp().drift_coef(0.5) < 0.0);
    }
}
