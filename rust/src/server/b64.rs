//! Minimal base64 (standard alphabet, padded) for the wire protocol.

use crate::{bail, Result};

const ALPHABET: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

pub fn encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let v = ((b[0] as u32) << 16) | ((b[1] as u32) << 8) | b[2] as u32;
        out.push(ALPHABET[(v >> 18) as usize & 63] as char);
        out.push(ALPHABET[(v >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 { ALPHABET[(v >> 6) as usize & 63] as char } else { '=' });
        out.push(if chunk.len() > 2 { ALPHABET[v as usize & 63] as char } else { '=' });
    }
    out
}

fn val(c: u8) -> Result<u32> {
    Ok(match c {
        b'A'..=b'Z' => (c - b'A') as u32,
        b'a'..=b'z' => (c - b'a' + 26) as u32,
        b'0'..=b'9' => (c - b'0' + 52) as u32,
        b'+' => 62,
        b'/' => 63,
        _ => bail!("invalid base64 byte {c}"),
    })
}

pub fn decode(s: &str) -> Result<Vec<u8>> {
    let s = s.trim_end_matches('=').as_bytes();
    let mut out = Vec::with_capacity(s.len() * 3 / 4);
    for chunk in s.chunks(4) {
        if chunk.len() == 1 {
            bail!("truncated base64");
        }
        let mut v = 0u32;
        for (i, &c) in chunk.iter().enumerate() {
            v |= val(c)? << (18 - 6 * i);
        }
        out.push((v >> 16) as u8);
        if chunk.len() > 2 {
            out.push((v >> 8) as u8);
        }
        if chunk.len() > 3 {
            out.push(v as u8);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc_vectors() {
        assert_eq!(encode(b""), "");
        assert_eq!(encode(b"f"), "Zg==");
        assert_eq!(encode(b"fo"), "Zm8=");
        assert_eq!(encode(b"foo"), "Zm9v");
        assert_eq!(encode(b"foob"), "Zm9vYg==");
        assert_eq!(encode(b"fooba"), "Zm9vYmE=");
        assert_eq!(encode(b"foobar"), "Zm9vYmFy");
    }

    #[test]
    fn roundtrip_binary() {
        let data: Vec<u8> = (0..=255).collect();
        assert_eq!(decode(&encode(&data)).unwrap(), data);
    }

    #[test]
    fn roundtrip_f32() {
        let vals = [1.5f32, -0.25, 1e-30, f32::MAX];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let back = decode(&encode(&bytes)).unwrap();
        assert_eq!(back, bytes);
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode("a!!!").is_err());
        assert!(decode("a").is_err());
    }
}
