//! JSON-lines TCP serving front-end + client library.
//!
//! The wire protocol — ops (`hello`/`ping`/`stats`/`generate`/
//! `evaluate`/`submit`/`poll`/`cancel`/`periodic`/`trace`/`metrics`/
//! `diag`/`health`),
//! the error-code table, binary payload framing, and the version
//! field — is specified
//! in **docs/PROTOCOL.md**; this module is its implementation. In
//! brief: one JSON object per line in both directions, every response
//! carries `"v":1`, every `ok:false` carries a machine-readable
//! `code`, and a response whose header carries `images_bin` is
//! followed by that many raw f32-le payload bytes (negotiated per
//! request via `"binary":true`, advertised by `hello`).
//!
//! Synchronous ops block the connection on the engine reply; the async
//! ops (`submit`/`poll`/`cancel`/`periodic`) go through the
//! server-global [`jobs::JobTable`], so a submitted job survives its
//! connection and can be polled from another one
//! (docs/ARCHITECTURE.md §Async jobs).
//!
//! One OS thread per connection (requests within a connection pipeline
//! through the shared engine, which does the real batching).

pub mod b64;
pub mod jobs;
pub mod stats;

use crate::coordinator::{
    qos, DiagQuery, EngineClient, EvalRequest as EngineEvalRequest, GenResult, SampleRequest,
    TraceQuery,
};
use crate::json::{self, Value};
use crate::solvers::spec;
use crate::{anyhow, bail, Context, Result};
use jobs::{CancelStatus, JobMeta, JobOutcome, JobTable};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// Protocol version stamped into every response (`"v"`).
pub const PROTO_VERSION: u64 = 1;

/// Every op the server answers; unknown-op errors echo this list.
pub const OPS: [&str; 13] = [
    "hello", "ping", "stats", "generate", "evaluate", "submit", "poll", "cancel", "periodic",
    "trace", "metrics", "diag", "health",
];

pub struct ServerConfig {
    pub port: u16,
    /// eps_rel applied when a generate request omits the field.
    pub default_eps_rel: f64,
}

/// Serve forever (each connection on its own thread). The job table is
/// server-global: jobs submitted on one connection are pollable from
/// any other.
pub fn serve(listener: TcpListener, engine: EngineClient, cfg: ServerConfig) -> Result<()> {
    let cfg = std::sync::Arc::new(cfg);
    let jobs = Arc::new(JobTable::new());
    for stream in listener.incoming() {
        let stream = stream?;
        let engine = engine.clone();
        let cfg = cfg.clone();
        let jobs = jobs.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, engine, &jobs, &cfg) {
                eprintln!("[server] connection error: {e:#}");
            }
        });
    }
    Ok(())
}

/// A response: the JSON header line plus any raw payload frames that
/// follow it on the wire (in field order of their `images_bin` keys).
struct Reply {
    head: Value,
    frames: Vec<Vec<u8>>,
}

impl Reply {
    fn head(head: Value) -> Reply {
        Reply { head, frames: Vec::new() }
    }
}

pub fn handle_conn(
    stream: TcpStream,
    engine: EngineClient,
    jobs: &Arc<JobTable>,
    cfg: &ServerConfig,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        if line.trim().is_empty() {
            continue;
        }
        let mut reply = match handle_request(&line, &engine, jobs, cfg) {
            Ok(r) => r,
            Err(e) => {
                let msg = format!("{e:#}");
                // every ok:false carries a code: structured rejections
                // keep theirs, everything else is the internal fallback
                let code = qos::error_code(&msg).unwrap_or(qos::CODE_INTERNAL);
                Reply::head(Value::obj(vec![
                    ("ok", Value::Bool(false)),
                    ("code", Value::str(code)),
                    ("error", Value::str(msg)),
                ]))
            }
        };
        reply.head.set("v", Value::num(PROTO_VERSION as f64));
        writeln!(writer, "{}", reply.head)?;
        for frame in &reply.frames {
            writer.write_all(frame)?;
        }
    }
}

/// Optional `priority` field ("interactive" | "batch"); `None` defers
/// to the engine's configured default class.
fn parse_priority(req: &Value) -> Result<Option<qos::Priority>> {
    req.get("priority")
        .map(|v| qos::Priority::parse(v.as_str()?))
        .transpose()
}

/// Wire-layer solver-spec parse: a malformed spec (unknown name,
/// `em:0`, `pc:64@0`, ...) is a structured `bad_solver` rejection, so
/// clients can distinguish it from load-dependent errors.
fn parse_solver(s: &str) -> Result<crate::solvers::ServingSolver> {
    spec::parse(s).map_err(|e| anyhow!("{}", qos::coded(qos::CODE_BAD_SOLVER, &format!("{e:#}"))))
}

/// Attach `code` to an error that carries none yet (request-parsing
/// failures become `bad_request`; already-coded rejections like
/// `bad_solver` pass through).
fn coded_or(e: anyhow::Error, code: &str) -> anyhow::Error {
    let msg = format!("{e:#}");
    if qos::error_code(&msg).is_some() {
        anyhow!("{msg}")
    } else {
        anyhow!("{}", qos::coded(code, &msg))
    }
}

/// A parsed generate body (shared by `generate`, `submit` and
/// `periodic` — async is a delivery mode, not a second parameter list).
struct GenParams {
    req: SampleRequest,
    want_images: bool,
    binary: bool,
}

fn parse_generate(req: &Value, cfg: &ServerConfig) -> Result<GenParams> {
    let n = req.get("n").map(|v| v.as_usize()).transpose()?.unwrap_or(1);
    let eps_rel = req
        .get("eps_rel")
        .map(|v| v.as_f64())
        .transpose()?
        .unwrap_or(cfg.default_eps_rel);
    let seed = req.get("seed").map(|v| v.as_f64()).transpose()?.unwrap_or(0.0) as u64;
    let model = req.get("model").map(|v| v.as_str()).transpose()?.unwrap_or("").to_string();
    let solver = parse_solver(req.get("solver").map(|v| v.as_str()).transpose()?.unwrap_or(""))?;
    let want_images = req.get("images").map(|v| v.as_bool()).transpose()?.unwrap_or(true);
    let binary = req.get("binary").map(|v| v.as_bool()).transpose()?.unwrap_or(false);
    let priority = parse_priority(req)?;
    // 0 means "no deadline", matching the builder and the CLI
    // --deadline-ms convention — not "shed immediately"
    let deadline_ms = req
        .get("deadline_ms")
        .map(|v| v.as_f64())
        .transpose()?
        .map(|v| v as u64)
        .filter(|&d| d > 0);
    Ok(GenParams {
        req: SampleRequest {
            model,
            solver,
            n,
            eps_rel,
            seed,
            sample_base: 0,
            priority,
            deadline_ms,
            cancel_token: None, // the job table stamps ids on submit
        },
        want_images,
        binary,
    })
}

fn parse_evaluate(req: &Value, cfg: &ServerConfig) -> Result<EngineEvalRequest> {
    let samples = req.get("samples").map(|v| v.as_usize()).transpose()?.unwrap_or(256);
    let eps_rel = req
        .get("eps_rel")
        .map(|v| v.as_f64())
        .transpose()?
        .unwrap_or(cfg.default_eps_rel);
    let seed = req.get("seed").map(|v| v.as_f64()).transpose()?.unwrap_or(0.0) as u64;
    let model = req.get("model").map(|v| v.as_str()).transpose()?.unwrap_or("").to_string();
    let solver = parse_solver(req.get("solver").map(|v| v.as_str()).transpose()?.unwrap_or(""))?;
    let priority = parse_priority(req)?;
    if req.get("deadline_ms").is_some() {
        bail!(
            "deadline_ms is not supported on evaluate (deadlines shed queued \
             generate requests; evaluation jobs run to completion)"
        );
    }
    Ok(EngineEvalRequest { model, solver, samples, eps_rel, seed, priority })
}

/// A completed generate as a response object. With `binary`, the
/// payload leaves the JSON line: the header carries
/// `"images_bin":<byte count>` and the raw f32-le bytes are appended
/// to `frames` (written after the line, in field order).
fn gen_json(
    r: &GenResult,
    solver: &str,
    n: usize,
    want_images: bool,
    binary: bool,
    frames: &mut Vec<Vec<u8>>,
) -> Value {
    let mut pairs = vec![
        ("ok", Value::Bool(true)),
        // the model that actually served it (resolved default)
        ("model", Value::str(r.model.clone())),
        ("solver", Value::str(solver)),
        ("n", Value::num(n as f64)),
        ("h", Value::num(r.h as f64)),
        ("w", Value::num(r.w as f64)),
        ("wall_s", Value::num(r.wall_s)),
        ("queued_s", Value::num(r.queued_s)),
        ("nfe", Value::Arr(r.nfe.iter().map(|&v| Value::num(v as f64)).collect())),
    ];
    if want_images {
        let bytes: Vec<u8> = r.images.data.iter().flat_map(|v| v.to_le_bytes()).collect();
        if binary {
            pairs.push(("images_bin", Value::num(bytes.len() as f64)));
            frames.push(bytes);
        } else {
            pairs.push(("images_b64", Value::str(b64::encode(&bytes))));
        }
    }
    Value::obj(pairs)
}

fn eval_json(r: &crate::coordinator::EvalResult) -> Value {
    Value::obj(vec![
        ("ok", Value::Bool(true)),
        ("model", Value::str(r.model.clone())),
        ("solver", Value::str(r.solver.clone())),
        ("samples", Value::num(r.samples as f64)),
        ("fid", Value::num(r.fid)),
        ("is", Value::num(r.is)),
        ("mean_nfe", Value::num(r.mean_nfe)),
        ("wall_s", Value::num(r.wall_s)),
        ("steps_per_bucket", buckets_obj(&r.steps_per_bucket)),
    ])
}

/// A failed job as a poll entry: same code plumbing as a top-level
/// error, scoped to the one job instead of failing the poll.
fn fail_json(op: &str, msg: &str) -> Value {
    Value::obj(vec![
        ("ok", Value::Bool(false)),
        ("op", Value::str(op)),
        ("code", Value::str(qos::error_code(msg).unwrap_or(qos::CODE_INTERNAL))),
        ("error", Value::str(msg)),
    ])
}

fn update_json(u: jobs::JobUpdate, binary: bool, frames: &mut Vec<Vec<u8>>) -> Value {
    let mut v = match &u.outcome {
        JobOutcome::Gen(Ok(r)) => {
            let mut v = gen_json(r, &u.meta.solver, u.meta.n, u.meta.want_images, binary, frames);
            v.set("op", Value::str("generate"));
            v
        }
        JobOutcome::Eval(Ok(r)) => {
            let mut v = eval_json(r);
            v.set("op", Value::str("evaluate"));
            v
        }
        JobOutcome::Gen(Err(e)) => fail_json("generate", e),
        JobOutcome::Eval(Err(e)) => fail_json("evaluate", e),
    };
    v.set("job", Value::num(u.id as f64));
    if let Some(round) = u.round {
        v.set("round", Value::num(round as f64));
    }
    v
}

fn handle_request(
    line: &str,
    engine: &EngineClient,
    jobs: &Arc<JobTable>,
    cfg: &ServerConfig,
) -> Result<Reply> {
    let req = json::parse(line)
        .context("parsing request json")
        .map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))?;
    let op = req
        .req("op")
        .and_then(|v| v.as_str())
        .map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))?
        .to_string();
    match op.as_str() {
        "ping" => Ok(Reply::head(Value::obj(vec![("ok", Value::Bool(true))]))),
        "hello" => {
            // capability discovery: version, ops, served models and
            // solver programs, binary-frame availability — so clients
            // stop probing `stats` for any of it
            let s = engine.stats()?;
            Ok(Reply::head(Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("ops", Value::Arr(OPS.iter().map(|&o| Value::str(o)).collect())),
                (
                    "models",
                    Value::Arr(s.models.iter().map(|m| Value::str(m.clone())).collect()),
                ),
                (
                    "solvers",
                    Value::Arr(s.programs.iter().map(|p| Value::str(p.solver.clone())).collect()),
                ),
                ("binary", Value::Bool(true)),
                // whether any adaptive pool dispatches the fused
                // device-side accept/reject fold (k attempts per
                // launch) rather than one attempt per dispatch
                (
                    "fused_adaptive",
                    Value::Bool(
                        s.pool_qos
                            .iter()
                            .any(|p| p.solver == "adaptive" && p.steps_per_dispatch > 1),
                    ),
                ),
            ])))
        }
        "stats" => {
            let s = engine.stats()?;
            Ok(Reply::head(stats::StatsTree::build(&s, &jobs.stats()).to_json()))
        }
        "metrics" => {
            // the same stats tree as `stats`, rendered as Prometheus
            // text exposition (docs/PROTOCOL.md §metrics)
            let s = engine.stats()?;
            let text = stats::StatsTree::build(&s, &jobs.stats()).to_prometheus();
            Ok(Reply::head(Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("content_type", Value::str("text/plain; version=0.0.4")),
                ("text", Value::str(text)),
            ])))
        }
        "trace" => {
            let parse_id = |key: &str| -> Result<Option<u64>> {
                req.get(key)
                    .map(|v| v.as_f64())
                    .transpose()
                    .map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))
                    .map(|v| v.map(|v| v as u64))
            };
            let (id, job) = (parse_id("id")?, parse_id("job")?);
            let last = req
                .get("last")
                .map(|v| v.as_usize())
                .transpose()
                .map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))?
                // a targeted query returns every matching span; an
                // open-ended listing defaults to the newest 16 (0 = all)
                .unwrap_or(if id.is_some() || job.is_some() { 0 } else { 16 });
            let timeline = req
                .get("timeline")
                .map(|v| v.as_bool())
                .transpose()
                .map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))?
                .unwrap_or(false);
            let r = engine.trace(TraceQuery { id, job, last, timeline })?;
            Ok(Reply::head(Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("spans", Value::Arr(r.spans.iter().map(|s| s.to_json()).collect())),
                ("timeline", Value::Arr(r.timeline.iter().map(|d| d.to_json()).collect())),
            ])))
        }
        "diag" => {
            // per-pool solver diagnostics: diffusion-time profiles plus
            // any sampled lane traces (docs/PROTOCOL.md §diag)
            let pool = req
                .get("pool")
                .map(|v| v.as_str().map(String::from))
                .transpose()
                .map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))?;
            let lane = req
                .get("lane")
                .map(|v| v.as_f64())
                .transpose()
                .map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))?
                .map(|v| v as u64);
            let r = engine.diag(DiagQuery { pool, lane })?;
            Ok(Reply::head(Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("pools", Value::Arr(r.pools.iter().map(|p| p.to_json()).collect())),
            ])))
        }
        "health" => {
            // watchdog status, retained events, per-kind counters
            // (docs/PROTOCOL.md §health)
            let r = engine.health()?;
            Ok(Reply::head(Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("status", Value::num(r.status as f64)),
                ("events", Value::Arr(r.events.iter().map(|e| e.to_json()).collect())),
                (
                    "counts",
                    Value::Obj(
                        r.counts
                            .iter()
                            .map(|(k, n)| (k.clone(), Value::num(*n as f64)))
                            .collect(),
                    ),
                ),
            ])))
        }
        "generate" => {
            let p = parse_generate(&req, cfg).map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))?;
            let solver = p.req.solver;
            let n = p.req.n;
            let r = engine.generate_request(p.req)?;
            let mut frames = Vec::new();
            let head = gen_json(
                &r,
                &solver.spec_string(),
                n,
                p.want_images,
                p.binary,
                &mut frames,
            );
            Ok(Reply { head, frames })
        }
        "evaluate" => {
            let er = parse_evaluate(&req, cfg).map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))?;
            let r = engine.evaluate(er)?;
            Ok(Reply::head(eval_json(&r)))
        }
        "submit" => {
            // wraps any generate/evaluate body: same fields, plus
            // kind ("generate" default); returns a job id immediately
            let kind = req
                .get("kind")
                .map(|v| v.as_str())
                .transpose()
                .map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))?
                .unwrap_or("generate");
            let id = match kind {
                "generate" => {
                    let p = parse_generate(&req, cfg)
                        .map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))?;
                    let meta = JobMeta {
                        solver: p.req.solver.spec_string(),
                        n: p.req.n,
                        want_images: p.want_images,
                    };
                    jobs.submit_gen(engine, p.req, meta)?
                }
                "evaluate" => {
                    let er = parse_evaluate(&req, cfg)
                        .map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))?;
                    let meta = JobMeta {
                        solver: er.solver.spec_string(),
                        n: er.samples,
                        want_images: false,
                    };
                    jobs.submit_eval(engine, er, meta)?
                }
                other => {
                    return Err(anyhow!(
                        "{}",
                        qos::coded(
                            qos::CODE_BAD_REQUEST,
                            &format!("submit kind must be 'generate' or 'evaluate', got '{other}'"),
                        )
                    ))
                }
            };
            Ok(Reply::head(Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("job", Value::num(id as f64)),
            ])))
        }
        "poll" => {
            let timeout_ms = req
                .get("timeout_ms")
                .map(|v| v.as_f64())
                .transpose()
                .map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))?
                .unwrap_or(0.0) as u64;
            let job = req
                .get("job")
                .map(|v| v.as_f64())
                .transpose()
                .map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))?
                .map(|v| v as u64);
            let binary = req
                .get("binary")
                .map(|v| v.as_bool())
                .transpose()
                .map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))?
                .unwrap_or(false);
            let updates = jobs.poll(timeout_ms, job).ok_or_else(|| {
                anyhow!(
                    "{}",
                    qos::coded(
                        qos::CODE_UNKNOWN_JOB,
                        &format!(
                            "no such job {} (never issued, already delivered, or canceled)",
                            job.unwrap_or(0)
                        ),
                    )
                )
            })?;
            let mut frames = Vec::new();
            let arr: Vec<Value> =
                updates.into_iter().map(|u| update_json(u, binary, &mut frames)).collect();
            Ok(Reply {
                head: Value::obj(vec![("ok", Value::Bool(true)), ("jobs", Value::Arr(arr))]),
                frames,
            })
        }
        "cancel" => {
            let id = req
                .req("job")
                .and_then(|v| v.as_f64())
                .map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))? as u64;
            match jobs.cancel(engine, id) {
                CancelStatus::Canceled => Ok(Reply::head(Value::obj(vec![
                    ("ok", Value::Bool(true)),
                    ("job", Value::num(id as f64)),
                    ("canceled", Value::Bool(true)),
                    ("state", Value::str("canceled")),
                ]))),
                CancelStatus::Running => Ok(Reply::head(Value::obj(vec![
                    ("ok", Value::Bool(true)),
                    ("job", Value::num(id as f64)),
                    ("canceled", Value::Bool(false)),
                    // lane-holding work runs to completion (deadline
                    // semantics); the result stays pollable
                    ("state", Value::str("running")),
                ]))),
                CancelStatus::AlreadyDone => Err(anyhow!(
                    "{}",
                    qos::coded(
                        qos::CODE_UNKNOWN_JOB,
                        &format!("job {id} already completed (its result remains pollable)"),
                    )
                )),
                CancelStatus::Unknown => Err(anyhow!(
                    "{}",
                    qos::coded(
                        qos::CODE_UNKNOWN_JOB,
                        &format!("no such job {id} (never issued, already delivered, or canceled)"),
                    )
                )),
            }
        }
        "periodic" => {
            let p = parse_generate(&req, cfg).map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))?;
            let rate_ms = req
                .req("rate_ms")
                .and_then(|v| v.as_f64())
                .map_err(|e| coded_or(e, qos::CODE_BAD_REQUEST))? as u64;
            if rate_ms == 0 {
                return Err(anyhow!(
                    "{}",
                    qos::coded(qos::CODE_BAD_REQUEST, "rate_ms must be >= 1")
                ));
            }
            let meta = JobMeta {
                solver: p.req.solver.spec_string(),
                n: p.req.n,
                want_images: p.want_images,
            };
            let id = jobs.submit_periodic(engine.clone(), p.req, rate_ms, meta);
            Ok(Reply::head(Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("job", Value::num(id as f64)),
            ])))
        }
        other => Err(anyhow!(
            "{}",
            qos::coded(
                qos::CODE_BAD_OP,
                &format!("unknown op '{other}' (supported: {})", OPS.join(", ")),
            )
        )),
    }
}

fn buckets_obj(per: &[(usize, u64)]) -> Value {
    Value::Obj(per.iter().map(|(b, n)| (b.to_string(), Value::num(*n as f64))).collect())
}

// --- client ---------------------------------------------------------------------

/// Blocking JSON-lines client for the serving protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

#[derive(Clone, Debug)]
pub struct ClientGenResult {
    pub images: crate::tensor::Tensor,
    pub nfe: Vec<u64>,
    pub wall_s: f64,
    pub queued_s: f64,
}

/// Parsed `evaluate` response (wire format in docs/PROTOCOL.md).
#[derive(Clone, Debug)]
pub struct ClientEvalResult {
    pub model: String,
    pub solver: String,
    pub samples: usize,
    pub fid: f64,
    pub is: f64,
    pub mean_nfe: f64,
    pub wall_s: f64,
    /// Fused steps per pool width consumed while the run was in flight.
    pub steps_per_bucket: Vec<(usize, u64)>,
}

/// A generation request under construction — the one parameter surface
/// both the sync op ([`Client::run`]) and the async ops
/// ([`Client::submit`], [`Client::periodic`]) serialize from.
#[derive(Clone, Debug)]
pub struct GenerateRequest {
    model: String,
    solver: String,
    n: usize,
    eps_rel: Option<f64>,
    seed: u64,
    priority: String,
    deadline_ms: u64,
    want_images: bool,
    binary: bool,
}

impl GenerateRequest {
    /// `n` samples from the server's default model with the default
    /// solver (adaptive), seed 0, server-default eps_rel, payload on.
    pub fn new(n: usize) -> GenerateRequest {
        GenerateRequest {
            model: String::new(),
            solver: String::new(),
            n,
            eps_rel: None,
            seed: 0,
            priority: String::new(),
            deadline_ms: 0,
            want_images: true,
            binary: false,
        }
    }

    /// Named model ("" = the server's default).
    pub fn model(mut self, model: &str) -> Self {
        self.model = model.to_string();
        self
    }

    /// Solver spec ("adaptive", "em:<n>", "ddim:<n>", "pc:<n>[@<snr>]";
    /// "" = the server default, adaptive).
    pub fn solver(mut self, solver: &str) -> Self {
        self.solver = solver.to_string();
        self
    }

    /// Adaptive tolerance knob (unset = the server's default).
    pub fn eps_rel(mut self, eps_rel: f64) -> Self {
        self.eps_rel = Some(eps_rel);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Priority class: "interactive" / "batch" ("" = server default).
    pub fn priority(mut self, priority: &str) -> Self {
        self.priority = priority.to_string();
        self
    }

    /// Shed the request if still fully queued after this many ms
    /// (0 = no deadline).
    pub fn deadline_ms(mut self, deadline_ms: u64) -> Self {
        self.deadline_ms = deadline_ms;
        self
    }

    /// Whether the response carries sample payloads (default true).
    pub fn images(mut self, want: bool) -> Self {
        self.want_images = want;
        self
    }

    /// Deliver payloads as a raw binary frame instead of base64
    /// (default false; availability advertised by `hello`).
    pub fn binary(mut self, binary: bool) -> Self {
        self.binary = binary;
        self
    }

    fn body(&self, op: &str) -> Value {
        let mut pairs = vec![
            ("op", Value::str(op)),
            ("n", Value::num(self.n as f64)),
            ("seed", Value::num(self.seed as f64)),
            ("images", Value::Bool(self.want_images)),
        ];
        if let Some(e) = self.eps_rel {
            pairs.push(("eps_rel", Value::num(e)));
        }
        if !self.model.is_empty() {
            pairs.push(("model", Value::str(self.model.clone())));
        }
        if !self.solver.is_empty() {
            pairs.push(("solver", Value::str(self.solver.clone())));
        }
        if !self.priority.is_empty() {
            pairs.push(("priority", Value::str(self.priority.clone())));
        }
        if self.deadline_ms > 0 {
            pairs.push(("deadline_ms", Value::num(self.deadline_ms as f64)));
        }
        if self.binary {
            pairs.push(("binary", Value::Bool(true)));
        }
        Value::obj(pairs)
    }
}

/// An evaluation request under construction — serialized by both
/// [`Client::run_eval`] and [`Client::submit_eval`].
#[derive(Clone, Debug)]
pub struct EvalRequest {
    model: String,
    solver: String,
    samples: usize,
    eps_rel: Option<f64>,
    seed: u64,
    priority: String,
}

impl EvalRequest {
    pub fn new(samples: usize) -> EvalRequest {
        EvalRequest {
            model: String::new(),
            solver: String::new(),
            samples,
            eps_rel: None,
            seed: 0,
            priority: String::new(),
        }
    }

    pub fn model(mut self, model: &str) -> Self {
        self.model = model.to_string();
        self
    }

    pub fn solver(mut self, solver: &str) -> Self {
        self.solver = solver.to_string();
        self
    }

    pub fn eps_rel(mut self, eps_rel: f64) -> Self {
        self.eps_rel = Some(eps_rel);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Mark bulk evaluation runs "batch" so interactive traffic on the
    /// same pool is admitted first ("" = server default).
    pub fn priority(mut self, priority: &str) -> Self {
        self.priority = priority.to_string();
        self
    }

    fn body(&self, op: &str) -> Value {
        let mut pairs = vec![
            ("op", Value::str(op)),
            ("samples", Value::num(self.samples as f64)),
            ("seed", Value::num(self.seed as f64)),
        ];
        if let Some(e) = self.eps_rel {
            pairs.push(("eps_rel", Value::num(e)));
        }
        if !self.model.is_empty() {
            pairs.push(("model", Value::str(self.model.clone())));
        }
        if !self.solver.is_empty() {
            pairs.push(("solver", Value::str(self.solver.clone())));
        }
        if !self.priority.is_empty() {
            pairs.push(("priority", Value::str(self.priority.clone())));
        }
        Value::obj(pairs)
    }
}

/// One completed job drained by [`Client::poll`]. `error`/`code` are
/// set for failed jobs; exactly one of `gen`/`eval` for successful
/// ones (by `op`).
#[derive(Debug)]
pub struct JobUpdate {
    pub job: u64,
    /// "generate" | "evaluate".
    pub op: String,
    /// Round index for periodic jobs.
    pub round: Option<u64>,
    pub code: Option<String>,
    pub error: Option<String>,
    pub gen: Option<ClientGenResult>,
    pub eval: Option<ClientEvalResult>,
}

impl JobUpdate {
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

fn parse_client_gen(v: &Value, bin: Option<Vec<u8>>) -> Result<ClientGenResult> {
    let n = v.req("n")?.as_usize()?;
    let nfe = v
        .req("nfe")?
        .as_arr()?
        .iter()
        .map(|x| Ok(x.as_f64()? as u64))
        .collect::<Result<Vec<_>>>()?;
    let (h, w) = (v.req("h")?.as_usize()?, v.req("w")?.as_usize()?);
    let bytes = match bin {
        Some(b) => Some(b),
        None => match v.get("images_b64") {
            Some(s) => Some(b64::decode(s.as_str()?)?),
            None => None,
        },
    };
    let images = match bytes {
        Some(bytes) => {
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            crate::tensor::Tensor::from_vec(&[n, h * w * 3], data)?
        }
        None => crate::tensor::Tensor::zeros(&[0]),
    };
    Ok(ClientGenResult {
        images,
        nfe,
        wall_s: v.req("wall_s")?.as_f64()?,
        queued_s: v.req("queued_s")?.as_f64()?,
    })
}

fn parse_client_eval(v: &Value) -> Result<ClientEvalResult> {
    let mut steps_per_bucket = v
        .req("steps_per_bucket")?
        .members()
        .iter()
        .map(|(b, n)| {
            Ok((
                b.parse::<usize>().map_err(|_| anyhow!("bad bucket key '{b}'"))?,
                n.as_f64()? as u64,
            ))
        })
        .collect::<Result<Vec<_>>>()?;
    steps_per_bucket.sort();
    Ok(ClientEvalResult {
        model: v.req("model")?.as_str()?.to_string(),
        solver: v.req("solver")?.as_str()?.to_string(),
        samples: v.req("samples")?.as_usize()?,
        fid: v.req("fid")?.as_f64()?,
        is: v.req("is")?.as_f64()?,
        mean_nfe: v.req("mean_nfe")?.as_f64()?,
        wall_s: v.req("wall_s")?.as_f64()?,
        steps_per_bucket,
    })
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    fn call(&mut self, req: &Value) -> Result<Value> {
        writeln!(self.writer, "{req}")?;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(anyhow!("server closed connection"));
        }
        let v = json::parse(&line)?;
        if !v.req("ok")?.as_bool()? {
            // the error text already embeds the code prefix for
            // structured rejections; surface the field anyway so
            // callers matching on "[quota_exceeded]" etc. are not
            // parsing prose
            let code = v
                .get("code")
                .and_then(|c| c.as_str().ok())
                .map(|c| format!(" [{c}]"))
                .unwrap_or_default();
            return Err(anyhow!(
                "server error{code}: {}",
                v.get("error").and_then(|e| e.as_str().ok()).unwrap_or("unknown")
            ));
        }
        Ok(v)
    }

    /// Read the raw payload frame a header object announced via
    /// `images_bin` (frames follow the JSON line in field order).
    fn take_frame(&mut self, head: &Value) -> Result<Option<Vec<u8>>> {
        match head.get("images_bin") {
            Some(len) => {
                let mut buf = vec![0u8; len.as_usize()?];
                self.reader.read_exact(&mut buf)?;
                Ok(Some(buf))
            }
            None => Ok(None),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        self.call(&Value::obj(vec![("op", Value::str("ping"))]))?;
        Ok(())
    }

    pub fn stats(&mut self) -> Result<Value> {
        self.call(&Value::obj(vec![("op", Value::str("stats"))]))
    }

    /// Capability discovery: `{"v", "ops", "models", "solvers",
    /// "binary"}` (docs/PROTOCOL.md §hello).
    pub fn hello(&mut self) -> Result<Value> {
        self.call(&Value::obj(vec![("op", Value::str("hello"))]))
    }

    /// Request-lifecycle spans from the server's trace ring, optionally
    /// with the runtime's dispatch timeline (docs/PROTOCOL.md §trace).
    /// `job` filters to one async job's spans; `last` keeps the newest
    /// N (0 = everything retained). Returns the raw response object
    /// (`spans` and `timeline` arrays).
    pub fn trace(&mut self, job: Option<u64>, last: usize, timeline: bool) -> Result<Value> {
        let mut pairs = vec![
            ("op", Value::str("trace")),
            ("last", Value::num(last as f64)),
            ("timeline", Value::Bool(timeline)),
        ];
        if let Some(j) = job {
            pairs.push(("job", Value::num(j as f64)));
        }
        self.call(&Value::obj(pairs))
    }

    /// Per-pool solver diagnostics (docs/PROTOCOL.md §diag): the
    /// diffusion-time profile bins plus any sampled lane traces.
    /// `pool` filters to one `model/solver` (or `model:solver`) pool;
    /// `lane` filters traces to one request id. Returns the raw
    /// response object (`pools` array).
    pub fn diag(&mut self, pool: Option<&str>, lane: Option<u64>) -> Result<Value> {
        let mut pairs = vec![("op", Value::str("diag"))];
        if let Some(p) = pool {
            pairs.push(("pool", Value::str(p)));
        }
        if let Some(l) = lane {
            pairs.push(("lane", Value::num(l as f64)));
        }
        self.call(&Value::obj(pairs))
    }

    /// Watchdog health snapshot (docs/PROTOCOL.md §health): `status`
    /// gauge (1 healthy / 0 degraded), retained `events`, per-kind
    /// `counts`. Returns the raw response object.
    pub fn health(&mut self) -> Result<Value> {
        self.call(&Value::obj(vec![("op", Value::str("health"))]))
    }

    /// The full stats tree in Prometheus text exposition format
    /// (docs/PROTOCOL.md §metrics) — scrape-ready, content type
    /// `text/plain; version=0.0.4`.
    pub fn metrics(&mut self) -> Result<String> {
        let v = self.call(&Value::obj(vec![("op", Value::str("metrics"))]))?;
        Ok(v.req("text")?.as_str()?.to_string())
    }

    /// Run a generate synchronously (blocks until the samples are done).
    pub fn run(&mut self, req: &GenerateRequest) -> Result<ClientGenResult> {
        let v = self.call(&req.body("generate"))?;
        let bin = self.take_frame(&v)?;
        parse_client_gen(&v, bin)
    }

    /// Run an evaluate synchronously.
    pub fn run_eval(&mut self, req: &EvalRequest) -> Result<ClientEvalResult> {
        let v = self.call(&req.body("evaluate"))?;
        parse_client_eval(&v)
    }

    /// Submit a generate asynchronously; returns the job id to `poll`
    /// for. The request's `binary` flag applies at delivery (pass the
    /// same preference to `poll`).
    pub fn submit(&mut self, req: &GenerateRequest) -> Result<u64> {
        let mut body = req.body("submit");
        body.set("kind", Value::str("generate"));
        let v = self.call(&body)?;
        Ok(v.req("job")?.as_f64()? as u64)
    }

    /// Submit an evaluate asynchronously; returns the job id.
    pub fn submit_eval(&mut self, req: &EvalRequest) -> Result<u64> {
        let mut body = req.body("submit");
        body.set("kind", Value::str("evaluate"));
        let v = self.call(&body)?;
        Ok(v.req("job")?.as_f64()? as u64)
    }

    /// Re-run a generation spec every `rate_ms` until canceled; the
    /// newest rounds are retained ring-buffer style and drained by
    /// `poll`. Returns the job id.
    pub fn periodic(&mut self, req: &GenerateRequest, rate_ms: u64) -> Result<u64> {
        let mut body = req.body("periodic");
        body.set("rate_ms", Value::num(rate_ms as f64));
        let v = self.call(&body)?;
        Ok(v.req("job")?.as_f64()? as u64)
    }

    /// Drain completed jobs (each delivered exactly once).
    /// `timeout_ms` = 0 returns immediately; otherwise blocks until at
    /// least one update or the timeout. `binary` asks for raw payload
    /// frames instead of base64.
    pub fn poll(&mut self, timeout_ms: u64, binary: bool) -> Result<Vec<JobUpdate>> {
        self.poll_inner(None, timeout_ms, binary)
    }

    /// [`Client::poll`] filtered to one job id; unknown ids (never
    /// issued or already delivered) are an `unknown_job` error.
    pub fn poll_job(&mut self, job: u64, timeout_ms: u64, binary: bool) -> Result<Vec<JobUpdate>> {
        self.poll_inner(Some(job), timeout_ms, binary)
    }

    fn poll_inner(
        &mut self,
        job: Option<u64>,
        timeout_ms: u64,
        binary: bool,
    ) -> Result<Vec<JobUpdate>> {
        let mut pairs = vec![
            ("op", Value::str("poll")),
            ("timeout_ms", Value::num(timeout_ms as f64)),
            ("binary", Value::Bool(binary)),
        ];
        if let Some(j) = job {
            pairs.push(("job", Value::num(j as f64)));
        }
        let v = self.call(&Value::obj(pairs))?;
        let mut out = Vec::new();
        for u in v.req("jobs")?.as_arr()? {
            let job = u.req("job")?.as_f64()? as u64;
            let op = u.req("op")?.as_str()?.to_string();
            let round = u.get("round").map(|r| r.as_f64()).transpose()?.map(|r| r as u64);
            if !u.req("ok")?.as_bool()? {
                out.push(JobUpdate {
                    job,
                    op,
                    round,
                    code: u.get("code").and_then(|c| c.as_str().ok()).map(String::from),
                    error: Some(
                        u.get("error")
                            .and_then(|e| e.as_str().ok())
                            .unwrap_or("unknown")
                            .to_string(),
                    ),
                    gen: None,
                    eval: None,
                });
                continue;
            }
            let (gen, eval) = if op == "evaluate" {
                (None, Some(parse_client_eval(u)?))
            } else {
                let bin = self.take_frame(u)?;
                (Some(parse_client_gen(u, bin)?), None)
            };
            out.push(JobUpdate { job, op, round, code: None, error: None, gen, eval });
        }
        Ok(out)
    }

    /// Cancel a job: `Ok(true)` = freed while still fully queued
    /// (quota/queue_depth released), `Ok(false)` = holds a lane (or is
    /// an eval job) and runs to completion, staying pollable. Unknown
    /// or already-completed jobs are an `unknown_job` error.
    pub fn cancel(&mut self, job: u64) -> Result<bool> {
        let v = self.call(&Value::obj(vec![
            ("op", Value::str("cancel")),
            ("job", Value::num(job as f64)),
        ]))?;
        v.req("canceled")?.as_bool()
    }

    // --- deprecated positional surface (pre-builder) ----------------------

    #[deprecated(note = "use Client::run with GenerateRequest::new(n)")]
    pub fn generate(
        &mut self,
        n: usize,
        eps_rel: f64,
        seed: u64,
        want_images: bool,
    ) -> Result<ClientGenResult> {
        self.run(&GenerateRequest::new(n).eps_rel(eps_rel).seed(seed).images(want_images))
    }

    #[deprecated(note = "use Client::run with GenerateRequest::new(n).model(..)")]
    pub fn generate_on(
        &mut self,
        model: &str,
        n: usize,
        eps_rel: f64,
        seed: u64,
        want_images: bool,
    ) -> Result<ClientGenResult> {
        self.run(
            &GenerateRequest::new(n)
                .model(model)
                .eps_rel(eps_rel)
                .seed(seed)
                .images(want_images),
        )
    }

    #[deprecated(note = "use Client::run with GenerateRequest::new(n).model(..).solver(..)")]
    pub fn generate_spec(
        &mut self,
        model: &str,
        solver: &str,
        n: usize,
        eps_rel: f64,
        seed: u64,
        want_images: bool,
    ) -> Result<ClientGenResult> {
        self.run(
            &GenerateRequest::new(n)
                .model(model)
                .solver(solver)
                .eps_rel(eps_rel)
                .seed(seed)
                .images(want_images),
        )
    }

    #[deprecated(note = "use Client::run with GenerateRequest's priority/deadline_ms builders")]
    pub fn generate_qos(
        &mut self,
        model: &str,
        solver: &str,
        n: usize,
        eps_rel: f64,
        seed: u64,
        priority: &str,
        deadline_ms: u64,
        want_images: bool,
    ) -> Result<ClientGenResult> {
        self.run(
            &GenerateRequest::new(n)
                .model(model)
                .solver(solver)
                .eps_rel(eps_rel)
                .seed(seed)
                .priority(priority)
                .deadline_ms(deadline_ms)
                .images(want_images),
        )
    }

    #[deprecated(note = "use Client::run_eval with EvalRequest::new(samples)")]
    pub fn evaluate(
        &mut self,
        model: &str,
        solver: &str,
        samples: usize,
        eps_rel: f64,
        seed: u64,
    ) -> Result<ClientEvalResult> {
        self.run_eval(&EvalRequest::new(samples).model(model).solver(solver).eps_rel(eps_rel).seed(seed))
    }

    #[deprecated(note = "use Client::run_eval with EvalRequest's priority builder")]
    pub fn evaluate_qos(
        &mut self,
        model: &str,
        solver: &str,
        samples: usize,
        eps_rel: f64,
        seed: u64,
        priority: &str,
    ) -> Result<ClientEvalResult> {
        self.run_eval(
            &EvalRequest::new(samples)
                .model(model)
                .solver(solver)
                .eps_rel(eps_rel)
                .seed(seed)
                .priority(priority),
        )
    }
}
