//! JSON-lines TCP serving front-end + client library.
//!
//! Protocol (one JSON object per line, both directions):
//!   -> {"op":"generate","n":16,"eps_rel":0.05,"seed":7,"model":"vp",
//!       "solver":"adaptive","priority":"interactive","deadline_ms":2000}
//!   <- {"ok":true,"model":"vp","solver":"adaptive","n":16,"h":16,
//!       "w":16,"nfe":[...],"wall_s":...,"queued_s":...,
//!       "images_b64":"<f32-le raw, base64>"}
//!   -> {"op":"evaluate","samples":256,"eps_rel":0.05,"seed":7,
//!       "model":"vp","solver":"em:128","priority":"batch"}
//!   <- {"ok":true,"model":"vp","solver":"em:128","samples":256,
//!       "fid":...,"is":...,"mean_nfe":...,"wall_s":...,
//!       "steps_per_bucket":{"<bucket>":steps,...}}
//!   -> {"op":"stats"}
//!   <- {"ok":true,"requests_done":...,"models":[...],
//!       "programs":{"adaptive":{"pools":...,"active_lanes":...,
//!         "queue_depth":...,
//!         "steps":...,"occupied_lane_steps":...,"wasted_lane_steps":...,
//!         "score_evals":...,"migrations_up":...,"migrations_down":...,
//!         "steps_per_bucket":{"<bucket>":steps,...}},"em":{...},...},
//!       "steps_per_bucket":{"<bucket>":steps,...},
//!       "migrations_up":...,"migrations_down":...,
//!       "wasted_lane_steps":...,"occupied_lane_steps":...,
//!       "dispatches":...,"bytes_h2d":...,"bytes_d2h":...,
//!       "evals_done":...,"eval_active":...,"eval_samples_done":...,
//!       "eval_lane_steps":...,
//!       "queue_depth":...,
//!       "qos":{"shed_deadline":...,"rejected_quota":...,
//!         "pools":{"<model>/<solver>":{"weight":...,"turns":...,
//!           "steps":...,"occupied_lane_steps":...,"queue_depth":...,
//!           "active_lanes":...},...},
//!         "classes":{"interactive":{"requests_done":...,
//!           "queue_wait_p50_s":...,"queue_wait_p95_s":...,
//!           "queue_wait_p99_s":...,"e2e_p50_s":...,"e2e_p95_s":...,
//!           "e2e_p99_s":...},"batch":{...}}},...}
//!   -> {"op":"ping"} / <- {"ok":true}
//!
//! Error responses are `{"ok":false,"error":"<message>"}`; structured
//! rejections additionally carry a machine-readable `"code"`:
//! `"queue_full"` (global cap), `"quota_exceeded"` (per-model admission
//! quota), `"deadline_exceeded"` (request shed after its `deadline_ms`
//! expired while still queued), `"bad_solver"` (malformed or degenerate
//! solver spec: unknown name, zero-step fixed schedule, non-positive or
//! non-finite Langevin `snr`).
//!
//! QoS fields (docs/ARCHITECTURE.md §Admission & QoS):
//! * `priority` (optional on `generate` and `evaluate`; `"interactive"`
//!   or `"batch"`, default = the server's `--default-priority`) —
//!   interactive requests are queued ahead of batch within their pool;
//!   the class never changes a sample's content, only its wait.
//! * `deadline_ms` (optional on `generate`; 0 or absent = no deadline)
//!   — a request still fully queued when the deadline expires is shed
//!   with `code:"deadline_exceeded"` instead of burning lane time; once
//!   any sample holds a lane the request runs to completion. `evaluate`
//!   rejects the field (evaluation jobs run to completion).
//! * `queue_depth` in `stats` is the QoS-standard alias of
//!   `queued_samples` (kept for compatibility); the per-pool and
//!   per-program splits exist only under the new names.
//!
//! Dispatch/transfer counters in `stats` — `dispatches` (executable
//! launches), `bytes_h2d`, `bytes_d2h` — expose the host↔device traffic
//! the fused k-step path amortises (serve `--steps-per-dispatch`,
//! docs/ARCHITECTURE.md §Device-resident lane state): at k > 1 the
//! fixed-step pools keep lane state device-resident and launch one
//! executable per k grid nodes, so `dispatches` and per-sample bytes
//! fall roughly k-fold while `score_evals` and the sample bits stay
//! identical to k = 1.
//!
//! `model` is optional and defaults to the engine's first configured
//! model; the response `h`/`w` are the geometry of the model that
//! actually served the request.
//!
//! `solver` (optional on both `generate` and `evaluate`, default
//! "adaptive") is a solver spec parsed by `solvers::spec::parse` — the
//! same parser `gofast evaluate` and `gofast serve --solvers` use, so
//! the accepted names and defaults cannot drift between the CLI and the
//! wire: `"adaptive"` (Algorithm 1, per-lane step sizes; `eps_rel` is
//! its tolerance knob), `"em[:<steps>]"`, `"ddim[:<steps>]"` and
//! `"pc[:<steps>[@<snr>]]"` (fixed uniform schedules, default 256
//! steps; `ddim` is VP-only and a request against a non-VP model gets a
//! clean `ok:false` protocol error at admission). `pc` is Song et
//! al.'s Reverse-Diffusion + Langevin predictor–corrector: `<steps>`
//! predictor steps at 2 score evals each (reported NFE = 2 x steps +
//! the denoise call), with the Langevin corrector targeting the
//! optional `@<snr>` signal-to-noise ratio — omitted, the serving
//! process's default applies (0.16 VE / 0.01 VP, Song et al.). A spec
//! with `snr <= 0`, a non-finite snr, or zero steps is rejected with
//! `code:"bad_solver"`. Each (model, solver) pair is served by its own
//! lane-program pool behind the bucket scheduler (docs/ARCHITECTURE.md
//! §Solver-program pools), so mixed solver traffic co-batches on one
//! engine thread. The response echoes the canonical spec string.
//!
//! `evaluate` runs FID*/IS* *through the serving path*: its samples are
//! admitted as evaluation lanes onto the named solver's pool through
//! the same scheduler/registry machinery as `generate` traffic
//! (docs/ARCHITECTURE.md §Evaluation). `eps_rel` defaults to the
//! server's solver tolerance, `samples` to 256 (must be >= 2: FID needs
//! a non-singular feature covariance). The response `steps_per_bucket`
//! counts the fused steps the serving pool ran while the job was in
//! flight (shared with concurrent traffic on the same pool); `fid`/`is`
//! use the in-tree synthception feature net (values comparable within
//! this repo only).
//!
//! The `stats` op reports, besides the aggregate counters, a
//! `programs` object keyed by solver name with that program's pool
//! count, live lanes, queued samples, fused step executions,
//! occupied/wasted lane-steps, useful score evaluations (occupied
//! lane-steps x the program's per-step NFE cost), migration counters
//! and per-bucket step counts — the per-program breakdown of the
//! aggregate `steps_per_bucket` / `*_lane_steps` fields. `evals_done` /
//! `eval_active` / `eval_samples_done` / `eval_lane_steps` expose the
//! eval-lane share of engine work. `queue_depth` is the global count of
//! samples awaiting a lane; the `qos` object breaks it down per
//! (model, solver) pool next to each pool's configured weight and
//! service-turn share, and reports per-priority-class queue-wait and
//! end-to-end latency percentiles plus the deadline-shed / quota-reject
//! counters.
//!
//! One OS thread per connection (requests within a connection pipeline
//! through the shared engine, which does the real batching).

pub mod b64;

use crate::coordinator::{qos, EngineClient, EngineStats, EvalRequest, SampleRequest};
use crate::json::{self, Value};
use crate::solvers::spec;
use crate::{anyhow, bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

pub struct ServerConfig {
    pub port: u16,
    /// eps_rel applied when a generate request omits the field.
    pub default_eps_rel: f64,
}

/// Serve forever (each connection on its own thread).
pub fn serve(listener: TcpListener, engine: EngineClient, cfg: ServerConfig) -> Result<()> {
    let cfg = std::sync::Arc::new(cfg);
    for stream in listener.incoming() {
        let stream = stream?;
        let engine = engine.clone();
        let cfg = cfg.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, engine, &cfg) {
                eprintln!("[server] connection error: {e:#}");
            }
        });
    }
    Ok(())
}

pub fn handle_conn(
    stream: TcpStream,
    engine: EngineClient,
    cfg: &ServerConfig,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        if line.trim().is_empty() {
            continue;
        }
        let resp = match handle_request(&line, &engine, cfg) {
            Ok(v) => v,
            Err(e) => {
                let msg = format!("{e:#}");
                let mut pairs = vec![("ok", Value::Bool(false))];
                // structured rejections (quota / queue cap / deadline
                // shed) carry a machine-readable code next to the text
                if let Some(code) = qos::error_code(&msg) {
                    pairs.push(("code", Value::str(code)));
                }
                pairs.push(("error", Value::str(msg)));
                Value::obj(pairs)
            }
        };
        writeln!(writer, "{resp}")?;
    }
}

/// Optional `priority` field ("interactive" | "batch"); `None` defers
/// to the engine's configured default class.
fn parse_priority(req: &Value) -> Result<Option<qos::Priority>> {
    req.get("priority")
        .map(|v| qos::Priority::parse(v.as_str()?))
        .transpose()
}

/// Wire-layer solver-spec parse: a malformed spec (unknown name,
/// `em:0`, `pc:64@0`, ...) is a structured `bad_solver` rejection, so
/// clients can distinguish it from load-dependent errors.
fn parse_solver(s: &str) -> Result<crate::solvers::ServingSolver> {
    spec::parse(s).map_err(|e| anyhow!("{}", qos::coded(qos::CODE_BAD_SOLVER, &format!("{e:#}"))))
}

fn handle_request(line: &str, engine: &EngineClient, cfg: &ServerConfig) -> Result<Value> {
    let req = json::parse(line).context("parsing request json")?;
    match req.req("op")?.as_str()? {
        "ping" => Ok(Value::obj(vec![("ok", Value::Bool(true))])),
        "stats" => {
            let s = engine.stats()?;
            Ok(stats_to_json(&s))
        }
        "generate" => {
            let n = req.get("n").map(|v| v.as_usize()).transpose()?.unwrap_or(1);
            let eps_rel = req
                .get("eps_rel")
                .map(|v| v.as_f64())
                .transpose()?
                .unwrap_or(cfg.default_eps_rel);
            let seed = req.get("seed").map(|v| v.as_f64()).transpose()?.unwrap_or(0.0) as u64;
            let model =
                req.get("model").map(|v| v.as_str()).transpose()?.unwrap_or("").to_string();
            let solver =
                parse_solver(req.get("solver").map(|v| v.as_str()).transpose()?.unwrap_or(""))?;
            let want_images =
                req.get("images").map(|v| v.as_bool()).transpose()?.unwrap_or(true);
            let priority = parse_priority(&req)?;
            // 0 means "no deadline", matching Client::generate_qos and
            // the CLI --deadline-ms convention — not "shed immediately"
            let deadline_ms = req
                .get("deadline_ms")
                .map(|v| v.as_f64())
                .transpose()?
                .map(|v| v as u64)
                .filter(|&d| d > 0);
            let r = engine.generate_request(SampleRequest {
                model,
                solver,
                n,
                eps_rel,
                seed,
                sample_base: 0,
                priority,
                deadline_ms,
            })?;
            let mut pairs = vec![
                ("ok", Value::Bool(true)),
                // the model that actually served it (resolved default)
                ("model", Value::str(r.model)),
                ("solver", Value::str(solver.spec_string())),
                ("n", Value::num(n as f64)),
                ("h", Value::num(r.h as f64)),
                ("w", Value::num(r.w as f64)),
                ("wall_s", Value::num(r.wall_s)),
                ("queued_s", Value::num(r.queued_s)),
                (
                    "nfe",
                    Value::Arr(r.nfe.iter().map(|&v| Value::num(v as f64)).collect()),
                ),
            ];
            if want_images {
                let bytes: Vec<u8> =
                    r.images.data.iter().flat_map(|v| v.to_le_bytes()).collect();
                pairs.push(("images_b64", Value::str(b64::encode(&bytes))));
            }
            Ok(Value::obj(pairs))
        }
        "evaluate" => {
            let samples = req.get("samples").map(|v| v.as_usize()).transpose()?.unwrap_or(256);
            let eps_rel = req
                .get("eps_rel")
                .map(|v| v.as_f64())
                .transpose()?
                .unwrap_or(cfg.default_eps_rel);
            let seed = req.get("seed").map(|v| v.as_f64()).transpose()?.unwrap_or(0.0) as u64;
            let model =
                req.get("model").map(|v| v.as_str()).transpose()?.unwrap_or("").to_string();
            let solver =
                parse_solver(req.get("solver").map(|v| v.as_str()).transpose()?.unwrap_or(""))?;
            let priority = parse_priority(&req)?;
            if req.get("deadline_ms").is_some() {
                bail!(
                    "deadline_ms is not supported on evaluate (deadlines shed queued \
                     generate requests; evaluation jobs run to completion)"
                );
            }
            let r = engine
                .evaluate(EvalRequest { model, solver, samples, eps_rel, seed, priority })?;
            Ok(Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("model", Value::str(r.model)),
                ("solver", Value::str(r.solver)),
                ("samples", Value::num(r.samples as f64)),
                ("fid", Value::num(r.fid)),
                ("is", Value::num(r.is)),
                ("mean_nfe", Value::num(r.mean_nfe)),
                ("wall_s", Value::num(r.wall_s)),
                ("steps_per_bucket", buckets_obj(&r.steps_per_bucket)),
            ]))
        }
        other => Err(anyhow!("unknown op '{other}'")),
    }
}

fn buckets_obj(per: &[(usize, u64)]) -> Value {
    Value::Obj(per.iter().map(|(b, n)| (b.to_string(), Value::num(*n as f64))).collect())
}

fn stats_to_json(s: &EngineStats) -> Value {
    Value::obj(vec![
        ("ok", Value::Bool(true)),
        ("requests_done", Value::num(s.requests_done as f64)),
        ("samples_done", Value::num(s.samples_done as f64)),
        ("queued_samples", Value::num(s.queued_samples as f64)),
        ("active_slots", Value::num(s.active_slots as f64)),
        ("steps", Value::num(s.steps as f64)),
        ("rejections", Value::num(s.rejections as f64)),
        ("score_evals", Value::num(s.score_evals as f64)),
        ("dispatches", Value::num(s.dispatches as f64)),
        ("bytes_h2d", Value::num(s.bytes_h2d as f64)),
        ("bytes_d2h", Value::num(s.bytes_d2h as f64)),
        ("latency_p50_s", Value::num(s.latency_p50_s)),
        ("latency_p95_s", Value::num(s.latency_p95_s)),
        ("latency_mean_s", Value::num(s.latency_mean_s)),
        ("mean_occupancy", Value::num(s.mean_occupancy)),
        ("models", Value::Arr(s.models.iter().map(|m| Value::str(m.clone())).collect())),
        (
            "programs",
            Value::Obj(
                s.programs
                    .iter()
                    .map(|p| {
                        (
                            p.solver.clone(),
                            Value::obj(vec![
                                ("pools", Value::num(p.pools as f64)),
                                ("active_lanes", Value::num(p.active_lanes as f64)),
                                ("queue_depth", Value::num(p.queue_depth as f64)),
                                ("steps", Value::num(p.steps as f64)),
                                (
                                    "occupied_lane_steps",
                                    Value::num(p.occupied_lane_steps as f64),
                                ),
                                ("wasted_lane_steps", Value::num(p.wasted_lane_steps as f64)),
                                ("score_evals", Value::num(p.score_evals as f64)),
                                ("migrations_up", Value::num(p.migrations_up as f64)),
                                ("migrations_down", Value::num(p.migrations_down as f64)),
                                ("steps_per_bucket", buckets_obj(&p.steps_per_bucket)),
                            ]),
                        )
                    })
                    .collect(),
            ),
        ),
        ("steps_per_bucket", buckets_obj(&s.steps_per_bucket)),
        ("migrations_up", Value::num(s.migrations_up as f64)),
        ("migrations_down", Value::num(s.migrations_down as f64)),
        ("wasted_lane_steps", Value::num(s.wasted_lane_steps as f64)),
        ("occupied_lane_steps", Value::num(s.occupied_lane_steps as f64)),
        ("evals_done", Value::num(s.evals_done as f64)),
        ("eval_active", Value::num(s.eval_active as f64)),
        ("eval_samples_done", Value::num(s.eval_samples_done as f64)),
        ("eval_lane_steps", Value::num(s.eval_lane_steps as f64)),
        // QoS-standard alias of queued_samples (kept above for compat)
        ("queue_depth", Value::num(s.queued_samples as f64)),
        (
            "qos",
            Value::obj(vec![
                ("shed_deadline", Value::num(s.shed_deadline as f64)),
                ("rejected_quota", Value::num(s.rejected_quota as f64)),
                (
                    "pools",
                    Value::Obj(
                        s.pool_qos
                            .iter()
                            .map(|p| {
                                (
                                    format!("{}/{}", p.model, p.solver),
                                    Value::obj(vec![
                                        ("weight", Value::num(p.weight)),
                                        ("turns", Value::num(p.turns as f64)),
                                        ("steps", Value::num(p.steps as f64)),
                                        (
                                            "occupied_lane_steps",
                                            Value::num(p.occupied_lane_steps as f64),
                                        ),
                                        ("queue_depth", Value::num(p.queue_depth as f64)),
                                        ("active_lanes", Value::num(p.active_lanes as f64)),
                                    ]),
                                )
                            })
                            .collect(),
                    ),
                ),
                (
                    "classes",
                    Value::Obj(
                        s.classes
                            .iter()
                            .map(|c| {
                                (
                                    c.class.clone(),
                                    Value::obj(vec![
                                        ("requests_done", Value::num(c.requests_done as f64)),
                                        ("queue_wait_p50_s", Value::num(c.queue_wait_p50_s)),
                                        ("queue_wait_p95_s", Value::num(c.queue_wait_p95_s)),
                                        ("queue_wait_p99_s", Value::num(c.queue_wait_p99_s)),
                                        ("e2e_p50_s", Value::num(c.e2e_p50_s)),
                                        ("e2e_p95_s", Value::num(c.e2e_p95_s)),
                                        ("e2e_p99_s", Value::num(c.e2e_p99_s)),
                                    ]),
                                )
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
    ])
}

// --- client ---------------------------------------------------------------------

/// Blocking JSON-lines client for the serving protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

#[derive(Clone, Debug)]
pub struct ClientGenResult {
    pub images: crate::tensor::Tensor,
    pub nfe: Vec<u64>,
    pub wall_s: f64,
    pub queued_s: f64,
}

/// Parsed `evaluate` response (wire format in the module docs).
#[derive(Clone, Debug)]
pub struct ClientEvalResult {
    pub model: String,
    pub solver: String,
    pub samples: usize,
    pub fid: f64,
    pub is: f64,
    pub mean_nfe: f64,
    pub wall_s: f64,
    /// Fused steps per pool width consumed while the run was in flight.
    pub steps_per_bucket: Vec<(usize, u64)>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    fn call(&mut self, req: &Value) -> Result<Value> {
        writeln!(self.writer, "{req}")?;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(anyhow!("server closed connection"));
        }
        let v = json::parse(&line)?;
        if !v.req("ok")?.as_bool()? {
            // the error text already embeds the code prefix for
            // structured rejections; surface the field anyway so
            // callers matching on "[quota_exceeded]" etc. are not
            // parsing prose
            let code = v
                .get("code")
                .and_then(|c| c.as_str().ok())
                .map(|c| format!(" [{c}]"))
                .unwrap_or_default();
            return Err(anyhow!(
                "server error{code}: {}",
                v.get("error").and_then(|e| e.as_str().ok()).unwrap_or("unknown")
            ));
        }
        Ok(v)
    }

    pub fn ping(&mut self) -> Result<()> {
        self.call(&Value::obj(vec![("op", Value::str("ping"))]))?;
        Ok(())
    }

    pub fn stats(&mut self) -> Result<Value> {
        self.call(&Value::obj(vec![("op", Value::str("stats"))]))
    }

    pub fn generate(
        &mut self,
        n: usize,
        eps_rel: f64,
        seed: u64,
        want_images: bool,
    ) -> Result<ClientGenResult> {
        self.generate_on("", n, eps_rel, seed, want_images)
    }

    /// Generate on a named model ("" = the server's default model) with
    /// the adaptive solver.
    pub fn generate_on(
        &mut self,
        model: &str,
        n: usize,
        eps_rel: f64,
        seed: u64,
        want_images: bool,
    ) -> Result<ClientGenResult> {
        self.generate_spec(model, "", n, eps_rel, seed, want_images)
    }

    /// Generate with an explicit solver spec ("adaptive", "em:<n>",
    /// "ddim:<n>", "pc:<n>[@<snr>]"; "" = the server default, adaptive).
    pub fn generate_spec(
        &mut self,
        model: &str,
        solver: &str,
        n: usize,
        eps_rel: f64,
        seed: u64,
        want_images: bool,
    ) -> Result<ClientGenResult> {
        self.generate_qos(model, solver, n, eps_rel, seed, "", 0, want_images)
    }

    /// Generate with QoS controls: `priority` is "interactive"/"batch"
    /// ("" = the server's default class); `deadline_ms` > 0 sheds the
    /// request if it is still fully queued when the deadline expires
    /// (0 = no deadline).
    pub fn generate_qos(
        &mut self,
        model: &str,
        solver: &str,
        n: usize,
        eps_rel: f64,
        seed: u64,
        priority: &str,
        deadline_ms: u64,
        want_images: bool,
    ) -> Result<ClientGenResult> {
        let mut pairs = vec![
            ("op", Value::str("generate")),
            ("n", Value::num(n as f64)),
            ("eps_rel", Value::num(eps_rel)),
            ("seed", Value::num(seed as f64)),
            ("images", Value::Bool(want_images)),
        ];
        if !model.is_empty() {
            pairs.push(("model", Value::str(model)));
        }
        if !solver.is_empty() {
            pairs.push(("solver", Value::str(solver)));
        }
        if !priority.is_empty() {
            pairs.push(("priority", Value::str(priority)));
        }
        if deadline_ms > 0 {
            pairs.push(("deadline_ms", Value::num(deadline_ms as f64)));
        }
        let req = Value::obj(pairs);
        let v = self.call(&req)?;
        let nfe = v
            .req("nfe")?
            .as_arr()?
            .iter()
            .map(|x| Ok(x.as_f64()? as u64))
            .collect::<Result<Vec<_>>>()?;
        let (h, w) = (v.req("h")?.as_usize()?, v.req("w")?.as_usize()?);
        let images = if want_images {
            let bytes = b64::decode(v.req("images_b64")?.as_str()?)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            crate::tensor::Tensor::from_vec(&[n, h * w * 3], data)?
        } else {
            crate::tensor::Tensor::zeros(&[0])
        };
        Ok(ClientGenResult {
            images,
            nfe,
            wall_s: v.req("wall_s")?.as_f64()?,
            queued_s: v.req("queued_s")?.as_f64()?,
        })
    }

    /// FID*/IS* evaluation served through the engine ("" model/solver =
    /// the server defaults; solver specs: "adaptive", "em:<n>",
    /// "ddim:<n>", "pc:<n>[@<snr>]").
    pub fn evaluate(
        &mut self,
        model: &str,
        solver: &str,
        samples: usize,
        eps_rel: f64,
        seed: u64,
    ) -> Result<ClientEvalResult> {
        self.evaluate_qos(model, solver, samples, eps_rel, seed, "")
    }

    /// [`Client::evaluate`] with an explicit priority class
    /// ("interactive"/"batch"; "" = the server's default). Mark bulk
    /// evaluation runs "batch" so interactive traffic on the same pool
    /// is admitted first.
    pub fn evaluate_qos(
        &mut self,
        model: &str,
        solver: &str,
        samples: usize,
        eps_rel: f64,
        seed: u64,
        priority: &str,
    ) -> Result<ClientEvalResult> {
        let mut pairs = vec![
            ("op", Value::str("evaluate")),
            ("samples", Value::num(samples as f64)),
            ("eps_rel", Value::num(eps_rel)),
            ("seed", Value::num(seed as f64)),
        ];
        if !model.is_empty() {
            pairs.push(("model", Value::str(model)));
        }
        if !solver.is_empty() {
            pairs.push(("solver", Value::str(solver)));
        }
        if !priority.is_empty() {
            pairs.push(("priority", Value::str(priority)));
        }
        let v = self.call(&Value::obj(pairs))?;
        let mut steps_per_bucket = v
            .req("steps_per_bucket")?
            .members()
            .iter()
            .map(|(b, n)| {
                Ok((
                    b.parse::<usize>().map_err(|_| anyhow!("bad bucket key '{b}'"))?,
                    n.as_f64()? as u64,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        steps_per_bucket.sort();
        Ok(ClientEvalResult {
            model: v.req("model")?.as_str()?.to_string(),
            solver: v.req("solver")?.as_str()?.to_string(),
            samples: v.req("samples")?.as_usize()?,
            fid: v.req("fid")?.as_f64()?,
            is: v.req("is")?.as_f64()?,
            mean_nfe: v.req("mean_nfe")?.as_f64()?,
            wall_s: v.req("wall_s")?.as_f64()?,
            steps_per_bucket,
        })
    }
}
