//! Typed stats tree: one builder feeding both renderers — the `stats`
//! op's JSON object and the `metrics` op's Prometheus text exposition
//! (docs/PROTOCOL.md §stats, §metrics).
//!
//! The JSON shape is load-bearing (benches and check scripts parse it),
//! so [`StatsTree::to_json`] reproduces the historical key order
//! exactly and appends new telemetry keys after the original ones. The
//! Prometheus renderer maps the same leaves to `gofast_*` series:
//! histogram percentiles become `quantile`-labelled gauges with
//! `_count`/`_sum` counter companions, per-solver and per-pool
//! breakdowns become label dimensions instead of nested objects.

use super::jobs::JobStats;
use crate::coordinator::EngineStats;
use crate::json::Value;

/// Prometheus series type (the `# TYPE` line).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
}

impl Kind {
    fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
        }
    }
}

/// One leaf of the tree: a JSON key and/or a Prometheus series carrying
/// a single value. Either name may be empty — a compatibility alias is
/// JSON-only, a histogram `_count`/`_sum` companion is Prometheus-only.
pub struct Scalar {
    /// JSON key within the enclosing object ("" = Prometheus-only).
    pub key: &'static str,
    /// Prometheus metric name without the `gofast_` prefix
    /// ("" = JSON-only).
    pub prom: &'static str,
    pub kind: Kind,
    /// Rendered as a `quantile="..."` label on the series.
    pub quantile: Option<&'static str>,
    pub value: f64,
}

impl Scalar {
    fn counter(key: &'static str, prom: &'static str, value: f64) -> Scalar {
        Scalar { key, prom, kind: Kind::Counter, quantile: None, value }
    }

    fn gauge(key: &'static str, prom: &'static str, value: f64) -> Scalar {
        Scalar { key, prom, kind: Kind::Gauge, quantile: None, value }
    }

    fn quantile(key: &'static str, prom: &'static str, q: &'static str, value: f64) -> Scalar {
        Scalar { key, prom, kind: Kind::Gauge, quantile: Some(q), value }
    }

    fn json_only(key: &'static str, value: f64) -> Scalar {
        Scalar { key, prom: "", kind: Kind::Gauge, quantile: None, value }
    }

    fn prom_only(prom: &'static str, kind: Kind, value: f64) -> Scalar {
        Scalar { key: "", prom, kind, quantile: None, value }
    }
}

/// Per-solver-program breakdown (`programs` object, `solver` label).
pub struct ProgramNode {
    pub solver: String,
    pub scalars: Vec<Scalar>,
    pub steps_per_bucket: Vec<(usize, u64)>,
    /// Keys added after the historical shape froze (appended after
    /// `steps_per_bucket` in JSON so the original prefix is unchanged).
    pub extra: Vec<Scalar>,
}

/// Per-(model, solver) pool breakdown (`qos.pools` object,
/// `model`/`solver` labels).
pub struct PoolNode {
    pub model: String,
    pub solver: String,
    pub scalars: Vec<Scalar>,
    /// Step executions per bucket width
    /// (`gofast_pool_bucket_steps_total{model,solver,bucket}`).
    pub steps_per_bucket: Vec<(usize, u64)>,
}

/// Per-priority-class latency breakdown (`qos.classes` object, `class`
/// label).
pub struct ClassNode {
    pub class: String,
    pub scalars: Vec<Scalar>,
}

/// The full stats tree, one node per section of the wire shape, in
/// wire order.
pub struct StatsTree {
    pub root: Vec<Scalar>,
    pub models: Vec<String>,
    pub programs: Vec<ProgramNode>,
    pub steps_per_bucket: Vec<(usize, u64)>,
    /// Aggregate counters between `steps_per_bucket` and `jobs`.
    pub tail: Vec<Scalar>,
    pub jobs: Vec<Scalar>,
    pub qos_root: Vec<Scalar>,
    pub pools: Vec<PoolNode>,
    pub classes: Vec<ClassNode>,
    /// Watchdog summary (`health` object, appended after `qos`):
    /// the `gofast_health_status` gauge plus per-kind
    /// `gofast_health_events_total{kind}` counters.
    pub health: Vec<Scalar>,
    pub health_counts: Vec<(String, u64)>,
}

impl StatsTree {
    pub fn build(s: &EngineStats, j: &JobStats) -> StatsTree {
        let root = vec![
            Scalar::counter("requests_done", "requests_done_total", s.requests_done as f64),
            Scalar::counter("samples_done", "samples_done_total", s.samples_done as f64),
            Scalar::gauge("queued_samples", "queued_samples", s.queued_samples as f64),
            Scalar::gauge("active_slots", "active_slots", s.active_slots as f64),
            Scalar::counter("steps", "steps_total", s.steps as f64),
            // adaptive-only: fixed-step solvers never reject a proposal
            Scalar::counter("rejections", "adaptive_rejections_total", s.rejections as f64),
            Scalar::counter("score_evals", "score_evals_total", s.score_evals as f64),
            Scalar::counter("dispatches", "dispatches_total", s.dispatches as f64),
            Scalar::counter("bytes_h2d", "bytes_h2d_total", s.bytes_h2d as f64),
            Scalar::counter("bytes_d2h", "bytes_d2h_total", s.bytes_d2h as f64),
            Scalar::quantile("latency_p50_s", "request_latency_seconds", "0.5", s.latency_p50_s),
            Scalar::quantile("latency_p95_s", "request_latency_seconds", "0.95", s.latency_p95_s),
            Scalar::gauge("latency_mean_s", "request_latency_seconds_mean", s.latency_mean_s),
            Scalar::gauge("mean_occupancy", "mean_occupancy", s.mean_occupancy),
        ];
        let programs = s
            .programs
            .iter()
            .map(|p| ProgramNode {
                solver: p.solver.clone(),
                scalars: vec![
                    Scalar::gauge("pools", "program_pools", p.pools as f64),
                    Scalar::gauge("active_lanes", "program_active_lanes", p.active_lanes as f64),
                    Scalar::gauge("queue_depth", "program_queue_depth", p.queue_depth as f64),
                    Scalar::counter("steps", "program_steps_total", p.steps as f64),
                    Scalar::counter(
                        "occupied_lane_steps",
                        "program_occupied_lane_steps_total",
                        p.occupied_lane_steps as f64,
                    ),
                    Scalar::counter(
                        "wasted_lane_steps",
                        "program_wasted_lane_steps_total",
                        p.wasted_lane_steps as f64,
                    ),
                    Scalar::counter(
                        "score_evals",
                        "program_score_evals_total",
                        p.score_evals as f64,
                    ),
                    Scalar::counter(
                        "migrations_up",
                        "program_migrations_up_total",
                        p.migrations_up as f64,
                    ),
                    Scalar::counter(
                        "migrations_down",
                        "program_migrations_down_total",
                        p.migrations_down as f64,
                    ),
                ],
                steps_per_bucket: p.steps_per_bucket.clone(),
                // adaptive-only accept/reject (fixed-step pools stay 0)
                extra: vec![
                    Scalar::counter(
                        "accepted",
                        "program_adaptive_accepted_total",
                        p.accepted as f64,
                    ),
                    Scalar::counter(
                        "rejected",
                        "program_adaptive_rejected_total",
                        p.rejected as f64,
                    ),
                ],
            })
            .collect();
        let tail = vec![
            Scalar::counter("migrations_up", "migrations_up_total", s.migrations_up as f64),
            Scalar::counter("migrations_down", "migrations_down_total", s.migrations_down as f64),
            Scalar::counter(
                "wasted_lane_steps",
                "wasted_lane_steps_total",
                s.wasted_lane_steps as f64,
            ),
            Scalar::counter(
                "occupied_lane_steps",
                "occupied_lane_steps_total",
                s.occupied_lane_steps as f64,
            ),
            Scalar::counter("evals_done", "evals_done_total", s.evals_done as f64),
            Scalar::gauge("eval_active", "eval_active", s.eval_active as f64),
            Scalar::counter(
                "eval_samples_done",
                "eval_samples_done_total",
                s.eval_samples_done as f64,
            ),
            Scalar::counter("eval_lane_steps", "eval_lane_steps_total", s.eval_lane_steps as f64),
            // QoS-standard alias of queued_samples (kept for compat;
            // Prometheus already has gofast_queued_samples)
            Scalar::json_only("queue_depth", s.queued_samples as f64),
        ];
        let jobs = vec![
            Scalar::counter("submitted", "jobs_submitted_total", j.submitted as f64),
            Scalar::counter("delivered", "jobs_delivered_total", j.delivered as f64),
            Scalar::counter("canceled", "jobs_canceled_total", j.canceled as f64),
            Scalar::gauge("active", "jobs_active", j.active as f64),
            Scalar::gauge("periodic", "jobs_periodic", j.periodic as f64),
        ];
        let qos_root = vec![
            Scalar::counter("shed_deadline", "shed_deadline_total", s.shed_deadline as f64),
            Scalar::counter("rejected_quota", "rejected_quota_total", s.rejected_quota as f64),
            // still-queued submissions freed through the cancel op
            Scalar::counter("canceled", "canceled_total", s.canceled as f64),
        ];
        let pools = s
            .pool_qos
            .iter()
            .map(|p| {
                let proposals = p.accepted + p.rejected;
                let reject_rate =
                    if proposals > 0 { p.rejected as f64 / proposals as f64 } else { 0.0 };
                PoolNode {
                    model: p.model.clone(),
                    solver: p.solver.clone(),
                    scalars: vec![
                        Scalar::gauge("weight", "pool_weight", p.weight),
                        Scalar::counter("turns", "pool_turns_total", p.turns as f64),
                        Scalar::counter("steps", "pool_steps_total", p.steps as f64),
                        Scalar::counter(
                            "occupied_lane_steps",
                            "pool_occupied_lane_steps_total",
                            p.occupied_lane_steps as f64,
                        ),
                        Scalar::gauge("queue_depth", "pool_queue_depth", p.queue_depth as f64),
                        Scalar::gauge("active_lanes", "pool_active_lanes", p.active_lanes as f64),
                        // resolved fused k (adaptive: Algorithm-1
                        // attempts folded per launch)
                        Scalar::gauge(
                            "steps_per_dispatch",
                            "pool_steps_per_dispatch",
                            p.steps_per_dispatch as f64,
                        ),
                        // per-pool step-time summary: quantile gauges +
                        // count/sum companions
                        Scalar::counter("step_count", "pool_step_seconds_count", p.step_count as f64),
                        Scalar::counter("step_sum_s", "pool_step_seconds_sum", p.step_sum_s),
                        Scalar::quantile("step_p50_s", "pool_step_seconds", "0.5", p.step_p50_s),
                        Scalar::quantile("step_p95_s", "pool_step_seconds", "0.95", p.step_p95_s),
                        Scalar::quantile("step_p99_s", "pool_step_seconds", "0.99", p.step_p99_s),
                        // adaptive-only (fixed-step pools never reject)
                        Scalar::counter(
                            "accepted",
                            "pool_adaptive_accepted_total",
                            p.accepted as f64,
                        ),
                        Scalar::counter(
                            "rejected",
                            "pool_adaptive_rejected_total",
                            p.rejected as f64,
                        ),
                        Scalar::prom_only("pool_adaptive_reject_rate", Kind::Gauge, reject_rate),
                    ],
                    steps_per_bucket: p.steps_per_bucket.clone(),
                }
            })
            .collect();
        let classes = s
            .classes
            .iter()
            .map(|c| ClassNode {
                class: c.class.clone(),
                scalars: vec![
                    Scalar::counter(
                        "requests_done",
                        "class_requests_done_total",
                        c.requests_done as f64,
                    ),
                    Scalar::quantile(
                        "queue_wait_p50_s",
                        "class_queue_wait_seconds",
                        "0.5",
                        c.queue_wait_p50_s,
                    ),
                    Scalar::quantile(
                        "queue_wait_p95_s",
                        "class_queue_wait_seconds",
                        "0.95",
                        c.queue_wait_p95_s,
                    ),
                    Scalar::quantile(
                        "queue_wait_p99_s",
                        "class_queue_wait_seconds",
                        "0.99",
                        c.queue_wait_p99_s,
                    ),
                    Scalar::quantile("e2e_p50_s", "class_e2e_seconds", "0.5", c.e2e_p50_s),
                    Scalar::quantile("e2e_p95_s", "class_e2e_seconds", "0.95", c.e2e_p95_s),
                    Scalar::quantile("e2e_p99_s", "class_e2e_seconds", "0.99", c.e2e_p99_s),
                    // the JSON shape keeps its original keys; count/sum
                    // exist for the Prometheus summary convention only
                    Scalar::prom_only(
                        "class_queue_wait_seconds_count",
                        Kind::Counter,
                        c.queue_wait_count as f64,
                    ),
                    Scalar::prom_only(
                        "class_queue_wait_seconds_sum",
                        Kind::Counter,
                        c.queue_wait_sum_s,
                    ),
                    Scalar::prom_only("class_e2e_seconds_count", Kind::Counter, c.e2e_count as f64),
                    Scalar::prom_only("class_e2e_seconds_sum", Kind::Counter, c.e2e_sum_s),
                ],
            })
            .collect();
        StatsTree {
            root,
            models: s.models.clone(),
            programs,
            steps_per_bucket: s.steps_per_bucket.clone(),
            tail,
            jobs,
            qos_root,
            pools,
            classes,
            health: vec![Scalar::gauge("status", "health_status", s.health.status as f64)],
            health_counts: s.health.counts.clone(),
        }
    }

    /// The `stats` op's response object (historical shape, new keys
    /// appended after the original ones within each section).
    pub fn to_json(&self) -> Value {
        let mut root: Vec<(String, Value)> = vec![("ok".to_string(), Value::Bool(true))];
        push_json(&mut root, &self.root);
        root.push((
            "models".to_string(),
            Value::Arr(self.models.iter().map(|m| Value::str(m.clone())).collect()),
        ));
        root.push((
            "programs".to_string(),
            Value::Obj(
                self.programs
                    .iter()
                    .map(|p| {
                        let mut o: Vec<(String, Value)> = Vec::new();
                        push_json(&mut o, &p.scalars);
                        o.push(("steps_per_bucket".to_string(), buckets_obj(&p.steps_per_bucket)));
                        push_json(&mut o, &p.extra);
                        (p.solver.clone(), Value::Obj(o))
                    })
                    .collect(),
            ),
        ));
        root.push(("steps_per_bucket".to_string(), buckets_obj(&self.steps_per_bucket)));
        push_json(&mut root, &self.tail);
        root.push(("jobs".to_string(), scalars_obj(&self.jobs)));
        let mut qos: Vec<(String, Value)> = Vec::new();
        push_json(&mut qos, &self.qos_root);
        qos.push((
            "pools".to_string(),
            Value::Obj(
                self.pools
                    .iter()
                    .map(|p| {
                        let mut o: Vec<(String, Value)> = Vec::new();
                        push_json(&mut o, &p.scalars);
                        o.push(("steps_per_bucket".to_string(), buckets_obj(&p.steps_per_bucket)));
                        (format!("{}/{}", p.model, p.solver), Value::Obj(o))
                    })
                    .collect(),
            ),
        ));
        qos.push((
            "classes".to_string(),
            Value::Obj(
                self.classes.iter().map(|c| (c.class.clone(), scalars_obj(&c.scalars))).collect(),
            ),
        ));
        root.push(("qos".to_string(), Value::Obj(qos)));
        let mut health: Vec<(String, Value)> = Vec::new();
        push_json(&mut health, &self.health);
        health.push((
            "events".to_string(),
            Value::Obj(
                self.health_counts
                    .iter()
                    .map(|(k, n)| (k.clone(), Value::num(*n as f64)))
                    .collect(),
            ),
        ));
        root.push(("health".to_string(), Value::Obj(health)));
        Value::Obj(root)
    }

    /// The `metrics` op's Prometheus text exposition (format 0.0.4):
    /// every series under one `# TYPE` line, label dimensions replacing
    /// the JSON nesting.
    pub fn to_prometheus(&self) -> String {
        let mut series: Vec<Series> = Vec::new();
        emit(&mut series, &self.root, "");
        for p in &self.programs {
            let base = format!("solver=\"{}\"", escape(&p.solver));
            emit(&mut series, &p.scalars, &base);
            for &(b, n) in &p.steps_per_bucket {
                add(
                    &mut series,
                    "program_bucket_steps_total",
                    Kind::Counter,
                    format!("{base},bucket=\"{b}\""),
                    n as f64,
                );
            }
            emit(&mut series, &p.extra, &base);
        }
        for &(b, n) in &self.steps_per_bucket {
            add(
                &mut series,
                "bucket_steps_total",
                Kind::Counter,
                format!("bucket=\"{b}\""),
                n as f64,
            );
        }
        emit(&mut series, &self.tail, "");
        emit(&mut series, &self.jobs, "");
        emit(&mut series, &self.qos_root, "");
        for p in &self.pools {
            let base =
                format!("model=\"{}\",solver=\"{}\"", escape(&p.model), escape(&p.solver));
            emit(&mut series, &p.scalars, &base);
            for &(b, n) in &p.steps_per_bucket {
                add(
                    &mut series,
                    "pool_bucket_steps_total",
                    Kind::Counter,
                    format!("{base},bucket=\"{b}\""),
                    n as f64,
                );
            }
        }
        for c in &self.classes {
            let base = format!("class=\"{}\"", escape(&c.class));
            emit(&mut series, &c.scalars, &base);
        }
        emit(&mut series, &self.health, "");
        for (k, n) in &self.health_counts {
            add(
                &mut series,
                "health_events_total",
                Kind::Counter,
                format!("kind=\"{}\"", escape(k)),
                *n as f64,
            );
        }
        let mut out = String::new();
        for s in &series {
            out.push_str("# TYPE gofast_");
            out.push_str(&s.name);
            out.push(' ');
            out.push_str(s.kind.as_str());
            out.push('\n');
            for (labels, v) in &s.points {
                if labels.is_empty() {
                    out.push_str(&format!("gofast_{} {v}\n", s.name));
                } else {
                    out.push_str(&format!("gofast_{}{{{labels}}} {v}\n", s.name));
                }
            }
        }
        out
    }
}

fn push_json(out: &mut Vec<(String, Value)>, scalars: &[Scalar]) {
    for s in scalars {
        if !s.key.is_empty() {
            out.push((s.key.to_string(), Value::num(s.value)));
        }
    }
}

fn scalars_obj(scalars: &[Scalar]) -> Value {
    let mut o: Vec<(String, Value)> = Vec::new();
    push_json(&mut o, scalars);
    Value::Obj(o)
}

fn buckets_obj(per: &[(usize, u64)]) -> Value {
    Value::Obj(per.iter().map(|(b, n)| (b.to_string(), Value::num(*n as f64))).collect())
}

/// One Prometheus metric: all its (label set, value) points, grouped so
/// the text output has exactly one `# TYPE` line per name.
struct Series {
    name: String,
    kind: Kind,
    points: Vec<(String, f64)>,
}

fn add(series: &mut Vec<Series>, name: &str, kind: Kind, labels: String, value: f64) {
    match series.iter_mut().find(|s| s.name == name) {
        Some(s) => s.points.push((labels, value)),
        None => series.push(Series { name: name.to_string(), kind, points: vec![(labels, value)] }),
    }
}

fn emit(series: &mut Vec<Series>, scalars: &[Scalar], base: &str) {
    for s in scalars {
        if s.prom.is_empty() {
            continue;
        }
        let labels = match s.quantile {
            Some(q) if base.is_empty() => format!("quantile=\"{q}\""),
            Some(q) => format!("{base},quantile=\"{q}\""),
            None => base.to_string(),
        };
        add(series, s.prom, s.kind, labels, s.value);
    }
}

/// Prometheus label-value escaping: backslash, double quote, newline.
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ClassLatencyStats, HealthStats, PoolQosStats, ProgramStats};

    fn sample() -> (EngineStats, JobStats) {
        let s = EngineStats {
            requests_done: 10,
            samples_done: 40,
            queued_samples: 3,
            active_slots: 5,
            steps: 100,
            rejections: 7,
            score_evals: 200,
            dispatches: 90,
            bytes_h2d: 1000,
            bytes_d2h: 2000,
            latency_p50_s: 0.1,
            latency_p95_s: 0.5,
            latency_mean_s: 0.2,
            mean_occupancy: 3.5,
            models: vec!["vp".to_string()],
            programs: vec![ProgramStats {
                solver: "adaptive".to_string(),
                pools: 1,
                active_lanes: 4,
                queue_depth: 3,
                steps: 100,
                occupied_lane_steps: 350,
                wasted_lane_steps: 50,
                score_evals: 200,
                migrations_up: 2,
                migrations_down: 1,
                steps_per_bucket: vec![(8, 60), (16, 40)],
                accepted: 343,
                rejected: 7,
            }],
            steps_per_bucket: vec![(8, 60), (16, 40)],
            migrations_up: 2,
            migrations_down: 1,
            wasted_lane_steps: 50,
            occupied_lane_steps: 350,
            evals_done: 1,
            eval_active: 0,
            eval_samples_done: 16,
            eval_lane_steps: 120,
            pool_qos: vec![PoolQosStats {
                model: "vp".to_string(),
                solver: "adaptive".to_string(),
                weight: 1.0,
                turns: 20,
                steps: 100,
                occupied_lane_steps: 350,
                queue_depth: 3,
                active_lanes: 4,
                steps_per_dispatch: 8,
                step_count: 100,
                step_sum_s: 1.5,
                step_p50_s: 0.012,
                step_p95_s: 0.03,
                step_p99_s: 0.04,
                accepted: 343,
                rejected: 7,
                steps_per_bucket: vec![(8, 60), (16, 40)],
            }],
            classes: vec![ClassLatencyStats {
                class: "interactive".to_string(),
                requests_done: 10,
                queue_wait_p50_s: 0.01,
                queue_wait_p95_s: 0.05,
                queue_wait_p99_s: 0.06,
                e2e_p50_s: 0.1,
                e2e_p95_s: 0.5,
                e2e_p99_s: 0.6,
                queue_wait_count: 10,
                queue_wait_sum_s: 0.2,
                e2e_count: 10,
                e2e_sum_s: 2.0,
            }],
            shed_deadline: 1,
            rejected_quota: 2,
            canceled: 3,
            health: HealthStats {
                status: 1,
                counts: vec![
                    ("stall".to_string(), 2),
                    ("reject_spike".to_string(), 0),
                    ("queue_saturation".to_string(), 0),
                    ("step_time_drift".to_string(), 0),
                ],
            },
        };
        let j = JobStats { submitted: 4, delivered: 3, canceled: 1, active: 1, periodic: 1 };
        (s, j)
    }

    /// The wire contract: top-level JSON key order is frozen (parsers
    /// in benches/ and tools/ index into it), new keys only append
    /// within nested sections.
    #[test]
    fn json_preserves_historical_key_order() {
        let (s, j) = sample();
        let v = StatsTree::build(&s, &j).to_json();
        let keys: Vec<&str> = v.members().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(
            keys,
            vec![
                "ok",
                "requests_done",
                "samples_done",
                "queued_samples",
                "active_slots",
                "steps",
                "rejections",
                "score_evals",
                "dispatches",
                "bytes_h2d",
                "bytes_d2h",
                "latency_p50_s",
                "latency_p95_s",
                "latency_mean_s",
                "mean_occupancy",
                "models",
                "programs",
                "steps_per_bucket",
                "migrations_up",
                "migrations_down",
                "wasted_lane_steps",
                "occupied_lane_steps",
                "evals_done",
                "eval_active",
                "eval_samples_done",
                "eval_lane_steps",
                "queue_depth",
                "jobs",
                "qos",
                "health",
            ]
        );
        // nested sections: original prefixes intact, telemetry appended
        let prog = v.req("programs").unwrap().req("adaptive").unwrap();
        let pkeys: Vec<&str> = prog.members().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(
            &pkeys[..10],
            &[
                "pools",
                "active_lanes",
                "queue_depth",
                "steps",
                "occupied_lane_steps",
                "wasted_lane_steps",
                "score_evals",
                "migrations_up",
                "migrations_down",
                "steps_per_bucket",
            ]
        );
        assert_eq!(&pkeys[10..], &["accepted", "rejected"]);
        let pool = v.req("qos").unwrap().req("pools").unwrap().req("vp/adaptive").unwrap();
        let poolkeys: Vec<&str> = pool.members().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(
            &poolkeys[..6],
            &["weight", "turns", "steps", "occupied_lane_steps", "queue_depth", "active_lanes"]
        );
        assert!(poolkeys.contains(&"step_p95_s") && poolkeys.contains(&"accepted"));
        // per-pool bucket split appends after the frozen pool keys
        assert_eq!(poolkeys.last(), Some(&"steps_per_bucket"));
        assert_eq!(
            pool.req("steps_per_bucket").unwrap().req("8").unwrap().as_f64().unwrap(),
            60.0
        );
        // watchdog summary appends after qos
        let health = v.req("health").unwrap();
        assert_eq!(health.req("status").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(
            health.req("events").unwrap().req("stall").unwrap().as_f64().unwrap(),
            2.0
        );
        // classes keep their original keys only (count/sum are
        // Prometheus-only)
        let class = v.req("qos").unwrap().req("classes").unwrap().req("interactive").unwrap();
        assert!(class.get("queue_wait_p99_s").is_some());
        assert!(class.get("queue_wait_count").is_none());
        // queue_depth alias mirrors queued_samples
        assert_eq!(v.req("queue_depth").unwrap().as_f64().unwrap(), 3.0);
        // round-trips through the writer/parser
        let parsed = crate::json::parse(&v.to_string()).unwrap();
        assert_eq!(parsed.req("rejections").unwrap().as_f64().unwrap(), 7.0);
    }

    /// Every line of the exposition is `# TYPE` or `name{labels} value`
    /// with a parseable float, one TYPE line per metric, and the
    /// telemetry series the scrape contract names are present.
    #[test]
    fn prometheus_text_is_well_formed() {
        let (s, j) = sample();
        let text = StatsTree::build(&s, &j).to_prometheus();
        let mut typed: Vec<&str> = Vec::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split_whitespace();
                let name = it.next().unwrap();
                let kind = it.next().unwrap();
                assert!(name.starts_with("gofast_"), "metric name {name}");
                assert!(kind == "counter" || kind == "gauge", "TYPE {kind}");
                assert!(!typed.contains(&name), "duplicate TYPE for {name}");
                typed.push(name);
                continue;
            }
            let (head, value) = line.rsplit_once(' ').expect("sample line");
            value.parse::<f64>().unwrap_or_else(|_| panic!("bad value in: {line}"));
            let name = head.split('{').next().unwrap();
            assert!(name.starts_with("gofast_"), "series {name}");
            // every sample sits under its TYPE line
            assert!(typed.contains(&name), "sample before TYPE: {line}");
        }
        for needle in [
            "gofast_requests_done_total 10",
            "gofast_request_latency_seconds{quantile=\"0.5\"} 0.1",
            "gofast_pool_step_seconds{model=\"vp\",solver=\"adaptive\",quantile=\"0.5\"} 0.012",
            "gofast_pool_step_seconds_count{model=\"vp\",solver=\"adaptive\"} 100",
            "gofast_pool_step_seconds_sum{model=\"vp\",solver=\"adaptive\"} 1.5",
            "gofast_pool_steps_per_dispatch{model=\"vp\",solver=\"adaptive\"} 8",
            "gofast_pool_adaptive_accepted_total{model=\"vp\",solver=\"adaptive\"} 343",
            "gofast_pool_adaptive_rejected_total{model=\"vp\",solver=\"adaptive\"} 7",
            "gofast_pool_adaptive_reject_rate{model=\"vp\",solver=\"adaptive\"} 0.02",
            "gofast_class_queue_wait_seconds{class=\"interactive\",quantile=\"0.99\"} 0.06",
            "gofast_class_e2e_seconds_sum{class=\"interactive\"} 2",
            "gofast_program_bucket_steps_total{solver=\"adaptive\",bucket=\"8\"} 60",
            "gofast_pool_bucket_steps_total{model=\"vp\",solver=\"adaptive\",bucket=\"8\"} 60",
            "gofast_pool_bucket_steps_total{model=\"vp\",solver=\"adaptive\",bucket=\"16\"} 40",
            "gofast_health_status 1",
            "gofast_health_events_total{kind=\"stall\"} 2",
            "gofast_health_events_total{kind=\"reject_spike\"} 0",
            "gofast_jobs_submitted_total 4",
            "gofast_shed_deadline_total 1",
        ] {
            assert!(text.contains(needle), "missing: {needle}\n{text}");
        }
    }

    /// Label values with quotes/backslashes/newlines must escape.
    #[test]
    fn label_values_escape() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
