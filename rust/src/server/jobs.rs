//! Async job table: fire-and-poll delivery between the wire protocol
//! and the engine (docs/ARCHITECTURE.md §Async jobs).
//!
//! A `submit` allocates a job id, stamps it into the request's
//! `cancel_token`, and hands the engine's reply channel to the table
//! instead of blocking the connection on it. `poll` drains whatever has
//! completed since (each result delivered exactly once), `cancel`
//! frees still-queued work through the engine's shed path (a request
//! with a sample in a lane runs to completion, mirroring deadline
//! semantics), and `periodic` re-runs a generation spec on an interval
//! with the newest results retained ring-buffer style.
//!
//! The table is server-global (one per `serve`), so jobs outlive the
//! connection that submitted them: a client may submit, disconnect,
//! reconnect and poll. Ownership of a result is transferred at
//! delivery — a polled job is gone from the table.

use crate::coordinator::{
    CancelOutcome, EngineClient, EvalResult as EngineEvalResult, EvalRequest as EngineEvalRequest,
    GenResult, SampleRequest,
};
use crate::Result;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Newest periodic rounds retained per job; older unpolled rounds are
/// dropped (a smoke-sampling consumer wants fresh samples, not a
/// backlog that grows while it sleeps).
pub const PERIODIC_RING: usize = 8;

/// Request facts echoed into every update so a poller can interpret a
/// payload without holding its own submit-time bookkeeping.
#[derive(Clone, Debug)]
pub struct JobMeta {
    /// Canonical solver spec string ("adaptive", "em:128", ...).
    pub solver: String,
    pub n: usize,
    /// Whether the submit asked for sample payloads (generate only).
    pub want_images: bool,
}

enum Job {
    Gen {
        rx: std::sync::mpsc::Receiver<std::result::Result<GenResult, String>>,
        /// Result parked by a losing `cancel` race (the engine had
        /// already replied): the job can no longer be canceled but its
        /// payload stays pollable.
        done: Option<std::result::Result<GenResult, String>>,
        meta: JobMeta,
    },
    Eval {
        rx: std::sync::mpsc::Receiver<std::result::Result<EngineEvalResult, String>>,
        meta: JobMeta,
    },
    Periodic {
        /// (round, result) pairs awaiting delivery, newest last.
        ring: VecDeque<(u64, std::result::Result<GenResult, String>)>,
        stop: Arc<AtomicBool>,
        meta: JobMeta,
    },
}

/// One completed unit of work drained by `poll`.
pub struct JobUpdate {
    pub id: u64,
    pub meta: JobMeta,
    /// Round index for periodic jobs (`None` for one-shot submits).
    pub round: Option<u64>,
    pub outcome: JobOutcome,
}

pub enum JobOutcome {
    Gen(std::result::Result<GenResult, String>),
    Eval(std::result::Result<EngineEvalResult, String>),
}

/// What a cancel did; the wire layer maps `AlreadyDone`/`Unknown` to a
/// structured `unknown_job` rejection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelStatus {
    /// Freed while still fully queued (quota/queue_depth released).
    Canceled,
    /// Holds at least one lane (or is an eval job): runs to completion,
    /// stays pollable.
    Running,
    /// Completed before the cancel arrived; the result stays pollable.
    AlreadyDone,
    /// Never issued, already polled, or already canceled.
    Unknown,
}

/// Lifetime counters for the `stats` op's `jobs` block.
#[derive(Clone, Copy, Debug, Default)]
pub struct JobStats {
    pub submitted: u64,
    pub delivered: u64,
    pub canceled: u64,
    /// Jobs currently held by the table (undelivered or periodic).
    pub active: usize,
    /// Periodic jobs among `active`.
    pub periodic: usize,
}

struct Inner {
    next_id: u64,
    jobs: HashMap<u64, Job>,
    submitted: u64,
    delivered: u64,
    canceled: u64,
}

pub struct JobTable {
    inner: Mutex<Inner>,
}

impl Default for JobTable {
    fn default() -> Self {
        Self::new()
    }
}

impl JobTable {
    pub fn new() -> JobTable {
        JobTable {
            inner: Mutex::new(Inner {
                next_id: 1,
                jobs: HashMap::new(),
                submitted: 0,
                delivered: 0,
                canceled: 0,
            }),
        }
    }

    /// Submit a generate body: the job id doubles as the engine-side
    /// `cancel_token`, and the engine's reply channel is parked in the
    /// table. Admission rejections (quota, queue cap) arrive on that
    /// channel too, surfacing as a failed job in `poll` — by the time
    /// submit returns, the caller only ever has an id.
    pub fn submit_gen(
        &self,
        engine: &EngineClient,
        mut req: SampleRequest,
        meta: JobMeta,
    ) -> Result<u64> {
        let id = self.alloc_id();
        req.cancel_token = Some(id);
        let rx = engine.generate_async(req)?;
        let mut inner = self.inner.lock().unwrap();
        inner.jobs.insert(id, Job::Gen { rx, done: None, meta });
        inner.submitted += 1;
        Ok(id)
    }

    /// Submit an evaluate body. Eval jobs run to completion (no engine
    /// cancel path, mirroring the deadline rules), so `cancel` reports
    /// them `Running`.
    pub fn submit_eval(
        &self,
        engine: &EngineClient,
        req: EngineEvalRequest,
        meta: JobMeta,
    ) -> Result<u64> {
        let id = self.alloc_id();
        let rx = engine.evaluate_async(req)?;
        let mut inner = self.inner.lock().unwrap();
        inner.jobs.insert(id, Job::Eval { rx, meta });
        inner.submitted += 1;
        Ok(id)
    }

    /// Start a periodic generation job: a worker thread re-runs `req`
    /// every `rate_ms` until canceled, each round drawing fresh sample
    /// streams (`sample_base = round * n`, so round r reproduces a sync
    /// generate of the same seed at that base). Results land in a ring
    /// capped at [`PERIODIC_RING`].
    pub fn submit_periodic(
        self: &Arc<Self>,
        engine: EngineClient,
        req: SampleRequest,
        rate_ms: u64,
        meta: JobMeta,
    ) -> u64 {
        let stop = Arc::new(AtomicBool::new(false));
        let id = {
            let mut inner = self.inner.lock().unwrap();
            let id = inner.next_id;
            inner.next_id += 1;
            inner
                .jobs
                .insert(id, Job::Periodic { ring: VecDeque::new(), stop: stop.clone(), meta });
            inner.submitted += 1;
            id
        };
        let table = self.clone();
        std::thread::spawn(move || {
            let mut round: u64 = 0;
            while !stop.load(Ordering::Relaxed) {
                let mut r = req.clone();
                r.sample_base = round * r.n as u64;
                // stamp the job id so every round's trace span carries
                // it (periodic cancel never reaches the engine, so the
                // token is only ever read by telemetry)
                r.cancel_token = Some(id);
                let res = engine.generate_request(r).map_err(|e| format!("{e:#}"));
                let fatal = res.is_err();
                if !table.periodic_push(id, round, res) {
                    return; // job canceled/removed: stop producing
                }
                if fatal {
                    // an engine that rejects (or died) would reject every
                    // round; park the error in the ring and stop
                    return;
                }
                round += 1;
                // sleep in small chunks so cancel takes effect promptly
                let mut slept = 0u64;
                while slept < rate_ms && !stop.load(Ordering::Relaxed) {
                    let chunk = (rate_ms - slept).min(10);
                    std::thread::sleep(Duration::from_millis(chunk));
                    slept += chunk;
                }
            }
        });
        id
    }

    /// Drain completed work. `timeout_ms` = 0 returns immediately with
    /// whatever is ready; otherwise blocks until at least one update or
    /// the timeout. `job` filters to a single id; `None` means that id
    /// is unknown (never issued or already delivered).
    pub fn poll(&self, timeout_ms: u64, job: Option<u64>) -> Option<Vec<JobUpdate>> {
        let deadline = Instant::now() + Duration::from_millis(timeout_ms);
        loop {
            let (updates, known) = self.drain(job);
            if job.is_some() && !known && updates.is_empty() {
                return None; // never issued or already delivered
            }
            if !updates.is_empty() || Instant::now() >= deadline {
                return Some(updates);
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    /// One non-blocking sweep; returns (updates, filtered-id-known).
    fn drain(&self, filter: Option<u64>) -> (Vec<JobUpdate>, bool) {
        let mut inner = self.inner.lock().unwrap();
        let mut ids: Vec<u64> = inner.jobs.keys().copied().collect();
        ids.sort_unstable(); // deliver in submit order
        let known = filter.is_none_or(|id| inner.jobs.contains_key(&id));
        let mut out = Vec::new();
        for id in ids {
            if let Some(f) = filter {
                if id != f {
                    continue;
                }
            }
            let finished = match inner.jobs.get_mut(&id) {
                Some(Job::Gen { rx, done, meta }) => {
                    done.take().or_else(|| rx.try_recv().ok()).map(|r| JobUpdate {
                        id,
                        meta: meta.clone(),
                        round: None,
                        outcome: JobOutcome::Gen(r),
                    })
                }
                Some(Job::Eval { rx, meta }) => rx.try_recv().ok().map(|r| JobUpdate {
                    id,
                    meta: meta.clone(),
                    round: None,
                    outcome: JobOutcome::Eval(r),
                }),
                Some(Job::Periodic { ring, meta, .. }) => {
                    while let Some((round, r)) = ring.pop_front() {
                        out.push(JobUpdate {
                            id,
                            meta: meta.clone(),
                            round: Some(round),
                            outcome: JobOutcome::Gen(r),
                        });
                    }
                    None // periodic jobs stay in the table
                }
                None => None,
            };
            if let Some(u) = finished {
                inner.jobs.remove(&id);
                out.push(u);
            }
        }
        inner.delivered += out.len() as u64;
        (out, known)
    }

    /// Cancel a job. One-shot generates go through the engine's dequeue
    /// hook (the job id is the `cancel_token`): still fully queued →
    /// freed, lane-holding → runs to completion. FIFO ordering of the
    /// engine mailbox means a `NotFound` here implies the result was
    /// already sent — it is parked so `poll` still delivers it, and the
    /// cancel reports `AlreadyDone`.
    pub fn cancel(&self, engine: &EngineClient, id: u64) -> CancelStatus {
        {
            let mut inner = self.inner.lock().unwrap();
            match inner.jobs.get_mut(&id) {
                None => return CancelStatus::Unknown,
                Some(Job::Periodic { stop, .. }) => {
                    stop.store(true, Ordering::Relaxed);
                    inner.jobs.remove(&id);
                    inner.canceled += 1;
                    return CancelStatus::Canceled;
                }
                Some(Job::Eval { .. }) => return CancelStatus::Running,
                Some(Job::Gen { rx, done, .. }) => {
                    if done.is_some() {
                        return CancelStatus::AlreadyDone;
                    }
                    if let Ok(r) = rx.try_recv() {
                        *done = Some(r);
                        return CancelStatus::AlreadyDone;
                    }
                    // in flight: fall through to the engine (lock
                    // dropped — the engine roundtrip must not stall
                    // concurrent polls)
                }
            }
        }
        match engine.cancel(id) {
            Ok(CancelOutcome::Canceled) => {
                // the engine pushed its "canceled" error into the reply
                // channel; dropping the job here keeps canceled work out
                // of the delivery stream
                let mut inner = self.inner.lock().unwrap();
                inner.jobs.remove(&id);
                inner.canceled += 1;
                CancelStatus::Canceled
            }
            Ok(CancelOutcome::Running) => CancelStatus::Running,
            Ok(CancelOutcome::NotFound) => {
                let mut inner = self.inner.lock().unwrap();
                if let Some(Job::Gen { rx, done, .. }) = inner.jobs.get_mut(&id) {
                    if done.is_none() {
                        if let Ok(r) = rx.try_recv() {
                            *done = Some(r);
                        }
                    }
                }
                CancelStatus::AlreadyDone
            }
            Err(_) => CancelStatus::Unknown, // engine is down
        }
    }

    pub fn stats(&self) -> JobStats {
        let inner = self.inner.lock().unwrap();
        JobStats {
            submitted: inner.submitted,
            delivered: inner.delivered,
            canceled: inner.canceled,
            active: inner.jobs.len(),
            periodic: inner
                .jobs
                .values()
                .filter(|j| matches!(j, Job::Periodic { .. }))
                .count(),
        }
    }

    fn alloc_id(&self) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        id
    }

    /// Worker-thread entry: append a periodic round. `false` once the
    /// job is gone (canceled) — the worker exits on it.
    fn periodic_push(
        &self,
        id: u64,
        round: u64,
        result: std::result::Result<GenResult, String>,
    ) -> bool {
        let mut inner = self.inner.lock().unwrap();
        match inner.jobs.get_mut(&id) {
            Some(Job::Periodic { ring, .. }) => {
                ring.push_back((round, result));
                while ring.len() > PERIODIC_RING {
                    ring.pop_front(); // oldest unpolled rounds age out
                }
                true
            }
            _ => false,
        }
    }
}
