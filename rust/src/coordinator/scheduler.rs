//! Occupancy-aware bucket scheduling (docs/ARCHITECTURE.md §Scheduler).
//!
//! The AOT pipeline compiles `adaptive_step` at several batch widths
//! ("buckets"), but the seed engine pinned one width at startup — a pool
//! serving two live lanes still paid a full-width step, with the idle
//! lanes advanced as `h = 0` no-ops. The scheduler owns the ladder of
//! compiled widths, picks the cheapest one that fits the live + queued
//! demand each iteration, and accounts per-bucket work so the waste is
//! observable.
//!
//! Migration moves every per-lane quantity — the slot bookkeeping
//! `(t, h, eps_rel, nfe, rng)` and the `x`/`xprev` rows — so a sample's
//! trajectory is bit-identical whether or not it ever changed buckets.
//! The per-sample step-size independence of paper §3.1.5 is exactly what
//! makes this legal: no lane's update reads another lane's state.

use super::Slot;
use crate::tensor::Tensor;

/// Bucket ladder + hysteresis policy + per-bucket accounting for one
/// model's slot pool.
#[derive(Clone, Debug)]
pub struct BucketScheduler {
    /// Ascending compiled widths the pool may run at.
    ladder: Vec<usize>,
    /// Current pool width (always a ladder entry).
    width: usize,
    /// Steps executed at each ladder width (parallel to `ladder`).
    steps: Vec<u64>,
    pub migrations_up: u64,
    pub migrations_down: u64,
    /// Free lanes carried through steps, summed — the waste metric the
    /// scheduler exists to shrink.
    pub wasted_lane_steps: u64,
    /// Occupied lanes carried through steps, summed (occupancy numerator).
    pub occupied_lane_steps: u64,
}

impl BucketScheduler {
    /// `ladder` must be non-empty, sorted ascending, duplicate-free. The
    /// pool starts at the widest bucket (the fixed-width behaviour until
    /// the first downshift).
    pub fn new(ladder: Vec<usize>) -> BucketScheduler {
        assert!(!ladder.is_empty(), "bucket ladder must not be empty");
        assert!(ladder.windows(2).all(|w| w[0] < w[1]), "bucket ladder must ascend: {ladder:?}");
        BucketScheduler {
            width: *ladder.last().unwrap(),
            steps: vec![0; ladder.len()],
            ladder,
            migrations_up: 0,
            migrations_down: 0,
            wasted_lane_steps: 0,
            occupied_lane_steps: 0,
        }
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn ladder(&self) -> &[usize] {
        &self.ladder
    }

    /// Width the pool should run at given `active` live lanes and
    /// `demand` admissible lanes (active + queued, saturating at the
    /// widest bucket). Growth is immediate — compiled executables are
    /// cached, so a wider bucket only costs its first compile. Shrinking
    /// is hysteretic: only when the live lanes fill at most half the
    /// current width, so the pool does not thrash around a bucket edge.
    pub fn target_width(&self, active: usize, demand: usize) -> usize {
        let fit = demand.max(active);
        let desired = crate::runtime::pick_bucket(&self.ladder, fit).expect("non-empty ladder");
        if desired > self.width {
            desired
        } else if desired < self.width && active * 2 <= self.width {
            desired
        } else {
            self.width
        }
    }

    /// Record a switch to `new_width` (the caller has already migrated
    /// the lanes).
    pub fn set_width(&mut self, new_width: usize) {
        debug_assert!(self.ladder.contains(&new_width), "{new_width} not in {:?}", self.ladder);
        if new_width > self.width {
            self.migrations_up += 1;
        } else if new_width < self.width {
            self.migrations_down += 1;
        }
        self.width = new_width;
    }

    /// Account one executed dispatch at the current width advancing
    /// `lane_nodes` real lane-grid-nodes. A dispatch covers `k` nodes
    /// per lane slot (k = 1 for single-step pools), so the waste metric
    /// counts the `width * k` node capacity not spent on live work —
    /// free lanes and fused no-op tail rows alike.
    pub fn note_step(&mut self, lane_nodes: u64, k: usize) {
        let i = self.ladder.iter().position(|&b| b == self.width).expect("width on ladder");
        self.steps[i] += 1;
        self.occupied_lane_steps += lane_nodes;
        self.wasted_lane_steps += (self.width * k) as u64 - lane_nodes;
    }

    /// `(bucket, steps run at it)` ascending, zero entries included.
    pub fn steps_per_bucket(&self) -> Vec<(usize, u64)> {
        self.ladder.iter().copied().zip(self.steps.iter().copied()).collect()
    }
}

/// Move live lanes (slot state + `x`/`xprev` rows) into a pool of
/// `new_width`, compacting them to the front in stable lane order.
/// Returns how many live lanes moved. Panics if they do not fit — the
/// scheduler policy never shrinks below the active-lane count.
pub(crate) fn migrate_lanes(
    slots: &mut Vec<Slot>,
    x: &mut Tensor,
    xprev: &mut Tensor,
    new_width: usize,
) -> usize {
    let dim = x.shape[1];
    let live = slots.iter().filter(|s| !s.is_free()).count();
    assert!(live <= new_width, "cannot migrate {live} live lanes into width {new_width}");
    let mut nslots = vec![Slot::Free; new_width];
    let mut nx = Tensor::zeros(&[new_width, dim]);
    let mut nxp = Tensor::zeros(&[new_width, dim]);
    let mut j = 0;
    for i in 0..slots.len() {
        if slots[i].is_free() {
            continue;
        }
        nslots[j] = std::mem::take(&mut slots[i]);
        nx.row_mut(j).copy_from_slice(x.row(i));
        nxp.row_mut(j).copy_from_slice(xprev.row(i));
        j += 1;
    }
    *slots = nslots;
    *x = nx;
    *xprev = nxp;
    j
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::programs::LaneState;
    use crate::rng::Rng;

    fn sched() -> BucketScheduler {
        BucketScheduler::new(vec![1, 2, 4, 8, 16])
    }

    #[test]
    fn starts_at_widest() {
        assert_eq!(sched().width(), 16);
    }

    #[test]
    fn grows_immediately_on_demand() {
        let mut s = sched();
        s.set_width(2);
        assert_eq!(s.target_width(2, 7), 8);
        assert_eq!(s.target_width(2, 100), 16, "demand clamps to the widest bucket");
    }

    #[test]
    fn shrinks_only_at_half_occupancy() {
        let s = sched();
        // 9 live lanes of 16: more than half, hold width
        assert_eq!(s.target_width(9, 9), 16);
        // exactly half: shrink to the smallest fitting bucket
        assert_eq!(s.target_width(8, 8), 8);
        assert_eq!(s.target_width(3, 3), 4);
        assert_eq!(s.target_width(1, 1), 1);
        assert_eq!(s.target_width(0, 0), 1);
    }

    #[test]
    fn queued_demand_blocks_a_shrink() {
        let s = sched();
        // only 2 live lanes, but 10 more queued: stay wide for admission
        assert_eq!(s.target_width(2, 12), 16);
    }

    #[test]
    fn single_rung_ladder_is_fixed_width() {
        let s = BucketScheduler::new(vec![16]);
        assert_eq!(s.target_width(1, 1), 16);
        assert_eq!(s.target_width(0, 40), 16);
    }

    #[test]
    fn step_accounting_splits_waste_and_work() {
        let mut s = sched();
        s.note_step(10, 1); // width 16
        s.set_width(4);
        s.note_step(3, 1);
        s.note_step(3, 1);
        assert_eq!(s.occupied_lane_steps, 16);
        assert_eq!(s.wasted_lane_steps, 6 + 1 + 1);
        assert_eq!(s.migrations_down, 1);
        assert_eq!(s.migrations_up, 0);
        let per = s.steps_per_bucket();
        assert_eq!(per, vec![(1, 0), (2, 0), (4, 2), (8, 0), (16, 1)]);
    }

    /// A fused dispatch covers `width * k` node capacity: real lane
    /// nodes count as work, no-op tail rows and free lanes as waste.
    #[test]
    fn fused_dispatch_accounting_charges_tail_noops_as_waste() {
        let mut s = sched();
        s.set_width(4);
        // 3 live lanes, k = 8, one lane with only 2 nodes left:
        // 8 + 8 + 2 = 18 real nodes of 32 capacity
        s.note_step(18, 8);
        assert_eq!(s.occupied_lane_steps, 18);
        assert_eq!(s.wasted_lane_steps, 32 - 18);
    }

    fn lane(req_id: u64, seed: u64) -> Slot {
        Slot::Running {
            req_id,
            sample_idx: req_id as usize,
            nfe: 10 + req_id,
            rng: Rng::new(seed),
            state: LaneState::Adaptive {
                t: 0.5 + req_id as f64 * 0.01,
                h: 0.003 + req_id as f64 * 1e-4,
                eps_rel: 0.05,
            },
        }
    }

    /// A lane's full state — program state, rng stream, and both tensor
    /// rows — must be bit-identical across a 16 -> 4 -> 16 round-trip
    /// (the determinism contract bucket switches rely on).
    #[test]
    fn migration_preserves_lane_state_bit_identically() {
        let dim = 6;
        let mut slots = vec![Slot::Free; 16];
        let mut x = Tensor::zeros(&[16, dim]);
        let mut xprev = Tensor::zeros(&[16, dim]);
        // three live lanes scattered through the pool
        for (k, i) in [3usize, 7, 12].iter().enumerate() {
            slots[*i] = lane(k as u64, 100 + k as u64);
            for (j, v) in x.row_mut(*i).iter_mut().enumerate() {
                *v = (k * 10 + j) as f32 * 0.25;
            }
            for (j, v) in xprev.row_mut(*i).iter_mut().enumerate() {
                *v = -((k * 10 + j) as f32) * 0.5;
            }
        }
        let snapshot_x: Vec<Vec<f32>> = [3usize, 7, 12].iter().map(|&i| x.row(i).to_vec()).collect();
        let snapshot_xp: Vec<Vec<f32>> =
            [3usize, 7, 12].iter().map(|&i| xprev.row(i).to_vec()).collect();

        assert_eq!(migrate_lanes(&mut slots, &mut x, &mut xprev, 4), 3);
        assert_eq!(slots.len(), 4);
        assert_eq!(x.shape, vec![4, dim]);
        assert_eq!(migrate_lanes(&mut slots, &mut x, &mut xprev, 16), 3);
        assert_eq!(slots.len(), 16);

        for (k, exp_x) in snapshot_x.iter().enumerate() {
            let Slot::Running { req_id, sample_idx, nfe, rng, state } = &mut slots[k] else {
                panic!("lane {k} lost in migration");
            };
            assert_eq!(*req_id, k as u64);
            assert_eq!(*sample_idx, k);
            assert_eq!(*nfe, 10 + k as u64);
            let LaneState::Adaptive { t, h, eps_rel } = state else {
                panic!("lane {k} changed program state kind");
            };
            assert_eq!(t.to_bits(), (0.5 + k as f64 * 0.01).to_bits());
            assert_eq!(h.to_bits(), (0.003 + k as f64 * 1e-4).to_bits());
            assert_eq!(eps_rel.to_bits(), 0.05f64.to_bits());
            // rng stream unchanged: same next draw as a fresh twin
            assert_eq!(rng.next_u64(), Rng::new(100 + k as u64).next_u64());
            assert_eq!(x.row(k), &exp_x[..]);
            assert_eq!(xprev.row(k), &snapshot_xp[k][..]);
        }
        for s in &slots[3..] {
            assert!(s.is_free(), "tail lanes must be free");
        }
    }

    /// Fixed-step lanes migrate like adaptive ones: the grid position
    /// `(done, total)`, the per-lane Langevin `snr` (PC pools) and the
    /// rng stream survive a bucket switch untouched, so a
    /// mid-trajectory EM/DDIM/PC sample cannot drift.
    #[test]
    fn migration_preserves_fixed_step_lane_state() {
        let dim = 3;
        let mut slots = vec![Slot::Free; 8];
        let mut x = Tensor::zeros(&[8, dim]);
        let mut xprev = Tensor::zeros(&[8, dim]);
        for (k, i) in [1usize, 6].iter().enumerate() {
            slots[*i] = Slot::Running {
                req_id: k as u64,
                sample_idx: k,
                nfe: 7 + k as u64,
                rng: Rng::new(40 + k as u64),
                state: LaneState::Fixed {
                    done: 5 + k,
                    total: 20 + k,
                    snr: 0.16 + k as f64 * 1e-3,
                },
            };
            for v in x.row_mut(*i).iter_mut() {
                *v = (k + 1) as f32 * 1.5;
            }
        }
        assert_eq!(migrate_lanes(&mut slots, &mut x, &mut xprev, 2), 2);
        assert_eq!(migrate_lanes(&mut slots, &mut x, &mut xprev, 8), 2);
        for k in 0..2 {
            let Slot::Running { nfe, rng, state, .. } = &mut slots[k] else {
                panic!("fixed lane {k} lost in migration");
            };
            assert_eq!(*nfe, 7 + k as u64);
            let LaneState::Fixed { done, total, snr } = state else {
                panic!("fixed lane {k} changed program state kind");
            };
            assert_eq!((*done, *total), (5 + k, 20 + k));
            assert_eq!(snr.to_bits(), (0.16 + k as f64 * 1e-3).to_bits());
            assert_eq!(rng.next_u64(), Rng::new(40 + k as u64).next_u64());
            assert!(x.row(k).iter().all(|&v| v == (k + 1) as f32 * 1.5));
        }
    }

    #[test]
    #[should_panic(expected = "cannot migrate")]
    fn migration_refuses_overfull_target() {
        let mut slots = vec![lane(0, 1), lane(1, 2), lane(2, 3)];
        let mut x = Tensor::zeros(&[3, 2]);
        let mut xprev = Tensor::zeros(&[3, 2]);
        migrate_lanes(&mut slots, &mut x, &mut xprev, 2);
    }
}
