//! Solver-program lane pools (docs/ARCHITECTURE.md §Solver-program
//! pools).
//!
//! The engine's step loop used to *be* Algorithm 1: the only thing a
//! pool could do was advance `adaptive_step`. This module abstracts "a
//! pool of lanes advancing under a compiled step program" behind the
//! [`LaneProgram`] trait, so the paper's fixed-step baselines (EM,
//! DDIM) are first-class serving workloads instead of offline bypasses
//! — the fixed-vs-adaptive comparison of the paper's Table 1 becomes a
//! pure serving-path measurement.
//!
//! A program owns three things:
//! * the per-lane integration state it threads through [`Slot::Running`]
//!   (a [`LaneState`] variant) — created at admission by `init_lane`;
//! * one fused `step` over the pool at its current bucket width: build
//!   the device args per lane, execute the compiled step artifact, fold
//!   the outputs back into lane state, and report which lanes completed
//!   their trajectory (the per-lane completion predicate);
//! * its cost model (`score_evals_per_step`, the paper's NFE metric).
//!
//! Free lanes ride through every program's step as exact no-ops
//! (`h = 0` for adaptive/EM, `t == t_next` for DDIM), which is what
//! makes the pools continuously batchable. Because no lane's update
//! reads another lane's state (§3.1.5), a lane's trajectory is
//! bit-identical to its offline twin (`solvers::spec::run_lanes`)
//! regardless of pool width, migration, or co-batched traffic — for
//! fixed-step programs exactly as for the adaptive solver.

use super::engine::EngineConfig;
use super::{SampleRequest, Slot};
use crate::runtime::{ExecArg, Model};
use crate::sde::Process;
use crate::solvers::uniform_t;
use crate::tensor::Tensor;
use crate::{bail, Result};

/// Program-specific per-lane integration state, carried in
/// [`Slot::Running`] and migrated verbatim across bucket switches.
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum LaneState {
    /// Algorithm-1 controller state: current time, step size, tolerance.
    Adaptive { t: f64, h: f64, eps_rel: f64 },
    /// Fixed uniform schedule: `done` of `total` steps taken; the lane's
    /// position is `uniform_t(t_eps, total, done)`. Per-lane `total`
    /// lets requests with different step budgets co-batch in one pool.
    Fixed { done: usize, total: usize },
}

/// Everything a program needs to advance one pool by one fused step.
pub(crate) struct StepIo<'a, 'rt> {
    pub model: &'a Model<'rt>,
    pub process: &'a Process,
    pub cfg: &'a EngineConfig,
    /// Pool lanes; length is the pool's current bucket width.
    pub slots: &'a mut [Slot],
    pub x: &'a mut Tensor,
    pub xprev: &'a mut Tensor,
}

/// Outcome of one fused pool step.
pub(crate) struct StepOutcome {
    /// Lanes that were live during the step (occupancy numerator).
    pub occupied: usize,
    /// Rejected proposals (adaptive programs only).
    pub rejections: u64,
    /// Lanes that completed their trajectory this step (to denoise).
    pub converged: Vec<usize>,
}

/// A compiled step program driving a pool of lanes.
pub(crate) trait LaneProgram {
    /// Solver-spec name requests route by ("adaptive" | "em" | "ddim").
    fn solver_name(&self) -> &'static str;
    /// Compiled artifact advancing the pool ("adaptive_step", ...).
    fn step_artifact(&self) -> &'static str;
    /// Score-network evaluations one fused step costs each live lane.
    fn score_evals_per_step(&self) -> u64;
    /// Fresh per-lane integration state for an admitted sample.
    fn init_lane(&self, cfg: &EngineConfig, req: &SampleRequest) -> LaneState;
    /// Advance the pool one fused step at its current width.
    fn step(&self, io: StepIo<'_, '_>) -> Result<StepOutcome>;
}

/// Program for a solver-spec name, if one exists.
pub(crate) fn for_solver(name: &str) -> Option<Box<dyn LaneProgram>> {
    match name {
        "adaptive" => Some(Box::new(AdaptiveProgram)),
        "em" => Some(Box::new(EmProgram)),
        "ddim" => Some(Box::new(DdimProgram)),
        _ => None,
    }
}

fn fixed_total(req: &SampleRequest) -> usize {
    req.solver.steps().unwrap_or(crate::solvers::spec::DEFAULT_FIXED_STEPS)
}

/// Fold a fixed-step kernel's output back into the pool — shared by
/// every `LaneState::Fixed` program so the completion predicate and
/// NFE accounting cannot diverge between EM and DDIM: each live lane
/// advances one grid node (+1 NFE), takes its output row, and is
/// reported converged once its schedule is exhausted.
fn fold_fixed_step(slots: &mut [Slot], x: &mut Tensor, xn: &Tensor) -> Vec<usize> {
    let mut converged = Vec::new();
    for i in 0..slots.len() {
        let Slot::Running { nfe, state: LaneState::Fixed { done, total }, .. } = &mut slots[i]
        else {
            continue;
        };
        *nfe += 1;
        x.row_mut(i).copy_from_slice(xn.row(i));
        *done += 1;
        if *done == *total {
            converged.push(i);
        }
    }
    converged
}

// --- Algorithm 1 ---------------------------------------------------------------

/// The paper's adaptive solver: 2 score evaluations per step, per-lane
/// step-size control, accept/reject on the host.
pub(crate) struct AdaptiveProgram;

impl LaneProgram for AdaptiveProgram {
    fn solver_name(&self) -> &'static str {
        "adaptive"
    }

    fn step_artifact(&self) -> &'static str {
        "adaptive_step"
    }

    fn score_evals_per_step(&self) -> u64 {
        2
    }

    fn init_lane(&self, cfg: &EngineConfig, req: &SampleRequest) -> LaneState {
        LaneState::Adaptive { t: 1.0, h: cfg.h_init, eps_rel: req.eps_rel }
    }

    fn step(&self, io: StepIo<'_, '_>) -> Result<StepOutcome> {
        let b = io.slots.len();
        let dim = io.model.meta.dim;
        let t_eps = io.process.t_eps();
        let eps_abs = io.process.eps_abs();
        let mut t_in = vec![1.0f32; b];
        let mut h_in = vec![0.0f32; b];
        let mut er_in = vec![0.01f32; b];
        let mut z = Tensor::zeros(&[b, dim]);
        let mut occupied = 0usize;
        for (i, slot) in io.slots.iter_mut().enumerate() {
            if let Slot::Running { rng, state: LaneState::Adaptive { t, h, eps_rel }, .. } = slot
            {
                occupied += 1;
                *h = h.min(*t - t_eps).max(0.0);
                t_in[i] = *t as f32;
                h_in[i] = *h as f32;
                er_in[i] = *eps_rel as f32;
                rng.fill_normal(z.row_mut(i));
            }
        }
        let t_t = Tensor { shape: vec![b], data: t_in };
        let h_t = Tensor { shape: vec![b], data: h_in };
        let er_t = Tensor { shape: vec![b], data: er_in };
        let ea_t = Tensor::scalar(eps_abs as f32);
        let out = io.model.exec_args(
            "adaptive_step",
            b,
            &[
                ExecArg::Host(io.x),
                ExecArg::Host(io.xprev),
                ExecArg::Host(&t_t),
                ExecArg::Host(&h_t),
                ExecArg::Host(&z),
                ExecArg::Const("eps_abs", &ea_t),
                ExecArg::Host(&er_t),
            ],
            io.cfg.fused_buffers,
        )?;
        let (xpp, xp, e2) = (&out[0], &out[1], &out[2]);
        let mut rejections = 0u64;
        let mut converged: Vec<usize> = Vec::new();
        for i in 0..b {
            let Slot::Running { nfe, state: LaneState::Adaptive { t, h, .. }, .. } =
                &mut io.slots[i]
            else {
                continue;
            };
            *nfe += 2;
            let err = e2.data[i] as f64;
            if err <= 1.0 {
                io.x.row_mut(i).copy_from_slice(xpp.row(i));
                io.xprev.row_mut(i).copy_from_slice(xp.row(i));
                *t -= *h;
                if *t <= t_eps + 1e-12 {
                    converged.push(i);
                }
            } else {
                rejections += 1;
            }
            // controller update either way (paper §3.1.4); the clamp
            // floors at 0 so converged lanes park rather than going
            // negative
            let grow = io.cfg.safety * err.max(1e-12).powf(-io.cfg.r);
            *h = (*h * grow).min((*t - t_eps).max(0.0));
        }
        Ok(StepOutcome { occupied, rejections, converged })
    }
}

// --- Euler–Maruyama ------------------------------------------------------------

/// Fixed uniform-schedule EM: 1 score evaluation per step, fresh noise
/// each step, per-lane step counts.
pub(crate) struct EmProgram;

impl LaneProgram for EmProgram {
    fn solver_name(&self) -> &'static str {
        "em"
    }

    fn step_artifact(&self) -> &'static str {
        "em_step"
    }

    fn score_evals_per_step(&self) -> u64 {
        1
    }

    fn init_lane(&self, _cfg: &EngineConfig, req: &SampleRequest) -> LaneState {
        LaneState::Fixed { done: 0, total: fixed_total(req) }
    }

    fn step(&self, io: StepIo<'_, '_>) -> Result<StepOutcome> {
        let b = io.slots.len();
        let dim = io.model.meta.dim;
        let t_eps = io.process.t_eps();
        let mut t_in = vec![1.0f32; b];
        let mut h_in = vec![0.0f32; b];
        let mut z = Tensor::zeros(&[b, dim]);
        let mut occupied = 0usize;
        for (i, slot) in io.slots.iter_mut().enumerate() {
            if let Slot::Running { rng, state: LaneState::Fixed { done, total }, .. } = slot {
                occupied += 1;
                let t = uniform_t(t_eps, *total, *done);
                let tn = uniform_t(t_eps, *total, *done + 1);
                t_in[i] = t as f32;
                h_in[i] = (t - tn) as f32;
                rng.fill_normal(z.row_mut(i));
            }
        }
        let t_t = Tensor { shape: vec![b], data: t_in };
        let h_t = Tensor { shape: vec![b], data: h_in };
        let out = io.model.exec_args(
            "em_step",
            b,
            &[ExecArg::Host(io.x), ExecArg::Host(&t_t), ExecArg::Host(&h_t), ExecArg::Host(&z)],
            io.cfg.fused_buffers,
        )?;
        let converged = fold_fixed_step(io.slots, io.x, &out[0]);
        Ok(StepOutcome { occupied, rejections: 0, converged })
    }
}

// --- DDIM ----------------------------------------------------------------------

/// Deterministic DDIM (VP only): 1 score evaluation per step, no noise
/// after the prior draw, per-lane step counts.
pub(crate) struct DdimProgram;

impl LaneProgram for DdimProgram {
    fn solver_name(&self) -> &'static str {
        "ddim"
    }

    fn step_artifact(&self) -> &'static str {
        "ddim_step"
    }

    fn score_evals_per_step(&self) -> u64 {
        1
    }

    fn init_lane(&self, _cfg: &EngineConfig, req: &SampleRequest) -> LaneState {
        LaneState::Fixed { done: 0, total: fixed_total(req) }
    }

    fn step(&self, io: StepIo<'_, '_>) -> Result<StepOutcome> {
        if io.process.kind() != "vp" {
            // the registry refuses to build a ddim pool for non-VP
            // models, so this is a defence-in-depth invariant, not a
            // reachable serving path
            bail!("ddim_step pool on a non-VP model");
        }
        let b = io.slots.len();
        let t_eps = io.process.t_eps();
        let mut t_in = vec![1.0f32; b];
        let mut tn_in = vec![1.0f32; b];
        let mut occupied = 0usize;
        for (i, slot) in io.slots.iter_mut().enumerate() {
            if let Slot::Running { state: LaneState::Fixed { done, total }, .. } = slot {
                occupied += 1;
                t_in[i] = uniform_t(t_eps, *total, *done) as f32;
                tn_in[i] = uniform_t(t_eps, *total, *done + 1) as f32;
            }
        }
        let t_t = Tensor { shape: vec![b], data: t_in };
        let tn_t = Tensor { shape: vec![b], data: tn_in };
        let out = io.model.exec_args(
            "ddim_step",
            b,
            &[ExecArg::Host(io.x), ExecArg::Host(&t_t), ExecArg::Host(&tn_t)],
            io.cfg.fused_buffers,
        )?;
        let converged = fold_fixed_step(io.slots, io.x, &out[0]);
        Ok(StepOutcome { occupied, rejections: 0, converged })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_solver_covers_the_served_trio() {
        for (name, artifact, evals) in [
            ("adaptive", "adaptive_step", 2),
            ("em", "em_step", 1),
            ("ddim", "ddim_step", 1),
        ] {
            let p = for_solver(name).expect(name);
            assert_eq!(p.solver_name(), name);
            assert_eq!(p.step_artifact(), artifact);
            assert_eq!(p.score_evals_per_step(), evals);
        }
        assert!(for_solver("ode").is_none());
    }

    #[test]
    fn init_lane_seeds_program_state_from_the_request() {
        let cfg = EngineConfig::new("artifacts", "vp");
        let req = SampleRequest {
            model: String::new(),
            solver: crate::solvers::ServingSolver::Em { steps: 12 },
            n: 1,
            eps_rel: 0.07,
            seed: 0,
            sample_base: 0,
            priority: None,
            deadline_ms: None,
        };
        assert_eq!(
            EmProgram.init_lane(&cfg, &req),
            LaneState::Fixed { done: 0, total: 12 }
        );
        assert_eq!(
            AdaptiveProgram.init_lane(&cfg, &req),
            LaneState::Adaptive { t: 1.0, h: cfg.h_init, eps_rel: 0.07 }
        );
    }
}
