//! Solver-program lane pools (docs/ARCHITECTURE.md §Solver-program
//! pools).
//!
//! The engine's step loop used to *be* Algorithm 1: the only thing a
//! pool could do was advance `adaptive_step`. This module abstracts "a
//! pool of lanes advancing under a compiled step program" behind the
//! [`LaneProgram`] trait — and every *fixed-step* solver (EM, DDIM, the
//! Reverse-Diffusion + Langevin predictor–corrector) is served by the
//! **one** descriptor-driven [`FixedProgram`], parameterised by its
//! [`StepKernel`] row (`solvers::spec::STEP_KERNELS`): artifact tag,
//! per-step NFE cost, the second time input's shape, how many fresh
//! noise tensors to draw, and whether a per-lane Langevin `snr` vector
//! trails the inputs. Adding a served fixed-step solver is a table row
//! plus an offline twin, not another hand-rolled program impl.
//!
//! A program owns three things:
//! * the per-lane integration state it threads through [`Slot::Running`]
//!   (a [`LaneState`] variant) — created at admission by `init_lane`;
//! * one fused `step` over the pool at its current bucket width: build
//!   the device args per lane, execute the compiled step artifact, fold
//!   the outputs back into lane state, and report which lanes completed
//!   their trajectory (the per-lane completion predicate);
//! * its cost model (`score_evals_per_step`, the paper's NFE metric).
//!
//! Free lanes ride through every program's step as exact no-ops
//! (`h = 0` + zero noise for adaptive/EM/PC, `t == t_next` for DDIM),
//! which is what makes the pools continuously batchable. Because no
//! lane's update reads another lane's state (§3.1.5), a lane's
//! trajectory is bit-identical to its offline twin
//! (`solvers::spec::run_lanes`) regardless of pool width, migration, or
//! co-batched traffic — for fixed-step programs exactly as for the
//! adaptive solver.

use super::diagnostics::PoolDiag;
use super::engine::EngineConfig;
use super::{SampleRequest, Slot};
use crate::runtime::{DeviceSlab, ExecArg, Model};
use crate::sde::Process;
use crate::solvers::spec::{fused_artifact, StepKernel, TimeArg};
use crate::solvers::{rdl, uniform_t};
use crate::tensor::Tensor;
use crate::{bail, Result};

/// Program-specific per-lane integration state, carried in
/// [`Slot::Running`] and migrated verbatim across bucket switches.
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum LaneState {
    /// Algorithm-1 controller state: current time, step size, tolerance.
    Adaptive { t: f64, h: f64, eps_rel: f64 },
    /// Fixed uniform schedule: `done` of `total` steps taken; the lane's
    /// position is `uniform_t(t_eps, total, done)`. Per-lane `total`
    /// lets requests with different step budgets co-batch in one pool.
    /// `snr` is the lane's Langevin corrector target (PC pools; kernels
    /// without an snr input carry 0.0) — per-lane, so PC requests with
    /// different SNR targets co-batch too.
    Fixed { done: usize, total: usize, snr: f64 },
}

/// Everything a program needs to advance one pool by one fused step.
pub(crate) struct StepIo<'a, 'rt> {
    pub model: &'a Model<'rt>,
    pub process: &'a Process,
    pub cfg: &'a EngineConfig,
    /// Pool lanes; length is the pool's current bucket width.
    pub slots: &'a mut [Slot],
    pub x: &'a mut Tensor,
    pub xprev: &'a mut Tensor,
    /// Device-resident lane state (fixed-step pools at
    /// `steps_per_dispatch > 1`): `None` means the host `x` is current
    /// and the next fused dispatch re-uploads it (admission, migration);
    /// `Some` means the slab is current and the host `x` is stale. Pools
    /// at k = 1 never touch it.
    pub dev_x: &'a mut Option<DeviceSlab>,
    /// Grid nodes each fused dispatch advances a live lane by (the
    /// pool's resolved `k`; 1 = today's single-step host path).
    pub steps_per_dispatch: usize,
    /// Pool diagnostics sink: the always-on diffusion-time profile plus
    /// the 1-in-N sampled lane traces (`--diag-sample`). Programs feed
    /// it from the values their step folds already compute — pre-step
    /// `(t, h)`, the error norm, and the accept/reject outcome.
    pub diag: &'a mut PoolDiag,
}

/// Outcome of one fused pool step.
pub(crate) struct StepOutcome {
    /// Lanes that were live during the step (occupancy numerator).
    pub occupied: usize,
    /// Real grid nodes (or adaptive attempts) advanced across all live
    /// lanes this dispatch (no-op tail padding excluded) — `occupied`
    /// x k for a full fused dispatch, less when lanes ride the tail.
    /// Equals `occupied` at k = 1.
    pub lane_nodes: u64,
    /// Slot-indexed share of `lane_nodes` (0 for free lanes) — the
    /// engine's eval-lane accounting sums the eval-sink slots' entries
    /// after the step, since only the step fold knows how many of the
    /// k attempts an adaptive lane really ran.
    pub per_lane_nodes: Vec<u64>,
    /// Rejected proposals (adaptive programs only).
    pub rejections: u64,
    /// Lanes that completed their trajectory this step (to denoise).
    pub converged: Vec<usize>,
    /// `converged` split into convergence order (fused adaptive
    /// dispatches: one group per attempt index at which lanes crossed
    /// t_eps). Empty means "one group: `converged`". The engine runs
    /// one batched denoise per group so the denoise call count — and
    /// with it `score_evals` and the downloaded bytes — stays exactly
    /// equal to the k = 1 dispatch sequence, where lanes converging on
    /// different attempts finish in different iterations.
    pub converged_groups: Vec<Vec<usize>>,
}

/// A compiled step program driving a pool of lanes.
pub(crate) trait LaneProgram {
    /// Solver-spec name requests route by ("adaptive" | "em" | "ddim" |
    /// "pc").
    fn solver_name(&self) -> &'static str;
    /// Compiled artifact advancing the pool ("adaptive_step", ...).
    fn step_artifact(&self) -> &'static str;
    /// Score-network evaluations one fused step costs each live lane.
    fn score_evals_per_step(&self) -> u64;
    /// Whether the program's kernel is VP-only (paper §4; the registry
    /// refuses to build such a pool for non-VP models).
    fn vp_only(&self) -> bool;
    /// Fresh per-lane integration state for an admitted sample.
    fn init_lane(&self, cfg: &EngineConfig, process: &Process, req: &SampleRequest) -> LaneState;
    /// Advance the pool one fused step at its current width.
    fn step(&self, io: StepIo<'_, '_>) -> Result<StepOutcome>;
}

/// Program for a solver-spec name, if one exists: the adaptive solver's
/// bespoke controller program, or the descriptor-driven [`FixedProgram`]
/// for any fixed-step row of the kernel table.
pub(crate) fn for_solver(name: &str) -> Option<Box<dyn LaneProgram>> {
    let kernel = crate::solvers::spec::kernel(name)?;
    if kernel.adaptive {
        Some(Box::new(AdaptiveProgram))
    } else {
        Some(Box::new(FixedProgram { kernel }))
    }
}

fn fixed_total(req: &SampleRequest) -> usize {
    req.solver.steps().unwrap_or(crate::solvers::spec::DEFAULT_FIXED_STEPS)
}

// --- Algorithm 1 ---------------------------------------------------------------

/// The paper's adaptive solver: 2 score evaluations per step, per-lane
/// step-size control, accept/reject on the host. The only program whose
/// control flow lives outside the [`StepKernel`] descriptor — it still
/// sources its table row for the shared facts.
pub(crate) struct AdaptiveProgram;

impl AdaptiveProgram {
    fn kernel() -> &'static StepKernel {
        crate::solvers::spec::kernel("adaptive").expect("adaptive row in STEP_KERNELS")
    }
}

impl LaneProgram for AdaptiveProgram {
    fn solver_name(&self) -> &'static str {
        Self::kernel().solver
    }

    fn step_artifact(&self) -> &'static str {
        Self::kernel().artifact
    }

    fn score_evals_per_step(&self) -> u64 {
        Self::kernel().score_evals_per_step
    }

    fn vp_only(&self) -> bool {
        Self::kernel().vp_only
    }

    fn init_lane(&self, cfg: &EngineConfig, _process: &Process, req: &SampleRequest) -> LaneState {
        LaneState::Adaptive { t: 1.0, h: cfg.h_init, eps_rel: req.eps_rel }
    }

    fn step(&self, io: StepIo<'_, '_>) -> Result<StepOutcome> {
        if io.steps_per_dispatch > 1 {
            return self.step_fused(io);
        }
        let b = io.slots.len();
        let dim = io.model.meta.dim;
        let t_eps = io.process.t_eps();
        let eps_abs = io.process.eps_abs();
        let mut t_in = vec![1.0f32; b];
        let mut h_in = vec![0.0f32; b];
        let mut er_in = vec![0.01f32; b];
        let mut z = Tensor::zeros(&[b, dim]);
        let mut occupied = 0usize;
        let mut per_lane_nodes = vec![0u64; b];
        for (i, slot) in io.slots.iter_mut().enumerate() {
            if let Slot::Running { rng, state: LaneState::Adaptive { t, h, eps_rel }, .. } = slot
            {
                occupied += 1;
                per_lane_nodes[i] = 1;
                *h = h.min(*t - t_eps).max(0.0);
                t_in[i] = *t as f32;
                h_in[i] = *h as f32;
                er_in[i] = *eps_rel as f32;
                rng.fill_normal(z.row_mut(i));
            }
        }
        let t_t = Tensor { shape: vec![b], data: t_in };
        let h_t = Tensor { shape: vec![b], data: h_in };
        let er_t = Tensor { shape: vec![b], data: er_in };
        let ea_t = Tensor::scalar(eps_abs as f32);
        let out = io.model.exec_args(
            "adaptive_step",
            b,
            &[
                ExecArg::Host(io.x),
                ExecArg::Host(io.xprev),
                ExecArg::Host(&t_t),
                ExecArg::Host(&h_t),
                ExecArg::Host(&z),
                ExecArg::Const("eps_abs", &ea_t),
                ExecArg::Host(&er_t),
            ],
            io.cfg.fused_buffers,
        )?;
        let (xpp, xp, e2) = (&out[0], &out[1], &out[2]);
        let mut rejections = 0u64;
        let mut converged: Vec<usize> = Vec::new();
        for i in 0..b {
            let Slot::Running { nfe, state: LaneState::Adaptive { t, h, .. }, .. } =
                &mut io.slots[i]
            else {
                continue;
            };
            *nfe += 2;
            let err = e2.data[i] as f64;
            // profile the proposal at its pre-step (t, h) — the inputs
            // the dispatch actually ran with, kept alive in the arg
            // tensors
            io.diag.record_adaptive(
                i,
                t_t.data[i] as f64,
                h_t.data[i] as f64,
                err,
                err <= 1.0,
            );
            if err <= 1.0 {
                io.x.row_mut(i).copy_from_slice(xpp.row(i));
                io.xprev.row_mut(i).copy_from_slice(xp.row(i));
                *t -= *h;
                if *t <= t_eps + 1e-12 {
                    converged.push(i);
                }
            } else {
                rejections += 1;
            }
            // controller update either way (paper §3.1.4); the clamp
            // floors at 0 so converged lanes park rather than going
            // negative
            let grow = io.cfg.safety * err.max(1e-12).powf(-io.cfg.r);
            *h = (*h * grow).min((*t - t_eps).max(0.0));
        }
        Ok(StepOutcome {
            occupied,
            lane_nodes: occupied as u64,
            per_lane_nodes,
            rejections,
            converged,
            converged_groups: Vec::new(),
        })
    }
}

impl AdaptiveProgram {
    /// Device-side accept/reject fold: one dispatch of the fused
    /// `adaptive_stepk<k>` artifact runs up to k attempts of
    /// Algorithm 1 per live lane, with the error test and the f64
    /// step-size controller on device. The artifact's state is a packed
    /// device-resident slab `x | xprev | t_log | h_log | err_log |
    /// accept_log` (`[2·B·dim + 4·k·B]` f32) whose output feeds back as
    /// the next dispatch's input; the host downloads it once per
    /// dispatch — that single pull replaces the per-attempt
    /// `x''/x'/err` round-trip of the k = 1 path and carries the
    /// `[k, B]` attempt logs the host folds NFE, rejections, and the
    /// diagnostics bins/traces from, *replaying* (not re-deciding) the
    /// controller in f64 from the logged f32 error norms so lane state
    /// stays bit-identical to k = 1.
    ///
    /// RNG contract: k noise rows are pre-drawn node-major per live
    /// lane — the exact draw order k single-attempt dispatches consume
    /// (a rejected attempt burns a draw at k = 1 too). Rows past a
    /// mid-dispatch convergence are over-draws on a stream the freed
    /// lane never uses again; a fresh admission re-forks its own.
    fn step_fused(&self, io: StepIo<'_, '_>) -> Result<StepOutcome> {
        let b = io.slots.len();
        let dim = io.model.meta.dim;
        let k = io.steps_per_dispatch;
        let t_eps = io.process.t_eps();
        let eps_abs = io.process.eps_abs();
        let mut t_in = vec![1.0f64; b];
        let mut h_in = vec![0.0f64; b];
        let mut live_in = vec![0.0f32; b];
        let mut er_in = vec![0.01f32; b];
        let mut z = Tensor::zeros(&[k, b, dim]);
        let mut occupied = 0usize;
        let mut live = vec![false; b];
        for (i, slot) in io.slots.iter_mut().enumerate() {
            if let Slot::Running { rng, state: LaneState::Adaptive { t, h, eps_rel }, .. } = slot
            {
                occupied += 1;
                live[i] = true;
                // raw (t, h) in f64: the device clamps h to the
                // remaining span itself, per attempt, exactly as the
                // k = 1 host loop does before each dispatch
                t_in[i] = *t;
                h_in[i] = *h;
                live_in[i] = 1.0;
                er_in[i] = *eps_rel as f32;
                for j in 0..k {
                    rng.fill_normal(z.row_mut(j * b + i));
                }
            }
        }
        let live_t = Tensor { shape: vec![b], data: live_in };
        let er_t = Tensor { shape: vec![b], data: er_in };
        let ea_t = Tensor::scalar(eps_abs as f32);
        let actrl = [t_eps, io.cfg.safety, io.cfg.r];
        let slab_len = 2 * b * dim + 4 * k * b;
        let artifact = fused_artifact("adaptive_step", k);
        let packed: Tensor;
        let out_slab = {
            let slab_arg = match io.dev_x.as_ref() {
                Some(slab) => ExecArg::Device(slab),
                None => {
                    // admission/migration/first dispatch: host x/xprev
                    // are current; pack them with a zeroed log region
                    // (the kernel ignores input logs)
                    let mut data = Vec::with_capacity(slab_len);
                    data.extend_from_slice(&io.x.data);
                    data.extend_from_slice(&io.xprev.data);
                    data.resize(slab_len, 0.0);
                    packed = Tensor { shape: vec![slab_len], data };
                    ExecArg::Host(&packed)
                }
            };
            // score_evals are billed after the fold, from the attempt
            // log (rejected attempts still ran the score net) — see
            // `bill_score_evals` below
            io.model.exec_device(
                &artifact,
                b,
                &[
                    slab_arg,
                    ExecArg::HostF64(&t_in, &[b]),
                    ExecArg::HostF64(&h_in, &[b]),
                    ExecArg::Host(&live_t),
                    ExecArg::Host(&z),
                    ExecArg::Const("eps_abs", &ea_t),
                    ExecArg::Host(&er_t),
                    ExecArg::HostF64(&actrl, &[3]),
                ],
                0,
            )?
        };
        // the one per-dispatch download: refreshes the host x/xprev
        // copies AND carries the attempt logs (the slab itself stays
        // resident as the next dispatch's input)
        let host = io.model.download(&out_slab)?;
        *io.dev_x = Some(out_slab);
        let (x_out, rest) = host.data.split_at(b * dim);
        let (xp_out, logs) = rest.split_at(b * dim);
        let t_log = &logs[..k * b];
        let h_log = &logs[k * b..2 * k * b];
        let e_log = &logs[2 * k * b..3 * k * b];
        for i in 0..b {
            if live[i] {
                io.x.row_mut(i).copy_from_slice(&x_out[i * dim..(i + 1) * dim]);
                io.xprev.row_mut(i).copy_from_slice(&xp_out[i * dim..(i + 1) * dim]);
            }
        }
        // replay the controller decisions attempt-major (the k = 1
        // event order) from the logged error norms: same f32→f64 cast,
        // same accept test, same f64 controller arithmetic — so (t, h)
        // and the diagnostics bins land bit-identically
        let mut per_lane_nodes = vec![0u64; b];
        let mut rejections = 0u64;
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); k];
        for j in 0..k {
            for i in 0..b {
                if !live[i] {
                    continue;
                }
                let Slot::Running { nfe, state: LaneState::Adaptive { t, h, .. }, .. } =
                    &mut io.slots[i]
                else {
                    continue;
                };
                let hc = h.min(*t - t_eps).max(0.0);
                per_lane_nodes[i] += 1;
                *nfe += 2;
                let err = e_log[j * b + i] as f64;
                io.diag.record_adaptive(
                    i,
                    t_log[j * b + i] as f64,
                    h_log[j * b + i] as f64,
                    err,
                    err <= 1.0,
                );
                if err <= 1.0 {
                    *t -= hc;
                    if *t <= t_eps + 1e-12 {
                        groups[j].push(i);
                        live[i] = false;
                    }
                } else {
                    rejections += 1;
                }
                let grow = io.cfg.safety * err.max(1e-12).powf(-io.cfg.r);
                *h = (hc * grow).min((*t - t_eps).max(0.0));
            }
        }
        // NFE parity with k = 1: a single-attempt dispatch bills 2
        // score evals per batched call while any lane is live, so the
        // fused dispatch costs 2 × (deepest live lane's attempt count)
        let max_attempts = per_lane_nodes.iter().copied().max().unwrap_or(0);
        io.model.bill_score_evals(2 * max_attempts);
        let lane_nodes = per_lane_nodes.iter().sum();
        let mut converged_groups: Vec<Vec<usize>> = Vec::new();
        let mut converged = Vec::new();
        for g in groups {
            if !g.is_empty() {
                converged.extend_from_slice(&g);
                converged_groups.push(g);
            }
        }
        Ok(StepOutcome {
            occupied,
            lane_nodes,
            per_lane_nodes,
            rejections,
            converged,
            converged_groups,
        })
    }
}

// --- descriptor-driven fixed-step programs -------------------------------------

/// One program for *every* fixed-step solver: the [`StepKernel`] row
/// says which artifact to run and which device args to build — `x`, the
/// per-lane grid time `t`, the second time input (`h` or `t_next`),
/// `noise_inputs` fresh per-lane noise tensors drawn in order from the
/// lane's RNG stream, and optionally the trailing per-lane `snr`
/// vector. Free lanes get exact no-op inputs (`t = 1`, `h = 0` /
/// `t_next = t`, zero noise, `snr = 0`). Completion and NFE accounting
/// are shared, so they cannot diverge between solvers: each live lane
/// advances one grid node (+`score_evals_per_step` NFE), takes its
/// output row, and is reported converged once its schedule is
/// exhausted.
pub(crate) struct FixedProgram {
    pub kernel: &'static StepKernel,
}

impl LaneProgram for FixedProgram {
    fn solver_name(&self) -> &'static str {
        self.kernel.solver
    }

    fn step_artifact(&self) -> &'static str {
        self.kernel.artifact
    }

    fn score_evals_per_step(&self) -> u64 {
        self.kernel.score_evals_per_step
    }

    fn vp_only(&self) -> bool {
        self.kernel.vp_only
    }

    fn init_lane(&self, _cfg: &EngineConfig, process: &Process, req: &SampleRequest) -> LaneState {
        // kernels without an snr input carry 0.0; a PC spec without an
        // explicit snr resolves the serving process's default here, so
        // the lane state (and migration) always holds the concrete value
        let snr = if self.kernel.snr_input {
            req.solver.snr().unwrap_or_else(|| rdl::default_snr(process))
        } else {
            0.0
        };
        LaneState::Fixed { done: 0, total: fixed_total(req), snr }
    }

    fn step(&self, io: StepIo<'_, '_>) -> Result<StepOutcome> {
        if self.kernel.vp_only && io.process.kind() != "vp" {
            // the registry refuses to build VP-only pools for non-VP
            // models, so this is a defence-in-depth invariant, not a
            // reachable serving path
            bail!("{} pool on a non-VP model", self.kernel.artifact);
        }
        if io.steps_per_dispatch > 1 {
            return self.step_fused(io);
        }
        let b = io.slots.len();
        let dim = io.model.meta.dim;
        let t_eps = io.process.t_eps();
        let mut t_in = vec![1.0f32; b];
        // free-lane no-op value: h = 0, or t_next = t = 1
        let free_t2 = match self.kernel.time {
            TimeArg::StepSize => 0.0f32,
            TimeArg::NextTime => 1.0f32,
        };
        let mut t2_in = vec![free_t2; b];
        let mut snr_in = vec![0.0f32; b];
        let mut noise: Vec<Tensor> =
            (0..self.kernel.noise_inputs).map(|_| Tensor::zeros(&[b, dim])).collect();
        let mut occupied = 0usize;
        let mut per_lane_nodes = vec![0u64; b];
        for (i, slot) in io.slots.iter_mut().enumerate() {
            if let Slot::Running { rng, state: LaneState::Fixed { done, total, snr }, .. } = slot
            {
                occupied += 1;
                per_lane_nodes[i] = 1;
                let t = uniform_t(t_eps, *total, *done);
                let tn = uniform_t(t_eps, *total, *done + 1);
                io.diag.record_fixed(i, t, t - tn);
                t_in[i] = t as f32;
                t2_in[i] = match self.kernel.time {
                    TimeArg::StepSize => (t - tn) as f32,
                    TimeArg::NextTime => tn as f32,
                };
                snr_in[i] = *snr as f32;
                // z1 then z2 from the lane's stream — the draw order the
                // offline twins replay
                for z in noise.iter_mut() {
                    rng.fill_normal(z.row_mut(i));
                }
            }
        }
        let t_t = Tensor { shape: vec![b], data: t_in };
        let t2_t = Tensor { shape: vec![b], data: t2_in };
        let snr_t = Tensor { shape: vec![b], data: snr_in };
        let mut args: Vec<ExecArg<'_>> =
            vec![ExecArg::Host(io.x), ExecArg::Host(&t_t), ExecArg::Host(&t2_t)];
        for z in &noise {
            args.push(ExecArg::Host(z));
        }
        if self.kernel.snr_input {
            args.push(ExecArg::Host(&snr_t));
        }
        let out = io.model.exec_args(self.kernel.artifact, b, &args, io.cfg.fused_buffers)?;
        let converged =
            fold_fixed_step(io.slots, io.x, &out[0], self.kernel.score_evals_per_step);
        Ok(StepOutcome {
            occupied,
            lane_nodes: occupied as u64,
            per_lane_nodes,
            rejections: 0,
            converged,
            converged_groups: Vec::new(),
        })
    }
}

impl FixedProgram {
    /// Device-resident fused path: one dispatch of the k-step artifact
    /// advances every live lane by up to k grid nodes, with `x` staying
    /// on device between dispatches. The per-step inputs are stacked
    /// `t/t2[k, B]` and noise `[k, B, dim]`; a lane with fewer than k
    /// nodes left rides the tail rows as exact no-ops (`h = 0` /
    /// `t_next = t = 1`, no noise drawn), so its RNG stream and output
    /// bits match the k = 1 path exactly. Host-side bookkeeping (done,
    /// nfe) folds only the real nodes; `x` rows are NOT copied back —
    /// the output slab becomes the next dispatch's input, and the
    /// engine downloads it only at admission, migration, or completion.
    fn step_fused(&self, io: StepIo<'_, '_>) -> Result<StepOutcome> {
        let b = io.slots.len();
        let dim = io.model.meta.dim;
        let k = io.steps_per_dispatch;
        let t_eps = io.process.t_eps();
        let free_t2 = match self.kernel.time {
            TimeArg::StepSize => 0.0f32,
            TimeArg::NextTime => 1.0f32,
        };
        // defaults are the no-op row (t = 1, h = 0 / t_next = 1): free
        // lanes and live-lane tail rows both keep them
        let mut t_in = vec![1.0f32; k * b];
        let mut t2_in = vec![free_t2; k * b];
        let mut snr_in = vec![0.0f32; b];
        let mut noise: Vec<Tensor> =
            (0..self.kernel.noise_inputs).map(|_| Tensor::zeros(&[k, b, dim])).collect();
        let mut occupied = 0usize;
        let mut lane_nodes = 0u64;
        let mut real = vec![0usize; b];
        for (i, slot) in io.slots.iter_mut().enumerate() {
            if let Slot::Running { rng, state: LaneState::Fixed { done, total, snr }, .. } = slot
            {
                occupied += 1;
                let r = k.min(*total - *done);
                real[i] = r;
                lane_nodes += r as u64;
                snr_in[i] = *snr as f32;
                for j in 0..r {
                    let t = uniform_t(t_eps, *total, *done + j);
                    let tn = uniform_t(t_eps, *total, *done + j + 1);
                    io.diag.record_fixed(i, t, t - tn);
                    t_in[j * b + i] = t as f32;
                    t2_in[j * b + i] = match self.kernel.time {
                        TimeArg::StepSize => (t - tn) as f32,
                        TimeArg::NextTime => tn as f32,
                    };
                    // z1 then z2 per node, node-major — the exact draw
                    // order k sequential single steps would consume
                    for z in noise.iter_mut() {
                        rng.fill_normal(z.row_mut(j * b + i));
                    }
                }
            }
        }
        let t_t = Tensor { shape: vec![k, b], data: t_in };
        let t2_t = Tensor { shape: vec![k, b], data: t2_in };
        let snr_t = Tensor { shape: vec![b], data: snr_in };
        if io.dev_x.is_none() {
            // first fused dispatch after admission/migration: the host
            // x is current, stage it device-resident
            *io.dev_x = Some(io.model.upload(io.x)?);
        }
        let artifact = fused_artifact(self.kernel.artifact, k);
        // score_evals parity with k = 1: a single-step dispatch bills
        // score_evals_per_step once per *batched call*, however many
        // lanes ride it — so a fused dispatch bills one batched step per
        // stacked node that advances at least one live lane (max real
        // over lanes), never the no-op tail beyond every lane's
        // schedule. Summed over dispatches this equals the k = 1
        // dispatch count exactly, which is the invariant the parity
        // tests and tools/check_perf.py assert.
        let real_steps = real.iter().copied().max().unwrap_or(0) as u64;
        let out = {
            let slab = io.dev_x.as_ref().expect("uploaded above");
            let mut args: Vec<ExecArg<'_>> =
                vec![ExecArg::Device(slab), ExecArg::Host(&t_t), ExecArg::Host(&t2_t)];
            for z in &noise {
                args.push(ExecArg::Host(z));
            }
            if self.kernel.snr_input {
                args.push(ExecArg::Host(&snr_t));
            }
            let evals = real_steps * self.kernel.score_evals_per_step;
            io.model.exec_device(&artifact, b, &args, evals)?
        };
        *io.dev_x = Some(out);
        let mut converged = Vec::new();
        for (i, slot) in io.slots.iter_mut().enumerate() {
            let Slot::Running { nfe, state: LaneState::Fixed { done, total, .. }, .. } = slot
            else {
                continue;
            };
            *nfe += self.kernel.score_evals_per_step * real[i] as u64;
            *done += real[i];
            if *done == *total {
                converged.push(i);
            }
        }
        Ok(StepOutcome {
            occupied,
            lane_nodes,
            per_lane_nodes: real.iter().map(|&r| r as u64).collect(),
            rejections: 0,
            converged,
            converged_groups: Vec::new(),
        })
    }
}

/// Fold a fixed-step kernel's output back into the pool — shared by
/// every `LaneState::Fixed` lane so the completion predicate and NFE
/// accounting cannot diverge between solvers: each live lane advances
/// one grid node (+`evals` NFE), takes its output row, and is reported
/// converged once its schedule is exhausted.
fn fold_fixed_step(slots: &mut [Slot], x: &mut Tensor, xn: &Tensor, evals: u64) -> Vec<usize> {
    let mut converged = Vec::new();
    for i in 0..slots.len() {
        let Slot::Running { nfe, state: LaneState::Fixed { done, total, .. }, .. } =
            &mut slots[i]
        else {
            continue;
        };
        *nfe += evals;
        x.row_mut(i).copy_from_slice(xn.row(i));
        *done += 1;
        if *done == *total {
            converged.push(i);
        }
    }
    converged
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::ServingSolver;

    #[test]
    fn for_solver_covers_the_served_set() {
        for (name, artifact, evals) in [
            ("adaptive", "adaptive_step", 2),
            ("em", "em_step", 1),
            ("ddim", "ddim_step", 1),
            ("pc", "pc_step", 2),
        ] {
            let p = for_solver(name).expect(name);
            assert_eq!(p.solver_name(), name);
            assert_eq!(p.step_artifact(), artifact);
            assert_eq!(p.score_evals_per_step(), evals);
        }
        assert!(for_solver("ddim").unwrap().vp_only());
        assert!(!for_solver("pc").unwrap().vp_only());
        assert!(for_solver("ode").is_none());
    }

    fn req(solver: ServingSolver) -> SampleRequest {
        SampleRequest {
            model: String::new(),
            solver,
            n: 1,
            eps_rel: 0.07,
            seed: 0,
            sample_base: 0,
            priority: None,
            deadline_ms: None,
            cancel_token: None,
        }
    }

    #[test]
    fn init_lane_seeds_program_state_from_the_request() {
        let cfg = EngineConfig::new("artifacts", "vp");
        let vp = Process::vp();
        let em = for_solver("em").unwrap();
        assert_eq!(
            em.init_lane(&cfg, &vp, &req(ServingSolver::Em { steps: 12 })),
            LaneState::Fixed { done: 0, total: 12, snr: 0.0 }
        );
        assert_eq!(
            AdaptiveProgram.init_lane(&cfg, &vp, &req(ServingSolver::Adaptive)),
            LaneState::Adaptive { t: 1.0, h: cfg.h_init, eps_rel: 0.07 }
        );
    }

    #[test]
    fn pc_lane_resolves_snr_from_the_spec_or_the_process() {
        let cfg = EngineConfig::new("artifacts", "vp");
        let pc = for_solver("pc").unwrap();
        // explicit spec snr wins
        assert_eq!(
            pc.init_lane(&cfg, &Process::vp(), &req(ServingSolver::Pc {
                steps: 8,
                snr: Some(0.17)
            })),
            LaneState::Fixed { done: 0, total: 8, snr: 0.17 }
        );
        // bare pc:<n> takes the serving process's default (Song et al.)
        assert_eq!(
            pc.init_lane(&cfg, &Process::vp(), &req(ServingSolver::Pc { steps: 8, snr: None })),
            LaneState::Fixed { done: 0, total: 8, snr: 0.01 }
        );
        assert_eq!(
            pc.init_lane(
                &cfg,
                &Process::ve(50.0),
                &req(ServingSolver::Pc { steps: 8, snr: None })
            ),
            LaneState::Fixed { done: 0, total: 8, snr: 0.16 }
        );
    }
}
