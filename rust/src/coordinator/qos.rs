//! QoS / admission control (docs/ARCHITECTURE.md §Admission & QoS).
//!
//! The registry used to service every (model, program) pool one fused
//! step per turn, unweighted, and the only admission control was the
//! engine's single global `max_queue_samples` cap. This module owns the
//! two decisions the serving path was missing:
//!
//! * **which requests get in** — per-model admission quotas (max queued
//!   samples, max active lanes), request priority classes
//!   (`interactive` / `batch`: interactive requests are queued ahead of
//!   batch within a pool's FIFO), and optional per-request deadlines
//!   (`deadline_ms`): a request whose deadline expires while it is
//!   still fully queued is shed with a structured error instead of
//!   burning lane time on an answer nobody is waiting for — the
//!   serving-side analogue of the paper's "never wastes work";
//! * **which pool steps next** — deficit-weighted round-robin
//!   ([`WeightedRoundRobin`]) across the flattened (model, program)
//!   pool list. Each pool has a configurable weight (default 1); a
//!   saturated pool receives fused steps proportional to its weight,
//!   and with all weights equal the service order is *identical* to the
//!   flat rotation the registry used before (the determinism guard in
//!   the tests below pins this).
//!
//! Decision flow per request: quota check at admission → priority
//! placement in the pool FIFO → deficit round-robin picks the pool →
//! the pool's `BucketScheduler` picks the bucket width. Rejections and
//! sheds carry machine-readable error codes ([`error_code`]) that the
//! wire layer surfaces as a `code` field next to `error`.
//!
//! Per-class queue-wait and end-to-end latency histograms
//! (`metrics::hist::Histogram`) are kept per priority class and
//! exported through `stats` as p50/p95/p99.

use crate::metrics::hist::Histogram;
use crate::{anyhow, bail, Result};

// --- priority classes -----------------------------------------------------------

/// Request priority class. `Interactive` requests are queued ahead of
/// `Batch` requests within a pool's FIFO (stable order within a class);
/// classes do not preempt running lanes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Throughput traffic; queued behind interactive requests.
    Batch,
    /// Latency-sensitive traffic; jumps ahead of batch in the queue.
    #[default]
    Interactive,
}

pub const PRIORITY_CLASSES: [Priority; 2] = [Priority::Interactive, Priority::Batch];

impl Priority {
    /// Wire/CLI name ("interactive" | "batch").
    pub fn as_str(&self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }

    /// Parse a wire/CLI priority name.
    pub fn parse(s: &str) -> Result<Priority> {
        match s.trim() {
            "interactive" => Ok(Priority::Interactive),
            "batch" => Ok(Priority::Batch),
            other => bail!("unknown priority '{other}' (accepted: interactive, batch)"),
        }
    }

    /// Index into per-class arrays (stable across the wire ordering).
    pub fn idx(&self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
        }
    }
}

// --- structured rejection codes -------------------------------------------------

/// Machine-readable code for a per-model admission-quota rejection.
pub const CODE_QUOTA: &str = "quota_exceeded";
/// Machine-readable code for the global queue cap rejection.
pub const CODE_QUEUE_FULL: &str = "queue_full";
/// Machine-readable code for a deadline-shed request.
pub const CODE_DEADLINE: &str = "deadline_exceeded";
/// Machine-readable code for a malformed or degenerate solver spec
/// (zero-step fixed schedule, non-positive / non-finite Langevin snr)
/// rejected at admission or in the wire parser.
pub const CODE_BAD_SOLVER: &str = "bad_solver";
/// Machine-readable code for a request the wire layer cannot parse:
/// malformed JSON, a missing/mistyped field, or a value out of range.
pub const CODE_BAD_REQUEST: &str = "bad_request";
/// Machine-readable code for an unknown wire op (the error text lists
/// the supported op names).
pub const CODE_BAD_OP: &str = "bad_op";
/// Machine-readable code for a job id the job table does not hold: never
/// issued, already polled, already canceled, or already completed (a
/// completed job can no longer be canceled; its result stays pollable).
pub const CODE_UNKNOWN_JOB: &str = "unknown_job";
/// Machine-readable fallback code for errors with no structured cause
/// (engine faults, routing errors surfaced as plain strings). Every
/// `ok:false` wire response carries *some* code; this is the catch-all.
pub const CODE_INTERNAL: &str = "internal";

/// Prefix an error message with a structured code; [`error_code`]
/// recovers it at the wire layer.
pub fn coded(code: &str, msg: &str) -> String {
    format!("{code}: {msg}")
}

/// The structured code a rejection message carries, if any. Engine
/// errors travel as strings through reply channels; the wire layer uses
/// this to emit a `code` field next to `error` without a parallel error
/// type crossing every channel.
pub fn error_code(msg: &str) -> Option<&'static str> {
    for code in [
        CODE_QUOTA,
        CODE_QUEUE_FULL,
        CODE_DEADLINE,
        CODE_BAD_SOLVER,
        CODE_BAD_REQUEST,
        CODE_BAD_OP,
        CODE_UNKNOWN_JOB,
        CODE_INTERNAL,
    ] {
        if let Some(rest) = msg.strip_prefix(code) {
            if rest.starts_with(':') {
                return Some(code);
            }
        }
    }
    None
}

// --- configuration --------------------------------------------------------------

/// Per-model admission quota. `None` = unlimited (the global
/// `max_queue_samples` cap still applies).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Quota {
    /// Max samples queued (not yet in a lane) for the model, summed over
    /// its pools; exceeding requests are rejected with [`CODE_QUOTA`].
    pub max_queued: Option<usize>,
    /// Max lanes the model may occupy concurrently, summed over its
    /// pools. A throttle, not a rejection: admission into lanes pauses
    /// at the cap and resumes as lanes free up.
    pub max_active_lanes: Option<usize>,
}

/// QoS configuration carried in `EngineConfig`. The default is
/// behaviour-preserving: every weight 1 (flat round-robin order), no
/// quotas, every request `interactive` unless it names a class.
#[derive(Clone, Debug, Default)]
pub struct QosConfig {
    /// Pool weights keyed by `"model"` (all of that model's pools) or
    /// `"model/program"` (one pool; the more specific key wins).
    /// Missing keys default to 1.0.
    pub weights: Vec<(String, f64)>,
    /// Per-model admission quotas keyed by model name.
    pub quotas: Vec<(String, Quota)>,
    /// Class assigned to requests that don't name one.
    pub default_priority: Priority,
}

impl QosConfig {
    fn quota_mut(&mut self, model: &str) -> &mut Quota {
        if let Some(i) = self.quotas.iter().position(|(m, _)| m == model) {
            return &mut self.quotas[i].1;
        }
        self.quotas.push((model.to_string(), Quota::default()));
        &mut self.quotas.last_mut().unwrap().1
    }

    pub fn set_max_queued(&mut self, model: &str, n: usize) {
        self.quota_mut(model).max_queued = Some(n);
    }

    pub fn set_max_active_lanes(&mut self, model: &str, n: usize) {
        self.quota_mut(model).max_active_lanes = Some(n);
    }
}

/// Parse a `--weights` spec: `"vp=3,ve=1"` or `"vp/em=0.5"`. Weights
/// must be finite and > 0 (a zero weight would starve the pool
/// forever — use a quota of 0 to close admission instead).
pub fn parse_weights(s: &str) -> Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (key, val) = part
            .split_once('=')
            .ok_or_else(|| anyhow!("bad weight '{part}' (expected model=w or model/program=w)"))?;
        let w: f64 = val
            .trim()
            .parse()
            .map_err(|_| anyhow!("bad weight value '{val}' for '{key}'"))?;
        if !w.is_finite() || w <= 0.0 {
            bail!("weight for '{key}' must be finite and > 0 (got {w})");
        }
        let key = key.trim().to_string();
        if out.iter().any(|(k, _)| *k == key) {
            bail!("weight for '{key}' given twice");
        }
        out.push((key, w));
    }
    Ok(out)
}

/// Parse a `--quota` / `--quota-lanes` spec: `"vp=256,ve=64"`.
pub fn parse_quota_list(s: &str) -> Result<Vec<(String, usize)>> {
    let mut out = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (key, val) = part
            .split_once('=')
            .ok_or_else(|| anyhow!("bad quota '{part}' (expected model=n)"))?;
        let n: usize = val
            .trim()
            .parse()
            .map_err(|_| anyhow!("bad quota value '{val}' for '{key}'"))?;
        let key = key.trim().to_string();
        if out.iter().any(|(k, _)| *k == key) {
            bail!("quota for '{key}' given twice");
        }
        out.push((key, n));
    }
    Ok(out)
}

/// Parse a `--steps-per-dispatch` spec: a bare k sets the global
/// default (`"8"`), `model=k` / `model/solver=k` entries override it
/// per pool (`"8,vp=4,vp:adaptive=8"`; `:` is accepted as the
/// model/solver separator and normalized to `/`). Returns the bare
/// global (if any) plus the override list in spec order; the registry
/// validates keys against served pools at startup, like `--weights`.
/// A k of 0 is rejected here — every pool dispatches at least one
/// step per turn.
pub fn parse_steps_spec(s: &str) -> Result<(Option<usize>, Vec<(String, usize)>)> {
    let mut global: Option<usize> = None;
    let mut out: Vec<(String, usize)> = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let Some((key, val)) = part.split_once('=') else {
            let k: usize = part.parse().map_err(|_| {
                anyhow!("bad steps-per-dispatch '{part}' (expected k, model=k or model/solver=k)")
            })?;
            if k == 0 {
                bail!("steps-per-dispatch must be >= 1 (got 0)");
            }
            if global.is_some() {
                bail!("global steps-per-dispatch given twice ('{part}')");
            }
            global = Some(k);
            continue;
        };
        let k: usize = val
            .trim()
            .parse()
            .map_err(|_| anyhow!("bad steps-per-dispatch value '{val}' for '{key}'"))?;
        if k == 0 {
            bail!("steps-per-dispatch for '{key}' must be >= 1 (got 0)");
        }
        let key = key.trim().replace(':', "/");
        if key.is_empty() || key.split('/').count() > 2 || key.split('/').any(str::is_empty) {
            bail!("bad steps-per-dispatch key '{key}' (expected model or model/solver)");
        }
        if out.iter().any(|(existing, _)| *existing == key) {
            bail!("steps-per-dispatch for '{key}' given twice");
        }
        out.push((key, k));
    }
    Ok((global, out))
}

// --- deficit-weighted round-robin ----------------------------------------------

/// Deficit-weighted round-robin over the flattened (model, program)
/// pool list: one service turn = one fused pool step (unit cost).
///
/// On each visit the cursor pool is granted its weight as credit; it is
/// served while it holds at least one full credit, then the cursor
/// moves on. A pool that goes idle forfeits its residual credit, so a
/// quiet pool cannot bank turns into a burst. Saturated pools therefore
/// receive turns proportional to their weights; with all weights 1 the
/// order degenerates to exactly the flat rotation the registry used
/// before (each busy pool: grant 1, spend 1, advance).
#[derive(Clone, Debug)]
pub struct WeightedRoundRobin {
    weights: Vec<f64>,
    deficit: Vec<f64>,
    /// Service turns granted per pool (fairness accounting, exported
    /// through `stats`).
    pub turns: Vec<u64>,
    cursor: usize,
    /// Whether the cursor pool has received its quantum for the current
    /// visit (cleared whenever the cursor advances).
    granted: bool,
}

impl WeightedRoundRobin {
    /// One weight per flattened pool; all must be finite and > 0.
    pub fn new(weights: Vec<f64>) -> WeightedRoundRobin {
        assert!(
            weights.iter().all(|w| w.is_finite() && *w > 0.0),
            "pool weights must be finite and > 0: {weights:?}"
        );
        let n = weights.len();
        WeightedRoundRobin {
            weights,
            deficit: vec![0.0; n],
            turns: vec![0; n],
            cursor: 0,
            granted: false,
        }
    }

    pub fn len(&self) -> usize {
        self.weights.len()
    }

    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }

    pub fn weight(&self, i: usize) -> f64 {
        self.weights[i]
    }

    fn advance(&mut self) {
        self.cursor = (self.cursor + 1) % self.weights.len();
        self.granted = false;
    }

    /// Next pool to grant a service turn, among those `busy` reports
    /// true for. Returns `None` only when no pool is busy. A full scan
    /// adds at least `min(weight)` credit to every busy pool, so the
    /// bounded number of passes below always finds an eligible pool
    /// when one is busy.
    pub fn next(&mut self, busy: &mut dyn FnMut(usize) -> bool) -> Option<usize> {
        let n = self.weights.len();
        if n == 0 {
            return None;
        }
        let min_w = self.weights.iter().copied().fold(f64::INFINITY, f64::min);
        let passes = (1.0 / min_w).ceil().max(1.0) as usize + 1;
        for _ in 0..passes {
            let mut any_busy = false;
            for _ in 0..n {
                let i = self.cursor;
                if !busy(i) {
                    // an emptied pool forfeits its residual credit
                    self.deficit[i] = 0.0;
                    self.advance();
                    continue;
                }
                any_busy = true;
                if !self.granted {
                    self.deficit[i] += self.weights[i];
                    self.granted = true;
                }
                if self.deficit[i] >= 1.0 {
                    self.deficit[i] -= 1.0;
                    self.turns[i] += 1;
                    if self.deficit[i] < 1.0 {
                        // visit exhausted; next call moves on
                        self.advance();
                    }
                    return Some(i);
                }
                // fractional weight still accumulating: skip this visit
                self.advance();
            }
            if !any_busy {
                return None;
            }
        }
        None
    }
}

// --- engine-side state ----------------------------------------------------------

/// Per-priority-class serving metrics (client traffic only; eval chunks
/// are internal requests with their own counters).
#[derive(Clone, Debug, Default)]
pub(crate) struct ClassMetrics {
    /// Admission-queue wait: first-sample admission minus enqueue.
    pub queue_wait: Histogram,
    /// End-to-end: completion minus enqueue.
    pub e2e: Histogram,
    pub requests_done: u64,
}

/// Snapshot of one class's latency metrics, exported through `stats`.
#[derive(Clone, Debug, Default)]
pub struct ClassLatencyStats {
    /// Class name ("interactive" | "batch").
    pub class: String,
    pub requests_done: u64,
    pub queue_wait_p50_s: f64,
    pub queue_wait_p95_s: f64,
    pub queue_wait_p99_s: f64,
    pub e2e_p50_s: f64,
    pub e2e_p95_s: f64,
    pub e2e_p99_s: f64,
    /// Histogram count/sum pairs backing the Prometheus summary
    /// exposition (`_count`/`_sum` next to the quantile gauges); the
    /// JSON `stats` shape keeps its original keys.
    pub queue_wait_count: u64,
    pub queue_wait_sum_s: f64,
    pub e2e_count: u64,
    pub e2e_sum_s: f64,
}

/// Per-(model, program) pool QoS snapshot, exported through `stats`.
#[derive(Clone, Debug, Default)]
pub struct PoolQosStats {
    pub model: String,
    pub solver: String,
    pub weight: f64,
    /// DWRR service turns granted to the pool.
    pub turns: u64,
    /// Fused steps the pool executed.
    pub steps: u64,
    pub occupied_lane_steps: u64,
    /// Samples queued on the pool (not yet in a lane).
    pub queue_depth: usize,
    pub active_lanes: usize,
    /// Resolved fused k the pool dispatches at (grid nodes for
    /// fixed-step pools, Algorithm-1 attempts for the adaptive fold),
    /// after per-pool overrides, kernel clamping and artifact-ladder
    /// resolution.
    pub steps_per_dispatch: usize,
    /// Per-pool step wall-time distribution (telemetry): dispatch
    /// count, summed seconds, and quantiles of the pool's step-time
    /// histogram — the Prometheus `gofast_pool_step_seconds` series.
    pub step_count: u64,
    pub step_sum_s: f64,
    pub step_p50_s: f64,
    pub step_p95_s: f64,
    pub step_p99_s: f64,
    /// Adaptive proposal accept/reject counters (Algorithm 1's step
    /// test; always 0 for fixed-step pools, which never reject).
    pub accepted: u64,
    pub rejected: u64,
    /// Step executions per bucket width, ascending — the per-pool
    /// split of the program-level breakdown, exported as
    /// `gofast_pool_bucket_steps_total{model,solver,bucket}`.
    pub steps_per_bucket: Vec<(usize, u64)>,
}

/// All QoS state the engine threads through admission and service:
/// the weighted scheduler, resolved per-model quotas, per-model queue
/// accounting, per-class latency metrics, and shed/reject counters.
pub(crate) struct QosState {
    pub wrr: WeightedRoundRobin,
    /// Per model index (parallel to the registry's entries).
    pub quotas: Vec<Quota>,
    pub queued_per_model: Vec<usize>,
    pub default_priority: Priority,
    /// Indexed by `Priority::idx()`.
    pub classes: [ClassMetrics; 2],
    pub shed_deadline: u64,
    pub rejected_quota: u64,
    /// Still-queued requests canceled through the async job API (the
    /// dequeue twin of `shed_deadline`: same accounting, client-driven
    /// trigger instead of a deadline).
    pub canceled: u64,
}

impl QosState {
    /// Resolve a config against the registry's flattened pool list.
    /// `pools` is `(model name, solver name)` in flat service order;
    /// `models` the model names in index order. Unknown weight/quota
    /// keys fail startup — a typo'd model name silently serving at
    /// weight 1 is exactly the misconfiguration this catches.
    pub fn new(
        cfg: &QosConfig,
        pools: &[(String, String)],
        models: &[String],
    ) -> Result<QosState> {
        for (key, _) in &cfg.weights {
            let (model, prog) = match key.split_once('/') {
                Some((m, p)) => (m, Some(p)),
                None => (key.as_str(), None),
            };
            let hit = pools
                .iter()
                .any(|(m, p)| m == model && prog.is_none_or(|want| p == want));
            if !hit {
                bail!(
                    "--weights key '{key}' matches no served pool (pools: {:?})",
                    pools.iter().map(|(m, p)| format!("{m}/{p}")).collect::<Vec<_>>()
                );
            }
        }
        for (model, q) in &cfg.quotas {
            if !models.contains(model) {
                bail!("--quota model '{model}' is not served (serving: {models:?})");
            }
            if q.max_active_lanes == Some(0) {
                // a 0-lane model could hold queued work forever; closing
                // admission is the queued quota's job
                bail!(
                    "--quota-lanes for '{model}' must be >= 1 (use --quota {model}=0 \
                     to close admission instead)"
                );
            }
        }
        let weights = pools
            .iter()
            .map(|(m, p)| {
                let exact = format!("{m}/{p}");
                cfg.weights
                    .iter()
                    .find(|(k, _)| *k == exact)
                    .or_else(|| cfg.weights.iter().find(|(k, _)| k == m))
                    .map(|(_, w)| *w)
                    .unwrap_or(1.0)
            })
            .collect();
        let quotas = models
            .iter()
            .map(|m| {
                cfg.quotas
                    .iter()
                    .find(|(k, _)| k == m)
                    .map(|(_, q)| *q)
                    .unwrap_or_default()
            })
            .collect();
        Ok(QosState {
            wrr: WeightedRoundRobin::new(weights),
            quotas,
            queued_per_model: vec![0; models.len()],
            default_priority: cfg.default_priority,
            classes: Default::default(),
            shed_deadline: 0,
            rejected_quota: 0,
            canceled: 0,
        })
    }

    /// Latency snapshots for every class, interactive first.
    pub fn class_stats(&self) -> Vec<ClassLatencyStats> {
        PRIORITY_CLASSES
            .iter()
            .map(|p| {
                let m = &self.classes[p.idx()];
                ClassLatencyStats {
                    class: p.as_str().to_string(),
                    requests_done: m.requests_done,
                    queue_wait_p50_s: m.queue_wait.quantile(0.5),
                    queue_wait_p95_s: m.queue_wait.quantile(0.95),
                    queue_wait_p99_s: m.queue_wait.quantile(0.99),
                    e2e_p50_s: m.e2e.quantile(0.5),
                    e2e_p95_s: m.e2e.quantile(0.95),
                    e2e_p99_s: m.e2e.quantile(0.99),
                    queue_wait_count: m.queue_wait.count(),
                    queue_wait_sum_s: m.queue_wait.sum(),
                    e2e_count: m.e2e.count(),
                    e2e_sum_s: m.e2e.sum(),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_parses_and_orders() {
        assert_eq!(Priority::parse("interactive").unwrap(), Priority::Interactive);
        assert_eq!(Priority::parse(" batch ").unwrap(), Priority::Batch);
        assert!(Priority::parse("urgent").is_err());
        assert!(Priority::Interactive > Priority::Batch);
        assert_eq!(Priority::default(), Priority::Interactive);
        for p in PRIORITY_CLASSES {
            assert_eq!(Priority::parse(p.as_str()).unwrap(), p);
        }
    }

    #[test]
    fn error_codes_roundtrip() {
        let msg = coded(CODE_QUOTA, "model 'vp' over quota");
        assert_eq!(error_code(&msg), Some(CODE_QUOTA));
        assert_eq!(error_code("queue full (8 samples)"), None);
        assert_eq!(error_code(&coded(CODE_DEADLINE, "x")), Some(CODE_DEADLINE));
        assert_eq!(error_code(&coded(CODE_BAD_SOLVER, "snr must be > 0")), Some(CODE_BAD_SOLVER));
        assert_eq!(error_code("quota_exceeded_extra: x"), None);
        // the async-protocol codes ride the same prefix scheme
        assert_eq!(error_code(&coded(CODE_BAD_REQUEST, "no op field")), Some(CODE_BAD_REQUEST));
        assert_eq!(error_code(&coded(CODE_BAD_OP, "unknown op 'x'")), Some(CODE_BAD_OP));
        assert_eq!(error_code(&coded(CODE_UNKNOWN_JOB, "job 9")), Some(CODE_UNKNOWN_JOB));
        assert_eq!(error_code(&coded(CODE_INTERNAL, "engine fault")), Some(CODE_INTERNAL));
    }

    #[test]
    fn weight_and_quota_parsers() {
        let w = parse_weights("vp=3, ve=1.5,vp/em=0.5").unwrap();
        assert_eq!(w.len(), 3);
        assert_eq!(w[0], ("vp".to_string(), 3.0));
        assert_eq!(w[2], ("vp/em".to_string(), 0.5));
        assert!(parse_weights("vp=0").is_err(), "zero weight starves");
        assert!(parse_weights("vp=-1").is_err());
        assert!(parse_weights("vp").is_err());
        assert!(parse_weights("vp=1,vp=2").is_err(), "duplicate key");
        assert_eq!(parse_weights("").unwrap(), vec![]);
        let q = parse_quota_list("vp=256,ve=0").unwrap();
        assert_eq!(q, vec![("vp".to_string(), 256), ("ve".to_string(), 0)]);
        assert!(parse_quota_list("vp=many").is_err());
    }

    #[test]
    fn steps_spec_parser() {
        // bare global, keyed overrides, ':' normalized to '/'
        let (g, o) = parse_steps_spec("8, vp=4,ve:adaptive=8").unwrap();
        assert_eq!(g, Some(8));
        assert_eq!(
            o,
            vec![("vp".to_string(), 4), ("ve/adaptive".to_string(), 8)]
        );
        let (g, o) = parse_steps_spec("vp/em=2").unwrap();
        assert_eq!(g, None);
        assert_eq!(o, vec![("vp/em".to_string(), 2)]);
        assert_eq!(parse_steps_spec("").unwrap(), (None, vec![]));
        assert!(parse_steps_spec("0").is_err(), "zero global k");
        assert!(parse_steps_spec("vp=0").is_err(), "zero override k");
        assert!(parse_steps_spec("4,8").is_err(), "duplicate global");
        assert!(parse_steps_spec("vp=1,vp=2").is_err(), "duplicate key");
        assert!(parse_steps_spec("vp:adaptive=1,vp/adaptive=2").is_err(), "':' aliases '/'");
        assert!(parse_steps_spec("many").is_err(), "non-numeric bare entry");
        assert!(parse_steps_spec("vp=many").is_err());
        assert!(parse_steps_spec("a/b/c=2").is_err(), "too many key parts");
        assert!(parse_steps_spec("/em=2").is_err(), "empty model part");
    }

    /// Reference model of the registry's pre-QoS flat rotation: scan
    /// from the cursor, serve the first busy pool, park the cursor just
    /// past it.
    struct FlatRr {
        cursor: usize,
        n: usize,
    }

    impl FlatRr {
        fn next(&mut self, busy: &mut dyn FnMut(usize) -> bool) -> Option<usize> {
            for k in 0..self.n {
                let i = (self.cursor + k) % self.n;
                if busy(i) {
                    self.cursor = (i + 1) % self.n;
                    return Some(i);
                }
            }
            None
        }
    }

    /// The determinism guard: with equal weights the DWRR service order
    /// is identical to the flat round-robin it replaced, over a busy
    /// pattern that churns (pools going idle and busy between turns).
    #[test]
    fn equal_weights_reproduce_flat_round_robin() {
        let n = 5;
        let mut wrr = WeightedRoundRobin::new(vec![1.0; n]);
        let mut flat = FlatRr { cursor: 0, n };
        // deterministic churn: pool i is busy at turn t iff (t + i) is
        // not divisible by its own modulus
        for t in 0..500u64 {
            let mut busy_w = |i: usize| (t + i as u64) % (2 + i as u64 % 3) != 0;
            let mut busy_f = |i: usize| (t + i as u64) % (2 + i as u64 % 3) != 0;
            assert_eq!(
                wrr.next(&mut busy_w),
                flat.next(&mut busy_f),
                "service order diverged from flat round-robin at turn {t}"
            );
        }
    }

    #[test]
    fn saturated_pools_share_turns_by_weight() {
        let mut wrr = WeightedRoundRobin::new(vec![3.0, 1.0]);
        for _ in 0..4000 {
            assert!(wrr.next(&mut |_| true).is_some());
        }
        assert_eq!(wrr.turns, vec![3000, 1000], "3:1 weights must split turns 3:1");
    }

    #[test]
    fn fractional_weights_accumulate_deficit() {
        // weight 0.5 pool is served on every other visit
        let mut wrr = WeightedRoundRobin::new(vec![1.0, 0.5]);
        for _ in 0..300 {
            assert!(wrr.next(&mut |_| true).is_some());
        }
        assert_eq!(wrr.turns, vec![200, 100], "1:0.5 weights must split turns 2:1");
    }

    #[test]
    fn idle_pool_forfeits_credit() {
        let mut wrr = WeightedRoundRobin::new(vec![4.0, 1.0]);
        // pool 0 busy alone: consumes its visit quantum
        assert_eq!(wrr.next(&mut |i| i == 0), Some(0));
        // goes idle; pool 1 is served and pool 0's residue is cleared
        assert_eq!(wrr.next(&mut |i| i == 1), Some(1));
        // pool 0 busy again: it gets a fresh quantum (4 turns), not a
        // banked burst on top of the 3 credits it abandoned
        let mut served = Vec::new();
        for _ in 0..5 {
            served.push(wrr.next(&mut |_| true).unwrap());
        }
        assert_eq!(served, vec![0, 0, 0, 0, 1], "fresh visit grants exactly the weight");
    }

    #[test]
    fn no_busy_pool_is_none() {
        let mut wrr = WeightedRoundRobin::new(vec![1.0, 1.0]);
        assert_eq!(wrr.next(&mut |_| false), None);
        assert!(WeightedRoundRobin::new(vec![]).next(&mut |_| true).is_none());
    }

    #[test]
    fn state_resolves_weights_and_quotas() {
        let pools = vec![
            ("vp".to_string(), "adaptive".to_string()),
            ("vp".to_string(), "em".to_string()),
            ("ve".to_string(), "adaptive".to_string()),
        ];
        let models = vec!["vp".to_string(), "ve".to_string()];
        let mut cfg = QosConfig {
            weights: parse_weights("vp=2,vp/em=5").unwrap(),
            ..Default::default()
        };
        cfg.set_max_queued("ve", 64);
        cfg.set_max_active_lanes("ve", 4);
        let st = QosState::new(&cfg, &pools, &models).unwrap();
        // model/program key wins over the model key; unlisted pools get 1
        assert_eq!(st.wrr.weight(0), 2.0);
        assert_eq!(st.wrr.weight(1), 5.0);
        assert_eq!(st.wrr.weight(2), 1.0);
        assert_eq!(st.quotas[0], Quota::default());
        assert_eq!(
            st.quotas[1],
            Quota { max_queued: Some(64), max_active_lanes: Some(4) }
        );

        let bad = QosConfig { weights: parse_weights("nope=2").unwrap(), ..Default::default() };
        assert!(QosState::new(&bad, &pools, &models).is_err(), "typo'd weight key");
        let mut bad = QosConfig::default();
        bad.set_max_queued("nope", 1);
        assert!(QosState::new(&bad, &pools, &models).is_err(), "typo'd quota model");
        let mut bad = QosConfig::default();
        bad.set_max_active_lanes("vp", 0);
        assert!(QosState::new(&bad, &pools, &models).is_err(), "0-lane quota would hang");
        // a queued quota of 0 is the sanctioned way to close admission
        let mut ok = QosConfig::default();
        ok.set_max_queued("vp", 0);
        assert!(QosState::new(&ok, &pools, &models).is_ok());
    }
}
