//! Engine thread: owns the PJRT runtime and runs the continuous-batching
//! step loop over every registered (model, solver-program) pool. See
//! module docs in `coordinator/mod.rs` and docs/ARCHITECTURE.md
//! §Coordinator.
//!
//! Loop shape per iteration: drain the mailbox, pick the next pool with
//! work (deficit-weighted round-robin over the flattened model x
//! program pool list — flat rotation at the default equal weights),
//! shed queued requests whose deadline expired, re-bucket the pool to
//! the cheapest compiled width that fits its demand, admit queued
//! samples into free lanes (interactive ahead of batch, capped by the
//! model's lane quota), and advance it one fused step of its program —
//! so adaptive generate traffic and EM/DDIM eval lanes interleave on
//! the single engine thread. Admission control (quotas, priorities,
//! deadlines, weights) lives in `coordinator/qos.rs`.

use super::diagnostics::{
    DiagQuery, DiagReply, HealthReply, HealthStats, PoolHealthSample, Watchdog,
};
use super::eval::{ChunkSpec, EvalManager, EvalRequest, EvalResult};
use super::programs::{LaneState, StepIo};
use super::qos::{self, ClassLatencyStats, PoolQosStats, QosConfig, QosState};
use super::registry::{ModelEntry, ProgramPool, Registry};
use super::scheduler::migrate_lanes;
use super::telemetry::{self, Kind, Outcome, SpanRing, TraceQuery, TraceReply};
use super::{Msg, Pending, SampleRequest, Sink, Slot};
use crate::metrics::hist::Histogram;
use crate::rng::Rng;
use crate::runtime::{ExecArg, Model, Runtime};
use crate::solvers::ServingSolver;
use crate::tensor::Tensor;
use crate::{anyhow, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub artifacts: PathBuf,
    /// Models served from the shared engine thread; the first is the
    /// default for requests that don't name one.
    pub models: Vec<String>,
    /// Solver programs each model gets a lane pool for (names accepted
    /// by `solvers::spec::parse`). "adaptive" is validated strictly;
    /// fixed-step pools are built from whatever artifacts exist.
    pub programs: Vec<String>,
    /// Widest slot-pool bucket; must be a compiled adaptive_step bucket
    /// of every served model (fixed-step pools cap their own ladders at
    /// the widest compiled rung <= this).
    pub bucket: usize,
    /// Occupancy-aware bucket migration. Off = every pool is pinned at
    /// its widest rung (the pre-scheduler fixed-width behaviour).
    pub migrate: bool,
    pub fused_buffers: bool,
    /// Grid nodes each fixed-step dispatch advances a lane by (the
    /// fused k-step kernels + device-resident lane state). 1 preserves
    /// the single-step host-resident behaviour; higher values are
    /// clamped per pool to the kernel's `max_steps_per_dispatch` and
    /// forced to 1 when `fused_buffers` is off (device residency needs
    /// the buffer path).
    pub steps_per_dispatch: usize,
    /// Per-pool fused-k overrides keyed `"model"` or `"model/solver"`
    /// (the more specific key wins; unlisted pools use
    /// `steps_per_dispatch`). A key matching no served pool fails
    /// startup, like a typo'd `--weights` key. Values are forced to 1
    /// alongside the global default when `fused_buffers` is off.
    pub steps_overrides: Vec<(String, usize)>,
    /// Admission control: maximum queued samples before rejecting
    /// (global; per-model quotas live in `qos`).
    pub max_queue_samples: usize,
    /// QoS policy: pool weights, per-model quotas, default priority
    /// class. The default is behaviour-preserving (flat rotation, no
    /// quotas, every request interactive).
    pub qos: QosConfig,
    /// Request-lifecycle span ring capacity (`serve --trace-ring`).
    /// 0 disables tracing entirely: the engine holds no ring and the
    /// hot step path records nothing and allocates nothing. Also sizes
    /// the runtime's dispatch-timeline ring (4x this, there being a few
    /// dispatches per request at typical NFE).
    pub trace_ring: usize,
    /// Lane-trace sampling for solver diagnostics (`serve
    /// --diag-sample N`): every Nth admitted lane records its full
    /// `(t, h, err, accepted)` sequence. 0 (the default) disables
    /// sampling; the always-on per-pool profiles cost a few float ops
    /// per lane step and allocate nothing, same contract as
    /// `--trace-ring 0`.
    pub diag_sample: usize,
    /// Seconds between watchdog health ticks (`serve
    /// --health-interval`). 0 checks on every engine-loop iteration.
    pub health_interval_s: f64,
    /// Wall-time a live lane may sit without progress before the
    /// watchdog fires a `stall` event (`serve --stall-budget`).
    pub stall_budget_s: f64,
    /// Algorithm-1 controller parameters (paper defaults).
    pub h_init: f64,
    pub r: f64,
    pub safety: f64,
}

impl EngineConfig {
    pub fn new(artifacts: impl Into<PathBuf>, model: &str) -> EngineConfig {
        EngineConfig {
            artifacts: artifacts.into(),
            models: vec![model.to_string()],
            programs: default_programs(),
            bucket: 16,
            migrate: true,
            fused_buffers: true,
            steps_per_dispatch: 1,
            steps_overrides: Vec::new(),
            max_queue_samples: 4096,
            qos: QosConfig::default(),
            trace_ring: 1024,
            diag_sample: 0,
            health_interval_s: 1.0,
            stall_budget_s: 10.0,
            h_init: 0.01,
            r: 0.9,
            safety: 0.9,
        }
    }
}

/// The full served-solver set: adaptive (mandatory artifacts) plus the
/// fixed-step baselines — EM, DDIM and the predictor–corrector —
/// wherever their artifacts exist.
pub fn default_programs() -> Vec<String> {
    vec!["adaptive".to_string(), "em".to_string(), "ddim".to_string(), "pc".to_string()]
}

/// What `EngineClient::cancel` found for a cancel token: a still-queued
/// request (now dequeued through the shed path), a request already
/// holding lanes (runs to completion, mirroring deadline semantics), or
/// no pending request at all (never admitted, or already finished).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelOutcome {
    Canceled,
    Running,
    NotFound,
}

#[derive(Clone, Debug)]
pub struct GenResult {
    /// Unit-range images, [n, dim].
    pub images: Tensor,
    pub nfe: Vec<u64>,
    /// Name and image geometry of the model that served the request.
    pub model: String,
    pub h: usize,
    pub w: usize,
    pub wall_s: f64,
    pub queued_s: f64,
}

/// Per-solver-program share of engine work, summed over models.
#[derive(Clone, Debug, Default)]
pub struct ProgramStats {
    /// Solver name ("adaptive" | "em" | "ddim" | "pc").
    pub solver: String,
    /// Pools serving this program (one per model that supports it).
    pub pools: usize,
    /// Currently occupied lanes.
    pub active_lanes: usize,
    /// Samples queued on this program's pools, not yet in a lane.
    pub queue_depth: usize,
    /// Fused step-program executions.
    pub steps: u64,
    pub occupied_lane_steps: u64,
    pub wasted_lane_steps: u64,
    /// Score-network evaluations spent advancing occupied lanes
    /// (occupied_lane_steps x the program's per-step NFE cost; excludes
    /// denoise calls and free-lane no-ops).
    pub score_evals: u64,
    pub migrations_up: u64,
    pub migrations_down: u64,
    /// Step executions per bucket width, ascending.
    pub steps_per_bucket: Vec<(usize, u64)>,
    /// Adaptive proposal outcomes summed over this program's pools
    /// (Algorithm 1's accept/reject test). Meaningful for the adaptive
    /// program only — fixed-step solvers never reject, so both stay 0.
    pub accepted: u64,
    pub rejected: u64,
}

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub requests_done: u64,
    pub samples_done: u64,
    /// Samples queued awaiting a lane, globally (the wire also exports
    /// this as `queue_depth`; per-pool split in `pool_qos`, per-program
    /// split in `programs`).
    pub queued_samples: usize,
    pub active_slots: usize,
    pub steps: u64,
    pub rejections: u64,
    pub score_evals: u64,
    /// Executable launches, summed over runtimes. At steps-per-dispatch
    /// k each fixed-step launch advances up to k grid nodes, so this
    /// falls roughly k-fold while `score_evals` stays put.
    pub dispatches: u64,
    /// Host→device bytes copied (lane uploads, staged constants,
    /// per-call argument transfers).
    pub bytes_h2d: u64,
    /// Device→host bytes copied (program outputs, lane downloads).
    pub bytes_d2h: u64,
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    pub latency_mean_s: f64,
    /// Mean occupied lane-nodes per dispatch since start (batching
    /// efficiency; equals occupied slots per step at
    /// steps-per-dispatch 1).
    pub mean_occupancy: f64,
    /// Models served, default first.
    pub models: Vec<String>,
    /// Per-solver-program lane/step counters (the program breakdown of
    /// the aggregate counters below).
    pub programs: Vec<ProgramStats>,
    /// Step executions per bucket width, summed over models & programs.
    pub steps_per_bucket: Vec<(usize, u64)>,
    /// Pool-width switches, summed over models & programs.
    pub migrations_up: u64,
    pub migrations_down: u64,
    /// Lane-nodes spent on exact no-ops: free lanes riding steps (the
    /// cost the bucket scheduler exists to shrink) plus, at
    /// steps-per-dispatch > 1, the no-op tail nodes of lanes whose
    /// remaining schedule was shorter than k.
    pub wasted_lane_steps: u64,
    /// Real grid nodes occupied lanes advanced through.
    pub occupied_lane_steps: u64,
    /// Engine-served evaluation runs completed.
    pub evals_done: u64,
    /// Evaluation jobs currently in flight.
    pub eval_active: usize,
    /// Samples generated for evaluation jobs (disjoint from client
    /// traffic; both are included in `samples_done`).
    pub eval_samples_done: u64,
    /// Real grid nodes advanced by lanes owned by eval jobs — the eval
    /// share of `occupied_lane_steps` (at steps-per-dispatch k a fused
    /// dispatch contributes up to k nodes per eval lane).
    pub eval_lane_steps: u64,
    /// Per-(model, program) pool QoS view: configured weight, service
    /// turns, steps, queue depth, active lanes.
    pub pool_qos: Vec<PoolQosStats>,
    /// Per-priority-class queue-wait / end-to-end latency percentiles
    /// (client traffic only), interactive first.
    pub classes: Vec<ClassLatencyStats>,
    /// Queued requests shed because their deadline expired.
    pub shed_deadline: u64,
    /// Requests rejected by per-model admission quotas.
    pub rejected_quota: u64,
    /// Still-queued requests dequeued by `EngineClient::cancel` (the
    /// async job API's cancel path).
    pub canceled: u64,
    /// Watchdog summary: health status gauge plus cumulative per-kind
    /// event counters.
    pub health: HealthStats,
}

/// Handle owning the engine thread.
pub struct Engine {
    tx: mpsc::Sender<Msg>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Cloneable, Send client for server/bench threads.
#[derive(Clone)]
pub struct EngineClient {
    tx: mpsc::Sender<Msg>,
}

impl Engine {
    /// Spawn the engine thread; fails fast if the runtime cannot load.
    pub fn start(cfg: EngineConfig) -> Result<Engine> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let join = std::thread::Builder::new()
            .name("gofast-engine".into())
            .spawn(move || engine_main(cfg, rx, ready_tx))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))?
            .map_err(|e| anyhow!("engine startup failed: {e}"))?;
        Ok(Engine { tx, join: Some(join) })
    }

    pub fn client(&self) -> EngineClient {
        EngineClient { tx: self.tx.clone() }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl EngineClient {
    /// Generate on the engine's default model with the adaptive solver.
    pub fn generate(&self, n: usize, eps_rel: f64, seed: u64) -> Result<GenResult> {
        self.generate_on("", n, eps_rel, seed)
    }

    /// Generate on a named model ("" = the default model) with the
    /// adaptive solver.
    pub fn generate_on(&self, model: &str, n: usize, eps_rel: f64, seed: u64) -> Result<GenResult> {
        self.generate_with(model, ServingSolver::Adaptive, n, eps_rel, seed)
    }

    /// Generate on a named model with any served solver program.
    pub fn generate_with(
        &self,
        model: &str,
        solver: ServingSolver,
        n: usize,
        eps_rel: f64,
        seed: u64,
    ) -> Result<GenResult> {
        self.generate_request(SampleRequest {
            model: model.to_string(),
            solver,
            n,
            eps_rel,
            seed,
            sample_base: 0,
            priority: None,
            deadline_ms: None,
            cancel_token: None,
        })
    }

    /// Generate with full request control (priority class, deadline).
    /// Client requests use `sample_base` 0.
    pub fn generate_request(&self, req: SampleRequest) -> Result<GenResult> {
        let rrx = self.generate_async(req)?;
        rrx.recv().map_err(|_| anyhow!("engine dropped the request"))?.map_err(|e| anyhow!(e))
    }

    /// Fire-and-poll variant of [`generate_request`]: enqueue the
    /// request and return the completion channel immediately. The async
    /// job table holds these receivers; admission rejections (queue cap,
    /// quota, bad solver) arrive on the channel like any other failure.
    ///
    /// [`generate_request`]: EngineClient::generate_request
    pub fn generate_async(
        &self,
        req: SampleRequest,
    ) -> Result<mpsc::Receiver<Result<GenResult, String>>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Generate(req, rtx)).map_err(|_| anyhow!("engine is down"))?;
        Ok(rrx)
    }

    /// FID*/IS* evaluation served through the engine's scheduler/registry
    /// machinery (blocks until the run completes).
    pub fn evaluate(&self, req: EvalRequest) -> Result<EvalResult> {
        let rrx = self.evaluate_async(req)?;
        rrx.recv().map_err(|_| anyhow!("engine dropped the request"))?.map_err(|e| anyhow!(e))
    }

    /// Fire-and-poll variant of [`EngineClient::evaluate`].
    pub fn evaluate_async(
        &self,
        req: EvalRequest,
    ) -> Result<mpsc::Receiver<Result<EvalResult, String>>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Evaluate(req, rtx)).map_err(|_| anyhow!("engine is down"))?;
        Ok(rrx)
    }

    /// Dequeue the still-queued request carrying `token` (its
    /// `SampleRequest::cancel_token`) through the shed path: pending
    /// state removed, queue/quota accounting released, its sink sent a
    /// terminal error. A request already holding lanes is left to run
    /// (`CancelOutcome::Running`), mirroring deadline semantics.
    pub fn cancel(&self, token: u64) -> Result<CancelOutcome> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Cancel(token, rtx)).map_err(|_| anyhow!("engine is down"))?;
        rrx.recv().map_err(|_| anyhow!("engine dropped the cancel"))
    }

    pub fn stats(&self) -> Result<EngineStats> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Stats(rtx)).map_err(|_| anyhow!("engine is down"))?;
        rrx.recv().map_err(|_| anyhow!("engine dropped the stats request"))
    }

    /// Snapshot request-lifecycle spans (and, with `q.timeline`, the
    /// runtime's dispatch timeline) from the engine's telemetry rings.
    /// Empty when the server runs with `--trace-ring 0`.
    pub fn trace(&self, q: TraceQuery) -> Result<TraceReply> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Trace(q, rtx)).map_err(|_| anyhow!("engine is down"))?;
        rrx.recv().map_err(|_| anyhow!("engine dropped the trace request"))
    }

    /// Snapshot per-pool solver diagnostics: diffusion-time profiles
    /// (always on) plus sampled lane traces (`serve --diag-sample N`).
    pub fn diag(&self, q: DiagQuery) -> Result<DiagReply> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Diag(q, rtx)).map_err(|_| anyhow!("engine is down"))?;
        rrx.recv().map_err(|_| anyhow!("engine dropped the diag request"))
    }

    /// Snapshot the watchdog's health status, retained events, and
    /// per-kind counters.
    pub fn health(&self) -> Result<HealthReply> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Health(rtx)).map_err(|_| anyhow!("engine is down"))?;
        rrx.recv().map_err(|_| anyhow!("engine dropped the health request"))
    }
}

// --- engine internals ---------------------------------------------------------

struct Metrics {
    requests_done: u64,
    samples_done: u64,
    steps: u64,
    rejections: u64,
    latency: Histogram,
}

impl Metrics {
    fn new() -> Metrics {
        Metrics {
            requests_done: 0,
            samples_done: 0,
            steps: 0,
            rejections: 0,
            latency: Histogram::new(),
        }
    }
}

struct EngineState<'rt> {
    registry: Registry<'rt>,
    cfg: EngineConfig,
    pending: HashMap<u64, Pending>,
    next_req_id: u64,
    queued_samples: usize,
    metrics: Metrics,
    evals: EvalManager<'rt>,
    qos: QosState,
    /// Request-lifecycle span ring; `None` when `trace_ring` is 0, and
    /// every hot-path record site is gated on that `Option` so disabled
    /// tracing costs neither time nor allocation.
    trace: Option<SpanRing>,
    /// Engine health watchdog, ticked every `health_interval_s` from
    /// the engine loop (state it reads — lane progress, accept/reject
    /// counters, step-time histograms — is all engine-owned, so the
    /// check is lock-free).
    watchdog: Watchdog,
}

fn engine_main(
    cfg: EngineConfig,
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<Result<(), String>>,
) {
    let rt = match Runtime::new(&cfg.artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };
    // dispatch-timeline ring on the runtime, sized to hold a few
    // dispatches per traced request; 0 leaves it off (no per-launch
    // records, no label allocations)
    if cfg.trace_ring > 0 {
        rt.set_timeline(cfg.trace_ring * 4);
    }
    // device residency rides the buffer path; with fused buffers off the
    // engine stays single-step and host-resident regardless of config
    let steps = if cfg.fused_buffers { cfg.steps_per_dispatch } else { 1 };
    // override keys are still validated with fused buffers off — only
    // their values degrade to single-step
    let overrides: Vec<(String, usize)> = cfg
        .steps_overrides
        .iter()
        .map(|(key, k)| (key.clone(), if cfg.fused_buffers { *k } else { 1 }))
        .collect();
    let registry = match Registry::load(
        &rt,
        &cfg.models,
        cfg.bucket,
        cfg.migrate,
        &cfg.programs,
        steps,
        &overrides,
        cfg.diag_sample,
    ) {
        Ok(r) => r,
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };
    let model_names: Vec<String> =
        registry.entries().iter().map(|e| e.model.meta.name.clone()).collect();
    let qos = match QosState::new(&cfg.qos, &registry.pool_labels(), &model_names) {
        Ok(q) => q,
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };
    let trace = if cfg.trace_ring > 0 { Some(SpanRing::new(cfg.trace_ring)) } else { None };
    // per-pool lane tracking sized at load width — the widest rung, an
    // upper bound on every later migration target
    let widths: Vec<usize> =
        registry.entries().iter().flat_map(|e| e.pools.iter().map(|p| p.slots.len())).collect();
    let watchdog = Watchdog::new(&widths, cfg.stall_budget_s);
    let mut st = EngineState {
        registry,
        cfg,
        pending: HashMap::new(),
        next_req_id: 1,
        queued_samples: 0,
        metrics: Metrics::new(),
        evals: EvalManager::new(),
        qos,
        trace,
        watchdog,
    };
    let _ = ready.send(Ok(()));

    loop {
        // 1. drain the mailbox (block only when every pool is idle; the
        //    timeout keeps watchdog ticks firing while quiescent)
        if st.registry.all_idle() {
            let wait = Duration::from_secs_f64(st.cfg.health_interval_s.clamp(0.01, 60.0));
            match rx.recv_timeout(wait) {
                Ok(msg) => {
                    if st.handle_msg(msg) {
                        return;
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(msg) => {
                    if st.handle_msg(msg) {
                        return;
                    }
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => return,
            }
        }
        // 2. periodic health check (interval 0 = every iteration)
        let now = telemetry::now_s();
        if now - st.watchdog.last_tick_s >= st.cfg.health_interval_s {
            st.health_tick(now);
        }
        // 3. service the next pool with work (deficit-weighted
        //    round-robin): shed expired queued requests, re-bucket to
        //    the cheapest fitting width, admit queued samples, advance
        //    one iteration of its solver program
        let next = {
            let EngineState { qos, registry, .. } = &mut st;
            qos.wrr.next(&mut |flat| {
                let (mi, pi) = registry.pool_at(flat);
                !registry.entries()[mi].pools[pi].idle()
            })
        };
        if let Some(flat) = next {
            let (mi, pi) = st.registry.pool_at(flat);
            st.shed_expired(mi, pi);
            // rebucket/admit can fail only on a device sync of a
            // device-resident pool; that is the same fault domain as a
            // step failure, so it gets the same isolation
            let prep = st.rebucket(mi, pi).and_then(|()| st.admit(mi, pi));
            if let Err(e) = prep {
                st.fail_pool(mi, pi, &format!("engine step failed: {e:#}"));
            } else if st.registry.entries()[mi].pools[pi].active() > 0 {
                match st.step(mi, pi) {
                    Ok(eval_chunks) => st.on_eval_chunks(mi, pi, eval_chunks),
                    Err(e) => {
                        // fault isolation: only this pool's requests fail
                        st.fail_pool(mi, pi, &format!("engine step failed: {e:#}"));
                    }
                }
            }
        }
    }
}

impl<'rt> EngineState<'rt> {
    /// Returns true on shutdown.
    fn handle_msg(&mut self, msg: Msg) -> bool {
        match msg {
            Msg::Shutdown => true,
            Msg::Stats(reply) => {
                let _ = reply.send(self.stats());
                false
            }
            Msg::Cancel(token, reply) => {
                let _ = reply.send(self.cancel_queued(token));
                false
            }
            Msg::Trace(q, reply) => {
                let spans = self.trace.as_ref().map(|r| r.query(&q)).unwrap_or_default();
                let timeline = if q.timeline {
                    self.registry.entries()[0].model.runtime().timeline_snapshot()
                } else {
                    Vec::new()
                };
                let _ = reply.send(TraceReply { spans, timeline });
                false
            }
            Msg::Diag(q, reply) => {
                let mut pools = Vec::new();
                for e in self.registry.entries() {
                    let model_name = &e.model.meta.name;
                    for pool in &e.pools {
                        let solver = pool.program.solver_name();
                        if !q.matches_pool(model_name, solver) {
                            continue;
                        }
                        let adaptive = crate::solvers::spec::kernel(solver)
                            .is_some_and(|sk| sk.adaptive);
                        pools.push(pool.diag.snapshot(model_name, solver, adaptive, q.lane));
                    }
                }
                let _ = reply.send(DiagReply { pools });
                false
            }
            Msg::Health(reply) => {
                let _ = reply.send(self.watchdog.snapshot());
                false
            }
            Msg::Generate(req, reply) => {
                if let Err(e) = req.solver.validate() {
                    // a spec the wire parser would refuse (em:0, pc@0)
                    // built via the Rust API: structured bad_solver code
                    self.reject_span(&req, Kind::Generate, qos::CODE_BAD_SOLVER);
                    let _ = reply.send(Err(qos::coded(qos::CODE_BAD_SOLVER, &format!("{e:#}"))));
                    return false;
                }
                let (mi, pi) = match self.registry.resolve_pool(&req.model, &req.solver) {
                    Ok(v) => v,
                    Err(e) => {
                        self.reject_span(&req, Kind::Generate, qos::CODE_BAD_REQUEST);
                        let _ = reply.send(Err(format!("{e:#}")));
                        return false;
                    }
                };
                if req.n == 0 {
                    self.reject_span(&req, Kind::Generate, qos::CODE_BAD_REQUEST);
                    let _ = reply.send(Err("n must be > 0".into()));
                    return false;
                }
                if self.queued_samples + req.n > self.cfg.max_queue_samples {
                    self.reject_span(&req, Kind::Generate, qos::CODE_QUEUE_FULL);
                    let _ = reply.send(Err(qos::coded(
                        qos::CODE_QUEUE_FULL,
                        &format!(
                            "queue full ({} samples queued, max {})",
                            self.queued_samples, self.cfg.max_queue_samples
                        ),
                    )));
                    return false;
                }
                if let Some(maxq) = self.qos.quotas[mi].max_queued {
                    if self.qos.queued_per_model[mi] + req.n > maxq {
                        self.qos.rejected_quota += 1;
                        self.reject_span(&req, Kind::Generate, qos::CODE_QUOTA);
                        let model = &self.registry.entries()[mi].model.meta.name;
                        let _ = reply.send(Err(qos::coded(
                            qos::CODE_QUOTA,
                            &format!(
                                "model '{model}' admission quota exceeded ({} samples \
                                 queued + {} requested > quota {maxq})",
                                self.qos.queued_per_model[mi], req.n
                            ),
                        )));
                        return false;
                    }
                }
                self.enqueue(mi, pi, req, Sink::Client(reply));
                false
            }
            Msg::Evaluate(req, reply) => {
                if let Err(e) = req.solver.validate() {
                    self.reject_eval_span(&req, qos::CODE_BAD_SOLVER);
                    let _ = reply.send(Err(qos::coded(qos::CODE_BAD_SOLVER, &format!("{e:#}"))));
                    return false;
                }
                let (mi, pi) = match self.registry.resolve_pool(&req.model, &req.solver) {
                    Ok(v) => v,
                    Err(e) => {
                        self.reject_eval_span(&req, qos::CODE_BAD_REQUEST);
                        let _ = reply.send(Err(format!("{e:#}")));
                        return false;
                    }
                };
                if req.samples < 2 {
                    // fail at admission, not after the run: FID needs a
                    // non-singular feature covariance
                    self.reject_eval_span(&req, qos::CODE_BAD_REQUEST);
                    let _ = reply.send(Err(format!(
                        "evaluate needs samples >= 2 (got {}); the feature \
                         covariance is singular below that",
                        req.samples
                    )));
                    return false;
                }
                if let Err(e) = self.evals.ensure_net(mi, &self.registry) {
                    self.reject_eval_span(&req, qos::CODE_INTERNAL);
                    let _ = reply.send(Err(e));
                    return false;
                }
                let snapshot = self.registry.entries()[mi].pools[pi].sched.steps_per_bucket();
                let chunks = self.evals.start_job(mi, pi, req, reply, snapshot);
                for spec in chunks {
                    self.enqueue_eval_chunk(spec);
                }
                false
            }
        }
    }

    /// Record an admission rejection as a terminal span, so refused
    /// traffic shows up in the trace ring with its code. Rejections
    /// happen before `enqueue`, so the span allocates its request id
    /// from the same counter admitted requests use.
    fn reject_span(&mut self, req: &SampleRequest, kind: Kind, code: &str) {
        let Some(ring) = self.trace.as_mut() else {
            return;
        };
        let id = self.next_req_id;
        self.next_req_id += 1;
        let pr = req.priority.unwrap_or(self.qos.default_priority).as_str();
        ring.on_reject(
            id,
            req.cancel_token,
            &req.model,
            req.solver.name(),
            kind,
            req.n,
            pr,
            code,
        );
    }

    /// [`reject_span`](Self::reject_span) for an evaluate request
    /// refused before it spawned any chunks.
    fn reject_eval_span(&mut self, req: &EvalRequest, code: &str) {
        let Some(ring) = self.trace.as_mut() else {
            return;
        };
        let id = self.next_req_id;
        self.next_req_id += 1;
        let pr = req.priority.unwrap_or(self.qos.default_priority).as_str();
        ring.on_reject(id, None, &req.model, req.solver.name(), Kind::Eval, req.samples, pr, code);
    }

    /// Register a request's accumulation state and queue it on pool
    /// `(mi, pi)`. Interactive requests are queued ahead of batch ones,
    /// but never ahead of an earlier request of their own class (stable
    /// within a class), and never preempt lanes already granted.
    fn enqueue(&mut self, mi: usize, pi: usize, req: SampleRequest, sink: Sink) {
        let id = self.next_req_id;
        self.next_req_id += 1;
        self.queued_samples += req.n;
        self.qos.queued_per_model[mi] += req.n;
        let priority = req.priority.unwrap_or(self.qos.default_priority);
        if let Some(ring) = self.trace.as_mut() {
            let (kind, job) = match &sink {
                Sink::Client(_) => (Kind::Generate, req.cancel_token),
                // eval spans carry the engine's eval-job id (the async
                // wire job id lives in a different namespace)
                Sink::Eval { job, .. } => (Kind::Eval, Some(*job)),
            };
            let model_name = &self.registry.entries()[mi].model.meta.name;
            ring.on_submit(id, job, model_name, req.solver.name(), kind, req.n, priority.as_str());
        }
        let dim = self.registry.entries()[mi].model.meta.dim;
        self.pending.insert(
            id,
            Pending {
                images: Tensor::zeros(&[req.n, dim]),
                nfe: vec![0; req.n],
                next_sample: 0,
                done: 0,
                sink,
                enqueued: Instant::now(),
                started: None,
                priority,
                req,
            },
        );
        let EngineState { registry, pending, .. } = self;
        let fifo = &mut registry.entry_mut(mi).pools[pi].fifo;
        let pos = fifo
            .iter()
            .position(|other| pending.get(other).is_some_and(|p| p.priority < priority))
            .unwrap_or(fifo.len());
        fifo.insert(pos, id);
    }

    /// Admit one evaluation chunk through the normal request path.
    /// Chunks bypass the client queue cap and the per-model quotas:
    /// their in-flight volume is already bounded by
    /// `MAX_INFLIGHT_CHUNKS` fid-bucket batches.
    fn enqueue_eval_chunk(&mut self, spec: ChunkSpec) {
        let req = SampleRequest {
            model: String::new(), // routed by index below
            solver: spec.solver,
            n: spec.n,
            eps_rel: spec.eps_rel,
            seed: spec.seed,
            sample_base: spec.sample_base,
            priority: spec.priority,
            deadline_ms: None,   // eval jobs run to completion
            cancel_token: None, // chunks are internal; cancel targets client requests
        };
        let sink = Sink::Eval { job: spec.job, chunk: spec.chunk };
        self.enqueue(spec.model_idx, spec.pool_idx, req, sink);
    }

    /// Shed queued requests on pool `(mi, pi)` whose deadline expired
    /// before any of their samples reached a lane. Requests with a lane
    /// run to completion — shedding only refuses work not yet started,
    /// so no lane time is ever wasted on it.
    fn shed_expired(&mut self, mi: usize, pi: usize) {
        let now = Instant::now();
        let EngineState { registry, pending, queued_samples, qos, trace, .. } = self;
        let pool = &mut registry.entry_mut(mi).pools[pi];
        let mut shed: Vec<u64> = Vec::new();
        pool.fifo.retain(|id| {
            let Some(p) = pending.get(id) else {
                return true; // finished ids are cleaned up by admit()
            };
            let expired = p.next_sample == 0
                && p.req.deadline_ms.is_some_and(|d| {
                    now.duration_since(p.enqueued).as_millis() as u64 >= d
                });
            if expired {
                shed.push(*id);
            }
            !expired
        });
        for id in shed {
            let p = pending.remove(&id).unwrap();
            *queued_samples -= p.req.n;
            qos.queued_per_model[mi] -= p.req.n;
            qos.shed_deadline += 1;
            if let Some(ring) = trace.as_mut() {
                ring.on_end(id, Outcome::Shed, Some(qos::CODE_DEADLINE));
            }
            if let Sink::Client(reply) = p.sink {
                let waited = now.duration_since(p.enqueued).as_millis();
                let _ = reply.send(Err(qos::coded(
                    qos::CODE_DEADLINE,
                    &format!(
                        "request shed after {waited}ms queued (deadline {}ms)",
                        p.req.deadline_ms.unwrap_or(0)
                    ),
                )));
            }
            // eval chunks never carry deadlines (see enqueue_eval_chunk)
        }
    }

    /// Dequeue the still-queued request carrying `token` — the
    /// client-driven twin of `shed_expired`: identical bookkeeping
    /// (pending removed, fifo entry dropped, queue/quota accounting
    /// released, terminal error to the sink), different trigger. A
    /// request with any sample in a lane keeps running
    /// (`CancelOutcome::Running`), exactly like an expired deadline.
    fn cancel_queued(&mut self, token: u64) -> CancelOutcome {
        let hit = self
            .pending
            .iter()
            .find(|(_, p)| p.req.cancel_token == Some(token))
            .map(|(id, p)| (*id, p.next_sample));
        let Some((id, next_sample)) = hit else {
            return CancelOutcome::NotFound;
        };
        if next_sample > 0 {
            return CancelOutcome::Running;
        }
        let p = self.pending.remove(&id).unwrap();
        // drop it from the pool that enqueued it: resolve succeeds
        // because admission resolved the same (model, solver) pair
        if let Ok((mi, pi)) = self.registry.resolve_pool(&p.req.model, &p.req.solver) {
            self.registry.entry_mut(mi).pools[pi].fifo.retain(|&q| q != id);
            self.queued_samples -= p.req.n;
            self.qos.queued_per_model[mi] -= p.req.n;
        }
        self.qos.canceled += 1;
        if let Some(ring) = self.trace.as_mut() {
            ring.on_end(id, Outcome::Canceled, None);
        }
        if let Sink::Client(reply) = p.sink {
            let _ = reply.send(Err("request canceled by client".to_string()));
        }
        CancelOutcome::Canceled
    }

    /// Fold completed eval chunks into their jobs, admitting follow-up
    /// chunks as each one lands.
    fn on_eval_chunks(&mut self, mi: usize, pi: usize, done: Vec<(u64, usize, GenResult)>) {
        for (job, chunk, gen) in done {
            let sched_now = self.registry.entries()[mi].pools[pi].sched.steps_per_bucket();
            let model_name = self.registry.entries()[mi].model.meta.name.clone();
            let follow = self.evals.on_chunk_done(
                job,
                chunk,
                &gen.images,
                &gen.nfe,
                &sched_now,
                &model_name,
            );
            for spec in follow {
                self.enqueue_eval_chunk(spec);
            }
        }
    }

    /// Live lanes plus samples still queued for pool `(mi, pi)`.
    fn pool_demand(&self, mi: usize, pi: usize) -> usize {
        let pool = &self.registry.entries()[mi].pools[pi];
        let queued: usize = pool
            .fifo
            .iter()
            .filter_map(|id| self.pending.get(id))
            .map(|p| p.req.n - p.next_sample)
            .sum();
        pool.active() + queued
    }

    /// Switch pool `(mi, pi)` to the scheduler's target width, migrating
    /// live lanes. A no-op unless the target differs from the current
    /// width. Device-resident pools download their slab first (the host
    /// row remap is the migration contract) and re-upload lazily on the
    /// next fused dispatch.
    fn rebucket(&mut self, mi: usize, pi: usize) -> Result<()> {
        let demand = self.pool_demand(mi, pi);
        let ModelEntry { model, pools, .. } = self.registry.entry_mut(mi);
        let pool = &mut pools[pi];
        let active = pool.active();
        let target = pool.sched.target_width(active, demand);
        if target != pool.sched.width() {
            sync_pool_host(model, pool)?;
            migrate_lanes(&mut pool.slots, &mut pool.x, &mut pool.xprev, target);
            // migration compacts live lanes into new slots; open trace
            // markers follow their lanes
            pool.diag.remap(&pool.slots);
            pool.sched.set_width(target);
        }
        Ok(())
    }

    /// Priority-ordered FIFO admission of queued samples into pool
    /// `(mi, pi)`'s free slots (the fifo is kept interactive-first by
    /// `enqueue`). Admission is program-agnostic: the prior draw and the
    /// forked per-sample RNG stream are shared by every solver; the
    /// pool's program supplies the per-lane integration state. A
    /// per-model `max_active_lanes` quota pauses admission at the cap;
    /// it resumes as lanes free up.
    fn admit(&mut self, mi: usize, pi: usize) -> Result<()> {
        let EngineState { registry, pending, queued_samples, cfg, qos, trace, .. } = self;
        let e = registry.entry_mut(mi);
        let lane_cap = qos.quotas[mi].max_active_lanes;
        let mut model_active: usize = e.pools.iter().map(|p| p.active()).sum();
        let prior_std = e.process.prior_std() as f32;
        // copied out so the pool borrow below doesn't pin `e`; programs
        // need it to resolve process-dependent lane state (the PC
        // default SNR)
        let process = e.process;
        let ModelEntry { model, pools, .. } = e;
        let pool = &mut pools[pi];
        // admission writes prior draws into host rows, so a
        // device-resident pool must pull its slab back first — but only
        // when admission will actually happen (a free slot under the
        // lane cap and a request with samples left), not on every
        // service turn of a busy pool
        if pool.dev_x.is_some()
            && !lane_cap.is_some_and(|c| model_active >= c)
            && pool.slots.iter().any(|s| s.is_free())
            && pool
                .fifo
                .iter()
                .any(|id| pending.get(id).is_some_and(|p| p.next_sample < p.req.n))
        {
            sync_pool_host(model, pool)?;
        }
        let ProgramPool { program, slots, x, xprev, fifo, diag, .. } = pool;
        let mut fi = 0;
        for si in 0..slots.len() {
            if !slots[si].is_free() {
                continue;
            }
            if lane_cap.is_some_and(|c| model_active >= c) {
                break;
            }
            // find next request with samples left to admit (completed
            // requests may still sit in fifo until the retain below)
            while fi < fifo.len() {
                let id = fifo[fi];
                match pending.get(&id) {
                    Some(p) if p.next_sample < p.req.n => break,
                    _ => fi += 1,
                }
            }
            if fi >= fifo.len() {
                break;
            }
            let id = fifo[fi];
            let p = pending.get_mut(&id).unwrap();
            let sample_idx = p.next_sample;
            p.next_sample += 1;
            if p.started.is_none() {
                let now = Instant::now();
                p.started = Some(now);
                if let Some(ring) = trace.as_mut() {
                    ring.on_admit(id);
                }
                if matches!(p.sink, Sink::Client(_)) {
                    qos.classes[p.priority.idx()]
                        .queue_wait
                        .record(now.duration_since(p.enqueued).as_secs_f64());
                }
            }
            *queued_samples -= 1;
            qos.queued_per_model[mi] -= 1;
            model_active += 1;
            // init the lane: prior draw, fresh forked rng per sample
            // (sample_base keeps chunked eval runs on the same streams
            // as one big request — and as the offline `run_lanes` twin)
            let mut rng = Rng::new(p.req.seed).fork(p.req.sample_base + sample_idx as u64);
            {
                let row = x.row_mut(si);
                for v in row.iter_mut() {
                    *v = rng.normal() as f32 * prior_std;
                }
                let prev = row.to_vec();
                xprev.row_mut(si).copy_from_slice(&prev);
            }
            slots[si] = Slot::Running {
                req_id: id,
                sample_idx,
                nfe: 0,
                rng,
                state: program.init_lane(cfg, &process, &p.req),
            };
            diag.on_lane_start(si, id, sample_idx);
        }
        // drop fully-admitted-and-finished request ids from fifo head
        fifo.retain(|id| pending.contains_key(id));
        Ok(())
    }

    /// One fused step of pool `(mi, pi)`'s program at its current width.
    /// Returns the eval chunks that completed this iteration.
    fn step(&mut self, mi: usize, pi: usize) -> Result<Vec<(u64, usize, GenResult)>> {
        let EngineState { registry, pending, cfg, metrics, evals, qos, trace, .. } = self;
        let e = registry.entry_mut(mi);
        // eval-lane slots of this dispatch: their share of the real
        // lane-nodes (the same unit as occupied_lane_steps) is summed
        // from the outcome below, since only the step fold knows how
        // many of the k fused nodes/attempts each lane really ran
        let eval_slots: Vec<usize> = e.pools[pi]
            .slots
            .iter()
            .enumerate()
            .filter_map(|(si, s)| match s {
                Slot::Running { req_id, .. }
                    if pending.get(req_id).is_some_and(|p| EvalManager::is_eval_sink(&p.sink)) =>
                {
                    Some(si)
                }
                _ => None,
            })
            .collect();
        let step_start = Instant::now();
        let outcome = {
            let ModelEntry { model, process, pools } = e;
            let ProgramPool { program, slots, x, xprev, dev_x, steps_per_dispatch, diag, .. } =
                &mut pools[pi];
            let k = *steps_per_dispatch;
            program.step(StepIo {
                model: &*model,
                process: &*process,
                cfg: &*cfg,
                slots: slots.as_mut_slice(),
                x,
                xprev,
                dev_x,
                steps_per_dispatch: k,
                diag,
            })?
        };
        metrics.steps += 1;
        metrics.rejections += outcome.rejections;
        evals.eval_lane_steps += eval_slots
            .iter()
            .map(|&si| outcome.per_lane_nodes.get(si).copied().unwrap_or(0))
            .sum::<u64>();
        let e = registry.entry_mut(mi);
        let k = e.pools[pi].steps_per_dispatch;
        e.pools[pi].sched.note_step(outcome.lane_nodes, k);
        {
            // per-pool step telemetry: Histogram::record is
            // allocation-free, and the accept/reject split only moves
            // for the adaptive program (fixed kernels never reject).
            // Proposals = lane_nodes (1 per lane at k = 1, the real
            // attempt count under the fused fold), so accepted =
            // proposals - rejections in both modes.
            let pool = &mut e.pools[pi];
            pool.step_time.record(step_start.elapsed().as_secs_f64());
            if crate::solvers::spec::kernel(pool.program.solver_name())
                .is_some_and(|sk| sk.adaptive)
            {
                pool.accepted += outcome.lane_nodes - outcome.rejections;
                pool.rejected += outcome.rejections;
            }
        }
        if let Some(ring) = trace.as_mut() {
            // one dispatch event per request with a live lane in this
            // batch (converged lanes are still Running here; they free
            // in finish_lanes below)
            let mut seen: Vec<u64> = Vec::new();
            for s in e.pools[pi].slots.iter() {
                if let Slot::Running { req_id, .. } = s {
                    if !seen.contains(req_id) {
                        seen.push(*req_id);
                        ring.on_dispatch(*req_id);
                    }
                }
            }
        }
        if outcome.converged.is_empty() {
            return Ok(Vec::new());
        }
        // fused adaptive dispatches group converged lanes by the attempt
        // they crossed t_eps on; one batched denoise per group keeps the
        // denoise call count (score_evals, d2h bytes) identical to k = 1
        let single = [outcome.converged];
        let groups: &[Vec<usize>] = if outcome.converged_groups.is_empty() {
            &single
        } else {
            &outcome.converged_groups
        };
        let mut done = Vec::new();
        for g in groups {
            done.extend(finish_lanes(e, pi, pending, metrics, qos, trace, cfg.fused_buffers, g)?);
        }
        Ok(done)
    }

    /// Fail every request owned by pool `(mi, pi)` (incomplete requests
    /// stay in the pool's fifo until done, so the fifo names them all)
    /// and reset its lanes. Other pools — of this model and others — are
    /// untouched.
    fn fail_pool(&mut self, mi: usize, pi: usize, msg: &str) {
        let pool = &mut self.registry.entry_mut(mi).pools[pi];
        // lane state is discarded wholesale, so the slab is dropped
        // without a download
        pool.dev_x = None;
        let mut ids: Vec<u64> = pool.fifo.drain(..).collect();
        for s in pool.slots.iter_mut() {
            if let Slot::Running { req_id, .. } = *s {
                ids.push(req_id);
            }
            *s = Slot::Free;
        }
        // every open sampled trace ends truncated with the reset
        pool.diag.clear_slots();
        ids.sort_unstable();
        ids.dedup();
        for id in ids {
            if let Some(p) = self.pending.remove(&id) {
                self.queued_samples -= p.req.n - p.next_sample;
                self.qos.queued_per_model[mi] -= p.req.n - p.next_sample;
                if let Some(ring) = self.trace.as_mut() {
                    ring.on_end(id, Outcome::Failed, Some(qos::CODE_INTERNAL));
                }
                if let Sink::Client(reply) = p.sink {
                    let _ = reply.send(Err(msg.to_string()));
                }
                // eval sinks are answered once per job below
            }
        }
        self.evals.fail_jobs_on_pool(mi, pi, msg);
    }

    /// One watchdog tick: queue saturation at the engine level, then
    /// stalled-lane / reject-spike / p95-drift checks per pool in flat
    /// service order. Reads only engine-owned state; the occupied-lane
    /// scratch Vec is the tick's sole allocation (periodic, not
    /// per-step).
    fn health_tick(&mut self, now: f64) {
        let EngineState { registry, watchdog, queued_samples, cfg, .. } = self;
        watchdog.begin_tick();
        watchdog.check_queue(*queued_samples, cfg.max_queue_samples, now);
        let mut flat = 0usize;
        let mut lanes: Vec<(usize, f64)> = Vec::new();
        for e in registry.entries() {
            let model_name = &e.model.meta.name;
            for pool in &e.pools {
                lanes.clear();
                for (si, s) in pool.slots.iter().enumerate() {
                    if let Slot::Running { state, .. } = s {
                        // any monotone scalar that moves on every real
                        // step works as lane progress
                        let progress = match state {
                            LaneState::Adaptive { t, .. } => *t,
                            LaneState::Fixed { done, .. } => *done as f64,
                        };
                        lanes.push((si, progress));
                    }
                }
                let solver = pool.program.solver_name();
                let adaptive =
                    crate::solvers::spec::kernel(solver).is_some_and(|sk| sk.adaptive);
                let sample = PoolHealthSample {
                    adaptive,
                    accepted: pool.accepted,
                    rejected: pool.rejected,
                    step_p95_s: pool.step_time.quantile(0.95),
                    step_count: pool.step_time.count(),
                };
                watchdog.tick_pool(flat, model_name, solver, &lanes, &sample, now);
                flat += 1;
            }
        }
        watchdog.end_tick(now);
    }

    fn stats(&self) -> EngineStats {
        let mut steps_per_bucket: Vec<(usize, u64)> = Vec::new();
        let (mut mig_up, mut mig_down) = (0u64, 0u64);
        let (mut wasted, mut occupied) = (0u64, 0u64);
        let mut active_slots = 0usize;
        let mut models = Vec::new();
        let mut programs: Vec<ProgramStats> = Vec::new();
        let mut pool_qos: Vec<PoolQosStats> = Vec::new();
        let mut flat = 0usize;
        for e in self.registry.entries() {
            models.push(e.model.meta.name.clone());
            for pool in &e.pools {
                active_slots += pool.active();
                let queue_depth: usize = pool
                    .fifo
                    .iter()
                    .filter_map(|id| self.pending.get(id))
                    .map(|p| p.req.n - p.next_sample)
                    .sum();
                let s = &pool.sched;
                mig_up += s.migrations_up;
                mig_down += s.migrations_down;
                wasted += s.wasted_lane_steps;
                occupied += s.occupied_lane_steps;
                let pool_steps: u64 = s.steps_per_bucket().iter().map(|(_, n)| *n).sum();
                pool_qos.push(PoolQosStats {
                    model: e.model.meta.name.clone(),
                    solver: pool.program.solver_name().to_string(),
                    weight: self.qos.wrr.weight(flat),
                    turns: self.qos.wrr.turns[flat],
                    steps: pool_steps,
                    occupied_lane_steps: s.occupied_lane_steps,
                    queue_depth,
                    active_lanes: pool.active(),
                    steps_per_dispatch: pool.steps_per_dispatch,
                    step_count: pool.step_time.count(),
                    step_sum_s: pool.step_time.sum(),
                    step_p50_s: pool.step_time.quantile(0.5),
                    step_p95_s: pool.step_time.quantile(0.95),
                    step_p99_s: pool.step_time.quantile(0.99),
                    accepted: pool.accepted,
                    rejected: pool.rejected,
                    steps_per_bucket: s.steps_per_bucket(),
                });
                flat += 1;
                let name = pool.program.solver_name();
                let ps = match programs.iter_mut().find(|p| p.solver == name) {
                    Some(p) => p,
                    None => {
                        programs.push(ProgramStats {
                            solver: name.to_string(),
                            ..Default::default()
                        });
                        programs.last_mut().unwrap()
                    }
                };
                ps.pools += 1;
                ps.active_lanes += pool.active();
                ps.queue_depth += queue_depth;
                ps.occupied_lane_steps += s.occupied_lane_steps;
                ps.wasted_lane_steps += s.wasted_lane_steps;
                ps.score_evals +=
                    s.occupied_lane_steps * pool.program.score_evals_per_step();
                ps.migrations_up += s.migrations_up;
                ps.migrations_down += s.migrations_down;
                ps.accepted += pool.accepted;
                ps.rejected += pool.rejected;
                for (bucket, n) in s.steps_per_bucket() {
                    ps.steps += n;
                    for acc in [&mut ps.steps_per_bucket, &mut steps_per_bucket] {
                        match acc.iter_mut().find(|(b, _)| *b == bucket) {
                            Some((_, v)) => *v += n,
                            None => acc.push((bucket, n)),
                        }
                    }
                }
                ps.steps_per_bucket.sort();
            }
        }
        steps_per_bucket.sort();
        let rt = self.registry.entries()[0].model.runtime().stats();
        EngineStats {
            requests_done: self.metrics.requests_done,
            samples_done: self.metrics.samples_done,
            queued_samples: self.queued_samples,
            active_slots,
            steps: self.metrics.steps,
            rejections: self.metrics.rejections,
            score_evals: rt.score_evals,
            dispatches: rt.dispatches,
            bytes_h2d: rt.bytes_h2d,
            bytes_d2h: rt.bytes_d2h,
            latency_p50_s: self.metrics.latency.quantile(0.5),
            latency_p95_s: self.metrics.latency.quantile(0.95),
            latency_mean_s: self.metrics.latency.mean(),
            mean_occupancy: if self.metrics.steps == 0 {
                0.0
            } else {
                occupied as f64 / self.metrics.steps as f64
            },
            models,
            programs,
            steps_per_bucket,
            migrations_up: mig_up,
            migrations_down: mig_down,
            wasted_lane_steps: wasted,
            occupied_lane_steps: occupied,
            evals_done: self.evals.evals_done,
            eval_active: self.evals.active(),
            eval_samples_done: self.evals.eval_samples_done,
            eval_lane_steps: self.evals.eval_lane_steps,
            pool_qos,
            classes: self.qos.class_stats(),
            shed_deadline: self.qos.shed_deadline,
            rejected_quota: self.qos.rejected_quota,
            canceled: self.qos.canceled,
            health: self.watchdog.stats(),
        }
    }
}

/// Denoise converged lanes (one batched Tweedie call at the pool's
/// current width) and hand their images back to their requests; free the
/// lanes. Client requests are answered directly; completed eval chunks
/// are returned to the caller for folding into their jobs. The denoise
/// call is shared by every solver program (+1 NFE per sample).
#[allow(clippy::too_many_arguments)]
fn finish_lanes(
    e: &mut ModelEntry<'_>,
    pi: usize,
    pending: &mut HashMap<u64, Pending>,
    metrics: &mut Metrics,
    qos: &mut QosState,
    trace: &mut Option<SpanRing>,
    fused_buffers: bool,
    lanes: &[usize],
) -> Result<Vec<(u64, usize, GenResult)>> {
    let b = e.pools[pi].sched.width();
    let t_end = crate::solvers::t_vec(b, e.process.t_eps());
    // fixed-step device-resident pools denoise straight from the slab —
    // it IS the [B, dim] x tensor, and the host rows of live lanes are
    // stale (a slab only exists when the engine runs fused buffers, so
    // the buffer exec path is guaranteed). Adaptive fused pools pack
    // x | xprev | attempt logs into their slab (a different shape) and
    // refresh the host x on every dispatch, so they denoise from host.
    let x_arg = match e.pools[pi].dev_x.as_ref() {
        Some(slab) if slab.shape() == e.pools[pi].x.shape.as_slice() => ExecArg::Device(slab),
        _ => ExecArg::Host(&e.pools[pi].x),
    };
    let mut out = e.model.exec_args(
        "denoise",
        b,
        &[x_arg, ExecArg::Const("t_end", &t_end)],
        fused_buffers,
    )?;
    let x0 = out.pop().unwrap();
    let (img_h, img_w) = (e.model.meta.h, e.model.meta.w);
    let (lo, hi) = e.process.data_range();
    let (lo, hi) = (lo as f32, hi as f32);
    let mut eval_done = Vec::new();
    for &i in lanes {
        let Slot::Running { req_id, sample_idx, nfe, .. } = e.pools[pi].slots[i] else {
            continue;
        };
        let nfe_total = nfe + 1; // the denoise eval
        let p = pending.get_mut(&req_id).expect("pending req exists");
        // unit-range conversion into the request buffer
        let dst = p.images.row_mut(sample_idx);
        for (d, &s) in dst.iter_mut().zip(x0.row(i)) {
            *d = ((s - lo) / (hi - lo)).clamp(0.0, 1.0);
        }
        p.nfe[sample_idx] = nfe_total;
        p.done += 1;
        metrics.samples_done += 1;
        if p.done == p.req.n {
            let p = pending.remove(&req_id).unwrap();
            if let Some(ring) = trace.as_mut() {
                ring.on_end(req_id, Outcome::Complete, None);
            }
            let now = Instant::now();
            let wall = now.duration_since(p.started.unwrap_or(p.enqueued)).as_secs_f64();
            let queued = p
                .started
                .map(|s| s.duration_since(p.enqueued).as_secs_f64())
                .unwrap_or(0.0);
            let result = GenResult {
                images: p.images,
                nfe: p.nfe,
                model: e.model.meta.name.clone(),
                h: img_h,
                w: img_w,
                wall_s: wall,
                queued_s: queued,
            };
            match p.sink {
                Sink::Client(reply) => {
                    // client latency/throughput metrics count client
                    // traffic only; eval chunks have their own counters
                    let e2e = now.duration_since(p.enqueued).as_secs_f64();
                    metrics.latency.record(e2e);
                    metrics.requests_done += 1;
                    let cm = &mut qos.classes[p.priority.idx()];
                    cm.e2e.record(e2e);
                    cm.requests_done += 1;
                    let _ = reply.send(Ok(result));
                }
                Sink::Eval { job, chunk } => eval_done.push((job, chunk, result)),
            }
        }
        e.pools[pi].slots[i] = Slot::Free;
        e.pools[pi].diag.on_lane_end(i);
    }
    Ok(eval_done)
}

/// Pull a device-resident pool's lane state back into its host `x`
/// (bit-exact) and drop the slab. Anything that touches host rows —
/// admission of new lanes, bucket migration — must run against current
/// state; the next fused dispatch re-uploads. No-op for pools without a
/// live slab (k=1 pools never grow one).
fn sync_pool_host(model: &Model<'_>, pool: &mut ProgramPool) -> Result<()> {
    if let Some(slab) = pool.dev_x.take() {
        if slab.shape() == pool.x.shape.as_slice() {
            pool.x = model.download(&slab)?;
        }
        // adaptive fused pools pack x | xprev | attempt logs into the
        // slab and already refreshed the host copies from this
        // dispatch's log download, so the host is current: just drop
        // the slab and let the next dispatch re-pack from host
    }
    Ok(())
}
