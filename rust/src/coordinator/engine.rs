//! Engine thread: owns the PJRT runtime and runs the continuous-batching
//! step loop. See module docs in `coordinator/mod.rs`.

use super::{Msg, Pending, SampleRequest, Slot};
use crate::metrics::hist::Histogram;
use crate::rng::Rng;
use crate::runtime::{Model, Runtime};
use crate::tensor::Tensor;
use crate::{anyhow, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub artifacts: PathBuf,
    pub model: String,
    /// Slot-pool width; must be one of the model's adaptive_step buckets.
    pub bucket: usize,
    pub fused_buffers: bool,
    /// Admission control: maximum queued samples before rejecting.
    pub max_queue_samples: usize,
    /// Algorithm-1 controller parameters (paper defaults).
    pub h_init: f64,
    pub r: f64,
    pub safety: f64,
}

impl EngineConfig {
    pub fn new(artifacts: impl Into<PathBuf>, model: &str) -> EngineConfig {
        EngineConfig {
            artifacts: artifacts.into(),
            model: model.to_string(),
            bucket: 16,
            fused_buffers: true,
            max_queue_samples: 4096,
            h_init: 0.01,
            r: 0.9,
            safety: 0.9,
        }
    }
}

#[derive(Clone, Debug)]
pub struct GenResult {
    /// Unit-range images, [n, dim].
    pub images: Tensor,
    pub nfe: Vec<u64>,
    pub wall_s: f64,
    pub queued_s: f64,
}

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub requests_done: u64,
    pub samples_done: u64,
    pub queued_samples: usize,
    pub active_slots: usize,
    pub steps: u64,
    pub rejections: u64,
    pub score_evals: u64,
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    pub latency_mean_s: f64,
    /// Mean occupied slots per step since start (batching efficiency).
    pub mean_occupancy: f64,
}

/// Handle owning the engine thread.
pub struct Engine {
    tx: mpsc::Sender<Msg>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Cloneable, Send client for server/bench threads.
#[derive(Clone)]
pub struct EngineClient {
    tx: mpsc::Sender<Msg>,
}

impl Engine {
    /// Spawn the engine thread; fails fast if the runtime cannot load.
    pub fn start(cfg: EngineConfig) -> Result<Engine> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let join = std::thread::Builder::new()
            .name("gofast-engine".into())
            .spawn(move || engine_main(cfg, rx, ready_tx))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))?
            .map_err(|e| anyhow!("engine startup failed: {e}"))?;
        Ok(Engine { tx, join: Some(join) })
    }

    pub fn client(&self) -> EngineClient {
        EngineClient { tx: self.tx.clone() }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl EngineClient {
    pub fn generate(&self, n: usize, eps_rel: f64, seed: u64) -> Result<GenResult> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Generate(SampleRequest { n, eps_rel, seed }, rtx))
            .map_err(|_| anyhow!("engine is down"))?;
        rrx.recv().map_err(|_| anyhow!("engine dropped the request"))?.map_err(|e| anyhow!(e))
    }

    pub fn stats(&self) -> Result<EngineStats> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Stats(rtx)).map_err(|_| anyhow!("engine is down"))?;
        rrx.recv().map_err(|_| anyhow!("engine dropped the stats request"))
    }
}

// --- engine internals ---------------------------------------------------------

struct EngineState<'m, 'rt> {
    model: &'m Model<'rt>,
    cfg: EngineConfig,
    process: crate::sde::Process,
    slots: Vec<Slot>,
    x: Tensor,
    xprev: Tensor,
    pending: HashMap<u64, Pending>,
    fifo: Vec<u64>, // request ids in arrival order
    next_req_id: u64,
    queued_samples: usize,
    // metrics
    requests_done: u64,
    samples_done: u64,
    steps: u64,
    rejections: u64,
    latency: Histogram,
    occupancy_sum: u64,
}

fn engine_main(
    cfg: EngineConfig,
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<Result<(), String>>,
) {
    let rt = match Runtime::new(&cfg.artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };
    let model = match rt.model(&cfg.model) {
        Ok(m) => m,
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };
    if !model.buckets("adaptive_step").contains(&cfg.bucket) {
        let _ = ready.send(Err(format!(
            "bucket {} not available for adaptive_step (have {:?})",
            cfg.bucket,
            model.buckets("adaptive_step")
        )));
        return;
    }
    let dim = model.meta.dim;
    let bucket = cfg.bucket;
    let mut st = EngineState {
        process: model.meta.process(),
        model: &model,
        slots: vec![Slot::Free; bucket],
        x: Tensor::zeros(&[bucket, dim]),
        xprev: Tensor::zeros(&[bucket, dim]),
        pending: HashMap::new(),
        fifo: Vec::new(),
        next_req_id: 1,
        queued_samples: 0,
        requests_done: 0,
        samples_done: 0,
        steps: 0,
        rejections: 0,
        latency: Histogram::new(),
        occupancy_sum: 0,
        cfg,
    };
    let _ = ready.send(Ok(()));

    loop {
        // 1. drain the mailbox (block only when fully idle)
        let idle = st.slots.iter().all(|s| s.is_free()) && st.fifo.is_empty();
        if idle {
            match rx.recv() {
                Ok(msg) => {
                    if st.handle_msg(msg) {
                        return;
                    }
                }
                Err(_) => return,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(msg) => {
                    if st.handle_msg(msg) {
                        return;
                    }
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => return,
            }
        }
        // 2. admit queued samples into free slots
        st.admit();
        // 3. advance the continuous batch one Algorithm-1 iteration
        if st.slots.iter().any(|s| !s.is_free()) {
            if let Err(e) = st.step() {
                st.fail_all(&format!("engine step failed: {e:#}"));
            }
        }
    }
}

impl<'m, 'rt> EngineState<'m, 'rt> {
    /// Returns true on shutdown.
    fn handle_msg(&mut self, msg: Msg) -> bool {
        match msg {
            Msg::Shutdown => true,
            Msg::Stats(reply) => {
                let _ = reply.send(self.stats());
                false
            }
            Msg::Generate(req, reply) => {
                if req.n == 0 {
                    let _ = reply.send(Err("n must be > 0".into()));
                    return false;
                }
                if self.queued_samples + req.n > self.cfg.max_queue_samples {
                    let _ = reply.send(Err(format!(
                        "queue full ({} samples queued, max {})",
                        self.queued_samples, self.cfg.max_queue_samples
                    )));
                    return false;
                }
                let id = self.next_req_id;
                self.next_req_id += 1;
                self.queued_samples += req.n;
                let dim = self.model.meta.dim;
                self.pending.insert(
                    id,
                    Pending {
                        images: Tensor::zeros(&[req.n, dim]),
                        nfe: vec![0; req.n],
                        next_sample: 0,
                        done: 0,
                        reply,
                        enqueued: Instant::now(),
                        started: None,
                        req,
                    },
                );
                self.fifo.push(id);
                false
            }
        }
    }

    /// FIFO admission of queued samples into free slots.
    fn admit(&mut self) {
        let mut fi = 0;
        for si in 0..self.slots.len() {
            if !self.slots[si].is_free() {
                continue;
            }
            // find next request with samples left to admit (completed
            // requests may still sit in fifo until the retain below)
            while fi < self.fifo.len() {
                let id = self.fifo[fi];
                match self.pending.get(&id) {
                    Some(p) if p.next_sample < p.req.n => break,
                    _ => fi += 1,
                }
            }
            if fi >= self.fifo.len() {
                break;
            }
            let id = self.fifo[fi];
            let p = self.pending.get_mut(&id).unwrap();
            let sample_idx = p.next_sample;
            p.next_sample += 1;
            if p.started.is_none() {
                p.started = Some(Instant::now());
            }
            self.queued_samples -= 1;
            // init the lane: prior draw, fresh forked rng per sample
            let mut rng = Rng::new(p.req.seed).fork(sample_idx as u64);
            {
                let row = self.x.row_mut(si);
                let std = self.process.prior_std() as f32;
                for v in row.iter_mut() {
                    *v = rng.normal() as f32 * std;
                }
                let prev = row.to_vec();
                self.xprev.row_mut(si).copy_from_slice(&prev);
            }
            self.slots[si] = Slot::Running {
                req_id: id,
                sample_idx,
                t: 1.0,
                h: self.cfg.h_init,
                eps_rel: p.req.eps_rel,
                nfe: 0,
                rng,
            };
        }
        // drop fully-admitted-and-finished request ids from fifo head
        self.fifo.retain(|id| self.pending.contains_key(id));
    }

    /// One fused adaptive_step over the slot pool.
    fn step(&mut self) -> Result<()> {
        let b = self.cfg.bucket;
        let dim = self.model.meta.dim;
        let t_eps = self.process.t_eps();
        let eps_abs = self.process.eps_abs();
        let mut t_in = vec![1.0f32; b];
        let mut h_in = vec![0.0f32; b];
        let mut er_in = vec![0.01f32; b];
        let mut z = Tensor::zeros(&[b, dim]);
        let mut occupied = 0u64;
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if let Slot::Running { t, h, eps_rel, rng, .. } = slot {
                occupied += 1;
                *h = h.min(*t - t_eps).max(0.0);
                t_in[i] = *t as f32;
                h_in[i] = *h as f32;
                er_in[i] = *eps_rel as f32;
                rng.fill_normal(z.row_mut(i));
            }
        }
        self.occupancy_sum += occupied;
        let t_t = Tensor { shape: vec![b], data: t_in };
        let h_t = Tensor { shape: vec![b], data: h_in };
        let er_t = Tensor { shape: vec![b], data: er_in };
        let ea_t = Tensor::scalar(eps_abs as f32);
        let out = self.model.exec(
            "adaptive_step",
            b,
            &[&self.x, &self.xprev, &t_t, &h_t, &z, &ea_t, &er_t],
            self.cfg.fused_buffers,
        )?;
        let (xpp, xp, e2) = (&out[0], &out[1], &out[2]);
        self.steps += 1;

        let mut converged: Vec<usize> = Vec::new();
        for i in 0..b {
            let Slot::Running { t, h, nfe, .. } = &mut self.slots[i] else {
                continue;
            };
            *nfe += 2;
            let e = e2.data[i] as f64;
            if e <= 1.0 {
                self.x.row_mut(i).copy_from_slice(xpp.row(i));
                self.xprev.row_mut(i).copy_from_slice(xp.row(i));
                *t -= *h;
                if *t <= t_eps + 1e-12 {
                    converged.push(i);
                }
            } else {
                self.rejections += 1;
            }
            let grow = self.cfg.safety * e.max(1e-12).powf(-self.cfg.r);
            *h = (*h * grow).min((*t - t_eps).max(0.0));
        }
        if !converged.is_empty() {
            self.finish_slots(&converged)?;
        }
        Ok(())
    }

    /// Denoise converged lanes (one batched Tweedie call) and hand their
    /// images back to their requests; free the lanes.
    fn finish_slots(&mut self, lanes: &[usize]) -> Result<()> {
        let b = self.cfg.bucket;
        let t_end = super::super::solvers::t_vec(b, self.process.t_eps());
        let mut out =
            self.model.exec("denoise", b, &[&self.x, &t_end], self.cfg.fused_buffers)?;
        let x0 = out.pop().unwrap();
        for &i in lanes {
            let Slot::Running { req_id, sample_idx, nfe, .. } = self.slots[i] else {
                continue;
            };
            let nfe_total = nfe + 1; // the denoise eval
            let p = self.pending.get_mut(&req_id).expect("pending req exists");
            // unit-range conversion into the request buffer
            let (lo, hi) = self.process.data_range();
            let (lo, hi) = (lo as f32, hi as f32);
            let dst = p.images.row_mut(sample_idx);
            for (d, &s) in dst.iter_mut().zip(x0.row(i)) {
                *d = ((s - lo) / (hi - lo)).clamp(0.0, 1.0);
            }
            p.nfe[sample_idx] = nfe_total;
            p.done += 1;
            self.samples_done += 1;
            if p.done == p.req.n {
                let p = self.pending.remove(&req_id).unwrap();
                let now = Instant::now();
                let wall =
                    now.duration_since(p.started.unwrap_or(p.enqueued)).as_secs_f64();
                let queued = p
                    .started
                    .map(|s| s.duration_since(p.enqueued).as_secs_f64())
                    .unwrap_or(0.0);
                self.latency.record(now.duration_since(p.enqueued).as_secs_f64());
                self.requests_done += 1;
                let _ = p.reply.send(Ok(GenResult {
                    images: p.images,
                    nfe: p.nfe,
                    wall_s: wall,
                    queued_s: queued,
                }));
            }
            self.slots[i] = Slot::Free;
        }
        Ok(())
    }

    fn fail_all(&mut self, msg: &str) {
        for (_, p) in self.pending.drain() {
            let _ = p.reply.send(Err(msg.to_string()));
        }
        self.fifo.clear();
        self.queued_samples = 0;
        for s in self.slots.iter_mut() {
            *s = Slot::Free;
        }
    }

    fn stats(&self) -> EngineStats {
        EngineStats {
            requests_done: self.requests_done,
            samples_done: self.samples_done,
            queued_samples: self.queued_samples,
            active_slots: self.slots.iter().filter(|s| !s.is_free()).count(),
            steps: self.steps,
            rejections: self.rejections,
            score_evals: self.model.runtime().stats().score_evals,
            latency_p50_s: self.latency.quantile(0.5),
            latency_p95_s: self.latency.quantile(0.95),
            latency_mean_s: self.latency.mean(),
            mean_occupancy: if self.steps == 0 {
                0.0
            } else {
                self.occupancy_sum as f64 / self.steps as f64
            },
        }
    }
}
