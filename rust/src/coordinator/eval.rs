//! Engine-side FID*/IS* evaluation jobs (docs/ARCHITECTURE.md
//! §Evaluation).
//!
//! An `evaluate` request is serviced by the *serving* machinery, not a
//! side path: the job is cut into fid-bucket-sized chunks, each admitted
//! as an internal sample request through the same FIFO / scheduler /
//! registry route client traffic takes — onto the lane-program pool of
//! whichever solver the request names (adaptive, em:<n>, ddim:<n>,
//! pc:<n>[@<snr>]), so
//! solver or scheduler regressions move the reported FID*. Completed
//! chunks are pushed through the model's feature net into per-chunk
//! `EvalAccumulator`s and Chan-merged **in chunk order** — completion
//! order may vary with co-batched traffic, but the merge order never
//! does, which keeps the result reproducible and comparable with the
//! `--offline` bypass (bit-identical when the lane order matches; the
//! per-lane RNG contract in `solvers::spec::run_lanes` is what makes
//! that possible, for fixed-step programs exactly as for adaptive).
//!
//! At most `MAX_INFLIGHT_CHUNKS` chunks are outstanding per job, so an
//! evaluation run holds O(chunk) images in memory regardless of its
//! sample count and cannot flood the admission queue.

use super::registry::Registry;
use crate::metrics::{self, EvalAccumulator, FeatureStats};
use crate::runtime::FidNet;
use crate::solvers::ServingSolver;
use crate::tensor::Tensor;
use std::collections::{BTreeMap, HashMap};
use std::sync::mpsc;
use std::time::Instant;

/// Evaluation chunks admitted concurrently per job (bounds eval memory
/// and queue pressure; merge order is by chunk index either way).
pub(crate) const MAX_INFLIGHT_CHUNKS: usize = 2;

/// An evaluation request as accepted by the engine. Any solver the
/// model has a lane-program pool for (adaptive, em:<n>, ddim:<n>,
/// pc:<n>[@<snr>]) can be evaluated through the serving path; parse
/// specs with `solvers::spec::parse`.
#[derive(Clone, Debug)]
pub struct EvalRequest {
    /// Model variant ("" = the engine's default model).
    pub model: String,
    /// Solver program the evaluation lanes advance under.
    pub solver: ServingSolver,
    pub samples: usize,
    /// Adaptive tolerance knob (ignored by fixed-step solvers).
    pub eps_rel: f64,
    pub seed: u64,
    /// Priority class the job's chunks are queued at (`None` = the
    /// engine's configured default). Evaluation runs are usually
    /// background work — mark them `batch` so interactive generate
    /// traffic on the same pool is admitted first.
    pub priority: Option<super::qos::Priority>,
}

/// Outcome of an engine-served evaluation run.
#[derive(Clone, Debug)]
pub struct EvalResult {
    /// Model that served the run (resolved default).
    pub model: String,
    /// Canonical spec string of the solver that ran ("adaptive",
    /// "em:<n>", "ddim:<n>", "pc:<n>[@<snr>]").
    pub solver: String,
    pub samples: usize,
    pub fid: f64,
    pub is: f64,
    /// Mean score-net evaluations per sample (incl. the denoise call).
    pub mean_nfe: f64,
    pub wall_s: f64,
    /// Fused steps per pool width the serving pool ran while this job
    /// was in flight (shared with concurrent traffic on the same model).
    pub steps_per_bucket: Vec<(usize, u64)>,
}

/// Feature net + reference Gaussian for one model, loaded lazily on the
/// first evaluate request that names it.
struct EvalNet<'rt> {
    net: FidNet<'rt>,
    reference: FeatureStats,
    /// Generation/featurization chunk: the net's widest bucket.
    chunk: usize,
}

struct EvalJob {
    model_idx: usize,
    /// Pool (within the model) serving this job's lanes.
    pool_idx: usize,
    req: EvalRequest,
    reply: mpsc::Sender<Result<EvalResult, String>>,
    merged: EvalAccumulator,
    /// Completed chunks awaiting in-order merge, keyed by chunk index.
    ready: BTreeMap<usize, EvalAccumulator>,
    next_merge: usize,
    chunks_total: usize,
    submitted: usize,
    nfe_sum: u64,
    started: Instant,
    steps_before: Vec<(usize, u64)>,
}

/// A chunk of an eval job to admit as an internal sample request.
pub(crate) struct ChunkSpec {
    pub job: u64,
    pub chunk: usize,
    pub model_idx: usize,
    pub pool_idx: usize,
    pub solver: ServingSolver,
    pub n: usize,
    pub sample_base: u64,
    pub eps_rel: f64,
    pub seed: u64,
    pub priority: Option<super::qos::Priority>,
}

/// All in-flight evaluation jobs plus the eval-lane counters exported
/// through `EngineStats`.
pub(crate) struct EvalManager<'rt> {
    jobs: HashMap<u64, EvalJob>,
    nets: HashMap<usize, EvalNet<'rt>>,
    next_id: u64,
    pub evals_done: u64,
    pub eval_samples_done: u64,
    /// Real grid nodes advanced by lanes owned by eval jobs (the eval
    /// share of `occupied_lane_steps`; up to k nodes per lane per fused
    /// dispatch).
    pub eval_lane_steps: u64,
}

impl<'rt> EvalManager<'rt> {
    pub fn new() -> EvalManager<'rt> {
        EvalManager {
            jobs: HashMap::new(),
            nets: HashMap::new(),
            next_id: 1,
            evals_done: 0,
            eval_samples_done: 0,
            eval_lane_steps: 0,
        }
    }

    pub fn active(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_eval_sink(sink: &super::Sink) -> bool {
        matches!(sink, super::Sink::Eval { .. })
    }

    /// Load (once) the feature net + reference stats for model `mi`.
    /// Runs on the engine thread (PJRT handles are not `Send`), so the
    /// *first* evaluate against a model pays the reference featurization
    /// as a one-time stall of co-batched traffic; later evaluates hit
    /// this cache.
    pub fn ensure_net(&mut self, mi: usize, registry: &Registry<'rt>) -> Result<(), String> {
        if self.nets.contains_key(&mi) {
            return Ok(());
        }
        let model = &registry.entries()[mi].model;
        let (net, reference) = metrics::reference_for(model.runtime(), &model.meta)
            .map_err(|e| format!("loading eval reference: {e:#}"))?;
        let chunk = *net
            .meta
            .buckets
            .last()
            .ok_or_else(|| "fid net has no compiled buckets".to_string())?;
        self.nets.insert(mi, EvalNet { net, reference, chunk });
        Ok(())
    }

    /// Register a job on pool `pi` of model `mi`; `ensure_net(mi)` must
    /// have succeeded first. Returns the chunk specs to admit now.
    pub fn start_job(
        &mut self,
        mi: usize,
        pi: usize,
        req: EvalRequest,
        reply: mpsc::Sender<Result<EvalResult, String>>,
        steps_before: Vec<(usize, u64)>,
    ) -> Vec<ChunkSpec> {
        let net = &self.nets[&mi];
        let chunk = net.chunk;
        let chunks_total = req.samples.div_ceil(chunk);
        let id = self.next_id;
        self.next_id += 1;
        self.jobs.insert(
            id,
            EvalJob {
                model_idx: mi,
                pool_idx: pi,
                merged: EvalAccumulator::new(net.net.meta.feat_dim, net.net.meta.n_classes),
                ready: BTreeMap::new(),
                next_merge: 0,
                chunks_total,
                submitted: 0,
                nfe_sum: 0,
                started: Instant::now(),
                steps_before,
                req,
                reply,
            },
        );
        self.next_chunks(id)
    }

    /// Chunk specs to admit so the job keeps `MAX_INFLIGHT_CHUNKS`
    /// outstanding.
    fn next_chunks(&mut self, job_id: u64) -> Vec<ChunkSpec> {
        let Some(job) = self.jobs.get_mut(&job_id) else {
            return Vec::new();
        };
        let chunk = self.nets[&job.model_idx].chunk;
        let mut specs = Vec::new();
        let merged_or_ready = job.next_merge + job.ready.len();
        while job.submitted < job.chunks_total
            && job.submitted - merged_or_ready < MAX_INFLIGHT_CHUNKS
        {
            let start = job.submitted * chunk;
            let n = (job.req.samples - start).min(chunk);
            specs.push(ChunkSpec {
                job: job_id,
                chunk: job.submitted,
                model_idx: job.model_idx,
                pool_idx: job.pool_idx,
                solver: job.req.solver,
                n,
                sample_base: start as u64,
                eps_rel: job.req.eps_rel,
                seed: job.req.seed,
                priority: job.req.priority,
            });
            job.submitted += 1;
        }
        specs
    }

    /// Fold a completed chunk in. Returns follow-up chunk specs to admit
    /// (empty when the job just finished or is unknown). `sched_now` is
    /// the serving pool's current per-bucket step counters, used for the
    /// consumed-steps delta when the job completes.
    pub fn on_chunk_done(
        &mut self,
        job_id: u64,
        chunk_idx: usize,
        images: &Tensor,
        nfe: &[u64],
        sched_now: &[(usize, u64)],
        model_name: &str,
    ) -> Vec<ChunkSpec> {
        let Some(job) = self.jobs.get_mut(&job_id) else {
            // job already failed (pool fault) — drop the stale chunk
            return Vec::new();
        };
        let net = &self.nets[&job.model_idx];
        let mut acc = EvalAccumulator::new(net.net.meta.feat_dim, net.net.meta.n_classes);
        match metrics::extract_features(&net.net, images) {
            Ok((f, l)) => acc.push(&f, &l),
            Err(e) => {
                let job = self.jobs.remove(&job_id).unwrap();
                let _ = job.reply.send(Err(format!("feature extraction failed: {e:#}")));
                return Vec::new();
            }
        }
        job.nfe_sum += nfe.iter().sum::<u64>();
        self.eval_samples_done += images.shape[0] as u64;
        job.ready.insert(chunk_idx, acc);
        // merge every chunk that is now contiguous with the merged prefix
        while let Some(acc) = job.ready.remove(&job.next_merge) {
            job.merged.merge(&acc);
            job.next_merge += 1;
        }
        if job.next_merge == job.chunks_total {
            let job = self.jobs.remove(&job_id).unwrap();
            let reply = match job.merged.finalize(&net.reference) {
                Ok((fid, is)) => {
                    self.evals_done += 1;
                    Ok(EvalResult {
                        model: model_name.to_string(),
                        solver: job.req.solver.spec_string(),
                        samples: job.req.samples,
                        fid,
                        is,
                        mean_nfe: job.nfe_sum as f64 / job.req.samples as f64,
                        wall_s: job.started.elapsed().as_secs_f64(),
                        steps_per_bucket: steps_delta(&job.steps_before, sched_now),
                    })
                }
                Err(e) => Err(format!("finalizing eval stats: {e:#}")),
            };
            let _ = job.reply.send(reply);
            return Vec::new();
        }
        self.next_chunks(job_id)
    }

    /// Fail every job whose serving pool died. Returns how many were
    /// failed (their chunk pendings are being torn down by the caller).
    pub fn fail_jobs_on_pool(&mut self, mi: usize, pi: usize, msg: &str) -> usize {
        let ids: Vec<u64> = self
            .jobs
            .iter()
            .filter(|(_, j)| j.model_idx == mi && j.pool_idx == pi)
            .map(|(id, _)| *id)
            .collect();
        for id in &ids {
            if let Some(j) = self.jobs.remove(id) {
                let _ = j.reply.send(Err(msg.to_string()));
            }
        }
        ids.len()
    }
}

/// Per-bucket steps consumed between two scheduler snapshots.
fn steps_delta(before: &[(usize, u64)], now: &[(usize, u64)]) -> Vec<(usize, u64)> {
    now.iter()
        .map(|&(b, n)| {
            let prev = before.iter().find(|(pb, _)| *pb == b).map(|(_, p)| *p).unwrap_or(0);
            (b, n.saturating_sub(prev))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::steps_delta;

    #[test]
    fn steps_delta_subtracts_per_bucket() {
        let before = vec![(1, 5), (2, 10)];
        let now = vec![(1, 5), (2, 25), (4, 3)];
        assert_eq!(steps_delta(&before, &now), vec![(1, 0), (2, 15), (4, 3)]);
    }
}
