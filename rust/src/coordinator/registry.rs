//! Model registry + per-(model, program) slot pools
//! (docs/ARCHITECTURE.md §Registry).
//!
//! Loads N score-model variants from one artifacts dir, gives each a
//! continuous-batching lane pool **per served solver program**
//! (adaptive / em / ddim / pc — see `programs`), and routes requests by the
//! (model name, solver) pair (the first listed model is the default).
//! Each pool carries its own bucket ladder, scheduler and FIFO, so
//! mixed traffic — adaptive generates next to EM eval lanes — co-exists
//! on one engine thread. PJRT handles are not `Send`, so every pool
//! shares the single engine thread; service order over the flattened
//! (model, program) pool list is owned by `qos::WeightedRoundRobin`
//! (flat rotation at the default equal weights), one fused step per
//! turn, so a hot pool cannot starve the others beyond its weight.
//!
//! Pool ladders are validated against the artifact manifest up front: a
//! rung needs both the step program and `denoise` compiled at that
//! width (converged lanes denoise at pool width). The adaptive pool is
//! mandatory when configured (missing artifacts fail startup, as
//! before); fixed-step pools are built best-effort from whatever the
//! manifest offers, and requests for an absent pool get a clean
//! protocol error at admission instead of an engine-thread fault.

use super::diagnostics::PoolDiag;
use super::programs::{self, LaneProgram};
use super::scheduler::BucketScheduler;
use super::Slot;
use crate::metrics::hist::Histogram;
use crate::runtime::{DeviceSlab, Model, Runtime};
use crate::sde::Process;
use crate::solvers::spec::fused_artifact;
use crate::solvers::ServingSolver;
use crate::tensor::Tensor;
use crate::{anyhow, bail, Result};
use std::collections::HashMap;

/// One (model, solver program) continuous-batching lane pool.
pub(crate) struct ProgramPool {
    pub program: Box<dyn LaneProgram>,
    pub slots: Vec<Slot>,
    pub x: Tensor,
    /// Companion state for the adaptive program's extrapolation pair;
    /// migrated with `x` for every program (fixed-step programs simply
    /// never read it).
    pub xprev: Tensor,
    /// Device-resident lane state for fused pools (k > 1): when `Some`,
    /// the slab is current and the host `x` is stale; the engine
    /// downloads it back into `x` (and drops it) before anything reads
    /// or writes host rows — admission, migration, pool failure. The
    /// `xprev` companion stays host-only: no fixed-step kernel reads or
    /// writes it, so keeping a device copy would only widen transfers.
    pub dev_x: Option<DeviceSlab>,
    /// Grid nodes per dispatch this pool runs at (1 = single-step).
    pub steps_per_dispatch: usize,
    /// Request ids (into the engine's pending map) in arrival order.
    pub fifo: Vec<u64>,
    pub sched: BucketScheduler,
    /// Wall seconds per fused step dispatch of this pool (telemetry:
    /// the per-pool step-time quantiles the `metrics` op exports).
    /// `Histogram::record` is allocation-free, so this runs
    /// unconditionally on the hot path.
    pub step_time: Histogram,
    /// Adaptive accept/reject outcome counters (Algorithm 1's
    /// proposal test). Fixed-step programs never reject, so both stay
    /// 0 for their pools; the wire documents the series as
    /// adaptive-only.
    pub accepted: u64,
    pub rejected: u64,
    /// Solver-numerics diagnostics: the always-on diffusion-time
    /// profile plus the 1-in-N sampled lane traces (`--diag-sample`;
    /// 0 keeps the per-step path allocation-free, profile only).
    pub diag: PoolDiag,
}

impl ProgramPool {
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| !s.is_free()).count()
    }

    pub fn idle(&self) -> bool {
        self.fifo.is_empty() && self.slots.iter().all(|s| s.is_free())
    }
}

pub(crate) struct ModelEntry<'rt> {
    pub model: Model<'rt>,
    pub process: Process,
    pub pools: Vec<ProgramPool>,
}

impl ModelEntry<'_> {
    /// Pool index serving solver `name`, if this model has one.
    pub fn pool_for(&self, name: &str) -> Option<usize> {
        self.pools.iter().position(|p| p.program.solver_name() == name)
    }
}

/// Whether the manifest-recorded input shapes of `solver`'s step
/// artifact at `bucket` match what the descriptor-driven fixed program
/// will feed it: `theta, x[b,d], t[b], t2[b], noise[b,d] x N, snr[b]?`
/// at `steps = 1`, or the fused-variant stacking `theta, x[b,d],
/// t[k,b], t2[k,b], noise[k,b,d] x N, snr[b]?` at `steps = k > 1` (see
/// `solvers::spec::STEP_KERNELS` / aot.py). Adaptive keeps its own
/// strict validation; manifests without the single-step entry are
/// accepted (the rung was already filtered by `has_artifact`) — but a
/// fused rung whose manifest lacks the k-step entry is rejected, which
/// is what makes a pre-fused artifact set fall back to a lower k (or
/// single-step) instead of faulting mid-step.
fn kernel_abi_matches(model: &Model, solver: &str, bucket: usize, steps: usize) -> bool {
    let Some(k) = crate::solvers::spec::kernel(solver) else {
        return true;
    };
    if k.adaptive {
        if steps <= 1 {
            // the single-step adaptive artifact keeps its own strict
            // fail-fast startup validation in Registry::load
            return true;
        }
        // the fused accept/reject fold's packed ABI (see aot.py's
        // make_adaptive_fused): theta, slab[2·b·d + 4·k·b], t f64[b],
        // h f64[b], live[b], z[k,b,d], eps_abs[1], eps_rel[b],
        // actrl f64[3]
        let fused = fused_artifact(k.artifact, steps);
        let Some(inputs) = model.artifact_inputs(&fused, bucket) else {
            return false;
        };
        let d = model.meta.dim;
        let want: Vec<Vec<usize>> = vec![
            vec![model.meta.n_params],
            vec![2 * bucket * d + 4 * steps * bucket],
            vec![bucket],
            vec![bucket],
            vec![bucket],
            vec![steps, bucket, d],
            vec![1],
            vec![bucket],
            vec![3],
        ];
        return inputs == want.as_slice();
    }
    let d = model.meta.dim;
    if steps > 1 {
        let fused = fused_artifact(k.artifact, steps);
        let Some(inputs) = model.artifact_inputs(&fused, bucket) else {
            return false;
        };
        let mut want: Vec<Vec<usize>> = vec![
            vec![model.meta.n_params],
            vec![bucket, d],
            vec![steps, bucket],
            vec![steps, bucket],
        ];
        for _ in 0..k.noise_inputs {
            want.push(vec![steps, bucket, d]);
        }
        if k.snr_input {
            want.push(vec![bucket]);
        }
        return inputs == want.as_slice();
    }
    let Some(inputs) = model.artifact_inputs(k.artifact, bucket) else {
        return true;
    };
    let mut want: Vec<Vec<usize>> =
        vec![vec![model.meta.n_params], vec![bucket, d], vec![bucket], vec![bucket]];
    for _ in 0..k.noise_inputs {
        want.push(vec![bucket, d]);
    }
    if k.snr_input {
        want.push(vec![bucket]);
    }
    inputs == want.as_slice()
}

pub(crate) struct Registry<'rt> {
    entries: Vec<ModelEntry<'rt>>,
    by_name: HashMap<String, usize>,
}

impl<'rt> Registry<'rt> {
    /// Load every named variant with a pool per entry of `programs`
    /// (solver names; see `programs::for_solver`). The adaptive pool
    /// starts at width `max_bucket` and — with `migrate` on — may move
    /// across every compiled rung <= `max_bucket`; fixed-step pools use
    /// the widest rung their own artifacts provide under the same cap.
    /// With `migrate` off every pool is pinned at its widest rung.
    /// `steps_per_dispatch` is the requested fused k; each pool clamps
    /// it to its kernel's `max_steps_per_dispatch` (fixed-step kernels
    /// fuse grid nodes, the adaptive kernel fuses Algorithm-1 attempts
    /// via the device-side accept/reject fold) and then resolves it
    /// down to the largest fused variant its artifact set provides (a
    /// pre-fused set degrades to single-step rather than un-serving the
    /// pool). `steps_overrides` are per-pool k overrides keyed
    /// `"model"` or `"model/solver"` (the more specific key wins over
    /// the model key, which wins over the global default); a key that
    /// matches no served pool fails startup like a typo'd `--weights`
    /// key.
    #[allow(clippy::too_many_arguments)]
    pub fn load(
        rt: &'rt Runtime,
        names: &[String],
        max_bucket: usize,
        migrate: bool,
        programs: &[String],
        steps_per_dispatch: usize,
        steps_overrides: &[(String, usize)],
        diag_sample: usize,
    ) -> Result<Registry<'rt>> {
        if names.is_empty() {
            bail!("registry needs at least one model");
        }
        if programs.is_empty() {
            bail!("registry needs at least one solver program");
        }
        let mut entries = Vec::new();
        let mut by_name = HashMap::new();
        let mut override_used = vec![false; steps_overrides.len()];
        for name in names {
            if by_name.contains_key(name.as_str()) {
                bail!("model '{name}' listed twice");
            }
            let model = rt.model(name)?;
            let process = model.meta.process();
            let mut pools = Vec::new();
            for prog_name in programs {
                let program = programs::for_solver(prog_name)
                    .ok_or_else(|| anyhow!("no lane program for solver '{prog_name}'"))?;
                if program.vp_only() && process.kind() != "vp" {
                    continue; // e.g. DDIM is VP-only (paper §4)
                }
                let step = program.step_artifact();
                if program.solver_name() == "adaptive" {
                    // mandatory pool: keep the strict fail-fast
                    // validation the engine has always had
                    let buckets = model.buckets(step);
                    if !buckets.contains(&max_bucket) {
                        bail!(
                            "bucket {max_bucket} not available for {name}/{step} (have {buckets:?})"
                        );
                    }
                    for prog in [step, "denoise"] {
                        if !model.has_artifact(prog, max_bucket) {
                            bail!("{name}: {prog}_b{max_bucket} artifact missing on disk");
                        }
                    }
                }
                // a rung needs the step program and denoise both listed
                // in the manifest and present on disk — converged lanes
                // denoise at pool width, and a lazy compile error
                // mid-serving would otherwise be the first sign — and
                // the artifact's recorded ABI must match what the lane
                // program will feed it (an artifact set lowered by an
                // older aot.py, e.g. pc_step with a scalar snr instead
                // of per-lane snr[B], must leave the pool unserved with
                // a clean rebuild-artifacts admission error, not fault
                // every request mid-step on an argument-shape error)
                // resolved fused k for this pool: the serve request
                // clamped to the kernel's table row (adaptive stays 1),
                // then lowered to the largest k whose fused variant the
                // manifest actually provides — aot.py lowers a fixed set
                // of fused steps (default 4,8), so e.g. a requested k=5
                // serves at k=4 instead of silently emptying the ladder
                // and un-serving the pool
                let kernel = crate::solvers::spec::kernel(program.solver_name())
                    .expect("for_solver implies a table row");
                // per-pool k: "model/solver" key > "model" key > global
                // (keys are only marked used once the pool actually
                // serves, matching --weights "no served pool" semantics)
                let exact = format!("{name}/{}", program.solver_name());
                let mut want_k = steps_per_dispatch;
                let mut matched: Vec<usize> = Vec::new();
                for specificity in [name.as_str(), exact.as_str()] {
                    for (oi, (key, v)) in steps_overrides.iter().enumerate() {
                        if key == specificity {
                            matched.push(oi);
                            want_k = *v;
                        }
                    }
                }
                let mut k = want_k.clamp(1, kernel.max_steps_per_dispatch);
                let ladder: Vec<usize> = loop {
                    let fused_step = fused_artifact(step, k);
                    let ladder: Vec<usize> = model
                        .buckets(step)
                        .iter()
                        .copied()
                        .filter(|&b| {
                            b <= max_bucket
                                && model.has_artifact(step, b)
                                && (k == 1 || model.has_artifact(&fused_step, b))
                                && model.has_artifact("denoise", b)
                                && kernel_abi_matches(&model, program.solver_name(), b, k)
                        })
                        .collect();
                    if !ladder.is_empty() || k == 1 {
                        break ladder;
                    }
                    k -= 1;
                };
                if ladder.is_empty() {
                    continue; // pool absent even single-step: clean
                              // error at admit
                }
                for oi in matched {
                    override_used[oi] = true;
                }
                let ladder = if migrate { ladder } else { vec![*ladder.last().unwrap()] };
                let dim = model.meta.dim;
                let sched = BucketScheduler::new(ladder);
                let width = sched.width();
                pools.push(ProgramPool {
                    program,
                    slots: vec![Slot::Free; width],
                    x: Tensor::zeros(&[width, dim]),
                    xprev: Tensor::zeros(&[width, dim]),
                    dev_x: None,
                    steps_per_dispatch: k,
                    fifo: Vec::new(),
                    sched,
                    step_time: Histogram::new(),
                    accepted: 0,
                    rejected: 0,
                    diag: PoolDiag::new(process.t_eps(), width, diag_sample),
                });
            }
            if pools.is_empty() {
                bail!(
                    "model '{name}' supports none of the configured solver \
                     programs {programs:?}"
                );
            }
            by_name.insert(name.clone(), entries.len());
            entries.push(ModelEntry { model, process, pools });
        }
        if let Some(i) = override_used.iter().position(|u| !u) {
            let key = &steps_overrides[i].0;
            let pools: Vec<String> = entries
                .iter()
                .flat_map(|e| {
                    e.pools
                        .iter()
                        .map(|p| format!("{}/{}", e.model.meta.name, p.program.solver_name()))
                })
                .collect();
            bail!("--steps-per-dispatch key '{key}' matches no served pool (pools: {pools:?})");
        }
        Ok(Registry { entries, by_name })
    }

    /// Model index for a request's model name ("" = the default model).
    pub fn resolve(&self, name: &str) -> Result<usize> {
        if name.is_empty() {
            return Ok(0);
        }
        self.by_name.get(name).copied().ok_or_else(|| {
            let mut have: Vec<&str> = self.by_name.keys().map(|s| s.as_str()).collect();
            have.sort();
            anyhow!("unknown model '{name}' (serving: {have:?})")
        })
    }

    /// (model, pool) indices for a request's (model, solver), with a
    /// clean protocol error when the model has no pool for the solver
    /// (non-VP DDIM, missing step artifacts, or a program excluded from
    /// the serve config).
    pub fn resolve_pool(&self, model: &str, solver: &ServingSolver) -> Result<(usize, usize)> {
        let mi = self.resolve(model)?;
        let e = &self.entries[mi];
        let name = solver.name();
        if let Some(pi) = e.pool_for(name) {
            return Ok((mi, pi));
        }
        let mname = &e.model.meta.name;
        let vp_only = crate::solvers::spec::kernel(name).is_some_and(|k| k.vp_only);
        if vp_only && e.process.kind() != "vp" {
            bail!(
                "solver '{name}' requires a VP model (paper §4); '{mname}' is {}",
                e.process.kind()
            );
        }
        let served: Vec<&str> = e.pools.iter().map(|p| p.program.solver_name()).collect();
        bail!(
            "model '{mname}' does not serve solver '{name}' (serving: {served:?}; \
             lower {} artifacts with aot.py or adjust the serve --solvers list)",
            solver.step_artifact()
        )
    }

    pub fn entries(&self) -> &[ModelEntry<'rt>] {
        &self.entries
    }

    pub fn entry_mut(&mut self, i: usize) -> &mut ModelEntry<'rt> {
        &mut self.entries[i]
    }

    /// (model, pool) indices for a flat pool index (flat service order
    /// = the order `pool_labels` lists).
    pub fn pool_at(&self, mut flat: usize) -> (usize, usize) {
        for (mi, e) in self.entries.iter().enumerate() {
            if flat < e.pools.len() {
                return (mi, flat);
            }
            flat -= e.pools.len();
        }
        unreachable!("flat pool index out of range")
    }

    /// `(model name, solver name)` per pool in flat service order — the
    /// list QoS weights are resolved against.
    pub fn pool_labels(&self) -> Vec<(String, String)> {
        self.entries
            .iter()
            .flat_map(|e| {
                e.pools
                    .iter()
                    .map(|p| (e.model.meta.name.clone(), p.program.solver_name().to_string()))
            })
            .collect()
    }

    pub fn all_idle(&self) -> bool {
        self.entries.iter().all(|e| e.pools.iter().all(|p| p.idle()))
    }
}
