//! Model registry + per-model slot pools (docs/ARCHITECTURE.md §Registry).
//!
//! Loads N score-model variants from one artifacts dir, gives each its
//! own continuous-batching lane pool, and routes requests by model name
//! (the first listed model is the default). PJRT handles are not `Send`,
//! so every pool shares the single engine thread; the engine services
//! them round-robin, one fused step per turn, so a hot model cannot
//! starve the others for more than one step.

use super::scheduler::BucketScheduler;
use super::Slot;
use crate::runtime::{Model, Runtime};
use crate::sde::Process;
use crate::tensor::Tensor;
use crate::{anyhow, bail, Result};
use std::collections::HashMap;

/// One model's continuous-batching lane pool.
pub(crate) struct Pool {
    pub slots: Vec<Slot>,
    pub x: Tensor,
    pub xprev: Tensor,
    /// Request ids (into the engine's pending map) in arrival order.
    pub fifo: Vec<u64>,
    pub sched: BucketScheduler,
}

impl Pool {
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| !s.is_free()).count()
    }

    pub fn idle(&self) -> bool {
        self.fifo.is_empty() && self.slots.iter().all(|s| s.is_free())
    }
}

pub(crate) struct ModelEntry<'rt> {
    pub model: Model<'rt>,
    pub process: Process,
    pub pool: Pool,
}

pub(crate) struct Registry<'rt> {
    entries: Vec<ModelEntry<'rt>>,
    by_name: HashMap<String, usize>,
    /// Round-robin position for fair pool servicing.
    cursor: usize,
}

impl<'rt> Registry<'rt> {
    /// Load every named variant. Each pool starts at width `max_bucket`;
    /// with `migrate` on it may move across every compiled
    /// `adaptive_step` bucket <= `max_bucket`, otherwise it is pinned.
    pub fn load(
        rt: &'rt Runtime,
        names: &[String],
        max_bucket: usize,
        migrate: bool,
    ) -> Result<Registry<'rt>> {
        if names.is_empty() {
            bail!("registry needs at least one model");
        }
        let mut entries = Vec::new();
        let mut by_name = HashMap::new();
        for name in names {
            if by_name.contains_key(name.as_str()) {
                bail!("model '{name}' listed twice");
            }
            let model = rt.model(name)?;
            let buckets = model.buckets("adaptive_step");
            if !buckets.contains(&max_bucket) {
                bail!(
                    "bucket {max_bucket} not available for {name}/adaptive_step (have {buckets:?})"
                );
            }
            // fail fast on missing artifacts — a lazy compile error
            // mid-serving would otherwise be the first sign (converged
            // lanes denoise at pool width, so a rung needs both
            // programs). The mandatory max rung errors; optional smaller
            // rungs just drop off the ladder.
            for prog in ["adaptive_step", "denoise"] {
                if !model.has_artifact(prog, max_bucket) {
                    bail!("{name}: {prog}_b{max_bucket} artifact missing on disk");
                }
            }
            let ladder: Vec<usize> = if migrate {
                buckets
                    .iter()
                    .copied()
                    .filter(|&b| {
                        b == max_bucket
                            || (b < max_bucket
                                && model.has_artifact("adaptive_step", b)
                                && model.has_artifact("denoise", b))
                    })
                    .collect()
            } else {
                vec![max_bucket]
            };
            let dim = model.meta.dim;
            let sched = BucketScheduler::new(ladder);
            let width = sched.width();
            by_name.insert(name.clone(), entries.len());
            entries.push(ModelEntry {
                process: model.meta.process(),
                pool: Pool {
                    slots: vec![Slot::Free; width],
                    x: Tensor::zeros(&[width, dim]),
                    xprev: Tensor::zeros(&[width, dim]),
                    fifo: Vec::new(),
                    sched,
                },
                model,
            });
        }
        Ok(Registry { entries, by_name, cursor: 0 })
    }

    /// Pool index for a request's model name ("" = the default model).
    pub fn resolve(&self, name: &str) -> Result<usize> {
        if name.is_empty() {
            return Ok(0);
        }
        self.by_name.get(name).copied().ok_or_else(|| {
            let mut have: Vec<&str> = self.by_name.keys().map(|s| s.as_str()).collect();
            have.sort();
            anyhow!("unknown model '{name}' (serving: {have:?})")
        })
    }

    pub fn entries(&self) -> &[ModelEntry<'rt>] {
        &self.entries
    }

    pub fn entry_mut(&mut self, i: usize) -> &mut ModelEntry<'rt> {
        &mut self.entries[i]
    }

    /// Next pool with runnable or admissible work, scanning round-robin
    /// from the cursor; advances the cursor so pools take turns.
    pub fn next_runnable(&mut self) -> Option<usize> {
        let n = self.entries.len();
        for k in 0..n {
            let i = (self.cursor + k) % n;
            if !self.entries[i].pool.idle() {
                self.cursor = (i + 1) % n;
                return Some(i);
            }
        }
        None
    }

    pub fn all_idle(&self) -> bool {
        self.entries.iter().all(|e| e.pool.idle())
    }
}
