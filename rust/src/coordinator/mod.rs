//! The serving coordinator: continuous batching for adaptive-SDE
//! sampling (docs/ARCHITECTURE.md §Coordinator).
//!
//! The paper's §3.1.5 observation — every sample's reverse diffusion is
//! independent, so each keeps its own step size — is exactly what makes
//! diffusion sampling *continuously batchable*: a fixed-shape
//! `adaptive_step` executable advances a slot pool where every lane has
//! its own `(x, t, h, eps_rel)`; lanes that converge are denoised,
//! returned to their request, and immediately backfilled from the
//! admission queue. No request ever waits for another request's slowest
//! sample (the lockstep penalty the paper's batch solver pays).
//!
//! Five sub-layers (bottom up):
//! * `programs` — solver-program abstraction: a `LaneProgram` advances
//!   a pool of lanes under one compiled step artifact (`adaptive_step`,
//!   `em_step`, `ddim_step`, `pc_step`), owning per-lane state, device
//!   args and the completion predicate; every fixed-step solver is one
//!   descriptor-driven `FixedProgram` over the `StepKernel` table in
//!   `solvers::spec`;
//! * `scheduler` — occupancy-aware bucket selection: each iteration a
//!   pool runs at the smallest compiled width that fits its live +
//!   queued lanes, migrating lane state between widths so low-occupancy
//!   traffic stops paying full-width steps;
//! * `registry` — N models loaded from one artifacts dir, each with one
//!   pool per served solver program, routed by the request's
//!   (model, solver) pair;
//! * `qos` — admission control and service order: per-model quotas,
//!   priority classes, deadline shedding, and deficit-weighted
//!   round-robin over the flattened pool list (flat rotation at the
//!   default equal weights);
//! * `engine` — the thread that owns the PJRT runtime and runs the
//!   admit / rebucket / step loop over every pool.
//!
//! Ownership: PJRT handles are not Send, so the engine thread creates and
//! owns the `Runtime`; everything else talks to it via channels.

pub mod diagnostics;
pub mod engine;
pub(crate) mod eval;
pub(crate) mod programs;
pub mod qos;
pub(crate) mod registry;
pub mod scheduler;
pub mod telemetry;

pub use diagnostics::{
    DiagQuery, DiagReply, HealthEvent, HealthReply, HealthStats, PoolDiagSnapshot,
};
pub use engine::{
    CancelOutcome, Engine, EngineClient, EngineConfig, EngineStats, GenResult, ProgramStats,
};
pub use eval::{EvalRequest, EvalResult};
pub use qos::{ClassLatencyStats, PoolQosStats, Priority, QosConfig, Quota};
pub use scheduler::BucketScheduler;
pub use telemetry::{DispatchRecord, Span, SpanRing, TraceQuery, TraceReply};

use crate::solvers::ServingSolver;
use crate::tensor::Tensor;
use programs::LaneState;
use std::sync::mpsc;

/// A sampling request as admitted by the engine.
#[derive(Clone, Debug)]
pub struct SampleRequest {
    /// Model variant to sample from ("" = the engine's default model).
    pub model: String,
    /// Solver program the samples advance under (routes to the model's
    /// matching lane pool).
    pub solver: ServingSolver,
    pub n: usize,
    /// Adaptive tolerance knob (ignored by fixed-step solvers).
    pub eps_rel: f64,
    pub seed: u64,
    /// Global index of this request's first sample: lane `i` forks its
    /// RNG as `Rng::new(seed).fork(sample_base + i)`. Client generates
    /// use 0; evaluation chunks use their offset into the eval run so a
    /// chunked run draws the same per-sample streams as one big request.
    pub sample_base: u64,
    /// Priority class (`None` = the engine's configured default).
    /// Interactive requests are queued ahead of batch within a pool's
    /// FIFO; the class never changes a sample's content, only its wait.
    pub priority: Option<qos::Priority>,
    /// Optional deadline, milliseconds from enqueue. A request whose
    /// deadline expires while it is still fully queued (no sample in a
    /// lane yet) is shed with a `deadline_exceeded` error; once any
    /// sample holds a lane the request runs to completion.
    pub deadline_ms: Option<u64>,
    /// Opaque caller-chosen token `Msg::Cancel` matches on. The async
    /// job table stamps the job id here so a still-queued submission can
    /// be dequeued through the shed path; sync requests leave it `None`
    /// (uncancellable, as before).
    pub cancel_token: Option<u64>,
}

/// Engine mailbox messages.
pub(crate) enum Msg {
    Generate(SampleRequest, mpsc::Sender<Result<GenResult, String>>),
    Evaluate(EvalRequest, mpsc::Sender<Result<EvalResult, String>>),
    /// Dequeue the still-queued request carrying this `cancel_token`
    /// (engine::CancelOutcome reports queued/running/absent).
    Cancel(u64, mpsc::Sender<engine::CancelOutcome>),
    Stats(mpsc::Sender<EngineStats>),
    /// Snapshot the span ring (and optionally the runtime's dispatch
    /// timeline) for the `trace` wire op.
    Trace(telemetry::TraceQuery, mpsc::Sender<telemetry::TraceReply>),
    /// Snapshot per-pool solver diagnostics (profiles + sampled lane
    /// traces) for the `diag` wire op.
    Diag(diagnostics::DiagQuery, mpsc::Sender<diagnostics::DiagReply>),
    /// Snapshot the watchdog's health ring for the `health` wire op.
    Health(mpsc::Sender<diagnostics::HealthReply>),
    Shutdown,
}

/// Where a finished request's images go: back to a waiting client, or
/// into an in-engine evaluation job's feature accumulator.
pub(crate) enum Sink {
    Client(mpsc::Sender<Result<GenResult, String>>),
    Eval { job: u64, chunk: usize },
}

/// Per-request accumulation state while its samples move through slots.
pub(crate) struct Pending {
    pub req: SampleRequest,
    /// Resolved priority class (request field or the engine default).
    pub priority: qos::Priority,
    pub next_sample: usize,
    pub done: usize,
    pub images: Tensor, // [n, dim] unit-range, filled as samples finish
    pub nfe: Vec<u64>,
    pub sink: Sink,
    pub enqueued: std::time::Instant,
    pub started: Option<std::time::Instant>,
}

/// One lane of the continuous batch.
#[derive(Clone, Debug, Default)]
pub(crate) enum Slot {
    #[default]
    Free,
    Running {
        /// index into the engine's pending list (by request id)
        req_id: u64,
        sample_idx: usize,
        nfe: u64,
        rng: crate::rng::Rng,
        /// Program-specific integration state (see `programs`).
        state: LaneState,
    },
}

impl Slot {
    pub fn is_free(&self) -> bool {
        matches!(self, Slot::Free)
    }
}
