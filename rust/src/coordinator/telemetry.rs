//! Request-lifecycle tracing and dispatch timelines
//! (docs/ARCHITECTURE.md §Observability).
//!
//! Two bounded overwrite-oldest rings, both stamped against one
//! process-wide monotonic epoch so their timestamps land on a single
//! timeline (the Chrome-trace export interleaves them):
//!
//! * [`SpanRing`] — one [`Span`] per engine request (a client generate,
//!   an eval chunk, an async-job round), recording monotonic seconds at
//!   submit → admit (or reject, with code) → first lane grant → each
//!   dispatch batch → terminal outcome. Owned by the engine thread;
//!   when `EngineConfig::trace_ring` is 0 the engine holds `None` and
//!   the hot step path records nothing and allocates nothing.
//! * [`DispatchRing`] — one [`DispatchRecord`] per executable launch,
//!   its wall time split into argument upload / device execution /
//!   output download and tagged (model, program, bucket, k). Owned by
//!   the runtime behind a `RefCell`; disabled (empty capacity) unless
//!   the engine turns it on at startup.
//!
//! Both rings are fixed capacity: steady-state serving retains the
//! newest N entries with no growth. The only per-record allocations are
//! the label strings of the record itself, and those happen only while
//! the ring is enabled — the overhead contract `tools/check_trace.py`
//! gates (ring-on throughput ≥ 0.95× ring-off).

use crate::json::Value;
use std::collections::HashMap;
use std::sync::OnceLock;
use std::time::Instant;

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// The process-wide monotonic epoch every telemetry timestamp is
/// relative to. First caller pins it; the engine and runtime both
/// touch it at startup so serving-time stamps are far from zero.
pub fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Seconds since [`epoch`] (monotonic, f64).
pub fn now_s() -> f64 {
    epoch().elapsed().as_secs_f64()
}

/// Seconds from [`epoch`] to `t` (0 if `t` predates the epoch).
pub fn since_epoch(t: Instant) -> f64 {
    t.saturating_duration_since(epoch()).as_secs_f64()
}

/// What kind of work a span covers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Generate,
    Eval,
}

impl Kind {
    pub fn as_str(self) -> &'static str {
        match self {
            Kind::Generate => "generate",
            Kind::Eval => "eval",
        }
    }
}

/// How a request left the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// All samples finished and were delivered to the sink.
    Complete,
    /// Dequeued while still fully queued (client cancel).
    Canceled,
    /// Shed because its deadline expired while queued.
    Shed,
    /// Refused at admission (never queued); `code` says why.
    Rejected,
    /// Pool fault failed the request mid-flight.
    Failed,
}

impl Outcome {
    pub fn as_str(self) -> &'static str {
        match self {
            Outcome::Complete => "complete",
            Outcome::Canceled => "canceled",
            Outcome::Shed => "shed",
            Outcome::Rejected => "rejected",
            Outcome::Failed => "failed",
        }
    }
}

/// The lifecycle of one engine request. All timestamps are monotonic
/// seconds since [`epoch`]; unset stages are `None` (a rejected span
/// never admits, a queued-then-canceled span never dispatches).
#[derive(Clone, Debug)]
pub struct Span {
    /// Engine request id (also allocated for rejections, so rejected
    /// traffic is visible in the ring).
    pub id: u64,
    /// Async job id when the request came through the job table
    /// (`SampleRequest::cancel_token`) or an eval job's id.
    pub job: Option<u64>,
    pub model: String,
    pub solver: String,
    pub kind: Kind,
    pub n: usize,
    pub priority: &'static str,
    pub submit_s: f64,
    /// First lane grant (the request left the queue).
    pub admit_s: Option<f64>,
    /// Dispatch batches that advanced at least one of this request's
    /// lanes (one count per engine step, not per lane).
    pub dispatches: u64,
    pub first_dispatch_s: Option<f64>,
    pub last_dispatch_s: Option<f64>,
    pub end_s: Option<f64>,
    pub outcome: Option<Outcome>,
    /// Machine-readable error code for rejected/shed/failed spans.
    pub code: Option<String>,
}

impl Span {
    fn new(
        id: u64,
        job: Option<u64>,
        model: &str,
        solver: &str,
        kind: Kind,
        n: usize,
        priority: &'static str,
    ) -> Span {
        Span {
            id,
            job,
            model: model.to_string(),
            solver: solver.to_string(),
            kind,
            n,
            priority,
            submit_s: now_s(),
            admit_s: None,
            dispatches: 0,
            first_dispatch_s: None,
            last_dispatch_s: None,
            end_s: None,
            outcome: None,
            code: None,
        }
    }

    /// Queue wait: submit → first lane grant.
    pub fn queued_s(&self) -> Option<f64> {
        self.admit_s.map(|a| a - self.submit_s)
    }

    /// Execution: first lane grant → terminal outcome.
    pub fn exec_s(&self) -> Option<f64> {
        match (self.admit_s, self.end_s) {
            (Some(a), Some(e)) => Some(e - a),
            _ => None,
        }
    }

    /// End to end: submit → terminal outcome.
    pub fn e2e_s(&self) -> Option<f64> {
        self.end_s.map(|e| e - self.submit_s)
    }

    /// Wire shape of one span (`trace` op, `gofast trace`). Optional
    /// stages are emitted only when set, so a span's present keys tell
    /// the reader how far it got.
    pub fn to_json(&self) -> Value {
        let mut o = Value::obj(vec![
            ("id", Value::num(self.id as f64)),
            ("kind", Value::str(self.kind.as_str())),
            ("model", Value::str(&self.model)),
            ("solver", Value::str(&self.solver)),
            ("n", Value::num(self.n as f64)),
            ("priority", Value::str(self.priority)),
            ("submit_s", Value::num(self.submit_s)),
            ("dispatches", Value::num(self.dispatches as f64)),
        ]);
        if let Some(j) = self.job {
            o.set("job", Value::num(j as f64));
        }
        if let Some(a) = self.admit_s {
            o.set("admit_s", Value::num(a));
        }
        if let Some(t) = self.first_dispatch_s {
            o.set("first_dispatch_s", Value::num(t));
        }
        if let Some(t) = self.last_dispatch_s {
            o.set("last_dispatch_s", Value::num(t));
        }
        if let Some(e) = self.end_s {
            o.set("end_s", Value::num(e));
        }
        if let Some(out) = self.outcome {
            o.set("outcome", Value::str(out.as_str()));
        }
        if let Some(ref c) = self.code {
            o.set("code", Value::str(c.as_str()));
        }
        if let Some(q) = self.queued_s() {
            o.set("queued_s", Value::num(q));
        }
        if let Some(x) = self.exec_s() {
            o.set("exec_s", Value::num(x));
        }
        if let Some(e) = self.e2e_s() {
            o.set("e2e_s", Value::num(e));
        }
        o
    }
}

/// Query shape of the `trace` wire op: by request id, by job id, or the
/// last N spans in submit order. `timeline` additionally pulls the
/// runtime's dispatch-timeline ring.
#[derive(Clone, Copy, Debug, Default)]
pub struct TraceQuery {
    pub id: Option<u64>,
    pub job: Option<u64>,
    pub last: usize,
    pub timeline: bool,
}

/// Reply of the `trace` wire op / `EngineClient::trace`: matching
/// spans plus (when `TraceQuery::timeline`) the runtime's dispatch
/// timeline, both cloned out of the engine thread.
#[derive(Clone, Debug, Default)]
pub struct TraceReply {
    pub spans: Vec<Span>,
    pub timeline: Vec<DispatchRecord>,
}

/// Bounded per-server span ring: the newest `cap` requests, indexed by
/// request id for O(1) stage updates from the engine loop. Overwriting
/// an old span drops its id from the index, so a lookup never aliases
/// an evicted request.
pub struct SpanRing {
    spans: Vec<Span>,
    cap: usize,
    /// Next overwrite position once `spans` is full.
    cursor: usize,
    index: HashMap<u64, usize>,
}

impl SpanRing {
    pub fn new(cap: usize) -> SpanRing {
        assert!(cap > 0, "SpanRing capacity must be > 0 (use None to disable tracing)");
        epoch(); // pin the timeline origin at startup
        SpanRing { spans: Vec::with_capacity(cap), cap, cursor: 0, index: HashMap::new() }
    }

    fn push(&mut self, span: Span) {
        if self.spans.len() < self.cap {
            self.index.insert(span.id, self.spans.len());
            self.spans.push(span);
        } else {
            let old = &self.spans[self.cursor];
            self.index.remove(&old.id);
            self.index.insert(span.id, self.cursor);
            self.spans[self.cursor] = span;
            self.cursor = (self.cursor + 1) % self.cap;
        }
    }

    fn get_mut(&mut self, id: u64) -> Option<&mut Span> {
        self.index.get(&id).map(|&i| &mut self.spans[i])
    }

    /// A request entered the engine mailbox and was queued.
    #[allow(clippy::too_many_arguments)]
    pub fn on_submit(
        &mut self,
        id: u64,
        job: Option<u64>,
        model: &str,
        solver: &str,
        kind: Kind,
        n: usize,
        priority: &'static str,
    ) {
        self.push(Span::new(id, job, model, solver, kind, n, priority));
    }

    /// A request was refused at admission (quota, queue cap, bad
    /// solver…): one span carrying the rejection code, already ended.
    #[allow(clippy::too_many_arguments)]
    pub fn on_reject(
        &mut self,
        id: u64,
        job: Option<u64>,
        model: &str,
        solver: &str,
        kind: Kind,
        n: usize,
        priority: &'static str,
        code: &str,
    ) {
        let mut s = Span::new(id, job, model, solver, kind, n, priority);
        s.end_s = Some(s.submit_s);
        s.outcome = Some(Outcome::Rejected);
        s.code = Some(code.to_string());
        self.push(s);
    }

    /// First lane grant: the request's first sample left the queue.
    pub fn on_admit(&mut self, id: u64) {
        let t = now_s();
        if let Some(s) = self.get_mut(id) {
            if s.admit_s.is_none() {
                s.admit_s = Some(t);
            }
        }
    }

    /// A dispatch batch advanced at least one of the request's lanes.
    pub fn on_dispatch(&mut self, id: u64) {
        let t = now_s();
        if let Some(s) = self.get_mut(id) {
            s.dispatches += 1;
            if s.first_dispatch_s.is_none() {
                s.first_dispatch_s = Some(t);
            }
            s.last_dispatch_s = Some(t);
        }
    }

    /// Terminal stage. `code` is the machine-readable error code for
    /// shed/failed/canceled ends (None for clean completion).
    pub fn on_end(&mut self, id: u64, outcome: Outcome, code: Option<&str>) {
        let t = now_s();
        if let Some(s) = self.get_mut(id) {
            if s.end_s.is_none() {
                s.end_s = Some(t);
                s.outcome = Some(outcome);
                s.code = code.map(|c| c.to_string());
            }
        }
    }

    /// Spans matching `q`, in submit (id) order, cloned for the wire.
    pub fn query(&self, q: &TraceQuery) -> Vec<Span> {
        if let Some(id) = q.id {
            return self.index.get(&id).map(|&i| vec![self.spans[i].clone()]).unwrap_or_default();
        }
        let mut out: Vec<Span> = match q.job {
            Some(job) => self.spans.iter().filter(|s| s.job == Some(job)).cloned().collect(),
            None => self.spans.to_vec(),
        };
        out.sort_by_key(|s| s.id);
        let keep = if q.last == 0 { usize::MAX } else { q.last };
        if out.len() > keep {
            out.drain(..out.len() - keep);
        }
        out
    }

    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }
}

/// Fused-dispatch depth encoded in a step artifact's name
/// (`em_stepk8` → 8; anything unfused → 1) — the `k` tag of a
/// [`DispatchRecord`] without plumbing engine state into the runtime.
pub fn k_of(program: &str) -> usize {
    program
        .rsplit_once('k')
        .and_then(|(head, digits)| {
            if head.ends_with("step") && !digits.is_empty() {
                digits.parse().ok()
            } else {
                None
            }
        })
        .unwrap_or(1)
}

/// One executable launch on the runtime's timeline, wall time split
/// into the three phases the buffer path optimises (upload is ~0 for
/// device-resident lane state; download is 0 for `exec_device`, whose
/// output stays on device).
#[derive(Clone, Debug)]
pub struct DispatchRecord {
    /// Launch start, seconds since [`epoch`].
    pub start_s: f64,
    /// Argument staging/upload (host→device, incl. literal conversion).
    pub upload_s: f64,
    /// Device execution.
    pub exec_s: f64,
    /// Output transfer back to host (device→host).
    pub download_s: f64,
    pub model: String,
    pub program: String,
    pub bucket: usize,
    /// Fused steps per dispatch (1 unless a `*_stepk<k>` artifact).
    pub k: usize,
}

impl DispatchRecord {
    /// Wire/`--chrome` source shape of one launch.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("start_s", Value::num(self.start_s)),
            ("upload_s", Value::num(self.upload_s)),
            ("exec_s", Value::num(self.exec_s)),
            ("download_s", Value::num(self.download_s)),
            ("model", Value::str(&self.model)),
            ("program", Value::str(&self.program)),
            ("bucket", Value::num(self.bucket as f64)),
            ("k", Value::num(self.k as f64)),
        ])
    }
}

/// Bounded ring of the runtime's newest `cap` dispatches.
pub struct DispatchRing {
    recs: Vec<DispatchRecord>,
    cap: usize,
    cursor: usize,
}

impl DispatchRing {
    pub fn new(cap: usize) -> DispatchRing {
        assert!(cap > 0, "DispatchRing capacity must be > 0 (use None to disable)");
        epoch();
        DispatchRing { recs: Vec::with_capacity(cap), cap, cursor: 0 }
    }

    pub fn push(&mut self, rec: DispatchRecord) {
        if self.recs.len() < self.cap {
            self.recs.push(rec);
        } else {
            self.recs[self.cursor] = rec;
            self.cursor = (self.cursor + 1) % self.cap;
        }
    }

    /// Records oldest → newest (unwraps the ring).
    pub fn snapshot(&self) -> Vec<DispatchRecord> {
        let mut out = Vec::with_capacity(self.recs.len());
        out.extend_from_slice(&self.recs[self.cursor..]);
        out.extend_from_slice(&self.recs[..self.cursor]);
        out
    }

    pub fn len(&self) -> usize {
        self.recs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.recs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn submit(ring: &mut SpanRing, id: u64) {
        ring.on_submit(id, None, "vp", "adaptive", Kind::Generate, 4, "interactive");
    }

    #[test]
    fn lifecycle_is_monotonic_and_complete() {
        let mut ring = SpanRing::new(8);
        submit(&mut ring, 1);
        ring.on_admit(1);
        ring.on_dispatch(1);
        ring.on_dispatch(1);
        ring.on_end(1, Outcome::Complete, None);
        let s = &ring.query(&TraceQuery { id: Some(1), ..Default::default() })[0];
        let admit = s.admit_s.unwrap();
        let first = s.first_dispatch_s.unwrap();
        let last = s.last_dispatch_s.unwrap();
        let end = s.end_s.unwrap();
        assert!(s.submit_s <= admit && admit <= first && first <= last && last <= end);
        assert_eq!(s.dispatches, 2);
        assert_eq!(s.outcome, Some(Outcome::Complete));
        // queued + exec == e2e by construction (the invariant
        // tools/check_trace.py asserts over the wire)
        let sum = s.queued_s().unwrap() + s.exec_s().unwrap();
        assert!((sum - s.e2e_s().unwrap()).abs() < 1e-12);
    }

    #[test]
    fn reject_span_is_terminal_at_submit() {
        let mut ring = SpanRing::new(8);
        ring.on_reject(7, Some(3), "vp", "em:16", Kind::Generate, 2, "batch", "quota_exceeded");
        let s = &ring.query(&TraceQuery { id: Some(7), ..Default::default() })[0];
        assert_eq!(s.outcome, Some(Outcome::Rejected));
        assert_eq!(s.code.as_deref(), Some("quota_exceeded"));
        assert_eq!(s.end_s, Some(s.submit_s));
        assert!(s.admit_s.is_none());
    }

    #[test]
    fn ring_evicts_oldest_and_unindexes_it() {
        let mut ring = SpanRing::new(2);
        submit(&mut ring, 1);
        submit(&mut ring, 2);
        submit(&mut ring, 3); // evicts 1
        assert_eq!(ring.len(), 2);
        assert!(ring.query(&TraceQuery { id: Some(1), ..Default::default() }).is_empty());
        // a late stage update for the evicted id must be a no-op, not a
        // write into whatever span reused the slot
        ring.on_end(1, Outcome::Complete, None);
        let ids: Vec<u64> =
            ring.query(&TraceQuery { last: 0, ..Default::default() }).iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![2, 3]);
        assert!(ring.query(&TraceQuery { id: Some(3), ..Default::default() })[0].end_s.is_none());
    }

    #[test]
    fn query_by_job_and_last_n() {
        let mut ring = SpanRing::new(8);
        for id in 1..=5 {
            ring.on_submit(id, Some(id % 2), "vp", "adaptive", Kind::Generate, 1, "batch");
        }
        let job1: Vec<u64> = ring
            .query(&TraceQuery { job: Some(1), ..Default::default() })
            .iter()
            .map(|s| s.id)
            .collect();
        assert_eq!(job1, vec![1, 3, 5]);
        let last2: Vec<u64> =
            ring.query(&TraceQuery { last: 2, ..Default::default() }).iter().map(|s| s.id).collect();
        assert_eq!(last2, vec![4, 5]);
    }

    #[test]
    fn span_json_has_stage_keys_only_when_set() {
        let mut ring = SpanRing::new(2);
        submit(&mut ring, 1);
        let queued = ring.query(&TraceQuery { id: Some(1), ..Default::default() })[0].to_json();
        assert!(queued.get("admit_s").is_none());
        assert!(queued.get("outcome").is_none());
        ring.on_admit(1);
        ring.on_end(1, Outcome::Complete, None);
        let done = ring.query(&TraceQuery { id: Some(1), ..Default::default() })[0].to_json();
        assert_eq!(done.get("outcome").unwrap().as_str().unwrap(), "complete");
        assert!(done.get("queued_s").is_some() && done.get("e2e_s").is_some());
    }

    #[test]
    fn k_of_parses_fused_artifacts_only() {
        assert_eq!(k_of("em_stepk8"), 8);
        assert_eq!(k_of("pc_stepk4"), 4);
        assert_eq!(k_of("ddim_stepk16"), 16);
        assert_eq!(k_of("em_step"), 1);
        assert_eq!(k_of("adaptive_step"), 1);
        assert_eq!(k_of("score"), 1);
        assert_eq!(k_of("denoise"), 1);
    }

    #[test]
    fn dispatch_ring_wraps_in_order() {
        let mut ring = DispatchRing::new(3);
        for i in 0..5 {
            ring.push(DispatchRecord {
                start_s: i as f64,
                upload_s: 0.0,
                exec_s: 0.0,
                download_s: 0.0,
                model: "vp".into(),
                program: "em_step".into(),
                bucket: 16,
                k: 1,
            });
        }
        let starts: Vec<f64> = ring.snapshot().iter().map(|r| r.start_s).collect();
        assert_eq!(starts, vec![2.0, 3.0, 4.0]);
    }
}
