//! Solver numerical diagnostics + engine health watchdog.
//!
//! Two layers, both owned by the engine thread (no locks, no extra
//! threads — the watchdog piggybacks on the engine loop):
//!
//! * **Per-pool profiles** ([`PoolProfile`]): a fixed
//!   [`PROFILE_BINS`]-bin grid over diffusion time `[t_eps, 1]`
//!   accumulating, per bin, step-size and error-norm statistics plus
//!   Algorithm 1 accept/reject counts (adaptive pools) or grid-node
//!   counts (fixed-step pools, which record steps-per-bin only). The
//!   bin array is allocated once at pool creation and `record_*`
//!   writes plain fields — the always-on cost is a few float ops per
//!   lane step, the same class as `Histogram::record`.
//! * **Sampled lane traces** ([`PoolDiag`]): with `serve
//!   --diag-sample N`, every Nth admitted lane records its full
//!   `(t, h, err, accepted)` sequence into a bounded ring. `0` (the
//!   default) disables sampling and the per-step path touches only
//!   the fixed profile — no allocation, the same overhead contract as
//!   `--trace-ring 0`.
//!
//! The [`Watchdog`] runs a periodic check over state the engine
//! already owns: stalled lanes (no progress for `stall_budget_s`),
//! reject-rate spikes against a per-pool EWMA baseline, admission
//! queue saturation, and step-time p95 drift. Events land in a
//! bounded ring plus per-kind counters, exported as the
//! `gofast_health_status` gauge and `gofast_health_events_total{kind}`
//! counters through the stats tree and as the `health` wire op.

use crate::json::Value;

/// Diffusion-time bins per pool profile.
pub const PROFILE_BINS: usize = 32;

/// Sampled lane traces retained per pool (ring; oldest evicted).
pub const TRACE_RING_CAP: usize = 256;

/// Per-trace step cap — an adaptive lane grinding at tiny `h` must not
/// grow a sampled trace without bound; the head of the sequence is the
/// diagnostic payload.
const TRACE_MAX_STEPS: usize = 4096;

// --- per-pool profiles ----------------------------------------------------------

/// One diffusion-time bin's accumulators. `h_*`/`err_*` cover adaptive
/// proposals only; `steps` counts fixed-grid nodes.
#[derive(Clone, Copy, Debug)]
pub struct BinStat {
    /// Fixed-step grid nodes that landed in the bin.
    pub steps: u64,
    /// Adaptive proposals accepted / rejected in the bin.
    pub accepted: u64,
    pub rejected: u64,
    h_sum: f64,
    h_min: f64,
    h_max: f64,
    err_sum: f64,
    err_max: f64,
}

impl BinStat {
    const EMPTY: BinStat = BinStat {
        steps: 0,
        accepted: 0,
        rejected: 0,
        h_sum: 0.0,
        h_min: f64::INFINITY,
        h_max: 0.0,
        err_sum: 0.0,
        err_max: 0.0,
    };

    fn proposals(&self) -> u64 {
        self.accepted + self.rejected
    }

    fn to_json(&self, t_lo: f64, t_hi: f64) -> Value {
        let n = self.proposals() as f64;
        let mean = |sum: f64| if n > 0.0 { sum / n } else { 0.0 };
        Value::obj(vec![
            ("t_lo", Value::num(t_lo)),
            ("t_hi", Value::num(t_hi)),
            ("steps", Value::num(self.steps as f64)),
            ("accepted", Value::num(self.accepted as f64)),
            ("rejected", Value::num(self.rejected as f64)),
            ("h_mean", Value::num(mean(self.h_sum))),
            ("h_min", Value::num(if n > 0.0 { self.h_min } else { 0.0 })),
            ("h_max", Value::num(self.h_max)),
            ("err_mean", Value::num(mean(self.err_sum))),
            ("err_max", Value::num(self.err_max)),
        ])
    }
}

/// Fixed diffusion-time grid over `[t_eps, 1]`: where in the reverse
/// SDE the solver spends its NFE budget, and how Algorithm 1's step
/// test behaves there.
#[derive(Clone, Debug)]
pub struct PoolProfile {
    t_lo: f64,
    t_hi: f64,
    bins: [BinStat; PROFILE_BINS],
}

impl PoolProfile {
    pub fn new(t_eps: f64) -> PoolProfile {
        PoolProfile {
            t_lo: t_eps.clamp(0.0, 0.999),
            t_hi: 1.0,
            bins: [BinStat::EMPTY; PROFILE_BINS],
        }
    }

    /// Bin index for diffusion time `t` (clamped to the grid).
    pub fn bin_of(&self, t: f64) -> usize {
        let frac = (t - self.t_lo) / (self.t_hi - self.t_lo);
        ((frac * PROFILE_BINS as f64) as isize).clamp(0, PROFILE_BINS as isize - 1) as usize
    }

    /// One adaptive proposal at pre-step `(t, h)` with error norm
    /// `err` and its accept/reject outcome.
    pub fn record_adaptive(&mut self, t: f64, h: f64, err: f64, accepted: bool) {
        let b = &mut self.bins[self.bin_of(t)];
        if accepted {
            b.accepted += 1;
        } else {
            b.rejected += 1;
        }
        b.h_sum += h;
        b.h_min = b.h_min.min(h);
        b.h_max = b.h_max.max(h);
        b.err_sum += err;
        b.err_max = b.err_max.max(err);
    }

    /// One fixed-grid node at diffusion time `t`.
    pub fn record_fixed(&mut self, t: f64) {
        self.bins[self.bin_of(t)].steps += 1;
    }

    /// `(steps, accepted, rejected)` summed over all bins — the
    /// reconciliation surface against the pool's stats counters.
    pub fn totals(&self) -> (u64, u64, u64) {
        self.bins.iter().fold((0, 0, 0), |(s, a, r), b| {
            (s + b.steps, a + b.accepted, r + b.rejected)
        })
    }

    pub fn bins(&self) -> &[BinStat] {
        &self.bins
    }
}

// --- sampled lane traces --------------------------------------------------------

/// One recorded solver step of a sampled lane.
#[derive(Clone, Copy, Debug)]
pub struct LaneStep {
    pub t: f64,
    pub h: f64,
    /// Algorithm 1 mixed-tolerance error norm (0 for fixed-step lanes).
    pub err: f64,
    pub accepted: bool,
}

/// The full step sequence of one sampled lane.
#[derive(Clone, Debug)]
pub struct LaneTrace {
    /// Engine request id of the lane (the `trace` op's span id space).
    pub req_id: u64,
    pub sample_idx: usize,
    /// The lane finished (converged, failed, or its pool was reset).
    pub done: bool,
    pub steps: Vec<LaneStep>,
}

impl LaneTrace {
    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("lane", Value::num(self.req_id as f64)),
            ("sample", Value::num(self.sample_idx as f64)),
            ("done", Value::Bool(self.done)),
            (
                "steps",
                Value::Arr(
                    self.steps
                        .iter()
                        .map(|s| {
                            Value::obj(vec![
                                ("t", Value::num(s.t)),
                                ("h", Value::num(s.h)),
                                ("err", Value::num(s.err)),
                                ("accepted", Value::Bool(s.accepted)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Per-pool diagnostics: the always-on profile plus the 1-in-N lane
/// trace sampler. Owned by `ProgramPool`, fed from the lane programs'
/// step folds through `StepIo`.
#[derive(Clone, Debug)]
pub struct PoolDiag {
    pub profile: PoolProfile,
    /// 1-in-N admission sampling; 0 disables lane traces entirely.
    sample_every: usize,
    admitted: u64,
    /// Ring position of the open trace per lane slot (None = unsampled).
    slot_trace: Vec<Option<usize>>,
    traces: Vec<LaneTrace>,
    cursor: usize,
    cap: usize,
}

impl PoolDiag {
    pub fn new(t_eps: f64, width: usize, sample_every: usize) -> PoolDiag {
        PoolDiag::with_cap(t_eps, width, sample_every, TRACE_RING_CAP)
    }

    fn with_cap(t_eps: f64, width: usize, sample_every: usize, cap: usize) -> PoolDiag {
        PoolDiag {
            profile: PoolProfile::new(t_eps),
            sample_every,
            admitted: 0,
            slot_trace: vec![None; width],
            traces: Vec::new(),
            cursor: 0,
            cap: cap.max(1),
        }
    }

    /// Admission hook: decides whether this lane is sampled (every Nth
    /// admitted lane) and opens its trace. No-op when sampling is off.
    pub fn on_lane_start(&mut self, slot: usize, req_id: u64, sample_idx: usize) {
        if self.sample_every == 0 {
            return;
        }
        let pick = self.admitted % self.sample_every as u64 == 0;
        self.admitted += 1;
        if !pick {
            self.slot_trace[slot] = None;
            return;
        }
        let trace = LaneTrace { req_id, sample_idx, done: false, steps: Vec::new() };
        let pos = if self.traces.len() < self.cap {
            self.traces.push(trace);
            self.traces.len() - 1
        } else {
            let pos = self.cursor;
            self.cursor = (pos + 1) % self.cap;
            // the evicted record may belong to a still-running lane —
            // that lane stops being sampled rather than appending its
            // tail to the newcomer's trace
            for s in &mut self.slot_trace {
                if *s == Some(pos) {
                    *s = None;
                }
            }
            self.traces[pos] = trace;
            pos
        };
        self.slot_trace[slot] = Some(pos);
    }

    /// Bucket-migration hook: `migrate_lanes` compacts live lanes into
    /// new slot positions, so open trace markers must follow their
    /// lanes. Re-derives the slot -> trace mapping from the migrated
    /// slot array by `(req_id, sample_idx)` identity. No-op (and
    /// allocation-free) with sampling off.
    pub(crate) fn remap(&mut self, slots: &[super::Slot]) {
        if self.sample_every == 0 {
            return;
        }
        let open: Vec<(usize, u64, usize)> = self
            .slot_trace
            .iter()
            .flatten()
            .map(|&pos| (pos, self.traces[pos].req_id, self.traces[pos].sample_idx))
            .collect();
        self.slot_trace.iter_mut().for_each(|s| *s = None);
        for (si, slot) in slots.iter().enumerate() {
            if let super::Slot::Running { req_id, sample_idx, .. } = slot {
                if let Some(&(pos, _, _)) =
                    open.iter().find(|&&(_, r, sx)| r == *req_id && sx == *sample_idx)
                {
                    self.slot_trace[si] = Some(pos);
                }
            }
        }
    }

    /// Lane completion hook (converged, failed, or reset).
    pub fn on_lane_end(&mut self, slot: usize) {
        if let Some(pos) = self.slot_trace[slot].take() {
            self.traces[pos].done = true;
        }
    }

    /// Pool reset (`fail_pool`): every open trace ends truncated.
    pub fn clear_slots(&mut self) {
        for slot in 0..self.slot_trace.len() {
            self.on_lane_end(slot);
        }
    }

    /// Adaptive proposal on lane `slot` — profile always, trace only
    /// when the slot is sampled.
    pub fn record_adaptive(&mut self, slot: usize, t: f64, h: f64, err: f64, accepted: bool) {
        self.profile.record_adaptive(t, h, err, accepted);
        if let Some(pos) = self.slot_trace[slot] {
            let steps = &mut self.traces[pos].steps;
            if steps.len() < TRACE_MAX_STEPS {
                steps.push(LaneStep { t, h, err, accepted });
            }
        }
    }

    /// Fixed-grid node on lane `slot` (steps-per-bin in the profile;
    /// sampled traces record the node with `err = 0`, accepted).
    pub fn record_fixed(&mut self, slot: usize, t: f64, h: f64) {
        self.profile.record_fixed(t);
        if let Some(pos) = self.slot_trace[slot] {
            let steps = &mut self.traces[pos].steps;
            if steps.len() < TRACE_MAX_STEPS {
                steps.push(LaneStep { t, h, err: 0.0, accepted: true });
            }
        }
    }

    /// Retained traces, oldest first.
    fn traces_in_order(&self) -> impl Iterator<Item = &LaneTrace> {
        let n = self.traces.len();
        let start = if n < self.cap { 0 } else { self.cursor };
        (0..n).map(move |i| &self.traces[(start + i) % n.max(1)])
    }

    /// Snapshot for the `diag` op; `lane` filters traces by request id.
    pub fn snapshot(
        &self,
        model: &str,
        solver: &str,
        adaptive: bool,
        lane: Option<u64>,
    ) -> PoolDiagSnapshot {
        PoolDiagSnapshot {
            model: model.to_string(),
            solver: solver.to_string(),
            adaptive,
            t_lo: self.profile.t_lo,
            t_hi: self.profile.t_hi,
            bins: self.profile.bins.to_vec(),
            traces: self
                .traces_in_order()
                .filter(|t| lane.is_none_or(|id| t.req_id == id))
                .cloned()
                .collect(),
        }
    }
}

/// Query for the `diag` op: optional `model/solver` (or `model:solver`)
/// pool filter and optional lane (request id) trace filter.
#[derive(Clone, Debug, Default)]
pub struct DiagQuery {
    pub pool: Option<String>,
    pub lane: Option<u64>,
}

impl DiagQuery {
    /// Pool filter match; accepts both `model/solver` and
    /// `model:solver` spellings.
    pub fn matches_pool(&self, model: &str, solver: &str) -> bool {
        match &self.pool {
            None => true,
            Some(p) => {
                let want = p.replace(':', "/");
                want == format!("{model}/{solver}")
            }
        }
    }
}

/// One pool's diagnostics snapshot (profile + retained lane traces).
#[derive(Clone, Debug)]
pub struct PoolDiagSnapshot {
    pub model: String,
    pub solver: String,
    pub adaptive: bool,
    pub t_lo: f64,
    pub t_hi: f64,
    pub bins: Vec<BinStat>,
    pub traces: Vec<LaneTrace>,
}

impl PoolDiagSnapshot {
    pub fn to_json(&self) -> Value {
        let w = (self.t_hi - self.t_lo) / PROFILE_BINS as f64;
        Value::obj(vec![
            ("model", Value::str(self.model.clone())),
            ("solver", Value::str(self.solver.clone())),
            ("adaptive", Value::Bool(self.adaptive)),
            ("t_lo", Value::num(self.t_lo)),
            ("t_hi", Value::num(self.t_hi)),
            (
                "bins",
                Value::Arr(
                    self.bins
                        .iter()
                        .enumerate()
                        .map(|(i, b)| {
                            b.to_json(self.t_lo + i as f64 * w, self.t_lo + (i + 1) as f64 * w)
                        })
                        .collect(),
                ),
            ),
            ("traces", Value::Arr(self.traces.iter().map(|t| t.to_json()).collect())),
        ])
    }
}

/// Reply to the `diag` op.
#[derive(Clone, Debug, Default)]
pub struct DiagReply {
    pub pools: Vec<PoolDiagSnapshot>,
}

// --- watchdog -------------------------------------------------------------------

/// Health event kinds, in counter order (`kind` label values).
pub const HEALTH_KINDS: [&str; 4] =
    ["stall", "reject_spike", "queue_saturation", "step_time_drift"];

const HEALTH_RING_CAP: usize = 256;
/// Reject-rate windows need at least this many proposals to judge.
const REJECT_MIN_PROPOSALS: u64 = 8;
/// EWMA smoothing for the reject-rate and p95 baselines.
const EWMA_ALPHA: f64 = 0.2;
/// A window's reject rate must exceed `2x baseline + margin` to fire.
const REJECT_SPIKE_MARGIN: f64 = 0.10;
/// Queued samples >= this fraction of the admission cap fires.
const QUEUE_SATURATION_FRAC: f64 = 0.9;
/// Step-time p95 must exceed `2x baseline` (and this floor) to fire.
const DRIFT_FACTOR: f64 = 2.0;
const DRIFT_FLOOR_S: f64 = 1e-4;

/// One structured health event (ring-retained, counter-counted).
#[derive(Clone, Debug)]
pub struct HealthEvent {
    /// Seconds on the telemetry epoch (same clock as trace spans).
    pub at_s: f64,
    pub kind: &'static str,
    /// Pool labels; empty for engine-level events (queue saturation).
    pub model: String,
    pub solver: String,
    pub detail: String,
}

impl HealthEvent {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("at_s", Value::num(self.at_s)),
            ("kind", Value::str(self.kind)),
            ("model", Value::str(self.model.clone())),
            ("solver", Value::str(self.solver.clone())),
            ("detail", Value::str(self.detail.clone())),
        ])
    }
}

/// Per-tick pool observation the engine hands the watchdog (cumulative
/// counters; the watchdog differences them against the previous tick).
pub struct PoolHealthSample {
    pub adaptive: bool,
    pub accepted: u64,
    pub rejected: u64,
    pub step_p95_s: f64,
    pub step_count: u64,
}

#[derive(Clone, Debug, Default)]
struct PoolHealth {
    /// Per slot: (progress scalar, wall time it last changed).
    lanes: Vec<Option<(f64, f64)>>,
    reject_ewma: f64,
    reject_primed: bool,
    last_accepted: u64,
    last_rejected: u64,
    p95_ewma: f64,
    p95_primed: bool,
    last_step_count: u64,
}

/// Reply to the `health` op.
#[derive(Clone, Debug, Default)]
pub struct HealthReply {
    /// 1 = healthy, 0 = degraded (an event fired on the last tick).
    pub status: u64,
    /// Retained events, oldest first.
    pub events: Vec<HealthEvent>,
    /// Cumulative per-kind counters (every kind, zeros included).
    pub counts: Vec<(String, u64)>,
}

/// Health summary carried on `EngineStats` into the stats tree.
#[derive(Clone, Debug, Default)]
pub struct HealthStats {
    /// 1 = healthy, 0 = degraded.
    pub status: u64,
    pub counts: Vec<(String, u64)>,
}

/// Periodic engine-health checks over state the engine already owns.
/// The engine calls `begin_tick`, then `check_queue` once and
/// `tick_pool` per pool (flat service order), then `end_tick`.
pub struct Watchdog {
    stall_budget_s: f64,
    pools: Vec<PoolHealth>,
    events: Vec<HealthEvent>,
    cursor: usize,
    counts: [u64; HEALTH_KINDS.len()],
    tick_fired: bool,
    degraded: bool,
    pub last_tick_s: f64,
}

impl Watchdog {
    /// `widths[flat]` = lane count of each pool in flat service order.
    pub fn new(widths: &[usize], stall_budget_s: f64) -> Watchdog {
        Watchdog {
            stall_budget_s,
            pools: widths
                .iter()
                .map(|&w| PoolHealth { lanes: vec![None; w], ..Default::default() })
                .collect(),
            events: Vec::new(),
            cursor: 0,
            counts: [0; HEALTH_KINDS.len()],
            tick_fired: false,
            degraded: false,
            last_tick_s: 0.0,
        }
    }

    pub fn begin_tick(&mut self) {
        self.tick_fired = false;
    }

    /// Engine-level admission-queue saturation check.
    pub fn check_queue(&mut self, queued: usize, cap: usize, now: f64) {
        if cap > 0 && queued as f64 >= cap as f64 * QUEUE_SATURATION_FRAC {
            self.push_event(
                2,
                "",
                "",
                format!("queued samples {queued} >= {QUEUE_SATURATION_FRAC} x cap {cap}"),
                now,
            );
        }
    }

    /// Per-pool checks. `lanes` lists occupied slots in ascending slot
    /// order with a monotone progress scalar (adaptive: remaining `t`;
    /// fixed: nodes done) that changes on every real step.
    pub fn tick_pool(
        &mut self,
        flat: usize,
        model: &str,
        solver: &str,
        lanes: &[(usize, f64)],
        s: &PoolHealthSample,
        now: f64,
    ) {
        let budget = self.stall_budget_s;
        let ph = &mut self.pools[flat];
        let mut stalled: Vec<usize> = Vec::new();
        let mut it = lanes.iter().peekable();
        for (si, entry) in ph.lanes.iter_mut().enumerate() {
            match it.peek() {
                Some(&&(slot, progress)) if slot == si => {
                    it.next();
                    match entry {
                        Some((last, changed_at)) if *last == progress => {
                            if now - *changed_at > budget {
                                stalled.push(si);
                                *changed_at = now; // re-arm
                            }
                        }
                        _ => *entry = Some((progress, now)),
                    }
                }
                _ => *entry = None, // slot freed
            }
        }

        // reject-rate spike: this tick's window vs the EWMA baseline
        let mut spike: Option<String> = None;
        if s.adaptive {
            let (da, dr) =
                (s.accepted - ph.last_accepted, s.rejected - ph.last_rejected);
            ph.last_accepted = s.accepted;
            ph.last_rejected = s.rejected;
            if da + dr >= REJECT_MIN_PROPOSALS {
                let rate = dr as f64 / (da + dr) as f64;
                if ph.reject_primed
                    && rate > DRIFT_FACTOR * ph.reject_ewma + REJECT_SPIKE_MARGIN
                {
                    spike = Some(format!(
                        "reject rate {rate:.3} vs baseline {:.3} ({} of {} proposals)",
                        ph.reject_ewma,
                        dr,
                        da + dr
                    ));
                }
                ph.reject_ewma = if ph.reject_primed {
                    (1.0 - EWMA_ALPHA) * ph.reject_ewma + EWMA_ALPHA * rate
                } else {
                    rate
                };
                ph.reject_primed = true;
            }
        }

        // step-time p95 drift: only when new dispatches landed
        let mut drift: Option<String> = None;
        if s.step_count > ph.last_step_count {
            ph.last_step_count = s.step_count;
            let p95 = s.step_p95_s;
            if ph.p95_primed && p95 > DRIFT_FACTOR * ph.p95_ewma && p95 > DRIFT_FLOOR_S {
                drift = Some(format!(
                    "step p95 {:.1}ms vs baseline {:.1}ms",
                    p95 * 1e3,
                    ph.p95_ewma * 1e3
                ));
            }
            ph.p95_ewma = if ph.p95_primed {
                (1.0 - EWMA_ALPHA) * ph.p95_ewma + EWMA_ALPHA * p95
            } else {
                p95
            };
            ph.p95_primed = true;
        }

        for si in stalled {
            let budget_ms = budget * 1e3;
            self.push_event(
                0,
                model,
                solver,
                format!("lane {si}: no progress for > {budget_ms:.0}ms"),
                now,
            );
        }
        if let Some(d) = spike {
            self.push_event(1, model, solver, d, now);
        }
        if let Some(d) = drift {
            self.push_event(3, model, solver, d, now);
        }
    }

    pub fn end_tick(&mut self, now: f64) {
        self.degraded = self.tick_fired;
        self.last_tick_s = now;
    }

    fn push_event(&mut self, kind: usize, model: &str, solver: &str, detail: String, now: f64) {
        let ev = HealthEvent {
            at_s: now,
            kind: HEALTH_KINDS[kind],
            model: model.to_string(),
            solver: solver.to_string(),
            detail,
        };
        if self.events.len() < HEALTH_RING_CAP {
            self.events.push(ev);
        } else {
            self.events[self.cursor] = ev;
            self.cursor = (self.cursor + 1) % HEALTH_RING_CAP;
        }
        self.counts[kind] += 1;
        self.tick_fired = true;
    }

    /// 1 = healthy, 0 = degraded on the last completed tick.
    pub fn status(&self) -> u64 {
        if self.degraded {
            0
        } else {
            1
        }
    }

    fn counts_vec(&self) -> Vec<(String, u64)> {
        HEALTH_KINDS
            .iter()
            .zip(self.counts.iter())
            .map(|(k, &n)| (k.to_string(), n))
            .collect()
    }

    /// Snapshot for the `health` op (events oldest first).
    pub fn snapshot(&self) -> HealthReply {
        let n = self.events.len();
        let start = if n < HEALTH_RING_CAP { 0 } else { self.cursor };
        HealthReply {
            status: self.status(),
            events: (0..n).map(|i| self.events[(start + i) % n.max(1)].clone()).collect(),
            counts: self.counts_vec(),
        }
    }

    /// Summary carried on `EngineStats` into the stats tree.
    pub fn stats(&self) -> HealthStats {
        HealthStats { status: self.status(), counts: self.counts_vec() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bin_grid_is_monotone_and_clamped() {
        let p = PoolProfile::new(0.01);
        assert_eq!(p.bin_of(0.01), 0);
        assert_eq!(p.bin_of(1.0), PROFILE_BINS - 1);
        assert_eq!(p.bin_of(-5.0), 0);
        assert_eq!(p.bin_of(5.0), PROFILE_BINS - 1);
        let mut last = 0;
        for i in 0..=1000 {
            let t = 0.01 + (1.0 - 0.01) * i as f64 / 1000.0;
            let b = p.bin_of(t);
            assert!(b >= last, "bin_of not monotone at t={t}");
            last = b;
        }
    }

    #[test]
    fn adaptive_totals_reconcile_with_bin_sums() {
        let mut p = PoolProfile::new(0.01);
        let (mut acc, mut rej) = (0u64, 0u64);
        for i in 0..500 {
            let t = 0.01 + 0.99 * (i as f64 / 500.0);
            let accepted = i % 3 != 0;
            p.record_adaptive(t, 0.02, 0.5, accepted);
            if accepted {
                acc += 1;
            } else {
                rej += 1;
            }
        }
        let (steps, a, r) = p.totals();
        assert_eq!((steps, a, r), (0, acc, rej));
        let bin_sum: u64 = p.bins().iter().map(|b| b.accepted + b.rejected).sum();
        assert_eq!(bin_sum, acc + rej);
    }

    #[test]
    fn sampling_cadence_is_one_in_n() {
        let mut d = PoolDiag::new(0.01, 4, 2);
        for i in 0..8 {
            d.on_lane_start(i % 4, 100 + i as u64, 0);
            d.on_lane_end(i % 4);
        }
        assert_eq!(d.snapshot("m", "s", true, None).traces.len(), 4);
        // sampling off: no traces, no admitted accounting
        let mut off = PoolDiag::new(0.01, 4, 0);
        for i in 0..8 {
            off.on_lane_start(i % 4, i as u64, 0);
            off.record_adaptive(i % 4, 0.5, 0.02, 0.3, true);
        }
        assert!(off.snapshot("m", "s", true, None).traces.is_empty());
        assert_eq!(off.profile.totals(), (0, 8, 0));
    }

    #[test]
    fn trace_ring_evicts_oldest_and_unmarks_live_slot() {
        let mut d = PoolDiag::with_cap(0.01, 2, 1, 2);
        d.on_lane_start(0, 1, 0); // pos 0, still running
        d.on_lane_start(1, 2, 0); // pos 1
        d.on_lane_end(1);
        d.on_lane_start(1, 3, 0); // evicts pos 0 (lane 1's trace)
        // lane in slot 0 lost its record: recording must not leak into
        // the newcomer that reused its ring position
        d.record_adaptive(0, 0.5, 0.02, 0.3, true);
        let snap = d.snapshot("m", "s", true, None);
        let ids: Vec<u64> = snap.traces.iter().map(|t| t.req_id).collect();
        assert_eq!(ids, vec![2, 3]);
        assert!(snap.traces.iter().all(|t| t.steps.is_empty()));
        assert!(snap.traces.iter().find(|t| t.req_id == 3).is_some_and(|t| !t.done));
        // evicted-id queries return empty, not stale records
        assert!(d.snapshot("m", "s", true, Some(1)).traces.is_empty());
    }

    #[test]
    fn sampled_lane_records_steps_and_lane_filter_works() {
        let mut d = PoolDiag::new(0.01, 2, 1);
        d.on_lane_start(0, 7, 3);
        d.record_adaptive(0, 0.9, 0.05, 0.8, false);
        d.record_adaptive(0, 0.9, 0.02, 0.4, true);
        d.on_lane_end(0);
        let snap = d.snapshot("m", "s", true, Some(7));
        assert_eq!(snap.traces.len(), 1);
        let t = &snap.traces[0];
        assert!(t.done && t.sample_idx == 3);
        assert_eq!(t.steps.len(), 2);
        assert!(!t.steps[0].accepted && t.steps[1].accepted);
        assert!(d.snapshot("m", "s", true, Some(8)).traces.is_empty());
    }

    #[test]
    fn watchdog_fires_stall_after_budget_and_recovers() {
        let mut w = Watchdog::new(&[2], 0.5);
        let quiet = PoolHealthSample {
            adaptive: true,
            accepted: 0,
            rejected: 0,
            step_p95_s: 0.0,
            step_count: 0,
        };
        w.begin_tick();
        w.tick_pool(0, "vp", "adaptive", &[(0, 0.9)], &quiet, 0.0);
        w.end_tick(0.0);
        assert_eq!(w.status(), 1);
        // same progress 1s later: budget exceeded
        w.begin_tick();
        w.tick_pool(0, "vp", "adaptive", &[(0, 0.9)], &quiet, 1.0);
        w.end_tick(1.0);
        assert_eq!(w.status(), 0);
        let r = w.snapshot();
        assert_eq!(r.events.len(), 1);
        assert_eq!(r.events[0].kind, "stall");
        assert_eq!(r.counts.iter().find(|(k, _)| k == "stall").unwrap().1, 1);
        // progress resumes: healthy again, counter retained
        w.begin_tick();
        w.tick_pool(0, "vp", "adaptive", &[(0, 0.7)], &quiet, 1.1);
        w.end_tick(1.1);
        assert_eq!(w.status(), 1);
        assert_eq!(w.snapshot().counts.iter().find(|(k, _)| k == "stall").unwrap().1, 1);
    }

    #[test]
    fn watchdog_reject_spike_vs_ewma_baseline() {
        let mut w = Watchdog::new(&[1], 10.0);
        let s = |a, r| PoolHealthSample {
            adaptive: true,
            accepted: a,
            rejected: r,
            step_p95_s: 0.0,
            step_count: 0,
        };
        w.begin_tick();
        w.tick_pool(0, "vp", "adaptive", &[], &s(90, 10), 0.0); // primes baseline at 0.1
        w.end_tick(0.0);
        assert_eq!(w.status(), 1);
        w.begin_tick();
        w.tick_pool(0, "vp", "adaptive", &[], &s(100, 30), 1.0); // window rate 0.667
        w.end_tick(1.0);
        assert_eq!(w.status(), 0);
        assert_eq!(w.snapshot().events.last().unwrap().kind, "reject_spike");
    }

    #[test]
    fn watchdog_queue_saturation_is_engine_level() {
        let mut w = Watchdog::new(&[1], 10.0);
        w.begin_tick();
        w.check_queue(100, 4096, 0.0);
        w.end_tick(0.0);
        assert_eq!(w.status(), 1);
        w.begin_tick();
        w.check_queue(4000, 4096, 1.0);
        w.end_tick(1.0);
        assert_eq!(w.status(), 0);
        let ev = w.snapshot().events.last().unwrap().clone();
        assert_eq!(ev.kind, "queue_saturation");
        assert!(ev.model.is_empty());
    }

    #[test]
    fn watchdog_step_time_drift_needs_new_dispatches() {
        let mut w = Watchdog::new(&[1], 10.0);
        let s = |p95, count| PoolHealthSample {
            adaptive: false,
            accepted: 0,
            rejected: 0,
            step_p95_s: p95,
            step_count: count,
        };
        w.begin_tick();
        w.tick_pool(0, "vp", "em", &[], &s(0.001, 10), 0.0); // primes baseline
        w.end_tick(0.0);
        w.begin_tick();
        w.tick_pool(0, "vp", "em", &[], &s(0.01, 10), 1.0); // no new dispatches
        w.end_tick(1.0);
        assert_eq!(w.status(), 1);
        w.begin_tick();
        w.tick_pool(0, "vp", "em", &[], &s(0.01, 20), 2.0);
        w.end_tick(2.0);
        assert_eq!(w.status(), 0);
        assert_eq!(w.snapshot().events.last().unwrap().kind, "step_time_drift");
    }
}
