//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them on the CPU PJRT client, and
//! executes them from the serving hot path.
//!
//! Two execution paths per program (docs/ARCHITECTURE.md §Runtime
//! describes both and the perf methodology):
//! * **literal path** (baseline) — every argument including the full
//!   parameter vector is re-uploaded per call;
//! * **buffer path** (optimised) — `theta` is uploaded once per model and
//!   kept device-resident for every `(program, bucket)` — a bucket
//!   switch never re-uploads parameters; per-step tensors are staged as
//!   `PjRtBuffer`s, and step constants (`ExecArg::Const`) are staged
//!   once per `(model, tag, bucket)` and reused across steps.
//!
//! PJRT handles are not `Send`; the `Runtime` is owned by a single engine
//! thread (see `coordinator::engine`), everything else talks to it over
//! channels — the same ownership discipline vLLM applies to its worker.

mod literal_util;

pub use literal_util::{literal_to_tensor, tensor_to_literal};

use crate::coordinator::telemetry::{self, DispatchRecord, DispatchRing};
use crate::json::{self, Value};
use crate::tensor::{read_f32_file, Tensor};
use crate::{anyhow, bail, Context, Result};
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Smallest bucket >= n, else the largest available (None if `buckets`
/// is empty). The bucket-ladder primitive shared by `Model::bucket_for`
/// and the coordinator's occupancy scheduler.
pub fn pick_bucket(buckets: &[usize], n: usize) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= n).or_else(|| buckets.last().copied())
}

/// Compiled buckets of `program` for `variant`, ascending, read straight
/// from the manifest — no PJRT client needed, so CLI bucket selection
/// and the integration tests can size an engine before starting one.
pub fn manifest_buckets(artifacts_dir: &Path, variant: &str, program: &str) -> Result<Vec<usize>> {
    let man = json::parse_file(&artifacts_dir.join("manifest.json"))?;
    let v = man
        .req("variants")?
        .get(variant)
        .ok_or_else(|| anyhow!("variant '{variant}' not in manifest"))?;
    let mut out = Vec::new();
    for p in v.req("programs")?.as_arr()? {
        if p.req("program")?.as_str()? == program {
            out.push(p.req("bucket")?.as_usize()?);
        }
    }
    out.sort_unstable();
    out.dedup();
    Ok(out)
}

/// Largest compiled `program` bucket <= `cap` for `variant` (or the
/// smallest compiled one when all exceed `cap`) — the ladder-capped
/// pool-width policy shared by `gofast evaluate`, the benches and the
/// tests, for any solver step program.
pub fn manifest_program_bucket(
    artifacts_dir: &Path,
    variant: &str,
    program: &str,
    cap: usize,
) -> Result<usize> {
    let buckets = manifest_buckets(artifacts_dir, variant, program)?;
    buckets
        .iter()
        .rev()
        .find(|&&b| b <= cap)
        .or(buckets.first())
        .copied()
        .ok_or_else(|| anyhow!("{variant} has no {program} artifacts"))
}

/// [`manifest_program_bucket`] for `adaptive_step` (the engine's
/// mandatory pool width).
pub fn manifest_engine_bucket(artifacts_dir: &Path, variant: &str, cap: usize) -> Result<usize> {
    manifest_program_bucket(artifacts_dir, variant, "adaptive_step", cap)
}

/// Number of score-network evaluations a single call of each program
/// performs — the paper's cost metric (NFE). Step programs source their
/// per-call cost from the one `StepKernel` table
/// (`solvers::spec::STEP_KERNELS`), so the runtime's accounting cannot
/// drift from the lane programs'.
pub fn score_evals_per_call(program: &str) -> u64 {
    if let Some(k) = crate::solvers::spec::kernel_for_artifact(program) {
        return k.score_evals_per_step;
    }
    // fused k-step dispatches carry no static per-call cost: the engine
    // passes the real (non-pad) eval count to `Model::exec_device`
    // explicitly, so no-op tail rows are never billed and `score_evals`
    // stays bit-identical to the k = 1 path — the invariant the wire
    // docs and tools/check_perf.py gate on
    if crate::solvers::spec::kernel_for_fused_artifact(program).is_some() {
        return 0;
    }
    match program {
        "score" | "ode_drift" | "denoise" => 1,
        _ => 0,
    }
}

#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub dataset: String,
    pub sde_kind: String,
    pub dim: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub sigma_max: f64,
    pub t_eps: f64,
    pub n_params: usize,
    /// program -> available batch buckets (ascending)
    pub buckets: HashMap<String, Vec<usize>>,
}

impl ModelMeta {
    pub fn process(&self) -> crate::sde::Process {
        match self.sde_kind.as_str() {
            "ve" => crate::sde::Process::ve(self.sigma_max),
            "vp" => crate::sde::Process::vp(),
            other => panic!("unknown sde kind {other}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct FidMeta {
    pub name: String,
    pub dim: usize,
    pub n_classes: usize,
    pub feat_dim: usize,
    pub n_params: usize,
    pub buckets: Vec<usize>,
}

/// Execution statistics the coordinator exports.
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub calls: Vec<(String, u64)>,
    pub score_evals: u64,
    /// Executable launches (every program call, fused or not) — the
    /// host↔device synchronization count the k-step path amortises.
    pub dispatches: u64,
    /// Host→device bytes staged (theta/const first fills, per-call Host
    /// tensors, lane-state uploads; literal-path argument uploads too).
    pub bytes_h2d: u64,
    /// Device→host bytes pulled back (program outputs, lane-state
    /// downloads).
    pub bytes_d2h: u64,
}

pub struct Runtime {
    client: PjRtClient,
    root: PathBuf,
    manifest: Value,
    exes: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    calls: RefCell<HashMap<String, u64>>,
    score_evals: Cell<u64>,
    dispatches: Cell<u64>,
    bytes_h2d: Cell<u64>,
    bytes_d2h: Cell<u64>,
    /// Dispatch-timeline ring (telemetry): one timed record per
    /// executable launch when enabled via [`Runtime::set_timeline`];
    /// `None` (the default) records nothing and allocates nothing.
    timeline: RefCell<Option<DispatchRing>>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = json::parse_file(&artifacts_dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {artifacts_dir:?} (run `make artifacts`)"))?;
        Ok(Runtime {
            client: PjRtClient::cpu()?,
            root: artifacts_dir.to_path_buf(),
            manifest,
            exes: RefCell::new(HashMap::new()),
            calls: RefCell::new(HashMap::new()),
            score_evals: Cell::new(0),
            dispatches: Cell::new(0),
            bytes_h2d: Cell::new(0),
            bytes_d2h: Cell::new(0),
            timeline: RefCell::new(None),
        })
    }

    /// Enable (or, with `cap` 0, disable) the dispatch-timeline ring:
    /// the newest `cap` executable launches, each timed and split into
    /// upload / execution / download. The engine turns this on at
    /// startup when its span ring is enabled.
    pub fn set_timeline(&self, cap: usize) {
        *self.timeline.borrow_mut() = if cap > 0 { Some(DispatchRing::new(cap)) } else { None };
    }

    /// Timeline records oldest → newest (empty when disabled).
    pub fn timeline_snapshot(&self) -> Vec<DispatchRecord> {
        self.timeline.borrow().as_ref().map(|r| r.snapshot()).unwrap_or_default()
    }

    /// Push one timed launch onto the timeline ring. The record (and
    /// its label allocations) is only built when the ring is enabled.
    #[allow(clippy::too_many_arguments)]
    fn note_timeline(
        &self,
        model: &str,
        program: &str,
        bucket: usize,
        start: Instant,
        upload_s: f64,
        exec_s: f64,
        download_s: f64,
    ) {
        if let Some(ring) = self.timeline.borrow_mut().as_mut() {
            ring.push(DispatchRecord {
                start_s: telemetry::since_epoch(start),
                upload_s,
                exec_s,
                download_s,
                model: model.to_string(),
                program: program.to_string(),
                bucket,
                k: telemetry::k_of(program),
            });
        }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn variant_names(&self) -> Vec<String> {
        self.manifest
            .get("variants")
            .map(|v| v.members().iter().map(|(k, _)| k.clone()).collect())
            .unwrap_or_default()
    }

    /// Compile (with caching) the artifact for `<variant>/<program>_b<bucket>`.
    fn executable(&self, key: &str, rel_path: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.borrow().get(key) {
            return Ok(exe.clone());
        }
        let path = self.root.join(rel_path);
        let proto = HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp).with_context(|| format!("compiling {key}"))?);
        self.exes.borrow_mut().insert(key.to_string(), exe.clone());
        Ok(exe)
    }

    fn note_call(&self, program: &str) {
        *self.calls.borrow_mut().entry(program.to_string()).or_insert(0) += 1;
        self.score_evals.set(self.score_evals.get() + score_evals_per_call(program));
        self.dispatches.set(self.dispatches.get() + 1);
    }

    fn note_score_evals(&self, n: u64) {
        self.score_evals.set(self.score_evals.get() + n);
    }

    fn note_h2d(&self, bytes: u64) {
        self.bytes_h2d.set(self.bytes_h2d.get() + bytes);
    }

    fn note_d2h(&self, bytes: u64) {
        self.bytes_d2h.set(self.bytes_d2h.get() + bytes);
    }

    pub fn stats(&self) -> RuntimeStats {
        let mut calls: Vec<(String, u64)> =
            self.calls.borrow().iter().map(|(k, v)| (k.clone(), *v)).collect();
        calls.sort();
        RuntimeStats {
            calls,
            score_evals: self.score_evals.get(),
            dispatches: self.dispatches.get(),
            bytes_h2d: self.bytes_h2d.get(),
            bytes_d2h: self.bytes_d2h.get(),
        }
    }

    pub fn reset_stats(&self) {
        self.calls.borrow_mut().clear();
        self.score_evals.set(0);
        self.dispatches.set(0);
        self.bytes_h2d.set(0);
        self.bytes_d2h.set(0);
    }

    /// Load a score-model variant: metadata, flat params, artifact set.
    pub fn model(&self, name: &str) -> Result<Model<'_>> {
        let v = self
            .manifest
            .req("variants")?
            .get(name)
            .ok_or_else(|| anyhow!("variant '{name}' not in manifest (have: {:?})", self.variant_names()))?;
        let meta_v = v.req("meta")?;
        let mut buckets: HashMap<String, Vec<usize>> = HashMap::new();
        let mut files: HashMap<(String, usize), String> = HashMap::new();
        let mut input_shapes: HashMap<(String, usize), Vec<Vec<usize>>> = HashMap::new();
        for p in v.req("programs")?.as_arr()? {
            let program = p.req("program")?.as_str()?.to_string();
            let bucket = p.req("bucket")?.as_usize()?;
            buckets.entry(program.clone()).or_default().push(bucket);
            // the manifest records each artifact's input shapes (the
            // compiled ABI) — kept so callers can validate an artifact
            // set built by an older aot.py before feeding it tensors
            let shapes = p
                .req("inputs")?
                .as_arr()?
                .iter()
                .map(|shape| shape.as_arr()?.iter().map(|d| d.as_usize()).collect())
                .collect::<Result<Vec<Vec<usize>>>>()?;
            input_shapes.insert((program.clone(), bucket), shapes);
            files.insert((program, bucket), p.req("file")?.as_str()?.to_string());
        }
        for b in buckets.values_mut() {
            b.sort();
        }
        let meta = ModelMeta {
            name: name.to_string(),
            dataset: meta_v.req("dataset")?.as_str()?.to_string(),
            sde_kind: meta_v.req("sde_kind")?.as_str()?.to_string(),
            dim: meta_v.req("dim")?.as_usize()?,
            h: meta_v.req("h")?.as_usize()?,
            w: meta_v.req("w")?.as_usize()?,
            c: meta_v.req("c")?.as_usize()?,
            sigma_max: meta_v.req("sigma_max")?.as_f64()?,
            t_eps: meta_v.req("t_eps")?.as_f64()?,
            n_params: meta_v.req("n_params")?.as_usize()?,
            buckets,
        };
        let theta = read_f32_file(
            &self.root.join("params").join(format!("{name}.bin")),
            &[meta.n_params],
        )?;
        Ok(Model {
            rt: self,
            theta_lit: tensor_to_literal(&theta)?,
            theta_host: theta,
            theta_buf: RefCell::new(None),
            const_bufs: RefCell::new(HashMap::new()),
            exes: RefCell::new(HashMap::new()),
            exe_misses: Cell::new(0),
            files,
            input_shapes,
            meta,
        })
    }

    /// Load a synthception FID/IS feature network.
    pub fn fid_net(&self, name: &str) -> Result<FidNet<'_>> {
        let v = self
            .manifest
            .req("fidnets")?
            .get(name)
            .ok_or_else(|| anyhow!("fid net '{name}' not in manifest"))?;
        let meta_v = v.req("meta")?;
        let mut buckets = Vec::new();
        let mut files = HashMap::new();
        for p in v.req("programs")?.as_arr()? {
            let bucket = p.req("bucket")?.as_usize()?;
            buckets.push(bucket);
            files.insert(bucket, p.req("file")?.as_str()?.to_string());
        }
        buckets.sort();
        let meta = FidMeta {
            name: name.to_string(),
            dim: meta_v.req("dim")?.as_usize()?,
            n_classes: meta_v.req("n_classes")?.as_usize()?,
            feat_dim: meta_v.req("feat_dim")?.as_usize()?,
            n_params: meta_v.req("n_params")?.as_usize()?,
            buckets,
        };
        let theta = read_f32_file(
            &self.root.join("params").join(format!("{name}.bin")),
            &[meta.n_params],
        )?;
        Ok(FidNet { rt: self, theta_lit: tensor_to_literal(&theta)?, files, meta })
    }
}

/// A device-resident tensor the engine keeps alive between dispatches
/// (the lane-state slab `x` of a fused k-step pool). Holding the `Rc`
/// keeps the PJRT buffer alive; the shape is tracked host-side for byte
/// accounting and output-shape derivation.
#[derive(Clone)]
pub struct DeviceSlab {
    buf: Rc<PjRtBuffer>,
    shape: Vec<usize>,
}

impl DeviceSlab {
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    fn bytes(&self) -> u64 {
        self.shape.iter().product::<usize>() as u64 * 4
    }
}

/// An input to `Model::exec_args`.
pub enum ExecArg<'a> {
    /// Per-call tensor, uploaded fresh on the buffer path.
    Host(&'a Tensor),
    /// Constant tensor staged device-resident once per (model, tag,
    /// bucket) and reused across calls; the value fills the cache on
    /// first use (and is sent directly on the literal path).
    Const(&'a str, &'a Tensor),
    /// Already-device-resident tensor ([`Model::upload`] or a previous
    /// [`Model::exec_device`] output) — no staging cost at all. Only
    /// valid on the buffer path; the literal path has no device state.
    Device(&'a DeviceSlab),
    /// Per-call f64 tensor (flat data + shape), uploaded fresh. The
    /// fused adaptive fold's step controller evolves on device in f64
    /// to match the host controller bit-for-bit, so its `t`/`h` lane
    /// vectors and `[t_eps, safety, r]` constants cross as f64. Only
    /// valid on the buffer path (like [`ExecArg::Device`]).
    HostF64(&'a [f64], &'a [usize]),
}

/// A loaded score-model variant: metadata + device-ready parameters +
/// executable cache keyed by (program, bucket).
pub struct Model<'rt> {
    rt: &'rt Runtime,
    pub meta: ModelMeta,
    theta_host: Tensor,
    theta_lit: Literal,
    theta_buf: RefCell<Option<Rc<PjRtBuffer>>>,
    /// Device-resident step constants keyed by (tag, bucket).
    const_bufs: RefCell<HashMap<(String, usize), Rc<PjRtBuffer>>>,
    /// Per-(program, bucket) executables, resolved through the runtime
    /// once and then served from this model-level map — the same cache
    /// path the `Const` staging uses, so steady-state dispatch does one
    /// map hit instead of a string format + runtime lookup per call.
    exes: RefCell<HashMap<(String, usize), Rc<PjRtLoadedExecutable>>>,
    exe_misses: Cell<u64>,
    files: HashMap<(String, usize), String>,
    /// Manifest-recorded input shapes (the compiled ABI) per
    /// (program, bucket).
    input_shapes: HashMap<(String, usize), Vec<Vec<usize>>>,
}

impl<'rt> Model<'rt> {
    pub fn runtime(&self) -> &'rt Runtime {
        self.rt
    }

    /// Smallest available bucket >= n (or the largest bucket).
    pub fn bucket_for(&self, program: &str, n: usize) -> Result<usize> {
        let buckets = self
            .meta
            .buckets
            .get(program)
            .ok_or_else(|| anyhow!("{}: no program '{program}'", self.meta.name))?;
        pick_bucket(buckets, n).ok_or_else(|| anyhow!("{program}: empty bucket list"))
    }

    pub fn buckets(&self, program: &str) -> &[usize] {
        self.meta.buckets.get(program).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Whether the artifact for (program, bucket) is both listed in the
    /// manifest and present on disk — lets callers validate a bucket
    /// ladder up front instead of hitting a lazy-compile error
    /// mid-serving.
    pub fn has_artifact(&self, program: &str, bucket: usize) -> bool {
        self.files
            .get(&(program.to_string(), bucket))
            .is_some_and(|rel| self.rt.root.join(rel).exists())
    }

    /// Manifest-recorded input shapes of the compiled (program, bucket)
    /// artifact — the ABI aot.py lowered, so callers can refuse an
    /// artifact built by an incompatible pipeline version up front
    /// instead of faulting mid-execution on an argument-shape error.
    pub fn artifact_inputs(&self, program: &str, bucket: usize) -> Option<&[Vec<usize>]> {
        self.input_shapes.get(&(program.to_string(), bucket)).map(|v| v.as_slice())
    }

    fn exe(&self, program: &str, bucket: usize) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.borrow().get(&(program.to_string(), bucket)) {
            return Ok(exe.clone());
        }
        self.exe_misses.set(self.exe_misses.get() + 1);
        let rel = self
            .files
            .get(&(program.to_string(), bucket))
            .ok_or_else(|| anyhow!("{}: no artifact {program}_b{bucket}", self.meta.name))?;
        let exe = self.rt.executable(&format!("{}/{program}_b{bucket}", self.meta.name), rel)?;
        self.exes.borrow_mut().insert((program.to_string(), bucket), exe.clone());
        Ok(exe)
    }

    /// Times `exe` fell through this model's (program, bucket) map to
    /// the runtime lookup — steady-state dispatch must not grow this
    /// (pinned by the cache-reuse integration test).
    pub fn exe_cache_misses(&self) -> u64 {
        self.exe_misses.get()
    }

    /// Baseline path: all args as literals (theta re-uploaded every call).
    pub fn exec_literals(
        &self,
        program: &str,
        bucket: usize,
        inputs: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        let exe = self.exe(program, bucket)?;
        let start = Instant::now();
        let mut args: Vec<Literal> = Vec::with_capacity(inputs.len() + 1);
        args.push(self.theta_lit.clone_literal()?);
        let mut up = self.theta_host.data.len() as u64 * 4;
        for t in inputs {
            up += t.data.len() as u64 * 4;
            args.push(tensor_to_literal(t)?);
        }
        let upload_s = start.elapsed().as_secs_f64();
        self.rt.note_call(program);
        self.rt.note_h2d(up);
        let (out, exec_s, download_s) = run_timed(&exe, ExecArgs::Literals(&args))?;
        self.rt.note_d2h(out.iter().map(|t| t.data.len() as u64 * 4).sum());
        self.rt.note_timeline(&self.meta.name, program, bucket, start, upload_s, exec_s, download_s);
        Ok(out)
    }

    /// theta staged once per model, device-resident for the model's
    /// lifetime — shared by every (program, bucket), so a pool's bucket
    /// switch never re-uploads parameters.
    fn theta_buffer(&self) -> Result<Rc<PjRtBuffer>> {
        let mut slot = self.theta_buf.borrow_mut();
        if slot.is_none() {
            *slot = Some(Rc::new(self.rt.client.buffer_from_host_buffer(
                &self.theta_host.data,
                &self.theta_host.shape,
                None,
            )?));
            self.rt.note_h2d(self.theta_host.data.len() as u64 * 4);
        }
        Ok(slot.as_ref().unwrap().clone())
    }

    /// Device-resident constant keyed by (tag, bucket); `value` uploads
    /// only on the first use of the key.
    fn const_buffer(&self, tag: &str, bucket: usize, value: &Tensor) -> Result<Rc<PjRtBuffer>> {
        if let Some(b) = self.const_bufs.borrow().get(&(tag.to_string(), bucket)) {
            return Ok(b.clone());
        }
        let buf =
            Rc::new(self.rt.client.buffer_from_host_buffer(&value.data, &value.shape, None)?);
        self.rt.note_h2d(value.data.len() as u64 * 4);
        self.const_bufs.borrow_mut().insert((tag.to_string(), bucket), buf.clone());
        Ok(buf)
    }

    /// Upload a tensor to a device-resident slab the caller owns — the
    /// explicit entry point of the device-resident lane-state lifecycle
    /// (admission and post-migration re-upload).
    pub fn upload(&self, value: &Tensor) -> Result<DeviceSlab> {
        let buf =
            Rc::new(self.rt.client.buffer_from_host_buffer(&value.data, &value.shape, None)?);
        let slab = DeviceSlab { buf, shape: value.shape.clone() };
        self.rt.note_h2d(slab.bytes());
        Ok(slab)
    }

    /// Pull a device-resident slab back to a host tensor — the explicit
    /// exit point (lane completion without a fused denoise, and bucket
    /// migration, which remaps rows host-side then re-uploads).
    pub fn download(&self, slab: &DeviceSlab) -> Result<Tensor> {
        let t = literal_to_tensor(&slab.buf.to_literal_sync()?)?;
        self.rt.note_d2h(slab.bytes());
        Ok(t)
    }

    /// Optimised path: theta resident on device, inputs staged as buffers.
    pub fn exec_buffers(
        &self,
        program: &str,
        bucket: usize,
        inputs: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        let args: Vec<ExecArg<'_>> = inputs.iter().copied().map(ExecArg::Host).collect();
        self.exec_args(program, bucket, &args, true)
    }

    /// Dispatch on the configured execution mode.
    pub fn exec(
        &self,
        program: &str,
        bucket: usize,
        inputs: &[&Tensor],
        fused_buffers: bool,
    ) -> Result<Vec<Tensor>> {
        if fused_buffers {
            self.exec_buffers(program, bucket, inputs)
        } else {
            self.exec_literals(program, bucket, inputs)
        }
    }

    /// Like `exec`, but `Const` inputs are staged device-resident once
    /// per (tag, bucket) and reused — the serving hot path uses this so
    /// step constants (eps_abs, the denoise time vector) upload once per
    /// bucket instead of once per step.
    pub fn exec_args(
        &self,
        program: &str,
        bucket: usize,
        inputs: &[ExecArg<'_>],
        fused_buffers: bool,
    ) -> Result<Vec<Tensor>> {
        if !fused_buffers {
            let tensors: Vec<&Tensor> = inputs
                .iter()
                .map(|a| match a {
                    ExecArg::Host(t) | ExecArg::Const(_, t) => Ok(*t),
                    ExecArg::Device(_) | ExecArg::HostF64(..) => Err(anyhow!(
                        "{program}: ExecArg::Device/HostF64 need the buffer \
                         path (literal execution has no device state and \
                         stages f32 only)"
                    )),
                })
                .collect::<Result<_>>()?;
            return self.exec_literals(program, bucket, &tensors);
        }
        let start = Instant::now();
        let (exe, staged) = self.stage(program, bucket, inputs)?;
        let upload_s = start.elapsed().as_secs_f64();
        let args = staged.arg_refs();
        self.rt.note_call(program);
        let (out, exec_s, download_s) = run_timed(&exe, ExecArgs::Buffers(&args))?;
        self.rt.note_d2h(out.iter().map(|t| t.data.len() as u64 * 4).sum());
        self.rt.note_timeline(&self.meta.name, program, bucket, start, upload_s, exec_s, download_s);
        Ok(out)
    }

    /// Stage `inputs` as device buffers (theta first), reusing cached
    /// constants and passing `Device` slabs through untouched.
    fn stage(
        &self,
        program: &str,
        bucket: usize,
        inputs: &[ExecArg<'_>],
    ) -> Result<(Rc<PjRtLoadedExecutable>, StagedArgs)> {
        let theta = self.theta_buffer()?;
        let exe = self.exe(program, bucket)?;
        let mut fresh: Vec<PjRtBuffer> = Vec::new();
        let mut cached: Vec<Rc<PjRtBuffer>> = Vec::new();
        let mut order: Vec<Staged> = Vec::with_capacity(inputs.len());
        let mut up = 0u64;
        for a in inputs {
            match a {
                ExecArg::Host(t) => {
                    fresh.push(self.rt.client.buffer_from_host_buffer(&t.data, &t.shape, None)?);
                    up += t.data.len() as u64 * 4;
                    order.push(Staged::Fresh(fresh.len() - 1));
                }
                ExecArg::Const(tag, t) => {
                    cached.push(self.const_buffer(tag, bucket, t)?);
                    order.push(Staged::Cached(cached.len() - 1));
                }
                ExecArg::Device(slab) => {
                    cached.push(slab.buf.clone());
                    order.push(Staged::Cached(cached.len() - 1));
                }
                ExecArg::HostF64(data, shape) => {
                    fresh.push(self.rt.client.buffer_from_host_buffer(data, shape, None)?);
                    up += data.len() as u64 * 8;
                    order.push(Staged::Fresh(fresh.len() - 1));
                }
            }
        }
        self.rt.note_h2d(up);
        Ok((exe, StagedArgs { theta, fresh, cached, order }))
    }

    /// Buffer-path execution of an **untupled single-output** artifact
    /// (the fused k-step kernels, lowered with `return_tuple=False`),
    /// leaving the result on device: the returned slab is the next
    /// dispatch's `ExecArg::Device` input, so a lane pool's state never
    /// crosses the host boundary between grid nodes. The output shape is
    /// that of the first input (fused step kernels map x -> x_next).
    /// `score_evals` is the real (non-pad) score-eval count of this
    /// dispatch, supplied by the caller — only the engine knows how many
    /// of the k stacked nodes advance a live lane vs ride as no-op tail
    /// padding, and the `score_evals` counter must stay bit-identical to
    /// the k = 1 dispatch sequence (which bills per batched call).
    pub fn exec_device(
        &self,
        program: &str,
        bucket: usize,
        inputs: &[ExecArg<'_>],
        score_evals: u64,
    ) -> Result<DeviceSlab> {
        let out_shape = match inputs.first() {
            Some(ExecArg::Host(t)) | Some(ExecArg::Const(_, t)) => t.shape.clone(),
            Some(ExecArg::Device(slab)) => slab.shape.clone(),
            Some(ExecArg::HostF64(_, shape)) => shape.to_vec(),
            None => bail!("{program}: exec_device needs at least the x input"),
        };
        let start = Instant::now();
        let (exe, staged) = self.stage(program, bucket, inputs)?;
        let upload_s = start.elapsed().as_secs_f64();
        let args = staged.arg_refs();
        self.rt.note_call(program);
        self.rt.note_score_evals(score_evals);
        let t_exec = Instant::now();
        let buf = exe
            .execute_b(&args)?
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| anyhow!("{program}: executable returned no outputs"))?;
        // output stays device-resident: download is 0 by design here
        let exec_s = t_exec.elapsed().as_secs_f64();
        self.rt.note_timeline(&self.meta.name, program, bucket, start, upload_s, exec_s, 0.0);
        Ok(DeviceSlab { buf: Rc::new(buf), shape: out_shape })
    }

    /// Bill score-network evaluations after the fact. The fused
    /// adaptive dispatch passes `score_evals = 0` to [`exec_device`]
    /// and folds the real cost here once the device attempt log is
    /// downloaded — rejected attempts still run the score net (the
    /// paper's NFE accounting), and the per-dispatch cost is
    /// 2 × (deepest live lane's attempt count), exactly what the k = 1
    /// per-batched-call billing sums to.
    pub fn bill_score_evals(&self, n: u64) {
        self.rt.note_score_evals(n);
    }
}

pub struct FidNet<'rt> {
    rt: &'rt Runtime,
    pub meta: FidMeta,
    theta_lit: Literal,
    files: HashMap<usize, String>,
}

impl<'rt> FidNet<'rt> {
    /// x must be in [0,1], shape [bucket, dim]. Returns (features, logits).
    pub fn features(&self, x: &Tensor) -> Result<(Tensor, Tensor)> {
        let bucket = x.shape[0];
        let rel = self
            .files
            .get(&bucket)
            .ok_or_else(|| anyhow!("fid net has no bucket {bucket} (have {:?})", self.meta.buckets))?;
        let exe = self.rt.executable(&format!("{}/fid_b{bucket}", self.meta.name), rel)?;
        let args = vec![self.theta_lit.clone_literal()?, tensor_to_literal(x)?];
        let mut out = run(&exe, ExecArgs::Literals(&args))?;
        if out.len() != 2 {
            bail!("fid_features returned {} outputs", out.len());
        }
        let logits = out.pop().unwrap();
        let feat = out.pop().unwrap();
        Ok((feat, logits))
    }
}

enum ExecArgs<'a> {
    Literals(&'a [Literal]),
    Buffers(&'a [&'a PjRtBuffer]),
}

/// Where each staged input lives (index into `StagedArgs::fresh` or
/// `::cached`), preserving kernel input order.
enum Staged {
    Fresh(usize),
    Cached(usize),
}

/// Device-staged argument list for one dispatch: theta + inputs in
/// kernel order, owning the fresh per-call buffers so the borrowed
/// argument slice stays valid for the launch.
struct StagedArgs {
    theta: Rc<PjRtBuffer>,
    fresh: Vec<PjRtBuffer>,
    cached: Vec<Rc<PjRtBuffer>>,
    order: Vec<Staged>,
}

impl StagedArgs {
    fn arg_refs(&self) -> Vec<&PjRtBuffer> {
        let mut args = Vec::with_capacity(self.order.len() + 1);
        args.push(self.theta.as_ref());
        for s in &self.order {
            match s {
                Staged::Fresh(i) => args.push(&self.fresh[*i]),
                Staged::Cached(i) => args.push(self.cached[*i].as_ref()),
            }
        }
        args
    }
}

/// Execute and pull every tuple element back to host tensors.
fn run(exe: &PjRtLoadedExecutable, args: ExecArgs<'_>) -> Result<Vec<Tensor>> {
    run_timed(exe, args).map(|(out, _, _)| out)
}

/// [`run`] plus the telemetry split: returns `(outputs, exec seconds,
/// download seconds)`, where download covers the device→host literal
/// pull and tensor conversion.
fn run_timed(exe: &PjRtLoadedExecutable, args: ExecArgs<'_>) -> Result<(Vec<Tensor>, f64, f64)> {
    let t0 = Instant::now();
    let result = match args {
        ExecArgs::Literals(lits) => exe.execute::<Literal>(lits)?,
        ExecArgs::Buffers(bufs) => exe.execute_b(bufs)?,
    };
    let exec_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let lit = result
        .first()
        .and_then(|r| r.first())
        .ok_or_else(|| anyhow!("executable returned no outputs"))?
        .to_literal_sync()?;
    // aot.py lowers the programs served through this path with
    // return_tuple=True: the output is always a tuple (the untupled
    // fused step artifacts go through `Model::exec_device` instead)
    let parts = lit.to_tuple()?;
    let out = parts.iter().map(literal_to_tensor).collect::<Result<Vec<Tensor>>>()?;
    Ok((out, exec_s, t1.elapsed().as_secs_f64()))
}

/// Extension trait: the xla crate's Literal lacks Clone.
trait CloneLiteral {
    fn clone_literal(&self) -> Result<Literal>;
}

impl CloneLiteral for Literal {
    fn clone_literal(&self) -> Result<Literal> {
        literal_util::clone_literal(self)
    }
}

#[cfg(test)]
mod tests {
    use super::pick_bucket;

    #[test]
    fn pick_bucket_smallest_fitting() {
        let buckets = [1, 2, 4, 16, 64];
        assert_eq!(pick_bucket(&buckets, 1), Some(1));
        assert_eq!(pick_bucket(&buckets, 3), Some(4));
        assert_eq!(pick_bucket(&buckets, 16), Some(16));
        assert_eq!(pick_bucket(&buckets, 17), Some(64));
    }

    #[test]
    fn pick_bucket_n_zero_takes_smallest() {
        assert_eq!(pick_bucket(&[4, 8], 0), Some(4));
    }

    #[test]
    fn pick_bucket_oversubscribed_clamps_to_largest() {
        assert_eq!(pick_bucket(&[4, 8], 1000), Some(8));
    }

    #[test]
    fn pick_bucket_empty_is_none() {
        assert_eq!(pick_bucket(&[], 1), None);
        assert_eq!(pick_bucket(&[], 0), None);
    }

    #[test]
    fn score_evals_per_call_reads_the_kernel_table() {
        use super::score_evals_per_call;
        // step programs come from solvers::spec::STEP_KERNELS — the one
        // definition the lane programs also read
        for k in crate::solvers::spec::STEP_KERNELS {
            assert_eq!(score_evals_per_call(k.artifact), k.score_evals_per_step, "{}", k.artifact);
        }
        assert_eq!(score_evals_per_call("pc_step"), 2);
        assert_eq!(score_evals_per_call("score"), 1);
        assert_eq!(score_evals_per_call("denoise"), 1);
        assert_eq!(score_evals_per_call("fid_features"), 0);
        // fused k-step dispatches have no static per-call cost: the
        // engine bills only real (non-pad) nodes via exec_device, so the
        // counter matches the k = 1 path bit-for-bit
        assert_eq!(score_evals_per_call("em_stepk8"), 0);
        assert_eq!(score_evals_per_call("pc_stepk4"), 0);
        assert_eq!(score_evals_per_call("ddim_stepk8"), 0);
        assert_eq!(score_evals_per_call("em_stepk1"), 0);
    }
}
