//! Host Tensor <-> xla::Literal conversion helpers.

use crate::tensor::Tensor;
use crate::{bail, Result};
use xla::{ElementType, Literal};

pub fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, &t.shape, bytes)?)
}

pub fn literal_to_tensor(lit: &Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    if data.len() != dims.iter().product::<usize>() {
        bail!("literal shape {:?} vs {} elements", dims, data.len());
    }
    Ok(Tensor { shape: dims, data })
}

pub fn clone_literal(lit: &Literal) -> Result<Literal> {
    // round-trip through host bytes; only used for the (small) theta vector
    // and per-step inputs on the baseline literal path.
    let t = literal_to_tensor(lit)?;
    tensor_to_literal(&t)
}
