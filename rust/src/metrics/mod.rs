//! Evaluation metrics: FID* and IS* over the synthception feature network
//! (DESIGN.md §2 — starred to flag the Inception-v3 substitution), plus
//! serving-side latency histograms and throughput counters.

pub mod hist;
pub mod streaming;

pub use streaming::{EvalAccumulator, IsAccumulator, StreamingStats};

use crate::json;
use crate::linalg::{mean_cov, trace, trace_sqrt_product};
use crate::runtime::{FidNet, ModelMeta, Runtime};
use crate::tensor::{read_f32_file, Tensor};
use crate::{bail, Result};

/// Cap on reference-split samples used for the FID* reference Gaussian
/// (shared by the offline bypass, the engine eval path and the benches,
/// so all three fit the same reference).
pub const REF_SAMPLES: usize = 2048;

/// First/second moments of feature activations over a sample set.
#[derive(Clone, Debug)]
pub struct FeatureStats {
    pub mu: Vec<f64>,
    pub cov: Vec<f64>,
    pub d: usize,
    pub n: usize,
}

/// Run images (unit range [0,1], [N, dim]) through the feature net in
/// bucket-sized chunks (padding the tail) and also return logits.
pub fn extract_features(net: &FidNet, images: &Tensor) -> Result<(Tensor, Tensor)> {
    let n = images.shape[0];
    let dim = images.shape[1];
    if dim != net.meta.dim {
        bail!("image dim {dim} != fid net dim {}", net.meta.dim);
    }
    let bucket = *net
        .meta
        .buckets
        .last()
        .ok_or_else(|| crate::anyhow!("fid net has no compiled buckets"))?;
    let fd = net.meta.feat_dim;
    let nc = net.meta.n_classes;
    let mut feats = Tensor::zeros(&[n, fd]);
    let mut logits = Tensor::zeros(&[n, nc]);
    let mut chunk = Tensor::zeros(&[bucket, dim]);
    let mut start = 0;
    while start < n {
        let take = (n - start).min(bucket);
        for i in 0..take {
            chunk.row_mut(i).copy_from_slice(images.row(start + i));
        }
        // tail padding rows repeat the last row; outputs are discarded
        for i in take..bucket {
            let src = images.row(start + take - 1).to_vec();
            chunk.row_mut(i).copy_from_slice(&src);
        }
        let (f, l) = net.features(&chunk)?;
        for i in 0..take {
            feats.row_mut(start + i).copy_from_slice(f.row(i));
            logits.row_mut(start + i).copy_from_slice(l.row(i));
        }
        start += take;
    }
    Ok((feats, logits))
}

/// Fit a Gaussian to feature rows. Errors below two samples: the
/// covariance is undefined there and `fid` would silently return
/// garbage from a singular fit.
pub fn feature_stats(feats: &Tensor) -> Result<FeatureStats> {
    let (n, d) = (feats.shape[0], feats.shape[1]);
    if n < 2 {
        bail!("feature stats need >= 2 samples, have {n}");
    }
    let (mu, cov) = mean_cov(&feats.data, n, d);
    Ok(FeatureStats { mu, cov, d, n })
}

/// Fréchet distance between two Gaussians fitted to feature sets:
/// |mu1-mu2|^2 + tr(C1 + C2 - 2 sqrtm(C1 C2)).
pub fn fid(a: &FeatureStats, b: &FeatureStats) -> f64 {
    assert_eq!(a.d, b.d);
    let d = a.d;
    let mean_term: f64 = a.mu.iter().zip(&b.mu).map(|(x, y)| (x - y) * (x - y)).sum();
    let tr_term = trace(&a.cov, d) + trace(&b.cov, d) - 2.0 * trace_sqrt_product(&a.cov, &b.cov, d);
    (mean_term + tr_term).max(0.0)
}

/// Inception Score*: exp(E_x KL(p(y|x) || p(y))) from raw logits [N, C].
pub fn inception_score(logits: &Tensor) -> f64 {
    let (n, c) = (logits.shape[0], logits.shape[1]);
    let mut probs = vec![0f64; n * c];
    for i in 0..n {
        let row = logits.row(i);
        let m = row.iter().cloned().fold(f32::MIN, f32::max) as f64;
        let mut z = 0f64;
        for j in 0..c {
            let e = ((row[j] as f64) - m).exp();
            probs[i * c + j] = e;
            z += e;
        }
        for j in 0..c {
            probs[i * c + j] /= z;
        }
    }
    let mut marginal = vec![0f64; c];
    for i in 0..n {
        for j in 0..c {
            marginal[j] += probs[i * c + j] / n as f64;
        }
    }
    let mut kl_sum = 0f64;
    for i in 0..n {
        for j in 0..c {
            let p = probs[i * c + j];
            if p > 1e-12 {
                kl_sum += p * (p.ln() - marginal[j].ln());
            }
        }
    }
    (kl_sum / n as f64).exp()
}

/// End-to-end helper: FID* of generated unit-range images against
/// reference stats, plus IS*.
pub fn evaluate(
    net: &FidNet,
    generated_unit: &Tensor,
    reference: &FeatureStats,
) -> Result<(f64, f64)> {
    let (feats, logits) = extract_features(net, generated_unit)?;
    let stats = feature_stats(&feats)?;
    Ok((fid(&stats, reference), inception_score(&logits)))
}

/// Like `evaluate`, but folds fid-bucket-sized chunks through an
/// `EvalAccumulator` — the exact arithmetic the engine's eval lanes use,
/// so the `--offline` bypass and the served path agree bit-for-bit when
/// the lane order matches.
pub fn evaluate_streaming(
    net: &FidNet,
    generated_unit: &Tensor,
    reference: &FeatureStats,
) -> Result<(f64, f64)> {
    let chunk = *net
        .meta
        .buckets
        .last()
        .ok_or_else(|| crate::anyhow!("fid net has no compiled buckets"))?;
    let (n, dim) = (generated_unit.shape[0], generated_unit.shape[1]);
    let mut acc = EvalAccumulator::new(net.meta.feat_dim, net.meta.n_classes);
    let mut start = 0;
    while start < n {
        let take = (n - start).min(chunk);
        let part = Tensor::from_vec(
            &[take, dim],
            generated_unit.data[start * dim..(start + take) * dim].to_vec(),
        )?;
        let (f, l) = extract_features(net, &part)?;
        acc.push(&f, &l);
        start += take;
    }
    acc.finalize(reference)
}

/// The fid net paired with a score model's image geometry (the 16x16
/// synth-cifar models share fid16; the 32x32 ones fid32).
pub fn fid_net_name_for(dim: usize) -> &'static str {
    if dim == 768 {
        "fid16"
    } else {
        "fid32"
    }
}

/// Load the feature net for `meta`'s geometry plus reference stats fitted
/// to (at most `REF_SAMPLES` of) the exported eval split — shared by the
/// offline bypass, the engine eval path, and the benches.
pub fn reference_for<'rt>(
    rt: &'rt Runtime,
    meta: &ModelMeta,
) -> Result<(FidNet<'rt>, FeatureStats)> {
    let net = rt.fid_net(fid_net_name_for(meta.dim))?;
    let data_meta =
        json::parse_file(&rt.root().join("data").join(format!("{}.meta.json", meta.dataset)))?;
    let n_total = data_meta.req("n")?.as_usize()?;
    let n_ref = n_total.min(REF_SAMPLES);
    let all = read_f32_file(
        &rt.root().join("data").join(format!("{}.bin", meta.dataset)),
        &[n_total, meta.dim],
    )?;
    let refs = Tensor::from_vec(&[n_ref, meta.dim], all.data[..n_ref * meta.dim].to_vec())?;
    let (f, _) = extract_features(&net, &refs)?;
    Ok((net, feature_stats(&f)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn gaussian_feats(n: usize, d: usize, mean: f32, seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        let data = (0..n * d).map(|_| r.normal() as f32 + mean).collect();
        Tensor { shape: vec![n, d], data }
    }

    #[test]
    fn fid_zero_for_same_distribution() {
        let a = feature_stats(&gaussian_feats(4000, 8, 0.0, 1)).unwrap();
        let b = feature_stats(&gaussian_feats(4000, 8, 0.0, 2)).unwrap();
        let v = fid(&a, &b);
        assert!(v < 0.05, "fid {v}");
    }

    #[test]
    fn fid_grows_with_mean_shift() {
        let a = feature_stats(&gaussian_feats(2000, 8, 0.0, 1)).unwrap();
        let b = feature_stats(&gaussian_feats(2000, 8, 0.5, 2)).unwrap();
        let c = feature_stats(&gaussian_feats(2000, 8, 2.0, 3)).unwrap();
        let f_ab = fid(&a, &b);
        let f_ac = fid(&a, &c);
        // mean term alone: d * shift^2 = 8*0.25 = 2 and 8*4 = 32
        assert!(f_ab > 1.0 && f_ab < 4.0, "{f_ab}");
        assert!(f_ac > 25.0 && f_ac < 40.0, "{f_ac}");
        assert!(f_ac > f_ab);
    }

    #[test]
    fn fid_detects_covariance_mismatch() {
        let a = feature_stats(&gaussian_feats(4000, 4, 0.0, 1)).unwrap();
        let mut wide = gaussian_feats(4000, 4, 0.0, 2);
        wide.scale(2.0);
        let b = feature_stats(&wide).unwrap();
        // analytic: tr(I + 4I - 2*2I) = d*(1+4-4) = 4 (per-dim (s1-s2)^2)
        let v = fid(&a, &b);
        assert!((v - 4.0).abs() < 0.5, "fid {v}");
    }

    #[test]
    fn is_one_for_uniform_and_c_for_onehot() {
        let n = 256;
        let c = 4;
        // uniform logits -> IS = 1
        let uniform = Tensor::zeros(&[n, c]);
        assert!((inception_score(&uniform) - 1.0).abs() < 1e-9);
        // perfectly confident, balanced classes -> IS = C
        let mut onehot = Tensor::zeros(&[n, c]);
        for i in 0..n {
            onehot.row_mut(i)[i % c] = 50.0;
        }
        let v = inception_score(&onehot);
        assert!((v - c as f64).abs() < 1e-6, "{v}");
    }

    #[test]
    fn is_between_one_and_c() {
        let mut r = Rng::new(5);
        let n = 128;
        let c = 6;
        let data: Vec<f32> = (0..n * c).map(|_| (r.normal() * 2.0) as f32).collect();
        let v = inception_score(&Tensor { shape: vec![n, c], data });
        assert!(v >= 1.0 - 1e-9 && v <= c as f64 + 1e-9, "{v}");
    }
}
