//! Incremental FID*/IS* accumulators for engine-driven evaluation.
//!
//! The serving engine generates evaluation samples in scheduler-sized
//! chunks, so the feature statistics must be *mergeable*: `StreamingStats`
//! keeps (n, mean, comoment) and combines partitions with Chan's parallel
//! update, which is exact for any split of the sample set — batches of
//! any bucket width combine into the same mean/covariance (up to fp
//! rounding) as a one-shot fit. `IsAccumulator` does the analogous
//! decomposition for the Inception Score: per-sample `sum p ln p` plus
//! class mass totals, from which the marginal term is recovered at
//! finalization.
//!
//! `EvalAccumulator` bundles both; the engine's eval lanes and the
//! `--offline` bypass in `main.rs` push identical chunk sequences through
//! it, which is what makes the two paths comparable to 1e-6 (exact when
//! the lane order matches).

use super::FeatureStats;
use crate::tensor::Tensor;
use crate::{bail, Result};

/// Mergeable first/second feature moments: n, mean, and the comoment
/// matrix M2 = sum (x - mean)(x - mean)^T (row-major d x d, f64).
#[derive(Clone, Debug)]
pub struct StreamingStats {
    d: usize,
    n: usize,
    mean: Vec<f64>,
    m2: Vec<f64>,
}

impl StreamingStats {
    pub fn new(d: usize) -> StreamingStats {
        StreamingStats { d, n: 0, mean: vec![0.0; d], m2: vec![0.0; d * d] }
    }

    /// Fit one batch of feature rows ([n, d], f32) — the same two-pass
    /// mean/comoment arithmetic as `linalg::mean_cov`, unnormalized.
    pub fn from_feats(feats: &Tensor) -> StreamingStats {
        let (n, d) = (feats.shape[0], feats.shape[1]);
        let mut s = StreamingStats::new(d);
        s.n = n;
        if n == 0 {
            return s;
        }
        for r in 0..n {
            let row = feats.row(r);
            for j in 0..d {
                s.mean[j] += row[j] as f64;
            }
        }
        s.mean.iter_mut().for_each(|v| *v /= n as f64);
        for r in 0..n {
            let row = feats.row(r);
            for i in 0..d {
                let di = row[i] as f64 - s.mean[i];
                for j in i..d {
                    s.m2[i * d + j] += di * (row[j] as f64 - s.mean[j]);
                }
            }
        }
        for i in 0..d {
            for j in i..d {
                s.m2[j * d + i] = s.m2[i * d + j];
            }
        }
        s
    }

    /// Fold a batch of feature rows in (fit, then Chan-merge).
    pub fn push(&mut self, feats: &Tensor) {
        self.merge(&StreamingStats::from_feats(feats));
    }

    /// Chan's parallel update: combine two partitions exactly.
    ///   delta = mean_b - mean_a
    ///   mean  = mean_a + delta * n_b / n
    ///   M2    = M2_a + M2_b + outer(delta, delta) * n_a n_b / n
    pub fn merge(&mut self, other: &StreamingStats) {
        assert_eq!(self.d, other.d, "feature dims differ");
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            self.n = other.n;
            self.mean.copy_from_slice(&other.mean);
            self.m2.copy_from_slice(&other.m2);
            return;
        }
        let d = self.d;
        let (na, nb) = (self.n as f64, other.n as f64);
        let total = na + nb;
        let delta: Vec<f64> = (0..d).map(|j| other.mean[j] - self.mean[j]).collect();
        for j in 0..d {
            self.mean[j] += delta[j] * nb / total;
        }
        let w = na * nb / total;
        for i in 0..d {
            for j in 0..d {
                self.m2[i * d + j] += other.m2[i * d + j] + delta[i] * delta[j] * w;
            }
        }
        self.n += other.n;
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Normalize into `FeatureStats` (cov = M2 / (n-1)); errors below two
    /// samples, where the covariance is undefined/singular.
    pub fn finalize(&self) -> Result<FeatureStats> {
        if self.n < 2 {
            bail!("feature stats need >= 2 samples, have {}", self.n);
        }
        let norm = 1.0 / (self.n as f64 - 1.0);
        Ok(FeatureStats {
            mu: self.mean.clone(),
            cov: self.m2.iter().map(|v| v * norm).collect(),
            d: self.d,
            n: self.n,
        })
    }
}

/// Mergeable Inception Score* state. For softmax rows p_i:
///   IS = exp( (sum_ij p_ij ln p_ij - sum_j c_j ln(c_j / n)) / n )
/// with c_j = sum_i p_ij, which equals the one-shot
/// `metrics::inception_score` decomposition of E_x KL(p(y|x) || p(y)).
#[derive(Clone, Debug)]
pub struct IsAccumulator {
    n: usize,
    sum_plogp: f64,
    class_mass: Vec<f64>,
}

impl IsAccumulator {
    pub fn new(n_classes: usize) -> IsAccumulator {
        IsAccumulator { n: 0, sum_plogp: 0.0, class_mass: vec![0.0; n_classes] }
    }

    /// Fold a batch of raw logits ([n, C]); softmax arithmetic matches
    /// `metrics::inception_score` (f64, max-subtracted).
    pub fn push(&mut self, logits: &Tensor) {
        let (n, c) = (logits.shape[0], logits.shape[1]);
        assert_eq!(c, self.class_mass.len(), "class count differs");
        let mut p = vec![0f64; c];
        for i in 0..n {
            let row = logits.row(i);
            let m = row.iter().cloned().fold(f32::MIN, f32::max) as f64;
            let mut z = 0f64;
            for j in 0..c {
                let e = ((row[j] as f64) - m).exp();
                p[j] = e;
                z += e;
            }
            for j in 0..c {
                let pj = p[j] / z;
                self.class_mass[j] += pj;
                if pj > 1e-12 {
                    self.sum_plogp += pj * pj.ln();
                }
            }
        }
        self.n += n;
    }

    pub fn merge(&mut self, other: &IsAccumulator) {
        assert_eq!(self.class_mass.len(), other.class_mass.len());
        self.n += other.n;
        self.sum_plogp += other.sum_plogp;
        for (a, b) in self.class_mass.iter_mut().zip(&other.class_mass) {
            *a += b;
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn finalize(&self) -> Result<f64> {
        if self.n == 0 {
            bail!("inception score needs >= 1 sample");
        }
        let n = self.n as f64;
        let mut marginal_term = 0f64;
        for &cj in &self.class_mass {
            if cj > 1e-12 {
                marginal_term += cj * (cj / n).ln();
            }
        }
        Ok(((self.sum_plogp - marginal_term) / n).exp())
    }
}

/// FID* + IS* over a stream of (features, logits) chunks. Both the
/// engine's eval lanes and the offline bypass feed chunks in sample
/// order, so identical lane order gives bit-identical results.
#[derive(Clone, Debug)]
pub struct EvalAccumulator {
    pub stats: StreamingStats,
    pub is: IsAccumulator,
}

impl EvalAccumulator {
    pub fn new(feat_dim: usize, n_classes: usize) -> EvalAccumulator {
        EvalAccumulator { stats: StreamingStats::new(feat_dim), is: IsAccumulator::new(n_classes) }
    }

    pub fn push(&mut self, feats: &Tensor, logits: &Tensor) {
        self.stats.push(feats);
        self.is.push(logits);
    }

    pub fn merge(&mut self, other: &EvalAccumulator) {
        self.stats.merge(&other.stats);
        self.is.merge(&other.is);
    }

    pub fn n(&self) -> usize {
        self.stats.n()
    }

    /// (FID* against `reference`, IS*).
    pub fn finalize(&self, reference: &FeatureStats) -> Result<(f64, f64)> {
        let stats = self.stats.finalize()?;
        Ok((super::fid(&stats, reference), self.is.finalize()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{feature_stats, inception_score};
    use crate::rng::Rng;

    fn gaussian(n: usize, d: usize, seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        let data = (0..n * d).map(|_| r.normal() as f32).collect();
        Tensor { shape: vec![n, d], data }
    }

    fn rows(t: &Tensor, lo: usize, hi: usize) -> Tensor {
        let d = t.shape[1];
        Tensor { shape: vec![hi - lo, d], data: t.data[lo * d..hi * d].to_vec() }
    }

    /// Satellite: merging uneven batch splits must match whole-batch
    /// stats to tight tolerance.
    #[test]
    fn uneven_split_merge_matches_one_shot() {
        let n = 1000;
        let d = 8;
        let feats = gaussian(n, d, 11);
        let whole = feature_stats(&feats).unwrap();
        // splits of widths a fused pool might actually produce
        for splits in [vec![1, 7, 64, 128, 800], vec![999, 1], vec![500, 500]] {
            assert_eq!(splits.iter().sum::<usize>(), n);
            let mut acc = StreamingStats::new(d);
            let mut lo = 0;
            for w in splits {
                acc.push(&rows(&feats, lo, lo + w));
                lo += w;
            }
            let merged = acc.finalize().unwrap();
            assert_eq!(merged.n, whole.n);
            for (a, b) in merged.mu.iter().zip(&whole.mu) {
                assert!((a - b).abs() < 1e-10, "mu {a} vs {b}");
            }
            for (a, b) in merged.cov.iter().zip(&whole.cov) {
                assert!((a - b).abs() < 1e-9, "cov {a} vs {b}");
            }
        }
    }

    #[test]
    fn single_batch_matches_one_shot_exactly() {
        let feats = gaussian(64, 6, 3);
        let one = feature_stats(&feats).unwrap();
        let s = StreamingStats::from_feats(&feats).finalize().unwrap();
        assert_eq!(s.mu, one.mu);
        assert_eq!(s.cov, one.cov);
    }

    #[test]
    fn finalize_guards_degenerate_sample_counts() {
        assert!(StreamingStats::new(4).finalize().is_err());
        let one = gaussian(1, 4, 1);
        assert!(StreamingStats::from_feats(&one).finalize().is_err());
        let two = gaussian(2, 4, 1);
        assert!(StreamingStats::from_feats(&two).finalize().is_ok());
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let feats = gaussian(16, 4, 9);
        let mut a = StreamingStats::from_feats(&feats);
        a.merge(&StreamingStats::new(4));
        let mut b = StreamingStats::new(4);
        b.merge(&StreamingStats::from_feats(&feats));
        let (fa, fb) = (a.finalize().unwrap(), b.finalize().unwrap());
        assert_eq!(fa.mu, fb.mu);
        assert_eq!(fa.cov, fb.cov);
    }

    #[test]
    fn streaming_is_matches_one_shot() {
        let mut r = Rng::new(7);
        let (n, c) = (96, 5);
        let data: Vec<f32> = (0..n * c).map(|_| (r.normal() * 2.0) as f32).collect();
        let logits = Tensor { shape: vec![n, c], data };
        let one = inception_score(&logits);
        let mut acc = IsAccumulator::new(c);
        for (lo, hi) in [(0usize, 1usize), (1, 33), (33, 96)] {
            acc.push(&rows(&logits, lo, hi));
        }
        let v = acc.finalize().unwrap();
        assert!((v - one).abs() < 1e-9, "{v} vs {one}");
    }

    /// Satellite: IS* of a single-sample batch is exactly 1 (marginal
    /// equals the sample's own p(y|x), so the KL is 0).
    #[test]
    fn single_sample_inception_score_is_one() {
        let logits = Tensor { shape: vec![1, 4], data: vec![3.0, -1.0, 0.5, 7.0] };
        assert!((inception_score(&logits) - 1.0).abs() < 1e-12);
        let mut acc = IsAccumulator::new(4);
        acc.push(&logits);
        assert!((acc.finalize().unwrap() - 1.0).abs() < 1e-12);
    }
}
