//! Log-bucketed latency histogram + throughput window for the serving
//! metrics endpoint (quantiles without storing every observation).

#[derive(Clone, Debug)]
pub struct Histogram {
    /// bucket i covers [base * ratio^i, base * ratio^(i+1))
    counts: Vec<u64>,
    base: f64,
    ratio: f64,
    total: u64,
    sum: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    /// Covers ~[10us, 1000s] with 5% resolution by default.
    pub fn new() -> Histogram {
        Histogram::with_range(1e-5, 1.05, 400)
    }

    pub fn with_range(base: f64, ratio: f64, buckets: usize) -> Histogram {
        Histogram { counts: vec![0; buckets], base, ratio, total: 0, sum: 0.0, max: 0.0 }
    }

    pub fn record(&mut self, v: f64) {
        let idx = if v <= self.base {
            0
        } else {
            ((v / self.base).ln() / self.ratio.ln()) as usize
        };
        let idx = idx.min(self.counts.len() - 1);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v;
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Sum of every recorded value (exact, not bucket-approximated) —
    /// the `_sum` of the Prometheus summary exposition.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Quantile via bucket upper bound (<= 5% relative error by
    /// design). An empty histogram reports 0; `q <= 0` reports the
    /// first occupied bucket (the target rank floors at 1, otherwise
    /// the scan would stop at the first — possibly empty — bucket) and
    /// `q >= 1` the last occupied one.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.base * self.ratio.powi(i as i32 + 1);
            }
        }
        self.max
    }

    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_are_close() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 / 1000.0); // 1ms..1s uniform
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!((p50 - 0.5).abs() / 0.5 < 0.08, "p50 {p50}");
        assert!((p99 - 0.99).abs() / 0.99 < 0.08, "p99 {p99}");
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 0.5005).abs() < 1e-9);
    }

    #[test]
    fn merge_combines() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(0.1);
        b.record(0.2);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean() - 0.15).abs() < 1e-12);
    }

    #[test]
    fn empty_histogram_is_sane() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.quantile(0.0), 0.0);
        assert_eq!(h.quantile(1.0), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.count(), 0);
    }

    /// A single sample dominates every quantile: q = 0, 0.5 and 1 must
    /// all land in its bucket (within the 5% bucket resolution), never
    /// at 0 or at the histogram floor.
    #[test]
    fn single_sample_dominates_every_quantile() {
        let mut h = Histogram::new();
        h.record(0.1);
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!(
                (0.1..=0.1 * 1.06).contains(&v),
                "quantile({q}) = {v}, expected ~0.1 (bucket upper bound)"
            );
        }
        assert_eq!(h.count(), 1);
        assert!((h.mean() - 0.1).abs() < 1e-12);
    }

    /// q = 0 must report the smallest occupied bucket, q = 1 the
    /// largest — not the ends of the bucket range.
    #[test]
    fn extreme_quantiles_hit_occupied_buckets() {
        let mut h = Histogram::new();
        h.record(0.01);
        h.record(1.0);
        let lo = h.quantile(0.0);
        let hi = h.quantile(1.0);
        assert!((0.01..=0.01 * 1.06).contains(&lo), "q=0 -> {lo}");
        assert!((1.0..=1.0 * 1.06).contains(&hi), "q=1 -> {hi}");
        // out-of-range q clamps rather than panicking or scanning past
        // the table
        assert_eq!(h.quantile(-0.5), lo);
        assert_eq!(h.quantile(2.0), hi);
    }

    /// Values beyond the bucket table clamp into the last bucket and
    /// keep quantiles finite.
    #[test]
    fn overflow_values_clamp_to_last_bucket() {
        let mut h = Histogram::with_range(1e-5, 1.05, 10);
        h.record(1e9);
        let v = h.quantile(0.5);
        assert!(v.is_finite() && v > 0.0, "overflow quantile {v}");
        assert_eq!(h.max(), 1e9);
    }

    /// Telemetry merges per-pool histograms into totals, so merge must
    /// preserve count and sum exactly and keep every quantile within
    /// one bucket (a factor of `ratio`) of the pooled stream's.
    #[test]
    fn merge_preserves_count_sum_and_quantiles() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut pooled = Histogram::new();
        // two disjoint-ish streams: fast pool vs slow pool
        for i in 0..500u32 {
            let fast = 0.001 + (i as f64) * 1e-5;
            let slow = 0.5 + (i as f64) * 1e-3;
            a.record(fast);
            b.record(slow);
            pooled.record(fast);
            pooled.record(slow);
        }
        let (ca, sa) = (a.count(), a.sum());
        let (cb, sb) = (b.count(), b.sum());
        a.merge(&b);
        // count and sum are exact under merge
        assert_eq!(a.count(), ca + cb);
        assert!((a.sum() - (sa + sb)).abs() < 1e-9);
        assert_eq!(a.count(), pooled.count());
        assert!((a.sum() - pooled.sum()).abs() < 1e-9);
        assert_eq!(a.max(), pooled.max());
        // merged quantiles match the pooled stream to within one
        // bucket of relative error (ratio 1.05)
        for q in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0] {
            let m = a.quantile(q);
            let p = pooled.quantile(q);
            assert!(
                (m / p) < 1.0501 && (p / m) < 1.0501,
                "quantile({q}): merged {m} vs pooled {p}"
            );
        }
    }

    /// Merging in either order lands on the same distribution (bucket
    /// counts add commutatively), and merging an empty histogram is the
    /// identity.
    #[test]
    fn merge_is_commutative_and_empty_is_identity() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for i in 1..=100u32 {
            a.record(i as f64 / 100.0);
            b.record(i as f64 / 10.0);
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.count(), ba.count());
        assert!((ab.sum() - ba.sum()).abs() < 1e-9);
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(ab.quantile(q), ba.quantile(q));
        }
        let before = (a.count(), a.sum(), a.quantile(0.5));
        a.merge(&Histogram::new());
        assert_eq!((a.count(), a.sum(), a.quantile(0.5)), before);
    }
}
