//! Table 1 — NFE / FID* on the CIFAR-10 stand-in (synth-cifar, 16x16)
//! across VP, VP-deep, VE, VE-deep:
//!
//!   Reverse-Diffusion & Langevin | Euler-Maruyama | DDIM (VP)
//!   Ours @ eps_rel in {0.01, 0.02, 0.05, 0.10, 0.50}
//!   Euler-Maruyama / DDIM at the same NFE | Probability Flow (ODE)
//!
//! Scaled testbed defaults: --samples 128, --em-steps 500 (the paper
//! used 50K samples and N=1000 on V100s; orderings are what transfer —
//! see DESIGN.md §2). Raise with flags for slower, tighter runs.
//!
//!   cargo bench --offline --bench table1 -- [--samples N] [--em-steps N]
//!       [--variants vp,ve] [--eps 0.01,...]

#[path = "common.rs"]
mod common;

use common::*;
use gofast::bench::Table;
use gofast::runtime::Runtime;
use gofast::solvers::{adaptive::AdaptiveOpts, prob_flow::OdeOpts, Spec};
use gofast::Result;

fn main() -> Result<()> {
    let args = bench_args();
    let samples = args.usize_or("samples", 64)?;
    let em_steps = args.usize_or("em-steps", 300)?;
    let eps_list = args.f64_list_or("eps", &[0.01, 0.02, 0.05, 0.10, 0.50])?;
    let variants = args.str_list_or("variants", &["vp", "vp_deep", "ve", "ve_deep"]);

    let rt = Runtime::new(&artifacts())?;
    let variants = variants_present(&rt, &variants.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut table = Table::new(&["method", "variant", "NFE", "FID*", "IS*", "wall_s"]);

    for vname in &variants {
        let model = rt.model(vname)?;
        let (net, refstats) = ref_stats(&rt, &model)?;
        let is_vp = model.meta.sde_kind == "vp";
        println!("== variant {vname} ({samples} samples) ==");

        let mut rows: Vec<(String, Spec)> = Vec::new();
        // baselines (paper: RDL best for VE, EM best for VP)
        rows.push(("reverse-diffusion+langevin".into(), Spec::Rdl(em_steps / 2)));
        rows.push(("euler-maruyama".into(), Spec::Em(em_steps)));
        if is_vp {
            rows.push(("ddim".into(), Spec::Ddim(em_steps)));
        }
        // run the static rows
        let mut our_nfes: Vec<(f64, f64)> = Vec::new();
        for (label, spec) in rows {
            let out = generate(&model, &spec, samples, 7)?;
            let (fid, is) = eval_fid(&net, &refstats, &out)?;
            println!("  {label:<34} NFE {:>7} FID* {}", fmt_f(out.mean_nfe, 0), fmt_f(fid, 2));
            table.row(vec![
                label,
                vname.clone(),
                fmt_f(out.mean_nfe, 0),
                fmt_f(fid, 2),
                fmt_f(is, 2),
                format!("{:.1}", out.wall_s),
            ]);
        }
        // ours at each tolerance + matched-budget baselines
        for &eps in &eps_list {
            let out =
                generate(&model, &Spec::Adaptive(AdaptiveOpts::with_eps_rel(eps)), samples, 7)?;
            let (fid, is) = eval_fid(&net, &refstats, &out)?;
            println!(
                "  ours(eps={eps:<5}) {:<19} NFE {:>7} FID* {}",
                "",
                fmt_f(out.mean_nfe, 0),
                fmt_f(fid, 2)
            );
            table.row(vec![
                format!("ours(eps_rel={eps})"),
                vname.clone(),
                fmt_f(out.mean_nfe, 0),
                fmt_f(fid, 2),
                fmt_f(is, 2),
                format!("{:.1}", out.wall_s),
            ]);
            our_nfes.push((eps, out.mean_nfe));
            // EM with the same NFE budget
            let n_match = em_steps_for_nfe(out.mean_nfe);
            let out_em = generate(&model, &Spec::Em(n_match), samples, 7)?;
            let (fid_em, is_em) = eval_fid(&net, &refstats, &out_em)?;
            table.row(vec![
                format!("euler-maruyama(same NFE as eps={eps})"),
                vname.clone(),
                fmt_f(out_em.mean_nfe, 0),
                fmt_f(fid_em, 2),
                fmt_f(is_em, 2),
                format!("{:.1}", out_em.wall_s),
            ]);
            if is_vp {
                let out_dd = generate(&model, &Spec::Ddim(n_match), samples, 7)?;
                let (fid_dd, is_dd) = eval_fid(&net, &refstats, &out_dd)?;
                table.row(vec![
                    format!("ddim(same NFE as eps={eps})"),
                    vname.clone(),
                    fmt_f(out_dd.mean_nfe, 0),
                    fmt_f(fid_dd, 2),
                    fmt_f(is_dd, 2),
                    format!("{:.1}", out_dd.wall_s),
                ]);
            }
        }
        // probability flow ODE
        let out = generate(&model, &Spec::Ode(OdeOpts::default()), samples, 7)?;
        let (fid, is) = eval_fid(&net, &refstats, &out)?;
        println!("  probability-flow (ODE)             NFE {:>7} FID* {}", fmt_f(out.mean_nfe, 0), fmt_f(fid, 2));
        table.row(vec![
            "probability-flow".into(),
            vname.clone(),
            fmt_f(out.mean_nfe, 0),
            fmt_f(fid, 2),
            fmt_f(is, 2),
            format!("{:.1}", out.wall_s),
        ]);
    }
    println!("\n=== Table 1 (scaled: {samples} samples, EM baseline {em_steps} steps) ===\n");
    print!("{}", table.render());
    write_outputs("table1", &table)
}
