//! FID*-vs-NFE through the serving path — the paper's headline
//! quality-vs-speed tradeoff, measured on the same scheduler/registry
//! machinery that serves traffic, so solver *and* scheduler regressions
//! move the same metric.
//!
//! Rows:
//! * served / adaptive — `evaluate` requests against an in-process
//!   engine at a sweep of `eps_rel` tolerances (the adaptive solver's
//!   quality knob; each tolerance is one point of the FID*-vs-NFE curve);
//! * offline / em, ddim — the paper's fixed-step baselines at step
//!   budgets matched to each adaptive run's NFE, through the engine
//!   bypass (the engine's step loop only speaks Algorithm 1).
//!
//! Output: table on stdout, CSV + JSON under bench_out/ (the JSON is
//! uploaded as a CI artifact on main-branch pushes).
//!
//!   cargo bench --offline --bench eval -- [--model vp] [--samples 128]
//!       [--eps 0.02,0.05,0.1,0.2] [--seed 0] [--bucket 16]

#[path = "common.rs"]
mod common;

use common::*;
use gofast::bench::Table;
use gofast::coordinator::{Engine, EngineConfig, EvalRequest};
use gofast::json::Value;
use gofast::runtime::Runtime;
use gofast::solvers::Spec;
use gofast::Result;

struct Row {
    path: &'static str,
    solver: String,
    knob: String,
    mean_nfe: f64,
    fid: f64,
    is: f64,
    wall_s: f64,
}

fn main() -> Result<()> {
    let args = bench_args();
    let dir = artifacts();
    let model_name = args.str_or("model", "vp");
    let samples = args.usize_or("samples", 128)?;
    let eps_list = args.f64_list_or("eps", &[0.02, 0.05, 0.1, 0.2])?;
    let seed = args.u64_or("seed", 0)?;
    let max_bucket = args.usize_or("bucket", 16)?;

    // local runtime for bucket discovery + the offline baseline rows
    let rt = Runtime::new(&dir)?;
    let model = rt.model(&model_name)?;
    let bucket = *model
        .buckets("adaptive_step")
        .iter()
        .filter(|&&b| b <= max_bucket)
        .max()
        .unwrap_or(&model.buckets("adaptive_step")[0]);

    let mut ecfg = EngineConfig::new(&dir, &model_name);
    ecfg.bucket = bucket;
    let engine = Engine::start(ecfg)?;
    let client = engine.client();

    let mut rows: Vec<Row> = Vec::new();
    println!("== eval: model={model_name} samples={samples} bucket={bucket} eps={eps_list:?} ==");
    for &eps in &eps_list {
        let r = client.evaluate(EvalRequest {
            model: String::new(),
            solver: "adaptive".into(),
            samples,
            eps_rel: eps,
            seed,
        })?;
        println!(
            "  [served] adaptive eps={eps} NFE={:.1} FID*={:.3} IS*={:.3} ({:.1}s)",
            r.mean_nfe, r.fid, r.is, r.wall_s
        );
        rows.push(Row {
            path: "served",
            solver: "adaptive".into(),
            knob: format!("eps={eps}"),
            mean_nfe: r.mean_nfe,
            fid: r.fid,
            is: r.is,
            wall_s: r.wall_s,
        });
    }
    let stats = client.stats()?;
    println!(
        "  engine: evals_done={} eval_samples_done={} eval_lane_steps={}",
        stats.evals_done, stats.eval_samples_done, stats.eval_lane_steps
    );

    // offline fixed-step baselines at matched NFE budgets
    let (net, refstats) = ref_stats(&rt, &model)?;
    let adaptive_nfes: Vec<f64> = rows.iter().map(|r| r.mean_nfe).collect();
    for nfe in adaptive_nfes {
        let steps = em_steps_for_nfe(nfe);
        let mut specs = vec![(Spec::Em(steps), "em")];
        if model.meta.sde_kind == "vp" {
            specs.push((Spec::Ddim(steps), "ddim"));
        }
        for (spec, name) in specs {
            let out = generate(&model, &spec, samples, seed)?;
            let (fid, is) = eval_fid(&net, &refstats, &out)?;
            println!(
                "  [offline] {name} steps={steps} NFE={:.1} FID*={:.3} IS*={:.3} ({:.1}s)",
                out.mean_nfe, fid, is, out.wall_s
            );
            rows.push(Row {
                path: "offline",
                solver: name.into(),
                knob: format!("steps={steps}"),
                mean_nfe: out.mean_nfe,
                fid,
                is,
                wall_s: out.wall_s,
            });
        }
    }

    let mut table = Table::new(&["path", "solver", "knob", "mean_nfe", "fid", "is", "wall_s"]);
    for r in &rows {
        table.row(vec![
            r.path.to_string(),
            r.solver.clone(),
            r.knob.clone(),
            fmt_f(r.mean_nfe, 1),
            fmt_f(r.fid, 3),
            fmt_f(r.is, 3),
            fmt_f(r.wall_s, 2),
        ]);
    }
    print!("\n{}", table.render());
    write_outputs("eval", &table)?;

    // machine-readable companion for the CI artifact
    let json_rows: Vec<Value> = rows
        .iter()
        .map(|r| {
            Value::obj(vec![
                ("path", Value::str(r.path)),
                ("solver", Value::str(r.solver.clone())),
                ("knob", Value::str(r.knob.clone())),
                ("mean_nfe", Value::num(r.mean_nfe)),
                ("fid", Value::num(r.fid)),
                ("is", Value::num(r.is)),
                ("wall_s", Value::num(r.wall_s)),
            ])
        })
        .collect();
    let doc = Value::obj(vec![
        ("model", Value::str(model_name.clone())),
        ("samples", Value::num(samples as f64)),
        ("seed", Value::num(seed as f64)),
        ("bucket", Value::num(bucket as f64)),
        ("rows", Value::Arr(json_rows)),
    ]);
    std::fs::create_dir_all("bench_out")?;
    std::fs::write("bench_out/eval.json", format!("{doc}"))?;
    println!("[eval] json -> bench_out/eval.json");
    Ok(())
}
