//! FID*-vs-NFE through the serving path — the paper's headline
//! quality-vs-speed tradeoff (Table 1's fixed-vs-adaptive framing),
//! measured on the same scheduler/registry machinery that serves
//! traffic, so solver *and* scheduler regressions move the same metric.
//!
//! Every solver is *served*: adaptive at a sweep of `eps_rel`
//! tolerances, then EM and DDIM (VP only) at step budgets matched to
//! each adaptive run's NFE — all through `evaluate` requests against an
//! in-process engine's solver-program lane pools. Each served row is
//! paired with its offline per-lane twin (`spec::run_lanes` + the same
//! streaming accumulator), and the CSV/JSON carry the served-vs-offline
//! NFE/FID*/IS* deltas per solver, so the bench doubles as a
//! serving-path parity check (`tools/check_eval.py` enforces thresholds
//! on the JSON in CI).
//!
//! Output: table on stdout, CSV + JSON under bench_out/ (the JSON is
//! uploaded as a CI artifact on main-branch pushes).
//!
//!   cargo bench --offline --bench eval -- [--model vp] [--samples 128]
//!       [--eps 0.02,0.05,0.1,0.2] [--seed 0] [--bucket 16]

#[path = "common.rs"]
mod common;

use common::*;
use gofast::bench::Table;
use gofast::coordinator::{Engine, EngineConfig, EvalRequest};
use gofast::json::Value;
use gofast::runtime::Runtime;
use gofast::solvers::{adaptive, spec, ServingSolver};
use gofast::Result;

struct Row {
    path: &'static str,
    solver: String,
    knob: String,
    mean_nfe: f64,
    fid: f64,
    is: f64,
    wall_s: f64,
    /// served - offline deltas (served rows only).
    d_nfe: Option<f64>,
    d_fid: Option<f64>,
    d_is: Option<f64>,
}

/// Offline per-lane twin of a served evaluation —
/// `spec::evaluate_offline_lanes`, the same implementation behind
/// `gofast evaluate --offline` and the agreement tests.
fn offline_eval(
    model: &gofast::runtime::Model,
    net: &gofast::runtime::FidNet,
    refstats: &gofast::metrics::FeatureStats,
    solver: ServingSolver,
    samples: usize,
    eps_rel: f64,
    seed: u64,
    cap: usize,
) -> Result<(f64, f64, f64, f64)> {
    let opts = adaptive::AdaptiveOpts { eps_rel, ..Default::default() };
    let r = spec::evaluate_offline_lanes(model, net, refstats, solver, samples, seed, &opts, cap)?;
    Ok((r.fid, r.is, r.mean_nfe, r.wall_s))
}

fn main() -> Result<()> {
    let args = bench_args();
    let dir = artifacts();
    let model_name = args.str_or("model", "vp");
    let samples = args.usize_or("samples", 128)?;
    let eps_list = args.f64_list_or("eps", &[0.02, 0.05, 0.1, 0.2])?;
    let seed = args.u64_or("seed", 0)?;
    let max_bucket = args.usize_or("bucket", 16)?;

    // local runtime for bucket discovery + the offline twin rows
    let rt = Runtime::new(&dir)?;
    let model = rt.model(&model_name)?;
    let (net, refstats) = ref_stats(&rt, &model)?;
    let is_vp = model.meta.sde_kind == "vp";
    let bucket = engine_bucket(&model, max_bucket);
    // a fixed-step pool exists only when a rung fits under the engine cap
    let has_ddim = model.buckets("ddim_step").iter().any(|&b| b <= bucket);
    let has_pc = model.buckets("pc_step").iter().any(|&b| b <= bucket);

    let mut ecfg = EngineConfig::new(&dir, &model_name);
    ecfg.bucket = bucket;
    let engine = Engine::start(ecfg)?;
    let client = engine.client();

    let mut rows: Vec<Row> = Vec::new();
    println!("== eval: model={model_name} samples={samples} bucket={bucket} eps={eps_list:?} ==");

    // one served + offline pair per (solver, knob); returns the served
    // mean NFE (to match fixed-step budgets to the adaptive sweep)
    let mut measure = |solver: ServingSolver, eps_rel: f64, knob: String| -> Result<f64> {
        let r = client.evaluate(EvalRequest {
            model: String::new(),
            solver,
            samples,
            eps_rel,
            seed,
            priority: None,
        })?;
        let (off_fid, off_is, off_nfe, off_wall) =
            offline_eval(&model, &net, &refstats, solver, samples, eps_rel, seed, max_bucket)?;
        println!(
            "  [served]  {} {knob} NFE={:.1} FID*={:.3} IS*={:.3} ({:.1}s)  \
             [offline d_nfe={:+.1e} d_fid={:+.1e}]",
            solver.name(),
            r.mean_nfe,
            r.fid,
            r.is,
            r.wall_s,
            r.mean_nfe - off_nfe,
            r.fid - off_fid,
        );
        rows.push(Row {
            path: "served",
            solver: solver.name().into(),
            knob: knob.clone(),
            mean_nfe: r.mean_nfe,
            fid: r.fid,
            is: r.is,
            wall_s: r.wall_s,
            d_nfe: Some(r.mean_nfe - off_nfe),
            d_fid: Some(r.fid - off_fid),
            d_is: Some(r.is - off_is),
        });
        let served_nfe = r.mean_nfe;
        rows.push(Row {
            path: "offline",
            solver: solver.name().into(),
            knob,
            mean_nfe: off_nfe,
            fid: off_fid,
            is: off_is,
            wall_s: off_wall,
            d_nfe: None,
            d_fid: None,
            d_is: None,
        });
        Ok(served_nfe)
    };

    let mut adaptive_nfes: Vec<f64> = Vec::new();
    for &eps in &eps_list {
        adaptive_nfes.push(measure(ServingSolver::Adaptive, eps, format!("eps={eps}"))?);
    }
    // the paper's fixed-step baselines at matched NFE budgets — served
    // from their own lane pools (Table 1's EM / DDIM / Reverse-Diffusion
    // + Langevin rows)
    for nfe in adaptive_nfes {
        let steps = em_steps_for_nfe(nfe);
        measure(ServingSolver::Em { steps }, 0.05, format!("steps={steps}"))?;
        if is_vp && has_ddim {
            measure(ServingSolver::Ddim { steps }, 0.05, format!("steps={steps}"))?;
        }
        if has_pc {
            // PC pays 2 score evals per predictor step: half the steps
            // for the same budget (process-default Langevin SNR)
            let steps = pc_steps_for_nfe(nfe);
            measure(ServingSolver::Pc { steps, snr: None }, 0.05, format!("steps={steps}"))?;
        }
    }
    if !(is_vp && has_ddim) {
        println!("  (ddim rows skipped: model is not VP or has no ddim_step artifacts)");
    }
    if !has_pc {
        println!("  (pc rows skipped: no pc_step artifacts at or below the engine bucket)");
    }

    let stats = client.stats()?;
    println!(
        "  engine: evals_done={} eval_samples_done={} eval_lane_steps={}",
        stats.evals_done, stats.eval_samples_done, stats.eval_lane_steps
    );
    for p in &stats.programs {
        println!(
            "  program {}: steps={} occupied_lane_steps={} wasted_lane_steps={}",
            p.solver, p.steps, p.occupied_lane_steps, p.wasted_lane_steps
        );
    }

    let fmt_d = |v: Option<f64>| v.map(|d| format!("{d:+.3e}")).unwrap_or_default();
    let mut table = Table::new(&[
        "path", "solver", "knob", "mean_nfe", "fid", "is", "wall_s", "d_nfe", "d_fid",
    ]);
    for r in &rows {
        table.row(vec![
            r.path.to_string(),
            r.solver.clone(),
            r.knob.clone(),
            fmt_f(r.mean_nfe, 1),
            fmt_f(r.fid, 3),
            fmt_f(r.is, 3),
            fmt_f(r.wall_s, 2),
            fmt_d(r.d_nfe),
            fmt_d(r.d_fid),
        ]);
    }
    print!("\n{}", table.render());
    write_outputs("eval", &table)?;

    // machine-readable companion for the CI artifact; `parity` is what
    // tools/check_eval.py enforces thresholds on
    let json_rows: Vec<Value> = rows
        .iter()
        .map(|r| {
            let mut pairs = vec![
                ("path", Value::str(r.path)),
                ("solver", Value::str(r.solver.clone())),
                ("knob", Value::str(r.knob.clone())),
                ("mean_nfe", Value::num(r.mean_nfe)),
                ("fid", Value::num(r.fid)),
                ("is", Value::num(r.is)),
                ("wall_s", Value::num(r.wall_s)),
            ];
            if let (Some(dn), Some(df), Some(di)) = (r.d_nfe, r.d_fid, r.d_is) {
                pairs.push(("d_nfe", Value::num(dn)));
                pairs.push(("d_fid", Value::num(df)));
                pairs.push(("d_is", Value::num(di)));
            }
            Value::obj(pairs)
        })
        .collect();
    let parity: Vec<Value> = rows
        .iter()
        .filter(|r| r.path == "served")
        .map(|r| {
            Value::obj(vec![
                ("solver", Value::str(r.solver.clone())),
                ("knob", Value::str(r.knob.clone())),
                ("fid", Value::num(r.fid)),
                ("is", Value::num(r.is)),
                ("d_nfe", Value::num(r.d_nfe.unwrap_or(f64::NAN))),
                ("d_fid", Value::num(r.d_fid.unwrap_or(f64::NAN))),
                ("d_is", Value::num(r.d_is.unwrap_or(f64::NAN))),
            ])
        })
        .collect();
    let doc = Value::obj(vec![
        ("model", Value::str(model_name.clone())),
        ("samples", Value::num(samples as f64)),
        ("seed", Value::num(seed as f64)),
        ("bucket", Value::num(bucket as f64)),
        ("rows", Value::Arr(json_rows)),
        ("parity", Value::Arr(parity)),
    ]);
    std::fs::create_dir_all("bench_out")?;
    std::fs::write("bench_out/eval.json", format!("{doc}"))?;
    println!("[eval] json -> bench_out/eval.json");
    Ok(())
}
