//! Table 6 (paper Appendix E) — Inception Score* on the CIFAR-10
//! stand-in for every method and variant: RDL, EM, ours @ eps grid,
//! probability flow.
//!
//!   cargo bench --offline --bench table6 -- [--samples N]

#[path = "common.rs"]
mod common;

use common::*;
use gofast::bench::Table;
use gofast::runtime::Runtime;
use gofast::solvers::{adaptive::AdaptiveOpts, prob_flow::OdeOpts, Spec};
use gofast::Result;

fn main() -> Result<()> {
    let args = bench_args();
    let samples = args.usize_or("samples", 64)?;
    let em_steps = args.usize_or("em-steps", 300)?;
    let variants = args.str_list_or("variants", &["vp", "vp_deep", "ve", "ve_deep"]);

    let rt = Runtime::new(&artifacts())?;
    let variants = variants_present(&rt, &variants.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let methods: Vec<(String, fn(usize) -> Spec, f64)> = Vec::new();
    drop(methods);

    let mut table = Table::new(&["method", "variant", "IS*"]);
    for vname in &variants {
        let model = rt.model(vname)?;
        let (net, refstats) = ref_stats(&rt, &model)?;
        println!("== IS* on {vname} ==");
        let mut specs: Vec<(String, Spec)> = vec![
            ("reverse-diffusion+langevin".into(), Spec::Rdl(em_steps / 2)),
            ("euler-maruyama".into(), Spec::Em(em_steps)),
        ];
        for eps in [0.01, 0.02, 0.05, 0.10, 0.50] {
            specs.push((
                format!("ours(eps_rel={eps})"),
                Spec::Adaptive(AdaptiveOpts::with_eps_rel(eps)),
            ));
        }
        specs.push(("probability-flow".into(), Spec::Ode(OdeOpts::default())));
        for (label, spec) in specs {
            let out = generate(&model, &spec, samples, 13)?;
            let (_, is) = eval_fid(&net, &refstats, &out)?;
            println!("  {label:<32} IS* {}", fmt_f(is, 2));
            table.row(vec![label, vname.clone(), fmt_f(is, 2)]);
        }
    }
    println!("\n=== Table 6 ({samples} samples) ===\n");
    print!("{}", table.render());
    write_outputs("table6", &table)
}
