//! Microbenchmarks for the §Perf pass (EXPERIMENTS.md):
//!   * per-call latency of every program by batch bucket;
//!   * literal path (theta re-uploaded each call) vs buffer path
//!     (device-resident theta) — the L3 execution-mode lever;
//!   * fused adaptive_step vs composed (2x score + host math) — the L2
//!     graph-granularity lever;
//!   * host-side overhead of one engine iteration (noise gen + copies).
//!
//!   cargo bench --offline --bench perf -- [--iters 20] [--model vp]

#[path = "common.rs"]
mod common;

use common::*;
use gofast::bench::{summarize, time_iters, Table};
use gofast::rng::Rng;
use gofast::runtime::Runtime;
use gofast::solvers::{adaptive, Ctx, SolveOpts};
use gofast::tensor::Tensor;
use gofast::Result;

fn main() -> Result<()> {
    let args = bench_args();
    let iters = args.usize_or("iters", 10)?;
    let model_name = args.str_or("model", "vp");
    let rt = Runtime::new(&artifacts())?;
    let model = rt.model(&model_name)?;
    let dim = model.meta.dim;
    let mut table = Table::new(&["benchmark", "bucket", "p50", "mean", "per-sample"]);

    // --- program call latency, literal vs buffer path -----------------------
    for program in ["score", "em_step", "adaptive_step"] {
        for &b in model.buckets(program) {
            let x = Tensor::zeros(&[b, dim]);
            let t = Tensor { shape: vec![b], data: vec![0.5; b] };
            let h = Tensor { shape: vec![b], data: vec![0.01; b] };
            let z = Tensor::zeros(&[b, dim]);
            let ea = Tensor::scalar(0.0078);
            let er = Tensor { shape: vec![b], data: vec![0.05; b] };
            let inputs: Vec<&Tensor> = match program {
                "score" => vec![&x, &t],
                "em_step" => vec![&x, &t, &h, &z],
                _ => vec![&x, &x, &t, &h, &z, &ea, &er],
            };
            for (mode, fused) in [("literal", false), ("buffer", true)] {
                let times = time_iters(3, iters, || {
                    model.exec(program, b, &inputs, fused).expect("exec");
                });
                let s = summarize(times);
                table.row(vec![
                    format!("{program} ({mode})"),
                    format!("{b}"),
                    gofast::bench::fmt_duration(s.p50),
                    gofast::bench::fmt_duration(s.mean),
                    gofast::bench::fmt_duration(s.p50 / b as f64),
                ]);
            }
        }
    }

    // --- fused vs composed full solve ----------------------------------------
    let bucket = *model.buckets("adaptive_step").last().unwrap();
    let ctx = Ctx::new(&model, bucket, SolveOpts::default());
    let opts = adaptive::AdaptiveOpts::with_eps_rel(0.05);
    for (label, composed) in [("solve fused", false), ("solve composed", true)] {
        let times = time_iters(1, 3, || {
            let mut rng = Rng::new(5);
            if composed {
                adaptive::run_composed(&ctx, &mut rng, &opts).expect("solve");
            } else {
                adaptive::run_fused(&ctx, &mut rng, &opts).expect("solve");
            }
        });
        let s = summarize(times);
        table.row(vec![
            label.into(),
            format!("{bucket}"),
            gofast::bench::fmt_duration(s.p50),
            gofast::bench::fmt_duration(s.mean),
            gofast::bench::fmt_duration(s.p50 / bucket as f64),
        ]);
    }

    // --- host-side overhead: noise + copies for one engine iteration ---------
    {
        let mut rng = Rng::new(1);
        let mut z = Tensor::zeros(&[bucket, dim]);
        let times = time_iters(3, iters, || {
            rng.fill_normal(&mut z.data);
        });
        let s = summarize(times);
        table.row(vec![
            "host: batch noise gen".into(),
            format!("{bucket}"),
            gofast::bench::fmt_duration(s.p50),
            gofast::bench::fmt_duration(s.mean),
            gofast::bench::fmt_duration(s.p50 / bucket as f64),
        ]);
    }

    println!("\n=== perf microbenchmarks (model {model_name}) ===\n");
    print!("{}", table.render());
    write_outputs("perf", &table)
}
