//! Microbenchmarks for the §Perf pass (EXPERIMENTS.md):
//!   * per-call latency of every program by batch bucket;
//!   * literal path (theta re-uploaded each call) vs buffer path
//!     (device-resident theta) — the L3 execution-mode lever;
//!   * fused adaptive_step vs composed (2x score + host math) — the L2
//!     graph-granularity lever;
//!   * host-side overhead of one engine iteration (noise gen + copies);
//!   * dispatch amortisation: the same em run at steps-per-dispatch
//!     k in {1, 4, 8} — dispatch count, host<->device bytes per sample,
//!     and a bitwise output comparison against k = 1. Results land in
//!     bench_out/perf_dispatch.json, gated in CI by
//!     tools/check_perf.py.
//!
//!   cargo bench --offline --bench perf -- [--iters 20] [--model vp]
//!       [--dispatch-steps 1000] [--dispatch-samples 4]

#[path = "common.rs"]
mod common;

use common::*;
use gofast::bench::{summarize, time_iters, Table};
use gofast::coordinator::{Engine, EngineConfig};
use gofast::json::Value;
use gofast::rng::Rng;
use gofast::runtime::Runtime;
use gofast::solvers::{adaptive, Ctx, ServingSolver, SolveOpts};
use gofast::tensor::Tensor;
use gofast::Result;

fn main() -> Result<()> {
    let args = bench_args();
    let iters = args.usize_or("iters", 10)?;
    let model_name = args.str_or("model", "vp");
    let rt = Runtime::new(&artifacts())?;
    let model = rt.model(&model_name)?;
    let dim = model.meta.dim;
    let mut table = Table::new(&["benchmark", "bucket", "p50", "mean", "per-sample"]);

    // --- program call latency, literal vs buffer path -----------------------
    for program in ["score", "em_step", "adaptive_step"] {
        for &b in model.buckets(program) {
            let x = Tensor::zeros(&[b, dim]);
            let t = Tensor { shape: vec![b], data: vec![0.5; b] };
            let h = Tensor { shape: vec![b], data: vec![0.01; b] };
            let z = Tensor::zeros(&[b, dim]);
            let ea = Tensor::scalar(0.0078);
            let er = Tensor { shape: vec![b], data: vec![0.05; b] };
            let inputs: Vec<&Tensor> = match program {
                "score" => vec![&x, &t],
                "em_step" => vec![&x, &t, &h, &z],
                _ => vec![&x, &x, &t, &h, &z, &ea, &er],
            };
            for (mode, fused) in [("literal", false), ("buffer", true)] {
                let times = time_iters(3, iters, || {
                    model.exec(program, b, &inputs, fused).expect("exec");
                });
                let s = summarize(times);
                table.row(vec![
                    format!("{program} ({mode})"),
                    format!("{b}"),
                    gofast::bench::fmt_duration(s.p50),
                    gofast::bench::fmt_duration(s.mean),
                    gofast::bench::fmt_duration(s.p50 / b as f64),
                ]);
            }
        }
    }

    // --- fused vs composed full solve ----------------------------------------
    let bucket = *model.buckets("adaptive_step").last().unwrap();
    let ctx = Ctx::new(&model, bucket, SolveOpts::default());
    let opts = adaptive::AdaptiveOpts::with_eps_rel(0.05);
    for (label, composed) in [("solve fused", false), ("solve composed", true)] {
        let times = time_iters(1, 3, || {
            let mut rng = Rng::new(5);
            if composed {
                adaptive::run_composed(&ctx, &mut rng, &opts).expect("solve");
            } else {
                adaptive::run_fused(&ctx, &mut rng, &opts).expect("solve");
            }
        });
        let s = summarize(times);
        table.row(vec![
            label.into(),
            format!("{bucket}"),
            gofast::bench::fmt_duration(s.p50),
            gofast::bench::fmt_duration(s.mean),
            gofast::bench::fmt_duration(s.p50 / bucket as f64),
        ]);
    }

    // --- host-side overhead: noise + copies for one engine iteration ---------
    {
        let mut rng = Rng::new(1);
        let mut z = Tensor::zeros(&[bucket, dim]);
        let times = time_iters(3, iters, || {
            rng.fill_normal(&mut z.data);
        });
        let s = summarize(times);
        table.row(vec![
            "host: batch noise gen".into(),
            format!("{bucket}"),
            gofast::bench::fmt_duration(s.p50),
            gofast::bench::fmt_duration(s.mean),
            gofast::bench::fmt_duration(s.p50 / bucket as f64),
        ]);
    }

    println!("\n=== perf microbenchmarks (model {model_name}) ===\n");
    print!("{}", table.render());
    write_outputs("perf", &table)?;

    // --- dispatch amortisation: em + adaptive at steps-per-dispatch 1/4/8 ----
    // The same request (model, solver, n, seed) through engines that
    // differ only in k. Bit-identical outputs are part of the contract
    // (fixed-step fused kernels consume pre-drawn noise on the same
    // streams; the adaptive fold additionally replays the device
    // attempt log through the host controller, so NFE, score_evals and
    // rejections must all match k = 1 exactly), so the sweep both
    // measures the dispatch/byte savings and asserts the equivalence
    // tools/check_perf.py gates on.
    let em_steps = args.usize_or("dispatch-steps", 1000)?;
    let n = args.usize_or("dispatch-samples", 4)?;
    let ebucket = engine_bucket(&model, args.usize_or("bucket", 16)?);
    let cases: [(&str, String, ServingSolver); 2] = [
        ("em", format!("em:{em_steps}"), ServingSolver::Em { steps: em_steps }),
        ("adaptive", "adaptive".to_string(), ServingSolver::Adaptive),
    ];
    let mut sweeps = Vec::new();
    for (program, label, solver) in cases {
        let mut disp_table = Table::new(&[
            "k", "dispatches", "score_evals", "nfe_total", "rejections", "h2d_bytes",
            "d2h_bytes", "bytes/sample", "wall", "match_k1",
        ]);
        let mut sweep = Vec::new();
        let mut baseline: Option<Vec<f32>> = None; // k=1 images
        println!("\n== dispatch amortisation: {label}, n={n}, bucket {ebucket} ==");
        for k in [1usize, 4, 8] {
            let mut cfg = EngineConfig::new("artifacts", &model_name);
            cfg.bucket = ebucket;
            cfg.programs = vec![program.to_string()];
            cfg.steps_per_dispatch = k;
            let engine = Engine::start(cfg)?;
            let client = engine.client();
            let t0 = std::time::Instant::now();
            let r = match client.generate_with("", solver, n, 0.05, 11) {
                Ok(r) => r,
                Err(e) => {
                    // pre-fused artifact sets un-serve the pool at k > 1;
                    // skip the gate file rather than write a partial sweep
                    println!("  k={k}: not served ({e:#}); skipping perf_dispatch.json");
                    println!(
                        "  (rebuild artifacts with fused k-step variants: make artifacts)"
                    );
                    return Ok(());
                }
            };
            let wall = t0.elapsed().as_secs_f64();
            let stats = client.stats()?;
            drop(engine);
            let nfe_total: u64 = r.nfe.iter().sum();
            let matches = match &baseline {
                None => {
                    baseline = Some(r.images.data.clone());
                    true
                }
                Some(img1) => img1[..] == r.images.data[..],
            };
            let bytes_per_sample = (stats.bytes_h2d + stats.bytes_d2h) as f64 / n as f64;
            println!(
                "  k={k}: dispatches {} score_evals {} nfe {} rejections {} h2d {} d2h {} \
                 ({:.0} B/sample) wall {wall:.2}s match {matches}",
                stats.dispatches, stats.score_evals, nfe_total, stats.rejections,
                stats.bytes_h2d, stats.bytes_d2h, bytes_per_sample,
            );
            disp_table.row(vec![
                format!("{k}"),
                format!("{}", stats.dispatches),
                format!("{}", stats.score_evals),
                format!("{nfe_total}"),
                format!("{}", stats.rejections),
                format!("{}", stats.bytes_h2d),
                format!("{}", stats.bytes_d2h),
                format!("{bytes_per_sample:.0}"),
                format!("{wall:.2}s"),
                format!("{matches}"),
            ]);
            sweep.push(Value::obj(vec![
                ("k", Value::num(k as f64)),
                ("dispatches", Value::num(stats.dispatches as f64)),
                ("score_evals", Value::num(stats.score_evals as f64)),
                ("nfe_total", Value::num(nfe_total as f64)),
                ("rejections", Value::num(stats.rejections as f64)),
                ("bytes_h2d", Value::num(stats.bytes_h2d as f64)),
                ("bytes_d2h", Value::num(stats.bytes_d2h as f64)),
                ("bytes_per_sample", Value::num(bytes_per_sample)),
                ("wall_s", Value::num(wall)),
                ("outputs_match", Value::Bool(matches)),
            ]));
        }
        println!("\n=== perf: dispatch amortisation ({label}) ===\n");
        print!("{}", disp_table.render());
        write_outputs(&format!("perf_dispatch_{program}"), &disp_table)?;
        sweeps.push(Value::obj(vec![
            ("solver", Value::str(label)),
            ("samples", Value::num(n as f64)),
            ("bucket", Value::num(ebucket as f64)),
            ("sweep", Value::Arr(sweep)),
        ]));
    }
    let doc = Value::obj(vec![
        ("model", Value::str(&model_name)),
        ("samples", Value::num(n as f64)),
        ("bucket", Value::num(ebucket as f64)),
        ("sweeps", Value::Arr(sweeps)),
    ]);
    std::fs::create_dir_all("bench_out")?;
    std::fs::write("bench_out/perf_dispatch.json", format!("{doc}"))?;
    println!("[perf_dispatch] json -> bench_out/perf_dispatch.json");
    Ok(())
}
