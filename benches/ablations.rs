//! Tables 4 & 5 (paper Appendix B) — ablations of Algorithm 1 on the VP
//! and VE CIFAR-stand-in models:
//!
//!   no change [q=2, r=0.9, delta(x', x'_prev)]
//!   delta(x') only (Eq. 4)            | no extrapolation (EM proposal)
//!   q = inf                           | r in {0.5, 0.8, 1.0}
//!   Lamba integration variants (r=0.5; +extrapolation; q=inf; theta=0.8)
//!
//! Run with --process vp (Table 4) or --process ve (Table 5); default both.
//!
//!   cargo bench --offline --bench ablations -- [--samples N] [--process vp|ve]

#[path = "common.rs"]
mod common;

use common::*;
use gofast::bench::Table;
use gofast::runtime::Runtime;
use gofast::solvers::adaptive::{AdaptiveOpts, ErrNorm};
use gofast::solvers::lamba::LambaOpts;
use gofast::solvers::Spec;
use gofast::Result;

fn main() -> Result<()> {
    let args = bench_args();
    let samples = args.usize_or("samples", 48)?;
    let eps = args.f64_or("eps-rel", 0.02)?; // paper App. B ran the tight setting
    let processes = args.str_list_or("process", &["vp", "ve"]);

    let rt = Runtime::new(&artifacts())?;
    let mut table = Table::new(&["change", "process", "IS*", "FID*", "NFE", "reject%"]);

    for pname in &processes {
        let model = rt.model(pname)?;
        let (net, refstats) = ref_stats(&rt, &model)?;
        println!("== ablations on {pname} (Table {}) ==", if pname == "vp" { 4 } else { 5 });

        let base = AdaptiveOpts { eps_rel: eps, ..Default::default() };
        let rows: Vec<(&str, Spec)> = vec![
            ("no change [q=2, r=0.9, delta(x',x'prev)]", Spec::AdaptiveComposed(base)),
            (
                "delta(x')",
                Spec::AdaptiveComposed(AdaptiveOpts { prev_in_delta: false, ..base }),
            ),
            (
                "no extrapolation (Euler-Maruyama)",
                Spec::AdaptiveComposed(AdaptiveOpts { extrapolate: false, ..base }),
            ),
            (
                "q = inf",
                Spec::AdaptiveComposed(AdaptiveOpts { norm: ErrNorm::LInf, ..base }),
            ),
            ("r = 0.5", Spec::AdaptiveComposed(AdaptiveOpts { r: 0.5, ..base })),
            ("r = 0.8", Spec::AdaptiveComposed(AdaptiveOpts { r: 0.8, ..base })),
            ("r = 1.0", Spec::AdaptiveComposed(AdaptiveOpts { r: 1.0, ..base })),
            (
                "r=0.5, Lamba integration",
                Spec::Lamba(LambaOpts { eps_rel: eps, norm: ErrNorm::L2, ..Default::default() }),
            ),
            (
                "r=0.5, Lamba integration, extrapolation",
                Spec::Lamba(LambaOpts {
                    eps_rel: eps,
                    norm: ErrNorm::L2,
                    extrapolate: true,
                    ..Default::default()
                }),
            ),
            (
                "r=0.5, Lamba integration, q=inf",
                Spec::Lamba(LambaOpts { eps_rel: eps, ..Default::default() }),
            ),
            (
                "r=0.5, Lamba integration, q=inf, theta=0.8",
                Spec::Lamba(LambaOpts { eps_rel: eps, safety: 0.8, ..Default::default() }),
            ),
        ];
        for (label, spec) in rows {
            let out = generate(&model, &spec, samples, 5)?;
            let (fid, is) = eval_fid(&net, &refstats, &out)?;
            let steps_attempted = if out.mean_nfe.is_nan() {
                f64::NAN
            } else {
                100.0 * out.rejections as f64
                    / ((out.mean_nfe * samples as f64 / 2.0) + out.rejections as f64)
            };
            println!(
                "  {label:<44} IS* {:>5} FID* {:>8} NFE {:>7}",
                fmt_f(is, 2),
                fmt_f(fid, 2),
                fmt_f(out.mean_nfe, 0)
            );
            table.row(vec![
                label.to_string(),
                pname.clone(),
                fmt_f(is, 2),
                fmt_f(fid, 2),
                fmt_f(out.mean_nfe, 0),
                fmt_f(steps_attempted, 1),
            ]);
        }
    }
    println!("\n=== Tables 4-5 (eps_rel={eps}, {samples} samples) ===\n");
    print!("{}", table.render());
    write_outputs("ablations", &table)
}
