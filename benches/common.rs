//! Shared bench plumbing: artifact discovery, reference FID* stats,
//! batched generation with a `Spec`, CSV output under bench_out/.
//! Included by every paper-table bench via `#[path = "common.rs"]`.

#![allow(dead_code)]

use gofast::bench::Table;
use gofast::cli::Args;
use gofast::metrics::{self, FeatureStats};
use gofast::rng::Rng;
use gofast::runtime::{FidNet, Model, Runtime};
use gofast::solvers::{Ctx, SolveOpts, Spec};
use gofast::tensor::Tensor;
use gofast::{Context, Result};
use std::path::PathBuf;

pub fn bench_args() -> Args {
    // cargo bench passes "--bench" through; drop it and any bare positionals
    let items = std::env::args().skip(1).filter(|a| a != "--bench");
    Args::parse(items).expect("parsing bench args")
}

pub fn artifacts() -> PathBuf {
    let p = PathBuf::from("artifacts");
    if !p.join("manifest.json").exists() {
        eprintln!("bench skipped: artifacts/manifest.json missing (run `make artifacts`)");
        std::process::exit(0);
    }
    p
}

/// Reference feature stats for a model's eval dataset split (shared
/// helper — the same reference the engine's eval lanes fit against).
pub fn ref_stats<'rt>(rt: &'rt Runtime, model: &Model) -> Result<(FidNet<'rt>, FeatureStats)> {
    metrics::reference_for(rt, &model.meta)
        .context("fid reference missing — rerun `make artifacts`")
}

/// Widest compiled `adaptive_step` bucket <= `cap` (falling back to the
/// smallest rung), so benches run unmodified on the miniature CI
/// artifact set with its (1, 2) ladder.
pub fn engine_bucket(model: &Model, cap: usize) -> usize {
    let buckets = model.buckets("adaptive_step");
    *buckets.iter().filter(|&&b| b <= cap).max().unwrap_or(&buckets[0])
}

pub struct GenOutcome {
    pub images_unit: Tensor,
    pub mean_nfe: f64,
    pub rejections: u64,
    pub wall_s: f64,
    pub converged: bool,
}

/// Generate `samples` images with `spec`, batching at the model's widest
/// bucket. A solver error (divergence guard) is reported as
/// converged=false rather than aborting the table.
pub fn generate(model: &Model, spec: &Spec, samples: usize, seed: u64) -> Result<GenOutcome> {
    let bucket = *model.buckets("adaptive_step").last().unwrap();
    let ctx = Ctx::new(model, bucket, SolveOpts::default());
    let mut rng = Rng::new(seed);
    let mut images = Tensor::zeros(&[samples, model.meta.dim]);
    let mut nfe_sum = 0u64;
    let mut rejections = 0u64;
    let t0 = std::time::Instant::now();
    let mut done = 0;
    while done < samples {
        let take = (samples - done).min(bucket);
        match spec.run(&ctx, &mut rng) {
            Ok(res) => {
                for i in 0..take {
                    images.row_mut(done + i).copy_from_slice(res.x.row(i));
                }
                nfe_sum += res.nfe_per_sample[..take].iter().sum::<u64>();
                rejections += res.rejections;
                done += take;
            }
            Err(e) => {
                eprintln!("  [{}] did not converge: {e:#}", spec.name());
                return Ok(GenOutcome {
                    images_unit: images,
                    mean_nfe: f64::NAN,
                    rejections,
                    wall_s: t0.elapsed().as_secs_f64(),
                    converged: false,
                });
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    model.meta.process().to_unit_range(&mut images);
    Ok(GenOutcome {
        images_unit: images,
        mean_nfe: nfe_sum as f64 / samples as f64,
        rejections,
        wall_s: wall,
        converged: true,
    })
}

/// Evaluate FID*/IS* for an outcome.
pub fn eval_fid(
    net: &FidNet,
    refstats: &FeatureStats,
    out: &GenOutcome,
) -> Result<(f64, f64)> {
    if !out.converged {
        return Ok((f64::NAN, f64::NAN));
    }
    metrics::evaluate(net, &out.images_unit, refstats)
}

pub fn write_outputs(name: &str, table: &Table) -> Result<()> {
    std::fs::create_dir_all("bench_out")?;
    let csv_path = format!("bench_out/{name}.csv");
    std::fs::write(&csv_path, table.to_csv())?;
    println!("\n[{name}] csv -> {csv_path}");
    Ok(())
}

pub fn fmt_f(v: f64, prec: usize) -> String {
    if v.is_nan() {
        "diverged".to_string()
    } else {
        format!("{v:.prec$}")
    }
}

/// Round a mean NFE to the nearest EM step count with the same budget.
pub fn em_steps_for_nfe(nfe: f64) -> usize {
    (nfe.round() as usize).saturating_sub(1).max(2) // minus the denoise eval
}

/// Round a mean NFE to the nearest PC predictor-step count with the
/// same budget (each predictor step costs 2 score evals, plus denoise).
pub fn pc_steps_for_nfe(nfe: f64) -> usize {
    (((nfe - 1.0) / 2.0).round() as usize).max(1)
}

pub fn variants_present(rt: &Runtime, wanted: &[&str]) -> Vec<String> {
    let have = rt.variant_names();
    wanted.iter().filter(|w| have.iter().any(|h| h == *w)).map(|s| s.to_string()).collect()
}
