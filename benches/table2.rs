//! Table 2 — NFE / FID* at "high" resolution (synth-church / synth-ffhq,
//! 32x32 = 3072-dim, the paper's 256^2 axis scaled to this testbed):
//! RDL, EM, ours @ eps_rel, EM @ same NFE, probability flow.
//!
//! The paper's headline here: EM cannot converge on moderate budgets in
//! high dimension while the adaptive solver can, and probability flow
//! falls apart entirely.
//!
//!   cargo bench --offline --bench table2 -- [--samples N] [--em-steps N]

#[path = "common.rs"]
mod common;

use common::*;
use gofast::bench::Table;
use gofast::runtime::Runtime;
use gofast::solvers::{adaptive::AdaptiveOpts, prob_flow::OdeOpts, Spec};
use gofast::Result;

fn main() -> Result<()> {
    let args = bench_args();
    let samples = args.usize_or("samples", 32)?;
    let em_steps = args.usize_or("em-steps", 400)?;
    let eps_list = args.f64_list_or("eps", &[0.01, 0.02, 0.05, 0.10])?;
    let variants = args.str_list_or("variants", &["ve_church", "ve_ffhq"]);

    let rt = Runtime::new(&artifacts())?;
    let variants = variants_present(&rt, &variants.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut table = Table::new(&["method", "variant", "NFE", "FID*", "IS*", "wall_s"]);

    for vname in &variants {
        let model = rt.model(vname)?;
        let (net, refstats) = ref_stats(&rt, &model)?;
        println!("== variant {vname} ({samples} samples) ==");
        let run = |label: String, spec: Spec, table: &mut Table| -> Result<f64> {
            let out = generate(&model, &spec, samples, 11)?;
            let (fid, is) = eval_fid(&net, &refstats, &out)?;
            println!("  {label:<40} NFE {:>7} FID* {}", fmt_f(out.mean_nfe, 0), fmt_f(fid, 2));
            table.row(vec![
                label,
                vname.clone(),
                fmt_f(out.mean_nfe, 0),
                fmt_f(fid, 2),
                fmt_f(is, 2),
                format!("{:.1}", out.wall_s),
            ]);
            Ok(out.mean_nfe)
        };
        run("reverse-diffusion+langevin".into(), Spec::Rdl(em_steps), &mut table)?;
        run("euler-maruyama".into(), Spec::Em(em_steps), &mut table)?;
        for &eps in &eps_list {
            let nfe = run(
                format!("ours(eps_rel={eps})"),
                Spec::Adaptive(AdaptiveOpts::with_eps_rel(eps)),
                &mut table,
            )?;
            run(
                format!("euler-maruyama(same NFE as eps={eps})"),
                Spec::Em(em_steps_for_nfe(nfe)),
                &mut table,
            )?;
        }
        run("probability-flow".into(), Spec::Ode(OdeOpts::default()), &mut table)?;
    }
    println!("\n=== Table 2 (scaled: {samples} samples, EM baseline {em_steps} steps) ===\n");
    print!("{}", table.render());
    write_outputs("table2", &table)
}
