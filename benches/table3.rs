//! Table 3 (paper Appendix A) — off-the-shelf SDE solvers vs EM on the
//! VP model: relative wall-clock speed at comparable quality, and
//! convergence behaviour. Reproduces the qualitative finding that
//! higher-order / generic adaptive schemes are slower than fixed-step EM
//! on score-based SDEs, with Lamba-style low-order adaptivity the only
//! competitive family.
//!
//!   cargo bench --offline --bench table3 -- [--samples N] [--em-steps N]

#[path = "common.rs"]
mod common;

use common::*;
use gofast::bench::Table;
use gofast::runtime::Runtime;
use gofast::solvers::{lamba::LambaOpts, table3::Sra1Opts, Spec};
use gofast::Result;

fn main() -> Result<()> {
    let args = bench_args();
    let samples = args.usize_or("samples", 32)?;
    let em_steps = args.usize_or("em-steps", 300)?;
    let model_name = args.str_or("model", "vp");

    let rt = Runtime::new(&artifacts())?;
    let model = rt.model(&model_name)?;
    let (net, refstats) = ref_stats(&rt, &model)?;

    let rows: Vec<(&str, Spec)> = vec![
        ("euler-maruyama (baseline)", Spec::Em(em_steps)),
        ("euler-heun (strong 0.5, fixed)", Spec::EulerHeun(em_steps)),
        ("sra1 (strong 1.5, adaptive)", Spec::Sra1(Sra1Opts::default())),
        (
            "sra1 (tight tol)",
            Spec::Sra1(Sra1Opts { eps_rel: 0.01, ..Default::default() }),
        ),
        (
            "lamba-em (atol default)",
            Spec::Lamba(LambaOpts::default()),
        ),
        (
            "lamba-em (rtol 1e-3-like)",
            Spec::Lamba(LambaOpts { eps_rel: 0.001, ..Default::default() }),
        ),
        ("milstein (adaptive; == EM here)", Spec::Milstein(0.05)),
        ("issem (implicit split-step)", Spec::Issem(em_steps)),
    ];

    let mut table = Table::new(&[
        "method", "strong-order", "adaptive", "NFE", "FID*", "wall_s", "speed vs EM",
    ]);
    let meta: Vec<(&str, &str)> = vec![
        ("0.5", "no"),
        ("0.5", "no"),
        ("1.5", "yes"),
        ("1.5", "yes"),
        ("0.5", "yes"),
        ("0.5", "yes"),
        ("1.0", "yes"),
        ("0.5", "no"),
    ];
    let mut em_wall = None;
    for ((label, spec), (order, adap)) in rows.iter().zip(meta) {
        let out = generate(&model, spec, samples, 3)?;
        let (fid, _) = eval_fid(&net, &refstats, &out)?;
        if em_wall.is_none() {
            em_wall = Some(out.wall_s);
        }
        let rel = em_wall.unwrap() / out.wall_s;
        let speed = if !out.converged {
            "did not converge".to_string()
        } else if rel >= 1.0 {
            format!("{rel:.2}x faster")
        } else {
            format!("{:.2}x slower", 1.0 / rel)
        };
        println!("{label:<34} NFE {:>7} FID* {:>8} {speed}", fmt_f(out.mean_nfe, 0), fmt_f(fid, 2));
        table.row(vec![
            label.to_string(),
            order.into(),
            adap.into(),
            fmt_f(out.mean_nfe, 0),
            fmt_f(fid, 2),
            format!("{:.1}", out.wall_s),
            speed,
        ]);
    }
    println!("\n=== Table 3 (model {model_name}, {samples} samples) ===\n");
    print!("{}", table.render());
    write_outputs("table3", &table)
}
