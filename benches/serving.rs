//! Serving benchmark (the L3 contribution; not a paper table), three
//! parts:
//!
//! 1. continuous batching vs request-exclusive ("static") batching under
//!    a Poisson trace with mixed request sizes and tolerances. Static
//!    baseline = each request is solved as its own batch run (the
//!    paper's §3.1.5 "wait for all images to converge" batch semantics);
//!    continuous = converged lanes backfilled from the queue.
//! 2. low-occupancy: a trickle of small sequential requests through a
//!    fixed-width pool vs the occupancy-aware bucket-migrating
//!    scheduler, reporting per-bucket step counts and wasted lane-steps
//!    (free lanes advanced as h = 0 no-ops).
//! 3. QoS (docs/ARCHITECTURE.md §Admission & QoS), two experiments:
//!    (a) weighted fairness — two pools saturated with deep backlogs
//!    under 3:1 deficit-round-robin weights must receive fused steps in
//!    a 3:1 ratio; (b) priority latency — interactive n=1 probes next
//!    to a saturating batch flood on the same pool, FIFO baseline vs
//!    priority classes: interactive p95 must improve without reducing
//!    total throughput. Results land in bench_out/serving_qos.json,
//!    gated in CI by tools/check_qos.py.
//! 4. async jobs (docs/PROTOCOL.md): a burst of submits drained through
//!    poll over real TCP vs the same burst run synchronously, with
//!    exactly-once delivery accounting, plus the base64-vs-binary-frame
//!    payload overhead for one image batch. Results land in
//!    bench_out/serving_async.json, gated in CI by
//!    tools/check_async.py.
//! 5. observability (docs/PROTOCOL.md §trace/§metrics): the same TCP
//!    topology under a submit burst plus one evaluate and one canceled
//!    job, the request-lifecycle spans and dispatch timeline pulled
//!    back through the `trace` op and the stats tree through `metrics`,
//!    plus the tracing-overhead ratio (steps/s with --trace-ring 1024
//!    vs 0 on the same fixed-step workload). Results land in
//!    bench_out/serving_trace.json, gated in CI by
//!    tools/check_trace.py.
//! 6. diagnostics + watchdog (docs/PROTOCOL.md §diag/§health): an
//!    adaptive workload with `--diag-sample 1`, its per-pool profile
//!    reconciled against the stats accept/reject counters; a
//!    stall-injection run (zero stall budget, per-iteration health
//!    checks, two concurrently active pools) observed through the
//!    `health` op and the Prometheus text; and the diag-on vs diag-off
//!    throughput ratio on the same fixed-step workload. Results land in
//!    bench_out/serving_diag.json, gated in CI by tools/check_diag.py.
//!
//!   cargo bench --offline --bench serving -- [--rate 2] [--duration 12]
//!       [--bucket 16] [--model vp] [--qos-only] [--qos-duration 4]
//!       [--async-only] [--async-burst 64] [--trace-only]
//!       [--trace-burst 32] [--trace-reqs 4] [--diag-only]
//!       [--diag-reqs 3]

#[path = "common.rs"]
mod common;

use common::*;
use gofast::bench::{summarize, Table};
use gofast::cli::Args;
use gofast::coordinator::{qos, DiagQuery, Engine, EngineConfig, SampleRequest};
use gofast::json::Value;
use gofast::rng::Rng;
use gofast::server::{serve, Client, EvalRequest, GenerateRequest, ServerConfig};
use gofast::solvers::ServingSolver;
use gofast::workload::{poisson_trace, TraceConfig};
use gofast::Result;
use std::collections::HashSet;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

fn main() -> Result<()> {
    let args = bench_args();
    let model = args.str_or("model", "vp");
    let rate = args.f64_or("rate", 2.0)?;
    let duration = args.f64_or("duration", 8.0)?;
    let bucket = args.usize_or("bucket", 16)?;
    let _ = artifacts();
    if args.has("qos-only") {
        return qos_bench(&args, &model);
    }
    if args.has("async-only") {
        return async_bench(&args, &model);
    }
    if args.has("trace-only") {
        return trace_bench(&args, &model);
    }
    if args.has("diag-only") {
        return diag_bench(&args, &model);
    }

    let mut table = Table::new(&[
        "mode", "requests", "samples", "p50_s", "p95_s", "samples/s", "occupancy", "score_evals",
    ]);

    for mode in ["continuous", "static"] {
        let mut cfg = EngineConfig::new("artifacts", &model);
        cfg.bucket = bucket;
        cfg.migrate = false; // part 1 isolates the batching comparison
        let engine = Engine::start(cfg)?;
        let client = engine.client();

        let mut rng = Rng::new(41);
        let trace = poisson_trace(
            &mut rng,
            &TraceConfig {
                duration_s: duration,
                rate_rps: rate,
                n_choices: vec![1, 2, 4, 8],
                eps_choices: vec![0.02, 0.05, 0.1],
            },
        );
        println!("== {mode} mode: {} requests over {duration}s ==", trace.len());
        let lat = Arc::new(Mutex::new(Vec::<f64>::new()));
        let done_samples = Arc::new(Mutex::new(0usize));
        let t0 = Instant::now();
        let mut handles = Vec::new();
        // In static mode, serialize requests through a mutex to emulate
        // one-request-at-a-time exclusive batching on the same engine.
        let static_gate = Arc::new(Mutex::new(()));
        for item in trace {
            let wait = item.at_s - t0.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(wait));
            }
            let client = client.clone();
            let lat = lat.clone();
            let done_samples = done_samples.clone();
            let gate = static_gate.clone();
            let is_static = mode == "static";
            handles.push(std::thread::spawn(move || {
                let t_req = Instant::now();
                let r = if is_static {
                    let _g = gate.lock().unwrap();
                    client.generate(item.n, item.eps_rel, item.seed)
                } else {
                    client.generate(item.n, item.eps_rel, item.seed)
                };
                if r.is_ok() {
                    lat.lock().unwrap().push(t_req.elapsed().as_secs_f64());
                    *done_samples.lock().unwrap() += item.n;
                }
            }));
        }
        for h in handles {
            let _ = h.join();
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let stats = engine.client().stats()?;
        let lat = lat.lock().unwrap().clone();
        let n_samples = *done_samples.lock().unwrap();
        if lat.is_empty() {
            println!("  no requests completed!");
            continue;
        }
        let s = summarize(lat);
        println!(
            "  p50 {:.2}s p95 {:.2}s throughput {:.2} samples/s occupancy {:.2}",
            s.p50,
            s.p95,
            n_samples as f64 / elapsed,
            stats.mean_occupancy
        );
        table.row(vec![
            mode.into(),
            format!("{}", s.n),
            format!("{n_samples}"),
            format!("{:.2}", s.p50),
            format!("{:.2}", s.p95),
            format!("{:.2}", n_samples as f64 / elapsed),
            format!("{:.2}", stats.mean_occupancy),
            format!("{}", stats.score_evals),
        ]);
    }
    println!("\n=== serving: continuous vs static batching ===\n");
    print!("{}", table.render());
    write_outputs("serving", &table)?;

    // --- part 2: low-occupancy, fixed width vs bucket migration -----------
    // Small sequential requests (active lanes <= 4 throughout) against a
    // pool of max width `bucket`. The fixed pool advances its free lanes
    // as h = 0 no-ops every step; the migrating pool shrinks to the
    // smallest compiled bucket that fits and should cut those wasted
    // lane-steps by >= 2x.
    let low_ns: &[usize] = &[1, 2, 4, 1, 2, 4, 1, 1];
    let mut lo_table = Table::new(&[
        "mode", "samples", "steps", "wasted_ls", "occupied_ls", "migrations", "bucket_steps",
    ]);
    let mut wasted_by_mode: Vec<u64> = Vec::new();
    println!("\n== low-occupancy: {} sequential requests, n in {{1,2,4}} ==", low_ns.len());
    for (mode, migrate) in [("fixed", false), ("migrating", true)] {
        let mut cfg = EngineConfig::new("artifacts", &model);
        cfg.bucket = bucket;
        cfg.migrate = migrate;
        let engine = Engine::start(cfg)?;
        let client = engine.client();
        let mut samples = 0usize;
        for (i, &n) in low_ns.iter().enumerate() {
            client.generate(n, 0.1, 9000 + i as u64)?;
            samples += n;
        }
        let stats = client.stats()?;
        let bucket_steps = stats
            .steps_per_bucket
            .iter()
            .filter(|(_, n)| *n > 0)
            .map(|(b, n)| format!("{b}:{n}"))
            .collect::<Vec<_>>()
            .join(" ");
        println!(
            "  {mode}: steps {} wasted {} occupied {} migrations {}v/{}^ [{bucket_steps}]",
            stats.steps,
            stats.wasted_lane_steps,
            stats.occupied_lane_steps,
            stats.migrations_down,
            stats.migrations_up,
        );
        lo_table.row(vec![
            mode.into(),
            format!("{samples}"),
            format!("{}", stats.steps),
            format!("{}", stats.wasted_lane_steps),
            format!("{}", stats.occupied_lane_steps),
            format!("{}", stats.migrations_down + stats.migrations_up),
            bucket_steps,
        ]);
        wasted_by_mode.push(stats.wasted_lane_steps);
    }
    println!("\n=== serving: low-occupancy bucket migration ===\n");
    print!("{}", lo_table.render());
    if let [fixed, migrating] = wasted_by_mode[..] {
        let ratio = fixed as f64 / migrating.max(1) as f64;
        println!(
            "\nwasted lane-steps: fixed {fixed} vs migrating {migrating} ({ratio:.1}x reduction)"
        );
    }
    write_outputs("serving_low_occupancy", &lo_table)?;

    qos_bench(&args, &model)?;
    async_bench(&args, &model)?;
    trace_bench(&args, &model)?;
    diag_bench(&args, &model)
}

/// Part 3: the QoS subsystem under mixed traffic. Writes
/// bench_out/serving_qos.json for tools/check_qos.py.
fn qos_bench(args: &Args, model: &str) -> Result<()> {
    let dur = args.f64_or("qos-duration", 4.0)?;
    let bucket = {
        let rt = gofast::runtime::Runtime::new("artifacts")?;
        engine_bucket(&rt.model(model)?, args.usize_or("bucket", 16)?)
    };

    // --- 3a: weighted fairness under saturation -----------------------
    // Both pools carry backlogs deep enough to stay busy for the whole
    // measurement window; under 3:1 weights the deficit round-robin
    // must split fused steps 3:1 (±10%, the acceptance criterion
    // tools/check_qos.py enforces).
    let (w_adaptive, w_em) = (3.0, 1.0);
    println!(
        "\n== qos fairness: {model}/adaptive (w={w_adaptive}) vs {model}/em (w={w_em}), \
         saturated {dur}s =="
    );
    let mut cfg = EngineConfig::new("artifacts", model);
    cfg.bucket = bucket;
    cfg.max_queue_samples = 100_000;
    // exactly the two pools under test — an idle third pool would trip
    // the all-pools-saturated snapshot condition
    cfg.programs = vec!["adaptive".to_string(), "em".to_string()];
    cfg.qos.weights = vec![
        (format!("{model}/adaptive"), w_adaptive),
        (format!("{model}/em"), w_em),
    ];
    let engine = Engine::start(cfg)?;
    let sat_reqs = args.usize_or("qos-sat-requests", 6)?;
    let sat_n = 4 * bucket;
    let mut backlog = Vec::new();
    for i in 0..sat_reqs {
        for solver in [ServingSolver::Adaptive, ServingSolver::Em { steps: 300 }] {
            let c = engine.client();
            backlog.push(std::thread::spawn(move || {
                // replies after engine teardown are expected failures
                let _ = c.generate_request(SampleRequest {
                    model: String::new(),
                    solver,
                    n: sat_n,
                    eps_rel: 0.02,
                    seed: 100 + i as u64,
                    sample_base: 0,
                    priority: None,
                    deadline_ms: None,
                    cancel_token: None,
                });
            }));
        }
    }
    // poll until the window closes or a pool drains; keep the last
    // snapshot with both pools still saturated so the share math only
    // covers the saturated period
    let c = engine.client();
    let t0 = Instant::now();
    let mut snapshot = None;
    loop {
        let stats = c.stats()?;
        let saturated = stats.pool_qos.iter().all(|p| p.queue_depth > 0);
        if saturated && stats.steps > 0 {
            snapshot = Some(stats);
        } else if snapshot.is_some() {
            break; // a pool drained: keep the last saturated snapshot
        }
        if t0.elapsed().as_secs_f64() >= dur {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    let fair = match snapshot {
        Some(s) => s,
        None => c.stats()?,
    };
    drop(engine); // tear down the backlog
    for h in backlog {
        let _ = h.join();
    }
    let mut fair_pools = Vec::new();
    let total_w: f64 = fair.pool_qos.iter().map(|p| p.weight).sum();
    let total_steps: u64 = fair.pool_qos.iter().map(|p| p.steps).sum();
    for p in &fair.pool_qos {
        let share = p.steps as f64 / total_steps.max(1) as f64;
        let expect = p.weight / total_w;
        println!(
            "  {}/{}: weight {} steps {} (share {:.3}, expected {:.3}) queue_depth {}",
            p.model, p.solver, p.weight, p.steps, share, expect, p.queue_depth
        );
        fair_pools.push(Value::obj(vec![
            ("pool", Value::str(format!("{}/{}", p.model, p.solver))),
            ("weight", Value::num(p.weight)),
            ("turns", Value::num(p.turns as f64)),
            ("steps", Value::num(p.steps as f64)),
            ("occupied_lane_steps", Value::num(p.occupied_lane_steps as f64)),
            ("queue_depth", Value::num(p.queue_depth as f64)),
            ("saturated", Value::Bool(p.queue_depth > 0)),
        ]));
    }

    // --- 3b: priority latency under a batch flood ---------------------
    // Interactive n=1 probes arrive while batch floods keep the same
    // pool saturated. Baseline: one class (plain FIFO). QoS: probes
    // marked interactive jump the batch queue. p95 must improve without
    // reducing total throughput (same work, different order).
    let flood_threads = 3;
    let mut modes = Vec::new();
    for mode in ["fifo", "qos"] {
        let mut cfg = EngineConfig::new("artifacts", model);
        cfg.bucket = bucket;
        cfg.max_queue_samples = 100_000;
        let engine = Engine::start(cfg)?;
        let stop = Arc::new(AtomicBool::new(false));
        let mut floods = Vec::new();
        for f in 0..flood_threads {
            let c = engine.client();
            let stop = stop.clone();
            let flood_prio = if mode == "qos" { Some(qos::Priority::Batch) } else { None };
            floods.push(std::thread::spawn(move || {
                let mut k = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let _ = c.generate_request(SampleRequest {
                        model: String::new(),
                        solver: ServingSolver::Adaptive,
                        n: bucket,
                        eps_rel: 0.05,
                        seed: 5000 + f as u64 * 1000 + k,
                        sample_base: 0,
                        priority: flood_prio,
                        deadline_ms: None,
                        cancel_token: None,
                    });
                    k += 1;
                }
            }));
        }
        let probe_prio =
            if mode == "qos" { Some(qos::Priority::Interactive) } else { None };
        let c = engine.client();
        let t0 = Instant::now();
        let mut lat = Vec::new();
        let mut k = 0u64;
        while t0.elapsed().as_secs_f64() < dur {
            let t_req = Instant::now();
            let r = c.generate_request(SampleRequest {
                model: String::new(),
                solver: ServingSolver::Adaptive,
                n: 1,
                eps_rel: 0.05,
                seed: 9000 + k,
                sample_base: 0,
                priority: probe_prio,
                deadline_ms: None,
                cancel_token: None,
            });
            if r.is_ok() {
                lat.push(t_req.elapsed().as_secs_f64());
            }
            k += 1;
            std::thread::sleep(std::time::Duration::from_millis(100));
        }
        stop.store(true, Ordering::Relaxed);
        for h in floods {
            let _ = h.join();
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let stats = c.stats()?;
        drop(engine);
        let tput = stats.samples_done as f64 / elapsed;
        // a probe-less run is a gate failure, not a bench panic
        let (n, p50, p95) = if lat.is_empty() {
            (0, f64::NAN, f64::NAN)
        } else {
            let s = summarize(lat);
            (s.n, s.p50, s.p95)
        };
        println!(
            "  {mode}: probes {n} p50 {p50:.3}s p95 {p95:.3}s throughput {tput:.1} samples/s"
        );
        modes.push((
            mode,
            Value::obj(vec![
                ("probes", Value::num(n as f64)),
                ("p50_s", Value::num(p50)),
                ("p95_s", Value::num(p95)),
                ("throughput_sps", Value::num(tput)),
                ("samples_done", Value::num(stats.samples_done as f64)),
                ("elapsed_s", Value::num(elapsed)),
            ]),
        ));
    }

    let doc = Value::obj(vec![
        ("model", Value::str(model)),
        ("bucket", Value::num(bucket as f64)),
        ("duration_s", Value::num(dur)),
        (
            "fairness",
            Value::obj(vec![("pools", Value::Arr(fair_pools))]),
        ),
        (
            "latency",
            Value::Obj(modes.into_iter().map(|(m, v)| (m.to_string(), v)).collect()),
        ),
    ]);
    std::fs::create_dir_all("bench_out")?;
    std::fs::write("bench_out/serving_qos.json", format!("{doc}"))?;
    println!("[serving_qos] json -> bench_out/serving_qos.json");
    Ok(())
}

/// Part 4: the async job layer over real TCP. A burst of submits is
/// drained through poll with exactly-once accounting and compared to
/// the same burst run synchronously; one image batch measures the
/// base64-vs-binary-frame payload overhead. Writes
/// bench_out/serving_async.json for tools/check_async.py.
fn async_bench(args: &Args, model: &str) -> Result<()> {
    let burst = args.usize_or("async-burst", 64)?;
    let bucket = {
        let rt = gofast::runtime::Runtime::new("artifacts")?;
        engine_bucket(&rt.model(model)?, args.usize_or("bucket", 16)?)
    };
    let mut cfg = EngineConfig::new("artifacts", model);
    cfg.bucket = bucket;
    cfg.max_queue_samples = 100_000;
    let engine = Engine::start(cfg)?;
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    {
        let c = engine.client();
        std::thread::spawn(move || {
            let _ = serve(
                listener,
                c,
                ServerConfig { port: addr.port(), default_eps_rel: 0.05 },
            );
        });
    }
    println!("\n== async jobs: burst of {burst} submits (n=1 em:8) over TCP ==");
    let spec = |seed: u64| {
        GenerateRequest::new(1).solver("em:8").eps_rel(0.5).seed(seed).images(false)
    };

    // sync baseline: the same burst, one blocking generate at a time
    let mut c = Client::connect(&addr.to_string())?;
    let t0 = Instant::now();
    for i in 0..burst {
        c.run(&spec(i as u64))?;
    }
    let sync_wall = t0.elapsed().as_secs_f64();

    // async: fire the whole burst, then drain; every submitted id must
    // come back exactly once (the check_async.py acceptance gate)
    let t0 = Instant::now();
    let mut expected = HashSet::new();
    for i in 0..burst {
        expected.insert(c.submit(&spec(i as u64))?);
    }
    let submit_wall = t0.elapsed().as_secs_f64();
    let mut seen = HashSet::new();
    let (mut delivered, mut duplicates, mut failures) = (0u64, 0u64, 0u64);
    while seen.len() < burst && t0.elapsed().as_secs_f64() < 120.0 {
        for u in c.poll(100, false)? {
            delivered += 1;
            if !u.is_ok() {
                failures += 1;
            }
            if !expected.contains(&u.job) || !seen.insert(u.job) {
                duplicates += 1;
            }
        }
    }
    let async_wall = t0.elapsed().as_secs_f64();
    println!(
        "  sync  : {burst} requests in {sync_wall:.2}s ({:.1} req/s)",
        burst as f64 / sync_wall
    );
    println!(
        "  async : submitted in {submit_wall:.3}s, drained in {async_wall:.2}s \
         ({:.1} req/s) delivered {delivered} duplicates {duplicates} failures {failures}",
        burst as f64 / async_wall
    );

    // payload overhead: one n=8 image batch, base64 line vs negotiated
    // binary frame, measured on a raw socket so the byte counts are the
    // real wire footprint
    let stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let body = format!(
        "{{\"op\":\"generate\",\"n\":8,\"solver\":\"em:8\",\"eps_rel\":0.5,\"seed\":7,\
         \"model\":\"{model}\"}}"
    );
    writeln!(writer, "{body}")?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let head = gofast::json::parse(line.trim_end())?;
    let b64_payload = head.req("images_b64")?.as_str()?.len() as u64;
    let b64_total = line.len() as u64;
    writeln!(writer, "{}", body.replace("\"seed\":7", "\"seed\":7,\"binary\":true"))?;
    line.clear();
    reader.read_line(&mut line)?;
    let head = gofast::json::parse(line.trim_end())?;
    let bin_payload = head.req("images_bin")?.as_f64()? as u64;
    let mut frame = vec![0u8; bin_payload as usize];
    reader.read_exact(&mut frame)?;
    let bin_total = line.len() as u64 + bin_payload;
    println!(
        "  payload (n=8): base64 {b64_payload} bytes (line {b64_total}) vs \
         binary {bin_payload} bytes (line+frame {bin_total}, {:.2}x smaller)",
        b64_total as f64 / bin_total.max(1) as f64
    );

    let doc = Value::obj(vec![
        ("model", Value::str(model)),
        ("bucket", Value::num(bucket as f64)),
        ("submitted", Value::num(burst as f64)),
        ("delivered", Value::num(delivered as f64)),
        ("duplicates", Value::num(duplicates as f64)),
        ("failures", Value::num(failures as f64)),
        ("sync_wall_s", Value::num(sync_wall)),
        ("sync_rps", Value::num(burst as f64 / sync_wall)),
        ("submit_wall_s", Value::num(submit_wall)),
        ("async_wall_s", Value::num(async_wall)),
        ("async_rps", Value::num(burst as f64 / async_wall)),
        (
            "payload",
            Value::obj(vec![
                ("samples", Value::num(8.0)),
                ("b64_bytes", Value::num(b64_payload as f64)),
                ("b64_total_bytes", Value::num(b64_total as f64)),
                ("bin_bytes", Value::num(bin_payload as f64)),
                ("bin_total_bytes", Value::num(bin_total as f64)),
            ]),
        ),
    ]);
    std::fs::create_dir_all("bench_out")?;
    std::fs::write("bench_out/serving_async.json", format!("{doc}"))?;
    println!("[serving_async] json -> bench_out/serving_async.json");
    Ok(())
}

/// Part 5: observability. The part-4 TCP topology under a mixed
/// workload — an async submit burst, one evaluate, one canceled job —
/// then the span ring, dispatch timeline and Prometheus text pulled
/// back through the `trace`/`metrics` ops, then the tracing-overhead
/// ratio from two in-process engines (`--trace-ring` 1024 vs 0) on the
/// same fixed-step workload. Writes bench_out/serving_trace.json for
/// tools/check_trace.py.
fn trace_bench(args: &Args, model: &str) -> Result<()> {
    let burst = args.usize_or("trace-burst", 32)?;
    let bucket = {
        let rt = gofast::runtime::Runtime::new("artifacts")?;
        engine_bucket(&rt.model(model)?, args.usize_or("bucket", 16)?)
    };
    let mut cfg = EngineConfig::new("artifacts", model);
    cfg.bucket = bucket;
    cfg.max_queue_samples = 100_000;
    cfg.trace_ring = 1024;
    let engine = Engine::start(cfg)?;
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    {
        let c = engine.client();
        std::thread::spawn(move || {
            let _ = serve(
                listener,
                c,
                ServerConfig { port: addr.port(), default_eps_rel: 0.05 },
            );
        });
    }
    println!("\n== trace: {burst} submits + evaluate + cancel, spans over TCP ==");
    let mut c = Client::connect(&addr.to_string())?;

    // a wide fixed-step job keeps every lane busy so the cancel victim
    // below is still fully queued when the cancel lands
    let flood = c.submit(
        &GenerateRequest::new(4 * bucket).solver("em:64").eps_rel(0.5).seed(999).images(false),
    )?;
    let victim = c
        .submit(&GenerateRequest::new(1).solver("em:8").eps_rel(0.5).seed(1000).images(false))?;
    // unknown_job (the cancel raced with completion) counts as a miss,
    // not a bench abort — the victim's result then drains normally
    let canceled = c.cancel(victim).unwrap_or(false);
    let mut expected = 1 + usize::from(!canceled); // flood (+ victim if the cancel raced)
    for i in 0..burst {
        c.submit(
            &GenerateRequest::new(1).solver("em:8").eps_rel(0.5).seed(i as u64).images(false),
        )?;
        expected += 1;
    }
    // one evaluate so the ring holds eval-kind span chains too
    let ev = c.run_eval(&EvalRequest::new(bucket).solver("em:8").eps_rel(0.5).seed(7))?;
    let mut delivered = 0usize;
    let t0 = Instant::now();
    while delivered < expected && t0.elapsed().as_secs_f64() < 120.0 {
        delivered += c.poll(100, false)?.len();
    }
    println!(
        "  flood job {flood}, victim job {victim} canceled={canceled}, \
         drained {delivered}/{expected}, eval fid {:.1} mean_nfe {:.1}",
        ev.fid, ev.mean_nfe
    );

    let tv = c.trace(None, 0, true)?;
    let spans = tv.req("spans")?.clone();
    let timeline = tv.req("timeline")?.clone();
    let metrics_text = c.metrics()?;
    println!(
        "  spans {} timeline records {} metrics {} bytes",
        spans.as_arr()?.len(),
        timeline.as_arr()?.len(),
        metrics_text.len()
    );
    drop(engine);

    // tracing overhead: the zero-allocation contract says a live span
    // ring must not tax the hot step path. Same fixed-step workload on
    // two fresh engines, ring off vs on; check_trace.py gates the
    // steps/s ratio at >= 0.95.
    let reqs = args.usize_or("trace-reqs", 4)?;
    let mut sps = Vec::new();
    for ring in [0usize, 1024] {
        let mut cfg = EngineConfig::new("artifacts", model);
        cfg.bucket = bucket;
        cfg.max_queue_samples = 100_000;
        cfg.trace_ring = ring;
        let engine = Engine::start(cfg)?;
        let c = engine.client();
        let gen = |steps: usize, seed: u64| SampleRequest {
            model: String::new(),
            solver: ServingSolver::Em { steps },
            n: bucket,
            eps_rel: 0.5,
            seed,
            sample_base: 0,
            priority: None,
            deadline_ms: None,
            cancel_token: None,
        };
        c.generate_request(gen(50, 1))?; // warm the pool and runtime caches
        let s0 = c.stats()?;
        let t0 = Instant::now();
        for r in 0..reqs {
            c.generate_request(gen(200, 2 + r as u64))?;
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let s1 = c.stats()?;
        let v = (s1.steps - s0.steps) as f64 / elapsed;
        println!("  trace_ring {ring}: {v:.0} steps/s");
        sps.push(v);
    }
    let (off_sps, on_sps) = (sps[0], sps[1]);
    let ratio = on_sps / off_sps.max(1e-9);
    println!("  ring-on / ring-off throughput ratio {ratio:.3}");

    let doc = Value::obj(vec![
        ("model", Value::str(model)),
        ("bucket", Value::num(bucket as f64)),
        ("submitted", Value::num(burst as f64)),
        ("delivered", Value::num(delivered as f64)),
        ("canceled_job", Value::num(victim as f64)),
        ("cancel_acked", Value::Bool(canceled)),
        ("eval_mean_nfe", Value::num(ev.mean_nfe)),
        ("spans", spans),
        ("timeline", timeline),
        ("metrics_text", Value::str(metrics_text)),
        (
            "ring",
            Value::obj(vec![
                ("off_steps_per_s", Value::num(off_sps)),
                ("on_steps_per_s", Value::num(on_sps)),
                ("ratio", Value::num(ratio)),
            ]),
        ),
    ]);
    std::fs::create_dir_all("bench_out")?;
    std::fs::write("bench_out/serving_trace.json", format!("{doc}"))?;
    println!("[serving_trace] json -> bench_out/serving_trace.json");
    Ok(())
}

/// Part 6: solver diagnostics + the health watchdog. Three
/// experiments: (a) an adaptive workload with `--diag-sample 1`, its
/// diffusion-time profile pulled from a quiesced engine and reconciled
/// against the stats accept/reject counters; (b) a stall-injection run
/// — zero stall budget, per-iteration health checks, and a long
/// fixed-step flood next to adaptive traffic so the unserved pool's
/// lanes sit unchanged between consecutive checks — observed through
/// the wire `health` op and the Prometheus text; (c) the diag-on vs
/// diag-off throughput ratio on the same fixed-step workload (the
/// `--diag-sample 0` zero-allocation contract). Writes
/// bench_out/serving_diag.json for tools/check_diag.py.
fn diag_bench(args: &Args, model: &str) -> Result<()> {
    let reqs = args.usize_or("diag-reqs", 3)?;
    let bucket = {
        let rt = gofast::runtime::Runtime::new("artifacts")?;
        engine_bucket(&rt.model(model)?, args.usize_or("bucket", 16)?)
    };

    // --- 6a: profile reconciliation under sampling --------------------
    println!("\n== diag: {reqs} adaptive bursts (n={bucket}) with --diag-sample 1 ==");
    let mut cfg = EngineConfig::new("artifacts", model);
    cfg.bucket = bucket;
    cfg.max_queue_samples = 100_000;
    cfg.diag_sample = 1;
    let engine = Engine::start(cfg)?;
    let c = engine.client();
    for r in 0..reqs {
        c.generate_request(SampleRequest {
            model: String::new(),
            solver: ServingSolver::Adaptive,
            n: bucket,
            eps_rel: 0.2,
            seed: 7000 + r as u64,
            sample_base: 0,
            priority: None,
            deadline_ms: None,
            cancel_token: None,
        })?;
    }
    // both snapshots from the quiesced engine, so the reconciliation
    // invariant must hold exactly: sum(accepted+rejected) over an
    // adaptive pool's bins == the pool's stats counters
    let stats = c.stats()?;
    let diag = c.diag(DiagQuery::default())?;
    let mut stats_pools = Vec::new();
    for p in &stats.pool_qos {
        stats_pools.push(Value::obj(vec![
            ("pool", Value::str(format!("{}/{}", p.model, p.solver))),
            ("accepted", Value::num(p.accepted as f64)),
            ("rejected", Value::num(p.rejected as f64)),
            ("steps", Value::num(p.steps as f64)),
        ]));
    }
    for p in &diag.pools {
        let (acc, rej): (u64, u64) = p
            .bins
            .iter()
            .fold((0, 0), |(a, r), b| (a + b.accepted, r + b.rejected));
        println!(
            "  {}/{}: {} bins, {} proposals ({} accepted, {} rejected), {} traces",
            p.model,
            p.solver,
            p.bins.len(),
            acc + rej,
            acc,
            rej,
            p.traces.len()
        );
    }
    let diag_pools: Vec<Value> = diag.pools.iter().map(|p| p.to_json()).collect();
    drop(engine);

    // --- 6b: stall injection, observed over the wire ------------------
    // stall budget 0 + health checks every loop iteration: whichever
    // pool the round-robin leaves unserved this iteration has made no
    // progress since the previous check, so a stall fires as soon as
    // both pools hold active lanes. The defaults (10s budget, 1s
    // interval) never fire on this workload.
    println!("== diag: stall injection (budget 0, per-iteration checks) ==");
    let mut cfg = EngineConfig::new("artifacts", model);
    cfg.bucket = bucket;
    cfg.max_queue_samples = 100_000;
    cfg.diag_sample = 1;
    cfg.stall_budget_s = 0.0;
    cfg.health_interval_s = 0.0;
    let engine = Engine::start(cfg)?;
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    {
        let c = engine.client();
        std::thread::spawn(move || {
            let _ = serve(
                listener,
                c,
                ServerConfig { port: addr.port(), default_eps_rel: 0.05 },
            );
        });
    }
    let mut wc = Client::connect(&addr.to_string())?;
    // a long fixed-step flood next to adaptive traffic: two pools with
    // active lanes, one loop, guaranteed unserved-pool checks
    wc.submit(
        &GenerateRequest::new(bucket).solver("em:300").eps_rel(0.5).seed(1).images(false),
    )?;
    wc.submit(&GenerateRequest::new(bucket).eps_rel(0.2).seed(2).images(false))?;
    let t0 = Instant::now();
    let mut stall_count = 0u64;
    let mut health = wc.health()?;
    while stall_count < 1 && t0.elapsed().as_secs_f64() < 60.0 {
        health = wc.health()?;
        stall_count = health.req("counts")?.req("stall")?.as_f64()? as u64;
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    let metrics_text = wc.metrics()?;
    let mut delivered = 0usize;
    while delivered < 2 && t0.elapsed().as_secs_f64() < 120.0 {
        delivered += wc.poll(100, false)?.len();
    }
    println!(
        "  stall events {stall_count} after {:.2}s, status {}, drained {delivered}/2",
        t0.elapsed().as_secs_f64(),
        health.req("status")?.as_f64()?,
    );
    drop(engine);

    // --- 6c: sampling overhead ----------------------------------------
    // The --diag-sample 0 contract says the always-on profile (and a
    // disabled sampler) must not tax the hot step path; check_diag.py
    // gates the steps/s ratio at >= 0.95. Default watchdog cadence on
    // both engines so only the sampler varies.
    let mut sps = Vec::new();
    for sample in [0usize, 1] {
        let mut cfg = EngineConfig::new("artifacts", model);
        cfg.bucket = bucket;
        cfg.max_queue_samples = 100_000;
        cfg.diag_sample = sample;
        let engine = Engine::start(cfg)?;
        let c = engine.client();
        let gen = |steps: usize, seed: u64| SampleRequest {
            model: String::new(),
            solver: ServingSolver::Em { steps },
            n: bucket,
            eps_rel: 0.5,
            seed,
            sample_base: 0,
            priority: None,
            deadline_ms: None,
            cancel_token: None,
        };
        c.generate_request(gen(50, 1))?; // warm the pool and runtime caches
        let s0 = c.stats()?;
        let t0 = Instant::now();
        for r in 0..args.usize_or("trace-reqs", 4)? {
            c.generate_request(gen(200, 2 + r as u64))?;
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let s1 = c.stats()?;
        let v = (s1.steps - s0.steps) as f64 / elapsed;
        println!("  diag_sample {sample}: {v:.0} steps/s");
        sps.push(v);
    }
    let (off_sps, on_sps) = (sps[0], sps[1]);
    let ratio = on_sps / off_sps.max(1e-9);
    println!("  diag-on / diag-off throughput ratio {ratio:.3}");

    let doc = Value::obj(vec![
        ("model", Value::str(model)),
        ("bucket", Value::num(bucket as f64)),
        (
            "profile",
            Value::obj(vec![
                ("pools", Value::Arr(diag_pools)),
                ("stats_pools", Value::Arr(stats_pools)),
            ]),
        ),
        (
            "stall",
            Value::obj(vec![
                ("fired", Value::Bool(stall_count >= 1)),
                ("stall_events", Value::num(stall_count as f64)),
                ("status", health.req("status")?.clone()),
                ("counts", health.req("counts")?.clone()),
                ("events", health.req("events")?.clone()),
            ]),
        ),
        ("metrics_text", Value::str(metrics_text)),
        (
            "overhead",
            Value::obj(vec![
                ("off_steps_per_s", Value::num(off_sps)),
                ("on_steps_per_s", Value::num(on_sps)),
                ("ratio", Value::num(ratio)),
            ]),
        ),
    ]);
    std::fs::create_dir_all("bench_out")?;
    std::fs::write("bench_out/serving_diag.json", format!("{doc}"))?;
    println!("[serving_diag] json -> bench_out/serving_diag.json");
    Ok(())
}
