//! Serving benchmark (the L3 contribution; not a paper table), two parts:
//!
//! 1. continuous batching vs request-exclusive ("static") batching under
//!    a Poisson trace with mixed request sizes and tolerances. Static
//!    baseline = each request is solved as its own batch run (the
//!    paper's §3.1.5 "wait for all images to converge" batch semantics);
//!    continuous = converged lanes backfilled from the queue.
//! 2. low-occupancy: a trickle of small sequential requests through a
//!    fixed-width pool vs the occupancy-aware bucket-migrating
//!    scheduler, reporting per-bucket step counts and wasted lane-steps
//!    (free lanes advanced as h = 0 no-ops).
//!
//!   cargo bench --offline --bench serving -- [--rate 2] [--duration 12]
//!       [--bucket 16] [--model vp]

#[path = "common.rs"]
mod common;

use common::*;
use gofast::bench::{summarize, Table};
use gofast::coordinator::{Engine, EngineConfig};
use gofast::rng::Rng;
use gofast::workload::{poisson_trace, TraceConfig};
use gofast::Result;
use std::sync::{Arc, Mutex};
use std::time::Instant;

fn main() -> Result<()> {
    let args = bench_args();
    let model = args.str_or("model", "vp");
    let rate = args.f64_or("rate", 2.0)?;
    let duration = args.f64_or("duration", 8.0)?;
    let bucket = args.usize_or("bucket", 16)?;
    let _ = artifacts();

    let mut table = Table::new(&[
        "mode", "requests", "samples", "p50_s", "p95_s", "samples/s", "occupancy", "score_evals",
    ]);

    for mode in ["continuous", "static"] {
        let mut cfg = EngineConfig::new("artifacts", &model);
        cfg.bucket = bucket;
        cfg.migrate = false; // part 1 isolates the batching comparison
        let engine = Engine::start(cfg)?;
        let client = engine.client();

        let mut rng = Rng::new(41);
        let trace = poisson_trace(
            &mut rng,
            &TraceConfig {
                duration_s: duration,
                rate_rps: rate,
                n_choices: vec![1, 2, 4, 8],
                eps_choices: vec![0.02, 0.05, 0.1],
            },
        );
        println!("== {mode} mode: {} requests over {duration}s ==", trace.len());
        let lat = Arc::new(Mutex::new(Vec::<f64>::new()));
        let done_samples = Arc::new(Mutex::new(0usize));
        let t0 = Instant::now();
        let mut handles = Vec::new();
        // In static mode, serialize requests through a mutex to emulate
        // one-request-at-a-time exclusive batching on the same engine.
        let static_gate = Arc::new(Mutex::new(()));
        for item in trace {
            let wait = item.at_s - t0.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(wait));
            }
            let client = client.clone();
            let lat = lat.clone();
            let done_samples = done_samples.clone();
            let gate = static_gate.clone();
            let is_static = mode == "static";
            handles.push(std::thread::spawn(move || {
                let t_req = Instant::now();
                let r = if is_static {
                    let _g = gate.lock().unwrap();
                    client.generate(item.n, item.eps_rel, item.seed)
                } else {
                    client.generate(item.n, item.eps_rel, item.seed)
                };
                if r.is_ok() {
                    lat.lock().unwrap().push(t_req.elapsed().as_secs_f64());
                    *done_samples.lock().unwrap() += item.n;
                }
            }));
        }
        for h in handles {
            let _ = h.join();
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let stats = engine.client().stats()?;
        let lat = lat.lock().unwrap().clone();
        let n_samples = *done_samples.lock().unwrap();
        if lat.is_empty() {
            println!("  no requests completed!");
            continue;
        }
        let s = summarize(lat);
        println!(
            "  p50 {:.2}s p95 {:.2}s throughput {:.2} samples/s occupancy {:.2}",
            s.p50,
            s.p95,
            n_samples as f64 / elapsed,
            stats.mean_occupancy
        );
        table.row(vec![
            mode.into(),
            format!("{}", s.n),
            format!("{n_samples}"),
            format!("{:.2}", s.p50),
            format!("{:.2}", s.p95),
            format!("{:.2}", n_samples as f64 / elapsed),
            format!("{:.2}", stats.mean_occupancy),
            format!("{}", stats.score_evals),
        ]);
    }
    println!("\n=== serving: continuous vs static batching ===\n");
    print!("{}", table.render());
    write_outputs("serving", &table)?;

    // --- part 2: low-occupancy, fixed width vs bucket migration -----------
    // Small sequential requests (active lanes <= 4 throughout) against a
    // pool of max width `bucket`. The fixed pool advances its free lanes
    // as h = 0 no-ops every step; the migrating pool shrinks to the
    // smallest compiled bucket that fits and should cut those wasted
    // lane-steps by >= 2x.
    let low_ns: &[usize] = &[1, 2, 4, 1, 2, 4, 1, 1];
    let mut lo_table = Table::new(&[
        "mode", "samples", "steps", "wasted_ls", "occupied_ls", "migrations", "bucket_steps",
    ]);
    let mut wasted_by_mode: Vec<u64> = Vec::new();
    println!("\n== low-occupancy: {} sequential requests, n in {{1,2,4}} ==", low_ns.len());
    for (mode, migrate) in [("fixed", false), ("migrating", true)] {
        let mut cfg = EngineConfig::new("artifacts", &model);
        cfg.bucket = bucket;
        cfg.migrate = migrate;
        let engine = Engine::start(cfg)?;
        let client = engine.client();
        let mut samples = 0usize;
        for (i, &n) in low_ns.iter().enumerate() {
            client.generate(n, 0.1, 9000 + i as u64)?;
            samples += n;
        }
        let stats = client.stats()?;
        let bucket_steps = stats
            .steps_per_bucket
            .iter()
            .filter(|(_, n)| *n > 0)
            .map(|(b, n)| format!("{b}:{n}"))
            .collect::<Vec<_>>()
            .join(" ");
        println!(
            "  {mode}: steps {} wasted {} occupied {} migrations {}v/{}^ [{bucket_steps}]",
            stats.steps,
            stats.wasted_lane_steps,
            stats.occupied_lane_steps,
            stats.migrations_down,
            stats.migrations_up,
        );
        lo_table.row(vec![
            mode.into(),
            format!("{samples}"),
            format!("{}", stats.steps),
            format!("{}", stats.wasted_lane_steps),
            format!("{}", stats.occupied_lane_steps),
            format!("{}", stats.migrations_down + stats.migrations_up),
            bucket_steps,
        ]);
        wasted_by_mode.push(stats.wasted_lane_steps);
    }
    println!("\n=== serving: low-occupancy bucket migration ===\n");
    print!("{}", lo_table.render());
    if let [fixed, migrating] = wasted_by_mode[..] {
        let ratio = fixed as f64 / migrating.max(1) as f64;
        println!(
            "\nwasted lane-steps: fixed {fixed} vs migrating {migrating} ({ratio:.1}x reduction)"
        );
    }
    write_outputs("serving_low_occupancy", &lo_table)
}
