//! Figure 1 — FID* vs NFE for the adaptive solver (sweeping eps_rel)
//! against Euler–Maruyama at the matched budget: the paper's headline
//! plot. Emits a CSV series and an ASCII rendering.
//!
//!   cargo bench --offline --bench figure1 -- [--samples N] [--model vp]

#[path = "common.rs"]
mod common;

use common::*;
use gofast::bench::{ascii_plot, Table};
use gofast::runtime::Runtime;
use gofast::solvers::{adaptive::AdaptiveOpts, Spec};
use gofast::Result;

fn main() -> Result<()> {
    let args = bench_args();
    let samples = args.usize_or("samples", 48)?;
    let models = args.str_list_or("model", &["vp", "ve"]);
    let eps_list = args.f64_list_or("eps", &[0.01, 0.02, 0.05, 0.10, 0.50])?;

    let rt = Runtime::new(&artifacts())?;
    let mut table = Table::new(&["model", "series", "eps_rel", "NFE", "FID*"]);

    for mname in &models {
        let Ok(model) = rt.model(mname) else { continue };
        let (net, refstats) = ref_stats(&rt, &model)?;
        let mut ours: Vec<(f64, f64)> = Vec::new();
        let mut em: Vec<(f64, f64)> = Vec::new();
        println!("== figure 1 series on {mname} ==");
        for &eps in &eps_list {
            let out =
                generate(&model, &Spec::Adaptive(AdaptiveOpts::with_eps_rel(eps)), samples, 21)?;
            let (fid, _) = eval_fid(&net, &refstats, &out)?;
            println!("  ours eps={eps:<5} NFE {:>6} FID* {}", fmt_f(out.mean_nfe, 0), fmt_f(fid, 2));
            if fid.is_finite() {
                ours.push((out.mean_nfe, fid));
            }
            table.row(vec![
                mname.clone(),
                "ours".into(),
                format!("{eps}"),
                fmt_f(out.mean_nfe, 0),
                fmt_f(fid, 2),
            ]);
            let out_em = generate(&model, &Spec::Em(em_steps_for_nfe(out.mean_nfe)), samples, 21)?;
            let (fid_em, _) = eval_fid(&net, &refstats, &out_em)?;
            println!("  em   @same   NFE {:>6} FID* {}", fmt_f(out_em.mean_nfe, 0), fmt_f(fid_em, 2));
            if fid_em.is_finite() {
                em.push((out_em.mean_nfe, fid_em));
            }
            table.row(vec![
                mname.clone(),
                "euler-maruyama".into(),
                format!("{eps}"),
                fmt_f(out_em.mean_nfe, 0),
                fmt_f(fid_em, 2),
            ]);
        }
        ours.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        em.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        println!("\nFID* (y) vs NFE (x) — {mname}:");
        println!("{}", ascii_plot(&[("ours", ours), ("euler-maruyama", em)], 64, 16));
    }
    print!("{}", table.render());
    write_outputs("figure1", &table)
}
