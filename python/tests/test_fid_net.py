"""Synthception feature net: shapes, param ABI, and discriminativeness
after a very short training (the property FID* depends on)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import dataset as ds
from compile import fid_net
from compile.train import adam_init, adam_update


def test_param_count_and_layout():
    cfg = fid_net.FidCfg(dim=768, n_classes=6)
    n = fid_net.n_params(cfg)
    flat = fid_net.init_params(0, cfg)
    assert flat.shape == (n,)
    p = fid_net.unflatten(jnp.asarray(flat), cfg)
    assert p["w1"].shape == (768, fid_net.HID)
    assert p["w4"].shape == (fid_net.FEAT_DIM, 6)


def test_features_logits_shapes():
    cfg = fid_net.FidCfg(dim=48, n_classes=4)
    flat = jnp.asarray(fid_net.init_params(1, cfg))
    x = jnp.zeros((8, 48))
    feat, logits = fid_net.features_logits(flat, x, cfg)
    assert feat.shape == (8, fid_net.FEAT_DIM)
    assert logits.shape == (8, 4)


def test_short_training_separates_classes():
    """300 steps on synth-cifar must beat chance accuracy clearly —
    otherwise FID* features carry no signal."""
    x, y = ds.generate("synth-cifar", 1024)
    cfg = fid_net.FidCfg(dim=x.shape[1], n_classes=6)
    flat = jnp.asarray(fid_net.init_params(2, cfg))
    m, v = adam_init(flat.shape[0])
    xj, yj = jnp.asarray(x), jnp.asarray(y)

    def loss_fn(flat, xb, yb):
        _, logits = fid_net.features_logits(flat, xb, cfg)
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(lp[jnp.arange(xb.shape[0]), yb])

    @jax.jit
    def step(flat, m, v, i, key):
        key, k = jax.random.split(key)
        idx = jax.random.randint(k, (128,), 0, xj.shape[0])
        loss, g = jax.value_and_grad(loss_fn)(flat, xj[idx], yj[idx])
        upd, m, v = adam_update(g, m, v, i, 2e-3)
        return flat - upd, m, v, key, loss

    key = jax.random.PRNGKey(0)
    for i in range(1, 301):
        flat, m, v, key, _ = step(flat, m, v, jnp.float32(i), key)
    xe, ye = ds.generate("synth-cifar", 256, seed_offset=123)
    _, logits = fid_net.features_logits(flat, jnp.asarray(xe), cfg)
    acc = float(jnp.mean(jnp.argmax(logits, 1) == jnp.asarray(ye)))
    assert acc > 0.3, f"accuracy {acc} barely beats chance (1/6)"
