"""Training substrate: from-scratch Adam, the DSM objective, the
Gaussian-prior baseline (eps_gauss), and EMA/frozen-stat behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.sde import VPSDE
from compile.train import adam_init, adam_update, dsm_loss, lr_at


def test_adam_converges_on_quadratic():
    """min (x - 3)^2 elementwise — Adam must get there."""
    x = jnp.zeros(8)
    m, v = adam_init(8)
    for step in range(1, 400):
        g = 2 * (x - 3.0)
        upd, m, v = adam_update(g, m, v, jnp.float32(step), 0.05)
        x = x - upd
    np.testing.assert_allclose(x, jnp.full(8, 3.0), atol=1e-2)


def test_adam_bias_correction_first_step():
    """After one step from zero state the update must be ~lr * sign(g)."""
    g = jnp.array([4.0, -0.25])
    m, v = adam_init(2)
    upd, _, _ = adam_update(g, m, v, jnp.float32(1), 1e-3)
    np.testing.assert_allclose(upd, jnp.array([1e-3, -1e-3]), rtol=1e-4)


def test_zero_grad_means_zero_update():
    """Frozen params (stop_gradient => g == 0) must never drift."""
    g = jnp.zeros(4)
    m, v = adam_init(4)
    for step in range(1, 10):
        upd, m, v = adam_update(g, m, v, jnp.float32(step), 1e-2)
        np.testing.assert_array_equal(upd, jnp.zeros(4))


def test_lr_warmup():
    assert float(lr_at(jnp.float32(1), 1.0, warmup=100)) == pytest.approx(0.01)
    assert float(lr_at(jnp.float32(100), 1.0, warmup=100)) == pytest.approx(1.0)
    assert float(lr_at(jnp.float32(5000), 1.0, warmup=100)) == pytest.approx(1.0)


# --- eps_gauss baseline ----------------------------------------------------------

def test_eps_gauss_exact_for_gaussian_data():
    """If the data really is N(mu0, v0), eps_gauss is the Bayes-optimal
    noise predictor: residual loss must be the conditional variance
    v0 a^2/(a^2 v0 + s^2) < naive loss 1."""
    cfg = model.ModelCfg(dim=32, hidden=128, blocks=0, sde_kind="vp")
    sde = cfg.sde
    key = jax.random.PRNGKey(0)
    mu0 = jnp.linspace(-0.5, 0.5, 32)
    v0 = jnp.linspace(0.2, 0.8, 32)
    n = 20000
    x0 = mu0 + jnp.sqrt(v0) * jax.random.normal(key, (n, 32))
    t = jnp.full((n,), 0.5)
    z = jax.random.normal(jax.random.fold_in(key, 1), (n, 32))
    xt = sde.mean_coef(t)[:, None] * x0 + sde.marginal_std(t)[:, None] * z
    pred = model.eps_gauss(xt, t, cfg, mu0, v0)
    resid = jnp.mean((pred - z) ** 2, axis=0)
    a = float(sde.mean_coef(0.5))
    s = float(sde.marginal_std(0.5))
    # residual variance of z | x_t = a^2 v0 / (a^2 v0 + s^2)
    want = (a * a * v0) / (a * a * v0 + s * s)
    np.testing.assert_allclose(resid, want, atol=0.05)


def test_eps_gauss_at_t1_is_identity_direction():
    """At t=1 the VP marginal is ~N(0,I): eps_gauss(x) ~ x."""
    cfg = model.ModelCfg(dim=16, hidden=128, blocks=0, sde_kind="vp")
    x = jnp.ones((4, 16)) * 0.7
    t = jnp.ones(4)
    out = model.eps_gauss(x, t, cfg, jnp.zeros(16), jnp.ones(16))
    np.testing.assert_allclose(out, x * float(cfg.sde.marginal_std(1.0)), rtol=1e-3)


def test_eps_gauss_blocks_gradients():
    cfg = model.ModelCfg(dim=8, hidden=128, blocks=0, sde_kind="vp")

    def f(mu0):
        out = model.eps_gauss(jnp.ones((2, 8)), jnp.full((2,), 0.5), cfg, mu0, jnp.ones(8))
        return jnp.sum(out**2)

    g = jax.grad(f)(jnp.zeros(8))
    np.testing.assert_array_equal(g, jnp.zeros(8))


# --- DSM objective ----------------------------------------------------------------

def test_dsm_loss_finite_and_positive():
    cfg = model.ModelCfg(dim=96, hidden=128, blocks=1, sde_kind="vp")
    flat = jnp.asarray(model.init_params(0, cfg))
    key = jax.random.PRNGKey(3)
    x0 = jax.random.uniform(key, (16, 96), minval=-1.0, maxval=1.0)
    t = jnp.linspace(0.05, 0.95, 16)
    z = jax.random.normal(jax.random.fold_in(key, 1), (16, 96))
    loss = float(dsm_loss(flat, x0, t, z, cfg))
    assert np.isfinite(loss) and loss > 0.0


def test_dsm_loss_beats_no_baseline_at_init():
    """With eps_gauss + accurate stats, the init loss must beat both the
    naive zero predictor (loss 1.0) and the same net with wrong stats —
    especially at large t where the reverse-VP blow-up originated."""
    cfg = model.ModelCfg(dim=64, hidden=128, blocks=1, sde_kind="vp")
    key = jax.random.PRNGKey(7)
    x0 = 0.3 * jax.random.normal(key, (256, 64))
    flat = model.init_params(0, cfg, mu0=np.zeros(64), v0=np.full(64, 0.09))
    # silence the (randomly initialised) output projection so the loss
    # measures the eps_gauss baseline alone
    off = 0
    for name, shape in model.param_shapes(cfg):
        size = int(np.prod(shape))
        if name == "out_w":
            flat[off : off + size] = 0.0
        off += size
    flat = jnp.asarray(flat)
    z = jax.random.normal(jax.random.fold_in(key, 2), (256, 64))
    # large-t regime: the baseline is near-exact there
    t_hi = jax.random.uniform(jax.random.fold_in(key, 1), (256,), minval=0.7, maxval=1.0)
    loss_hi = float(dsm_loss(flat, x0, t_hi, z, cfg))
    assert loss_hi < 0.25, f"large-t loss {loss_hi} — baseline not effective"
    # over all t, still beats the zero predictor
    t_all = jax.random.uniform(jax.random.fold_in(key, 3), (256,), minval=1e-3, maxval=1.0)
    loss_all = float(dsm_loss(flat, x0, t_all, z, cfg))
    assert loss_all < 0.95, f"overall loss {loss_all}"


def test_short_training_reduces_loss():
    """Five hundred SGD steps on a tiny model must cut the DSM loss."""
    cfg = model.ModelCfg(dim=48, hidden=128, blocks=1, sde_kind="ve", sigma_max=10.0)
    key = jax.random.PRNGKey(1)
    x0_all = jax.random.uniform(jax.random.fold_in(key, 9), (512, 48))
    flat = jnp.asarray(
        model.init_params(
            0, cfg,
            mu0=np.asarray(x0_all.mean(0)),
            v0=np.asarray(x0_all.var(0)) + 1e-3,
        )
    )
    m, v = adam_init(flat.shape[0])

    @jax.jit
    def step(flat, m, v, i, key):
        key, k1, k2, k3 = jax.random.split(key, 4)
        idx = jax.random.randint(k1, (64,), 0, 512)
        t = jax.random.uniform(k2, (64,), minval=1e-5, maxval=1.0)
        z = jax.random.normal(k3, (64, 48))
        loss, g = jax.value_and_grad(dsm_loss)(flat, x0_all[idx], t, z, cfg)
        upd, m, v = adam_update(g, m, v, i, 2e-3)
        return flat - upd, m, v, key, loss

    first = None
    loss = None
    for i in range(1, 301):
        flat, m, v, key, loss = step(flat, m, v, jnp.float32(i), key)
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.9, f"{first} -> {float(loss)}"
