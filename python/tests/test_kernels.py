"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

The AOT artifacts are lowered from these kernels, so this is the
correctness signal for everything the Rust runtime serves. Hypothesis
sweeps shapes and value ranges; fixed cases pin the exact production
shapes used by the artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import em_update, err_norm, fused_block
from compile.kernels import ref

ATOL = 2e-5


def _key(seed):
    return jax.random.PRNGKey(seed)


# --- fused_block --------------------------------------------------------------

PROD_SHAPES = [
    (1, 768, 256), (16, 256, 256), (64, 256, 256),
    (16, 3072, 384), (64, 384, 384), (4, 128, 256),
]


@pytest.mark.parametrize("b,k,n", PROD_SHAPES)
def test_fused_block_production_shapes(b, k, n):
    kk = _key(b * 7 + k + n)
    x = jax.random.normal(kk, (b, k))
    w = jax.random.normal(kk, (k, n)) * 0.05
    bias = jax.random.normal(kk, (n,))
    m = jax.random.normal(kk, (b, n))
    np.testing.assert_allclose(
        fused_block(x, w, bias, m), ref.fused_block_ref(x, w, bias, m), atol=ATOL
    )


@settings(max_examples=15, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 8, 16]),
    k=st.sampled_from([128, 256, 384]),
    n=st.sampled_from([128, 256]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.01, 3.0),
)
def test_fused_block_hypothesis(b, k, n, seed, scale):
    kk = _key(seed)
    x = jax.random.normal(kk, (b, k)) * scale
    w = jax.random.normal(jax.random.fold_in(kk, 1), (k, n)) * 0.05
    bias = jax.random.normal(jax.random.fold_in(kk, 2), (n,))
    m = jax.random.normal(jax.random.fold_in(kk, 3), (b, n))
    np.testing.assert_allclose(
        fused_block(x, w, bias, m), ref.fused_block_ref(x, w, bias, m),
        atol=ATOL * max(1.0, scale),
    )


def test_fused_block_block_size_invariance():
    """Different tilings must give identical results (schedule != math)."""
    kk = _key(3)
    x = jax.random.normal(kk, (16, 256))
    w = jax.random.normal(kk, (256, 256)) * 0.05
    bias = jnp.zeros(256)
    m = jnp.zeros((16, 256))
    a = fused_block(x, w, bias, m, block_m=16, block_n=256)
    b = fused_block(x, w, bias, m, block_m=4, block_n=128)
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_fused_block_rejects_misaligned():
    with pytest.raises(AssertionError):
        fused_block(
            jnp.zeros((3, 256)), jnp.zeros((256, 256)), jnp.zeros(256),
            jnp.zeros((3, 256)), block_m=2,
        )


# --- em_update ------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    b=st.sampled_from([1, 4, 16, 64]),
    d=st.sampled_from([32, 768, 3072]),
    seed=st.integers(0, 2**31 - 1),
)
def test_em_update_hypothesis(b, d, seed):
    kk = _key(seed)
    x = jax.random.normal(kk, (b, d))
    u = jax.random.normal(jax.random.fold_in(kk, 1), (b, d))
    z = jax.random.normal(jax.random.fold_in(kk, 2), (b, d))
    a = jax.random.uniform(jax.random.fold_in(kk, 3), (b,), minval=-1.0)
    c = jax.random.uniform(jax.random.fold_in(kk, 4), (b,))
    np.testing.assert_allclose(
        em_update(x, u, z, a, c), ref.em_update_ref(x, u, z, a, c), atol=ATOL
    )


def test_em_update_zero_step_is_identity():
    """h=0 slots (inactive batch lanes in the coordinator) must not move."""
    kk = _key(0)
    x = jax.random.normal(kk, (8, 96))
    u = jax.random.normal(jax.random.fold_in(kk, 1), (8, 96))
    z = jax.random.normal(jax.random.fold_in(kk, 2), (8, 96))
    zero = jnp.zeros(8)
    np.testing.assert_allclose(em_update(x, u, z, zero, zero), x, atol=0)


def test_em_update_per_sample_independence():
    """Row i of the output depends only on row i of the inputs (§3.1.5)."""
    kk = _key(9)
    x = jax.random.normal(kk, (4, 64))
    u = jax.random.normal(jax.random.fold_in(kk, 1), (4, 64))
    z = jax.random.normal(jax.random.fold_in(kk, 2), (4, 64))
    a = jnp.array([0.1, 0.2, 0.3, 0.4])
    c = jnp.array([1.0, 2.0, 3.0, 4.0])
    full = em_update(x, u, z, a, c)
    for i in range(4):
        row = em_update(x[i : i + 1], u[i : i + 1], z[i : i + 1], a[i : i + 1], c[i : i + 1])
        np.testing.assert_allclose(full[i], row[0], atol=1e-6)


# --- err_norm -------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    b=st.sampled_from([1, 4, 16]),
    d=st.sampled_from([64, 768]),
    seed=st.integers(0, 2**31 - 1),
    ea=st.floats(1e-4, 0.1),
    er=st.floats(1e-3, 0.5),
)
def test_err_norm_hypothesis(b, d, seed, ea, er):
    kk = _key(seed)
    xp = jax.random.normal(kk, (b, d))
    xpp = xp + 0.01 * jax.random.normal(jax.random.fold_in(kk, 1), (b, d))
    xprev = jax.random.normal(jax.random.fold_in(kk, 2), (b, d))
    eav = jnp.array([ea], jnp.float32)
    erv = jnp.full((b,), er, jnp.float32)
    np.testing.assert_allclose(
        err_norm(xp, xpp, xprev, eav, erv),
        ref.err_norm_ref(xp, xpp, xprev, eav, erv),
        rtol=1e-5, atol=1e-6,
    )


def test_err_norm_identical_proposals_zero():
    x = jnp.ones((4, 32))
    e = err_norm(x, x, x, jnp.array([0.01]), jnp.full((4,), 0.1))
    np.testing.assert_allclose(e, jnp.zeros(4), atol=0)


def test_err_norm_scale_invariance_of_accept():
    """E2 <= 1 acceptance is what matters: doubling the tolerance halves E2."""
    kk = _key(5)
    xp = jax.random.normal(kk, (4, 128))
    xpp = xp + 0.05
    xprev = xp
    # large eps_abs dominates => delta == eps_abs => exact halving
    e1 = err_norm(xp, xpp, xprev, jnp.array([10.0]), jnp.full((4,), 0.01))
    e2 = err_norm(xp, xpp, xprev, jnp.array([20.0]), jnp.full((4,), 0.01))
    np.testing.assert_allclose(e1, 2 * e2, rtol=1e-6)


def test_err_norm_single_pixel_l2_vs_linf():
    """Paper §3.1.3: one bad pixel must not dominate the l2 norm — E2 grows
    like sqrt(1/n), not like the pixel error itself."""
    d = 1024
    xp = jnp.zeros((1, d))
    xpp = xp.at[0, 0].set(1.0)  # one huge local error
    e = err_norm(xp, xpp, xp, jnp.array([1.0]), jnp.zeros((1,)))
    assert float(e[0]) == pytest.approx(1.0 / np.sqrt(d), rel=1e-5)
