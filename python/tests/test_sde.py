"""L2 process math: VE/VP schedules, transition kernels, and the numeric
fixtures shared with the Rust mirror (rust/src/sde) — both sides must
agree on these exact values (see rust/src/sde/mod.rs tests)."""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.sde import VESDE, VPSDE, eps_abs_for, make_sde


def test_ve_sigma_endpoints():
    s = VESDE(sigma_max=50.0)
    assert float(s.sigma(0.0)) == pytest.approx(0.01)
    assert float(s.sigma(1.0)) == pytest.approx(50.0)


def test_ve_diffusion_matches_dsigma2_dt():
    """g(t)^2 == d[sigma^2]/dt (the defining property of the VE SDE)."""
    s = VESDE(sigma_max=50.0)
    for t in [0.1, 0.5, 0.9]:
        dt = 1e-5
        num = (float(s.sigma(t + dt)) ** 2 - float(s.sigma(t - dt)) ** 2) / (2 * dt)
        assert float(s.diffusion(t)) ** 2 == pytest.approx(num, rel=1e-3)


def test_vp_int_beta_closed_form():
    s = VPSDE()
    for t in [0.0, 0.25, 1.0]:
        # trapezoid integration of beta
        ts = np.linspace(0, t, 10001)
        num = np.trapezoid(s.beta_min + ts * (s.beta_max - s.beta_min), ts)
        assert float(s.int_beta(t)) == pytest.approx(float(num), abs=1e-5)


def test_vp_alpha_std_consistency():
    """mean_coef^2 + marginal_std^2 == 1 (variance preserving)."""
    s = VPSDE()
    for t in [0.05, 0.3, 0.7, 1.0]:
        a = float(s.alpha(t))
        std = float(s.marginal_std(t))
        assert a * a + std * std == pytest.approx(1.0, abs=1e-6)


def test_vp_prior_is_standard_normal():
    s = VPSDE()
    assert float(s.marginal_std(1.0)) == pytest.approx(1.0, abs=1e-4)
    # int beta over [0,1] = 0.1 + 0.5*19.9 = 10.05
    assert float(s.alpha(1.0)) == pytest.approx(math.exp(-0.5 * 10.05), rel=1e-5)


def test_eps_abs_one_colour_increment():
    assert eps_abs_for(VPSDE()) == pytest.approx(2.0 / 256)   # 0.0078 (paper)
    assert eps_abs_for(VESDE()) == pytest.approx(1.0 / 256)   # 0.0039 (paper)


@settings(max_examples=20, deadline=None)
@given(t=st.floats(1e-4, 1.0))
def test_ve_marginal_std_monotone(t):
    s = VESDE(sigma_max=30.0)
    assert float(s.marginal_std(t)) <= float(s.marginal_std(min(1.0, t + 0.01))) + 1e-9


@settings(max_examples=20, deadline=None)
@given(t=st.floats(1e-4, 1.0), kind=st.sampled_from(["ve", "vp"]))
def test_tweedie_var_is_marginal_var(t, kind):
    s = make_sde(kind, sigma_max=30.0)
    assert float(s.tweedie_var(t)) == pytest.approx(
        float(s.marginal_std(t)) ** 2, rel=1e-4
    )


# --- shared fixtures with rust/src/sde (keep in sync!) -------------------------

RUST_FIXTURES_VE = [  # (t, sigma, g)  for sigma_max=50
    (0.0, 0.01, 0.04127273),
    (0.25, 0.08408964, 0.347061),
    (0.5, 0.7071068, 2.918423),
    (0.75, 5.946036, 24.54091),
    (1.0, 50.0, 206.3637),
]

RUST_FIXTURES_VP = [  # (t, beta, alpha, std)
    (0.25, 5.075, 0.7236571, 0.6901596),
    (0.5, 10.05, 0.2811829, 0.9596542),
    (0.75, 15.025, 0.0586635, 0.9982778),
    (1.0, 20.0, 0.006571586, 0.9999784),
]


def test_rust_fixture_values_ve():
    s = VESDE(sigma_max=50.0)
    for t, sig, g in RUST_FIXTURES_VE:
        assert float(s.sigma(t)) == pytest.approx(sig, rel=1e-5)
        assert float(s.diffusion(t)) == pytest.approx(g, rel=1e-4)


def test_rust_fixture_values_vp():
    s = VPSDE()
    for t, beta, alpha, std in RUST_FIXTURES_VP:
        assert float(s.beta(t)) == pytest.approx(beta, rel=1e-6)
        assert float(s.alpha(t)) == pytest.approx(alpha, rel=1e-3)
        assert float(s.marginal_std(t)) == pytest.approx(std, abs=1e-5)
