"""Dataset generator: determinism, ranges, class balance, and the
sigma_max heuristic the VE models depend on."""

import numpy as np
import pytest

from compile import dataset as ds


@pytest.mark.parametrize("name", list(ds.SPECS))
def test_deterministic(name):
    a, la = ds.generate(name, 16)
    b, lb = ds.generate(name, 16)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(la, lb)


@pytest.mark.parametrize("name", list(ds.SPECS))
def test_range_and_shape(name):
    spec = ds.SPECS[name]
    x, y = ds.generate(name, 32)
    assert x.shape == (32, spec.dim)
    assert x.dtype == np.float32
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert y.min() >= 0 and y.max() < spec.n_classes


def test_seed_offset_gives_disjoint_split():
    a, _ = ds.generate("synth-cifar", 64)
    b, _ = ds.generate("synth-cifar", 64, seed_offset=77777)
    assert not np.allclose(a, b)


def test_classes_all_present():
    _, y = ds.generate("synth-cifar", 600)
    assert set(np.unique(y)) == set(range(ds.SPECS["synth-cifar"].n_classes))


def test_class_conditional_structure():
    """Class-conditional mean images must be distinguishable — otherwise
    the synthception classifier cannot learn and FID* is meaningless.
    (Raw pairwise distances are dominated by random palettes, so compare
    class means, which average the colour noise out.)"""
    x, y = ds.generate("synth-cifar", 1200)
    means = [x[y == c].mean(axis=0) for c in range(ds.SPECS["synth-cifar"].n_classes)]
    seps = [
        np.linalg.norm(means[a] - means[b])
        for a in range(len(means))
        for b in range(a + 1, len(means))
    ]
    # every pair of class means separated by a clear margin
    assert min(seps) > 0.15, f"min class-mean separation {min(seps):.3f}"


def test_max_pairwise_distance_bounds():
    x, _ = ds.generate("synth-cifar", 256)
    m = ds.max_pairwise_distance(x)
    d = x.shape[1]
    assert 0.0 < m <= np.sqrt(d)  # values in [0,1] bound the distance
    # must exceed typical pair distance
    assert m > np.linalg.norm(x[0] - x[1])


def test_max_pairwise_distance_exact_on_small():
    x = np.array([[0.0, 0.0], [3.0, 4.0], [1.0, 1.0]], np.float32)
    assert ds.max_pairwise_distance(x) == pytest.approx(5.0)
