"""AOT layer: HLO-text emission and the artifact ABI recorded in the
manifest. Uses a tiny throwaway config so it runs without `make artifacts`;
also cross-checks the real manifest when artifacts exist."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import (
    FUSED_BASES,
    fused_name,
    make_fused_programs,
    make_programs,
    program_specs,
    to_hlo_text,
)

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def tiny_cfg():
    return model.ModelCfg(dim=128, hidden=128, blocks=1, sde_kind="ve", sigma_max=10.0)


def test_hlo_text_emission(tiny_cfg):
    programs = make_programs(tiny_cfg)
    n = model.n_params(tiny_cfg)
    spec = (
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((4, 128), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.float32),
    )
    text = to_hlo_text(jax.jit(programs["score"]).lower(*spec))
    assert text.startswith("HloModule")
    assert "f32[4,128]" in text


def test_program_specs_cover_all_programs(tiny_cfg):
    buckets, args = program_specs(tiny_cfg, model.n_params(tiny_cfg))
    for program in ["score", "adaptive_step", "em_step", "pc_step",
                    "ddim_step", "ode_drift", "denoise"]:
        assert program in buckets
        spec = args(16, program)
        assert spec[0].shape == (model.n_params(tiny_cfg),)


def test_adaptive_step_abi(tiny_cfg):
    """The exact input ordering Rust's runtime::Program::adaptive relies on:
    (theta, x, xprev, t, h, z, eps_abs, eps_rel)."""
    _, args = program_specs(tiny_cfg, model.n_params(tiny_cfg))
    spec = args(8, "adaptive_step")
    shapes = [s.shape for s in spec]
    assert shapes == [
        (model.n_params(tiny_cfg),), (8, 128), (8, 128), (8,), (8,), (8, 128),
        (1,), (8,),
    ]


def test_pc_step_abi_and_ladder(tiny_cfg):
    """The input ordering the Rust FixedProgram builds for the pc pool:
    (theta, x, t, h, z1, z2, snr) with snr PER-LANE (shape [B]) so
    requests with different SNR targets co-batch and free lanes ride as
    no-ops — and pc_step rides the serving step ladder like em_step."""
    n = model.n_params(tiny_cfg)
    buckets, args = program_specs(tiny_cfg, n)
    spec = args(8, "pc_step")
    shapes = [s.shape for s in spec]
    assert shapes == [(n,), (8, 128), (8,), (8,), (8, 128), (8, 128), (8,)]
    assert buckets["pc_step"] == buckets["em_step"]


def test_pc_step_is_noop_for_free_lanes(tiny_cfg):
    """A free serving lane feeds pc_step h=0, z1=z2=0, snr=0 and must get
    its row back bit-identically (the continuous-batching contract)."""
    programs = make_programs(tiny_cfg)
    n = model.n_params(tiny_cfg)
    rng = np.random.default_rng(0)
    flat = jnp.asarray(rng.normal(size=(n,), scale=0.05), jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 128)), jnp.float32)
    t = jnp.full((4,), 0.7, jnp.float32)
    zeros = jnp.zeros((4, 128), jnp.float32)
    out = programs["pc_step"](
        flat, x, t, jnp.zeros((4,), jnp.float32), zeros, zeros,
        jnp.zeros((4,), jnp.float32),
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_fused_abi(tiny_cfg):
    """The stacked input ordering Rust's fused dispatch path builds:
    (theta, x[B,D], t[k,B], t2[k,B], z[k,B,D] x noise_inputs, snr[B]?) —
    x stays [B,D] (it is the device-resident slab), everything per-node
    arrives stacked node-major."""
    n = model.n_params(tiny_cfg)
    buckets, args = program_specs(tiny_cfg, n)
    shapes = [s.shape for s in args(4, fused_name("em_step", 8))]
    assert shapes == [(n,), (4, 128), (8, 4), (8, 4), (8, 4, 128)]
    shapes = [s.shape for s in args(4, fused_name("pc_step", 4))]
    assert shapes == [(n,), (4, 128), (4, 4), (4, 4), (4, 4, 128),
                      (4, 4, 128), (4,)]
    shapes = [s.shape for s in args(2, fused_name("ddim_step", 8))]
    assert shapes == [(n,), (2, 128), (8, 2), (8, 2)]
    with pytest.raises(KeyError):
        args(4, "em_stepk")  # no bare-k names


def _fused_parity_case(cfg, base, k=4, b=3, seed=3):
    """Fused k-step vs k sequential full-batch single steps with the
    engine's host-side live-row fold. Lane i runs real[i] real nodes;
    pad rows carry the no-op defaults the Rust engine sends (t=1, h=0 /
    tn=t, no noise) and must come back bit-identical."""
    d = cfg.dim
    nz, has_snr = FUSED_BASES[base]
    rng = np.random.default_rng(seed)
    n = model.n_params(cfg)
    flat = jnp.asarray(rng.normal(size=(n,), scale=0.05), jnp.float32)
    x0 = np.asarray(rng.normal(size=(b, d)), np.float32)
    real = [k, k // 2, 0][:b]  # full lane, short lane, free lane
    t = np.ones((k, b), np.float32)
    t2 = np.zeros((k, b), np.float32) if base != "ddim_step" else t.copy()
    zs = [np.zeros((k, b, d), np.float32) for _ in range(nz)]
    h = 0.08
    for i, r in enumerate(real):
        for j in range(r):
            t[j, i] = 1.0 - h * j
            t2[j, i] = h if base != "ddim_step" else t[j, i] - h
            for z in zs:
                z[j, i] = rng.normal(size=(d,))
    snr = (np.full((b,), 0.16, np.float32),) if has_snr else ()

    fused = make_fused_programs(cfg)[base]
    got = np.asarray(fused(flat, jnp.asarray(x0), jnp.asarray(t),
                           jnp.asarray(t2), *map(jnp.asarray, zs), *snr))

    step = make_programs(cfg)[base]
    want = x0.copy()
    for j in range(k):
        out = np.asarray(step(flat, jnp.asarray(want), jnp.asarray(t[j]),
                              jnp.asarray(t2[j]),
                              *(jnp.asarray(z[j]) for z in zs), *snr))
        for i, r in enumerate(real):
            if j < r:  # the k=1 engine folds back live rows only
                want[i] = out[i]
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got[-1], x0[-1])  # free lane untouched


@pytest.mark.parametrize("base", ["em_step", "pc_step"])
def test_fused_matches_sequential_single_steps(tiny_cfg, base):
    _fused_parity_case(tiny_cfg, base)


def test_fused_ddim_matches_sequential_on_vp():
    # ddim is VP-only; its pad rows rely on the select (the divide/
    # re-multiply by alpha(t) is not the bitwise identity)
    cfg = model.ModelCfg(dim=128, hidden=128, blocks=1, sde_kind="vp",
                         sigma_max=10.0)
    _fused_parity_case(cfg, "ddim_step")


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_manifest_consistency():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for vname, v in man["variants"].items():
        meta = v["meta"]
        cfg = model.ModelCfg(
            dim=meta["dim"], hidden=meta["hidden"], blocks=meta["blocks"],
            sde_kind=meta["sde_kind"], sigma_max=meta["sigma_max"],
        )
        assert model.n_params(cfg) == meta["n_params"]
        for prog in v["programs"]:
            path = os.path.join(ART, prog["file"])
            assert os.path.exists(path), path
            assert prog["inputs"][0] == [meta["n_params"]]


@needs_artifacts
def test_params_bin_size_matches_meta():
    pdir = os.path.join(ART, "params")
    for fn in os.listdir(pdir):
        if not fn.endswith(".meta.json"):
            continue
        with open(os.path.join(pdir, fn)) as f:
            meta = json.load(f)
        binpath = os.path.join(pdir, fn.replace(".meta.json", ".bin"))
        assert os.path.getsize(binpath) == meta["n_params"] * 4
