"""AOT layer: HLO-text emission and the artifact ABI recorded in the
manifest. Uses a tiny throwaway config so it runs without `make artifacts`;
also cross-checks the real manifest when artifacts exist."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import make_programs, program_specs, to_hlo_text

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def tiny_cfg():
    return model.ModelCfg(dim=128, hidden=128, blocks=1, sde_kind="ve", sigma_max=10.0)


def test_hlo_text_emission(tiny_cfg):
    programs = make_programs(tiny_cfg)
    n = model.n_params(tiny_cfg)
    spec = (
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((4, 128), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.float32),
    )
    text = to_hlo_text(jax.jit(programs["score"]).lower(*spec))
    assert text.startswith("HloModule")
    assert "f32[4,128]" in text


def test_program_specs_cover_all_programs(tiny_cfg):
    buckets, args = program_specs(tiny_cfg, model.n_params(tiny_cfg))
    for program in ["score", "adaptive_step", "em_step", "pc_step",
                    "ddim_step", "ode_drift", "denoise"]:
        assert program in buckets
        spec = args(16, program)
        assert spec[0].shape == (model.n_params(tiny_cfg),)


def test_adaptive_step_abi(tiny_cfg):
    """The exact input ordering Rust's runtime::Program::adaptive relies on:
    (theta, x, xprev, t, h, z, eps_abs, eps_rel)."""
    _, args = program_specs(tiny_cfg, model.n_params(tiny_cfg))
    spec = args(8, "adaptive_step")
    shapes = [s.shape for s in spec]
    assert shapes == [
        (model.n_params(tiny_cfg),), (8, 128), (8, 128), (8,), (8,), (8, 128),
        (1,), (8,),
    ]


def test_pc_step_abi_and_ladder(tiny_cfg):
    """The input ordering the Rust FixedProgram builds for the pc pool:
    (theta, x, t, h, z1, z2, snr) with snr PER-LANE (shape [B]) so
    requests with different SNR targets co-batch and free lanes ride as
    no-ops — and pc_step rides the serving step ladder like em_step."""
    n = model.n_params(tiny_cfg)
    buckets, args = program_specs(tiny_cfg, n)
    spec = args(8, "pc_step")
    shapes = [s.shape for s in spec]
    assert shapes == [(n,), (8, 128), (8,), (8,), (8, 128), (8, 128), (8,)]
    assert buckets["pc_step"] == buckets["em_step"]


def test_pc_step_is_noop_for_free_lanes(tiny_cfg):
    """A free serving lane feeds pc_step h=0, z1=z2=0, snr=0 and must get
    its row back bit-identically (the continuous-batching contract)."""
    programs = make_programs(tiny_cfg)
    n = model.n_params(tiny_cfg)
    rng = np.random.default_rng(0)
    flat = jnp.asarray(rng.normal(size=(n,), scale=0.05), jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 128)), jnp.float32)
    t = jnp.full((4,), 0.7, jnp.float32)
    zeros = jnp.zeros((4, 128), jnp.float32)
    out = programs["pc_step"](
        flat, x, t, jnp.zeros((4,), jnp.float32), zeros, zeros,
        jnp.zeros((4,), jnp.float32),
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_manifest_consistency():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for vname, v in man["variants"].items():
        meta = v["meta"]
        cfg = model.ModelCfg(
            dim=meta["dim"], hidden=meta["hidden"], blocks=meta["blocks"],
            sde_kind=meta["sde_kind"], sigma_max=meta["sigma_max"],
        )
        assert model.n_params(cfg) == meta["n_params"]
        for prog in v["programs"]:
            path = os.path.join(ART, prog["file"])
            assert os.path.exists(path), path
            assert prog["inputs"][0] == [meta["n_params"]]


@needs_artifacts
def test_params_bin_size_matches_meta():
    pdir = os.path.join(ART, "params")
    for fn in os.listdir(pdir):
        if not fn.endswith(".meta.json"):
            continue
        with open(os.path.join(pdir, fn)) as f:
            meta = json.load(f)
        binpath = os.path.join(pdir, fn.replace(".meta.json", ".bin"))
        assert os.path.getsize(binpath) == meta["n_params"] * 4
