"""AOT layer: HLO-text emission and the artifact ABI recorded in the
manifest. Uses a tiny throwaway config so it runs without `make artifacts`;
also cross-checks the real manifest when artifacts exist."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import (
    FUSED_BASES,
    fused_name,
    make_adaptive_fused,
    make_fused_programs,
    make_programs,
    program_specs,
    to_hlo_text,
)

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def tiny_cfg():
    return model.ModelCfg(dim=128, hidden=128, blocks=1, sde_kind="ve", sigma_max=10.0)


def test_hlo_text_emission(tiny_cfg):
    programs = make_programs(tiny_cfg)
    n = model.n_params(tiny_cfg)
    spec = (
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((4, 128), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.float32),
    )
    text = to_hlo_text(jax.jit(programs["score"]).lower(*spec))
    assert text.startswith("HloModule")
    assert "f32[4,128]" in text


def test_program_specs_cover_all_programs(tiny_cfg):
    buckets, args = program_specs(tiny_cfg, model.n_params(tiny_cfg))
    for program in ["score", "adaptive_step", "em_step", "pc_step",
                    "ddim_step", "ode_drift", "denoise"]:
        assert program in buckets
        spec = args(16, program)
        assert spec[0].shape == (model.n_params(tiny_cfg),)


def test_adaptive_step_abi(tiny_cfg):
    """The exact input ordering Rust's runtime::Program::adaptive relies on:
    (theta, x, xprev, t, h, z, eps_abs, eps_rel)."""
    _, args = program_specs(tiny_cfg, model.n_params(tiny_cfg))
    spec = args(8, "adaptive_step")
    shapes = [s.shape for s in spec]
    assert shapes == [
        (model.n_params(tiny_cfg),), (8, 128), (8, 128), (8,), (8,), (8, 128),
        (1,), (8,),
    ]


def test_pc_step_abi_and_ladder(tiny_cfg):
    """The input ordering the Rust FixedProgram builds for the pc pool:
    (theta, x, t, h, z1, z2, snr) with snr PER-LANE (shape [B]) so
    requests with different SNR targets co-batch and free lanes ride as
    no-ops — and pc_step rides the serving step ladder like em_step."""
    n = model.n_params(tiny_cfg)
    buckets, args = program_specs(tiny_cfg, n)
    spec = args(8, "pc_step")
    shapes = [s.shape for s in spec]
    assert shapes == [(n,), (8, 128), (8,), (8,), (8, 128), (8, 128), (8,)]
    assert buckets["pc_step"] == buckets["em_step"]


def test_pc_step_is_noop_for_free_lanes(tiny_cfg):
    """A free serving lane feeds pc_step h=0, z1=z2=0, snr=0 and must get
    its row back bit-identically (the continuous-batching contract)."""
    programs = make_programs(tiny_cfg)
    n = model.n_params(tiny_cfg)
    rng = np.random.default_rng(0)
    flat = jnp.asarray(rng.normal(size=(n,), scale=0.05), jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 128)), jnp.float32)
    t = jnp.full((4,), 0.7, jnp.float32)
    zeros = jnp.zeros((4, 128), jnp.float32)
    out = programs["pc_step"](
        flat, x, t, jnp.zeros((4,), jnp.float32), zeros, zeros,
        jnp.zeros((4,), jnp.float32),
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_fused_abi(tiny_cfg):
    """The stacked input ordering Rust's fused dispatch path builds:
    (theta, x[B,D], t[k,B], t2[k,B], z[k,B,D] x noise_inputs, snr[B]?) —
    x stays [B,D] (it is the device-resident slab), everything per-node
    arrives stacked node-major."""
    n = model.n_params(tiny_cfg)
    buckets, args = program_specs(tiny_cfg, n)
    shapes = [s.shape for s in args(4, fused_name("em_step", 8))]
    assert shapes == [(n,), (4, 128), (8, 4), (8, 4), (8, 4, 128)]
    shapes = [s.shape for s in args(4, fused_name("pc_step", 4))]
    assert shapes == [(n,), (4, 128), (4, 4), (4, 4), (4, 4, 128),
                      (4, 4, 128), (4,)]
    shapes = [s.shape for s in args(2, fused_name("ddim_step", 8))]
    assert shapes == [(n,), (2, 128), (8, 2), (8, 2)]
    with pytest.raises(KeyError):
        args(4, "em_stepk")  # no bare-k names


def _fused_parity_case(cfg, base, k=4, b=3, seed=3):
    """Fused k-step vs k sequential full-batch single steps with the
    engine's host-side live-row fold. Lane i runs real[i] real nodes;
    pad rows carry the no-op defaults the Rust engine sends (t=1, h=0 /
    tn=t, no noise) and must come back bit-identical."""
    d = cfg.dim
    nz, has_snr = FUSED_BASES[base]
    rng = np.random.default_rng(seed)
    n = model.n_params(cfg)
    flat = jnp.asarray(rng.normal(size=(n,), scale=0.05), jnp.float32)
    x0 = np.asarray(rng.normal(size=(b, d)), np.float32)
    real = [k, k // 2, 0][:b]  # full lane, short lane, free lane
    t = np.ones((k, b), np.float32)
    t2 = np.zeros((k, b), np.float32) if base != "ddim_step" else t.copy()
    zs = [np.zeros((k, b, d), np.float32) for _ in range(nz)]
    h = 0.08
    for i, r in enumerate(real):
        for j in range(r):
            t[j, i] = 1.0 - h * j
            t2[j, i] = h if base != "ddim_step" else t[j, i] - h
            for z in zs:
                z[j, i] = rng.normal(size=(d,))
    snr = (np.full((b,), 0.16, np.float32),) if has_snr else ()

    fused = make_fused_programs(cfg)[base]
    got = np.asarray(fused(flat, jnp.asarray(x0), jnp.asarray(t),
                           jnp.asarray(t2), *map(jnp.asarray, zs), *snr))

    step = make_programs(cfg)[base]
    want = x0.copy()
    for j in range(k):
        out = np.asarray(step(flat, jnp.asarray(want), jnp.asarray(t[j]),
                              jnp.asarray(t2[j]),
                              *(jnp.asarray(z[j]) for z in zs), *snr))
        for i, r in enumerate(real):
            if j < r:  # the k=1 engine folds back live rows only
                want[i] = out[i]
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got[-1], x0[-1])  # free lane untouched


@pytest.mark.parametrize("base", ["em_step", "pc_step"])
def test_fused_matches_sequential_single_steps(tiny_cfg, base):
    _fused_parity_case(tiny_cfg, base)


def test_fused_ddim_matches_sequential_on_vp():
    # ddim is VP-only; its pad rows rely on the select (the divide/
    # re-multiply by alpha(t) is not the bitwise identity)
    cfg = model.ModelCfg(dim=128, hidden=128, blocks=1, sde_kind="vp",
                         sigma_max=10.0)
    _fused_parity_case(cfg, "ddim_step")


def test_adaptive_fused_abi(tiny_cfg):
    """The packed input ordering Rust's adaptive fused dispatch builds:
    (theta, slab[2BD+4kB], t f64[B], h f64[B], live[B], z[k,B,D],
    eps_abs[1], eps_rel[B], actrl f64[3]) — the slab packs
    x | xprev | t_log | h_log | err_log | accept_log, and the f64
    vectors let the on-device controller evolve in host precision."""
    n = model.n_params(tiny_cfg)
    _, args = program_specs(tiny_cfg, n)
    spec = args(4, fused_name("adaptive_step", 8))
    shapes = [s.shape for s in spec]
    assert shapes == [(n,), (2 * 4 * 128 + 4 * 8 * 4,), (4,), (4,), (4,),
                      (8, 4, 128), (1,), (4,), (3,)]
    dtypes = [s.dtype for s in spec]
    assert [str(d) for d in dtypes] == [
        "float32", "float32", "float64", "float64", "float32",
        "float32", "float32", "float32", "float64",
    ]


def _adaptive_fused_parity_case(cfg, k=4, b=3, seed=7, t_hot=1.0,
                                eps=0.05, t_conv=None):
    """Fused adaptive fold vs k sequential adaptive_step calls driven by
    the host controller replayed in f64 (bit-for-bit the Rust fold in
    AdaptiveProgram::step). Lane b-1 is dead (live=0) and must come back
    untouched with zeroed log entries; mid-sequence rejections and
    convergence must match the host's accept/reject/controller decisions
    exactly."""
    d = cfg.dim
    rng = np.random.default_rng(seed)
    n = model.n_params(cfg)
    flat = jnp.asarray(rng.normal(size=(n,), scale=0.05), jnp.float32)
    theta = np.asarray(flat)
    x0 = rng.normal(size=(b, d)).astype(np.float32)
    t0 = np.full(b, t_hot, np.float64)
    if t_conv is not None:
        t0[1] = t_conv  # lane 1 converges mid-dispatch
    h0 = np.full(b, 0.01, np.float64)
    live = np.ones(b, np.float32)
    live[-1] = 0.0
    z = rng.normal(size=(k, b, d)).astype(np.float32)
    ea = np.array([eps], np.float32)
    er = np.full(b, eps, np.float32)
    t_eps, safety, r_exp = 1e-3, 0.9, 0.9
    actrl = np.array([t_eps, safety, r_exp], np.float64)

    # host reference: f64 controller around the single-attempt kernel
    astep = jax.jit(make_programs(cfg)["adaptive_step"])
    x, xp = x0.copy(), x0.copy()
    t, h = t0.copy(), h0.copy()
    alive = live > 0
    logs = {key: np.zeros((k, b), np.float32) for key in "thea"}
    rejections = 0
    for j in range(k):
        hc = np.maximum(np.minimum(h, t - t_eps), 0.0)
        t32, h32 = t.astype(np.float32), hc.astype(np.float32)
        xpp, xpr, e2 = map(
            np.asarray, astep(theta, x, xp, t32, h32, z[j], ea, er)
        )
        for i in range(b):
            if not alive[i]:
                continue
            err = float(np.float64(e2[i]))
            acc = err <= 1.0
            logs["t"][j, i], logs["h"][j, i] = t32[i], h32[i]
            logs["e"][j, i], logs["a"][j, i] = e2[i], float(acc)
            if acc:
                x[i], xp[i] = xpp[i], xpr[i]
                t[i] = t[i] - hc[i]
                if t[i] <= t_eps + 1e-12:
                    alive[i] = False
            else:
                rejections += 1
            grow = safety * max(err, 1e-12) ** (-r_exp)
            h[i] = min(hc[i] * grow, max(t[i] - t_eps, 0.0))

    # fused device run on the packed slab
    slab = np.concatenate(
        [x0.reshape(-1), x0.reshape(-1), np.zeros(4 * k * b, np.float32)]
    )
    with jax.experimental.enable_x64():
        out = np.asarray(
            jax.jit(make_adaptive_fused(cfg))(
                theta, slab, t0, h0, live, z, ea, er, actrl
            )
        )
    fx = out[: b * d].reshape(b, d)
    fxp = out[b * d : 2 * b * d].reshape(b, d)
    flog = out[2 * b * d :].reshape(4, k, b)
    np.testing.assert_array_equal(fx, x)
    np.testing.assert_array_equal(fxp, xp)
    for li, key in enumerate("thea"):
        np.testing.assert_array_equal(flog[li], logs[key])
    np.testing.assert_array_equal(fx[-1], x0[-1])  # dead lane untouched
    assert (flog[:, :, -1] == 0).all()  # ...and logged as zeros
    return rejections, alive


def test_adaptive_fused_matches_host_controller(tiny_cfg):
    rejections, _ = _adaptive_fused_parity_case(tiny_cfg)
    assert rejections > 0  # the case must exercise the reject branch


def test_adaptive_fused_mid_dispatch_convergence(tiny_cfg):
    # lane 1 starts near t_eps so it converges before the k attempts run
    # out; the remaining attempts must be select-masked no-ops
    _, alive = _adaptive_fused_parity_case(
        tiny_cfg, eps=50.0, t_conv=0.02
    )
    assert not alive[1]  # the case must exercise mid-dispatch convergence


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_manifest_consistency():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for vname, v in man["variants"].items():
        meta = v["meta"]
        cfg = model.ModelCfg(
            dim=meta["dim"], hidden=meta["hidden"], blocks=meta["blocks"],
            sde_kind=meta["sde_kind"], sigma_max=meta["sigma_max"],
        )
        assert model.n_params(cfg) == meta["n_params"]
        for prog in v["programs"]:
            path = os.path.join(ART, prog["file"])
            assert os.path.exists(path), path
            assert prog["inputs"][0] == [meta["n_params"]]


@needs_artifacts
def test_params_bin_size_matches_meta():
    pdir = os.path.join(ART, "params")
    for fn in os.listdir(pdir):
        if not fn.endswith(".meta.json"):
            continue
        with open(os.path.join(pdir, fn)) as f:
            meta = json.load(f)
        binpath = os.path.join(pdir, fn.replace(".meta.json", ".bin"))
        assert os.path.getsize(binpath) == meta["n_params"] * 4
