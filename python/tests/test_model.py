"""L2 model: shapes, kernel-vs-ref equivalence through the full network,
param layout stability (the flat-vector ABI the Rust runtime depends on),
and the solver-step program semantics lowered by aot.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import make_programs


@pytest.fixture(scope="module")
def small_cfg():
    return model.ModelCfg(dim=768, hidden=256, blocks=2, sde_kind="vp")


@pytest.fixture(scope="module")
def small_flat(small_cfg):
    return jnp.asarray(model.init_params(3, small_cfg))


def test_param_count_formula(small_cfg):
    expected = sum(int(np.prod(s)) for _, s in model.param_shapes(small_cfg))
    assert model.n_params(small_cfg) == expected
    assert model.init_params(0, small_cfg).shape == (expected,)


def test_param_layout_roundtrip(small_cfg):
    flat = np.arange(model.n_params(small_cfg), dtype=np.float32)
    p = model.unflatten(jnp.asarray(flat), small_cfg)
    # first entry is temb_w, stored row-major from offset 0
    assert float(p["temb_w"].reshape(-1)[0]) == 0.0
    assert float(p["temb_w"].reshape(-1)[-1]) == model.TEMB_DIM * small_cfg.hidden - 1
    # total coverage, no overlap
    total = sum(int(np.prod(v.shape)) for v in p.values())
    assert total == model.n_params(small_cfg)


def test_score_shapes(small_cfg, small_flat):
    x = jnp.zeros((4, 768))
    t = jnp.full((4,), 0.5)
    s = model.score(small_flat, x, t, small_cfg)
    assert s.shape == (4, 768)
    assert bool(jnp.all(jnp.isfinite(s)))


def test_kernel_path_equals_ref_path(small_cfg, small_flat):
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (8, 768))
    t = jnp.linspace(0.05, 0.95, 8)
    a = model.score(small_flat, x, t, small_cfg, use_kernel=True)
    b = model.score(small_flat, x, t, small_cfg, use_kernel=False)
    np.testing.assert_allclose(a, b, atol=1e-4)


def test_fourier_features_range():
    t = jnp.linspace(0, 1, 32)
    ff = model.fourier_features(t)
    assert ff.shape == (32, model.TEMB_DIM)
    assert float(jnp.abs(ff).max()) <= 1.0 + 1e-6


def test_init_residual_blocks_start_dead(small_cfg):
    """w2 zero-init => at init the net is input-proj + output-proj only;
    eps prediction must be identical with 2 and 0 effective blocks."""
    flat = jnp.asarray(model.init_params(3, small_cfg))
    cfg0 = model.ModelCfg(dim=768, hidden=256, blocks=0, sde_kind="vp")
    # build a 0-block flat vector reusing the shared prefix + suffix
    p = model.unflatten(flat, small_cfg)
    chunks = [p["temb_w"], p["temb_b"], p["in_w"], p["in_b"], p["out_w"], p["out_b"],
              p["mu0"], p["v0"]]
    flat0 = jnp.concatenate([c.reshape(-1) for c in chunks])
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 768))
    t = jnp.full((4,), 0.3)
    np.testing.assert_allclose(
        model.apply_eps_ref(flat, x, t, small_cfg),
        model.apply_eps_ref(flat0, x, t, cfg0),
        atol=1e-5,
    )


# --- solver-step program semantics (what aot.py lowers) ------------------------

@pytest.fixture(scope="module")
def programs(small_cfg):
    return make_programs(small_cfg)


def test_adaptive_step_zero_h_keeps_state(programs, small_flat):
    """h=0 lanes: x' == x'' == x and E2 == 0 (inactive coordinator slots)."""
    k = jax.random.PRNGKey(2)
    x = jax.random.normal(k, (4, 768))
    t = jnp.full((4,), 0.5)
    h = jnp.zeros(4)
    z = jax.random.normal(k, (4, 768))
    ea, er = jnp.array([0.0078]), jnp.full((4,), 0.01)
    xpp, xp, e2 = programs["adaptive_step"](small_flat, x, x, t, h, z, ea, er)
    np.testing.assert_allclose(xp, x, atol=1e-6)
    np.testing.assert_allclose(xpp, x, atol=1e-6)
    np.testing.assert_allclose(e2, jnp.zeros(4), atol=1e-6)


def test_adaptive_step_proposal_is_em(programs, small_flat):
    """The x' output of adaptive_step must equal the em_step output for the
    same (x, t, h, z) — the pair shares its first score evaluation."""
    k = jax.random.PRNGKey(4)
    x = jax.random.normal(k, (4, 768))
    t = jnp.full((4,), 0.7)
    h = jnp.full((4,), 0.01)
    z = jax.random.normal(jax.random.fold_in(k, 1), (4, 768))
    _, xp, _ = programs["adaptive_step"](
        small_flat, x, x, t, h, z, jnp.array([0.0078]), jnp.full((4,), 0.01)
    )
    em = programs["em_step"](small_flat, x, t, h, z)
    np.testing.assert_allclose(xp, em, atol=1e-5)


def test_em_step_noise_scales_with_sqrt_h(programs, small_flat):
    """With score ~ finite, the stochastic term dominates as z doubles."""
    k = jax.random.PRNGKey(5)
    x = jax.random.normal(k, (2, 768))
    t = jnp.full((2,), 0.9)
    h = jnp.full((2,), 0.0004)
    z = jax.random.normal(jax.random.fold_in(k, 2), (2, 768))
    a = programs["em_step"](small_flat, x, t, h, z)
    b = programs["em_step"](small_flat, x, t, h, 2 * z)
    diff = b - a  # = sqrt(h) g z
    sde = model.ModelCfg(dim=768, hidden=256, blocks=2, sde_kind="vp").sde
    expect = jnp.sqrt(h)[:, None] * sde.diffusion(t)[:, None] * z
    np.testing.assert_allclose(diff, expect, rtol=2e-3, atol=2e-5)


def test_ddim_step_at_same_time_is_identity(programs, small_flat):
    k = jax.random.PRNGKey(6)
    x = jax.random.normal(k, (2, 768))
    t = jnp.full((2,), 0.5)
    out = programs["ddim_step"](small_flat, x, t, t)
    np.testing.assert_allclose(out, x, atol=1e-4)


def test_denoise_vp_rescales_by_alpha(programs, small_flat, small_cfg):
    """Tweedie: x0 = (x + var * s) / alpha (paper App. D corrected form)."""
    sde = small_cfg.sde
    k = jax.random.PRNGKey(7)
    x = jax.random.normal(k, (2, 768))
    t = jnp.full((2,), sde.t_eps)
    s = model.score(small_flat, x, t, small_cfg)
    expect = (x + sde.tweedie_var(t)[:, None] * s) / sde.mean_coef(t)[:, None]
    np.testing.assert_allclose(
        programs["denoise"](small_flat, x, t), expect, atol=1e-5
    )


def test_ode_drift_is_half_noise_term(programs, small_flat, small_cfg):
    """prob-flow drift = f - 1/2 g^2 s; reverse-SDE drift = f - g^2 s.
    So (em_drift - ode_drift) == ode_drift - f."""
    sde = small_cfg.sde
    k = jax.random.PRNGKey(8)
    x = jax.random.normal(k, (2, 768))
    t = jnp.full((2,), 0.6)
    s = model.score(small_flat, x, t, small_cfg)
    g2 = sde.diffusion(t) ** 2
    f = sde.drift(x, t)
    expect = f - 0.5 * g2[:, None] * s
    np.testing.assert_allclose(programs["ode_drift"](small_flat, x, t), expect, atol=1e-5)
