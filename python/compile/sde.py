"""Forward diffusion processes (paper §2.2-2.3).

Variance Exploding (VE) and Variance Preserving (VP) SDEs with the exact
parameterisations of Song et al. 2020a used by the paper:

  VE:  dx = sqrt(d[sigma^2(t)]/dt) dw,   sigma(t) = s_min (s_max/s_min)^t
  VP:  dx = -1/2 beta(t) x dt + sqrt(beta(t)) dw,
       beta(t) = b_min + t (b_max - b_min),  b_min = 0.1, b_max = 20

Both are affine-drift, so the transition kernel p(x(t)|x(0)) is Gaussian
and sampled in closed form (used by the DSM training objective, Eq. 3).

This module is mirrored on the Rust side in ``rust/src/sde/`` for
host-side solver math; ``python/tests/test_sde.py`` and
``rust/tests`` pin the same numeric fixtures on both sides.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class VESDE:
    """Variance-exploding process. Data range [0, 1]."""

    sigma_min: float = 0.01
    sigma_max: float = 50.0

    kind: str = "ve"
    y_min: float = 0.0
    y_max: float = 1.0
    t_eps: float = 1e-5  # integration lower limit (paper App. D)

    def sigma(self, t):
        return self.sigma_min * (self.sigma_max / self.sigma_min) ** t

    def drift(self, x, t):
        return jnp.zeros_like(x)

    def diffusion(self, t):
        # g(t) = sigma(t) * sqrt(2 log(s_max/s_min))  (d[sigma^2]/dt = 2 sigma sigma')
        return self.sigma(t) * jnp.sqrt(
            2.0 * math.log(self.sigma_max / self.sigma_min)
        )

    # -- transition kernel x(t)|x(0) ~ N(mean, std^2 I) ----------------------
    def mean_coef(self, t):
        return jnp.ones_like(jnp.asarray(t))

    def marginal_std(self, t):
        return self.sigma(t)

    def prior_std(self) -> float:
        return self.sigma_max

    def tweedie_var(self, t):
        """Var[x(t)|x(0)] for the final denoising step (paper App. D)."""
        return self.sigma(t) ** 2


@dataclasses.dataclass(frozen=True)
class VPSDE:
    """Variance-preserving process. Data range [-1, 1]."""

    beta_min: float = 0.1
    beta_max: float = 20.0

    kind: str = "vp"
    y_min: float = -1.0
    y_max: float = 1.0
    t_eps: float = 1e-3

    def beta(self, t):
        return self.beta_min + t * (self.beta_max - self.beta_min)

    def int_beta(self, t):
        """integral of beta from 0 to t."""
        return self.beta_min * t + 0.5 * t**2 * (self.beta_max - self.beta_min)

    def drift(self, x, t):
        b = jnp.asarray(self.beta(t))
        return -0.5 * b[..., None] * x if b.ndim == 1 else -0.5 * b * x

    def diffusion(self, t):
        return jnp.sqrt(self.beta(t))

    def alpha(self, t):
        """mean coefficient exp(-1/2 int beta)."""
        return jnp.exp(-0.5 * self.int_beta(t))

    def mean_coef(self, t):
        return self.alpha(t)

    def marginal_std(self, t):
        return jnp.sqrt(jnp.maximum(1.0 - jnp.exp(-self.int_beta(t)), 1e-12))

    def prior_std(self) -> float:
        return 1.0

    def tweedie_var(self, t):
        return 1.0 - jnp.exp(-self.int_beta(t))


def make_sde(kind: str, sigma_max: float = 50.0):
    """Factory used by model/train/aot. ``sigma_max`` is dataset-dependent
    for VE (max pairwise distance, paper §2.2); ignored for VP."""
    if kind == "ve":
        return VESDE(sigma_max=sigma_max)
    if kind == "vp":
        return VPSDE()
    raise ValueError(f"unknown sde kind: {kind}")


def eps_abs_for(sde) -> float:
    """Paper §3.1.2: one 8-bit colour increment."""
    return (sde.y_max - sde.y_min) / 256.0
