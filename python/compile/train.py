"""Build-time training (denoising score matching, paper Eq. 3).

Trains the score networks and the synthception FID classifiers on the
procedural datasets, with a from-scratch Adam (no optax offline) and
parameter EMA (standard for score models). Emits:

  artifacts/params/<variant>.bin        flat f32 LE parameter vector (EMA)
  artifacts/params/<variant>.meta.json  config + dataset stats
  artifacts/data/<dataset>.bin|.labels.bin|.meta.json   eval split for FID*

Run: cd python && python -m compile.train --variant vp --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import dataset as ds
from compile import fid_net, model
from compile import sde as sde_mod

EVAL_N = 4096  # eval-split size exported for reference FID* stats
TRAIN_N = 8192


# --- from-scratch Adam over a single flat vector -----------------------------

def adam_init(n):
    return jnp.zeros(n), jnp.zeros(n)


def adam_update(g, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mhat = m / (1 - b1**step)
    vhat = v / (1 - b2**step)
    return lr * mhat / (jnp.sqrt(vhat) + eps), m, v


def lr_at(step, base, warmup=100):
    return base * jnp.minimum(1.0, step / warmup)


# --- score-model training -----------------------------------------------------

def dsm_loss(flat, x0, t, z, cfg):
    """||eps_theta(x_t, t) - z||^2 with x_t from the closed-form kernel.
    Equivalent to Eq. 3 with lambda(t) = marginal_std(t)^2."""
    s = cfg.sde
    mean = s.mean_coef(t)[:, None] * x0
    xt = mean + s.marginal_std(t)[:, None] * z
    eps = model.apply_eps_ref(flat, xt, t, cfg)
    return jnp.mean(jnp.sum((eps - z) ** 2, axis=1)) / x0.shape[1]


def train_score(variant: model.Variant, out_dir: str, steps_override=None):
    spec = ds.SPECS[variant.dataset]
    x_train, _ = ds.generate(variant.dataset, TRAIN_N)
    sigma_max = ds.max_pairwise_distance(x_train)
    cfg = model.ModelCfg(
        dim=spec.dim,
        hidden=variant.hidden,
        blocks=variant.blocks,
        sde_kind=variant.sde_kind,
        sigma_max=sigma_max,
    )
    sde = cfg.sde
    # map to process data range: VE keeps [0,1], VP uses [-1,1]
    if sde.kind == "vp":
        x_train = 2.0 * x_train - 1.0

    flat = jnp.asarray(
        model.init_params(
            seed=7,
            cfg=cfg,
            mu0=x_train.mean(axis=0),
            v0=np.maximum(x_train.var(axis=0), 1e-4),
        )
    )
    m, v = adam_init(flat.shape[0])
    ema = flat
    steps = steps_override or variant.train_steps
    key = jax.random.PRNGKey(42)
    xt_all = jnp.asarray(x_train)

    @jax.jit
    def update(flat, m, v, ema, step, key):
        key, k1, k2, k3 = jax.random.split(key, 4)
        idx = jax.random.randint(k1, (variant.batch,), 0, xt_all.shape[0])
        x0 = xt_all[idx]
        t = jax.random.uniform(
            k2, (variant.batch,), minval=sde.t_eps, maxval=1.0
        )
        z = jax.random.normal(k3, x0.shape)
        loss, g = jax.value_and_grad(dsm_loss)(flat, x0, t, z, cfg)
        upd, m, v = adam_update(g, m, v, step, lr_at(step, variant.lr))
        flat = flat - upd
        ema = 0.999 * ema + 0.001 * flat
        return flat, m, v, ema, key, loss

    t0 = time.time()
    last = None
    for step in range(1, steps + 1):
        flat, m, v, ema, key, loss = update(flat, m, v, ema, jnp.float32(step), key)
        if step % 500 == 0 or step == 1:
            last = float(loss)
            print(f"[{variant.name}] step {step}/{steps} loss {last:.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)

    meta = {
        "name": variant.name,
        "kind": "score",
        "dataset": variant.dataset,
        "sde_kind": variant.sde_kind,
        "blocks": variant.blocks,
        "hidden": variant.hidden,
        "dim": spec.dim,
        "h": spec.h,
        "w": spec.w,
        "c": spec.c,
        "sigma_min": 0.01,
        "sigma_max": sigma_max,
        "beta_min": 0.1,
        "beta_max": 20.0,
        "y_min": sde.y_min,
        "y_max": sde.y_max,
        "t_eps": sde.t_eps,
        "n_params": int(flat.shape[0]),
        "train_steps": steps,
        "final_loss": last,
    }
    _save(out_dir, variant.name, np.asarray(ema, np.float32), meta)
    _export_dataset(variant.dataset, out_dir)


# --- FID classifier training ---------------------------------------------------

def train_fid(name: str, out_dir: str, steps_override=None):
    datasets, dim = fid_net.FIDNETS[name]
    xs, ys, off = [], [], 0
    for d in datasets:
        x, y = ds.generate(d, TRAIN_N // len(datasets))
        xs.append(x)
        ys.append(y + off)
        off += ds.SPECS[d].n_classes
    x_train = jnp.asarray(np.concatenate(xs))
    y_train = jnp.asarray(np.concatenate(ys))
    cfg = fid_net.FidCfg(dim=dim, n_classes=off)
    flat = jnp.asarray(fid_net.init_params(seed=11, cfg=cfg))
    m, v = adam_init(flat.shape[0])
    steps = steps_override or 500
    key = jax.random.PRNGKey(5)

    def loss_fn(flat, x, y, key):
        x = x + 0.05 * jax.random.normal(key, x.shape)  # feature robustness
        _, logits = fid_net.features_logits(flat, x, cfg)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(logp[jnp.arange(x.shape[0]), y])

    @jax.jit
    def update(flat, m, v, step, key):
        key, k1, k2 = jax.random.split(key, 3)
        idx = jax.random.randint(k1, (256,), 0, x_train.shape[0])
        loss, g = jax.value_and_grad(loss_fn)(flat, x_train[idx], y_train[idx], k2)
        upd, m, v = adam_update(g, m, v, step, lr_at(step, 2e-3))
        return flat - upd, m, v, key, loss

    t0 = time.time()
    last = None
    for step in range(1, steps + 1):
        flat, m, v, key, loss = update(flat, m, v, jnp.float32(step), key)
        if step % 500 == 0 or step == 1:
            last = float(loss)
            print(f"[{name}] step {step}/{steps} loss {last:.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)

    # held-out accuracy as a sanity signal for FID* feature quality
    xe, ye, off = [], [], 0
    for d in datasets:
        x, y = ds.generate(d, 512, seed_offset=99991)
        xe.append(x)
        ye.append(y + off)
        off += ds.SPECS[d].n_classes
    _, logits = fid_net.features_logits(
        np.asarray(flat), jnp.asarray(np.concatenate(xe)), cfg
    )
    acc = float(jnp.mean(jnp.argmax(logits, 1) == jnp.asarray(np.concatenate(ye))))
    print(f"[{name}] held-out accuracy {acc:.3f}")

    meta = {
        "name": name,
        "kind": "fid",
        "datasets": datasets,
        "dim": dim,
        "n_classes": off,
        "feat_dim": fid_net.FEAT_DIM,
        "n_params": int(flat.shape[0]),
        "train_steps": steps,
        "final_loss": last,
        "holdout_acc": acc,
    }
    _save(out_dir, name, np.asarray(flat, np.float32), meta)


# --- I/O -----------------------------------------------------------------------

def _save(out_dir, name, flat: np.ndarray, meta: dict):
    pdir = os.path.join(out_dir, "params")
    os.makedirs(pdir, exist_ok=True)
    flat.astype("<f4").tofile(os.path.join(pdir, f"{name}.bin"))
    with open(os.path.join(pdir, f"{name}.meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"[{name}] saved {flat.shape[0]} params -> {pdir}/{name}.bin")


def _export_dataset(name: str, out_dir: str):
    """Eval split for Rust-side reference FID* stats (idempotent)."""
    ddir = os.path.join(out_dir, "data")
    os.makedirs(ddir, exist_ok=True)
    path = os.path.join(ddir, f"{name}.bin")
    if os.path.exists(path):
        return
    spec = ds.SPECS[name]
    x, y = ds.generate(name, EVAL_N, seed_offset=77777)  # disjoint from train
    x.astype("<f4").tofile(path)
    y.astype("<i4").tofile(os.path.join(ddir, f"{name}.labels.bin"))
    with open(os.path.join(ddir, f"{name}.meta.json"), "w") as f:
        json.dump(
            {"name": name, "n": EVAL_N, "dim": spec.dim, "h": spec.h,
             "w": spec.w, "c": spec.c, "n_classes": spec.n_classes}, f, indent=1,
        )
    print(f"[data] exported {name} eval split ({EVAL_N} x {spec.dim})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", required=True,
                    choices=list(model.VARIANTS) + list(fid_net.FIDNETS))
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    if args.variant in model.VARIANTS:
        train_score(model.VARIANTS[args.variant], args.out, args.steps)
    else:
        train_fid(args.variant, args.out, args.steps)


if __name__ == "__main__":
    main()
