"""Fused SDE-step update kernel: ``out = x + a*u + c*z`` with per-sample
scalars ``a, c`` (the Euler–Maruyama / improved-Euler state update of
Algorithm 1 and 2).

A naive jnp expression materialises h*drift, sqrt(h)*g*z and two adds as
separate [B, D] HBM tensors; this kernel is a single VPU pass (one load
per operand, one store). Per-sample scalars implement the paper's
§3.1.5 per-sample step sizes.

TPU mapping: rows tile to (bm, D) VMEM blocks (D <= 3072 -> 12 KiB/row);
pure VPU (8x128 lanes), no MXU. Lowered interpret=True on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, u_ref, z_ref, a_ref, c_ref, o_ref):
    a = a_ref[...][:, None]
    c = c_ref[...][:, None]
    o_ref[...] = x_ref[...] + a * u_ref[...] + c * z_ref[...]


def em_update(x, u, z, a, c, *, block_m: int | None = None):
    """x: [B,D] state, u: [B,D] drift term, z: [B,D] noise,
    a: [B] drift scale (e.g. -h), c: [B] noise scale (e.g. sqrt(h)*g)."""
    bsz, d = x.shape
    bm = block_m or min(bsz, 64)
    assert bsz % bm == 0
    grid = (bsz // bm,)
    row = pl.BlockSpec((bm, d), lambda i: (i, 0))
    scl = pl.BlockSpec((bm,), lambda i: (i,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[row, row, row, scl, scl],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((bsz, d), jnp.float32),
        interpret=True,
    )(x, u, z, a, c)
