"""Fused mixed-tolerance scaled-l2 error norm (Algorithm 1's delta & E2).

  delta = max(eps_abs, eps_rel * max(|x'|, |x'_prev|))        (paper Eq. 5)
  E2    = sqrt(mean_i ((x' - x'')_i / delta_i)^2)             (scaled l2)

One pass over three [B, D] operands producing a [B] result — the paper's
per-sample error (each image keeps its own step size, §3.1.5). eps_abs is
a runtime scalar ([1] array); eps_rel is a **per-sample vector** ([B]) so
the serving coordinator can continuously batch requests with different
tolerances into one step executable.

TPU mapping: row-tiled VPU reduction, (bm, D) blocks, lane-sum then
sqrt on the scalar unit. Lowered interpret=True on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xp_ref, xpp_ref, xprev_ref, ea_ref, er_ref, o_ref):
    xp = xp_ref[...]
    er = er_ref[...][:, None]
    delta = jnp.maximum(
        ea_ref[0], er * jnp.maximum(jnp.abs(xp), jnp.abs(xprev_ref[...]))
    )
    r = (xp - xpp_ref[...]) / delta
    o_ref[...] = jnp.sqrt(jnp.mean(r * r, axis=1))


def err_norm(xp, xpp, xprev, eps_abs, eps_rel, *, block_m: int | None = None):
    """xp, xpp, xprev: [B,D]; eps_abs: [1]; eps_rel: [B]. Returns E2 [B]."""
    bsz, d = xp.shape
    bm = block_m or min(bsz, 64)
    assert bsz % bm == 0
    grid = (bsz // bm,)
    row = pl.BlockSpec((bm, d), lambda i: (i, 0))
    one = pl.BlockSpec((1,), lambda i: (0,))
    vec = pl.BlockSpec((bm,), lambda i: (i,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[row, row, row, one, vec],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bsz,), jnp.float32),
        interpret=True,
    )(xp, xpp, xprev, eps_abs, eps_rel)
